//! Quickstart: load the AOT artifacts, train the HDC classifier on the tiny
//! synthetic dataset, classify with progressive search, and print the chip
//! model's latency/energy estimate for what just ran.
//!
//!     make artifacts && cargo run --release --example quickstart

use clo_hdnn::data::Dataset;
use clo_hdnn::hdc::{HdClassifier, ProgressiveSearch, Trainer};
use clo_hdnn::hdc::HdBackend;
use clo_hdnn::runtime::{Engine, Manifest, PjrtBackend};
use clo_hdnn::sim::{Chip, Mode};
use clo_hdnn::util::stats::fmt_secs;

fn main() -> clo_hdnn::Result<()> {
    // 1. open the artifact directory and start the PJRT engine
    let dir = Manifest::default_dir();
    let mut engine = Engine::load(&dir)?;
    println!("engine up on {} ({} executables in manifest)",
             engine.platform(), engine.manifest.executables.len());

    // 2. build the HD classifier on the AOT backend (Pallas kernels inside)
    let backend = PjrtBackend::new(&mut engine, "tiny", 1)?;
    let cfg = backend.cfg().clone();
    let mut classifier = HdClassifier::new(
        Box::new(backend),
        ProgressiveSearch { tau: 0.5, min_segments: 1 },
    );

    // 3. gradient-free training: single pass + one mistake-driven epoch
    let train = Dataset::load(engine.manifest.dataset_path("ds_tiny_train")?)?;
    let test = Dataset::load(engine.manifest.dataset_path("ds_tiny_test")?)?;
    let idx: Vec<usize> = (0..train.n).collect();
    let report = Trainer { retrain_epochs: 1 }.train_indices(&mut classifier, &train, &idx)?;
    println!("trained on {} samples; retrain mistakes per epoch: {:?}",
             report.samples, report.mistakes);

    // 4. progressive inference
    let eval = classifier.evaluate(
        (0..test.n).map(|i| (test.sample(i).to_vec(), test.label(i))))?;
    println!(
        "accuracy {:.4} | {:.2}/{} segments used on average -> {:.1}% of the \
         encode+search work skipped (Fig.4)",
        eval.accuracy,
        eval.mean_segments,
        eval.total_segments,
        eval.complexity_reduction() * 100.0
    );

    // 5. what would this cost on the 40nm chip?
    let chip = Chip::default();
    for v in [0.7, 1.2] {
        let r = chip.simulate_inference(&cfg, Mode::Bypass,
                                        eval.mean_segments.round() as usize, None, v);
        println!(
            "chip model @ {:.1}V/{:.0}MHz: {} per inference, {:.3} uJ",
            r.op.voltage, r.op.freq_mhz, fmt_secs(r.latency_s), r.energy_j * 1e6
        );
    }
    Ok(())
}
