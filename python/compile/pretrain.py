"""WCFE pretraining + post-training weight clustering (Fig.7a).

Pretrains the small CNN front-end on the synthetic CIFAR-100-like dataset
with plain SGD+momentum (gradient training happens ONCE, at build time —
the chip never backprops; continual learning is handled by the HDC module),
then clusters each conv layer's weights with 1-D k-means into a
`clusters`-entry codebook (4-bit indices for the default 16).
"""

import numpy as np
import jax
import jax.numpy as jnp

from . import model as M


def init_params(wcfe, rng):
    """He-initialized conv stack + FC + (pretraining-only) classifier head."""
    chans = [wcfe.image_c, *wcfe.channels]
    params = {}
    for i in range(len(wcfe.channels)):
        fan_in = 9 * chans[i]
        params[f"conv{i + 1}"] = (rng.standard_normal((fan_in, chans[i + 1]))
                                  * np.sqrt(2.0 / fan_in)).astype(np.float32)
    params["fc"] = (rng.standard_normal((wcfe.channels[-1], wcfe.fc_out))
                    * np.sqrt(2.0 / wcfe.channels[-1])).astype(np.float32)
    params["head"] = (rng.standard_normal((wcfe.fc_out, wcfe.classes))
                      * np.sqrt(1.0 / wcfe.fc_out)).astype(np.float32)
    return params


def _loss_fn(params, imgs, labels):
    _, logits = M.wcfe_classifier_forward(params, imgs)
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()


def pretrain(wcfe, x_train, y_train, x_test, y_test, log=print):
    """SGD+momentum pretraining; returns (params, test_accuracy)."""
    rng = np.random.default_rng(wcfe.seed)
    params = {k: jnp.asarray(v) for k, v in init_params(wcfe, rng).items()}
    vel = {k: jnp.zeros_like(v) for k, v in params.items()}
    grad_fn = jax.jit(jax.value_and_grad(_loss_fn))

    n = x_train.shape[0]
    for step in range(wcfe.train_steps):
        idx = rng.integers(0, n, size=wcfe.batch)
        loss, g = grad_fn(params, jnp.asarray(x_train[idx]),
                          jnp.asarray(y_train[idx].astype(np.int32)))
        for k in params:
            vel[k] = 0.9 * vel[k] - wcfe.lr * g[k]
            params[k] = params[k] + vel[k]
        if step % 100 == 0 or step == wcfe.train_steps - 1:
            log(f"[pretrain] step {step:4d} loss {float(loss):.4f}")

    acc = evaluate(params, x_test, y_test)
    log(f"[pretrain] test accuracy {acc:.4f}")
    return {k: np.asarray(v) for k, v in params.items()}, acc


def evaluate(params, x, y, batch: int = 200):
    fwd = jax.jit(lambda p, im: M.wcfe_classifier_forward(p, im)[1])
    correct = 0
    for i in range(0, x.shape[0], batch):
        logits = fwd(params, jnp.asarray(x[i:i + batch]))
        correct += int((np.argmax(np.asarray(logits), axis=1)
                        == y[i:i + batch]).sum())
    return correct / x.shape[0]


def kmeans_1d(values: np.ndarray, k: int, iters: int = 30, seed: int = 0):
    """Lloyd's algorithm on scalar weight values (k-means++ style init).

    Returns (centroids (k,), idx (len(values),) int32).
    """
    rng = np.random.default_rng(seed)
    v = values.astype(np.float64)
    # quantile init: robust and deterministic for 1-D
    cent = np.quantile(v, (np.arange(k) + 0.5) / k)
    cent += rng.standard_normal(k) * 1e-9  # break exact ties
    for _ in range(iters):
        idx = np.argmin(np.abs(v[:, None] - cent[None, :]), axis=1)
        for j in range(k):
            sel = v[idx == j]
            if sel.size:
                cent[j] = sel.mean()
    idx = np.argmin(np.abs(v[:, None] - cent[None, :]), axis=1)
    return cent.astype(np.float32), idx.astype(np.int32)


def cluster_weights(params, wcfe, log=print):
    """Post-training clustering of every conv layer (Fig.7a).

    Returns (clustered_params, codebooks) where codebooks maps layer name ->
    (centroids (k,), idx (fan_in, cout) int32). FC/head stay dense (the
    paper clusters the CONV filters).
    """
    clustered = dict(params)
    codebooks = {}
    for name in ("conv1", "conv2", "conv3"):
        w = params[name]
        cent, idx = kmeans_1d(w.reshape(-1), wcfe.clusters, seed=wcfe.seed)
        wq = cent[idx].reshape(w.shape)
        err = float(np.abs(wq - w).mean() / (np.abs(w).mean() + 1e-12))
        log(f"[cluster] {name}: {w.size} weights -> {wcfe.clusters} centroids, "
            f"rel L1 err {err:.4f}")
        clustered[name] = wq
        codebooks[name] = (cent, idx.reshape(w.shape))
    return clustered, codebooks
