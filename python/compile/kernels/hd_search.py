"""L1 Pallas kernel: associative HD search (Fig.6).

The chip's HD Search module fetches 64-bit slices of each class hypervector
per cycle and reduces them through an XOR tree against the query segment.
The Pallas mapping is a (classes x seg_len) block reduction: the query
segment is the small VMEM-resident operand, CHV rows stream through the
grid in class-blocks.

Two distance modes, matching the chip's precision modes:
  * 'l1'  — INT2-8 CHVs: Manhattan distance (per-element |q - c| add-reduce)
  * 'dot' — INT1 (+-1)  : negative dot product == XOR-tree Hamming up to an
            affine map (hamming = (L - dot) / 2)
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _search_kernel(q_ref, c_ref, o_ref, *, metric: str):
    q = q_ref[0]        # (L,)
    c = c_ref[...]      # (cb, L)
    if metric == "l1":
        d = jnp.sum(jnp.abs(c - q[None, :]), axis=1)
    elif metric == "dot":
        d = -jnp.dot(c, q, preferred_element_type=jnp.float32)
    else:
        raise ValueError(f"unknown metric {metric!r}")
    o_ref[0] = d


def hd_search(qs, chvs, *, metric: str = "l1", class_block: int = 0,
              interpret: bool = True):
    """Distances from each query (segment) to each class hypervector.

    qs   : (n, L)  query hypervector (segments)
    chvs : (C, L)  class hypervector (segments)
    returns (n, C) distances (smaller = closer for both metrics).
    """
    n, length = qs.shape
    classes, l2 = chvs.shape
    assert length == l2
    cb = class_block or classes
    assert classes % cb == 0
    kern = functools.partial(_search_kernel, metric=metric)
    return pl.pallas_call(
        kern,
        grid=(n, classes // cb),
        in_specs=[
            pl.BlockSpec((1, length), lambda i, j: (i, 0)),
            pl.BlockSpec((cb, length), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, cb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, classes), jnp.float32),
        interpret=interpret,
    )(qs, chvs)
