"""L1 Pallas kernel: weight-clustered conv inner product (Fig.7).

The WCFE's trick: after post-training clustering, each weight is a 4-bit
index into a small centroid codebook, and inputs sharing a weight index are
ACCUMULATED FIRST and MULTIPLIED ONCE (pattern reuse) — turning K BF16
multiplies per output into `ncl` multiplies plus K adds.

Two kernel modes:
  * 'codebook'  — the faithful cluster-accumulate data flow in f32
                  (bit-exact vs ref.conv_codebook); used for correctness.
  * 'dense_bf16'— centroid-reconstructed dense weights in BF16 (the MXU
                  path the lowered model uses; numerically identical weight
                  VALUES, bf16 rounding as on the chip's BF16 MACs).

The cycle/energy story of the 4x16 PE array lives in rust/src/wcfe/pe_array.rs;
this kernel carries the numerics.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _codebook_kernel(p_ref, oh_ref, cen_ref, o_ref):
    p = p_ref[...]          # (pb, K)
    onehot = oh_ref[...]    # (K, Co*ncl) flattened one-hot codebook indices
    cen = cen_ref[...]      # (ncl,)
    ncl = cen.shape[0]
    co = onehot.shape[1] // ncl
    # Pattern reuse: accumulate inputs per (out-channel, cluster) pair...
    acc = jnp.dot(p, onehot, preferred_element_type=jnp.float32)  # (pb, Co*ncl)
    acc = acc.reshape(p.shape[0], co, ncl)
    # ...then one multiply per cluster.
    o_ref[...] = jnp.dot(acc, cen, preferred_element_type=jnp.float32)


def _dense_bf16_kernel(p_ref, w_ref, o_ref):
    p = p_ref[...].astype(jnp.bfloat16)
    w = w_ref[...].astype(jnp.bfloat16)
    o_ref[...] = jnp.dot(p, w, preferred_element_type=jnp.float32)


def conv_codebook(patches, idx, centroids, *, patch_block: int = 0,
                  interpret: bool = True):
    """Cluster-accumulate conv: patches (P, K) x idx (K, Co) -> (P, Co)."""
    pcount, k = patches.shape
    k2, co = idx.shape
    assert k == k2
    ncl = centroids.shape[0]
    pb = patch_block or pcount
    assert pcount % pb == 0
    onehot = (idx[:, :, None] == jnp.arange(ncl)[None, None, :]).astype(
        jnp.float32).reshape(k, co * ncl)
    return pl.pallas_call(
        _codebook_kernel,
        grid=(pcount // pb,),
        in_specs=[
            pl.BlockSpec((pb, k), lambda i: (i, 0)),
            pl.BlockSpec((k, co * ncl), lambda i: (0, 0)),
            pl.BlockSpec((ncl,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((pb, co), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pcount, co), jnp.float32),
        interpret=interpret,
    )(patches, onehot, centroids)


def conv_dense_bf16(patches, w, *, patch_block: int = 0, interpret: bool = True):
    """BF16 dense conv inner product: (P, K) @ (K, Co) -> (P, Co) f32."""
    pcount, k = patches.shape
    k2, co = w.shape
    assert k == k2
    pb = patch_block or pcount
    assert pcount % pb == 0
    return pl.pallas_call(
        _dense_bf16_kernel,
        grid=(pcount // pb,),
        in_specs=[
            pl.BlockSpec((pb, k), lambda i: (i, 0)),
            pl.BlockSpec((k, co), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((pb, co), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pcount, co), jnp.float32),
        interpret=interpret,
    )(patches, w)
