"""L1 Pallas kernel: Kronecker HD encoder (Fig.5).

The chip's encoder holds the two small +-1 factor matrices A (d1 x f1) and
B (d2 x f2) in an 8-bank 1 KB weight buffer (256 b of weights per cycle feed
32 8-to-1 adder trees); the full D x F projection matrix never exists. The
Pallas mapping keeps the same memory story: A-segment and B are the small
VMEM-resident operands (BlockSpec constant index maps), the feature matrix X
streams per batch element, and the two-stage block matmul
`(A_seg @ X) @ B^T` produces one partial QHV per grid step.

On a real TPU the +-1 matmuls land on the MXU as bf16; under interpret=True
(required on CPU PJRT) numerics are exact f32. Quantization to INT1-8 QHV
elements happens in-kernel so the executable's output already carries the
chip's precision mode.

TPU sizing note (DESIGN.md SPerf): with the default configs the VMEM
footprint per grid step is A_seg (seg_rows x f1) + X (f1 x f2) + B (d2 x f2)
+ out (seg_rows x d2), e.g. isolet-full: 64*32 + 32*20 + 32*20 + 64*32 floats
= ~22 KiB << 16 MiB VMEM, so the whole encoder is resident and the grid only
iterates over the batch.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _encode_kernel(x_ref, a_ref, b_ref, o_ref, *, bits: int, scale: float):
    """One batch element: out = quantize(A_seg @ X @ B^T)."""
    x = x_ref[0]          # (f1, f2)
    a = a_ref[...]        # (dr, f1)
    b = b_ref[...]        # (d2, f2)
    # Stage 1: reshape + first block matmul (the chip's adder trees: A is
    # +-1 so this is add/subtract only).
    t = jnp.dot(a, x, preferred_element_type=jnp.float32)      # (dr, f2)
    # Stage 2: second block matmul against B^T.
    y = jnp.dot(t, b.T, preferred_element_type=jnp.float32)    # (dr, d2)
    if bits == 1:
        q = jnp.where(y >= 0, 1.0, -1.0)
    else:
        qmax = float(2 ** (bits - 1) - 1)
        q = jnp.clip(jnp.round(y / scale), -qmax, qmax)
    o_ref[0] = q


def kron_encode(xs, a_seg, b, *, bits: int = 8, scale: float = 1.0,
                interpret: bool = True):
    """Encode a batch of feature vectors into (partial) QHVs.

    xs    : (n, F)  f32 (values already INT-quantized features)
    a_seg : (dr, f1) +-1 — full A or one progressive-search segment
    b     : (d2, f2) +-1
    returns (n, dr*d2) f32 carrying INT`bits` values.
    """
    n, feat = xs.shape
    dr, f1 = a_seg.shape
    d2, f2 = b.shape
    assert feat == f1 * f2, f"F={feat} != f1*f2={f1 * f2}"
    xm = xs.reshape(n, f1, f2)
    kern = functools.partial(_encode_kernel, bits=bits, scale=scale)
    out = pl.pallas_call(
        kern,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, f1, f2), lambda i: (i, 0, 0)),
            pl.BlockSpec((dr, f1), lambda i: (0, 0)),
            pl.BlockSpec((d2, f2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, dr, d2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, dr, d2), jnp.float32),
        interpret=interpret,
    )(xm, a_seg, b)
    return out.reshape(n, dr * d2)
