"""Pure-jnp correctness oracles for every Pallas kernel (L1).

These are the ground truth the pytest suite checks the kernels against,
and the fixtures the Rust software implementations are cross-checked with
(python/tests/test_fixtures.py writes golden vectors consumed by
rust/src tests).
"""

import jax.numpy as jnp


def quantize(y, bits: int, scale: float):
    """Symmetric signed quantization to `bits` (INT1..INT8), kept in f32.

    INT1 is sign (+-1, never 0) — the XOR-tree/Hamming mode of the chip.
    """
    if bits == 1:
        return jnp.where(y >= 0, 1.0, -1.0)
    qmax = float(2 ** (bits - 1) - 1)
    return jnp.clip(jnp.round(y / scale), -qmax, qmax)


def kron_encode(x, a, b, bits: int = 8, scale: float = 1.0):
    """Kronecker HD encoding (Fig.5): QHV = quantize(vec(A @ X @ B^T)).

    x : (F,)      input feature vector (already INT-quantized values, f32)
    a : (dr, f1)  row-block of the first factor (full A or one segment)
    b : (d2, f2)  second factor
    returns (dr*d2,) flattened row-major:
    QHV[i1*d2+i2] = sum_j1,j2 A[i1,j1] X[j1,j2] B[i2,j2]
    which equals (A kron B) @ vec(X) for row-major vec.
    """
    f1 = a.shape[1]
    f2 = b.shape[1]
    xm = x.reshape(f1, f2)
    y = a @ xm @ b.T
    return quantize(y, bits, scale).reshape(-1)


def kron_encode_batch(xs, a, b, bits: int = 8, scale: float = 1.0):
    """Batched encode: xs (n, F) -> (n, dr*d2)."""
    f1 = a.shape[1]
    f2 = b.shape[1]
    xm = xs.reshape(xs.shape[0], f1, f2)
    y = jnp.einsum("rj,njk,ck->nrc", a, xm, b)
    return quantize(y, bits, scale).reshape(xs.shape[0], -1)


def hd_search_l1(q, chvs):
    """Associative search, L1 (Manhattan) distance: q (L,), chvs (C, L)."""
    return jnp.sum(jnp.abs(chvs - q[None, :]), axis=1)


def hd_search_dot(q, chvs):
    """Associative search, negative dot similarity (Hamming-equivalent for
    +-1 hypervectors: hamming = (L - dot)/2, monotone in -dot)."""
    return -(chvs @ q)


def hd_search_l1_batch(qs, chvs):
    return jnp.sum(jnp.abs(chvs[None, :, :] - qs[:, None, :]), axis=2)


def hd_search_dot_batch(qs, chvs):
    return -(qs @ chvs.T)


def train_update(chvs, qhv, coef):
    """Gradient-free CHV update (Fig.6): chvs += coef (outer) qhv, clipped INT8.

    coef is per-class: +1 for the true class, -1 for a mispredicted class,
    0 elsewhere (single-pass training uses only the +1 row).
    """
    out = chvs + coef[:, None] * qhv[None, :]
    return jnp.clip(out, -127.0, 127.0)


def conv_codebook(patches, idx, centroids):
    """Weight-clustered conv inner product (Fig.7b pattern reuse).

    patches   : (P, K)   im2col patches (P output positions, K = kh*kw*Cin)
    idx       : (K, Co)  int32 codebook indices per weight
    centroids : (ncl,)   f32 cluster centroids
    returns (P, Co) = patches @ centroids[idx], computed cluster-wise:
    inputs sharing a weight index are accumulated first, multiplied once.
    """
    ncl = centroids.shape[0]
    # one-hot (K, Co, ncl) -> cluster-accumulated patches (P, Co, ncl)
    onehot = (idx[:, :, None] == jnp.arange(ncl)[None, None, :]).astype(patches.dtype)
    acc = jnp.einsum("pk,kcn->pcn", patches, onehot)
    return acc @ centroids


def conv_dense_bf16(patches, w):
    """Dense BF16 conv reference: (P, K) @ (K, Co), bf16 operands with f32
    accumulation (the chip's BF16 MAC array keeps a wide accumulator)."""
    return jnp.dot(patches.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32)
