"""Lower jitted JAX functions to HLO *text* for the Rust PJRT loader.

HLO text (not serialized HloModuleProto) is the interchange format: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what the
published `xla` 0.1.6 crate links) rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly. Lowered with
return_tuple=True; the Rust side unwraps with `to_tuple1()`.
"""

import jax
from jax._src.lib import xla_client as xc


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default printer elides big literals as
    # "constant({...})", which the 0.5.1 text parser reads back as ZEROS —
    # any graph with baked weights would silently return garbage.
    return comp.as_hlo_text(print_large_constants=True)


def lower_to_text(fn, example_args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def write_hlo(path, fn, example_args) -> dict:
    """Lower + write; returns manifest entry fragment (shapes/dtypes)."""
    text = lower_to_text(fn, example_args)
    with open(path, "w") as f:
        f.write(text)
    return {
        "inputs": [
            {"shape": list(a.shape), "dtype": str(a.dtype)} for a in example_args
        ],
        "bytes": len(text),
    }
