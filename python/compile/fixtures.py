"""Golden fixtures: JAX-oracle inputs/outputs consumed by the Rust tests.

`rust/tests/golden.rs` and unit tests in rust/src/hdc cross-check the Rust
software implementations (encoder fallback, distances, training, quantizer)
against these exact vectors, pinning L3 to the same arithmetic the L1/L2
artifacts carry.

Usage: cd python && python -m compile.fixtures --out ../artifacts/golden.bin
"""

import argparse

import numpy as np
import jax.numpy as jnp

from .kernels import ref
from . import weights_io as W


def build(seed: int = 123) -> dict:
    rng = np.random.default_rng(seed)
    t = {}

    # Kronecker encode (f1=8, f2=8, d1=32, d2=32; INT8, scale 4.0)
    f1 = f2 = 8
    d1 = d2 = 32
    a = np.sign(rng.standard_normal((d1, f1))).astype(np.float32)
    b = np.sign(rng.standard_normal((d2, f2))).astype(np.float32)
    a[a == 0] = 1
    b[b == 0] = 1
    x = rng.integers(-100, 101, size=(4, f1 * f2)).astype(np.float32)
    t["kron_a"], t["kron_b"], t["kron_x"] = a, b, x
    t["kron_scale"] = np.array([4.0], np.float32)
    t["kron_out"] = np.asarray(ref.kron_encode_batch(
        jnp.asarray(x), jnp.asarray(a), jnp.asarray(b), bits=8, scale=4.0))
    t["kron_out_b1"] = np.asarray(ref.kron_encode_batch(
        jnp.asarray(x), jnp.asarray(a), jnp.asarray(b), bits=1, scale=4.0))
    t["kron_out_b4"] = np.asarray(ref.kron_encode_batch(
        jnp.asarray(x), jnp.asarray(a), jnp.asarray(b), bits=4, scale=4.0))

    # HD search
    q = rng.integers(-127, 128, size=(3, 256)).astype(np.float32)
    chv = rng.integers(-127, 128, size=(12, 256)).astype(np.float32)
    t["search_q"], t["search_chv"] = q, chv
    t["search_l1"] = np.asarray(ref.hd_search_l1_batch(jnp.asarray(q),
                                                       jnp.asarray(chv)))
    t["search_dot"] = np.asarray(ref.hd_search_dot_batch(jnp.asarray(q),
                                                         jnp.asarray(chv)))

    # Train update
    chvs = rng.integers(-120, 121, size=(12, 256)).astype(np.float32)
    qhv = rng.integers(-127, 128, size=(256,)).astype(np.float32)
    coef = np.zeros(12, np.float32)
    coef[3], coef[7] = 1.0, -1.0
    t["train_chvs"], t["train_qhv"], t["train_coef"] = chvs, qhv, coef
    t["train_out"] = np.asarray(ref.train_update(
        jnp.asarray(chvs), jnp.asarray(qhv), jnp.asarray(coef)))

    # Quantizer sweep
    y = (rng.standard_normal(128) * 300).astype(np.float32)
    t["quant_in"] = y
    for bits in (1, 2, 4, 8):
        t[f"quant_out_b{bits}"] = np.asarray(
            ref.quantize(jnp.asarray(y), bits, 2.5))

    # Codebook conv
    patches = rng.standard_normal((8, 18)).astype(np.float32)
    idx = rng.integers(0, 4, size=(18, 5)).astype(np.int32)
    cen = rng.standard_normal(4).astype(np.float32)
    t["conv_patches"], t["conv_idx"], t["conv_cen"] = patches, idx, cen
    t["conv_out"] = np.asarray(ref.conv_codebook(
        jnp.asarray(patches), jnp.asarray(idx), jnp.asarray(cen)))
    return t


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/golden.bin")
    args = ap.parse_args()
    t = build()
    W.write_tensors(args.out, t)
    print(f"wrote {len(t)} golden tensors to {args.out}")


if __name__ == "__main__":
    main()
