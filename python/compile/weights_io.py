"""Named-tensor binary format shared with rust/src/data/tensors.rs.

Format (little-endian), magic "CLOW":
  u8[4] magic "CLOW"
  u32   version 1
  u32   n_tensors
  per tensor:
    u16      name_len, name bytes (utf-8)
    u8       dtype: 0 = f32, 1 = i32
    u32      ndim, u32 dims[ndim]
    payload  prod(dims) elements
"""

import struct

import numpy as np

MAGIC = b"CLOW"


def write_tensors(path, tensors: dict):
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<2I", 1, len(tensors)))
        for name, arr in tensors.items():
            arr = np.asarray(arr)
            if arr.dtype in (np.int32, np.int64):
                arr = arr.astype("<i4")
                dt = 1
            else:
                arr = arr.astype("<f4")
                dt = 0
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BI", dt, arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(arr.tobytes())


def read_tensors(path) -> dict:
    out = {}
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC
        _, n = struct.unpack("<2I", f.read(8))
        for _ in range(n):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode()
            dt, ndim = struct.unpack("<BI", f.read(5))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            count = int(np.prod(dims)) if ndim else 1
            if dt == 1:
                arr = np.frombuffer(f.read(4 * count), dtype="<i4")
            else:
                arr = np.frombuffer(f.read(4 * count), dtype="<f4")
            out[name] = arr.reshape(dims).copy()
    return out
