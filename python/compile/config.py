"""Build-time configuration for the Clo-HDnn artifact pipeline.

Each `HdConfig` mirrors one operating point of the chip (Fig.11 summary):
feature dimension F (8-1024), HDC dimension D (1024-8192), <=128 classes,
INT1-8 inference / INT8 training. The Kronecker factorization requires
F = f1*f2 and D = d1*d2; progressive search splits D into `segments`
contiguous row-groups of A (so segment length = (d1/segments) * d2).
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class HdConfig:
    name: str
    # feature space
    f1: int
    f2: int
    # hyperspace
    d1: int
    d2: int
    segments: int
    classes: int
    # quantization (bits for QHV elements during inference; CHVs are INT8)
    qbits: int = 8
    # batch sizes to emit executables for
    batches: tuple = (1, 8)
    # dataset generation
    n_train: int = 2000
    n_test: int = 500
    sep: float = 4.0
    noise: float = 1.0
    seed: int = 0
    # normal-mode (WCFE) datasets are image shaped
    image: bool = False

    @property
    def features(self) -> int:
        return self.f1 * self.f2

    @property
    def dim(self) -> int:
        return self.d1 * self.d2

    @property
    def seg_rows(self) -> int:
        assert self.d1 % self.segments == 0, "segments must divide d1"
        return self.d1 // self.segments

    @property
    def seg_len(self) -> int:
        return self.seg_rows * self.d2

    def to_meta(self) -> dict:
        m = asdict(self)
        m.update(
            features=self.features,
            dim=self.dim,
            seg_rows=self.seg_rows,
            seg_len=self.seg_len,
        )
        m["batches"] = list(self.batches)
        return m


# Operating points mirroring the paper's three benchmarks plus a tiny config
# used by fast integration tests. Synthetic datasets keep the real datasets'
# (F, #classes) geometry (see DESIGN.md Substitutions).
CONFIGS = {
    # fast tests / quickstart
    "tiny": HdConfig(
        name="tiny", f1=8, f2=8, d1=32, d2=32, segments=8, classes=10,
        batches=(1, 8), n_train=400, n_test=200, sep=5.0, seed=7,
    ),
    # ISOLET: 617 features (padded to 640 = 32*20), 26 classes, bypass mode
    "isolet": HdConfig(
        name="isolet", f1=32, f2=20, d1=64, d2=32, segments=16, classes=26,
        batches=(1, 8), n_train=6238, n_test=1559, sep=1.45, noise=1.0, seed=1,
    ),
    # UCIHAR: 561 features (padded to 576 = 24*24), 6 classes, bypass mode
    "ucihar": HdConfig(
        name="ucihar", f1=24, f2=24, d1=64, d2=32, segments=16, classes=6,
        batches=(1, 8), n_train=7352, n_test=2947, sep=1.35, noise=1.0, seed=2,
    ),
    # CIFAR-100: WCFE features F=512 (32*16), 100 classes, normal mode
    "cifar100": HdConfig(
        name="cifar100", f1=32, f2=16, d1=128, d2=32, segments=16, classes=100,
        batches=(1, 8), n_train=5000, n_test=1000, sep=3.0, noise=1.0, seed=3,
        image=True,
    ),
}


@dataclass(frozen=True)
class WcfeConfig:
    """The BF16 CNN front-end (Fig.7). 3 conv stages + GAP + FC."""
    image_hw: int = 32
    image_c: int = 3
    channels: tuple = (32, 64, 128)
    fc_out: int = 512  # must equal CONFIGS["cifar100"].features
    clusters: int = 16  # post-training weight-clustering codebook size
    classes: int = 100
    train_steps: int = 500
    batch: int = 64
    lr: float = 1e-2
    seed: int = 42


WCFE = WcfeConfig()
