"""L2: JAX compute graphs for Clo-HDnn, lowered once by aot.py.

Every graph here is a pure function of runtime arguments plus baked-in
constants (the +-1 Kronecker factors A/B, quantization scales, clustered
WCFE weights). Each is jit-lowered to one HLO-text executable that the Rust
runtime loads and drives from the request path.

Graphs (one per artifact kind):
  encode_segment  — progressive search: one QHV segment, segment index is a
                    runtime operand (dynamic-slice over the baked A factor)
  encode_full     — whole-QHV encoding (single-shot mode)
  search          — partial/full associative search (L1 or dot metric)
  train_update    — gradient-free CHV update (INT8, clipped)
  wcfe_forward    — BF16 CNN feature extraction with weight-clustered convs
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import kron_encode as _ke
from .kernels import wcfe_conv as _wc
from .kernels import hd_search as _hs


# ---------------------------------------------------------------------------
# HD module graphs
# ---------------------------------------------------------------------------

def make_encode_segment(cfg, a: np.ndarray, b: np.ndarray, scale: float, batch: int):
    """fn(xs (batch, F), seg_idx ()) -> (batch, seg_len) INT`qbits` QHV segment.

    A and B are baked constants (they live in the chip's weight buffer); the
    segment index is a runtime operand so ONE executable serves all segments
    of the progressive search.
    """
    a_c = jnp.asarray(a, jnp.float32)
    b_c = jnp.asarray(b, jnp.float32)
    seg_rows = cfg.seg_rows

    def fn(xs, seg_idx):
        a_seg = jax.lax.dynamic_slice(
            a_c, (seg_idx * seg_rows, 0), (seg_rows, cfg.f1))
        return _ke.kron_encode(xs, a_seg, b_c, bits=cfg.qbits, scale=scale)

    args = (
        jax.ShapeDtypeStruct((batch, cfg.features), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    return fn, args


def make_encode_full(cfg, a: np.ndarray, b: np.ndarray, scale: float, batch: int):
    """fn(xs (batch, F)) -> (batch, D) full QHV."""
    a_c = jnp.asarray(a, jnp.float32)
    b_c = jnp.asarray(b, jnp.float32)

    def fn(xs):
        return _ke.kron_encode(xs, a_c, b_c, bits=cfg.qbits, scale=scale)

    return fn, (jax.ShapeDtypeStruct((batch, cfg.features), jnp.float32),)


def make_search(cfg, length: int, batch: int, metric: str = "l1"):
    """fn(qs (batch, L), chvs (C, L)) -> (batch, C) distances."""

    def fn(qs, chvs):
        return _hs.hd_search(qs, chvs, metric=metric)

    args = (
        jax.ShapeDtypeStruct((batch, length), jnp.float32),
        jax.ShapeDtypeStruct((cfg.classes, length), jnp.float32),
    )
    return fn, args


def make_train_update(cfg):
    """fn(chvs (C, D), qhv (D,), coef (C,)) -> updated clipped-INT8 CHVs."""

    def fn(chvs, qhv, coef):
        out = chvs + coef[:, None] * qhv[None, :]
        return jnp.clip(out, -127.0, 127.0)

    args = (
        jax.ShapeDtypeStruct((cfg.classes, cfg.dim), jnp.float32),
        jax.ShapeDtypeStruct((cfg.dim,), jnp.float32),
        jax.ShapeDtypeStruct((cfg.classes,), jnp.float32),
    )
    return fn, args


# ---------------------------------------------------------------------------
# WCFE forward (Fig.7): conv(3x3) -> relu -> maxpool2, x3, GAP, FC
# ---------------------------------------------------------------------------

def im2col(x, k: int = 3):
    """SAME-padded 3x3 patch extraction: (n,h,w,c) -> (n,h,w,k*k*c)."""
    n, h, w, c = x.shape
    pad = k // 2
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    cols = [xp[:, dy:dy + h, dx:dx + w, :] for dy in range(k) for dx in range(k)]
    return jnp.concatenate(cols, axis=-1)


def maxpool2(x):
    n, h, w, c = x.shape
    return x.reshape(n, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def conv_layer_dense(x, w, use_kernel: bool = True, interpret: bool = True):
    """One BF16 conv layer via the L1 kernel. w: (k*k*cin, cout)."""
    n, h, wd, _ = x.shape
    patches = im2col(x).reshape(n * h * wd, -1)
    if use_kernel:
        out = _wc.conv_dense_bf16(patches, w, interpret=interpret)
    else:
        out = (patches.astype(jnp.bfloat16) @ w.astype(jnp.bfloat16)).astype(jnp.float32)
    return out.reshape(n, h, wd, -1)


def wcfe_forward(params, imgs, use_kernel: bool = True, interpret: bool = True):
    """Feature extraction: imgs (n, 32, 32, 3) in [0,1] -> features (n, F).

    params: dict with conv1/conv2/conv3 (k*k*cin, cout) and fc (c3, F).
    On the lowered artifact the conv weights are the CLUSTERED (codebook-
    reconstructed) values — numerics match the chip's post-clustering BF16
    datapath.
    """
    x = imgs * 2.0 - 1.0
    for name in ("conv1", "conv2", "conv3"):
        x = conv_layer_dense(x, params[name], use_kernel, interpret)
        x = jnp.maximum(x, 0.0)
        x = maxpool2(x)
    feat = x.mean(axis=(1, 2))                      # GAP -> (n, c3)
    out = (feat.astype(jnp.bfloat16) @ params["fc"].astype(jnp.bfloat16))
    return out.astype(jnp.float32)                  # (n, F)


def make_wcfe_forward(params, batch: int, hw: int = 32, c: int = 3):
    p = {k: jnp.asarray(v, jnp.float32) for k, v in params.items()}

    def fn(imgs):
        return wcfe_forward(p, imgs)

    return fn, (jax.ShapeDtypeStruct((batch, hw, hw, c), jnp.float32),)


def wcfe_classifier_forward(params, imgs):
    """Pretraining-time forward: WCFE features -> linear head logits.

    Runs in plain f32 (no pallas, no bf16) for fast, stable training; the
    clustered/bf16 path is what gets lowered for inference.
    """
    x = imgs * 2.0 - 1.0
    for name in ("conv1", "conv2", "conv3"):
        n, h, w, _ = x.shape
        patches = im2col(x).reshape(n * h * w, -1)
        x = (patches @ params[name]).reshape(n, h, w, -1)
        x = jnp.maximum(x, 0.0)
        x = maxpool2(x)
    feat = x.mean(axis=(1, 2))
    feats = feat @ params["fc"]
    logits = feats @ params["head"]
    return feats, logits
