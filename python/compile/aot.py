"""AOT artifact pipeline: lower every L2 graph to HLO text, generate the
synthetic datasets, pretrain + cluster the WCFE, and write the manifest.

Run once via `make artifacts`; Python never runs on the request path.
Emits HLO *text* (NOT .serialize()) — see hlo.py and
/opt/xla-example/load_hlo/gen_hlo.py for why.

Usage: cd python && python -m compile.aot --out-dir ../artifacts
       [--configs tiny,isolet,...] [--fast]
"""

import argparse
import json
import os
import time

import numpy as np

from . import datasets as D
from . import hlo as H
from . import model as M
from . import pretrain as P
from . import weights_io as W
from .config import CONFIGS, WCFE, WcfeConfig


def gen_factors(cfg):
    """The +-1 Kronecker factors A (d1, f1), B (d2, f2) — the entire encoder
    state (the 1376x memory saving vs a dense D x F projection)."""
    rng = np.random.default_rng(cfg.seed + 77)
    a = np.sign(rng.standard_normal((cfg.d1, cfg.f1))).astype(np.float32)
    b = np.sign(rng.standard_normal((cfg.d2, cfg.f2))).astype(np.float32)
    a[a == 0] = 1.0
    b[b == 0] = 1.0
    return a, b


def quantize_features(x, scale):
    return np.clip(np.round(x / scale), -127, 127).astype(np.float32)


def calibrate(cfg, a, b, x_train):
    """Choose the feature and QHV quantization steps from training data."""
    scale_x = float(np.abs(x_train).max() / 127.0) or 1.0
    xq = quantize_features(x_train[:256], scale_x)
    xm = xq.reshape(-1, cfg.f1, cfg.f2)
    y = np.einsum("rj,njk,ck->nrc", a, xm, b)
    scale_q = float(np.abs(y).max() / 127.0) or 1.0
    # expected per-element |q_i - q_j| between independent QHVs: feeds the
    # progressive-search margin threshold (rust hdc/progressive.rs)
    q = np.clip(np.round(y / scale_q), -127, 127).reshape(y.shape[0], -1)
    half = q.shape[0] // 2
    mean_absdiff = float(np.abs(q[:half] - q[half:2 * half]).mean())
    return scale_x, scale_q, mean_absdiff


def emit_hd_artifacts(cfg, out_dir, manifest, x_train):
    a, b = gen_factors(cfg)
    scale_x, scale_q, mean_absdiff = calibrate(cfg, a, b, x_train)
    meta = cfg.to_meta()
    meta.update(scale_x=scale_x, scale_q=scale_q, mean_absdiff=mean_absdiff)
    manifest["configs"][cfg.name] = meta

    W.write_tensors(os.path.join(out_dir, f"hd_factors_{cfg.name}.bin"),
                    {"a": a, "b": b})
    manifest["weights"].append({
        "name": f"hd_factors_{cfg.name}", "config": cfg.name,
        "file": f"hd_factors_{cfg.name}.bin",
        "tensors": {"a": [cfg.d1, cfg.f1], "b": [cfg.d2, cfg.f2]},
    })

    def emit(name, fn, args, kind, batch, extra=None):
        fname = f"{name}.hlo.txt"
        entry = H.write_hlo(os.path.join(out_dir, fname), fn, args)
        entry.update(name=name, file=fname, config=cfg.name, kind=kind,
                     batch=batch, **(extra or {}))
        manifest["executables"].append(entry)

    for batch in cfg.batches:
        fn, args = M.make_encode_segment(cfg, a, b, scale_q, batch)
        emit(f"encode_segment_{cfg.name}_b{batch}", fn, args,
             "encode_segment", batch, {"out": [batch, cfg.seg_len]})
        fn, args = M.make_encode_full(cfg, a, b, scale_q, batch)
        emit(f"encode_full_{cfg.name}_b{batch}", fn, args,
             "encode_full", batch, {"out": [batch, cfg.dim]})
        fn, args = M.make_search(cfg, cfg.seg_len, batch)
        emit(f"search_seg_{cfg.name}_b{batch}", fn, args,
             "search_seg", batch, {"out": [batch, cfg.classes],
                                   "length": cfg.seg_len})
    fn, args = M.make_search(cfg, cfg.dim, 1)
    emit(f"search_full_{cfg.name}_b1", fn, args, "search_full", 1,
         {"out": [1, cfg.classes], "length": cfg.dim})
    fn, args = M.make_train_update(cfg)
    emit(f"train_update_{cfg.name}", fn, args, "train_update", 1,
         {"out": [cfg.classes, cfg.dim]})


def emit_dataset(name, out_dir, manifest, x, y, classes, img_shape=(0, 0, 0),
                 as_u8=False):
    fname = f"ds_{name}.bin"
    D.write_bin(os.path.join(out_dir, fname), x, y, classes, img_shape, as_u8)
    manifest["datasets"].append({
        "name": f"ds_{name}", "file": fname, "n": int(x.shape[0]),
        "dim": int(np.prod(x.shape[1:])), "classes": classes,
        "image": list(img_shape) if img_shape[0] else None,
    })


def build_cifar(cfg, wcfe, out_dir, manifest, log):
    (x_tr, y_tr), (x_te, y_te) = D.gen_images(cfg, wcfe.image_hw, wcfe.image_c)
    emit_dataset(f"{cfg.name}_img_train", out_dir, manifest, x_tr, y_tr,
                 cfg.classes, (wcfe.image_hw, wcfe.image_hw, wcfe.image_c), True)
    emit_dataset(f"{cfg.name}_img_test", out_dir, manifest, x_te, y_te,
                 cfg.classes, (wcfe.image_hw, wcfe.image_hw, wcfe.image_c), True)

    params, acc = P.pretrain(wcfe, x_tr, y_tr, x_te, y_te, log)
    clustered, codebooks = P.cluster_weights(params, wcfe, log)
    acc_q = P.evaluate(clustered, x_te, y_te)
    log(f"[cluster] clustered test accuracy {acc_q:.4f} (dense {acc:.4f})")

    # weights + codebook binaries (rust wcfe module and Fig.7 bench)
    W.write_tensors(os.path.join(out_dir, "wcfe_weights.bin"),
                    {k: v for k, v in clustered.items() if k != "head"})
    W.write_tensors(os.path.join(out_dir, "wcfe_weights_dense.bin"),
                    {k: v for k, v in params.items() if k != "head"})
    cb_tensors = {}
    for lname, (cent, idx) in codebooks.items():
        cb_tensors[f"{lname}_centroids"] = cent
        cb_tensors[f"{lname}_idx"] = idx.astype(np.int32)
    W.write_tensors(os.path.join(out_dir, "wcfe_codebook.bin"), cb_tensors)
    manifest["wcfe"] = {
        "image_hw": wcfe.image_hw, "image_c": wcfe.image_c,
        "channels": list(wcfe.channels), "fc_out": wcfe.fc_out,
        "clusters": wcfe.clusters, "pretrain_acc": acc,
        "clustered_acc": acc_q,
        "weights": "wcfe_weights.bin", "weights_dense": "wcfe_weights_dense.bin",
        "codebook": "wcfe_codebook.bin",
    }

    # lowered feature extractor (clustered weights baked)
    infer_params = {k: v for k, v in clustered.items() if k != "head"}
    for batch in cfg.batches:
        fn, args = M.make_wcfe_forward(infer_params, batch, wcfe.image_hw,
                                       wcfe.image_c)
        fname = f"wcfe_fwd_b{batch}.hlo.txt"
        entry = H.write_hlo(os.path.join(out_dir, fname), fn, args)
        entry.update(name=f"wcfe_fwd_b{batch}", file=fname, config=cfg.name,
                     kind="wcfe_fwd", batch=batch, out=[batch, wcfe.fc_out])
        manifest["executables"].append(entry)

    # WCFE features of the image sets -> the HD module's input space
    import jax.numpy as jnp
    feats = []
    for xs in (x_tr, x_te):
        fs = []
        for i in range(0, xs.shape[0], 100):
            fs.append(np.asarray(M.wcfe_forward(
                {k: jnp.asarray(v) for k, v in infer_params.items()},
                jnp.asarray(xs[i:i + 100]), use_kernel=False)))
        feats.append(np.concatenate(fs))
    emit_dataset(f"{cfg.name}_train", out_dir, manifest, feats[0], y_tr, cfg.classes)
    emit_dataset(f"{cfg.name}_test", out_dir, manifest, feats[1], y_te, cfg.classes)
    return feats[0]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="tiny,isolet,ucihar,cifar100")
    ap.add_argument("--fast", action="store_true",
                    help="fewer pretrain steps (CI)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    t0 = time.time()
    manifest = {"version": 1, "configs": {}, "executables": [],
                "datasets": [], "weights": []}
    wcfe = WCFE
    if args.fast or os.environ.get("ARTIFACT_FAST"):
        wcfe = WcfeConfig(train_steps=80)

    for name in args.configs.split(","):
        cfg = CONFIGS[name]
        print(f"=== config {name}: F={cfg.features} D={cfg.dim} "
              f"C={cfg.classes} segs={cfg.segments}")
        if cfg.image:
            x_train = build_cifar(cfg, wcfe, args.out_dir, manifest, print)
        else:
            (x_tr, y_tr), (x_te, y_te) = D.gen_features(cfg)
            emit_dataset(f"{cfg.name}_train", args.out_dir, manifest,
                         x_tr, y_tr, cfg.classes)
            emit_dataset(f"{cfg.name}_test", args.out_dir, manifest,
                         x_te, y_te, cfg.classes)
            x_train = x_tr
        emit_hd_artifacts(cfg, args.out_dir, manifest, x_train)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    n_exe = len(manifest["executables"])
    print(f"wrote {n_exe} executables + {len(manifest['datasets'])} datasets "
          f"to {args.out_dir} in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
