"""Deterministic synthetic dataset generators + binary writers.

Substitution rule (DESIGN.md): ISOLET / UCIHAR / CIFAR-100 are not available
offline, so we generate class-mean Gaussian-cluster datasets with the same
(F, #classes, #samples) geometry. HDC accuracy, forgetting behaviour and the
bypass-vs-normal trade-off depend on class-cluster geometry, which the
generator controls (`sep` = between-class separation in within-class sigma
units along the mean-difference direction).

Binary format (little-endian), magic "CLOD":
  u8[4]  magic          "CLOD"
  u32    version        1
  u32    dtype          0 = f32, 1 = u8
  u32    n              samples
  u32    dim            flattened feature count
  u32    classes
  u32    h, w, c        image shape (0,0,0 for flat feature data)
  u16[n] labels
  data   n*dim elements (f32 or u8)
"""

import struct

import numpy as np


MAGIC = b"CLOD"


def gen_features(cfg):
    """Flat-feature dataset (bypass mode): returns train/test (x, y)."""
    rng = np.random.default_rng(cfg.seed + 1000)
    feat = cfg.f1 * cfg.f2
    # Unit-norm mean directions scaled so E||mu_i - mu_j|| ~ sep * noise;
    # with per-element within-class sigma = noise/sqrt(F) the projected
    # margin along (mu_i - mu_j) is ~ sep within-class sigmas.
    means = rng.standard_normal((cfg.classes, feat))
    means /= np.linalg.norm(means, axis=1, keepdims=True)
    means *= cfg.sep * cfg.noise / np.sqrt(2.0)

    def draw(n, seed_off):
        r = np.random.default_rng(cfg.seed + seed_off)
        y = r.integers(0, cfg.classes, size=n).astype(np.uint16)
        # mild per-class covariance variation for realism
        cls_scale = 1.0 + 0.1 * np.sin(np.arange(cfg.classes))
        x = means[y] + r.standard_normal((n, feat)) * (
            cfg.noise * cls_scale[y][:, None]) / np.sqrt(feat)
        return x.astype(np.float32), y

    return draw(cfg.n_train, 1), draw(cfg.n_test, 2)


def gen_images(cfg, hw: int = 32, c: int = 3):
    """Image dataset (normal mode): low-frequency class-mean patterns + noise."""
    rng = np.random.default_rng(cfg.seed + 2000)
    base = rng.standard_normal((cfg.classes, 4, 4, c))
    # bilinear-ish upsample x8 by repetition then box smoothing
    mean_img = base.repeat(hw // 4, axis=1).repeat(hw // 4, axis=2)
    k = 5
    pad = k // 2
    mp = np.pad(mean_img, ((0, 0), (pad, pad), (pad, pad), (0, 0)), mode="edge")
    sm = np.zeros_like(mean_img)
    for dy in range(k):
        for dx in range(k):
            sm += mp[:, dy:dy + hw, dx:dx + hw, :]
    mean_img = sm / (k * k)
    mean_img = 0.5 + 0.22 * mean_img / np.abs(mean_img).max()

    def draw(n, seed_off):
        r = np.random.default_rng(cfg.seed + seed_off)
        y = r.integers(0, cfg.classes, size=n).astype(np.uint16)
        x = mean_img[y] + r.standard_normal((n, hw, hw, c)) * 0.20
        return np.clip(x, 0.0, 1.0).astype(np.float32), y

    return draw(cfg.n_train, 3), draw(cfg.n_test, 4)


def write_bin(path, x: np.ndarray, y: np.ndarray, classes: int,
              img_shape=(0, 0, 0), as_u8: bool = False):
    n = x.shape[0]
    flat = x.reshape(n, -1)
    dim = flat.shape[1]
    dtype = 1 if as_u8 else 0
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<6I", 1, dtype, n, dim, classes, img_shape[0]))
        f.write(struct.pack("<2I", img_shape[1], img_shape[2]))
        f.write(y.astype("<u2").tobytes())
        if as_u8:
            f.write((np.clip(flat, 0.0, 1.0) * 255.0).round().astype(np.uint8).tobytes())
        else:
            f.write(flat.astype("<f4").tobytes())


def read_bin(path):
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC
        ver, dtype, n, dim, classes, h = struct.unpack("<6I", f.read(24))
        w, c = struct.unpack("<2I", f.read(8))
        y = np.frombuffer(f.read(2 * n), dtype="<u2")
        if dtype == 1:
            x = np.frombuffer(f.read(n * dim), dtype=np.uint8).astype(np.float32) / 255.0
        else:
            x = np.frombuffer(f.read(4 * n * dim), dtype="<f4").copy()
        x = x.reshape(n, dim)
        if h:
            x = x.reshape(n, h, w, c)
    return x, np.asarray(y), classes
