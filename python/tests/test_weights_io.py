"""Named-tensor container roundtrip (shared with rust/src/data/tensors.rs)."""

import numpy as np
import pytest

from compile import weights_io as W


def test_roundtrip_mixed_dtypes(tmp_path):
    rng = np.random.default_rng(0)
    tensors = {
        "a": rng.standard_normal((4, 5)).astype(np.float32),
        "idx": rng.integers(0, 16, size=(3, 2, 2)).astype(np.int32),
        "scalarish": np.array([1.5], dtype=np.float32),
    }
    p = tmp_path / "t.bin"
    W.write_tensors(str(p), tensors)
    out = W.read_tensors(str(p))
    assert set(out) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(out[k], tensors[k])
        assert out[k].dtype == tensors[k].dtype


def test_empty_file(tmp_path):
    p = tmp_path / "e.bin"
    W.write_tensors(str(p), {})
    assert W.read_tensors(str(p)) == {}
