"""Kernel-vs-ref correctness: the CORE L1 signal.

Hypothesis sweeps shapes/bit-widths; every case asserts the Pallas kernel
(interpret=True) matches the pure-jnp oracle bit-exactly (both are f32
graphs of the same arithmetic).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import kron_encode as KE
from compile.kernels import ref


def rand_factors(rng, d1, f1, d2, f2):
    a = np.sign(rng.standard_normal((d1, f1))).astype(np.float32)
    b = np.sign(rng.standard_normal((d2, f2))).astype(np.float32)
    a[a == 0] = 1
    b[b == 0] = 1
    return a, b


@settings(max_examples=25, deadline=None)
@given(
    f1=st.sampled_from([4, 8, 16]),
    f2=st.sampled_from([4, 8, 20]),
    d1=st.sampled_from([8, 32]),
    d2=st.sampled_from([8, 32]),
    n=st.integers(1, 5),
    bits=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kron_encode_matches_ref(f1, f2, d1, d2, n, bits, seed):
    rng = np.random.default_rng(seed)
    a, b = rand_factors(rng, d1, f1, d2, f2)
    xs = rng.integers(-127, 128, size=(n, f1 * f2)).astype(np.float32)
    scale = float(rng.uniform(0.5, 50.0))
    got = KE.kron_encode(jnp.asarray(xs), jnp.asarray(a), jnp.asarray(b),
                         bits=bits, scale=scale)
    want = ref.kron_encode_batch(jnp.asarray(xs), jnp.asarray(a),
                                 jnp.asarray(b), bits=bits, scale=scale)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kron_encode_segment_consistency():
    """Segments concatenated == full encode (the progressive-search invariant)."""
    rng = np.random.default_rng(3)
    d1, f1, d2, f2, segs = 32, 8, 16, 8, 4
    a, b = rand_factors(rng, d1, f1, d2, f2)
    xs = rng.integers(-50, 50, size=(2, f1 * f2)).astype(np.float32)
    full = np.asarray(KE.kron_encode(jnp.asarray(xs), jnp.asarray(a),
                                     jnp.asarray(b), bits=8, scale=3.0))
    rows = d1 // segs
    parts = [np.asarray(KE.kron_encode(jnp.asarray(xs),
                                       jnp.asarray(a[s * rows:(s + 1) * rows]),
                                       jnp.asarray(b), bits=8, scale=3.0))
             for s in range(segs)]
    np.testing.assert_array_equal(full, np.concatenate(parts, axis=1))


def test_kron_equals_dense_kronecker_projection():
    """A (x) B applied to vec(X) equals the two-stage block matmul: the
    mathematical identity behind the 1376x encoder-memory saving."""
    rng = np.random.default_rng(11)
    f1, f2, d1, d2 = 4, 6, 8, 10
    a, b = rand_factors(rng, d1, f1, d2, f2)
    x = rng.integers(-20, 20, size=(f1 * f2,)).astype(np.float32)
    dense = np.kron(a, b) @ x
    got = np.asarray(ref.kron_encode(jnp.asarray(x), jnp.asarray(a),
                                     jnp.asarray(b), bits=8, scale=1.0))
    np.testing.assert_array_equal(got, np.clip(np.round(dense), -127, 127))


def test_int1_is_sign_never_zero():
    rng = np.random.default_rng(5)
    a, b = rand_factors(rng, 8, 4, 8, 4)
    xs = np.zeros((1, 16), dtype=np.float32)
    out = np.asarray(KE.kron_encode(jnp.asarray(xs), jnp.asarray(a),
                                    jnp.asarray(b), bits=1, scale=1.0))
    assert set(np.unique(out)) <= {-1.0, 1.0}


def test_quantize_range_int8():
    rng = np.random.default_rng(6)
    a, b = rand_factors(rng, 8, 8, 8, 8)
    xs = rng.integers(-127, 128, size=(4, 64)).astype(np.float32)
    out = np.asarray(KE.kron_encode(jnp.asarray(xs), jnp.asarray(a),
                                    jnp.asarray(b), bits=8, scale=1.0))
    assert out.max() <= 127 and out.min() >= -127
