"""Weight-clustered conv kernel vs oracle (Fig.7 numerics)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import wcfe_conv as WC
from compile.kernels import ref


@settings(max_examples=20, deadline=None)
@given(
    p=st.sampled_from([8, 16, 32]),
    k=st.sampled_from([9, 27, 36]),
    co=st.sampled_from([4, 8, 16]),
    ncl=st.sampled_from([2, 4, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_codebook_conv_matches_ref(p, k, co, ncl, seed):
    rng = np.random.default_rng(seed)
    patches = rng.standard_normal((p, k)).astype(np.float32)
    idx = rng.integers(0, ncl, size=(k, co)).astype(np.int32)
    cen = rng.standard_normal(ncl).astype(np.float32)
    got = WC.conv_codebook(jnp.asarray(patches), jnp.asarray(idx),
                           jnp.asarray(cen))
    want = ref.conv_codebook(jnp.asarray(patches), jnp.asarray(idx),
                             jnp.asarray(cen))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_codebook_equals_dense_reconstruction():
    """Cluster-accumulate-then-multiply == dense matmul with reconstructed
    weights (the pattern-reuse identity: same math, fewer multiplies)."""
    rng = np.random.default_rng(9)
    p, k, co, ncl = 16, 18, 8, 4
    patches = rng.standard_normal((p, k)).astype(np.float32)
    idx = rng.integers(0, ncl, size=(k, co)).astype(np.int32)
    cen = rng.standard_normal(ncl).astype(np.float32)
    w = cen[idx]
    got = np.asarray(WC.conv_codebook(jnp.asarray(patches), jnp.asarray(idx),
                                      jnp.asarray(cen)))
    np.testing.assert_allclose(got, patches @ w, rtol=1e-4, atol=1e-4)


def test_dense_bf16_matches_ref():
    rng = np.random.default_rng(10)
    patches = rng.standard_normal((32, 27)).astype(np.float32)
    w = rng.standard_normal((27, 16)).astype(np.float32)
    got = WC.conv_dense_bf16(jnp.asarray(patches), jnp.asarray(w))
    want = ref.conv_dense_bf16(jnp.asarray(patches), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_patch_blocking_invariant():
    rng = np.random.default_rng(11)
    patches = rng.standard_normal((32, 9)).astype(np.float32)
    idx = rng.integers(0, 4, size=(9, 8)).astype(np.int32)
    cen = rng.standard_normal(4).astype(np.float32)
    a = WC.conv_codebook(jnp.asarray(patches), jnp.asarray(idx),
                         jnp.asarray(cen), patch_block=32)
    b = WC.conv_codebook(jnp.asarray(patches), jnp.asarray(idx),
                         jnp.asarray(cen), patch_block=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
