"""L2 graph semantics: shapes, quantization carry-through, update rule,
WCFE forward pipeline."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import pretrain as P
from compile.config import CONFIGS, WcfeConfig
from compile.kernels import ref


CFG = CONFIGS["tiny"]


def factors(cfg, seed=0):
    rng = np.random.default_rng(seed)
    a = np.sign(rng.standard_normal((cfg.d1, cfg.f1))).astype(np.float32)
    b = np.sign(rng.standard_normal((cfg.d2, cfg.f2))).astype(np.float32)
    a[a == 0] = 1
    b[b == 0] = 1
    return a, b


def test_encode_segment_graph_matches_manual_slice():
    a, b = factors(CFG)
    fn, args = M.make_encode_segment(CFG, a, b, scale=2.0, batch=3)
    rng = np.random.default_rng(1)
    xs = rng.integers(-40, 40, size=(3, CFG.features)).astype(np.float32)
    for seg in range(CFG.segments):
        out = np.asarray(fn(jnp.asarray(xs), jnp.int32(seg)))
        rows = CFG.seg_rows
        want = np.asarray(ref.kron_encode_batch(
            jnp.asarray(xs), jnp.asarray(a[seg * rows:(seg + 1) * rows]),
            jnp.asarray(b), bits=CFG.qbits, scale=2.0))
        np.testing.assert_array_equal(out, want)


def test_encode_full_equals_segment_concat():
    a, b = factors(CFG)
    full_fn, _ = M.make_encode_full(CFG, a, b, scale=2.0, batch=2)
    seg_fn, _ = M.make_encode_segment(CFG, a, b, scale=2.0, batch=2)
    rng = np.random.default_rng(2)
    xs = rng.integers(-40, 40, size=(2, CFG.features)).astype(np.float32)
    full = np.asarray(full_fn(jnp.asarray(xs)))
    parts = [np.asarray(seg_fn(jnp.asarray(xs), jnp.int32(s)))
             for s in range(CFG.segments)]
    np.testing.assert_array_equal(full, np.concatenate(parts, axis=1))


def test_search_graph_shapes_and_values():
    fn, _ = M.make_search(CFG, CFG.seg_len, batch=2)
    rng = np.random.default_rng(3)
    qs = rng.integers(-127, 128, size=(2, CFG.seg_len)).astype(np.float32)
    chvs = rng.integers(-127, 128, size=(CFG.classes, CFG.seg_len)).astype(np.float32)
    out = np.asarray(fn(jnp.asarray(qs), jnp.asarray(chvs)))
    assert out.shape == (2, CFG.classes)
    want = np.abs(chvs[None] - qs[:, None]).sum(axis=2)
    np.testing.assert_allclose(out, want, rtol=1e-6)


def test_train_update_add_and_subtract():
    fn, _ = M.make_train_update(CFG)
    chvs = np.zeros((CFG.classes, CFG.dim), dtype=np.float32)
    qhv = np.full((CFG.dim,), 3.0, dtype=np.float32)
    coef = np.zeros((CFG.classes,), dtype=np.float32)
    coef[2], coef[5] = 1.0, -1.0
    out = np.asarray(fn(jnp.asarray(chvs), jnp.asarray(qhv), jnp.asarray(coef)))
    assert (out[2] == 3.0).all() and (out[5] == -3.0).all()
    mask = np.ones(CFG.classes, bool)
    mask[[2, 5]] = False
    assert (out[mask] == 0).all()


def test_train_update_clips_to_int8():
    fn, _ = M.make_train_update(CFG)
    chvs = np.full((CFG.classes, CFG.dim), 126.0, dtype=np.float32)
    qhv = np.full((CFG.dim,), 100.0, dtype=np.float32)
    coef = np.ones((CFG.classes,), dtype=np.float32)
    out = np.asarray(fn(jnp.asarray(chvs), jnp.asarray(qhv), jnp.asarray(coef)))
    assert out.max() == 127.0


def test_wcfe_forward_shapes():
    wcfe = WcfeConfig()
    rng = np.random.default_rng(4)
    params = P.init_params(wcfe, rng)
    infer = {k: v for k, v in params.items() if k != "head"}
    fn, args = M.make_wcfe_forward(infer, batch=2)
    imgs = rng.uniform(0, 1, size=(2, 32, 32, 3)).astype(np.float32)
    out = np.asarray(fn(jnp.asarray(imgs)))
    assert out.shape == (2, wcfe.fc_out)
    assert np.isfinite(out).all()


def test_wcfe_kernel_path_matches_plain_path():
    """Pallas dense-bf16 conv path == plain jnp bf16 path."""
    wcfe = WcfeConfig(channels=(8, 8, 8), fc_out=16)
    rng = np.random.default_rng(5)
    params = {k: jnp.asarray(v) for k, v in P.init_params(wcfe, rng).items()
              if k != "head"}
    imgs = jnp.asarray(rng.uniform(0, 1, size=(1, 32, 32, 3)).astype(np.float32))
    a = np.asarray(M.wcfe_forward(params, imgs, use_kernel=True))
    b = np.asarray(M.wcfe_forward(params, imgs, use_kernel=False))
    np.testing.assert_array_equal(a, b)


def test_im2col_matches_direct_conv():
    rng = np.random.default_rng(6)
    x = rng.standard_normal((1, 8, 8, 2)).astype(np.float32)
    w = rng.standard_normal((18, 3)).astype(np.float32)
    patches = np.asarray(M.im2col(jnp.asarray(x))).reshape(64, 18)
    out = (patches @ w).reshape(8, 8, 3)
    # direct SAME conv at an interior pixel
    wk = w.reshape(3, 3, 2, 3)
    py, px = 4, 5
    want = sum(
        x[0, py + dy - 1, px + dx - 1, ci] * wk[dy, dx, ci, :]
        for dy in range(3) for dx in range(3) for ci in range(2)
    )
    np.testing.assert_allclose(out[py, px], want, rtol=1e-4)


def test_maxpool2():
    x = jnp.asarray(np.arange(16, dtype=np.float32).reshape(1, 4, 4, 1))
    out = np.asarray(M.maxpool2(x))
    np.testing.assert_array_equal(out[0, :, :, 0], [[5, 7], [13, 15]])
