"""HD search kernel vs oracle + metric semantics."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import hd_search as HS
from compile.kernels import ref


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 4),
    classes=st.sampled_from([2, 6, 10, 26]),
    length=st.sampled_from([16, 64, 128]),
    metric=st.sampled_from(["l1", "dot"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_search_matches_ref(n, classes, length, metric, seed):
    rng = np.random.default_rng(seed)
    qs = rng.integers(-127, 128, size=(n, length)).astype(np.float32)
    chvs = rng.integers(-127, 128, size=(classes, length)).astype(np.float32)
    got = HS.hd_search(jnp.asarray(qs), jnp.asarray(chvs), metric=metric)
    if metric == "l1":
        want = ref.hd_search_l1_batch(jnp.asarray(qs), jnp.asarray(chvs))
    else:
        want = ref.hd_search_dot_batch(jnp.asarray(qs), jnp.asarray(chvs))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_class_blocking_invariant():
    """Streaming CHVs in class-blocks (the XOR-tree fetch pattern) must not
    change results."""
    rng = np.random.default_rng(1)
    qs = rng.integers(-8, 9, size=(3, 32)).astype(np.float32)
    chvs = rng.integers(-8, 9, size=(12, 32)).astype(np.float32)
    a = HS.hd_search(jnp.asarray(qs), jnp.asarray(chvs), class_block=12)
    b = HS.hd_search(jnp.asarray(qs), jnp.asarray(chvs), class_block=4)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dot_metric_equals_hamming_for_pm1():
    """For +-1 hypervectors: hamming = (L - dot) / 2 — the chip's XOR tree."""
    rng = np.random.default_rng(2)
    length = 64
    q = np.sign(rng.standard_normal((1, length))).astype(np.float32)
    chvs = np.sign(rng.standard_normal((5, length))).astype(np.float32)
    q[q == 0] = 1
    chvs[chvs == 0] = 1
    negdot = np.asarray(HS.hd_search(jnp.asarray(q), jnp.asarray(chvs),
                                     metric="dot"))[0]
    hamming = (chvs != q).sum(axis=1)
    np.testing.assert_array_equal((length + negdot) / 2.0, hamming)


def test_self_distance_zero_l1():
    rng = np.random.default_rng(3)
    chvs = rng.integers(-127, 128, size=(4, 100)).astype(np.float32)
    d = np.asarray(HS.hd_search(jnp.asarray(chvs[:1]), jnp.asarray(chvs),
                                metric="l1"))
    assert d[0, 0] == 0.0
    assert (d[0, 1:] > 0).all()


def test_partial_distances_sum_to_full():
    """L1 distance is additive over segments — the progressive-search
    accumulation identity."""
    rng = np.random.default_rng(4)
    seg, nseg = 32, 4
    q = rng.integers(-127, 128, size=(1, seg * nseg)).astype(np.float32)
    chvs = rng.integers(-127, 128, size=(7, seg * nseg)).astype(np.float32)
    full = np.asarray(HS.hd_search(jnp.asarray(q), jnp.asarray(chvs)))
    acc = np.zeros_like(full)
    for s in range(nseg):
        sl = slice(s * seg, (s + 1) * seg)
        acc += np.asarray(HS.hd_search(jnp.asarray(q[:, sl]),
                                       jnp.asarray(chvs[:, sl])))
    np.testing.assert_array_equal(full, acc)
