"""Dataset generators + binary container roundtrip."""

import numpy as np
import pytest

from compile import datasets as D
from compile.config import CONFIGS


def test_features_deterministic():
    cfg = CONFIGS["tiny"]
    (x1, y1), _ = D.gen_features(cfg)
    (x2, y2), _ = D.gen_features(cfg)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)


def test_features_shapes_and_labels():
    cfg = CONFIGS["tiny"]
    (xtr, ytr), (xte, yte) = D.gen_features(cfg)
    assert xtr.shape == (cfg.n_train, cfg.features)
    assert xte.shape == (cfg.n_test, cfg.features)
    assert ytr.max() < cfg.classes and yte.max() < cfg.classes
    assert len(np.unique(ytr)) == cfg.classes


def test_features_separable_by_nearest_mean():
    """Classes must be learnable — nearest-class-mean should beat 90% on
    tiny (sep=5); this anchors all Fig.9 accuracy results."""
    cfg = CONFIGS["tiny"]
    (xtr, ytr), (xte, yte) = D.gen_features(cfg)
    means = np.stack([xtr[ytr == c].mean(axis=0) for c in range(cfg.classes)])
    pred = np.argmin(
        ((xte[:, None, :] - means[None]) ** 2).sum(axis=2), axis=1)
    assert (pred == yte).mean() > 0.9


def test_images_shapes_and_range():
    cfg = CONFIGS["cifar100"]
    (xtr, ytr), (xte, yte) = D.gen_images(cfg)
    assert xtr.shape == (cfg.n_train, 32, 32, 3)
    assert 0.0 <= xtr.min() and xtr.max() <= 1.0
    assert ytr.dtype == np.uint16


def test_bin_roundtrip_f32(tmp_path):
    rng = np.random.default_rng(0)
    x = rng.standard_normal((10, 7)).astype(np.float32)
    y = rng.integers(0, 3, 10).astype(np.uint16)
    p = tmp_path / "d.bin"
    D.write_bin(str(p), x, y, 3)
    x2, y2, classes = D.read_bin(str(p))
    assert classes == 3
    np.testing.assert_array_equal(x, x2)
    np.testing.assert_array_equal(y, y2)


def test_bin_roundtrip_u8_images(tmp_path):
    rng = np.random.default_rng(1)
    x = rng.uniform(0, 1, size=(4, 8, 8, 3)).astype(np.float32)
    y = rng.integers(0, 2, 4).astype(np.uint16)
    p = tmp_path / "img.bin"
    D.write_bin(str(p), x, y, 2, (8, 8, 3), as_u8=True)
    x2, y2, _ = D.read_bin(str(p))
    assert x2.shape == (4, 8, 8, 3)
    assert np.abs(x2 - x).max() <= (0.5 / 255.0) + 1e-6
