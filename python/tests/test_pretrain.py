"""Pretraining utilities: k-means clustering + init shapes."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import pretrain as P
from compile.config import WcfeConfig


def test_kmeans_recovers_well_separated_clusters():
    rng = np.random.default_rng(0)
    centers = np.array([-3.0, 0.0, 4.0])
    v = np.concatenate([c + 0.01 * rng.standard_normal(50) for c in centers])
    cent, idx = P.kmeans_1d(v, 3, seed=0)
    np.testing.assert_allclose(np.sort(cent), centers, atol=0.05)
    assert idx.shape == v.shape


@settings(max_examples=15, deadline=None)
@given(k=st.sampled_from([2, 4, 16]), n=st.integers(50, 300),
       seed=st.integers(0, 2**31 - 1))
def test_kmeans_invariants(k, n, seed):
    rng = np.random.default_rng(seed)
    v = rng.standard_normal(n).astype(np.float32)
    cent, idx = P.kmeans_1d(v, k, seed=1)
    assert cent.shape == (k,)
    assert idx.min() >= 0 and idx.max() < k
    # assignment is nearest-centroid
    want = np.argmin(np.abs(v[:, None] - cent[None]), axis=1)
    np.testing.assert_array_equal(idx, want)
    # clustering reduces within-cluster error vs a single centroid
    err_k = np.abs(v - cent[idx]).mean()
    err_1 = np.abs(v - v.mean()).mean()
    assert err_k <= err_1 + 1e-6


def test_cluster_weights_reconstruction_error_small():
    wcfe = WcfeConfig(channels=(4, 4, 4), fc_out=8, clusters=16)
    rng = np.random.default_rng(2)
    params = P.init_params(wcfe, rng)
    clustered, codebooks = P.cluster_weights(params, wcfe, log=lambda *_: None)
    for name in ("conv1", "conv2", "conv3"):
        cent, idx = codebooks[name]
        np.testing.assert_array_equal(clustered[name], cent[idx])
        rel = np.abs(clustered[name] - params[name]).mean() / np.abs(params[name]).mean()
        assert rel < 0.2
        assert cent.shape == (wcfe.clusters,)


def test_init_params_shapes():
    wcfe = WcfeConfig()
    params = P.init_params(wcfe, np.random.default_rng(0))
    assert params["conv1"].shape == (27, 32)
    assert params["conv2"].shape == (288, 64)
    assert params["conv3"].shape == (576, 128)
    assert params["fc"].shape == (128, 512)
    assert params["head"].shape == (512, 100)
