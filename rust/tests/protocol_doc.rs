//! Pins `docs/PROTOCOL.md` to the implementation: the documented magic
//! numbers, opcodes, versions, caps, and header offsets must match the
//! constants in `serve::wire` and `hdc::knowledge`, so the written spec
//! cannot drift from the code it describes.

use clo_hdnn::hdc::knowledge;
use clo_hdnn::serve::wire;

fn spec() -> String {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/PROTOCOL.md");
    std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("docs/PROTOCOL.md must exist next to the code it pins: {e}"))
}

/// Assert the spec's constants table carries exactly this row.
fn pin(doc: &str, name: &str, value: &str) {
    let row = format!("| `{name}` | `{value}` |");
    assert!(
        doc.contains(&row),
        "docs/PROTOCOL.md is out of date: expected the constants table row\n  {row}\n\
         (the implementation constant changed, or the doc did)"
    );
}

#[test]
fn wire_constants_match_the_documented_table() {
    let doc = spec();
    pin(&doc, "MAX_FRAME", &wire::MAX_FRAME.to_string());
    pin(&doc, "WIRE_V1", &wire::WIRE_V1.to_string());
    pin(&doc, "WIRE_V2", &wire::WIRE_V2.to_string());
    pin(&doc, "MAX_INFLIGHT", &wire::MAX_INFLIGHT.to_string());
    pin(&doc, "OP_INFER", &format!("{:#04X}", wire::OP_INFER));
    pin(&doc, "OP_LEARN", &format!("{:#04X}", wire::OP_LEARN));
    pin(&doc, "OP_SNAPSHOT", &format!("{:#04X}", wire::OP_SNAPSHOT));
    pin(&doc, "OP_STATS", &format!("{:#04X}", wire::OP_STATS));
    pin(&doc, "OP_HELLO", &format!("{:#04X}", wire::OP_HELLO));
    pin(&doc, "OP_CONN_STATS", &format!("{:#04X}", wire::OP_CONN_STATS));
    pin(&doc, "OP_WAL_TAIL", &format!("{:#04X}", wire::OP_WAL_TAIL));
    pin(&doc, "OP_SNAPSHOT_FETCH", &format!("{:#04X}", wire::OP_SNAPSHOT_FETCH));
    pin(&doc, "OP_INFER_IMAGE", &format!("{:#04X}", wire::OP_INFER_IMAGE));
    pin(&doc, "OP_LEARN_IMAGE", &format!("{:#04X}", wire::OP_LEARN_IMAGE));
    pin(&doc, "OP_PROMOTE", &format!("{:#04X}", wire::OP_PROMOTE));
    pin(&doc, "OP_MODEL_ADD", &format!("{:#04X}", wire::OP_MODEL_ADD));
    pin(&doc, "OP_MODEL_REMOVE", &format!("{:#04X}", wire::OP_MODEL_REMOVE));
    pin(&doc, "KIND_ERROR", &format!("{:#04X}", wire::KIND_ERROR));
    pin(&doc, "MODE_DEFAULT", &format!("{:#04X}", wire::MODE_DEFAULT));
    pin(&doc, "MODE_L1", &format!("{:#04X}", wire::MODE_L1));
    pin(&doc, "MODE_PACKED", &format!("{:#04X}", wire::MODE_PACKED));
    pin(&doc, "FLAG_WCFE", &format!("{:#04X}", wire::FLAG_WCFE));
    pin(&doc, "FLAG_ESCALATED", &format!("{:#04X}", wire::FLAG_ESCALATED));
    // the 16 MiB cap really is 16 MiB
    assert_eq!(wire::MAX_FRAME, 16 * 1024 * 1024);
}

#[test]
fn clok_constants_match_the_documented_table() {
    let doc = spec();
    pin(
        &doc,
        "CLOK_MAGIC",
        &format!("\"{}\"", std::str::from_utf8(knowledge::MAGIC).unwrap()),
    );
    pin(&doc, "CLOK_VERSION", &knowledge::VERSION.to_string());
    pin(&doc, "CLOK_VERSION_MIN", &knowledge::VERSION_MIN.to_string());
    // the documented header offsets (magic 0, version 4, checksum 8,
    // payload 16) are the ones the loader actually reads
    for line in [
        "offset 0    magic     \"CLOK\"",
        "offset 4    version   u32",
        "offset 8    checksum  u64",
        "offset 16   payload:",
    ] {
        assert!(doc.contains(line), "CLOK layout line missing from spec: {line:?}");
    }
}

#[test]
fn documented_request_header_offsets_match_the_encoder() {
    let doc = spec();
    // the spec promises: id at 0 (u64), op at 8, v2 model str16 at 9 —
    // verify against real encoded frames, and that the doc states it
    for line in ["offset 8   op   u8", "offset 9   model  str16"] {
        assert!(doc.contains(line), "wire header line missing from spec: {line:?}");
    }
    let v1 = wire::WireRequest::new(0xAABB, wire::ReqBody::Stats)
        .encode(wire::WIRE_V1)
        .unwrap();
    assert_eq!(u64::from_le_bytes(v1[0..8].try_into().unwrap()), 0xAABB);
    assert_eq!(v1[8], wire::OP_STATS);
    let v2 = wire::WireRequest::for_model(1, "ab", wire::ReqBody::Stats)
        .encode(wire::WIRE_V2)
        .unwrap();
    assert_eq!(v2[8], wire::OP_STATS);
    assert_eq!(&v2[9..11], &2u16.to_le_bytes());
    assert_eq!(&v2[11..13], b"ab");
    // responses: id at 0, kind at 8 (KIND_ERROR for errors)
    let err = wire::WireResponse::Error { id: 7, msg: "x".into() }.encode();
    assert_eq!(err[8], wire::KIND_ERROR);
}

#[test]
fn documented_conn_stats_reply_layout_matches_the_encoder() {
    // the spec promises the conn-stats reply body in this exact order:
    // conn_id u64, age_ms u64, frames u64, replies u64, errors u64,
    // inflight u32, pending u32, peak_window u32, queued_write_bytes u64
    let stats = wire::WireConnStats {
        conn_id: 0x1111,
        age_ms: 0x2222,
        frames: 0x3333,
        replies: 0x4444,
        errors: 0x5555,
        inflight: 0x66,
        pending: 0x77,
        peak_window: 0x88,
        queued_write_bytes: 0x9999,
    };
    let buf = wire::WireResponse::ConnStats { id: 9, stats }.encode();
    assert_eq!(u64::from_le_bytes(buf[0..8].try_into().unwrap()), 9);
    assert_eq!(buf[8], wire::OP_CONN_STATS);
    let body = &buf[9..];
    assert_eq!(u64::from_le_bytes(body[0..8].try_into().unwrap()), 0x1111);
    assert_eq!(u64::from_le_bytes(body[8..16].try_into().unwrap()), 0x2222);
    assert_eq!(u64::from_le_bytes(body[16..24].try_into().unwrap()), 0x3333);
    assert_eq!(u64::from_le_bytes(body[24..32].try_into().unwrap()), 0x4444);
    assert_eq!(u64::from_le_bytes(body[32..40].try_into().unwrap()), 0x5555);
    assert_eq!(u32::from_le_bytes(body[40..44].try_into().unwrap()), 0x66);
    assert_eq!(u32::from_le_bytes(body[44..48].try_into().unwrap()), 0x77);
    assert_eq!(u32::from_le_bytes(body[48..52].try_into().unwrap()), 0x88);
    assert_eq!(u64::from_le_bytes(body[52..60].try_into().unwrap()), 0x9999);
    assert_eq!(body.len(), 60, "no trailing bytes in the conn-stats body");
}

#[test]
fn clow_constants_and_segment_layout_match_the_documented_spec() {
    use clo_hdnn::hdc::wal;
    let doc = spec();
    pin(
        &doc,
        "CLOW_MAGIC",
        &format!("\"{}\"", std::str::from_utf8(wal::MAGIC).unwrap()),
    );
    pin(&doc, "CLOW_VERSION", &wal::VERSION.to_string());
    pin(&doc, "CLOW_VERSION_MIN", &wal::VERSION_MIN.to_string());
    pin(&doc, "CLOW_FRAME_OVERHEAD", &wal::FRAME_OVERHEAD.to_string());
    pin(&doc, "CLOW_MAX_RECORD", &wal::MAX_RECORD.to_string());
    // the documented segment layout lines are present verbatim
    for line in [
        "offset 0   magic    \"CLOW\" (4 bytes)",
        "offset 4   version  u32    current = 2; loaders accept 1..=2",
        "header payload:   model str16, features u32, classes u32, base_seq u64,",
        "                  epoch u64 (v2; absent in v1 = epoch 0)",
        "record payload:   seq u64, class u32, n u32, n × f32",
    ] {
        assert!(doc.contains(line), "CLOW layout line missing from spec: {line:?}");
    }
    // ... and they are the bytes the writer actually emits. Segment
    // preamble: magic, version, framed header payload.
    let hdr = wal::SegmentHeader {
        model: "alpha".into(),
        features: 0x0101,
        classes: 0x0202,
        base_seq: 0x0303,
        epoch: 0x0404,
    };
    let b = hdr.to_bytes();
    assert_eq!(&b[0..4], wal::MAGIC);
    assert_eq!(&b[4..8], &wal::VERSION.to_le_bytes());
    let payload = &b[8 + wal::FRAME_OVERHEAD..];
    assert_eq!(
        u32::from_le_bytes(b[8..12].try_into().unwrap()) as usize,
        payload.len(),
        "frame length prefix covers exactly the payload"
    );
    assert_eq!(
        u64::from_le_bytes(b[12..20].try_into().unwrap()),
        knowledge::fnv1a64(payload),
        "frame checksum is CLOK's FNV-1a over the payload"
    );
    assert_eq!(&payload[0..2], &5u16.to_le_bytes());
    assert_eq!(&payload[2..7], b"alpha");
    assert_eq!(&payload[7..11], &0x0101u32.to_le_bytes());
    assert_eq!(&payload[11..15], &0x0202u32.to_le_bytes());
    assert_eq!(&payload[15..23], &0x0303u64.to_le_bytes());
    assert_eq!(&payload[23..31], &0x0404u64.to_le_bytes());
    assert_eq!(payload.len(), 31, "no trailing bytes in the header payload");
    // record frame: [len][checksum][seq u64, class u32, n u32, n × f32]
    let rec = wal::WalRecord { seq: 7, class: 3, features: vec![1.5, -2.5] };
    let f = rec.frame();
    assert_eq!(u32::from_le_bytes(f[0..4].try_into().unwrap()), 16 + 2 * 4);
    assert_eq!(
        u64::from_le_bytes(f[4..12].try_into().unwrap()),
        knowledge::fnv1a64(&f[12..])
    );
    assert_eq!(&f[12..20], &7u64.to_le_bytes());
    assert_eq!(&f[20..24], &3u32.to_le_bytes());
    assert_eq!(&f[24..28], &2u32.to_le_bytes());
    assert_eq!(&f[28..32], &1.5f32.to_le_bytes());
    assert_eq!(&f[32..36], &(-2.5f32).to_le_bytes());
    assert_eq!(f.len(), wal::FRAME_OVERHEAD + 24, "no trailing bytes in the record frame");
    // round-trip through the decoder the loader and the wire share
    assert_eq!(wal::WalRecord::from_payload(&f[12..]).unwrap(), rec);
}

#[test]
fn documented_stats_reply_layout_matches_the_encoder() {
    let doc = spec();
    // the spec promises the stats reply body in this exact order, with
    // epoch — the promotion generation — as the final u64
    for line in [
        "OP_STATS     served u64, wire_errors u64, learns u64,",
        "             trained_classes u32, snapshots u64, learn_seq u64",
        "             policy u8, policy_margin f32, epoch u64",
    ] {
        assert!(doc.contains(line), "stats reply line missing from spec: {line:?}");
    }
    let stats = wire::WireStats {
        served: 0x1111,
        wire_errors: 0x2222,
        learns: 0x3333,
        trained_classes: 0x44,
        snapshots: 0x5555,
        learn_seq: 0x6666,
        bypass: 0x7777,
        normal: 0x8888,
        escalations: 0x9999,
        policy: 3,
        policy_margin: 6.5,
        epoch: 0xAAAA,
    };
    let buf = wire::WireResponse::Stats { id: 9, stats }.encode();
    assert_eq!(u64::from_le_bytes(buf[0..8].try_into().unwrap()), 9);
    assert_eq!(buf[8], wire::OP_STATS);
    let body = &buf[9..];
    assert_eq!(u64::from_le_bytes(body[0..8].try_into().unwrap()), 0x1111);
    assert_eq!(u64::from_le_bytes(body[8..16].try_into().unwrap()), 0x2222);
    assert_eq!(u64::from_le_bytes(body[16..24].try_into().unwrap()), 0x3333);
    assert_eq!(u32::from_le_bytes(body[24..28].try_into().unwrap()), 0x44);
    assert_eq!(u64::from_le_bytes(body[28..36].try_into().unwrap()), 0x5555);
    assert_eq!(u64::from_le_bytes(body[36..44].try_into().unwrap()), 0x6666);
    assert_eq!(u64::from_le_bytes(body[44..52].try_into().unwrap()), 0x7777);
    assert_eq!(u64::from_le_bytes(body[52..60].try_into().unwrap()), 0x8888);
    assert_eq!(u64::from_le_bytes(body[60..68].try_into().unwrap()), 0x9999);
    assert_eq!(body[68], 3);
    assert_eq!(f32::from_le_bytes(body[69..73].try_into().unwrap()), 6.5);
    assert_eq!(u64::from_le_bytes(body[73..81].try_into().unwrap()), 0xAAAA);
    assert_eq!(body.len(), 81, "no trailing bytes in the stats body");
}

#[test]
fn documented_dual_mode_layouts_match_the_encoders() {
    let doc = spec();
    // the spec promises the extended infer reply, the image request
    // bodies, and the stats counter extension in these exact lines
    for line in [
        "OP_INFER     class u32, segments u32, early u8 (0|1),",
        "             flags u8, energy_j f64",
        "OP_INFER_IMAGE mode u8, n u32, n × f32",
        "OP_LEARN_IMAGE class u32, n u32, n × f32",
        "             bypass u64, normal u64, escalations u64,",
        "             policy u8, policy_margin f32",
    ] {
        assert!(doc.contains(line), "dual-mode line missing from spec: {line:?}");
    }
    // image-infer request: mode at 9, n at 10, pixels from 14 (v1 shape)
    let req = wire::WireRequest::new(
        2,
        wire::ReqBody::InferImage { mode: wire::MODE_PACKED, pixels: vec![0.25, -1.0] },
    )
    .encode(wire::WIRE_V1)
    .unwrap();
    assert_eq!(req[8], wire::OP_INFER_IMAGE);
    assert_eq!(req[9], wire::MODE_PACKED);
    assert_eq!(&req[10..14], &2u32.to_le_bytes());
    assert_eq!(&req[14..18], &0.25f32.to_le_bytes());
    assert_eq!(req.len(), 22);
    // image-learn request: class at 9, n at 13, pixels from 17
    let req = wire::WireRequest::new(3, wire::ReqBody::LearnImage { class: 6, pixels: vec![1.0] })
        .encode(wire::WIRE_V1)
        .unwrap();
    assert_eq!(req[8], wire::OP_LEARN_IMAGE);
    assert_eq!(&req[9..13], &6u32.to_le_bytes());
    assert_eq!(&req[13..17], &1u32.to_le_bytes());
    assert_eq!(req.len(), 21);
    // infer reply: flags at body offset 9, energy_j at 10..18
    let buf = wire::WireResponse::Infer {
        id: 5,
        class: 2,
        segments: 7,
        early: true,
        wcfe: true,
        escalated: false,
        energy_j: 1.5e-6,
    }
    .encode();
    assert_eq!(buf[8], wire::OP_INFER);
    let body = &buf[9..];
    assert_eq!(u32::from_le_bytes(body[0..4].try_into().unwrap()), 2);
    assert_eq!(u32::from_le_bytes(body[4..8].try_into().unwrap()), 7);
    assert_eq!(body[8], 1);
    assert_eq!(body[9], wire::FLAG_WCFE);
    assert_eq!(f64::from_le_bytes(body[10..18].try_into().unwrap()), 1.5e-6);
    assert_eq!(body.len(), 18, "no trailing bytes in the infer body");
}

#[test]
fn documented_replication_frame_layouts_match_the_encoders() {
    use clo_hdnn::hdc::wal::WalRecord;
    let doc = spec();
    for line in [
        "OP_WAL_TAIL  after u64",
        "OP_WAL_TAIL  base_seq u64, last_seq u64, epoch u64, count u32,",
        "             last_seq u64, img_len u32, img_len × u8",
    ] {
        assert!(doc.contains(line), "replication frame line missing from spec: {line:?}");
    }
    // request: after at the body offset (9 in v1)
    let req = wire::WireRequest::new(1, wire::ReqBody::WalTail { after: 0xABCD })
        .encode(wire::WIRE_V1)
        .unwrap();
    assert_eq!(req[8], wire::OP_WAL_TAIL);
    assert_eq!(&req[9..17], &0xABCDu64.to_le_bytes());
    assert_eq!(req.len(), 17);
    // wal-tail reply: base_seq, last_seq, epoch, count, then each record
    // as [rec_len u32][record payload] — the CLOW payload WITHOUT the
    // on-disk len/checksum frame
    let rec = WalRecord { seq: 5, class: 2, features: vec![0.25] };
    let buf = wire::WireResponse::WalTail {
        id: 3,
        base_seq: 0x0A,
        last_seq: 0x0B,
        epoch: 0x0E,
        records: vec![rec.clone()],
    }
    .encode();
    assert_eq!(buf[8], wire::OP_WAL_TAIL);
    let body = &buf[9..];
    assert_eq!(u64::from_le_bytes(body[0..8].try_into().unwrap()), 0x0A);
    assert_eq!(u64::from_le_bytes(body[8..16].try_into().unwrap()), 0x0B);
    assert_eq!(u64::from_le_bytes(body[16..24].try_into().unwrap()), 0x0E);
    assert_eq!(u32::from_le_bytes(body[24..28].try_into().unwrap()), 1);
    let rec_len = u32::from_le_bytes(body[28..32].try_into().unwrap()) as usize;
    assert_eq!(rec_len, 16 + 4, "seq u64 + class u32 + n u32 + one f32");
    assert_eq!(&body[32..32 + rec_len], &rec.payload()[..]);
    assert_eq!(body.len(), 32 + rec_len, "no trailing bytes after the last record");
    // snapshot-fetch reply: last_seq, img_len, raw CLOK bytes
    let buf = wire::WireResponse::SnapshotImage {
        id: 4,
        last_seq: 0x0C,
        image: vec![0xAA, 0xBB, 0xCC],
    }
    .encode();
    assert_eq!(buf[8], wire::OP_SNAPSHOT_FETCH);
    let body = &buf[9..];
    assert_eq!(u64::from_le_bytes(body[0..8].try_into().unwrap()), 0x0C);
    assert_eq!(u32::from_le_bytes(body[8..12].try_into().unwrap()), 3);
    assert_eq!(&body[12..15], &[0xAA, 0xBB, 0xCC]);
    assert_eq!(body.len(), 15, "no trailing bytes after the image");
}

#[test]
fn documented_promotion_and_model_admin_layouts_match_the_encoders() {
    let doc = spec();
    for line in [
        "OP_PROMOTE   (empty)",
        "OP_PROMOTE   epoch u64, base_seq u64",
        "OP_MODEL_ADD name str16, source str16",
        "OP_MODEL_REMOVE name str16",
        "count u16, count × model str16",
    ] {
        assert!(doc.contains(line), "fleet-lifecycle line missing from spec: {line:?}");
    }
    // promote request: empty body in both shapes
    let req = wire::WireRequest::new(1, wire::ReqBody::Promote)
        .encode(wire::WIRE_V1)
        .unwrap();
    assert_eq!(req[8], wire::OP_PROMOTE);
    assert_eq!(req.len(), 9, "the promote request body is empty");
    // promote reply: epoch u64, base_seq u64
    let buf = wire::WireResponse::Promote { id: 5, epoch: 0x0D, base_seq: 0x0E }.encode();
    assert_eq!(buf[8], wire::OP_PROMOTE);
    let body = &buf[9..];
    assert_eq!(u64::from_le_bytes(body[0..8].try_into().unwrap()), 0x0D);
    assert_eq!(u64::from_le_bytes(body[8..16].try_into().unwrap()), 0x0E);
    assert_eq!(body.len(), 16, "no trailing bytes in the promote body");
    // model-add request: name str16, source str16
    let req = wire::WireRequest::new(
        2,
        wire::ReqBody::ModelAdd { name: "xy".into(), source: "abc".into() },
    )
    .encode(wire::WIRE_V1)
    .unwrap();
    assert_eq!(req[8], wire::OP_MODEL_ADD);
    assert_eq!(&req[9..11], &2u16.to_le_bytes());
    assert_eq!(&req[11..13], b"xy");
    assert_eq!(&req[13..15], &3u16.to_le_bytes());
    assert_eq!(&req[15..18], b"abc");
    assert_eq!(req.len(), 18);
    // model-remove request: name str16
    let req = wire::WireRequest::new(3, wire::ReqBody::ModelRemove { name: "xy".into() })
        .encode(wire::WIRE_V1)
        .unwrap();
    assert_eq!(req[8], wire::OP_MODEL_REMOVE);
    assert_eq!(&req[9..11], &2u16.to_le_bytes());
    assert_eq!(&req[11..13], b"xy");
    assert_eq!(req.len(), 13);
    // model-admin reply: one shape, kind byte echoes the mutating opcode,
    // body is the post-mutation model list
    for op in [wire::OP_MODEL_ADD, wire::OP_MODEL_REMOVE] {
        let buf = wire::WireResponse::ModelAdmin {
            id: 7,
            op,
            models: vec!["a".into(), "bc".into()],
        }
        .encode();
        assert_eq!(buf[8], op, "the reply kind echoes the mutating opcode");
        let body = &buf[9..];
        assert_eq!(&body[0..2], &2u16.to_le_bytes());
        assert_eq!(&body[2..4], &1u16.to_le_bytes());
        assert_eq!(&body[4..5], b"a");
        assert_eq!(&body[5..7], &2u16.to_le_bytes());
        assert_eq!(&body[7..9], b"bc");
        assert_eq!(body.len(), 9, "no trailing bytes after the model list");
    }
}

#[test]
fn clok_model_field_sits_where_the_spec_says() {
    // the spec's version history: v2 = v1 + one model str16 placed
    // immediately after the config name. Pin that structurally: in a v2
    // image the two bytes right after the name str16 ARE the model length
    // (0 for an unnamed save), followed by the model bytes — and naming a
    // model grows the image by exactly len(model) over the unnamed v2
    // image (whose always-present model_len field covers the +2).
    use clo_hdnn::config::HdConfig;
    use clo_hdnn::hdc::chv::ChvStore;
    let cfg = HdConfig::synthetic("tcfg", 8, 8, 32, 32, 8, 4);
    let store = ChvStore::new(cfg);
    let unnamed = knowledge::to_bytes(&store);
    let named = knowledge::to_bytes_named(&store, "alpha");
    assert_eq!(named.len(), unnamed.len() + "alpha".len());
    assert_eq!(&unnamed[4..8], &knowledge::VERSION.to_le_bytes());
    // walk the payload: name str16, then the model str16 at the documented
    // offset in both images
    let payload = &unnamed[16..];
    let name_len = u16::from_le_bytes(payload[0..2].try_into().unwrap()) as usize;
    assert_eq!(&payload[2..2 + name_len], b"tcfg");
    let off = 2 + name_len;
    assert_eq!(
        &payload[off..off + 2],
        &0u16.to_le_bytes(),
        "unnamed v2 image carries an empty model field after the name"
    );
    let npayload = &named[16..];
    assert_eq!(
        &npayload[off..off + 2],
        &(b"alpha".len() as u16).to_le_bytes(),
        "model length immediately follows the config name"
    );
    assert_eq!(&npayload[off + 2..off + 2 + 5], b"alpha");
    // a v1 image (no model field) still loads — the back-compat fixture
    // lives in the knowledge unit tests; here we pin that the loader
    // window is exactly 1..=current
    assert_eq!(knowledge::VERSION_MIN, 1);
}
