//! Integration tests over the PJRT runtime + AOT artifacts: the lowered
//! Pallas/JAX executables must agree with the Rust software implementations
//! bit-for-bit, and the full serving path must work end-to-end.
//!
//! These tests are skipped (with a note) if `artifacts/` has not been built,
//! and the whole suite only compiles with `--features pjrt` (the default
//! build exercises the NativeBackend equivalents in `tests/native_backend.rs`
//! and `tests/golden.rs` instead).

#![cfg(feature = "pjrt")]

use clo_hdnn::config::HdConfig;
use clo_hdnn::data::{Dataset, TensorFile};
use clo_hdnn::hdc::encoder::SoftwareEncoder;
use clo_hdnn::hdc::{HdBackend, HdClassifier, ProgressiveSearch, Trainer};
use clo_hdnn::runtime::{Arg, Engine, PjrtBackend};
use clo_hdnn::util::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping runtime tests: artifacts/ missing (run make artifacts)");
        None
    }
}

fn software_twin(engine: &Engine, cfg: &HdConfig) -> SoftwareEncoder {
    let tf = TensorFile::load(engine.manifest.dir.join(format!("hd_factors_{}.bin", cfg.name)))
        .expect("factors bin");
    SoftwareEncoder::new(
        cfg.clone(),
        tf.f32_shaped("a", &[cfg.d1, cfg.f1]).unwrap().to_vec(),
        tf.f32_shaped("b", &[cfg.d2, cfg.f2]).unwrap().to_vec(),
    )
    .unwrap()
}

fn int8_features(cfg: &HdConfig, n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n * cfg.features())
        .map(|_| rng.range(-127, 128) as f32)
        .collect()
}

#[test]
fn pjrt_encode_full_matches_software() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::load(&dir).unwrap();
    let cfg = engine.manifest.config("tiny").unwrap().clone();
    let mut pjrt = PjrtBackend::new(&mut engine, "tiny", 1).unwrap();
    let mut sw = software_twin(&engine, &cfg);
    let xs = int8_features(&cfg, 1, 1);
    let got = pjrt.encode_full(&xs, 1).unwrap();
    let want = sw.encode_full(&xs, 1).unwrap();
    assert_eq!(got, want);
}

#[test]
fn pjrt_encode_segments_match_software_and_concat_to_full() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::load(&dir).unwrap();
    let cfg = engine.manifest.config("tiny").unwrap().clone();
    let mut pjrt = PjrtBackend::new(&mut engine, "tiny", 1).unwrap();
    let mut sw = software_twin(&engine, &cfg);
    let xs = int8_features(&cfg, 1, 2);
    let full = pjrt.encode_full(&xs, 1).unwrap();
    let mut cat = Vec::new();
    for s in 0..cfg.segments {
        let seg_pjrt = pjrt.encode_segment(&xs, 1, s).unwrap();
        let seg_sw = sw.encode_segment(&xs, 1, s).unwrap();
        assert_eq!(seg_pjrt, seg_sw, "segment {s}");
        cat.extend(seg_pjrt);
    }
    assert_eq!(cat, full);
}

#[test]
fn pjrt_batched_encode_matches_loop() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::load(&dir).unwrap();
    let cfg = engine.manifest.config("tiny").unwrap().clone();
    let mut b8 = PjrtBackend::new(&mut engine, "tiny", 8).unwrap();
    let xs = int8_features(&cfg, 8, 3);
    let batched = b8.encode_full(&xs, 8).unwrap();
    let mut b1 = PjrtBackend::new(&mut engine, "tiny", 1).unwrap();
    for n in 0..8 {
        let one = b1
            .encode_full(&xs[n * cfg.features()..(n + 1) * cfg.features()], 1)
            .unwrap();
        assert_eq!(&batched[n * cfg.dim()..(n + 1) * cfg.dim()], &one[..], "row {n}");
    }
    // partial batch via padding
    let part = b8.encode_full(&xs[..3 * cfg.features()], 3).unwrap();
    assert_eq!(&part[..], &batched[..3 * cfg.dim()]);
}

#[test]
fn pjrt_search_matches_software_l1() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::load(&dir).unwrap();
    let cfg = engine.manifest.config("tiny").unwrap().clone();
    let mut pjrt = PjrtBackend::new(&mut engine, "tiny", 1).unwrap();
    let mut rng = Rng::new(4);
    let q: Vec<f32> = (0..cfg.seg_len()).map(|_| rng.range(-127, 128) as f32).collect();
    let chv: Vec<f32> = (0..cfg.classes * cfg.seg_len())
        .map(|_| rng.range(-127, 128) as f32)
        .collect();
    let got = pjrt.search(&q, 1, &chv, cfg.classes, cfg.seg_len()).unwrap();
    let want =
        clo_hdnn::hdc::distance::l1_batch(&q, 1, &chv, cfg.classes, cfg.seg_len()).unwrap();
    assert_eq!(got, want);
}

#[test]
fn pjrt_train_update_executable_matches_semantics() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::load(&dir).unwrap();
    let cfg = engine.manifest.config("tiny").unwrap().clone();
    let exe = engine.executable("train_update_tiny").unwrap();
    let mut rng = Rng::new(5);
    let chvs: Vec<f32> = (0..cfg.classes * cfg.dim())
        .map(|_| rng.range(-120, 121) as f32)
        .collect();
    let qhv: Vec<f32> = (0..cfg.dim()).map(|_| rng.range(-127, 128) as f32).collect();
    let mut coef = vec![0.0f32; cfg.classes];
    coef[2] = 1.0;
    coef[7] = -1.0;
    let out = exe
        .run(&[
            Arg::F32(&chvs, &[cfg.classes, cfg.dim()]),
            Arg::F32(&qhv, &[cfg.dim()]),
            Arg::F32(&coef, &[cfg.classes]),
        ])
        .unwrap();
    for c in 0..cfg.classes {
        for i in 0..cfg.dim() {
            let want = (chvs[c * cfg.dim() + i] + coef[c] * qhv[i]).clamp(-127.0, 127.0);
            assert_eq!(out[c * cfg.dim() + i], want, "class {c} elem {i}");
        }
    }
}

#[test]
fn end_to_end_train_and_classify_tiny_via_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::load(&dir).unwrap();
    let backend = PjrtBackend::new(&mut engine, "tiny", 1).unwrap();
    let mut cl = HdClassifier::new(
        Box::new(backend),
        ProgressiveSearch { tau: 0.5, min_segments: 1, ..Default::default() },
    );
    let train = Dataset::load(engine.manifest.dataset_path("ds_tiny_train").unwrap()).unwrap();
    let test = Dataset::load(engine.manifest.dataset_path("ds_tiny_test").unwrap()).unwrap();
    let idx: Vec<usize> = (0..train.n).collect();
    Trainer { retrain_epochs: 1 }
        .train_indices(&mut cl, &train, &idx)
        .unwrap();
    let report = cl
        .evaluate((0..100).map(|i| (test.sample(i).to_vec(), test.label(i))))
        .unwrap();
    assert!(
        report.accuracy > 0.9,
        "tiny accuracy through PJRT: {}",
        report.accuracy
    );
}

#[test]
fn wcfe_forward_artifact_runs_and_matches_software_model() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::load(&dir).unwrap();
    let Some(wcfe) = engine.manifest.wcfe.clone() else {
        eprintln!("skipping: no wcfe in manifest");
        return;
    };
    let exe = engine.executable("wcfe_fwd_b1").unwrap();
    let tf = TensorFile::load(engine.manifest.dir.join(&wcfe.weights)).unwrap();
    let model = clo_hdnn::wcfe::WcfeModel::load(
        &tf,
        &wcfe.channels,
        wcfe.fc_out,
        wcfe.image_hw,
        wcfe.image_c,
    )
    .unwrap();
    let mut rng = Rng::new(6);
    let img: Vec<f32> = (0..wcfe.image_hw * wcfe.image_hw * wcfe.image_c)
        .map(|_| rng.uniform() as f32)
        .collect();
    let got = exe
        .run(&[Arg::F32(&img, &[1, wcfe.image_hw, wcfe.image_hw, wcfe.image_c])])
        .unwrap();
    let want = model.forward(&img).unwrap();
    assert_eq!(got.len(), want.len());
    // the artifact runs in BF16, the software twin in f32: compare loosely
    let mut max_rel: f32 = 0.0;
    let scale = want.iter().map(|v| v.abs()).fold(0.0f32, f32::max).max(1e-3);
    for (g, w) in got.iter().zip(&want) {
        max_rel = max_rel.max((g - w).abs() / scale);
    }
    assert!(max_rel < 0.05, "bf16-vs-f32 relative deviation {max_rel}");
}
