//! NativeBackend contracts: bit-exact equivalence with [`SoftwareEncoder`]
//! (single samples, batches, and batches assembled by the coordinator's
//! dynamic [`Batcher`]), the empty-batch guard, and the hermetic classify +
//! learn round-trip through the [`Coordinator`] with zero Python artifacts.

use clo_hdnn::config::HdConfig;
use clo_hdnn::coordinator::batcher::{BatchPolicy, Batcher};
use clo_hdnn::coordinator::{Coordinator, CoordinatorOptions, Payload};
use clo_hdnn::data::synthetic;
use clo_hdnn::hdc::encoder::SoftwareEncoder;
use clo_hdnn::hdc::HdBackend;
use clo_hdnn::runtime::NativeBackend;
use clo_hdnn::util::prop::{forall, gen};
use std::time::Duration;

fn tiny() -> HdConfig {
    HdConfig::synthetic("t", 8, 8, 32, 32, 8, 5)
}

#[test]
fn prop_native_equals_software_across_batches_and_segments() {
    forall(15, 0x4A7, |rng| {
        let cfg = tiny();
        let seed = rng.next_u64();
        let mut native = NativeBackend::seeded(cfg.clone(), seed, 8).unwrap();
        let mut sw = SoftwareEncoder::random(cfg.clone(), seed);
        let batch = 1 + rng.below(8);
        let xs = gen::int8_vec(rng, batch * cfg.features());
        assert_eq!(
            native.encode_full(&xs, batch).unwrap(),
            sw.encode_full(&xs, batch).unwrap()
        );
        let seg = rng.below(cfg.segments);
        assert_eq!(
            native.encode_segment(&xs, batch, seg).unwrap(),
            sw.encode_segment(&xs, batch, seg).unwrap()
        );
        let q = gen::int8_vec(rng, batch * cfg.seg_len());
        let chv = gen::int8_vec(rng, cfg.classes * cfg.seg_len());
        assert_eq!(
            native.search(&q, batch, &chv, cfg.classes, cfg.seg_len()).unwrap(),
            sw.search(&q, batch, &chv, cfg.classes, cfg.seg_len()).unwrap()
        );
    });
}

#[test]
fn batcher_assembled_batches_match_per_sample_encoding() {
    // The serving shape: requests queue in the dynamic Batcher, the executor
    // encodes each taken batch in one NativeBackend call. Row n of every
    // batched encode must equal the per-sample software encode.
    let cfg = tiny();
    let mut native = NativeBackend::seeded(cfg.clone(), 33, 8).unwrap();
    let mut sw = SoftwareEncoder::random(cfg.clone(), 33);
    let mut rng = clo_hdnn::util::Rng::new(34);
    let samples: Vec<Vec<f32>> = (0..13)
        .map(|_| gen::int8_vec(&mut rng, cfg.features()))
        .collect();

    let mut batcher: Batcher<Vec<f32>> =
        Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::from_secs(60) });
    for s in &samples {
        batcher.push(s.clone());
    }

    let mut seen = 0usize;
    while !batcher.is_empty() {
        let batch = batcher.take();
        let n = batch.len();
        assert!(n <= 8 && n > 0);
        let flat: Vec<f32> = batch.iter().flatten().copied().collect();
        let got = native.encode_full(&flat, n).unwrap();
        for (row, sample) in batch.iter().enumerate() {
            let want = sw.encode_full(sample, 1).unwrap();
            assert_eq!(
                &got[row * cfg.dim()..(row + 1) * cfg.dim()],
                &want[..],
                "batch row {row}"
            );
        }
        seen += n;
    }
    assert_eq!(seen, samples.len());
}

#[test]
fn empty_batch_is_an_error_not_a_panic() {
    let cfg = tiny();
    let mut native = NativeBackend::seeded(cfg.clone(), 1, 8).unwrap();
    let err = native.encode_full(&[], 0).unwrap_err();
    assert!(format!("{err:#}").contains("empty batch"), "{err:#}");
    assert!(native.encode_segment(&[], 0, 0).is_err());
    assert!(native.search(&[], 0, &[], cfg.classes, cfg.seg_len()).is_err());
}

#[test]
fn hermetic_classify_learn_round_trip_through_coordinator() {
    // The zero-artifact serving path end-to-end: synthetic config + blob
    // data -> Coordinator on a seeded NativeBackend -> online learn ->
    // progressive classify. No Python, no PJRT, no files.
    let cfg = synthetic::config("tiny").unwrap();
    let (train, test) = synthetic::blobs(&cfg, 6, 4, 99);
    let coord = Coordinator::start(CoordinatorOptions::software(cfg.clone())).unwrap();
    for i in 0..train.n {
        let r = coord
            .call(Payload::Learn(train.sample(i).to_vec(), train.label(i)))
            .unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
    }
    let mut correct = 0usize;
    let mut segments = 0usize;
    for i in 0..test.n {
        let r = coord
            .call(Payload::Features(test.sample(i).to_vec()))
            .unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        correct += usize::from(r.class == Some(test.label(i)));
        segments += r.segments_used;
        assert!(r.segments_used >= 1 && r.segments_used <= cfg.segments);
    }
    let acc = correct as f64 / test.n as f64;
    assert!(acc > 0.9, "hermetic round-trip accuracy {acc}");
    assert!(segments >= test.n, "at least one segment per request");
}
