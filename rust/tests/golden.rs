//! Golden-vector cross-checks for the HD compute kernels.
//!
//! The fixtures are generated at test time by an independent, deterministic
//! Rust oracle (dense Kronecker matmul, from-scratch round-half-even
//! quantizer, naive f64 distance loops, scalar saturating train update) and
//! carried through the CLOW tensor container — so the kron-encode, quantize,
//! search, and train assertions ALWAYS execute in CI; nothing silently
//! skips. When a Python-built `artifacts/golden.bin` (written by
//! `python -m compile.fixtures`) is present, the same assertions run against
//! the JAX oracle too: the Rust implementations must reproduce it
//! bit-for-bit.

use clo_hdnn::config::HdConfig;
use clo_hdnn::data::TensorFile;
use clo_hdnn::hdc::encoder::SoftwareEncoder;
use clo_hdnn::hdc::{distance, quantize, HdBackend};
use clo_hdnn::util::Rng;

/// The fixture's HD geometry (matches `python/compile/fixtures.py`).
fn golden_cfg() -> HdConfig {
    HdConfig::synthetic("g", 8, 8, 32, 32, 8, 4)
}

/// Independent implementations the fixtures are generated from. These avoid
/// the library code paths on purpose: the dense Kronecker product instead of
/// the two-stage encoder, a from-scratch round-half-even instead of
/// `f32::round_ties_even`, and plain f64 loops for distances and updates.
mod oracle {
    /// Round to nearest integer, ties to even.
    pub fn round_half_even(t: f64) -> f64 {
        let f = t.floor();
        let diff = t - f;
        if diff > 0.5 {
            f + 1.0
        } else if diff < 0.5 {
            f
        } else if (f as i64) % 2 == 0 {
            f
        } else {
            f + 1.0
        }
    }

    /// INT`bits` quantizer (INT1 = sign, never 0).
    pub fn quantize(y: f32, bits: u8, scale: f32) -> f32 {
        if bits == 1 {
            return if y >= 0.0 { 1.0 } else { -1.0 };
        }
        let m = ((1i32 << (bits - 1)) - 1) as f64;
        round_half_even((y / scale) as f64).clamp(-m, m) as f32
    }

    /// Dense (A ⊗ B) @ vec(X) encode of one sample, then quantize.
    pub fn kron_encode(
        a: &[f32],
        b: &[f32],
        x: &[f32],
        (d1, d2, f1, f2): (usize, usize, usize, usize),
        bits: u8,
        scale: f32,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; d1 * d2];
        for i1 in 0..d1 {
            for i2 in 0..d2 {
                let mut acc = 0.0f64;
                for j1 in 0..f1 {
                    for j2 in 0..f2 {
                        acc += (a[i1 * f1 + j1] * b[i2 * f2 + j2] * x[j1 * f2 + j2]) as f64;
                    }
                }
                out[i1 * d2 + i2] = quantize(acc as f32, bits, scale);
            }
        }
        out
    }

    /// Row-by-row L1 distances in f64.
    pub fn l1(q: &[f32], chvs: &[f32], classes: usize, len: usize) -> Vec<f32> {
        let batch = q.len() / len;
        let mut out = vec![0.0f32; batch * classes];
        for n in 0..batch {
            for c in 0..classes {
                let mut acc = 0.0f64;
                for i in 0..len {
                    acc += (q[n * len + i] - chvs[c * len + i]).abs() as f64;
                }
                out[n * classes + c] = acc as f32;
            }
        }
        out
    }

    /// Row-by-row negative dot in f64.
    pub fn neg_dot(q: &[f32], chvs: &[f32], classes: usize, len: usize) -> Vec<f32> {
        let batch = q.len() / len;
        let mut out = vec![0.0f32; batch * classes];
        for n in 0..batch {
            for c in 0..classes {
                let mut acc = 0.0f64;
                for i in 0..len {
                    acc += (q[n * len + i] * chvs[c * len + i]) as f64;
                }
                out[n * classes + c] = -acc as f32;
            }
        }
        out
    }

    /// Saturating per-class CHV update: chvs += coef ⊗ qhv, clamped to INT8.
    pub fn train_update(chvs: &[f32], qhv: &[f32], coef: &[f32]) -> Vec<f32> {
        let d = qhv.len();
        let mut out = chvs.to_vec();
        for (c, &co) in coef.iter().enumerate() {
            for i in 0..d {
                let v = out[c * d + i] as f64 + (co * qhv[i]) as f64;
                out[c * d + i] = v.clamp(-127.0, 127.0) as f32;
            }
        }
        out
    }
}

/// Deterministically generate the full fixture set with the oracle.
fn generate_fixture() -> TensorFile {
    let cfg = golden_cfg();
    let (d1, d2, f1, f2) = (cfg.d1, cfg.d2, cfg.f1, cfg.f2);
    let mut tf = TensorFile::default();
    let mut rng = Rng::new(0x601D);

    // kron encode: 4 samples, INT8/INT1/INT4 outputs. scale 24 keeps the
    // quotient grid coarse (multiples of 1/24), so exact .5 ties occur and
    // are exercised.
    let scale = 24.0f32;
    let a: Vec<f32> = (0..d1 * f1).map(|_| rng.sign()).collect();
    let b: Vec<f32> = (0..d2 * f2).map(|_| rng.sign()).collect();
    let x: Vec<f32> = (0..4 * f1 * f2).map(|_| rng.range(-100, 101) as f32).collect();
    for (bits, name) in [(8u8, "kron_out"), (1, "kron_out_b1"), (4, "kron_out_b4")] {
        let mut out = Vec::with_capacity(4 * d1 * d2);
        for n in 0..4 {
            out.extend(oracle::kron_encode(
                &a,
                &b,
                &x[n * f1 * f2..(n + 1) * f1 * f2],
                (d1, d2, f1, f2),
                bits,
                scale,
            ));
        }
        tf.insert_f32(name, &[4, d1 * d2], out);
    }
    tf.insert_f32("kron_a", &[d1, f1], a);
    tf.insert_f32("kron_b", &[d2, f2], b);
    tf.insert_f32("kron_x", &[4, f1 * f2], x);
    tf.insert_f32("kron_scale", &[1], vec![scale]);

    // search: 3 queries vs 12 CHVs of length 256
    let (batch, classes, len) = (3usize, 12usize, 256usize);
    let q: Vec<f32> = (0..batch * len).map(|_| rng.range(-127, 128) as f32).collect();
    let chv: Vec<f32> = (0..classes * len).map(|_| rng.range(-127, 128) as f32).collect();
    tf.insert_f32("search_l1", &[batch, classes], oracle::l1(&q, &chv, classes, len));
    tf.insert_f32(
        "search_dot",
        &[batch, classes],
        oracle::neg_dot(&q, &chv, classes, len),
    );
    tf.insert_f32("search_q", &[batch, len], q);
    tf.insert_f32("search_chv", &[classes, len], chv);

    // train update: 6 classes x D=512, coefficients in {-1, 0, +1}
    let (c_n, d_n) = (6usize, 512usize);
    let chvs: Vec<f32> = (0..c_n * d_n).map(|_| rng.range(-120, 121) as f32).collect();
    let qhv: Vec<f32> = (0..d_n).map(|_| rng.range(-127, 128) as f32).collect();
    let coef: Vec<f32> = vec![1.0, -1.0, 0.0, 1.0, 0.0, -1.0];
    tf.insert_f32("train_out", &[c_n, d_n], oracle::train_update(&chvs, &qhv, &coef));
    tf.insert_f32("train_chvs", &[c_n, d_n], chvs);
    tf.insert_f32("train_qhv", &[d_n], qhv);
    tf.insert_f32("train_coef", &[c_n], coef);

    // quantizer: specials (zeros, exact ties at multiples of 1.25, clipping
    // extremes) plus random values; scale fixed at 2.5 like the JAX fixture
    let mut quant_in: Vec<f32> = vec![0.0, -0.0, 1e9, -1e9, 317.5, -317.5];
    for k in -8..=8 {
        quant_in.push(k as f32 * 1.25);
    }
    for _ in 0..224 {
        quant_in.push(rng.normal_f32() * 10.0);
    }
    for bits in [1u8, 2, 4, 8] {
        let out: Vec<f32> = quant_in.iter().map(|&v| oracle::quantize(v, bits, 2.5)).collect();
        tf.insert_f32(&format!("quant_out_b{bits}"), &[quant_in.len()], out);
    }
    let n = quant_in.len();
    tf.insert_f32("quant_in", &[n], quant_in);

    tf
}

/// The JAX-written fixture, when the Python toolchain has produced it.
fn python_golden() -> Option<TensorFile> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/golden.bin");
    if !path.exists() {
        return None;
    }
    Some(TensorFile::load(path).expect("load golden.bin"))
}

fn check_kron(tf: &TensorFile) {
    let a = tf.f32("kron_a").unwrap().to_vec();
    let b = tf.f32("kron_b").unwrap().to_vec();
    let x = tf.f32("kron_x").unwrap();
    let scale = tf.f32("kron_scale").unwrap()[0];
    let mut cfg = golden_cfg();
    cfg.scale_q = scale;
    let mut enc = SoftwareEncoder::new(cfg.clone(), a.clone(), b.clone()).unwrap();
    let got = enc.encode_full(x, 4).unwrap();
    assert_eq!(got, tf.f32("kron_out").unwrap());

    // INT1 and INT4 modes
    for (bits, name) in [(1u8, "kron_out_b1"), (4, "kron_out_b4")] {
        let mut c = cfg.clone();
        c.qbits = bits;
        let mut e = SoftwareEncoder::new(c, a.clone(), b.clone()).unwrap();
        assert_eq!(e.encode_full(x, 4).unwrap(), tf.f32(name).unwrap(), "bits={bits}");
    }
}

fn check_search(tf: &TensorFile) {
    let q = tf.f32("search_q").unwrap();
    let chv = tf.f32("search_chv").unwrap();
    let l1 = distance::l1_batch(q, 3, chv, 12, 256).unwrap();
    assert_eq!(l1, tf.f32("search_l1").unwrap());
    let dot = distance::neg_dot_batch(q, 3, chv, 12, 256).unwrap();
    let want = tf.f32("search_dot").unwrap();
    for (g, w) in dot.iter().zip(want) {
        assert!((g - w).abs() < 1e-2, "{g} vs {w}");
    }
}

fn check_train(tf: &TensorFile) {
    let chvs = tf.f32("train_chvs").unwrap();
    let qhv = tf.f32("train_qhv").unwrap();
    let coef = tf.f32("train_coef").unwrap();
    let want = tf.f32("train_out").unwrap();
    // the raw saturating chip update (== the train_update HLO artifact)
    let mut got = chvs.to_vec();
    clo_hdnn::hdc::chv::raw_update(&mut got, qhv, coef);
    assert_eq!(got, want);
}

fn check_quant(tf: &TensorFile) {
    let y = tf.f32("quant_in").unwrap();
    for bits in [1u8, 2, 4, 8] {
        let want = tf.f32(&format!("quant_out_b{bits}")).unwrap();
        for (i, &v) in y.iter().enumerate() {
            let got = quantize::quantize(v, bits, 2.5);
            assert_eq!(got, want[i], "bits={bits} idx={i} in={v}");
        }
    }
}

#[test]
fn kron_encode_matches_dense_oracle() {
    check_kron(&generate_fixture());
}

#[test]
fn search_matches_naive_oracle() {
    check_search(&generate_fixture());
}

#[test]
fn train_update_matches_scalar_oracle() {
    check_train(&generate_fixture());
}

#[test]
fn quantizer_matches_independent_rounding_oracle() {
    check_quant(&generate_fixture());
}

#[test]
fn fixture_roundtrips_through_clow_container() {
    // the on-disk path the Python fixtures travel: write, reload, re-check
    let dir = std::env::temp_dir().join("clo_hdnn_golden_selfgen");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("golden_rust.bin");
    let tf = generate_fixture();
    tf.save(&path).unwrap();
    let back = TensorFile::load(&path).unwrap();
    assert_eq!(back.tensors, tf.tensors);
    check_kron(&back);
    check_search(&back);
    check_train(&back);
    check_quant(&back);
}

#[test]
fn jax_golden_still_matches_when_present() {
    match python_golden() {
        Some(tf) => {
            check_kron(&tf);
            check_search(&tf);
            check_train(&tf);
            check_quant(&tf);
        }
        None => {
            // Not a skip: the contract is fully exercised by the Rust oracle
            // above; the JAX fixture is an additional cross-toolchain check.
            eprintln!("artifacts/golden.bin absent; JAX cross-check covered by Rust oracle");
        }
    }
}
