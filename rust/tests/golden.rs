//! Golden-vector cross-checks: the Rust software implementations must
//! reproduce the JAX oracle exactly (artifacts/golden.bin, written by
//! python -m compile.fixtures).

use clo_hdnn::config::HdConfig;
use clo_hdnn::data::TensorFile;
use clo_hdnn::hdc::encoder::SoftwareEncoder;
use clo_hdnn::hdc::{distance, quantize, HdBackend};

fn golden() -> Option<TensorFile> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/golden.bin");
    if !path.exists() {
        eprintln!("skipping golden tests: {} missing (run make artifacts)", path.display());
        return None;
    }
    Some(TensorFile::load(path).expect("load golden.bin"))
}

#[test]
fn kron_encode_matches_jax_oracle() {
    let Some(tf) = golden() else { return };
    let a = tf.f32("kron_a").unwrap().to_vec();
    let b = tf.f32("kron_b").unwrap().to_vec();
    let x = tf.f32("kron_x").unwrap();
    let scale = tf.f32("kron_scale").unwrap()[0];
    let mut cfg = HdConfig::synthetic("g", 8, 8, 32, 32, 8, 4);
    cfg.scale_q = scale;
    let mut enc = SoftwareEncoder::new(cfg.clone(), a.clone(), b.clone()).unwrap();
    let got = enc.encode_full(x, 4).unwrap();
    assert_eq!(got, tf.f32("kron_out").unwrap());

    // INT1 and INT4 modes
    for (bits, name) in [(1u8, "kron_out_b1"), (4, "kron_out_b4")] {
        let mut c = cfg.clone();
        c.qbits = bits;
        let mut e = SoftwareEncoder::new(c, a.clone(), b.clone()).unwrap();
        assert_eq!(e.encode_full(x, 4).unwrap(), tf.f32(name).unwrap(), "bits={bits}");
    }
}

#[test]
fn search_matches_jax_oracle() {
    let Some(tf) = golden() else { return };
    let q = tf.f32("search_q").unwrap();
    let chv = tf.f32("search_chv").unwrap();
    let l1 = distance::l1_batch(q, 3, chv, 12, 256).unwrap();
    assert_eq!(l1, tf.f32("search_l1").unwrap());
    let dot = distance::neg_dot_batch(q, 3, chv, 12, 256).unwrap();
    let want = tf.f32("search_dot").unwrap();
    for (g, w) in dot.iter().zip(want) {
        assert!((g - w).abs() < 1e-2, "{g} vs {w}");
    }
}

#[test]
fn train_update_matches_jax_oracle() {
    let Some(tf) = golden() else { return };
    let chvs = tf.f32("train_chvs").unwrap();
    let qhv = tf.f32("train_qhv").unwrap();
    let coef = tf.f32("train_coef").unwrap();
    let want = tf.f32("train_out").unwrap();
    // the raw saturating chip update (== the train_update HLO artifact)
    let mut got = chvs.to_vec();
    clo_hdnn::hdc::chv::raw_update(&mut got, qhv, coef);
    assert_eq!(got, want);
}

#[test]
fn quantizer_matches_jax_oracle() {
    let Some(tf) = golden() else { return };
    let y = tf.f32("quant_in").unwrap();
    for bits in [1u8, 2, 4, 8] {
        let want = tf.f32(&format!("quant_out_b{bits}")).unwrap();
        for (i, &v) in y.iter().enumerate() {
            let got = quantize::quantize(v, bits, 2.5);
            assert_eq!(got, want[i], "bits={bits} idx={i} in={v}");
        }
    }
}
