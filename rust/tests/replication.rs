//! End-to-end durability + replication tests over live TCP sockets: a
//! primary that logs every acknowledged Learn to its WAL, a torn-tail
//! "crash" whose recovery rebuilds a bit-identical knowledge store, the
//! `OP_WAL_TAIL` / `OP_SNAPSHOT_FETCH` replication opcodes spoken through
//! the real client, and a follower server that keeps answering Infer
//! traffic with zero wire errors after the primary dies.
//!
//! These complement the module-level tests: `hdc::wal` pins the record
//! format and torn-tail truncation, `coordinator::server` pins the
//! executor-side handlers, and `serve::replica` pins the tailer against an
//! in-process coordinator. Here every hop crosses a real socket.

use clo_hdnn::config::HdConfig;
use clo_hdnn::coordinator::{Coordinator, CoordinatorOptions};
use clo_hdnn::hdc::knowledge;
use clo_hdnn::serve::{Client, Registry, Replica, ReplicaOptions, ServeOptions, Server};
use clo_hdnn::util::Rng;
use std::io::Write;
use std::time::Duration;

fn cfg4() -> HdConfig {
    HdConfig::synthetic("t", 8, 8, 32, 32, 8, 4)
}

fn protos(cfg: &HdConfig, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..cfg.classes)
        .map(|_| (0..cfg.features()).map(|_| rng.normal_f32() * 40.0).collect())
        .collect()
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("clo_hdnn_replication");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Poll `f` every 10 ms until it holds or `ms` elapses.
fn wait_until(f: impl Fn() -> bool, ms: u64) -> bool {
    let deadline = std::time::Instant::now() + Duration::from_millis(ms);
    while std::time::Instant::now() < deadline {
        if f() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    f()
}

/// A single-model server named "t", optionally logging learns to `wal`.
fn start_server(cfg: &HdConfig, wal: Option<&std::path::Path>) -> Server {
    let mut opts = CoordinatorOptions::software(cfg.clone());
    opts.wal_path = wal.map(|p| p.to_path_buf());
    let coord = Coordinator::start(opts).unwrap();
    let serve_opts = ServeOptions { allow_snapshot_paths: true, ..ServeOptions::default() };
    Server::start("127.0.0.1:0", Registry::single("t", coord), serve_opts).unwrap()
}

#[test]
fn acked_learns_survive_a_torn_tail_and_rebuild_bit_identically() {
    let cfg = cfg4();
    let ps = protos(&cfg, 91);
    let wal = tmp("crash.clow");
    let _ = std::fs::remove_file(&wal);

    // learn over the wire: every reply here means the record is fsynced
    let server = start_server(&cfg, Some(&wal));
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    for _ in 0..3 {
        for (c, p) in ps.iter().enumerate() {
            client.learn(p, c).unwrap();
        }
    }
    let stats = client.stats().unwrap();
    assert_eq!(stats.learns, 12);
    assert_eq!(stats.learn_seq, 12, "every acknowledged learn is sequenced");
    drop(client);
    server.stop();

    // simulate the crash artifact a kill -9 leaves behind: a torn,
    // half-written append at the tail of the segment
    let before = std::fs::metadata(&wal).unwrap().len();
    let mut f = std::fs::OpenOptions::new().append(true).open(&wal).unwrap();
    f.write_all(&[0x55; 7]).unwrap();
    drop(f);

    // recovery: the torn tail is discarded, the 12 acknowledged learns
    // replay, and the server answers exactly as before the crash
    let recovered = start_server(&cfg, Some(&wal));
    let addr = recovered.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    let stats = client.stats().unwrap();
    assert_eq!(stats.learns, 12, "replay recovers the acknowledged prefix");
    assert_eq!(stats.learn_seq, 12);
    assert!(
        std::fs::metadata(&wal).unwrap().len() <= before,
        "recovery must not keep the torn bytes"
    );
    for (c, p) in ps.iter().enumerate() {
        assert_eq!(client.infer(p).unwrap().class, c);
    }
    let rec_snap = tmp("crash_recovered.clok");
    let _ = std::fs::remove_file(&rec_snap);
    client.snapshot(Some(rec_snap.to_str().unwrap())).unwrap();
    drop(client);
    recovered.stop();

    // reference: the same 12 learns into a fresh store, never crashed
    let reference = start_server(&cfg, None);
    let addr = reference.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    for _ in 0..3 {
        for (c, p) in ps.iter().enumerate() {
            client.learn(p, c).unwrap();
        }
    }
    let ref_snap = tmp("crash_reference.clok");
    let _ = std::fs::remove_file(&ref_snap);
    client.snapshot(Some(ref_snap.to_str().unwrap())).unwrap();
    drop(client);
    reference.stop();

    let rec = std::fs::read(&rec_snap).unwrap();
    let reference = std::fs::read(&ref_snap).unwrap();
    assert_eq!(rec, reference, "recovered store must be bit-identical to the reference");
}

#[test]
fn wal_tail_and_snapshot_fetch_speak_over_live_sockets() {
    let cfg = cfg4();
    let ps = protos(&cfg, 91);
    let wal = tmp("tail.clow");
    let _ = std::fs::remove_file(&wal);
    let server = start_server(&cfg, Some(&wal));
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    for (c, p) in ps.iter().enumerate() {
        client.learn(p, c).unwrap();
    }

    // full tail from the origin: every acknowledged learn, in order
    let t = client.wal_tail(0).unwrap();
    assert_eq!(t.base_seq, 0);
    assert_eq!(t.last_seq, 4);
    assert_eq!(t.records.len(), 4);
    for (i, rec) in t.records.iter().enumerate() {
        assert_eq!(rec.seq, i as u64 + 1);
        assert_eq!(rec.class as usize, i);
        assert_eq!(rec.features, ps[i]);
    }

    // caught-up tail: an empty (idle) reply, not an error
    let t = client.wal_tail(4).unwrap();
    assert_eq!(t.last_seq, 4);
    assert!(t.records.is_empty());

    // bootstrap image: a loadable CLOK checkpoint of the live store,
    // stamped with the sequence it captures
    let (seq, image) = client.snapshot_fetch().unwrap();
    assert_eq!(seq, 4);
    assert_eq!(&image[..4], b"CLOK");
    let store = knowledge::from_bytes(&image).unwrap();
    assert_eq!(store.total_learns(), 4);
    assert_eq!(store.trained_classes(), 4);

    drop(client);
    server.stop();
}

#[test]
fn follower_serves_reads_over_tcp_with_zero_wire_errors_while_primary_down() {
    let cfg = cfg4();
    let ps = protos(&cfg, 91);
    let wal = tmp("fanout.clow");
    let _ = std::fs::remove_file(&wal);

    let primary = start_server(&cfg, Some(&wal));
    let primary_addr = primary.local_addr().to_string();
    let mut feeder = Client::connect(&primary_addr).unwrap();
    for _ in 0..2 {
        for (c, p) in ps.iter().enumerate() {
            feeder.learn(p, c).unwrap();
        }
    }

    // the follower is itself a full TCP server; the tailer applies the
    // primary's log to the same coordinator the socket serves from
    let follower_coord =
        Coordinator::start(CoordinatorOptions::software(cfg.clone())).unwrap();
    let registry = Registry::single("t", follower_coord);
    let local = registry.get("t").unwrap().clone();
    let follower = Server::start("127.0.0.1:0", registry, ServeOptions::default()).unwrap();
    let follower_addr = follower.local_addr().to_string();
    let replica = Replica::start(local, ReplicaOptions::new(&primary_addr)).unwrap();

    // convergence is observable over the wire: the follower's own Stats
    // carries the applied learn_seq
    let mut reader = Client::connect(&follower_addr).unwrap();
    assert!(
        wait_until(
            || {
                let mut c = Client::connect(&follower_addr).unwrap();
                c.stats().map(|s| s.learn_seq == 8).unwrap_or(false)
            },
            5000
        ),
        "follower never caught up to learn_seq 8 (status {:?})",
        replica.status()
    );

    // kill the primary; the follower keeps answering from its converged
    // state — no wire errors, no stale-model misclassification
    drop(feeder);
    primary.stop();
    assert!(
        wait_until(|| !replica.status().connected, 5000),
        "tailer never noticed the dead primary"
    );
    for _ in 0..3 {
        for (c, p) in ps.iter().enumerate() {
            let r = reader.infer(p).unwrap();
            assert_eq!(r.class, c, "follower must serve class {c} while the primary is down");
        }
    }
    let stats = reader.stats().unwrap();
    assert_eq!(stats.wire_errors, 0, "read fan-out must be error-free");
    assert_eq!(stats.learn_seq, 8, "the follower's applied sequence is stable");

    drop(reader);
    replica.stop();
    follower.stop();
}
