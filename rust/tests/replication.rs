//! End-to-end durability + replication tests over live TCP sockets: a
//! primary that logs every acknowledged Learn to its WAL, a torn-tail
//! "crash" whose recovery rebuilds a bit-identical knowledge store, the
//! `OP_WAL_TAIL` / `OP_SNAPSHOT_FETCH` replication opcodes spoken through
//! the real client, and a follower server that keeps answering Infer
//! traffic with zero wire errors after the primary dies.
//!
//! These complement the module-level tests: `hdc::wal` pins the record
//! format and torn-tail truncation, `coordinator::server` pins the
//! executor-side handlers, and `serve::replica` pins the tailer against an
//! in-process coordinator. Here every hop crosses a real socket.

use clo_hdnn::config::HdConfig;
use clo_hdnn::coordinator::{Coordinator, CoordinatorOptions};
use clo_hdnn::hdc::knowledge;
use clo_hdnn::serve::{Client, Registry, Replica, ReplicaOptions, ServeOptions, Server};
use clo_hdnn::util::Rng;
use std::io::Write;
use std::time::Duration;

fn cfg4() -> HdConfig {
    HdConfig::synthetic("t", 8, 8, 32, 32, 8, 4)
}

fn protos(cfg: &HdConfig, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..cfg.classes)
        .map(|_| (0..cfg.features()).map(|_| rng.normal_f32() * 40.0).collect())
        .collect()
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("clo_hdnn_replication");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Poll `f` every 10 ms until it holds or `ms` elapses.
fn wait_until(f: impl Fn() -> bool, ms: u64) -> bool {
    let deadline = std::time::Instant::now() + Duration::from_millis(ms);
    while std::time::Instant::now() < deadline {
        if f() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    f()
}

/// A single-model server named "t", optionally logging learns to `wal`.
fn start_server(cfg: &HdConfig, wal: Option<&std::path::Path>) -> Server {
    let mut opts = CoordinatorOptions::software(cfg.clone());
    opts.wal_path = wal.map(|p| p.to_path_buf());
    let coord = Coordinator::start(opts).unwrap();
    let serve_opts = ServeOptions { allow_snapshot_paths: true, ..ServeOptions::default() };
    Server::start("127.0.0.1:0", Registry::single("t", coord), serve_opts).unwrap()
}

#[test]
fn acked_learns_survive_a_torn_tail_and_rebuild_bit_identically() {
    let cfg = cfg4();
    let ps = protos(&cfg, 91);
    let wal = tmp("crash.clow");
    let _ = std::fs::remove_file(&wal);

    // learn over the wire: every reply here means the record is fsynced
    let server = start_server(&cfg, Some(&wal));
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    for _ in 0..3 {
        for (c, p) in ps.iter().enumerate() {
            client.learn(p, c).unwrap();
        }
    }
    let stats = client.stats().unwrap();
    assert_eq!(stats.learns, 12);
    assert_eq!(stats.learn_seq, 12, "every acknowledged learn is sequenced");
    drop(client);
    server.stop();

    // simulate the crash artifact a kill -9 leaves behind: a torn,
    // half-written append at the tail of the segment
    let before = std::fs::metadata(&wal).unwrap().len();
    let mut f = std::fs::OpenOptions::new().append(true).open(&wal).unwrap();
    f.write_all(&[0x55; 7]).unwrap();
    drop(f);

    // recovery: the torn tail is discarded, the 12 acknowledged learns
    // replay, and the server answers exactly as before the crash
    let recovered = start_server(&cfg, Some(&wal));
    let addr = recovered.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    let stats = client.stats().unwrap();
    assert_eq!(stats.learns, 12, "replay recovers the acknowledged prefix");
    assert_eq!(stats.learn_seq, 12);
    assert!(
        std::fs::metadata(&wal).unwrap().len() <= before,
        "recovery must not keep the torn bytes"
    );
    for (c, p) in ps.iter().enumerate() {
        assert_eq!(client.infer(p).unwrap().class, c);
    }
    let rec_snap = tmp("crash_recovered.clok");
    let _ = std::fs::remove_file(&rec_snap);
    client.snapshot(Some(rec_snap.to_str().unwrap())).unwrap();
    drop(client);
    recovered.stop();

    // reference: the same 12 learns into a fresh store, never crashed
    let reference = start_server(&cfg, None);
    let addr = reference.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    for _ in 0..3 {
        for (c, p) in ps.iter().enumerate() {
            client.learn(p, c).unwrap();
        }
    }
    let ref_snap = tmp("crash_reference.clok");
    let _ = std::fs::remove_file(&ref_snap);
    client.snapshot(Some(ref_snap.to_str().unwrap())).unwrap();
    drop(client);
    reference.stop();

    let rec = std::fs::read(&rec_snap).unwrap();
    let reference = std::fs::read(&ref_snap).unwrap();
    assert_eq!(rec, reference, "recovered store must be bit-identical to the reference");
}

#[test]
fn wal_tail_and_snapshot_fetch_speak_over_live_sockets() {
    let cfg = cfg4();
    let ps = protos(&cfg, 91);
    let wal = tmp("tail.clow");
    let _ = std::fs::remove_file(&wal);
    let server = start_server(&cfg, Some(&wal));
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    for (c, p) in ps.iter().enumerate() {
        client.learn(p, c).unwrap();
    }

    // full tail from the origin: every acknowledged learn, in order
    let t = client.wal_tail(0).unwrap();
    assert_eq!(t.base_seq, 0);
    assert_eq!(t.last_seq, 4);
    assert_eq!(t.records.len(), 4);
    for (i, rec) in t.records.iter().enumerate() {
        assert_eq!(rec.seq, i as u64 + 1);
        assert_eq!(rec.class as usize, i);
        assert_eq!(rec.features, ps[i]);
    }

    // caught-up tail: an empty (idle) reply, not an error
    let t = client.wal_tail(4).unwrap();
    assert_eq!(t.last_seq, 4);
    assert!(t.records.is_empty());

    // bootstrap image: a loadable CLOK checkpoint of the live store,
    // stamped with the sequence it captures
    let (seq, image) = client.snapshot_fetch().unwrap();
    assert_eq!(seq, 4);
    assert_eq!(&image[..4], b"CLOK");
    let store = knowledge::from_bytes(&image).unwrap();
    assert_eq!(store.total_learns(), 4);
    assert_eq!(store.trained_classes(), 4);

    drop(client);
    server.stop();
}

#[test]
fn follower_serves_reads_over_tcp_with_zero_wire_errors_while_primary_down() {
    let cfg = cfg4();
    let ps = protos(&cfg, 91);
    let wal = tmp("fanout.clow");
    let _ = std::fs::remove_file(&wal);

    let primary = start_server(&cfg, Some(&wal));
    let primary_addr = primary.local_addr().to_string();
    let mut feeder = Client::connect(&primary_addr).unwrap();
    for _ in 0..2 {
        for (c, p) in ps.iter().enumerate() {
            feeder.learn(p, c).unwrap();
        }
    }

    // the follower is itself a full TCP server; the tailer applies the
    // primary's log to the same coordinator the socket serves from
    let follower_coord =
        Coordinator::start(CoordinatorOptions::software(cfg.clone())).unwrap();
    let registry = Registry::single("t", follower_coord);
    let local = registry.get("t").unwrap().clone();
    let follower = Server::start("127.0.0.1:0", registry, ServeOptions::default()).unwrap();
    let follower_addr = follower.local_addr().to_string();
    let replica = Replica::start(local, ReplicaOptions::new(&primary_addr)).unwrap();

    // convergence is observable over the wire: the follower's own Stats
    // carries the applied learn_seq
    let mut reader = Client::connect(&follower_addr).unwrap();
    assert!(
        wait_until(
            || {
                let mut c = Client::connect(&follower_addr).unwrap();
                c.stats().map(|s| s.learn_seq == 8).unwrap_or(false)
            },
            5000
        ),
        "follower never caught up to learn_seq 8 (status {:?})",
        replica.status()
    );

    // kill the primary; the follower keeps answering from its converged
    // state — no wire errors, no stale-model misclassification
    drop(feeder);
    primary.stop();
    assert!(
        wait_until(|| !replica.status().connected, 5000),
        "tailer never noticed the dead primary"
    );
    for _ in 0..3 {
        for (c, p) in ps.iter().enumerate() {
            let r = reader.infer(p).unwrap();
            assert_eq!(r.class, c, "follower must serve class {c} while the primary is down");
        }
    }
    let stats = reader.stats().unwrap();
    assert_eq!(stats.wire_errors, 0, "read fan-out must be error-free");
    assert_eq!(stats.learn_seq, 8, "the follower's applied sequence is stable");

    drop(reader);
    replica.stop();
    follower.stop();
}

// ---------------------------------------------------------------------------
// Chaos-failover suite: seeded fault schedules in learn-seq space.
// ---------------------------------------------------------------------------

/// A seeded chaos schedule. Every trigger is expressed in learn-sequence
/// space — "kill the primary after learn k" — never in wall-clock time, so
/// the same plan replays identically on a loaded CI box and a fast laptop,
/// and under any `CLO_HDNN_THREADS` setting: the drivers below are
/// single-threaded clients, so the applied `(class, features)` stream (and
/// therefore the CLOK bytes) does not depend on how many worker threads
/// the backends use.
struct FaultPlan {
    seed: u64,
    /// the primary dies after acknowledging exactly this many learns
    kill_at: u64,
    /// learns driven into the promoted follower after takeover
    after_promotion: u64,
}

impl FaultPlan {
    fn seeded(seed: u64) -> FaultPlan {
        let mut rng = Rng::new(seed);
        // kill strictly mid-stream: both sides of the failover must carry
        // real work or the bit-identity check proves nothing
        let kill_at = 4 + rng.next_u64() % 9;
        let after_promotion = 4 + rng.next_u64() % 9;
        FaultPlan { seed, kill_at, after_promotion }
    }

    /// Total learns the schedule acknowledges across both generations.
    fn total(&self) -> u64 {
        self.kill_at + self.after_promotion
    }

    /// The i-th learn of the schedule (0-based): class + features, derived
    /// from the plan seed alone so the never-failed reference run replays
    /// byte-identical samples without sharing any state with the chaos run.
    fn learn(&self, cfg: &HdConfig, i: u64) -> (usize, Vec<f32>) {
        let mut rng = Rng::new(self.seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(i + 1));
        let class = (rng.next_u64() % cfg.classes as u64) as usize;
        let x = (0..cfg.features()).map(|_| rng.normal_f32() * 40.0).collect();
        (class, x)
    }
}

/// Snapshot a server's default model to `name` and return the CLOK bytes.
fn clok_bytes(addr: &str, name: &str) -> Vec<u8> {
    let path = tmp(name);
    let _ = std::fs::remove_file(&path);
    let mut c = Client::connect(addr).unwrap();
    c.snapshot(Some(path.to_str().unwrap())).unwrap();
    std::fs::read(&path).unwrap()
}

/// The tentpole drill: kill the primary at the plan's learn-seq point,
/// promote the follower, keep learning through the new primary, let the
/// stale old primary come back from its own WAL and get fenced — then
/// prove the surviving store is bit-identical to a never-failed reference
/// replaying the same plan.
#[test]
fn chaos_kill_primary_promote_follower_and_fence_the_stale_one_bit_identically() {
    let cfg = cfg4();
    let plan = FaultPlan::seeded(0xC7A0_5EED);
    let wal_a = tmp("chaos_a.clow");
    let wal_b = tmp("chaos_b.clow");
    for f in [&wal_a, &wal_b] {
        let _ = std::fs::remove_file(f);
    }

    // generation 0: primary A logs to its WAL; follower B is a full
    // server with its own WAL, tailing A
    let a = start_server(&cfg, Some(&wal_a));
    let a_addr = a.local_addr().to_string();
    let mut bopts = CoordinatorOptions::software(cfg.clone());
    bopts.wal_path = Some(wal_b.clone());
    let registry = Registry::single("t", Coordinator::start(bopts).unwrap());
    let b_local = registry.get("t").unwrap();
    let b_sopts = ServeOptions { allow_snapshot_paths: true, ..ServeOptions::default() };
    let b = Server::start("127.0.0.1:0", registry, b_sopts).unwrap();
    let b_addr = b.local_addr().to_string();
    let replica = Replica::start(b_local.clone(), ReplicaOptions::new(a_addr.clone())).unwrap();

    let mut c = Client::connect(&a_addr).unwrap();
    for i in 0..plan.kill_at {
        let (class, x) = plan.learn(&cfg, i);
        c.learn(&x, class).unwrap();
    }
    assert!(
        wait_until(|| replica.status().applied_seq == plan.kill_at, 5000),
        "follower never converged before the kill point: {:?}",
        replica.status()
    );

    // the plan's kill point: the primary is gone for good
    drop(c);
    a.stop();

    // promotion: tailing quiesces, the inherited log position seals, and
    // the follower steps into epoch 1
    let (epoch, sealed) = replica.promote().unwrap();
    assert_eq!(epoch, 1, "first promotion over an epoch-0 lineage");
    assert_eq!(sealed, plan.kill_at, "the WAL seals at the applied sequence");

    // generation 1: the promoted model accepts learns over its own socket
    let mut cb = Client::connect(&b_addr).unwrap();
    for i in plan.kill_at..plan.total() {
        let (class, x) = plan.learn(&cfg, i);
        cb.learn(&x, class).unwrap();
    }
    let st = cb.stats().unwrap();
    assert_eq!(st.learn_seq, plan.total(), "no acknowledged learn was lost");
    assert_eq!(st.epoch, 1, "the promotion epoch travels in stats replies");
    drop(cb);

    // the stale old primary reappears from its own WAL: same knowledge it
    // died with, still epoch 0
    let a2 = start_server(&cfg, Some(&wal_a));
    let a2_addr = a2.local_addr().to_string();
    {
        let mut ca = Client::connect(&a2_addr).unwrap();
        let sa = ca.stats().unwrap();
        assert_eq!(sa.learn_seq, plan.kill_at);
        assert_eq!(sa.epoch, 0, "the old primary recovered its stale epoch");
    }

    // divergence refusal: a tailer pointed at the stale primary fences it
    // instead of applying its records over the promoted lineage
    let fencer = Replica::start(b_local.clone(), ReplicaOptions::new(a2_addr.clone())).unwrap();
    assert!(
        wait_until(|| fencer.status().fenced >= 1, 5000),
        "the stale primary was never fenced: {:?}",
        fencer.status()
    );
    assert_eq!(
        fencer.status().applied_seq,
        plan.total(),
        "no stale record may land on the promoted model"
    );
    fencer.stop();
    a2.stop();

    // bit-identity: the surviving store equals a never-failed reference
    // that replayed the plan's full schedule on a single server
    let survived = clok_bytes(&b_addr, "chaos_b.clok");
    b.stop();
    let reference = start_server(&cfg, None);
    let ref_addr = reference.local_addr().to_string();
    let mut cr = Client::connect(&ref_addr).unwrap();
    for i in 0..plan.total() {
        let (class, x) = plan.learn(&cfg, i);
        cr.learn(&x, class).unwrap();
    }
    drop(cr);
    let wanted = clok_bytes(&ref_addr, "chaos_ref.clok");
    reference.stop();
    assert_eq!(
        survived, wanted,
        "failover must be invisible in the knowledge bytes: the promoted \
         store and the never-failed reference diverged"
    );
}

/// The same drill under a second seed: a different kill point and
/// post-promotion load, pinning that the failover invariants are not an
/// artifact of one schedule.
#[test]
fn chaos_second_seed_replays_a_different_schedule_with_the_same_invariants() {
    let cfg = cfg4();
    let plan_a = FaultPlan::seeded(0xC7A0_5EED);
    let plan = FaultPlan::seeded(0xBAD5_EED2);
    assert!(
        plan.kill_at != plan_a.kill_at || plan.after_promotion != plan_a.after_promotion,
        "distinct seeds should yield distinct schedules"
    );

    let wal_a = tmp("chaos2_a.clow");
    let _ = std::fs::remove_file(&wal_a);
    let a = start_server(&cfg, Some(&wal_a));
    let a_addr = a.local_addr().to_string();
    // this follower keeps no WAL: promotion must still fence for the
    // process lifetime (the epoch is tracked in memory)
    let registry =
        Registry::single("t", Coordinator::start(CoordinatorOptions::software(cfg.clone())).unwrap());
    let b_local = registry.get("t").unwrap();
    let b_sopts = ServeOptions { allow_snapshot_paths: true, ..ServeOptions::default() };
    let b = Server::start("127.0.0.1:0", registry, b_sopts).unwrap();
    let b_addr = b.local_addr().to_string();
    let replica = Replica::start(b_local.clone(), ReplicaOptions::new(a_addr.clone())).unwrap();

    let mut c = Client::connect(&a_addr).unwrap();
    for i in 0..plan.kill_at {
        let (class, x) = plan.learn(&cfg, i);
        c.learn(&x, class).unwrap();
    }
    assert!(
        wait_until(|| replica.status().applied_seq == plan.kill_at, 5000),
        "follower never converged: {:?}",
        replica.status()
    );
    drop(c);
    a.stop();

    let (epoch, sealed) = replica.promote().unwrap();
    assert_eq!((epoch, sealed), (1, plan.kill_at));

    let mut cb = Client::connect(&b_addr).unwrap();
    for i in plan.kill_at..plan.total() {
        let (class, x) = plan.learn(&cfg, i);
        cb.learn(&x, class).unwrap();
    }
    let st = cb.stats().unwrap();
    assert_eq!((st.learn_seq, st.epoch), (plan.total(), 1));
    drop(cb);

    let survived = clok_bytes(&b_addr, "chaos2_b.clok");
    b.stop();
    let reference = start_server(&cfg, None);
    let ref_addr = reference.local_addr().to_string();
    let mut cr = Client::connect(&ref_addr).unwrap();
    for i in 0..plan.total() {
        let (class, x) = plan.learn(&cfg, i);
        cr.learn(&x, class).unwrap();
    }
    drop(cr);
    let wanted = clok_bytes(&ref_addr, "chaos2_ref.clok");
    reference.stop();
    assert_eq!(survived, wanted);
}

/// Runtime registry mutation under load: `OP_MODEL_ADD` boots a model
/// while learn traffic runs against the default, learns land on the new
/// model, a `ModelSync` follower converges its model *set* (and the new
/// model's knowledge), and `OP_MODEL_REMOVE` tears it down everywhere —
/// all without a single wire error on the surviving models.
#[test]
fn model_add_and_remove_under_load_converge_on_the_follower() {
    use clo_hdnn::serve::{ModelSpec, ModelSync, ModelSyncOptions};

    let cfg = cfg4();
    let ps = protos(&cfg, 91);
    let dir = tmp("mutate");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    // primary: a template-keeping registry (Registry::start), so runtime
    // adds can clone the default model's configuration; WAL paths derive
    // per model, so the added model is tailable
    let mut popts = CoordinatorOptions::software(cfg.clone());
    popts.wal_path = Some(dir.join("p.clog"));
    let registry = Registry::start(vec![ModelSpec::new("m", popts)]).unwrap();
    let primary = Server::start("127.0.0.1:0", registry, ServeOptions::default()).unwrap();
    let p_addr = primary.local_addr().to_string();

    // follower: its own registry + server, with ModelSync converging the
    // model set and per-model tailers converging knowledge
    let fregistry = std::sync::Arc::new(
        Registry::start(vec![ModelSpec::new(
            "m",
            CoordinatorOptions::software(cfg.clone()),
        )])
        .unwrap(),
    );
    let mut sopts = ModelSyncOptions::new(p_addr.clone());
    sopts.poll_interval = Duration::from_millis(25);
    sopts.replica.poll_interval = Duration::from_millis(5);
    let sync = ModelSync::start(fregistry.clone(), sopts);

    // load phase 1: learns against the default model
    let mut c = Client::connect_v2(&p_addr).unwrap();
    for (cls, p) in ps.iter().enumerate() {
        c.learn(p, cls).unwrap();
    }

    // mutate under that load: boot "x" from the default's template
    let models = c.model_add("x", "").unwrap();
    assert_eq!(models, ["m".to_string(), "x".to_string()]);
    // load phase 2: interleave learns against both models
    c.set_model("x").unwrap();
    for (cls, p) in ps.iter().enumerate() {
        c.learn(p, cls).unwrap();
    }
    c.set_model("").unwrap();
    for (cls, p) in ps.iter().enumerate() {
        c.learn(p, cls).unwrap();
    }

    // the follower observes the addition and converges both stores
    assert!(
        wait_until(|| fregistry.names().contains(&"x".to_string()), 5000),
        "follower never added model 'x' (sync counters {:?})",
        sync.counters()
    );
    let fx = || -> u64 {
        fregistry
            .get("x")
            .ok()
            .and_then(|co| co.call(clo_hdnn::coordinator::Payload::Stats).ok())
            .and_then(|r| r.stats)
            .map(|s| s.learn_seq)
            .unwrap_or(0)
    };
    assert!(
        wait_until(|| fx() == ps.len() as u64, 5000),
        "follower's 'x' never converged (at {})",
        fx()
    );

    // remove "x" (its executor flushes before the ack); the default model
    // keeps serving untouched
    let models = c.model_remove("x").unwrap();
    assert_eq!(models, ["m".to_string()]);
    c.set_model("x").unwrap();
    assert!(c.learn(&ps[0], 0).is_err(), "removed model must refuse traffic");
    c.set_model("").unwrap();
    for (cls, p) in ps.iter().enumerate() {
        assert_eq!(c.infer(p).unwrap().class, cls);
    }
    assert!(
        wait_until(|| !fregistry.names().contains(&"x".to_string()), 5000),
        "follower never removed model 'x'"
    );
    let st = c.stats().unwrap();
    assert_eq!(st.learn_seq, 2 * ps.len() as u64, "the default model's log is untouched");

    drop(c);
    sync.stop();
    primary.stop();
}

/// `Replica::status().connected` must flap false→true across a primary
/// outage (capped-backoff reconnect), with `reconnects` counting the
/// failed attempts — the signal `serve --promote-on down:<ms>` keys on.
#[test]
fn replica_status_connected_flaps_and_reconnects_count_across_an_outage() {
    let cfg = cfg4();
    let ps = protos(&cfg, 91);
    let wal = tmp("flap.clow");
    let _ = std::fs::remove_file(&wal);

    let first = start_server(&cfg, Some(&wal));
    let addr = first.local_addr().to_string();
    let mut c = Client::connect(&addr).unwrap();
    for (cls, p) in ps.iter().enumerate() {
        c.learn(p, cls).unwrap();
    }

    let follower = Coordinator::start(CoordinatorOptions::software(cfg.clone())).unwrap();
    let registry = Registry::single("t", follower);
    let local = registry.get("t").unwrap();
    let mut ropts = ReplicaOptions::new(addr.clone());
    ropts.poll_interval = Duration::from_millis(5);
    ropts.reconnect_base = Duration::from_millis(20);
    ropts.reconnect_max = Duration::from_millis(100);
    let replica = Replica::start(local, ropts).unwrap();
    assert!(
        wait_until(|| replica.status().connected, 5000),
        "never connected: {:?}",
        replica.status()
    );
    assert!(
        wait_until(|| replica.status().applied_seq == ps.len() as u64, 5000),
        "never converged: {:?}",
        replica.status()
    );

    // outage: connected must drop and reconnect attempts must accrue
    drop(c);
    first.stop();
    assert!(
        wait_until(|| !replica.status().connected, 5000),
        "outage not observed: {:?}",
        replica.status()
    );
    assert!(
        wait_until(|| replica.status().reconnects >= 2, 5000),
        "backoff retries not counted: {:?}",
        replica.status()
    );

    // recovery on the same address — the restarted primary replays its
    // WAL, so the returning tailer finds the same log and just idles:
    // connected must rise again without losing the applied sequence
    let second = match Server::start(&addr, Registry::single("t", {
        let mut opts = CoordinatorOptions::software(cfg.clone());
        opts.wal_path = Some(wal.clone());
        Coordinator::start(opts).unwrap()
    }), ServeOptions::default())
    {
        Ok(s) => s,
        // the freed port was taken in the interim: extremely rare, and
        // the flap-down half of the test already passed
        Err(_) => {
            replica.stop();
            return;
        }
    };
    assert!(
        wait_until(|| replica.status().connected, 10_000),
        "never re-connected: {:?}",
        replica.status()
    );
    assert_eq!(replica.status().applied_seq, ps.len() as u64);
    replica.stop();
    second.stop();
}

/// `Replica::status().bootstraps` must increment when the tailer returns
/// after the primary compacted past its position: the gap is answered by
/// a snapshot-image re-bootstrap, not silent divergence.
#[test]
fn replica_bootstraps_increment_on_a_compaction_gap_rebootstrap() {
    let cfg = cfg4();
    let ps = protos(&cfg, 91);
    let dir = tmp("gap");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let mut popts = CoordinatorOptions::software(cfg.clone());
    popts.wal_path = Some(dir.join("p.clog"));
    popts.snapshot_path = Some(dir.join("p.clok"));
    let registry = Registry::start(vec![clo_hdnn::serve::ModelSpec::new("m", popts)]).unwrap();
    let server = Server::start("127.0.0.1:0", registry, ServeOptions::default()).unwrap();
    let addr = server.local_addr().to_string();

    let mut c = Client::connect(&addr).unwrap();
    for (cls, p) in ps.iter().enumerate() {
        c.learn(p, cls).unwrap();
    }

    // first life: converge from the live log — zero bootstraps, since the
    // log still reaches back to sequence 0
    let follower = Coordinator::start(CoordinatorOptions::software(cfg.clone())).unwrap();
    let local = std::sync::Arc::new(follower);
    let mut ropts = ReplicaOptions::new(addr.clone());
    ropts.poll_interval = Duration::from_millis(5);
    let replica = Replica::start(local.clone(), ropts.clone()).unwrap();
    assert!(
        wait_until(|| replica.status().applied_seq == ps.len() as u64, 5000),
        "never converged: {:?}",
        replica.status()
    );
    assert_eq!(replica.status().bootstraps, 0, "{:?}", replica.status());
    replica.stop();

    // while the tailer is offline, the primary learns on and compacts:
    // the snapshot rotates the log past the follower's position
    for (cls, p) in ps.iter().enumerate() {
        c.learn(p, cls).unwrap();
    }
    c.snapshot(None).unwrap();

    // second life, same local store: the tail hits the compaction refusal
    // and re-bootstraps from the primary's image
    let replica = Replica::start(local.clone(), ropts).unwrap();
    assert!(
        wait_until(|| replica.status().applied_seq == 2 * ps.len() as u64, 5000),
        "never re-converged: {:?}",
        replica.status()
    );
    assert_eq!(replica.status().bootstraps, 1, "{:?}", replica.status());
    for (cls, p) in ps.iter().enumerate() {
        let r = local.call(clo_hdnn::coordinator::Payload::Features(p.clone())).unwrap();
        assert_eq!(r.class, Some(cls));
    }
    replica.stop();
    drop(c);
    server.stop();
}
