//! End-to-end tests for the TCP serving layer and the durable knowledge
//! store: live learn/infer/snapshot/stats over a loopback socket,
//! malformed-frame fuzzing against the wire contract, concurrent-client
//! multiplexing, wire-v2 pipelining across a two-model registry (replies
//! matched by client-assigned id, cross-model isolation under garbled
//! frames), v1 back-compat, and the warm-restart invariant (learn ->
//! snapshot -> restart -> bit-identical predictions in both search modes).
//!
//! The fault-injection half of the suite pins the reactor's survival
//! contract: a byte-dribbling slowloris peer is served without starving
//! anyone, silent connections are reaped at the idle timeout, a peer that
//! stops reading its replies is shed without an executor ever blocking,
//! and the per-connection pipeline window holds under a 3x overload blast
//! (observable through the reactor-answered ConnStats opcode).

use clo_hdnn::config::HdConfig;
use clo_hdnn::coordinator::{Coordinator, CoordinatorOptions};
use clo_hdnn::hdc::{knowledge, SearchMode};
use clo_hdnn::serve::{wire, Client, ModelSpec, Registry, ReqBody, ServeOptions, Server};
use clo_hdnn::util::Rng;
use std::io::{Read, Write};
use std::net::TcpStream;

fn cfg4() -> HdConfig {
    HdConfig::synthetic("t", 8, 8, 32, 32, 8, 4)
}

fn protos(cfg: &HdConfig, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..cfg.classes)
        .map(|_| (0..cfg.features()).map(|_| rng.normal_f32() * 40.0).collect())
        .collect()
}

fn start_server(opts: CoordinatorOptions) -> Server {
    let coord = Coordinator::start(opts).unwrap();
    // tests exercise explicit snapshot paths over the wire, which the
    // default (hardened) options refuse — opt in here
    let serve_opts = ServeOptions { allow_snapshot_paths: true, ..ServeOptions::default() };
    Server::start("127.0.0.1:0", Registry::single("t", coord), serve_opts).unwrap()
}

/// Two models with *different* feature widths behind one server — a frame
/// routed to the wrong model cannot silently succeed.
fn start_two_model_server() -> (Server, HdConfig, HdConfig) {
    let cfg_a = HdConfig::synthetic("a", 8, 8, 32, 32, 8, 4); // F=64
    let cfg_b = HdConfig::synthetic("b", 4, 4, 32, 32, 8, 3); // F=16
    let registry = Registry::start(vec![
        ModelSpec::new("alpha", CoordinatorOptions::software(cfg_a.clone())),
        ModelSpec::new("beta", CoordinatorOptions::software(cfg_b.clone())),
    ])
    .unwrap();
    let serve_opts = ServeOptions { allow_snapshot_paths: true, ..ServeOptions::default() };
    let server = Server::start("127.0.0.1:0", registry, serve_opts).unwrap();
    (server, cfg_a, cfg_b)
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("clo_hdnn_serve_tcp");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn learn_infer_stats_snapshot_over_the_wire() {
    let cfg = cfg4();
    let server = start_server(CoordinatorOptions::software(cfg.clone()));
    let addr = server.local_addr().to_string();
    let ps = protos(&cfg, 91);

    let mut client = Client::connect(&addr).unwrap();
    for (c, p) in ps.iter().enumerate() {
        for _ in 0..3 {
            client.learn(p, c).unwrap();
        }
    }
    for (c, p) in ps.iter().enumerate() {
        let r = client.infer(p).unwrap();
        assert_eq!(r.class, c, "served inference must recover class {c}");
        // both explicit kernels agree over the wire
        let l1 = client.infer_mode(p, Some(SearchMode::L1Int8)).unwrap();
        let packed = client.infer_mode(p, Some(SearchMode::HammingPacked)).unwrap();
        assert_eq!(l1.class, c);
        assert_eq!(packed.class, c);
    }
    let stats = client.stats().unwrap();
    assert_eq!(stats.learns, 12);
    assert_eq!(stats.trained_classes, 4);
    assert_eq!(stats.wire_errors, 0);
    assert!(stats.served >= 12 + 12 + 1);

    // snapshot over the wire, then verify the file is a valid checkpoint
    let snap = tmp("wire_snapshot.clok");
    let _ = std::fs::remove_file(&snap);
    let written = client.snapshot(Some(snap.to_str().unwrap())).unwrap();
    assert_eq!(written, snap.display().to_string());
    let store = knowledge::load(&snap).unwrap();
    assert_eq!(store.total_learns(), 12);
    assert_eq!(store.trained_classes(), 4);

    drop(client);
    server.stop();
}

#[test]
fn concurrent_clients_multiplex_with_zero_errors() {
    let cfg = cfg4();
    let server = start_server(CoordinatorOptions::software(cfg.clone()));
    let addr = server.local_addr().to_string();
    let ps = protos(&cfg, 92);

    // seed the store so inferences have something to hit
    let mut seeder = Client::connect(&addr).unwrap();
    for (c, p) in ps.iter().enumerate() {
        seeder.learn(p, c).unwrap();
    }

    let n_clients = 6usize;
    let per_client = 25usize;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..n_clients)
            .map(|t| {
                let addr = addr.clone();
                let ps = &ps;
                s.spawn(move || {
                    let mut client = Client::connect(&addr).unwrap();
                    let mut rng = Rng::new(0xC11E + t as u64);
                    for i in 0..per_client {
                        let c = (t + i) % ps.len();
                        if rng.below(4) == 0 {
                            client.learn(&ps[c], c).unwrap();
                        } else {
                            let r = client.infer(&ps[c]).unwrap();
                            assert_eq!(r.class, c, "client {t} request {i}");
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });

    let stats = seeder.stats().unwrap();
    assert_eq!(stats.wire_errors, 0, "concurrent traffic must stay clean");
    assert!(stats.served as usize >= n_clients * per_client);
    drop(seeder);
    server.stop();
}

#[test]
fn malformed_frames_get_error_replies_and_framing_survives() {
    let cfg = cfg4();
    let server = start_server(CoordinatorOptions::software(cfg.clone()));
    let addr = server.local_addr().to_string();
    let ps = protos(&cfg, 93);
    let mut seeder = Client::connect(&addr).unwrap();
    for (c, p) in ps.iter().enumerate() {
        seeder.learn(p, c).unwrap();
    }

    // 1) garbage opcode in a well-framed payload -> error reply carrying
    //    the request id, and the SAME connection keeps serving
    let mut raw = TcpStream::connect(&addr).unwrap();
    let mut bad = Vec::new();
    bad.extend_from_slice(&42u64.to_le_bytes());
    bad.push(0x77); // no such opcode
    wire::write_frame(&mut raw, &bad).unwrap();
    let mut reader = std::io::BufReader::new(raw.try_clone().unwrap());
    match wire::read_frame(&mut reader, wire::MAX_FRAME).unwrap() {
        wire::Frame::Payload(p) => match wire::WireResponse::decode(&p).unwrap() {
            wire::WireResponse::Error { id, msg } => {
                assert_eq!(id, 42);
                assert!(msg.contains("opcode"), "{msg}");
            }
            other => panic!("expected error reply, got {other:?}"),
        },
        other => panic!("{other:?}"),
    }
    // the connection survives: a valid infer on the same socket works
    let good = wire::WireRequest::new(
        43,
        ReqBody::Infer { mode: wire::MODE_DEFAULT, features: ps[0].clone() },
    );
    wire::write_frame(&mut raw, &good.encode(wire::WIRE_V1).unwrap()).unwrap();
    match wire::read_frame(&mut reader, wire::MAX_FRAME).unwrap() {
        wire::Frame::Payload(p) => match wire::WireResponse::decode(&p).unwrap() {
            wire::WireResponse::Infer { id, class, .. } => {
                assert_eq!(id, 43);
                assert_eq!(class, 0);
            }
            other => panic!("expected infer reply, got {other:?}"),
        },
        other => panic!("{other:?}"),
    }

    // 2) truncated body (id only, op missing) -> error reply, connection
    //    still in sync
    let mut short = Vec::new();
    short.extend_from_slice(&44u64.to_le_bytes());
    wire::write_frame(&mut raw, &short).unwrap();
    match wire::read_frame(&mut reader, wire::MAX_FRAME).unwrap() {
        wire::Frame::Payload(p) => match wire::WireResponse::decode(&p).unwrap() {
            wire::WireResponse::Error { id, .. } => assert_eq!(id, 44),
            other => panic!("{other:?}"),
        },
        other => panic!("{other:?}"),
    }
    drop(reader);
    drop(raw);

    // 3) oversized length header -> best-effort error frame, then close
    let mut raw = TcpStream::connect(&addr).unwrap();
    raw.write_all(&(u32::MAX).to_le_bytes()).unwrap();
    raw.flush().unwrap();
    let mut reader = std::io::BufReader::new(raw.try_clone().unwrap());
    match wire::read_frame(&mut reader, wire::MAX_FRAME).unwrap() {
        wire::Frame::Payload(p) => match wire::WireResponse::decode(&p).unwrap() {
            wire::WireResponse::Error { msg, .. } => {
                assert!(msg.contains("exceeds"), "{msg}")
            }
            other => panic!("{other:?}"),
        },
        other => panic!("{other:?}"),
    }
    // ... followed by EOF: the stream cannot be resynchronized
    let mut rest = Vec::new();
    reader.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty());
    drop(reader);
    drop(raw);

    // 4) truncated header then disconnect: server must simply survive
    let mut raw = TcpStream::connect(&addr).unwrap();
    raw.write_all(&[9u8, 0]).unwrap();
    drop(raw);

    // server is alive and healthy after all of the above
    let stats = seeder.stats().unwrap();
    assert!(stats.wire_errors >= 3);
    let r = seeder.infer(&ps[1]).unwrap();
    assert_eq!(r.class, 1);
    drop(seeder);
    server.stop();
}

#[test]
fn warm_restart_over_the_wire_is_bit_identical() {
    let cfg = cfg4();
    let snap = tmp("warm_restart.clok");
    let _ = std::fs::remove_file(&snap);
    let ps = protos(&cfg, 94);
    let mut rng = Rng::new(95);
    // a noisy synthetic CL stream: 5 draws per class
    let stream: Vec<(Vec<f32>, usize)> = (0..5)
        .flat_map(|_| {
            ps.iter()
                .enumerate()
                .map(|(c, p)| {
                    (
                        p.iter().map(|&v| v + rng.normal_f32() * 4.0).collect::<Vec<f32>>(),
                        c,
                    )
                })
                .collect::<Vec<_>>()
        })
        .collect();
    let queries: Vec<Vec<f32>> = (0..20)
        .map(|i| {
            let p = &ps[i % ps.len()];
            p.iter().map(|&v| v + rng.normal_f32() * 8.0).collect()
        })
        .collect();

    // phase 1: learn the stream over the wire, snapshot, record predictions
    let server = start_server(CoordinatorOptions::software(cfg.clone()));
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    for (x, c) in &stream {
        client.learn(x, *c).unwrap();
    }
    client.snapshot(Some(snap.to_str().unwrap())).unwrap();
    let mut before = Vec::new();
    for q in &queries {
        for mode in [SearchMode::L1Int8, SearchMode::HammingPacked] {
            before.push(client.infer_mode(q, Some(mode)).unwrap());
        }
    }
    drop(client);
    server.stop(); // the first process dies

    // phase 2: a fresh server warm-starts from the checkpoint
    let mut opts = CoordinatorOptions::software(cfg.clone());
    opts.restore_path = Some(snap.clone());
    let server = start_server(opts);
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    let mut after = Vec::new();
    for q in &queries {
        for mode in [SearchMode::L1Int8, SearchMode::HammingPacked] {
            after.push(client.infer_mode(q, Some(mode)).unwrap());
        }
    }
    assert_eq!(
        before, after,
        "every prediction (class, segments, early-exit) must be bit-identical \
         across the restart, in both search modes"
    );

    // and the restored store itself equals the checkpoint bit for bit
    let restored = knowledge::load(&snap).unwrap();
    assert_eq!(restored.total_learns(), stream.len() as u64);
    let stats = client.stats().unwrap();
    assert_eq!(stats.learns, stream.len() as u64);
    assert_eq!(stats.wire_errors, 0);
    drop(client);
    server.stop();
}

#[test]
fn remote_snapshot_paths_are_refused_by_default() {
    // hardened default: an unauthenticated client must not get a
    // write-file-anywhere primitive; only the server's configured default
    // checkpoint is reachable over the wire
    let cfg = cfg4();
    let snap = tmp("default_only.clok");
    let _ = std::fs::remove_file(&snap);
    let mut opts = CoordinatorOptions::software(cfg.clone());
    opts.snapshot_path = Some(snap.clone());
    let coord = Coordinator::start(opts).unwrap();
    let server =
        Server::start("127.0.0.1:0", Registry::single("t", coord), ServeOptions::default())
            .unwrap();
    let addr = server.local_addr().to_string();
    let ps = protos(&cfg, 97);

    let mut client = Client::connect(&addr).unwrap();
    client.learn(&ps[0], 0).unwrap();
    let evil = tmp("evil_target.clok");
    let err = client.snapshot(Some(evil.to_str().unwrap())).unwrap_err();
    assert!(err.to_string().contains("disabled"), "{err}");
    assert!(!evil.exists(), "refused snapshot must not touch the path");
    // the connection survives the refusal, and the default path still works
    let written = client.snapshot(None).unwrap();
    assert_eq!(written, snap.display().to_string());
    assert!(snap.exists());
    drop(client);
    server.stop();
}

#[test]
fn server_default_snapshot_path_and_auto_cadence_work_over_tcp() {
    let cfg = cfg4();
    let snap = tmp("auto_cadence.clok");
    let _ = std::fs::remove_file(&snap);
    let mut opts = CoordinatorOptions::software(cfg.clone());
    opts.snapshot_path = Some(snap.clone());
    opts.snapshot_every = 4;
    let server = start_server(opts);
    let addr = server.local_addr().to_string();
    let ps = protos(&cfg, 96);

    let mut client = Client::connect(&addr).unwrap();
    for (c, p) in ps.iter().enumerate() {
        client.learn(p, c).unwrap();
    }
    // 4 learns -> the cadence fired; the default-path snapshot exists
    let stats = client.stats().unwrap();
    assert_eq!(stats.snapshots, 1);
    assert!(snap.exists());
    // empty path on the wire = "use the server default"
    let written = client.snapshot(None).unwrap();
    assert_eq!(written, snap.display().to_string());
    assert_eq!(client.stats().unwrap().snapshots, 2);
    drop(client);
    server.stop();
    // shutdown flush appended nothing new (no learns since), file loads
    assert_eq!(knowledge::load(&snap).unwrap().total_learns(), 4);
}

#[test]
fn hello_negotiates_v2_and_lists_models() {
    let (server, _, _) = start_two_model_server();
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    assert_eq!(client.version(), wire::WIRE_V1);
    let (version, default_model, models) = client.hello().unwrap();
    assert_eq!(version, wire::WIRE_V2);
    assert_eq!(client.version(), wire::WIRE_V2);
    assert_eq!(default_model, "alpha");
    assert_eq!(models, ["alpha".to_string(), "beta".to_string()]);
    // connect_v2 is the one-call form of the same negotiation
    let client2 = Client::connect_v2(&addr).unwrap();
    assert_eq!(client2.version(), wire::WIRE_V2);
    drop(client);
    drop(client2);
    server.stop();
}

#[test]
fn model_targeting_without_hello_is_refused_client_side() {
    let (server, cfg_a, _) = start_two_model_server();
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    let e = client.set_model("beta").unwrap_err().to_string();
    assert!(e.contains("hello"), "{e}");
    let e = client
        .send_for("beta", ReqBody::Stats)
        .unwrap_err()
        .to_string();
    assert!(e.contains("wire v2"), "{e}");
    // the default model still works on the un-upgraded connection
    let ps = protos(&cfg_a, 90);
    client.learn(&ps[0], 0).unwrap();
    drop(client);
    server.stop();
}

#[test]
fn v1_client_round_trips_against_the_default_model_unchanged() {
    // a never-upgraded client against a multi-model server behaves exactly
    // like the single-model protocol: every frame lands on the default
    let (server, cfg_a, _) = start_two_model_server();
    let addr = server.local_addr().to_string();
    let ps = protos(&cfg_a, 91);
    let mut client = Client::connect(&addr).unwrap();
    for (c, p) in ps.iter().enumerate() {
        for _ in 0..3 {
            client.learn(p, c).unwrap();
        }
    }
    for (c, p) in ps.iter().enumerate() {
        assert_eq!(client.infer(p).unwrap().class, c);
    }
    let stats = client.stats().unwrap();
    assert_eq!(stats.learns, 12, "v1 stats report the default model");
    assert_eq!(stats.trained_classes, 4);
    assert_eq!(stats.wire_errors, 0);
    drop(client);
    server.stop();
}

#[test]
fn pipelined_mixed_traffic_across_two_models_matches_ids() {
    let (server, cfg_a, cfg_b) = start_two_model_server();
    let addr = server.local_addr().to_string();
    let ps_a = protos(&cfg_a, 92);
    let ps_b = protos(&cfg_b, 93);

    // seed both models
    let mut seeder = Client::connect_v2(&addr).unwrap();
    for (c, p) in ps_a.iter().enumerate() {
        seeder.set_model("alpha").unwrap();
        for _ in 0..3 {
            seeder.learn(p, c).unwrap();
        }
    }
    for (c, p) in ps_b.iter().enumerate() {
        seeder.set_model("beta").unwrap();
        for _ in 0..3 {
            seeder.learn(p, c).unwrap();
        }
    }

    // one connection, K = 8 mixed Infer/Learn frames across both models,
    // all written before ANY reply is read
    let mut client = Client::connect_v2(&addr).unwrap();
    // (id, model, expected class for infers / None for learns)
    let mut expected: std::collections::HashMap<u64, (&str, Option<usize>)> =
        std::collections::HashMap::new();
    for round in 0..2 {
        let id = client
            .send_for("alpha", ReqBody::Infer { mode: 0, features: ps_a[round].clone() })
            .unwrap();
        expected.insert(id, ("alpha", Some(round)));
        let id = client
            .send_for("beta", ReqBody::Infer { mode: 0, features: ps_b[round].clone() })
            .unwrap();
        expected.insert(id, ("beta", Some(round)));
        let id = client
            .send_for(
                "alpha",
                ReqBody::Learn { class: round as u32, features: ps_a[round].clone() },
            )
            .unwrap();
        expected.insert(id, ("alpha", None));
        let id = client
            .send_for(
                "beta",
                ReqBody::Learn { class: round as u32, features: ps_b[round].clone() },
            )
            .unwrap();
        expected.insert(id, ("beta", None));
    }
    assert_eq!(expected.len(), 8, "8 frames in flight");
    for _ in 0..8 {
        let resp = client.recv().unwrap();
        let (model, expect) = expected
            .remove(&resp.id())
            .unwrap_or_else(|| panic!("unmatched reply id {}", resp.id()));
        match (resp, expect) {
            (wire::WireResponse::Infer { class, .. }, Some(want)) => {
                assert_eq!(class as usize, want, "model {model}");
            }
            (wire::WireResponse::Learn { class, .. }, None) => {
                assert!((class as usize) < 4, "model {model}");
            }
            (other, _) => panic!("model {model}: unexpected reply {other:?}"),
        }
    }
    assert!(expected.is_empty(), "every in-flight frame got exactly one reply");

    // the pipelined learns landed in the right stores: per-model counts
    client.set_model("alpha").unwrap();
    assert_eq!(client.stats().unwrap().learns, 3 * 4 + 2);
    client.set_model("beta").unwrap();
    assert_eq!(client.stats().unwrap().learns, 3 * 3 + 2);
    drop(seeder);
    drop(client);
    server.stop();
}

#[test]
fn error_replies_echo_request_ids_under_pipelining() {
    let (server, cfg_a, _) = start_two_model_server();
    let addr = server.local_addr().to_string();
    let mut client = Client::connect_v2(&addr).unwrap();
    let ps = protos(&cfg_a, 94);
    // three failures in flight at once: class out of range, wrong feature
    // width, unknown model — each error must name its request
    let id_class = client
        .send_for("alpha", ReqBody::Learn { class: 99, features: ps[0].clone() })
        .unwrap();
    let id_width = client
        .send_for("alpha", ReqBody::Infer { mode: 0, features: vec![0.0; 3] })
        .unwrap();
    let id_model = client.send_for("gamma", ReqBody::Stats).unwrap();
    let id_good = client
        .send_for("alpha", ReqBody::Learn { class: 0, features: ps[0].clone() })
        .unwrap();
    let mut seen = std::collections::HashMap::new();
    for _ in 0..4 {
        let resp = client.recv().unwrap();
        seen.insert(resp.id(), resp);
    }
    for (id, needle) in [(id_class, "class"), (id_width, "len"), (id_model, "gamma")] {
        match &seen[&id] {
            wire::WireResponse::Error { id: eid, msg } => {
                assert_eq!(*eid, id);
                assert!(msg.contains(needle), "id {id}: {msg}");
            }
            other => panic!("expected error for id {id}, got {other:?}"),
        }
    }
    assert!(
        matches!(seen[&id_good], wire::WireResponse::Learn { .. }),
        "the valid request in the same burst still succeeds"
    );
    drop(client);
    server.stop();
}

#[test]
fn garbled_frames_on_a_pipelined_connection_leave_the_other_model_untouched() {
    let (server, cfg_a, cfg_b) = start_two_model_server();
    let addr = server.local_addr().to_string();
    let ps_a = protos(&cfg_a, 95);
    let ps_b = protos(&cfg_b, 96);
    let snap_before = tmp("isolation_before.clok");
    let snap_after = tmp("isolation_after.clok");
    let _ = std::fs::remove_file(&snap_before);
    let _ = std::fs::remove_file(&snap_after);

    // seed beta, then checkpoint it: the reference image
    let mut seeder = Client::connect_v2(&addr).unwrap();
    seeder.set_model("beta").unwrap();
    for (c, p) in ps_b.iter().enumerate() {
        seeder.learn(p, c).unwrap();
    }
    seeder.snapshot(Some(snap_before.to_str().unwrap())).unwrap();

    // a v2 connection interleaves valid alpha traffic with garbage frames
    let mut raw = TcpStream::connect(&addr).unwrap();
    let mut reader = std::io::BufReader::new(raw.try_clone().unwrap());
    let hello = wire::WireRequest::new(1, ReqBody::Hello { version: wire::WIRE_V2 });
    wire::write_frame(&mut raw, &hello.encode(wire::WIRE_V1).unwrap()).unwrap();
    match wire::read_frame(&mut reader, wire::MAX_FRAME).unwrap() {
        wire::Frame::Payload(p) => {
            assert!(matches!(
                wire::WireResponse::decode(&p).unwrap(),
                wire::WireResponse::Hello { .. }
            ));
        }
        other => panic!("{other:?}"),
    }
    // burst: valid infer(alpha), garbage opcode, truncated body, valid
    // learn(alpha) — all pipelined before reading anything back
    let infer = wire::WireRequest::for_model(
        10,
        "alpha",
        ReqBody::Infer { mode: 0, features: ps_a[1].clone() },
    );
    wire::write_frame(&mut raw, &infer.encode(wire::WIRE_V2).unwrap()).unwrap();
    let mut garbage = Vec::new();
    garbage.extend_from_slice(&11u64.to_le_bytes());
    garbage.push(0x7F); // no such opcode
    wire::write_frame(&mut raw, &garbage).unwrap();
    let mut truncated = Vec::new();
    truncated.extend_from_slice(&12u64.to_le_bytes());
    wire::write_frame(&mut raw, &truncated).unwrap();
    let learn = wire::WireRequest::for_model(
        13,
        "alpha",
        ReqBody::Learn { class: 2, features: ps_a[2].clone() },
    );
    wire::write_frame(&mut raw, &learn.encode(wire::WIRE_V2).unwrap()).unwrap();

    let mut seen = std::collections::HashMap::new();
    for _ in 0..4 {
        match wire::read_frame(&mut reader, wire::MAX_FRAME).unwrap() {
            wire::Frame::Payload(p) => {
                let resp = wire::WireResponse::decode(&p).unwrap();
                seen.insert(resp.id(), resp);
            }
            other => panic!("{other:?}"),
        }
    }
    assert!(matches!(seen[&10], wire::WireResponse::Infer { .. }));
    assert!(matches!(seen[&11], wire::WireResponse::Error { .. }));
    assert!(matches!(seen[&12], wire::WireResponse::Error { .. }));
    assert!(matches!(seen[&13], wire::WireResponse::Learn { .. }));
    drop(reader);
    drop(raw);

    // beta's knowledge is bit-identical to before the fuzzing: snapshot
    // again and compare the CLOK images byte for byte
    seeder.snapshot(Some(snap_after.to_str().unwrap())).unwrap();
    let before = std::fs::read(&snap_before).unwrap();
    let after = std::fs::read(&snap_after).unwrap();
    assert_eq!(before, after, "model beta must be untouched by the fuzzed connection");
    // while alpha DID change (the valid learn landed)
    seeder.set_model("alpha").unwrap();
    assert_eq!(seeder.stats().unwrap().learns, 1);
    drop(seeder);
    server.stop();
}

#[test]
fn conn_stats_reports_per_connection_counters() {
    let cfg = cfg4();
    let server = start_server(CoordinatorOptions::software(cfg.clone()));
    let addr = server.local_addr().to_string();
    let ps = protos(&cfg, 98);

    let mut client = Client::connect_v2(&addr).unwrap();
    for _ in 0..3 {
        client.learn(&ps[0], 0).unwrap();
    }
    client.infer(&ps[0]).unwrap();
    let st = client.conn_stats().unwrap();
    assert!(st.conn_id > 0);
    // hello + 3 learns + 1 infer + the conn-stats frame itself
    assert_eq!(st.frames, 6);
    // ... while `replies` is counted before the conn-stats reply
    assert_eq!(st.replies, 5);
    assert_eq!(st.errors, 0);
    assert_eq!(st.inflight, 0, "a synchronous client leaves nothing in flight");
    assert_eq!(st.pending, 0);
    assert!(st.peak_window >= 1);
    assert!(st.peak_window as usize <= wire::MAX_INFLIGHT);

    // a second connection has its own token and fresh counters
    let mut other = Client::connect_v2(&addr).unwrap();
    let st2 = other.conn_stats().unwrap();
    assert_ne!(st2.conn_id, st.conn_id);
    assert_eq!(st2.frames, 2, "hello + conn-stats");
    assert_eq!(st2.replies, 1);

    // error replies are attributed to the connection that earned them
    let id = client.send_for("nope", ReqBody::Stats).unwrap();
    match client.recv().unwrap() {
        wire::WireResponse::Error { id: eid, .. } => assert_eq!(eid, id),
        other => panic!("{other:?}"),
    }
    assert_eq!(client.conn_stats().unwrap().errors, 1);
    assert_eq!(other.conn_stats().unwrap().errors, 0);
    drop(client);
    drop(other);
    server.stop();
}

#[test]
fn slowloris_byte_dribble_is_served_without_starving_others() {
    let cfg = cfg4();
    let coord = Coordinator::start(CoordinatorOptions::software(cfg.clone())).unwrap();
    let serve_opts = ServeOptions {
        idle_timeout: std::time::Duration::from_millis(400),
        ..ServeOptions::default()
    };
    let server = Server::start("127.0.0.1:0", Registry::single("t", coord), serve_opts).unwrap();
    let addr = server.local_addr().to_string();
    let ps = protos(&cfg, 99);
    let mut seeder = Client::connect(&addr).unwrap();
    for (c, p) in ps.iter().enumerate() {
        seeder.learn(p, c).unwrap();
    }

    // the dribbler: one valid v1 infer frame, one byte at a time — the
    // whole frame takes ~2x the idle timeout to arrive, but no single gap
    // approaches it, so the server must keep the connection and answer
    let req = wire::WireRequest::new(
        7,
        ReqBody::Infer { mode: wire::MODE_DEFAULT, features: ps[2].clone() },
    );
    let payload = req.encode(wire::WIRE_V1).unwrap();
    let mut framed = Vec::with_capacity(4 + payload.len());
    framed.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    framed.extend_from_slice(&payload);
    let dribbler = std::thread::spawn({
        let addr = addr.clone();
        move || {
            let mut raw = TcpStream::connect(&addr).unwrap();
            raw.set_nodelay(true).unwrap();
            for &b in &framed {
                raw.write_all(&[b]).unwrap();
                raw.flush().unwrap();
                std::thread::sleep(std::time::Duration::from_millis(3));
            }
            let mut reader = std::io::BufReader::new(raw);
            match wire::read_frame(&mut reader, wire::MAX_FRAME).unwrap() {
                wire::Frame::Payload(p) => match wire::WireResponse::decode(&p).unwrap() {
                    wire::WireResponse::Infer { id, class, .. } => {
                        assert_eq!(id, 7);
                        assert_eq!(class, 2, "the dribbled frame is answered correctly");
                    }
                    other => panic!("dribbled frame must be answered: {other:?}"),
                },
                other => panic!("{other:?}"),
            }
        }
    });
    // while the dribbler crawls, a normal client is served at full speed
    for round in 0..20 {
        let c = round % ps.len();
        assert_eq!(seeder.infer(&ps[c]).unwrap().class, c);
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    dribbler.join().unwrap();
    let (_, wire_errors, _) = server.counters();
    assert_eq!(wire_errors, 0, "a slow but well-formed peer is not a protocol error");
    drop(seeder);
    server.stop();
}

#[test]
fn idle_connections_are_reaped_with_a_goodbye_error() {
    let cfg = cfg4();
    let coord = Coordinator::start(CoordinatorOptions::software(cfg.clone())).unwrap();
    let serve_opts = ServeOptions {
        idle_timeout: std::time::Duration::from_millis(300),
        ..ServeOptions::default()
    };
    let server = Server::start("127.0.0.1:0", Registry::single("t", coord), serve_opts).unwrap();
    let addr = server.local_addr().to_string();

    // a connection that never sends anything is told why, then closed
    let raw = TcpStream::connect(&addr).unwrap();
    raw.set_read_timeout(Some(std::time::Duration::from_secs(10))).unwrap();
    let mut reader = std::io::BufReader::new(raw);
    let frame = loop {
        match wire::read_frame(&mut reader, wire::MAX_FRAME).unwrap() {
            wire::Frame::Idle => continue,
            f => break f,
        }
    };
    match frame {
        wire::Frame::Payload(p) => match wire::WireResponse::decode(&p).unwrap() {
            wire::WireResponse::Error { id, msg } => {
                assert_eq!(id, 0);
                assert!(msg.contains("idle timeout"), "{msg}");
            }
            other => panic!("{other:?}"),
        },
        other => panic!("expected an idle-timeout goodbye, got {other:?}"),
    }
    // ... followed by EOF, not limbo
    let mut rest = Vec::new();
    let _ = reader.read_to_end(&mut rest);
    assert!(rest.is_empty());

    // a client that stays under the idle timeout is never reaped
    let ps = protos(&cfg, 90);
    let mut client = Client::connect(&addr).unwrap();
    client.learn(&ps[0], 0).unwrap();
    for _ in 0..6 {
        std::thread::sleep(std::time::Duration::from_millis(100));
        assert_eq!(client.infer(&ps[0]).unwrap().class, 0);
    }
    drop(client);
    server.stop();
}

#[test]
fn stalled_reader_is_shed_without_stalling_the_executors() {
    let cfg = cfg4();
    let coord = Coordinator::start(CoordinatorOptions::software(cfg.clone())).unwrap();
    let serve_opts = ServeOptions {
        max_wbuf: 32 * 1024,
        write_stall_timeout: std::time::Duration::from_millis(500),
        ..ServeOptions::default()
    };
    let server = Server::start("127.0.0.1:0", Registry::single("t", coord), serve_opts).unwrap();
    let addr = server.local_addr().to_string();
    let ps = protos(&cfg, 89);
    let mut seeder = Client::connect(&addr).unwrap();
    for (c, p) in ps.iter().enumerate() {
        seeder.learn(p, c).unwrap();
    }

    // the stalled reader: pump pipelined infers and never read a reply.
    // Replies fill the kernel buffers, then the server-side write buffer,
    // until the shed trips; the pump then sees a dead socket.
    let pump = std::thread::spawn({
        let addr = addr.clone();
        let q = ps[0].clone();
        move || {
            let mut raw = TcpStream::connect(&addr).unwrap();
            let req = wire::WireRequest::new(
                1,
                ReqBody::Infer { mode: wire::MODE_DEFAULT, features: q },
            );
            let payload = req.encode(wire::WIRE_V1).unwrap();
            let mut framed = Vec::with_capacity(4 + payload.len());
            framed.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            framed.extend_from_slice(&payload);
            for _ in 0..200_000 {
                if raw.write_all(&framed).is_err() {
                    return true; // shed: the server closed on us
                }
            }
            false
        }
    });
    // a victim connection stays responsive the whole time the pump floods
    let mut victim = Client::connect(&addr).unwrap();
    victim.set_timeout(Some(std::time::Duration::from_secs(10))).unwrap();
    let t0 = std::time::Instant::now();
    while server.sheds() == 0 {
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(30),
            "the stalled reader was never shed"
        );
        assert_eq!(victim.infer(&ps[1]).unwrap().class, 1);
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert!(pump.join().unwrap(), "the pump must observe the shed as a dead socket");
    assert!(server.sheds() >= 1);
    // and a fresh connection is served as if nothing happened
    let mut fresh = Client::connect(&addr).unwrap();
    assert_eq!(fresh.infer(&ps[2]).unwrap().class, 2);
    drop(victim);
    drop(fresh);
    drop(seeder);
    server.stop();
}

#[test]
fn pipeline_window_is_enforced_under_overload() {
    let cfg = cfg4();
    let server = start_server(CoordinatorOptions::software(cfg.clone()));
    let addr = server.local_addr().to_string();
    let ps = protos(&cfg, 88);
    let mut seeder = Client::connect(&addr).unwrap();
    for (c, p) in ps.iter().enumerate() {
        seeder.learn(p, c).unwrap();
    }

    // blast 200 pipelined infers — 3x the window — without reading a reply
    let mut blaster = Client::connect_v2(&addr).unwrap();
    let mut ids = std::collections::HashSet::new();
    for i in 0..200usize {
        let id = blaster
            .send_for("", ReqBody::Infer { mode: 0, features: ps[i % ps.len()].clone() })
            .unwrap();
        ids.insert(id);
    }
    // a second connection is not starved by the blast
    let mut bystander = Client::connect(&addr).unwrap();
    bystander.set_timeout(Some(std::time::Duration::from_secs(5))).unwrap();
    assert_eq!(bystander.infer(&ps[1]).unwrap().class, 1);
    // every blasted request is answered exactly once
    for _ in 0..200 {
        let resp = blaster.recv().unwrap();
        assert!(ids.remove(&resp.id()), "duplicate or unknown reply id {}", resp.id());
        assert!(matches!(resp, wire::WireResponse::Infer { .. }));
    }
    assert!(ids.is_empty());
    // the reactor never admitted more than the window into execution
    let st = blaster.conn_stats().unwrap();
    assert!(st.peak_window >= 1);
    assert!(
        st.peak_window as usize <= wire::MAX_INFLIGHT,
        "window blown: peak {} > {}",
        st.peak_window,
        wire::MAX_INFLIGHT
    );
    assert_eq!(st.frames, 202, "hello + 200 infers + conn-stats");
    assert_eq!(st.replies, 201);
    assert_eq!(st.errors, 0);
    drop(seeder);
    drop(blaster);
    drop(bystander);
    server.stop();
}
