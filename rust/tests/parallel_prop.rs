//! Parallel-runtime contracts: the worker pool must never change a result —
//! pool-sharded kernels are bit-identical to their single-thread twins, the
//! sharded associative search preserves the argmin on tie-free inputs, and
//! a multi-threaded backend classifies exactly like a serial one through
//! the full progressive pipeline (both search modes).

use clo_hdnn::config::HdConfig;
use clo_hdnn::hdc::encoder::SoftwareEncoder;
use clo_hdnn::hdc::quantize::quantize_features;
use clo_hdnn::hdc::signmat::{self, SeededSignMat, SignMat};
use clo_hdnn::hdc::simd::{self, SimdLevel};
use clo_hdnn::hdc::{best_two, packed, ChvStore, HdBackend, ProgressiveSearch, SearchMode};
use clo_hdnn::runtime::NativeBackend;
use clo_hdnn::util::pool::WorkerPool;
use clo_hdnn::util::prop::{forall, gen};
use clo_hdnn::util::Rng;

fn cfg_with_classes(classes: usize) -> HdConfig {
    HdConfig::synthetic("par", 8, 8, 32, 32, 8, classes)
}

#[test]
fn prop_pool_sharded_search_preserves_argmin_on_tie_free_inputs() {
    // The satellite contract spelled as argmin: shard the AM over row-blocks
    // and the winning class must be the single-thread one whenever the
    // distance vector is tie-free (ties have no canonical winner across
    // partitions in general; the kernels are bit-identical anyway, but the
    // argmin statement is the serving-level guarantee).
    forall(15, 0x9A1, |rng| {
        let classes = 8 + rng.below(40);
        let len = classes + rng.below(300);
        let q = gen::pm1_vec(rng, len);
        let qp = packed::pack_signs(&q);
        // tie-free by construction: class c is the query with `counts[c]`
        // elements sign-flipped, and the flip counts are a permutation of
        // 0..classes — so distances (2 * flips) are pairwise distinct
        let counts = rng.permutation(classes);
        let mut chvs = Vec::with_capacity(classes * len);
        for &k in &counts {
            let mut row = q.clone();
            for v in row.iter_mut().take(k) {
                *v = -*v;
            }
            chvs.extend(row);
        }
        let cp = packed::pack_rows(&chvs, classes, len).unwrap();
        let want = counts.iter().position(|&k| k == 0).unwrap();
        let d = packed::hamming_search(&qp, 1, &cp, classes, len).unwrap();
        assert_eq!(best_two(&d).0, want, "single-thread argmin");
        let mut sorted = d.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(sorted.windows(2).all(|w| w[0] != w[1]), "bank must be tie-free");
        for threads in [2usize, 3, 8] {
            let pool = WorkerPool::new(threads);
            let dp = packed::hamming_search_pool(&pool, &qp, 1, &cp, classes, len).unwrap();
            assert_eq!(best_two(&dp).0, want, "threads={threads} classes={classes}");
        }
    });
}

#[test]
fn prop_threaded_backend_classifies_identically_in_both_modes() {
    // Full pipeline (quantize -> progressive encode/search -> argmin) on a
    // 32-class AM: a 4-thread NativeBackend must reproduce the serial
    // backend's class, segment count, and accumulated distances exactly,
    // in the scalar L1 mode and the packed XOR-tree mode.
    forall(5, 0x9A2, |rng| {
        let cfg = cfg_with_classes(32);
        let seed = rng.next_u64();
        let mut serial = NativeBackend::seeded(cfg.clone(), seed, 8).unwrap();
        serial.set_threads(1);
        let mut pooled = NativeBackend::seeded(cfg.clone(), seed, 8).unwrap();
        pooled.set_threads(4);
        let mut store = ChvStore::new(cfg.clone());
        for c in 0..cfg.classes {
            store.update(c, &gen::int8_vec(rng, cfg.dim()), 1.0).unwrap();
        }
        for mode in [SearchMode::L1Int8, SearchMode::HammingPacked] {
            let ps = ProgressiveSearch { tau: 0.5, min_segments: 1, mode };
            for _ in 0..3 {
                let xq = gen::int8_vec(rng, cfg.features());
                let a = ps.classify(&mut serial, &store, &xq).unwrap();
                let b = ps.classify(&mut pooled, &store, &xq).unwrap();
                assert_eq!(a.class, b.class, "{mode:?}");
                assert_eq!(a.segments_used, b.segments_used, "{mode:?}");
                assert_eq!(a.dists, b.dists, "{mode:?}");
            }
        }
    });
}

#[test]
fn prop_zero_repack_encode_matches_manual_pack_through_the_pipeline() {
    // encode_segment_packed (the fused quantize-and-pack path the packed
    // progressive mode consumes) vs pack_rows(encode_segment) — through
    // both the SoftwareEncoder override and the NativeBackend delegation.
    forall(10, 0x9A3, |rng| {
        let cfg = cfg_with_classes(5);
        let seed = rng.next_u64();
        let mut sw = SoftwareEncoder::random(cfg.clone(), seed);
        let mut native = NativeBackend::seeded(cfg.clone(), seed, 8).unwrap();
        let batch = 1 + rng.below(4);
        let xs = gen::int8_vec(rng, batch * cfg.features());
        for s in 0..cfg.segments {
            let q = sw.encode_segment(&xs, batch, s).unwrap();
            let want = packed::pack_rows(&q, batch, cfg.seg_len()).unwrap();
            assert_eq!(sw.encode_segment_packed(&xs, batch, s).unwrap(), want);
            assert_eq!(native.encode_segment_packed(&xs, batch, s).unwrap(), want);
        }
    });
}

#[test]
fn threaded_batch_encode_through_backend_matches_per_sample_software_encode() {
    // the serving shape at batch depth: a pooled backend's batched encode
    // row n must equal the per-sample software encode, like the Batcher test
    // pins for the serial path
    let cfg = cfg_with_classes(5);
    let mut pooled = NativeBackend::seeded(cfg.clone(), 77, 16).unwrap();
    pooled.set_threads(4);
    let mut sw = SoftwareEncoder::random(cfg.clone(), 77);
    let mut rng = Rng::new(78);
    let batch = 11;
    let xs: Vec<f32> =
        (0..batch * cfg.features()).map(|_| rng.range(-90, 91) as f32).collect();
    let got = pooled.encode_full(&xs, batch).unwrap();
    for n in 0..batch {
        let want = sw
            .encode_full(&xs[n * cfg.features()..(n + 1) * cfg.features()], 1)
            .unwrap();
        assert_eq!(&got[n * cfg.dim()..(n + 1) * cfg.dim()], &want[..], "row {n}");
    }
}

#[test]
fn prop_forced_simd_levels_bit_match_scalar_hamming() {
    // The host's detected SIMD level vs forced scalar, through the
    // explicit-level seams: word counts off the 4/8-word SIMD strides,
    // non-64-multiple bit tails, empty batches, and the pool-sharded
    // composition. Distances are integer popcounts scaled by 2, so every
    // level must agree exactly (on a scalar-only host this degenerates to
    // scalar vs scalar, and the CI SIMD matrix still covers dispatch).
    let detected = simd::detect();
    forall(12, 0xB01, |rng| {
        let classes = 1 + rng.below(20);
        let len = 1 + rng.below(520); // 1..9 words incl. partial tail bits
        let batch = rng.below(4); // 0 is a legal (empty) batch
        let mut chvs_f = Vec::with_capacity(classes * len);
        for _ in 0..classes {
            chvs_f.extend(gen::pm1_vec(rng, len));
        }
        let chvs = packed::pack_rows(&chvs_f, classes, len).unwrap();
        let mut qs = Vec::new();
        for _ in 0..batch {
            qs.extend(packed::pack_signs(&gen::pm1_vec(rng, len)));
        }
        let want =
            packed::hamming_search_with(SimdLevel::Scalar, &qs, batch, &chvs, classes, len)
                .unwrap();
        let got = packed::hamming_search_with(detected, &qs, batch, &chvs, classes, len).unwrap();
        assert_eq!(want, got, "level={detected:?} len={len} classes={classes}");
        if batch > 0 {
            // the word-granular kernel (the segment-partial distance arm
            // accumulates through it) agrees on every prefix length too
            let w = packed::words_for(len);
            for words in [1usize, w / 2, w] {
                let words = words.max(1);
                assert_eq!(
                    packed::hamming_words_with(SimdLevel::Scalar, &qs[..words], &chvs[..words]),
                    packed::hamming_words_with(detected, &qs[..words], &chvs[..words]),
                    "words={words}"
                );
            }
        }
        let pool = WorkerPool::new(3);
        let pooled =
            packed::hamming_search_pool_with(detected, &pool, &qs, batch, &chvs, classes, len)
                .unwrap();
        assert_eq!(want, pooled, "pool-sharded level={detected:?}");
    });
}

#[test]
fn prop_forced_simd_levels_bit_match_scalar_signgemm() {
    // Sign-GEMM stage1/stage2 at the detected level vs forced scalar, over
    // stored AND seed-rematerialized planes: ragged shapes off the column
    // tile and off the 4/8-row stage2 blocks, compared bit for bit (the
    // per-element accumulation chains are identical by construction).
    let detected = simd::detect();
    forall(8, 0xB02, |rng| {
        let d1 = 1 + rng.below(12);
        let d2 = 1 + rng.below(20);
        let f1 = 1 + rng.below(10);
        let f2 = 1 + rng.below(30);
        let a_stored = SignMat::from_pm1(&gen::pm1_vec(rng, d1 * f1), d1, f1).unwrap();
        let b_stored = SignMat::from_pm1(&gen::pm1_vec(rng, d2 * f2), d2, f2).unwrap();
        let a_seeded = SeededSignMat::new(rng.next_u64(), d1, f1);
        let b_seeded = SeededSignMat::new(rng.next_u64(), d2, f2);
        let x = gen::normal_vec(rng, f1 * f2, 1.0);

        let mut t_ref = vec![0.0f32; d1 * f2];
        signmat::stage1_with(SimdLevel::Scalar, &a_stored, 0, d1, &x, f2, &mut t_ref);
        let mut y_ref = vec![0.0f32; d1 * d2];
        signmat::stage2_with(SimdLevel::Scalar, &b_stored, &t_ref, d1, f2, &mut y_ref);

        // stored planes, detected level
        let mut t = vec![0.0f32; d1 * f2];
        signmat::stage1_with(detected, &a_stored, 0, d1, &x, f2, &mut t);
        assert_eq!(t_bits(&t_ref), t_bits(&t), "stage1 stored level={detected:?}");
        let mut y = vec![0.0f32; d1 * d2];
        signmat::stage2_with(detected, &b_stored, &t_ref, d1, f2, &mut y);
        assert_eq!(t_bits(&y_ref), t_bits(&y), "stage2 stored level={detected:?}");

        // seeded planes: scalar must equal the materialized twin, and the
        // detected level must equal seeded-scalar
        let am = a_seeded.materialize();
        let bm = b_seeded.materialize();
        let mut ts_ref = vec![0.0f32; d1 * f2];
        signmat::stage1_with(SimdLevel::Scalar, &am, 0, d1, &x, f2, &mut ts_ref);
        for level in [SimdLevel::Scalar, detected] {
            let mut ts = vec![0.0f32; d1 * f2];
            signmat::stage1_with(level, &a_seeded, 0, d1, &x, f2, &mut ts);
            assert_eq!(t_bits(&ts_ref), t_bits(&ts), "stage1 seeded level={level:?}");
            let mut ys_ref = vec![0.0f32; d1 * d2];
            signmat::stage2_with(SimdLevel::Scalar, &bm, &ts_ref, d1, f2, &mut ys_ref);
            let mut ys = vec![0.0f32; d1 * d2];
            signmat::stage2_with(level, &b_seeded, &ts_ref, d1, f2, &mut ys);
            assert_eq!(t_bits(&ys_ref), t_bits(&ys), "stage2 seeded level={level:?}");
        }
    });
}

/// Bit images of an f32 slice — the strictest equality (also -0.0 vs 0.0).
fn t_bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn remat_backend_matches_its_materialized_twin_end_to_end() {
    // A rematerializing backend (planes regenerated from the seed on every
    // encode) against the backend holding the materialized copy of the
    // exact same planes: full encode, fused packed-segment encode, and the
    // complete progressive pipeline in both search modes must agree bit
    // for bit — while the remat side holds an order-of-magnitude less
    // factor memory resident.
    let cfg = cfg_with_classes(6);
    let seed = 0xC0FFEE;
    let mut remat = NativeBackend::seeded_remat(cfg.clone(), seed, 8).unwrap();
    let mut stored =
        NativeBackend::new(SoftwareEncoder::random_remat_materialized(cfg.clone(), seed), 8)
            .unwrap();
    assert!(remat.is_remat() && !stored.is_remat());
    assert!(remat.factor_bytes() < stored.factor_bytes());

    let mut rng = Rng::new(41);
    let batch = 5;
    let xs: Vec<f32> =
        (0..batch * cfg.features()).map(|_| rng.range(-90, 91) as f32).collect();
    assert_eq!(remat.encode_full(&xs, batch).unwrap(), stored.encode_full(&xs, batch).unwrap());
    for s in 0..cfg.segments {
        assert_eq!(
            remat.encode_segment_packed(&xs, batch, s).unwrap(),
            stored.encode_segment_packed(&xs, batch, s).unwrap(),
            "segment {s}"
        );
    }

    let mut store = ChvStore::new(cfg.clone());
    for c in 0..cfg.classes {
        store.update(c, &gen::int8_vec(&mut rng, cfg.dim()), 1.0).unwrap();
    }
    for mode in [SearchMode::L1Int8, SearchMode::HammingPacked] {
        let ps = ProgressiveSearch { tau: 0.5, min_segments: 1, mode };
        for i in 0..batch {
            let xq = &xs[i * cfg.features()..(i + 1) * cfg.features()];
            let a = ps.classify(&mut remat, &store, xq).unwrap();
            let b = ps.classify(&mut stored, &store, xq).unwrap();
            assert_eq!(a.class, b.class, "{mode:?}");
            assert_eq!(a.segments_used, b.segments_used, "{mode:?}");
            assert_eq!(a.dists, b.dists, "{mode:?}");
        }
    }
}

#[test]
fn blob_trained_threaded_classifier_recovers_classes_in_packed_mode() {
    // end-to-end sanity on structured data: learn blobs through a threaded
    // backend, classify through the packed zero-repack path
    let cfg = cfg_with_classes(6);
    let mut backend = NativeBackend::seeded(cfg.clone(), 5, 8).unwrap();
    backend.set_threads(4);
    let mut store = ChvStore::new(cfg.clone());
    let mut rng = Rng::new(6);
    let protos: Vec<Vec<f32>> = (0..cfg.classes)
        .map(|_| (0..cfg.features()).map(|_| rng.normal_f32() * 50.0).collect())
        .collect();
    for (c, p) in protos.iter().enumerate() {
        for _ in 0..5 {
            let noisy: Vec<f32> = p.iter().map(|&v| v + rng.normal_f32() * 5.0).collect();
            let xq = quantize_features(&noisy, 1.0);
            let q = backend.encode_full(&xq, 1).unwrap();
            store.update(c, &q, 1.0).unwrap();
        }
    }
    let ps = ProgressiveSearch { tau: 0.4, min_segments: 1, mode: SearchMode::HammingPacked };
    for (c, p) in protos.iter().enumerate() {
        let xq = quantize_features(p, 1.0);
        let r = ps.classify(&mut backend, &store, &xq).unwrap();
        assert_eq!(r.class, c, "packed threaded classify missed class {c}");
    }
}
