//! Parallel-runtime contracts: the worker pool must never change a result —
//! pool-sharded kernels are bit-identical to their single-thread twins, the
//! sharded associative search preserves the argmin on tie-free inputs, and
//! a multi-threaded backend classifies exactly like a serial one through
//! the full progressive pipeline (both search modes).

use clo_hdnn::config::HdConfig;
use clo_hdnn::hdc::encoder::SoftwareEncoder;
use clo_hdnn::hdc::quantize::quantize_features;
use clo_hdnn::hdc::{best_two, packed, ChvStore, HdBackend, ProgressiveSearch, SearchMode};
use clo_hdnn::runtime::NativeBackend;
use clo_hdnn::util::pool::WorkerPool;
use clo_hdnn::util::prop::{forall, gen};
use clo_hdnn::util::Rng;

fn cfg_with_classes(classes: usize) -> HdConfig {
    HdConfig::synthetic("par", 8, 8, 32, 32, 8, classes)
}

#[test]
fn prop_pool_sharded_search_preserves_argmin_on_tie_free_inputs() {
    // The satellite contract spelled as argmin: shard the AM over row-blocks
    // and the winning class must be the single-thread one whenever the
    // distance vector is tie-free (ties have no canonical winner across
    // partitions in general; the kernels are bit-identical anyway, but the
    // argmin statement is the serving-level guarantee).
    forall(15, 0x9A1, |rng| {
        let classes = 8 + rng.below(40);
        let len = classes + rng.below(300);
        let q = gen::pm1_vec(rng, len);
        let qp = packed::pack_signs(&q);
        // tie-free by construction: class c is the query with `counts[c]`
        // elements sign-flipped, and the flip counts are a permutation of
        // 0..classes — so distances (2 * flips) are pairwise distinct
        let counts = rng.permutation(classes);
        let mut chvs = Vec::with_capacity(classes * len);
        for &k in &counts {
            let mut row = q.clone();
            for v in row.iter_mut().take(k) {
                *v = -*v;
            }
            chvs.extend(row);
        }
        let cp = packed::pack_rows(&chvs, classes, len).unwrap();
        let want = counts.iter().position(|&k| k == 0).unwrap();
        let d = packed::hamming_search(&qp, 1, &cp, classes, len).unwrap();
        assert_eq!(best_two(&d).0, want, "single-thread argmin");
        let mut sorted = d.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(sorted.windows(2).all(|w| w[0] != w[1]), "bank must be tie-free");
        for threads in [2usize, 3, 8] {
            let pool = WorkerPool::new(threads);
            let dp = packed::hamming_search_pool(&pool, &qp, 1, &cp, classes, len).unwrap();
            assert_eq!(best_two(&dp).0, want, "threads={threads} classes={classes}");
        }
    });
}

#[test]
fn prop_threaded_backend_classifies_identically_in_both_modes() {
    // Full pipeline (quantize -> progressive encode/search -> argmin) on a
    // 32-class AM: a 4-thread NativeBackend must reproduce the serial
    // backend's class, segment count, and accumulated distances exactly,
    // in the scalar L1 mode and the packed XOR-tree mode.
    forall(5, 0x9A2, |rng| {
        let cfg = cfg_with_classes(32);
        let seed = rng.next_u64();
        let mut serial = NativeBackend::seeded(cfg.clone(), seed, 8).unwrap();
        serial.set_threads(1);
        let mut pooled = NativeBackend::seeded(cfg.clone(), seed, 8).unwrap();
        pooled.set_threads(4);
        let mut store = ChvStore::new(cfg.clone());
        for c in 0..cfg.classes {
            store.update(c, &gen::int8_vec(rng, cfg.dim()), 1.0).unwrap();
        }
        for mode in [SearchMode::L1Int8, SearchMode::HammingPacked] {
            let ps = ProgressiveSearch { tau: 0.5, min_segments: 1, mode };
            for _ in 0..3 {
                let xq = gen::int8_vec(rng, cfg.features());
                let a = ps.classify(&mut serial, &store, &xq).unwrap();
                let b = ps.classify(&mut pooled, &store, &xq).unwrap();
                assert_eq!(a.class, b.class, "{mode:?}");
                assert_eq!(a.segments_used, b.segments_used, "{mode:?}");
                assert_eq!(a.dists, b.dists, "{mode:?}");
            }
        }
    });
}

#[test]
fn prop_zero_repack_encode_matches_manual_pack_through_the_pipeline() {
    // encode_segment_packed (the fused quantize-and-pack path the packed
    // progressive mode consumes) vs pack_rows(encode_segment) — through
    // both the SoftwareEncoder override and the NativeBackend delegation.
    forall(10, 0x9A3, |rng| {
        let cfg = cfg_with_classes(5);
        let seed = rng.next_u64();
        let mut sw = SoftwareEncoder::random(cfg.clone(), seed);
        let mut native = NativeBackend::seeded(cfg.clone(), seed, 8).unwrap();
        let batch = 1 + rng.below(4);
        let xs = gen::int8_vec(rng, batch * cfg.features());
        for s in 0..cfg.segments {
            let q = sw.encode_segment(&xs, batch, s).unwrap();
            let want = packed::pack_rows(&q, batch, cfg.seg_len()).unwrap();
            assert_eq!(sw.encode_segment_packed(&xs, batch, s).unwrap(), want);
            assert_eq!(native.encode_segment_packed(&xs, batch, s).unwrap(), want);
        }
    });
}

#[test]
fn threaded_batch_encode_through_backend_matches_per_sample_software_encode() {
    // the serving shape at batch depth: a pooled backend's batched encode
    // row n must equal the per-sample software encode, like the Batcher test
    // pins for the serial path
    let cfg = cfg_with_classes(5);
    let mut pooled = NativeBackend::seeded(cfg.clone(), 77, 16).unwrap();
    pooled.set_threads(4);
    let mut sw = SoftwareEncoder::random(cfg.clone(), 77);
    let mut rng = Rng::new(78);
    let batch = 11;
    let xs: Vec<f32> =
        (0..batch * cfg.features()).map(|_| rng.range(-90, 91) as f32).collect();
    let got = pooled.encode_full(&xs, batch).unwrap();
    for n in 0..batch {
        let want = sw
            .encode_full(&xs[n * cfg.features()..(n + 1) * cfg.features()], 1)
            .unwrap();
        assert_eq!(&got[n * cfg.dim()..(n + 1) * cfg.dim()], &want[..], "row {n}");
    }
}

#[test]
fn blob_trained_threaded_classifier_recovers_classes_in_packed_mode() {
    // end-to-end sanity on structured data: learn blobs through a threaded
    // backend, classify through the packed zero-repack path
    let cfg = cfg_with_classes(6);
    let mut backend = NativeBackend::seeded(cfg.clone(), 5, 8).unwrap();
    backend.set_threads(4);
    let mut store = ChvStore::new(cfg.clone());
    let mut rng = Rng::new(6);
    let protos: Vec<Vec<f32>> = (0..cfg.classes)
        .map(|_| (0..cfg.features()).map(|_| rng.normal_f32() * 50.0).collect())
        .collect();
    for (c, p) in protos.iter().enumerate() {
        for _ in 0..5 {
            let noisy: Vec<f32> = p.iter().map(|&v| v + rng.normal_f32() * 5.0).collect();
            let xq = quantize_features(&noisy, 1.0);
            let q = backend.encode_full(&xq, 1).unwrap();
            store.update(c, &q, 1.0).unwrap();
        }
    }
    let ps = ProgressiveSearch { tau: 0.4, min_segments: 1, mode: SearchMode::HammingPacked };
    for (c, p) in protos.iter().enumerate() {
        let xq = quantize_features(p, 1.0);
        let r = ps.classify(&mut backend, &store, &xq).unwrap();
        assert_eq!(r.class, c, "packed threaded classify missed class {c}");
    }
}
