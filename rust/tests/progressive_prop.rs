//! Property tests for the progressive-search controller — the paper's
//! inference-complexity contribution (up to 61% of encode+search work
//! skipped with negligible accuracy loss).
//!
//! Covered contracts:
//! * soundness: with the margin bound that exceeds the maximum possible
//!   remaining contribution, early exit NEVER changes the argmin vs a full
//!   search — over fully randomized CHV banks, encoders, and queries;
//! * monotonicity: per query, the number of segments used (and therefore
//!   the reported dimension-fraction saving) is monotone in the confidence
//!   threshold `tau`;
//! * the saving actually materializes on confident inputs, and
//!   `min_segments` / infinite-`tau` bounds hold;
//! * the packed INT1 (XOR-tree) mode at its sound threshold is
//!   bit-identical in argmin to a full scalar search over the binarized AM,
//!   on every sample of a synthetic continual-learning run — and its
//!   segments-used stays monotone in `tau` under the Hamming bound.

use clo_hdnn::config::HdConfig;
use clo_hdnn::hdc::encoder::SoftwareEncoder;
use clo_hdnn::hdc::quantize::quantize_features;
use clo_hdnn::hdc::{best_two, distance, packed, SearchMode};
use clo_hdnn::hdc::{ChvStore, HdBackend, ProgressiveSearch};
use clo_hdnn::runtime::NativeBackend;
use clo_hdnn::util::prop::{forall, gen};
use clo_hdnn::util::Rng;

fn prop_cfg(classes: usize) -> HdConfig {
    HdConfig::synthetic("p", 8, 8, 32, 32, 8, classes)
}

/// Blob-trained encoder + store (the regime where early exits happen), plus
/// the prototypes used as confident queries.
fn blob_setup(rng: &mut Rng) -> (SoftwareEncoder, ChvStore, Vec<Vec<f32>>) {
    let cfg = prop_cfg(4);
    let mut enc = SoftwareEncoder::random(cfg.clone(), rng.next_u64());
    let mut store = ChvStore::new(cfg.clone());
    let protos: Vec<Vec<f32>> = (0..cfg.classes)
        .map(|_| gen::normal_vec(rng, cfg.features(), 50.0))
        .collect();
    for (c, p) in protos.iter().enumerate() {
        for _ in 0..5 {
            let noisy: Vec<f32> = p.iter().map(|&v| v + rng.normal_f32() * 5.0).collect();
            let xq = quantize_features(&noisy, 1.0);
            let q = enc.encode_full(&xq, 1).unwrap();
            store.update(c, &q, 1.0).unwrap();
        }
    }
    (enc, store, protos)
}

#[test]
fn prop_sound_threshold_agrees_with_full_search_on_random_banks() {
    forall(20, 0xAB1, |rng| {
        let cfg = prop_cfg(6);
        let mut enc = SoftwareEncoder::random(cfg.clone(), rng.next_u64());
        let mut store = ChvStore::new(cfg.clone());
        for c in 0..cfg.classes {
            // fully random INT8 CHV bank (not blob structure)
            store.update(c, &gen::int8_vec(rng, cfg.dim()), 1.0).unwrap();
        }
        // tau * mean_absdiff == 254 == the maximum per-element contribution
        // any remaining segment can add: exit is provably safe.
        let ps = ProgressiveSearch {
            tau: 254.0 / cfg.mean_absdiff,
            min_segments: 1,
            ..Default::default()
        };
        for _ in 0..4 {
            let x = gen::int8_vec(rng, cfg.features());
            let full = ProgressiveSearch::classify_full(&mut enc, &store, &x).unwrap();
            let prog = ps.classify(&mut enc, &store, &x).unwrap();
            assert_eq!(full.class, prog.class, "early exit changed the argmin");
            assert!(prog.segments_used <= full.segments_used);
        }
    });
}

#[test]
fn prop_segments_and_savings_monotone_in_tau() {
    forall(10, 0xAB2, |rng| {
        let (mut enc, store, protos) = blob_setup(rng);
        let total = enc.cfg().segments;
        let taus = [0.01f32, 0.05, 0.2, 0.5, 1.0, 4.0];
        for p in &protos {
            let xq = quantize_features(p, 1.0);
            let mut prev_used = 0usize;
            let mut prev_saving = 1.0f64;
            for &tau in &taus {
                let r = ProgressiveSearch { tau, min_segments: 1, ..Default::default() }
                    .classify(&mut enc, &store, &xq)
                    .unwrap();
                assert!(
                    r.segments_used >= prev_used,
                    "tau={tau}: segments_used {} < {prev_used} — must be non-decreasing",
                    r.segments_used
                );
                let saving = r.complexity_saving(total);
                assert!(
                    saving <= prev_saving + 1e-12,
                    "tau={tau}: saving {saving} > {prev_saving} — must be non-increasing"
                );
                assert!((0.0..=1.0).contains(&saving));
                prev_used = r.segments_used;
                prev_saving = saving;
            }
        }
    });
}

#[test]
fn prop_confident_inputs_save_work_and_agree_with_full() {
    forall(10, 0xAB3, |rng| {
        let (mut enc, store, protos) = blob_setup(rng);
        let total = enc.cfg().segments;
        let ps = ProgressiveSearch { tau: 0.3, min_segments: 1, ..Default::default() };
        let mut used_sum = 0usize;
        for p in &protos {
            let xq = quantize_features(p, 1.0);
            let full = ProgressiveSearch::classify_full(&mut enc, &store, &xq).unwrap();
            let prog = ps.classify(&mut enc, &store, &xq).unwrap();
            assert_eq!(prog.class, full.class);
            used_sum += prog.segments_used;
        }
        // the whole point of progressive search: on well-separated inputs
        // the mean complexity must drop below the full search
        assert!(
            used_sum < protos.len() * total,
            "no work saved: {used_sum} / {}",
            protos.len() * total
        );
    });
}

#[test]
fn prop_min_segments_and_infinite_tau_bounds() {
    forall(20, 0xAB4, |rng| {
        let (mut enc, store, protos) = blob_setup(rng);
        let total = enc.cfg().segments;
        let xq = quantize_features(&protos[rng.below(protos.len())], 1.0);
        let k = 1 + rng.below(total);
        let r = ProgressiveSearch { tau: 0.0, min_segments: k, ..Default::default() }
            .classify(&mut enc, &store, &xq)
            .unwrap();
        assert!(r.segments_used >= k, "min_segments={k} violated: {}", r.segments_used);
        let full = ProgressiveSearch::classify_full(&mut enc, &store, &xq).unwrap();
        assert!(!full.early_exit);
        assert_eq!(full.segments_used, total);
        assert_eq!(full.complexity_saving(total), 0.0);
    });
}

/// Scalar full-search oracle over the **binarized** AM: encode the full
/// QHV, binarize it by sign, take L1 against every binarized CHV (which is
/// exactly `2 × Hamming`, the packed metric), mask untrained classes, and
/// return (argmin, distances).
fn binarized_full_search_oracle(
    backend: &mut dyn HdBackend,
    store: &ChvStore,
    x: &[f32],
) -> (usize, Vec<f32>) {
    let cfg = backend.cfg().clone();
    let qhv = backend.encode_full(x, 1).unwrap();
    let qbin = packed::unpack_pm1(&packed::pack_signs(&qhv), cfg.dim());
    let mut chvs = Vec::with_capacity(cfg.classes * cfg.dim());
    for c in 0..cfg.classes {
        chvs.extend(store.packed().class_hv(c));
    }
    let mut dists = distance::l1_batch(&qbin, 1, &chvs, cfg.classes, cfg.dim()).unwrap();
    for (c, d) in dists.iter_mut().enumerate() {
        if !store.is_trained(c) {
            *d = f32::INFINITY;
        }
    }
    let (class, _, _) = best_two(&dists);
    (class, dists)
}

#[test]
fn prop_packed_sound_tau_bit_identical_to_scalar_full_search_over_cl_stream() {
    // A synthetic continual-learning run: classes arrive two at a time, the
    // AM is partially trained between evaluations. At the sound Hamming
    // threshold (tau = 2.0: margin > 2 * remaining elements can never be
    // overturned), the packed progressive search must agree with the full
    // scalar search over the binarized AM on EVERY sample — including
    // mid-stream states with untrained (masked) classes.
    forall(6, 0xAB5, |rng| {
        let cfg = prop_cfg(6);
        let mut backend = NativeBackend::seeded(cfg.clone(), rng.next_u64(), 8).unwrap();
        let mut store = ChvStore::new(cfg.clone());
        let ps = ProgressiveSearch::sound(&cfg, SearchMode::HammingPacked);
        assert_eq!(ps.tau, 2.0);
        let protos: Vec<Vec<f32>> = (0..cfg.classes)
            .map(|_| gen::normal_vec(rng, cfg.features(), 50.0))
            .collect();
        for task in 0..cfg.classes / 2 {
            // train this task's two classes (bundle in INT8)
            for c in [2 * task, 2 * task + 1] {
                for _ in 0..4 {
                    let noisy: Vec<f32> =
                        protos[c].iter().map(|&v| v + rng.normal_f32() * 5.0).collect();
                    let xq = quantize_features(&noisy, 1.0);
                    let q = backend.encode_full(&xq, 1).unwrap();
                    store.update(c, &q, 1.0).unwrap();
                }
            }
            // evaluate the whole synthetic test set seen so far, plus
            // fully random queries (stress the bound, not just blobs)
            let mut queries: Vec<Vec<f32>> = Vec::new();
            for c in 0..2 * (task + 1) {
                queries.push(quantize_features(
                    &protos[c]
                        .iter()
                        .map(|&v| v + rng.normal_f32() * 10.0)
                        .collect::<Vec<f32>>(),
                    1.0,
                ));
            }
            queries.push(gen::int8_vec(rng, cfg.features()));
            for xq in &queries {
                let (want, dists) = binarized_full_search_oracle(&mut backend, &store, xq);
                let prog = ps.classify(&mut backend, &store, xq).unwrap();
                assert_eq!(
                    prog.class, want,
                    "packed progressive diverged from scalar full search \
                     (task {task}, early_exit {})",
                    prog.early_exit
                );
                if prog.segments_used == cfg.segments {
                    // no early exit: accumulated distances must be
                    // bit-identical, not just argmin-identical
                    assert_eq!(prog.dists, dists);
                }
            }
        }
    });
}

#[test]
fn prop_packed_segments_monotone_in_tau_under_hamming_bound() {
    forall(10, 0xAB6, |rng| {
        let (mut enc, store, protos) = blob_setup(rng);
        let total = enc.cfg().segments;
        let taus = [0.01f32, 0.05, 0.2, 0.5, 1.0, 2.0, 4.0];
        for p in &protos {
            let xq = quantize_features(p, 1.0);
            let mut prev_used = 0usize;
            for &tau in &taus {
                let r = ProgressiveSearch {
                    tau,
                    min_segments: 1,
                    mode: SearchMode::HammingPacked,
                }
                .classify(&mut enc, &store, &xq)
                .unwrap();
                assert!(
                    r.segments_used >= prev_used,
                    "tau={tau}: packed segments_used {} < {prev_used}",
                    r.segments_used
                );
                assert!((0.0..=1.0).contains(&r.complexity_saving(total)));
                prev_used = r.segments_used;
            }
        }
    });
}
