//! Property tests for the progressive-search controller — the paper's
//! inference-complexity contribution (up to 61% of encode+search work
//! skipped with negligible accuracy loss).
//!
//! Covered contracts:
//! * soundness: with the margin bound that exceeds the maximum possible
//!   remaining contribution, early exit NEVER changes the argmin vs a full
//!   search — over fully randomized CHV banks, encoders, and queries;
//! * monotonicity: per query, the number of segments used (and therefore
//!   the reported dimension-fraction saving) is monotone in the confidence
//!   threshold `tau`;
//! * the saving actually materializes on confident inputs, and
//!   `min_segments` / infinite-`tau` bounds hold.

use clo_hdnn::config::HdConfig;
use clo_hdnn::hdc::encoder::SoftwareEncoder;
use clo_hdnn::hdc::quantize::quantize_features;
use clo_hdnn::hdc::{ChvStore, HdBackend, ProgressiveSearch};
use clo_hdnn::util::prop::{forall, gen};
use clo_hdnn::util::Rng;

fn prop_cfg(classes: usize) -> HdConfig {
    HdConfig::synthetic("p", 8, 8, 32, 32, 8, classes)
}

/// Blob-trained encoder + store (the regime where early exits happen), plus
/// the prototypes used as confident queries.
fn blob_setup(rng: &mut Rng) -> (SoftwareEncoder, ChvStore, Vec<Vec<f32>>) {
    let cfg = prop_cfg(4);
    let mut enc = SoftwareEncoder::random(cfg.clone(), rng.next_u64());
    let mut store = ChvStore::new(cfg.clone());
    let protos: Vec<Vec<f32>> = (0..cfg.classes)
        .map(|_| gen::normal_vec(rng, cfg.features(), 50.0))
        .collect();
    for (c, p) in protos.iter().enumerate() {
        for _ in 0..5 {
            let noisy: Vec<f32> = p.iter().map(|&v| v + rng.normal_f32() * 5.0).collect();
            let xq = quantize_features(&noisy, 1.0);
            let q = enc.encode_full(&xq, 1).unwrap();
            store.update(c, &q, 1.0).unwrap();
        }
    }
    (enc, store, protos)
}

#[test]
fn prop_sound_threshold_agrees_with_full_search_on_random_banks() {
    forall(20, 0xAB1, |rng| {
        let cfg = prop_cfg(6);
        let mut enc = SoftwareEncoder::random(cfg.clone(), rng.next_u64());
        let mut store = ChvStore::new(cfg.clone());
        for c in 0..cfg.classes {
            // fully random INT8 CHV bank (not blob structure)
            store.update(c, &gen::int8_vec(rng, cfg.dim()), 1.0).unwrap();
        }
        // tau * mean_absdiff == 254 == the maximum per-element contribution
        // any remaining segment can add: exit is provably safe.
        let ps = ProgressiveSearch { tau: 254.0 / cfg.mean_absdiff, min_segments: 1 };
        for _ in 0..4 {
            let x = gen::int8_vec(rng, cfg.features());
            let full = ProgressiveSearch::classify_full(&mut enc, &store, &x).unwrap();
            let prog = ps.classify(&mut enc, &store, &x).unwrap();
            assert_eq!(full.class, prog.class, "early exit changed the argmin");
            assert!(prog.segments_used <= full.segments_used);
        }
    });
}

#[test]
fn prop_segments_and_savings_monotone_in_tau() {
    forall(10, 0xAB2, |rng| {
        let (mut enc, store, protos) = blob_setup(rng);
        let total = enc.cfg().segments;
        let taus = [0.01f32, 0.05, 0.2, 0.5, 1.0, 4.0];
        for p in &protos {
            let xq = quantize_features(p, 1.0);
            let mut prev_used = 0usize;
            let mut prev_saving = 1.0f64;
            for &tau in &taus {
                let r = ProgressiveSearch { tau, min_segments: 1 }
                    .classify(&mut enc, &store, &xq)
                    .unwrap();
                assert!(
                    r.segments_used >= prev_used,
                    "tau={tau}: segments_used {} < {prev_used} — must be non-decreasing",
                    r.segments_used
                );
                let saving = r.complexity_saving(total);
                assert!(
                    saving <= prev_saving + 1e-12,
                    "tau={tau}: saving {saving} > {prev_saving} — must be non-increasing"
                );
                assert!((0.0..=1.0).contains(&saving));
                prev_used = r.segments_used;
                prev_saving = saving;
            }
        }
    });
}

#[test]
fn prop_confident_inputs_save_work_and_agree_with_full() {
    forall(10, 0xAB3, |rng| {
        let (mut enc, store, protos) = blob_setup(rng);
        let total = enc.cfg().segments;
        let ps = ProgressiveSearch { tau: 0.3, min_segments: 1 };
        let mut used_sum = 0usize;
        for p in &protos {
            let xq = quantize_features(p, 1.0);
            let full = ProgressiveSearch::classify_full(&mut enc, &store, &xq).unwrap();
            let prog = ps.classify(&mut enc, &store, &xq).unwrap();
            assert_eq!(prog.class, full.class);
            used_sum += prog.segments_used;
        }
        // the whole point of progressive search: on well-separated inputs
        // the mean complexity must drop below the full search
        assert!(
            used_sum < protos.len() * total,
            "no work saved: {used_sum} / {}",
            protos.len() * total
        );
    });
}

#[test]
fn prop_min_segments_and_infinite_tau_bounds() {
    forall(20, 0xAB4, |rng| {
        let (mut enc, store, protos) = blob_setup(rng);
        let total = enc.cfg().segments;
        let xq = quantize_features(&protos[rng.below(protos.len())], 1.0);
        let k = 1 + rng.below(total);
        let r = ProgressiveSearch { tau: 0.0, min_segments: k }
            .classify(&mut enc, &store, &xq)
            .unwrap();
        assert!(r.segments_used >= k, "min_segments={k} violated: {}", r.segments_used);
        let full = ProgressiveSearch::classify_full(&mut enc, &store, &xq).unwrap();
        assert!(!full.early_exit);
        assert_eq!(full.segments_used, total);
        assert_eq!(full.complexity_saving(total), 0.0);
    });
}
