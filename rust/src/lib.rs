//! # Clo-HDnn — continual on-device learning accelerator, reproduced in software
//!
//! Rust implementation of the Clo-HDnn system (Song, Xu, et al., VLSI 2025):
//! a continual-learning accelerator pairing a weight-clustering CNN feature
//! extractor (WCFE) with a gradient-free hyperdimensional-computing (HDC)
//! classifier, a Kronecker HD encoder, and progressive associative search.
//!
//! Layering (see DESIGN.md):
//! * **L3 (this crate)** — the chip's coordination fabric: dual-mode routing,
//!   progressive-search control, CHV cache, training, the custom ISA, the
//!   CDC FIFO, plus the DVFS energy/latency model calibrated to the paper's
//!   silicon measurements.
//! * **L2/L1** — the compute backends behind [`hdc::HdBackend`]:
//!   [`runtime::NativeBackend`] (default: pure Rust, hermetic, no artifacts
//!   needed) and, behind the non-default `pjrt` cargo feature,
//!   `runtime::PjrtBackend` executing JAX/Pallas graphs AOT-lowered to HLO
//!   text under `artifacts/` via the PJRT C API. Python only ever runs at
//!   build time, and only for the PJRT path.
//!
//! The public API a downstream user touches: [`runtime::NativeBackend`] (or
//! `runtime::Engine` with `--features pjrt`), [`hdc::HdClassifier`] +
//! [`coordinator::Coordinator`] for serving/learning, [`serve::Server`] +
//! [`serve::Registry`] + [`serve::Client`] for the multi-model TCP wire
//! protocol (v1 single-model, v2 model-addressed + pipelined — byte-level
//! spec in `docs/PROTOCOL.md`), [`hdc::knowledge`] for durable
//! class-hypervector checkpoints, [`cl::ClHarness`] for continual-learning
//! experiments, [`data::synthetic`] for hermetic workloads, and
//! [`sim::Chip`] for cycle/energy estimates.

pub mod baselines;
pub mod cl;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod energy;
pub mod fifo;
pub mod hdc;
pub mod isa;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod util;
pub mod wcfe;

/// Crate-wide result type (anyhow, matching the xla crate's error style).
pub type Result<T> = anyhow::Result<T>;
