//! Gradient-free HDC training driver (Fig.6 HDC Training module):
//! single-pass bundling + mistake-driven retraining epochs, in the
//! continual-learning setting (per-task training never touches other
//! tasks' CHVs — the no-catastrophic-forgetting property, tested here).

use crate::data::{Dataset, Task};
use crate::hdc::HdClassifier;
use crate::Result;

/// Batch trainer over datasets / CL tasks.
pub struct Trainer {
    /// mistake-driven retrain epochs after the single pass
    pub retrain_epochs: usize,
}

impl Default for Trainer {
    fn default() -> Self {
        Trainer { retrain_epochs: 2 }
    }
}

/// What a training call did.
#[derive(Clone, Debug, Default)]
pub struct RetrainReport {
    pub samples: usize,
    pub epochs: usize,
    /// wrong predictions per retrain epoch (should be non-increasing-ish)
    pub mistakes: Vec<usize>,
}

impl Trainer {
    /// Single-pass + retrain over an explicit index set of a dataset.
    pub fn train_indices(
        &self,
        cl: &mut HdClassifier,
        ds: &Dataset,
        indices: &[usize],
    ) -> Result<RetrainReport> {
        for &i in indices {
            cl.learn(ds.sample(i), ds.label(i))?;
        }
        let mut report = RetrainReport {
            samples: indices.len(),
            epochs: self.retrain_epochs,
            mistakes: Vec::new(),
        };
        for _ in 0..self.retrain_epochs {
            let mut wrong = 0usize;
            for &i in indices {
                if !cl.retrain_step(ds.sample(i), ds.label(i))? {
                    wrong += 1;
                }
            }
            report.mistakes.push(wrong);
        }
        Ok(report)
    }

    /// Train on one CL task (only its samples — HDC's class independence is
    /// what keeps earlier tasks intact).
    pub fn train_task(
        &self,
        cl: &mut HdClassifier,
        ds: &Dataset,
        task: &Task,
    ) -> Result<RetrainReport> {
        self.train_indices(cl, ds, &task.train_indices)
    }

    /// Train on a whole dataset.
    pub fn train_all(&self, cl: &mut HdClassifier, ds: &Dataset) -> Result<RetrainReport> {
        let idx: Vec<usize> = (0..ds.n).collect();
        self.train_indices(cl, ds, &idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HdConfig;
    use crate::data::TaskStream;
    use crate::hdc::encoder::SoftwareEncoder;
    use crate::hdc::ProgressiveSearch;
    use crate::util::Rng;

    fn blob_dataset(classes: usize, per_class: usize, feat: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let protos: Vec<Vec<f32>> = (0..classes)
            .map(|_| (0..feat).map(|_| rng.normal_f32() * 30.0).collect())
            .collect();
        let mut x = Vec::new();
        let mut y = Vec::new();
        for c in 0..classes {
            for _ in 0..per_class {
                x.extend(protos[c].iter().map(|&v| v + rng.normal_f32() * 4.0));
                y.push(c as u16);
            }
        }
        Dataset::from_parts(x, y, feat, classes).unwrap()
    }

    fn classifier(classes: usize) -> HdClassifier {
        let cfg = HdConfig::synthetic("t", 8, 8, 32, 32, 8, classes);
        HdClassifier::new(
            Box::new(SoftwareEncoder::random(cfg, 31)),
            ProgressiveSearch { tau: 0.4, min_segments: 1, ..Default::default() },
        )
    }

    fn accuracy(cl: &mut HdClassifier, ds: &Dataset, classes: &[usize]) -> f64 {
        let idx = ds.indices_of_classes(classes);
        let samples = idx
            .iter()
            .map(|&i| (ds.sample(i).to_vec(), ds.label(i)));
        cl.evaluate(samples).unwrap().accuracy
    }

    #[test]
    fn single_pass_learns_blobs() {
        let ds = blob_dataset(6, 10, 64, 41);
        let mut cl = classifier(6);
        Trainer { retrain_epochs: 0 }.train_all(&mut cl, &ds).unwrap();
        let acc = accuracy(&mut cl, &ds, &(0..6).collect::<Vec<_>>());
        assert!(acc > 0.9, "accuracy {acc}");
    }

    #[test]
    fn no_catastrophic_forgetting_across_tasks() {
        // Train task 0, snapshot accuracy on task-0 classes, train task 1,
        // re-measure: accuracy on task 0 must not collapse (HDC's class-
        // independence, challenge C2 -> solution S2).
        let ds = blob_dataset(8, 12, 64, 42);
        let stream = TaskStream::class_incremental(&ds, 2, 1);
        let mut cl = classifier(8);
        let t = Trainer { retrain_epochs: 1 };
        t.train_task(&mut cl, &ds, &stream.tasks[0]).unwrap();
        let acc0_before = accuracy(&mut cl, &ds, &stream.tasks[0].classes);
        t.train_task(&mut cl, &ds, &stream.tasks[1]).unwrap();
        let acc0_after = accuracy(&mut cl, &ds, &stream.tasks[0].classes);
        assert!(acc0_before > 0.85, "task0 never learned: {acc0_before}");
        assert!(
            acc0_after > acc0_before - 0.15,
            "forgetting: {acc0_before} -> {acc0_after}"
        );
    }

    #[test]
    fn retrain_reports_mistakes() {
        let ds = blob_dataset(4, 8, 64, 43);
        let mut cl = classifier(4);
        let rep = Trainer { retrain_epochs: 3 }.train_all(&mut cl, &ds).unwrap();
        assert_eq!(rep.samples, 32);
        assert_eq!(rep.mistakes.len(), 3);
        // final epoch should be no worse than the first
        assert!(rep.mistakes.last().unwrap() <= rep.mistakes.first().unwrap());
    }

    #[test]
    fn trained_classes_tracked() {
        let ds = blob_dataset(5, 4, 64, 44);
        let stream = TaskStream::class_incremental(&ds, 5, 2);
        let mut cl = classifier(5);
        let t = Trainer { retrain_epochs: 0 };
        for (i, task) in stream.tasks.iter().enumerate() {
            t.train_task(&mut cl, &ds, task).unwrap();
            assert_eq!(cl.store.trained_classes(), i + 1);
        }
    }
}
