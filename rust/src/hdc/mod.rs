//! The HD module (Fig.3 right / Fig.6): Kronecker encoder, associative
//! search, CHV cache, gradient-free training, and the progressive-search
//! controller — the paper's classifier contribution.
//!
//! Compute can run on two interchangeable backends via [`HdBackend`]:
//! * [`SoftwareEncoder`]-based pure-Rust backend (reference + fallback), and
//! * the PJRT backend in [`crate::runtime`], executing the AOT-lowered
//!   Pallas/JAX artifacts (the production path).
//! Both are held to the same golden vectors (artifacts/golden.bin).

pub mod chv;
pub mod classifier;
pub mod distance;
pub mod encoder;
pub mod knowledge;
pub mod packed;
pub mod progressive;
pub mod quantize;
pub mod signmat;
pub mod simd;
pub mod train;
pub mod wal;

pub use chv::ChvStore;
pub use classifier::HdClassifier;
pub use encoder::{EncodeKernel, EncodedBatch, SoftwareEncoder};
pub use packed::{PackedChvStore, PackedHv};
pub use progressive::{ProgressiveResult, ProgressiveSearch, SearchMode};
pub use signmat::{SeededSignMat, SignMat, SignRows};
pub use simd::SimdLevel;
pub use train::{RetrainReport, Trainer};

use crate::config::HdConfig;
use crate::Result;

/// Execution backend for the HD module's two hot operations.
///
/// Shapes are row-major flattened; `batch` rows of `cfg.features()` in,
/// `batch` rows of segment/D out.
/// NOTE: not `Send` — the PJRT backend wraps raw C-API handles; the
/// coordinator therefore runs all backends on a dedicated executor thread
/// (leader/worker pattern, see `crate::coordinator`).
pub trait HdBackend {
    fn cfg(&self) -> &HdConfig;

    /// Encode one progressive-search segment: xs (batch, F) -> (batch, seg_len).
    fn encode_segment(&mut self, xs: &[f32], batch: usize, seg: usize) -> Result<Vec<f32>>;

    /// Encode the full QHV: xs (batch, F) -> (batch, D).
    fn encode_full(&mut self, xs: &[f32], batch: usize) -> Result<Vec<f32>>;

    /// L1 distances: qs (batch, len) vs chvs (classes, len) -> (batch, classes).
    fn search(
        &mut self,
        qs: &[f32],
        batch: usize,
        chvs: &[f32],
        classes: usize,
        len: usize,
    ) -> Result<Vec<f32>>;

    /// Bit-packed associative search (the XOR-tree mode): qs (batch, words)
    /// vs chvs (classes, words) -> (batch, classes), where each row packs
    /// `len` ±1 elements into `len.div_ceil(64)` words and distances are
    /// `2 × Hamming` — the L1 distance between the ±1 vectors, so packed
    /// and scalar search agree bit for bit on binarized operands.
    ///
    /// The default implementation unpacks both operands and reuses
    /// [`HdBackend::search`]; fast backends override it with an
    /// XOR+popcount kernel.
    fn search_packed(
        &mut self,
        qs: &[u64],
        batch: usize,
        chvs: &[u64],
        classes: usize,
        len: usize,
    ) -> Result<Vec<f32>> {
        let qf = packed::unpack_pm1_rows(qs, batch, len)?;
        let cf = packed::unpack_pm1_rows(chvs, classes, len)?;
        self.search(&qf, batch, &cf, classes, len)
    }

    /// Encode one progressive-search segment straight into its bit-packed
    /// (sign) image: xs (batch, F) -> (batch, `words_for(seg_len)`) — the
    /// operand [`HdBackend::search_packed`] takes, with no intermediate
    /// repacking. The default implementation encodes and packs; fast
    /// backends override it with a fused quantize-and-pack pass. Bits are
    /// always identical to `pack_rows(encode_segment(..))`.
    fn encode_segment_packed(&mut self, xs: &[f32], batch: usize, seg: usize) -> Result<Vec<u64>> {
        let q = self.encode_segment(xs, batch, seg)?;
        packed::pack_rows(&q, batch, self.cfg().seg_len())
    }

    /// Hint how many worker threads the backend may fan out to **within one
    /// call** (`0` = auto: `CLO_HDNN_THREADS` when set, else all cores).
    /// The executor thread still owns the backend — parallelism never
    /// crosses a request boundary. Default: ignored (the PJRT path
    /// parallelizes inside the runtime already).
    fn set_parallelism(&mut self, _threads: usize) {}
}

/// argmin + runner-up over one row of distances; returns
/// (best_class, best, second_best).
pub fn best_two(dists: &[f32]) -> (usize, f32, f32) {
    assert!(!dists.is_empty());
    let (mut bi, mut b1, mut b2) = (0usize, f32::INFINITY, f32::INFINITY);
    for (i, &d) in dists.iter().enumerate() {
        if d < b1 {
            b2 = b1;
            b1 = d;
            bi = i;
        } else if d < b2 {
            b2 = d;
        }
    }
    (bi, b1, b2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_two_basic() {
        let (i, b1, b2) = best_two(&[5.0, 1.0, 3.0, 1.5]);
        assert_eq!(i, 1);
        assert_eq!(b1, 1.0);
        assert_eq!(b2, 1.5);
    }

    #[test]
    fn best_two_single_class() {
        let (i, b1, b2) = best_two(&[2.0]);
        assert_eq!(i, 0);
        assert_eq!(b1, 2.0);
        assert!(b2.is_infinite());
    }

    #[test]
    fn best_two_ties_prefer_first() {
        let (i, b1, b2) = best_two(&[3.0, 3.0]);
        assert_eq!(i, 0);
        assert_eq!(b1, 3.0);
        assert_eq!(b2, 3.0);
    }
}
