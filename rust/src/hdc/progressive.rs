//! Progressive search controller (Fig.4 right / Fig.6) — the paper's
//! inference-complexity contribution.
//!
//! The QHV is encoded one segment at a time; after each segment the partial
//! L1 distances (exactly additive over segments) are accumulated and the
//! margin between the best and runner-up class is tested against a
//! confidence threshold. If the margin exceeds what the remaining segments
//! could plausibly overturn, encoding + search terminate early — saving up
//! to 61% of the encode/search work with negligible accuracy loss.
//!
//! Threshold: `margin > tau * mean_absdiff * remaining_len`, where
//! `mean_absdiff` (from build-time calibration, manifest) estimates the
//! expected per-element |q - c| contribution of a *wrong* class; `tau` is
//! the preset confidence knob the Fig.4 bench sweeps.
//!
//! Two search modes share the controller (the chip's precision split):
//! * [`SearchMode::L1Int8`] — scalar L1 over the INT8 CHV view; the sound
//!   exit needs `tau * mean_absdiff >= 254` (max per-element contribution).
//! * [`SearchMode::HammingPacked`] — XOR+popcount over the bit-packed INT1
//!   AM; distances are `2 × Hamming` (the L1 over ±1 vectors), the expected
//!   per-element contribution of a wrong class is exactly 1, and the max is
//!   2 — so `tau = 2.0` is already provably sound, independent of the
//!   build-time calibration.

use crate::config::HdConfig;
use crate::hdc::chv::ChvStore;
use crate::hdc::{best_two, HdBackend};
use crate::Result;
use anyhow::bail;

/// Which distance kernel the progressive controller drives.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SearchMode {
    /// Scalar L1 over the INT8 CHV view (the chip's arithmetic mode).
    #[default]
    L1Int8,
    /// XOR+popcount over the bit-packed INT1 AM (the chip's XOR-tree mode);
    /// distances are `2 × Hamming` == L1 over the ±1 vectors.
    HammingPacked,
}

impl SearchMode {
    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> Result<SearchMode> {
        match s {
            "l1" | "l1int8" | "int8" | "scalar" => Ok(SearchMode::L1Int8),
            "packed" | "hamming" | "int1" => Ok(SearchMode::HammingPacked),
            other => bail!("unknown search mode '{other}' (l1|packed)"),
        }
    }

    /// Expected per-element distance contribution of a *wrong* class — the
    /// unit `tau` is expressed in. INT8 L1 uses the build-time calibration;
    /// for the Hamming metric it is exactly 1 (a wrong-class element
    /// differs with probability 1/2 and contributes 2 when it does).
    pub fn mean_absdiff(&self, cfg: &HdConfig) -> f32 {
        match self {
            SearchMode::L1Int8 => cfg.mean_absdiff,
            SearchMode::HammingPacked => 1.0,
        }
    }

    /// Maximum per-element contribution to the remaining margin change:
    /// 254 for INT8 L1 (|127 - (-127)|), 2 for the Hamming metric.
    pub fn max_step(&self) -> f32 {
        match self {
            SearchMode::L1Int8 => 254.0,
            SearchMode::HammingPacked => 2.0,
        }
    }

    /// The `tau` at which early exit is provably sound (can never change
    /// the argmin vs a full search in the same mode).
    pub fn sound_tau(&self, cfg: &HdConfig) -> f32 {
        self.max_step() / self.mean_absdiff(cfg)
    }
}

/// Confidence policy for early termination.
#[derive(Clone, Copy, Debug)]
pub struct ProgressiveSearch {
    /// Confidence threshold in units of expected per-element distance.
    pub tau: f32,
    /// Never terminate before this many segments (>= 1).
    pub min_segments: usize,
    /// Which distance kernel to drive (INT8 L1 or packed INT1 Hamming).
    pub mode: SearchMode,
}

impl Default for ProgressiveSearch {
    fn default() -> Self {
        ProgressiveSearch { tau: 0.5, min_segments: 1, mode: SearchMode::default() }
    }
}

/// Outcome of one progressive classification.
#[derive(Clone, Debug)]
pub struct ProgressiveResult {
    pub class: usize,
    /// segments actually encoded + searched (<= cfg.segments)
    pub segments_used: usize,
    /// accumulated distances over the used segments
    pub dists: Vec<f32>,
    /// margin (second - best) at termination
    pub margin: f32,
    pub early_exit: bool,
}

impl ProgressiveResult {
    /// Fraction of encode+search work skipped vs a full search.
    pub fn complexity_saving(&self, total_segments: usize) -> f64 {
        1.0 - self.segments_used as f64 / total_segments as f64
    }
}

impl ProgressiveSearch {
    /// Never-early-exit policy in the given search mode (exhaustive search).
    pub fn full(mode: SearchMode) -> ProgressiveSearch {
        ProgressiveSearch { tau: f32::INFINITY, min_segments: usize::MAX, mode }
    }

    /// Policy at the provably sound early-exit threshold for `cfg`.
    pub fn sound(cfg: &HdConfig, mode: SearchMode) -> ProgressiveSearch {
        ProgressiveSearch { tau: mode.sound_tau(cfg), min_segments: 1, mode }
    }

    /// Classify one (already feature-quantized) sample against the CHV store.
    pub fn classify(
        &self,
        backend: &mut dyn HdBackend,
        store: &ChvStore,
        x: &[f32],
    ) -> Result<ProgressiveResult> {
        let cfg = backend.cfg().clone();
        let (segments, seg_len, classes) = (cfg.segments, cfg.seg_len(), cfg.classes);
        let per_elem = self.mode.mean_absdiff(&cfg);
        let mut acc = vec![0.0f32; classes];
        let mut used = 0usize;
        let mut early = false;
        let mut margin = 0.0f32;
        // the AM cache only holds CHVs of classes seen so far — empty slots
        // are excluded from the search (their all-zero rows would otherwise
        // attract low-magnitude queries)
        let untrained: Vec<usize> =
            (0..classes).filter(|&c| !store.is_trained(c)).collect();
        let mask = |acc: &mut Vec<f32>| {
            for &c in &untrained {
                acc[c] = f32::INFINITY;
            }
        };
        for s in 0..segments {
            let d = match self.mode {
                SearchMode::L1Int8 => {
                    let q = backend.encode_segment(x, 1, s)?;
                    backend.search(&q, 1, store.segment(s), classes, seg_len)?
                }
                SearchMode::HammingPacked => {
                    // the encoder emits the binarized (sign) segment image
                    // directly — zero repacking between encode and the
                    // XOR-tree search against the packed AM
                    let qp = backend.encode_segment_packed(x, 1, s)?;
                    backend.search_packed(&qp, 1, store.packed().segment(s), classes, seg_len)?
                }
            };
            for (a, v) in acc.iter_mut().zip(&d) {
                *a += v;
            }
            mask(&mut acc);
            used = s + 1;
            let (_, b1, b2) = best_two(&acc);
            margin = b2 - b1;
            if used >= self.min_segments && used < segments {
                let remaining = ((segments - used) * seg_len) as f32;
                if margin > self.tau * per_elem * remaining {
                    early = true;
                    break;
                }
            }
        }
        let (class, b1, b2) = best_two(&acc);
        Ok(ProgressiveResult {
            class,
            segments_used: used,
            dists: acc,
            margin: b2 - b1,
            early_exit: early,
        })
    }

    /// Full (non-progressive) classification in the scalar INT8 mode:
    /// encode everything, one exhaustive L1 search. This is the
    /// high-precision oracle training and the differential tests compare
    /// against; use [`ProgressiveSearch::full`] for an exhaustive search in
    /// a specific mode.
    pub fn classify_full(
        backend: &mut dyn HdBackend,
        store: &ChvStore,
        x: &[f32],
    ) -> Result<ProgressiveResult> {
        ProgressiveSearch::full(SearchMode::L1Int8).classify(backend, store, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HdConfig;
    use crate::hdc::encoder::SoftwareEncoder;
    use crate::hdc::quantize::quantize_features;
    use crate::util::Rng;

    fn setup() -> (SoftwareEncoder, ChvStore, Vec<Vec<f32>>) {
        let cfg = HdConfig::synthetic("t", 8, 8, 32, 32, 8, 4);
        let mut enc = SoftwareEncoder::random(cfg.clone(), 9);
        let mut store = ChvStore::new(cfg.clone());
        let mut rng = Rng::new(10);
        // four well-separated class prototypes, bundled from 5 noisy draws
        let protos: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..cfg.features()).map(|_| rng.normal_f32() * 50.0).collect())
            .collect();
        for (c, p) in protos.iter().enumerate() {
            for _ in 0..5 {
                let noisy: Vec<f32> = p.iter().map(|&v| v + rng.normal_f32() * 5.0).collect();
                let xq = quantize_features(&noisy, 1.0);
                let q = enc.encode_full(&xq, 1).unwrap();
                store.update(c, &q, 1.0).unwrap();
            }
        }
        (enc, store, protos)
    }

    #[test]
    fn progressive_matches_full_on_confident_inputs() {
        let (mut enc, store, protos) = setup();
        let ps = ProgressiveSearch { tau: 0.3, min_segments: 1, ..Default::default() };
        for (c, p) in protos.iter().enumerate() {
            let xq = quantize_features(p, 1.0);
            let full = ProgressiveSearch::classify_full(&mut enc, &store, &xq).unwrap();
            let prog = ps.classify(&mut enc, &store, &xq).unwrap();
            assert_eq!(full.class, c);
            assert_eq!(prog.class, c, "progressive disagreed on class {c}");
            assert!(prog.segments_used <= full.segments_used);
        }
    }

    #[test]
    fn early_exit_happens_for_confident_inputs() {
        let (mut enc, store, protos) = setup();
        // generous threshold: should exit well before all 8 segments
        let ps = ProgressiveSearch { tau: 0.05, min_segments: 1, ..Default::default() };
        let xq = quantize_features(&protos[0], 1.0);
        let r = ps.classify(&mut enc, &store, &xq).unwrap();
        assert!(r.early_exit);
        assert!(r.segments_used < enc.cfg().segments);
        assert!(r.complexity_saving(enc.cfg().segments) > 0.0);
    }

    #[test]
    fn infinite_tau_never_exits_early() {
        let (mut enc, store, protos) = setup();
        let xq = quantize_features(&protos[1], 1.0);
        let r = ProgressiveSearch::classify_full(&mut enc, &store, &xq).unwrap();
        assert!(!r.early_exit);
        assert_eq!(r.segments_used, enc.cfg().segments);
    }

    #[test]
    fn min_segments_respected() {
        let (mut enc, store, protos) = setup();
        let ps = ProgressiveSearch { tau: 0.0, min_segments: 3, ..Default::default() };
        let xq = quantize_features(&protos[2], 1.0);
        let r = ps.classify(&mut enc, &store, &xq).unwrap();
        assert!(r.segments_used >= 3);
    }

    #[test]
    fn margin_bound_guarantees_agreement_with_full() {
        // Soundness: if the margin exceeds the MAXIMUM possible remaining
        // contribution (254 per element), early exit can NEVER change the
        // argmin. tau chosen so tau*mean_absdiff >= 254 with min margin.
        let (mut enc, store, protos) = setup();
        let cfg = enc.cfg().clone();
        let tau_sound = 254.0 / cfg.mean_absdiff;
        let ps = ProgressiveSearch { tau: tau_sound, min_segments: 1, ..Default::default() };
        let mut rng = Rng::new(33);
        for p in &protos {
            let noisy: Vec<f32> = p.iter().map(|&v| v + rng.normal_f32() * 20.0).collect();
            let xq = quantize_features(&noisy, 1.0);
            let full = ProgressiveSearch::classify_full(&mut enc, &store, &xq).unwrap();
            let prog = ps.classify(&mut enc, &store, &xq).unwrap();
            assert_eq!(full.class, prog.class);
        }
    }

    #[test]
    fn search_mode_parse_and_sound_tau() {
        let cfg = HdConfig::synthetic("t", 8, 8, 32, 32, 8, 4);
        assert_eq!(SearchMode::parse("l1").unwrap(), SearchMode::L1Int8);
        assert_eq!(SearchMode::parse("packed").unwrap(), SearchMode::HammingPacked);
        assert_eq!(SearchMode::parse("hamming").unwrap(), SearchMode::HammingPacked);
        assert!(SearchMode::parse("xor-tree").is_err());
        assert_eq!(SearchMode::L1Int8.sound_tau(&cfg), 254.0 / cfg.mean_absdiff);
        // the Hamming bound does not depend on calibration: max step 2 over
        // a mean contribution of exactly 1
        assert_eq!(SearchMode::HammingPacked.sound_tau(&cfg), 2.0);
    }

    #[test]
    fn packed_mode_recovers_classes() {
        let (mut enc, store, protos) = setup();
        let ps = ProgressiveSearch {
            tau: 0.3,
            min_segments: 1,
            mode: SearchMode::HammingPacked,
        };
        for (c, p) in protos.iter().enumerate() {
            let xq = quantize_features(p, 1.0);
            let r = ps.classify(&mut enc, &store, &xq).unwrap();
            assert_eq!(r.class, c, "packed mode disagreed on class {c}");
        }
    }

    #[test]
    fn packed_sound_tau_matches_packed_full_search() {
        let (mut enc, store, protos) = setup();
        let cfg = enc.cfg().clone();
        let ps = ProgressiveSearch::sound(&cfg, SearchMode::HammingPacked);
        let full = ProgressiveSearch::full(SearchMode::HammingPacked);
        let mut rng = Rng::new(44);
        for p in &protos {
            let noisy: Vec<f32> = p.iter().map(|&v| v + rng.normal_f32() * 20.0).collect();
            let xq = quantize_features(&noisy, 1.0);
            let f = full.classify(&mut enc, &store, &xq).unwrap();
            let g = ps.classify(&mut enc, &store, &xq).unwrap();
            assert_eq!(f.class, g.class, "sound Hamming exit changed the argmin");
            assert!(g.segments_used <= f.segments_used);
            assert!(!f.early_exit);
        }
    }

    #[test]
    fn complexity_saving_math() {
        let r = ProgressiveResult {
            class: 0,
            segments_used: 4,
            dists: vec![],
            margin: 0.0,
            early_exit: true,
        };
        assert!((r.complexity_saving(16) - 0.75).abs() < 1e-12);
    }
}
