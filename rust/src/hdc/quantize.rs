//! INT1-8 quantization (the chip's HDC inference precision modes) —
//! value-identical to `python/compile/kernels/ref.py::quantize`.

/// Max magnitude representable at `bits` (symmetric signed): 2^(bits-1) - 1.
pub fn qmax(bits: u8) -> f32 {
    ((1i32 << (bits - 1)) - 1) as f32
}

/// Quantize one accumulator value to INT`bits` (kept in f32).
/// INT1 is sign (+-1, never 0) — the Hamming/XOR-tree mode.
pub fn quantize(y: f32, bits: u8, scale: f32) -> f32 {
    if bits == 1 {
        return if y >= 0.0 { 1.0 } else { -1.0 };
    }
    let m = qmax(bits);
    (y / scale).round_ties_even().clamp(-m, m)
}

/// Quantize a slice in place.
pub fn quantize_slice(ys: &mut [f32], bits: u8, scale: f32) {
    for y in ys.iter_mut() {
        *y = quantize(*y, bits, scale);
    }
}

/// Feature quantization (f32 -> INT8 values, the HD module's input format).
pub fn quantize_features(x: &[f32], scale_x: f32) -> Vec<f32> {
    x.iter()
        .map(|&v| (v / scale_x).round_ties_even().clamp(-127.0, 127.0))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};

    #[test]
    fn int1_is_sign() {
        assert_eq!(quantize(0.0, 1, 5.0), 1.0);
        assert_eq!(quantize(-0.1, 1, 5.0), -1.0);
        assert_eq!(quantize(123.0, 1, 5.0), 1.0);
    }

    #[test]
    fn int8_clips() {
        assert_eq!(quantize(1e9, 8, 1.0), 127.0);
        assert_eq!(quantize(-1e9, 8, 1.0), -127.0);
    }

    #[test]
    fn scale_divides_before_round() {
        assert_eq!(quantize(10.0, 8, 4.0), 2.0); // 2.5 rounds-to-even -> 2
        assert_eq!(quantize(14.0, 8, 4.0), 4.0); // 3.5 rounds-to-even -> 4
        assert_eq!(quantize(9.0, 8, 4.0), 2.0);
    }

    #[test]
    fn qmax_values() {
        assert_eq!(qmax(8), 127.0);
        assert_eq!(qmax(4), 7.0);
        assert_eq!(qmax(2), 1.0);
    }

    #[test]
    fn prop_quantized_within_range_and_integer() {
        forall(100, 0xBEEF, |rng| {
            let bits = gen::choice(rng, &[2u8, 4, 8]);
            let scale = rng.range_f64(0.5, 100.0) as f32;
            let y = rng.normal_f32() * 500.0;
            let q = quantize(y, bits, scale);
            assert!(q.abs() <= qmax(bits));
            assert_eq!(q.fract(), 0.0);
        });
    }

    #[test]
    fn prop_monotone_in_input() {
        forall(100, 0xCAFE, |rng| {
            let scale = rng.range_f64(0.5, 10.0) as f32;
            let a = rng.normal_f32() * 100.0;
            let b = a + rng.uniform() as f32 * 50.0;
            assert!(quantize(a, 8, scale) <= quantize(b, 8, scale));
        });
    }

    #[test]
    fn features_match_manual() {
        let q = quantize_features(&[1.0, -0.26, 300.0], 0.5);
        assert_eq!(q, vec![2.0, -1.0, 127.0]);
    }

    #[test]
    fn prop_int1_int4_dequantize_roundtrip() {
        // Quantization is a projection: dequantizing (q * scale) and
        // re-quantizing must be a fixed point for both INT1 and INT4.
        forall(100, 0x9A1, |rng| {
            let scale = rng.range_f64(0.5, 50.0) as f32;
            let y = rng.normal_f32() * 300.0;
            for bits in [1u8, 4] {
                let q = quantize(y, bits, scale);
                let rq = quantize(q * scale, bits, scale);
                assert_eq!(q, rq, "bits={bits} y={y} scale={scale}");
            }
        });
    }

    #[test]
    fn prop_int1_int4_values_live_on_the_grid() {
        forall(100, 0x9A2, |rng| {
            let scale = rng.range_f64(0.5, 20.0) as f32;
            let y = rng.normal_f32() * 100.0;
            let q1 = quantize(y, 1, scale);
            assert!(q1 == 1.0 || q1 == -1.0, "INT1 must be ±1, got {q1}");
            let q4 = quantize(y, 4, scale);
            assert!(q4.abs() <= 7.0 && q4.fract() == 0.0, "INT4 grid: {q4}");
        });
    }

    #[test]
    fn prop_quantize_odd_symmetry() {
        // q(-y) == -q(y) for bits > 1 (round-ties-even and clamp are both
        // odd); INT1 is sign-based so the symmetry holds for y != 0.
        forall(100, 0x9A3, |rng| {
            let scale = rng.range_f64(0.5, 20.0) as f32;
            let y = rng.normal_f32() * 150.0;
            for bits in [2u8, 4, 8] {
                assert_eq!(quantize(-y, bits, scale), -quantize(y, bits, scale), "bits={bits}");
            }
            if y != 0.0 {
                assert_eq!(quantize(-y, 1, scale), -quantize(y, 1, scale));
            }
        });
    }
}
