//! Durable learn write-ahead log (WAL).
//!
//! The paper's gradient-free CL keeps all learned knowledge as class
//! hypervectors updated by **commutative bundling** — replaying the learn
//! stream through the same deterministic encoder reconstructs the exact
//! same [`crate::hdc::ChvStore`]. That makes the learn stream itself the
//! natural unit of durability: the executor appends each `(class,
//! features)` sample here **before** applying it, so a `kill -9` at any
//! point loses nothing that was acknowledged. On restart the coordinator
//! restores the last CLOK snapshot and replays the log suffix newer than
//! it; the recovered store is bit-identical to the acknowledged-learn
//! prefix.
//!
//! ## CLOW segment layout (little-endian; full spec in `docs/PROTOCOL.md`)
//!
//! ```text
//! offset 0   magic    b"CLOW"
//!        4   version  u32 (2; v1 segments remain readable)
//!        8   header frame (framed exactly like a record):
//!            [len u32][checksum u64 = FNV-1a over payload]
//!            [payload: model str16, features u32, classes u32, base_seq u64,
//!                      epoch u64 (v2; absent in v1 = epoch 0)]
//! then records, each:
//!            [len u32][checksum u64][payload: seq u64, class u32,
//!                                    n u32, n × f32]
//! ```
//!
//! `base_seq` is the store's `total_learns()` at segment creation: record
//! seqs continue `base_seq + 1, base_seq + 2, …`, and a record's seq equals
//! `total_learns()` *after* it applies. Replay therefore skips records with
//! `seq <= restored total_learns()` — the snapshot already folded them in.
//!
//! ## Torn-tail recovery
//!
//! A crash mid-append leaves a torn final frame: a short header, a short
//! body, or a checksum mismatch. [`Wal::open`] scans the segment record by
//! record, keeps the longest valid prefix, and truncates the file at the
//! first bad frame — a torn tail can only ever hold a learn that was never
//! acknowledged (acks happen after the append's write, and fsync cadence 1,
//! the default, makes the ack strictly after durability). The segment
//! header itself is never torn: creation and rotation stage the fresh
//! segment in `<path>.tmp`, fsync, and rename — the same atomic idiom as
//! [`crate::hdc::knowledge::save`].
//!
//! ## Compaction
//!
//! A successful snapshot to the coordinator's default checkpoint path folds
//! every logged learn into the CLOK file; [`Wal::rotate`] then atomically
//! replaces the segment with a fresh one whose `base_seq` is the snapshot's
//! learn count. The log never grows past one snapshot cadence.

use crate::hdc::knowledge::fnv1a64;
use crate::Result;
use anyhow::{bail, Context};
use std::io::{Seek, Write};
use std::path::{Path, PathBuf};

/// File magic of a WAL segment.
pub const MAGIC: &[u8; 4] = b"CLOW";
/// Current segment format version. v2 adds the promotion `epoch` to the
/// header payload; writers always emit v2.
pub const VERSION: u32 = 2;
/// Oldest segment version still readable (v1 = no epoch field; such
/// segments load with epoch 0).
pub const VERSION_MIN: u32 = 1;
/// Per-frame overhead: the `len: u32` prefix plus the `checksum: u64`.
pub const FRAME_OVERHEAD: usize = 12;
/// Hard cap on one frame's payload — matches the serve wire's frame cap,
/// so any record the log accepts is also streamable to a follower, and a
/// garbage length field in a torn tail cannot drive a huge allocation.
pub const MAX_RECORD: usize = 16 * 1024 * 1024;

/// One logged learn: the raw sample exactly as the executor received it.
/// Replay re-encodes through the same deterministic backend, so applying a
/// record is bit-identical to the original learn.
#[derive(Clone, Debug, PartialEq)]
pub struct WalRecord {
    /// the store's `total_learns()` after this record applies (1-based,
    /// strictly monotonic across segments)
    pub seq: u64,
    /// the sample's class label
    pub class: u32,
    /// the raw feature vector (pre-encode)
    pub features: Vec<f32>,
}

impl WalRecord {
    /// The record payload bytes (everything inside the frame).
    pub fn payload(&self) -> Vec<u8> {
        let mut p = Vec::with_capacity(16 + 4 * self.features.len());
        p.extend_from_slice(&self.seq.to_le_bytes());
        p.extend_from_slice(&self.class.to_le_bytes());
        p.extend_from_slice(&(self.features.len() as u32).to_le_bytes());
        for v in &self.features {
            p.extend_from_slice(&v.to_le_bytes());
        }
        p
    }

    /// Decode a record payload (the checksum has already been verified).
    pub fn from_payload(bytes: &[u8]) -> Result<WalRecord> {
        let mut c = crate::util::Cursor::new(bytes);
        let seq = c.u64()?;
        let class = c.u32()?;
        let n = c.u32()? as usize;
        let features = c.f32s(n)?;
        c.finish()?;
        Ok(WalRecord { seq, class, features })
    }

    /// The full on-disk frame: `[len][checksum][payload]`.
    pub fn frame(&self) -> Vec<u8> {
        frame_bytes(&self.payload())
    }
}

fn frame_bytes(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(FRAME_OVERHEAD + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&fnv1a64(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// The segment identity header: which model and geometry the records
/// belong to, and where the seq numbering resumes. Mirrors the CLOK
/// identity checks — a WAL recorded under one model/geometry must never
/// replay into another.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SegmentHeader {
    /// owning model's registry name ("" = unowned, matches any model)
    pub model: String,
    /// feature count F of the recording config (replay sanity check)
    pub features: u32,
    /// class count of the recording config (replay sanity check)
    pub classes: u32,
    /// the store's `total_learns()` when this segment started; the first
    /// record is `base_seq + 1`
    pub base_seq: u64,
    /// promotion generation: 0 for a segment opened by an original primary,
    /// bumped by one each time a follower is promoted over this log. Stale
    /// primaries are fenced by comparing epochs — a lower-epoch peer must
    /// never feed learns into a higher-epoch store.
    pub epoch: u64,
}

impl SegmentHeader {
    fn payload(&self) -> Vec<u8> {
        let mut p = Vec::new();
        let b = self.model.as_bytes();
        p.extend_from_slice(&(b.len().min(u16::MAX as usize) as u16).to_le_bytes());
        p.extend_from_slice(&b[..b.len().min(u16::MAX as usize)]);
        p.extend_from_slice(&self.features.to_le_bytes());
        p.extend_from_slice(&self.classes.to_le_bytes());
        p.extend_from_slice(&self.base_seq.to_le_bytes());
        p.extend_from_slice(&self.epoch.to_le_bytes());
        p
    }

    fn from_payload(bytes: &[u8], version: u32) -> Result<SegmentHeader> {
        let mut c = crate::util::Cursor::new(bytes);
        let model = c.str16()?;
        let features = c.u32()?;
        let classes = c.u32()?;
        let base_seq = c.u64()?;
        // v1 headers predate promotion: they carry no epoch and load as
        // generation 0 (writers always rewrite v2 on the next rotation)
        let epoch = if version >= 2 { c.u64()? } else { 0 };
        c.finish()?;
        Ok(SegmentHeader { model, features, classes, base_seq, epoch })
    }

    /// The full segment preamble: magic, version, and the framed header.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&frame_bytes(&self.payload()));
        out
    }
}

/// Pop one `[len][checksum][payload]` frame from `bytes[*off..]`.
/// `Ok(None)` = a torn tail starts at `*off` (short header, short body,
/// oversized length, or checksum mismatch — all indistinguishable from a
/// crash mid-write). `Err` = the frame is intact but its payload is
/// malformed, which a torn write cannot produce: real corruption.
fn next_frame<'a>(bytes: &'a [u8], off: &mut usize) -> Result<Option<&'a [u8]>> {
    let rest = &bytes[*off..];
    if rest.len() < FRAME_OVERHEAD {
        return Ok(None);
    }
    let len = u32::from_le_bytes(rest[0..4].try_into().unwrap()) as usize;
    if len > MAX_RECORD || rest.len() < FRAME_OVERHEAD + len {
        return Ok(None);
    }
    let checksum = u64::from_le_bytes(rest[4..12].try_into().unwrap());
    let payload = &rest[FRAME_OVERHEAD..FRAME_OVERHEAD + len];
    if fnv1a64(payload) != checksum {
        return Ok(None);
    }
    *off += FRAME_OVERHEAD + len;
    Ok(Some(payload))
}

/// Stage a fresh segment (preamble only) in `<path>.tmp`, fsync, rename
/// over `path`, fsync the directory entry — and keep the fd, which follows
/// the inode across the rename. A crash anywhere leaves either the old
/// segment or the new one, never a torn header.
fn create_segment(path: &Path, header: &SegmentHeader) -> Result<std::fs::File> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("create WAL dir {}", parent.display()))?;
        }
    }
    let tmp = crate::hdc::knowledge::tmp_path(path);
    let mut f = std::fs::OpenOptions::new()
        .read(true)
        .write(true)
        .create(true)
        .truncate(true)
        .open(&tmp)
        .with_context(|| format!("create {}", tmp.display()))?;
    f.write_all(&header.to_bytes())?;
    f.sync_all().with_context(|| format!("fsync {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename {} -> {}", tmp.display(), path.display()))?;
    #[cfg(unix)]
    {
        let dir = match path.parent() {
            Some(p) if !p.as_os_str().is_empty() => p,
            _ => Path::new("."),
        };
        std::fs::File::open(dir)
            .and_then(|d| d.sync_all())
            .with_context(|| format!("fsync WAL dir {}", dir.display()))?;
    }
    Ok(f)
}

/// An open WAL segment: append-only writer plus the in-memory record tail
/// (what [`crate::coordinator::Payload::WalTail`] serves to followers
/// without touching the disk on the read path).
pub struct Wal {
    path: PathBuf,
    file: std::fs::File,
    header: SegmentHeader,
    records: Vec<WalRecord>,
    /// append records between fsyncs (1 = every append is durable before
    /// it is acknowledged — the default; larger trades the tail of the
    /// cadence for throughput)
    fsync_every: usize,
    unsynced: usize,
    /// file length known fully written; a failed append truncates back to
    /// this so later appends can never strand good records behind a tear
    good_len: u64,
    /// a failed append that could not be rolled back poisons the log
    broken: bool,
}

impl Wal {
    /// Open the segment at `path`, creating it when absent (or empty).
    ///
    /// An existing segment is verified against the caller's identity —
    /// model (empty matches anything, as for CLOK restore), feature count,
    /// class count — its torn tail is truncated on disk, and its valid
    /// records are loaded for replay/serving. `base_seq_if_new` seeds a
    /// freshly created segment (the restored store's `total_learns()`);
    /// it is ignored when the segment already exists.
    pub fn open(
        path: impl AsRef<Path>,
        model: &str,
        features: usize,
        classes: usize,
        base_seq_if_new: u64,
        fsync_every: usize,
    ) -> Result<Wal> {
        let path = path.as_ref();
        let fsync_every = fsync_every.max(1);
        let existing = std::fs::metadata(path).map(|m| m.len() > 0).unwrap_or(false);
        if !existing {
            let header = SegmentHeader {
                model: model.to_string(),
                features: features as u32,
                classes: classes as u32,
                base_seq: base_seq_if_new,
                epoch: 0,
            };
            let file = create_segment(path, &header)?;
            let good_len = header.to_bytes().len() as u64;
            return Ok(Wal {
                path: path.to_path_buf(),
                file,
                header,
                records: Vec::new(),
                fsync_every,
                unsynced: 0,
                good_len,
                broken: false,
            });
        }
        let bytes = std::fs::read(path)
            .with_context(|| format!("read WAL segment {}", path.display()))?;
        if bytes.len() < 8 || &bytes[0..4] != MAGIC {
            bail!("{} is not a CLOW WAL segment (bad magic)", path.display());
        }
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        if !(VERSION_MIN..=VERSION).contains(&version) {
            bail!(
                "unsupported WAL version {version} in {} (readable: \
                 {VERSION_MIN}..={VERSION})",
                path.display()
            );
        }
        let mut off = 8usize;
        // the header frame is written atomically (tmp+fsync+rename): a torn
        // or corrupt header cannot come from a crash mid-append, so it is a
        // hard error rather than a truncation point
        let header = match next_frame(&bytes, &mut off)? {
            Some(p) => SegmentHeader::from_payload(p, version)
                .with_context(|| format!("parse WAL header of {}", path.display()))?,
            None => bail!("WAL segment {} has a corrupt header", path.display()),
        };
        if !header.model.is_empty() && !model.is_empty() && header.model != model {
            bail!(
                "WAL segment {} belongs to model '{}' (this executor serves model '{model}')",
                path.display(),
                header.model
            );
        }
        if header.features as usize != features || header.classes as usize != classes {
            bail!(
                "WAL segment {} was recorded under F={}/classes={} \
                 (serving config has F={features}/classes={classes})",
                path.display(),
                header.features,
                header.classes
            );
        }
        let mut records = Vec::new();
        let mut expect = header.base_seq + 1;
        let good_end = loop {
            let start = off;
            match next_frame(&bytes, &mut off)? {
                None => break start,
                Some(p) => {
                    let rec = WalRecord::from_payload(p).with_context(|| {
                        format!("parse WAL record at offset {start} of {}", path.display())
                    })?;
                    if rec.seq != expect {
                        bail!(
                            "WAL record at offset {start} of {} has seq {} (expected {expect}): \
                             the log is out of order — refusing to replay",
                            path.display(),
                            rec.seq
                        );
                    }
                    expect += 1;
                    records.push(rec);
                }
            }
        };
        let mut file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .with_context(|| format!("open WAL segment {}", path.display()))?;
        if (good_end as u64) < bytes.len() as u64 {
            // torn tail: drop the partial frame so future appends land on a
            // clean boundary
            file.set_len(good_end as u64)
                .with_context(|| format!("truncate torn WAL tail of {}", path.display()))?;
            file.sync_all()?;
        }
        file.seek(std::io::SeekFrom::Start(good_end as u64))?;
        Ok(Wal {
            path: path.to_path_buf(),
            file,
            header,
            records,
            fsync_every,
            unsynced: 0,
            good_len: good_end as u64,
            broken: false,
        })
    }

    /// The segment path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The segment identity header.
    pub fn header(&self) -> &SegmentHeader {
        &self.header
    }

    /// `total_learns()` at segment start; records continue from here.
    pub fn base_seq(&self) -> u64 {
        self.header.base_seq
    }

    /// The segment's promotion generation (0 = original primary lineage).
    pub fn epoch(&self) -> u64 {
        self.header.epoch
    }

    /// Seq of the newest logged record (== `base_seq` when the segment is
    /// empty). This is the monotonic learn sequence number STATS reports.
    pub fn last_seq(&self) -> u64 {
        self.records.last().map_or(self.header.base_seq, |r| r.seq)
    }

    /// The current segment's records, oldest first.
    pub fn records(&self) -> &[WalRecord] {
        &self.records
    }

    /// Append one learn; returns its assigned seq. The record is on disk
    /// (and, per the fsync cadence, durable) before this returns — the
    /// caller applies the learn and acknowledges only afterwards.
    pub fn append(&mut self, class: u32, features: &[f32]) -> Result<u64> {
        self.append_batch(std::slice::from_ref(&(class, features)))
    }

    /// Append a run of learns in one write (one cadence check, at most one
    /// fsync); returns the first assigned seq. All-or-nothing: on a write
    /// error the file is rolled back to the last good boundary and no seq
    /// is consumed.
    pub fn append_batch(&mut self, items: &[(u32, &[f32])]) -> Result<u64> {
        if self.broken {
            bail!("WAL {} is broken by an earlier failed append", self.path.display());
        }
        if items.is_empty() {
            return Ok(self.last_seq());
        }
        let first = self.last_seq() + 1;
        let mut buf = Vec::new();
        let mut pending = Vec::with_capacity(items.len());
        for (i, (class, features)) in items.iter().enumerate() {
            let rec = WalRecord {
                seq: first + i as u64,
                class: *class,
                features: features.to_vec(),
            };
            buf.extend_from_slice(&rec.frame());
            pending.push(rec);
        }
        if let Err(e) = self.file.write_all(&buf) {
            // roll back to the known-good boundary; if even that fails the
            // log can no longer be trusted and every later append refuses
            if self.file.set_len(self.good_len).is_err()
                || self
                    .file
                    .seek(std::io::SeekFrom::Start(self.good_len))
                    .is_err()
            {
                self.broken = true;
            }
            return Err(anyhow::Error::from(e)
                .context(format!("append to WAL {}", self.path.display())));
        }
        self.good_len += buf.len() as u64;
        self.unsynced += items.len();
        if self.unsynced >= self.fsync_every {
            self.sync()?;
        }
        self.records.extend(pending);
        Ok(first)
    }

    /// Drop the newest `n` records from the log (disk and memory) — the
    /// executor's compensation when a validated learn fails *after* its
    /// append: the sample never reached the store, so leaving it logged
    /// would replay an unacknowledged learn on restart. A failed rollback
    /// poisons the log (every later append refuses) rather than risking a
    /// replay/store mismatch.
    pub fn rollback(&mut self, n: usize) -> Result<u64> {
        let keep = self.records.len().saturating_sub(n);
        let dropped: u64 = self.records[keep..]
            .iter()
            .map(|r| (FRAME_OVERHEAD + 16 + 4 * r.features.len()) as u64)
            .sum();
        let target = self.good_len - dropped;
        if let Err(e) = self
            .file
            .set_len(target)
            .and_then(|_| self.file.seek(std::io::SeekFrom::Start(target)).map(|_| ()))
        {
            self.broken = true;
            return Err(anyhow::Error::from(e)
                .context(format!("roll back WAL {}", self.path.display())));
        }
        self.good_len = target;
        self.records.truncate(keep);
        self.unsynced = self.unsynced.min(keep);
        Ok(self.last_seq())
    }

    /// Flush appended records to stable storage now, regardless of cadence.
    pub fn sync(&mut self) -> Result<()> {
        if self.unsynced > 0 {
            self.file
                .sync_data()
                .with_context(|| format!("fsync WAL {}", self.path.display()))?;
            self.unsynced = 0;
        }
        Ok(())
    }

    /// Fold-point: a snapshot holding `base_seq` learns is durable, so the
    /// segment restarts empty from there. Atomic (tmp+fsync+rename): a
    /// crash mid-rotation leaves either the old segment or the new one.
    /// The epoch is preserved — rotation is a compaction, not a promotion.
    pub fn rotate(&mut self, base_seq: u64) -> Result<()> {
        self.rotate_to(base_seq, self.header.epoch)
    }

    /// Promotion seal: replace the segment with a fresh one at `base_seq`
    /// under a new `epoch`. Everything at or before `base_seq` is sealed —
    /// the old segment's records are atomically discarded with the rename,
    /// so no recovery path can ever resurrect a pre-promotion record past
    /// the fold point, torn tail or not. `epoch` must not move backwards
    /// (a lower generation could be mistaken for the fenced old primary).
    pub fn rotate_to(&mut self, base_seq: u64, epoch: u64) -> Result<()> {
        if epoch < self.header.epoch {
            bail!(
                "WAL {} epoch may not move backwards ({} -> {epoch})",
                self.path.display(),
                self.header.epoch
            );
        }
        let header = SegmentHeader { base_seq, epoch, ..self.header.clone() };
        let file = create_segment(&self.path, &header)?;
        self.good_len = header.to_bytes().len() as u64;
        self.file = file;
        self.header = header;
        self.records.clear();
        self.unsynced = 0;
        self.broken = false;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HdConfig;
    use crate::hdc::{HdClassifier, ProgressiveSearch};
    use crate::runtime::NativeBackend;
    use crate::util::prop::forall;
    use crate::util::Rng;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("clo_hdnn_wal_{name}"));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn tiny() -> HdConfig {
        HdConfig::synthetic("t", 8, 8, 32, 32, 8, 4)
    }

    fn classifier(cfg: &HdConfig) -> HdClassifier {
        HdClassifier::new(
            Box::new(NativeBackend::seeded(cfg.clone(), 7, 8).unwrap()),
            ProgressiveSearch { tau: 0.5, min_segments: 1, mode: Default::default() },
        )
    }

    fn sample(rng: &mut Rng, cfg: &HdConfig) -> (u32, Vec<f32>) {
        let class = rng.below(cfg.classes) as u32;
        let x: Vec<f32> = (0..cfg.features()).map(|_| rng.normal_f32() * 40.0).collect();
        (class, x)
    }

    #[test]
    fn fresh_segment_roundtrips_across_reopen() {
        let path = tmp_dir("roundtrip").join("w.clog");
        let _ = std::fs::remove_file(&path);
        let cfg = tiny();
        let mut rng = Rng::new(0xE01);
        let mut wal = Wal::open(&path, "m", cfg.features(), cfg.classes, 0, 1).unwrap();
        assert_eq!(wal.base_seq(), 0);
        assert_eq!(wal.last_seq(), 0);
        let mut expect = Vec::new();
        for i in 0..5u64 {
            let (class, x) = sample(&mut rng, &cfg);
            assert_eq!(wal.append(class, &x).unwrap(), i + 1);
            expect.push(WalRecord { seq: i + 1, class, features: x });
        }
        assert_eq!(wal.records(), expect.as_slice());
        assert_eq!(wal.last_seq(), 5);
        drop(wal);
        let wal = Wal::open(&path, "m", cfg.features(), cfg.classes, 99, 1).unwrap();
        assert_eq!(wal.base_seq(), 0, "base_seq_if_new ignored for existing segments");
        assert_eq!(wal.records(), expect.as_slice());
        assert_eq!(wal.last_seq(), 5);
    }

    #[test]
    fn append_batch_matches_singles_and_continues_after_reopen() {
        let dir = tmp_dir("batch");
        let pa = dir.join("a.clog");
        let pb = dir.join("b.clog");
        let _ = std::fs::remove_file(&pa);
        let _ = std::fs::remove_file(&pb);
        let cfg = tiny();
        let mut rng = Rng::new(0xE02);
        let samples: Vec<(u32, Vec<f32>)> = (0..6).map(|_| sample(&mut rng, &cfg)).collect();
        let mut a = Wal::open(&pa, "", cfg.features(), cfg.classes, 3, 2).unwrap();
        for (c, x) in &samples {
            a.append(*c, x).unwrap();
        }
        let mut b = Wal::open(&pb, "", cfg.features(), cfg.classes, 3, 2).unwrap();
        let items: Vec<(u32, &[f32])> =
            samples.iter().map(|(c, x)| (*c, x.as_slice())).collect();
        assert_eq!(b.append_batch(&items).unwrap(), 4, "first seq after base 3");
        assert_eq!(a.records(), b.records());
        assert_eq!(std::fs::read(&pa).unwrap(), std::fs::read(&pb).unwrap());
        drop(b);
        // seq numbering resumes where the segment left off
        let mut b = Wal::open(&pb, "", cfg.features(), cfg.classes, 0, 1).unwrap();
        let (c, x) = sample(&mut rng, &cfg);
        assert_eq!(b.append(c, &x).unwrap(), 10);
    }

    #[test]
    fn identity_mismatches_are_refused() {
        let path = tmp_dir("identity").join("w.clog");
        let _ = std::fs::remove_file(&path);
        let cfg = tiny();
        let (f, k) = (cfg.features(), cfg.classes);
        drop(Wal::open(&path, "alpha", f, k, 0, 1).unwrap());
        let e = Wal::open(&path, "beta", f, k, 0, 1).unwrap_err().to_string();
        assert!(e.contains("alpha") && e.contains("beta"), "{e}");
        assert!(Wal::open(&path, "alpha", f + 1, k, 0, 1).is_err(), "feature mismatch");
        assert!(Wal::open(&path, "alpha", f, k + 1, 0, 1).is_err(), "class mismatch");
        // an empty caller model matches any stamped model (CLOK semantics)
        assert!(Wal::open(&path, "", f, k, 0, 1).is_ok());
        // garbage file refused outright
        let junk = tmp_dir("identity").join("junk.clog");
        std::fs::write(&junk, b"not a wal").unwrap();
        assert!(Wal::open(&junk, "", f, k, 0, 1).unwrap_err().to_string().contains("magic"));
    }

    #[test]
    fn rotation_starts_an_empty_segment_at_the_fold_point() {
        let path = tmp_dir("rotate").join("w.clog");
        let _ = std::fs::remove_file(&path);
        let cfg = tiny();
        let mut rng = Rng::new(0xE03);
        let mut wal = Wal::open(&path, "m", cfg.features(), cfg.classes, 0, 1).unwrap();
        for _ in 0..3 {
            let (c, x) = sample(&mut rng, &cfg);
            wal.append(c, &x).unwrap();
        }
        wal.rotate(3).unwrap();
        assert_eq!(wal.base_seq(), 3);
        assert_eq!(wal.last_seq(), 3);
        assert!(wal.records().is_empty());
        assert!(
            !crate::hdc::knowledge::tmp_path(&path).exists(),
            "rotation tmp must be renamed away"
        );
        let (c, x) = sample(&mut rng, &cfg);
        assert_eq!(wal.append(c, &x).unwrap(), 4);
        drop(wal);
        let wal = Wal::open(&path, "m", cfg.features(), cfg.classes, 0, 1).unwrap();
        assert_eq!(wal.base_seq(), 3);
        assert_eq!(wal.records().len(), 1);
        assert_eq!(wal.last_seq(), 4);
    }

    #[test]
    fn out_of_order_seq_is_real_corruption_not_a_torn_tail() {
        let path = tmp_dir("order").join("w.clog");
        let _ = std::fs::remove_file(&path);
        let cfg = tiny();
        let mut wal = Wal::open(&path, "", cfg.features(), cfg.classes, 0, 1).unwrap();
        wal.append(0, &vec![0.0; cfg.features()]).unwrap();
        drop(wal);
        // append a frame that skips seq 2 -> 7: checksums fine, order wrong
        let rogue = WalRecord { seq: 7, class: 0, features: vec![0.0; cfg.features()] };
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(&rogue.frame());
        std::fs::write(&path, &bytes).unwrap();
        let e = Wal::open(&path, "", cfg.features(), cfg.classes, 0, 1)
            .unwrap_err()
            .to_string();
        assert!(e.contains("seq"), "{e}");
    }

    /// Satellite: truncate the segment at **every byte boundary** of the
    /// final record; recovery must yield exactly the records of the log
    /// stopped one learn earlier, and replaying the recovered log into a
    /// fresh classifier must land bit-identically on the store that never
    /// saw the final learn (mirrors the CLOK corruption proptests).
    #[test]
    fn prop_torn_tail_recovers_the_previous_learn_boundary() {
        forall(6, 0xE04, |rng| {
            let dir = tmp_dir("torn");
            let path = dir.join("w.clog");
            let _ = std::fs::remove_file(&path);
            let cfg = tiny();
            let n = 2 + rng.below(4);
            let samples: Vec<(u32, Vec<f32>)> =
                (0..n).map(|_| sample(rng, &cfg)).collect();
            let mut wal = Wal::open(&path, "m", cfg.features(), cfg.classes, 0, 1).unwrap();
            let mut len_before_last = 0u64;
            for (i, (c, x)) in samples.iter().enumerate() {
                if i + 1 == n {
                    len_before_last = std::fs::metadata(&path).unwrap().len();
                }
                wal.append(*c, x).unwrap();
            }
            let full = wal.records().to_vec();
            drop(wal);
            let bytes = std::fs::read(&path).unwrap();
            assert!(len_before_last > 0 && (len_before_last as usize) < bytes.len());

            // replay references: all n learns vs the first n-1
            let mut with_last = classifier(&cfg);
            let mut without_last = classifier(&cfg);
            for (i, (c, x)) in samples.iter().enumerate() {
                with_last.learn(x, *c as usize).unwrap();
                if i + 1 < n {
                    without_last.learn(x, *c as usize).unwrap();
                }
            }
            assert_ne!(
                with_last.store.packed(),
                without_last.store.packed(),
                "the final learn must change the store for the assertion to bite"
            );

            let torn = dir.join("torn.clog");
            for cut in (len_before_last as usize)..bytes.len() {
                std::fs::write(&torn, &bytes[..cut]).unwrap();
                let wal =
                    Wal::open(&torn, "m", cfg.features(), cfg.classes, 0, 1).unwrap();
                assert_eq!(
                    wal.records(),
                    &full[..n - 1],
                    "cut at byte {cut} of {}",
                    bytes.len()
                );
                assert_eq!(
                    std::fs::metadata(&torn).unwrap().len(),
                    len_before_last,
                    "the torn tail must be truncated on disk (cut {cut})"
                );
            }
            // one full replay check: the recovered log reconstructs the
            // stopped-one-earlier store bit for bit
            std::fs::write(&torn, &bytes[..bytes.len() - 1]).unwrap();
            let wal = Wal::open(&torn, "m", cfg.features(), cfg.classes, 0, 1).unwrap();
            let mut replayed = classifier(&cfg);
            for r in wal.records() {
                replayed.learn(&r.features, r.class as usize).unwrap();
            }
            assert_eq!(replayed.store.packed(), without_last.store.packed());
            assert_eq!(replayed.store.total_learns(), wal.last_seq());
            for s in 0..cfg.segments {
                assert_eq!(
                    replayed.store.sums_segment(s),
                    without_last.store.sums_segment(s)
                );
            }
        });
    }

    #[test]
    fn checksum_flip_in_the_final_record_truncates_there() {
        let path = tmp_dir("flip").join("w.clog");
        let _ = std::fs::remove_file(&path);
        let cfg = tiny();
        let mut rng = Rng::new(0xE05);
        let mut wal = Wal::open(&path, "", cfg.features(), cfg.classes, 0, 1).unwrap();
        let mut boundary = 0u64;
        for i in 0..3 {
            if i == 2 {
                boundary = std::fs::metadata(&path).unwrap().len();
            }
            let (c, x) = sample(&mut rng, &cfg);
            wal.append(c, &x).unwrap();
        }
        drop(wal);
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40; // flip inside the final record's payload
        std::fs::write(&path, &bytes).unwrap();
        let wal = Wal::open(&path, "", cfg.features(), cfg.classes, 0, 1).unwrap();
        assert_eq!(wal.records().len(), 2);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), boundary);
    }

    #[test]
    fn rollback_drops_the_newest_records_on_disk_and_in_memory() {
        let path = tmp_dir("rollback").join("w.clog");
        let _ = std::fs::remove_file(&path);
        let cfg = tiny();
        let mut rng = Rng::new(0xE06);
        let mut wal = Wal::open(&path, "", cfg.features(), cfg.classes, 0, 1).unwrap();
        let mut boundary = 0u64;
        for i in 0..4 {
            if i == 2 {
                boundary = std::fs::metadata(&path).unwrap().len();
            }
            let (c, x) = sample(&mut rng, &cfg);
            wal.append(c, &x).unwrap();
        }
        assert_eq!(wal.rollback(2).unwrap(), 2);
        assert_eq!(wal.records().len(), 2);
        assert_eq!(wal.last_seq(), 2);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), boundary);
        // seq numbering resumes at the rolled-back boundary, on disk too
        let (c, x) = sample(&mut rng, &cfg);
        assert_eq!(wal.append(c, &x).unwrap(), 3);
        drop(wal);
        let wal = Wal::open(&path, "", cfg.features(), cfg.classes, 0, 1).unwrap();
        assert_eq!(wal.last_seq(), 3);
    }

    #[test]
    fn record_payload_roundtrips_and_rejects_malformed() {
        let rec = WalRecord { seq: 42, class: 3, features: vec![1.5, -2.25, 0.0] };
        let p = rec.payload();
        assert_eq!(WalRecord::from_payload(&p).unwrap(), rec);
        assert!(WalRecord::from_payload(&p[..p.len() - 1]).is_err(), "truncated");
        let mut bad = p.clone();
        bad.push(0);
        assert!(WalRecord::from_payload(&bad).is_err(), "trailing");
        // the frame pins [len][fnv][payload]
        let f = rec.frame();
        assert_eq!(&f[0..4], &(p.len() as u32).to_le_bytes());
        assert_eq!(&f[4..12], &fnv1a64(&p).to_le_bytes());
        assert_eq!(&f[12..], p.as_slice());
    }

    /// Hand-build a v1 segment (no epoch in the header payload) holding
    /// `records`, exactly as a pre-promotion build wrote it.
    fn write_v1_segment(path: &Path, header: &SegmentHeader, records: &[WalRecord]) {
        let mut p = Vec::new();
        let b = header.model.as_bytes();
        p.extend_from_slice(&(b.len() as u16).to_le_bytes());
        p.extend_from_slice(b);
        p.extend_from_slice(&header.features.to_le_bytes());
        p.extend_from_slice(&header.classes.to_le_bytes());
        p.extend_from_slice(&header.base_seq.to_le_bytes());
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&frame_bytes(&p));
        for r in records {
            bytes.extend_from_slice(&r.frame());
        }
        std::fs::write(path, &bytes).unwrap();
    }

    #[test]
    fn v1_segments_remain_readable_as_epoch_zero() {
        let path = tmp_dir("v1compat").join("w.clog");
        let _ = std::fs::remove_file(&path);
        let cfg = tiny();
        let header = SegmentHeader {
            model: "m".into(),
            features: cfg.features() as u32,
            classes: cfg.classes as u32,
            base_seq: 2,
            epoch: 0,
        };
        let recs = vec![
            WalRecord { seq: 3, class: 1, features: vec![0.5; cfg.features()] },
            WalRecord { seq: 4, class: 0, features: vec![-1.0; cfg.features()] },
        ];
        write_v1_segment(&path, &header, &recs);
        let mut wal = Wal::open(&path, "m", cfg.features(), cfg.classes, 0, 1).unwrap();
        assert_eq!(wal.epoch(), 0, "v1 segments load as generation 0");
        assert_eq!(wal.records(), recs.as_slice());
        assert_eq!(wal.base_seq(), 2);
        // appends continue against the v1 file; the next rotation rewrites
        // the segment at the current version
        assert_eq!(wal.append(2, &vec![1.0; cfg.features()]).unwrap(), 5);
        wal.rotate(5).unwrap();
        drop(wal);
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), VERSION);
        // unknown future versions stay refused
        let mut future = bytes.clone();
        future[4..8].copy_from_slice(&(VERSION + 1).to_le_bytes());
        std::fs::write(&path, &future).unwrap();
        let e = Wal::open(&path, "m", cfg.features(), cfg.classes, 0, 1)
            .unwrap_err()
            .to_string();
        assert!(e.contains("unsupported WAL version"), "{e}");
    }

    #[test]
    fn rotation_preserves_the_epoch_and_promotion_bumps_it() {
        let path = tmp_dir("epoch").join("w.clog");
        let _ = std::fs::remove_file(&path);
        let cfg = tiny();
        let mut rng = Rng::new(0xE07);
        let mut wal = Wal::open(&path, "m", cfg.features(), cfg.classes, 0, 1).unwrap();
        assert_eq!(wal.epoch(), 0);
        let (c, x) = sample(&mut rng, &cfg);
        wal.append(c, &x).unwrap();
        // compaction keeps the generation
        wal.rotate(1).unwrap();
        assert_eq!(wal.epoch(), 0);
        // promotion bumps it, durably
        wal.rotate_to(1, 1).unwrap();
        assert_eq!(wal.epoch(), 1);
        drop(wal);
        let mut wal = Wal::open(&path, "m", cfg.features(), cfg.classes, 0, 1).unwrap();
        assert_eq!(wal.epoch(), 1);
        assert_eq!(wal.base_seq(), 1);
        // the generation can never move backwards
        let e = wal.rotate_to(1, 0).unwrap_err().to_string();
        assert!(e.contains("backwards"), "{e}");
        assert_eq!(wal.epoch(), 1);
    }

    /// Satellite: tear a **promoted** follower's log at every byte offset
    /// past the sealed header. Recovery must never resurrect a
    /// pre-promotion record (all of which sit at or before the sealed
    /// `base_seq`), must keep the promoted epoch, and must keep every
    /// surviving record's seq strictly past the seal — the epoch-fencing
    /// analogue of the plain torn-tail proptest above.
    #[test]
    fn prop_promoted_log_torn_anywhere_never_resurrects_sealed_records() {
        forall(6, 0xE08, |rng| {
            let dir = tmp_dir("promote_torn");
            let path = dir.join("w.clog");
            let _ = std::fs::remove_file(&path);
            let cfg = tiny();
            // pre-promotion lineage: epoch 0 records the seal must bury
            let pre = 1 + rng.below(3) as u64;
            let mut wal = Wal::open(&path, "m", cfg.features(), cfg.classes, 0, 1).unwrap();
            for _ in 0..pre {
                let (c, x) = sample(rng, &cfg);
                wal.append(c, &x).unwrap();
            }
            // promotion: seal at the applied position under epoch 1
            wal.rotate_to(pre, 1).unwrap();
            let sealed_len = std::fs::metadata(&path).unwrap().len();
            // post-promotion learns under the new generation
            let post = 1 + rng.below(3) as u64;
            for _ in 0..post {
                let (c, x) = sample(rng, &cfg);
                wal.append(c, &x).unwrap();
            }
            drop(wal);
            let bytes = std::fs::read(&path).unwrap();
            assert!(sealed_len as usize <= bytes.len());
            let torn = dir.join("torn.clog");
            for cut in (sealed_len as usize)..=bytes.len() {
                std::fs::write(&torn, &bytes[..cut]).unwrap();
                let wal =
                    Wal::open(&torn, "m", cfg.features(), cfg.classes, 0, 1).unwrap();
                assert_eq!(wal.epoch(), 1, "cut {cut}: promoted epoch must survive");
                assert_eq!(wal.base_seq(), pre, "cut {cut}: seal point must survive");
                for r in wal.records() {
                    assert!(
                        r.seq > pre,
                        "cut {cut}: recovery resurrected sealed record seq {} \
                         (seal is {pre})",
                        r.seq
                    );
                }
                // the recovered suffix is exactly a prefix of the
                // post-promotion appends: nothing reordered, nothing invented
                assert!(wal.records().len() as u64 <= post);
                assert_eq!(wal.last_seq(), pre + wal.records().len() as u64);
            }
        });
    }
}
