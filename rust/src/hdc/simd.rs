//! Runtime-dispatched SIMD kernels for the two HDC hot paths.
//!
//! The chip wins its energy numbers by keeping the hot loops trivially
//! parallel: XOR+popcount Hamming distance over bit-packed hypervectors
//! (classifier search) and the sign-applied accumulations of the Kronecker
//! sign-GEMM (encode). This module gives the software reproduction the same
//! property on commodity CPUs: one feature detection at first use picks the
//! widest instruction set the machine offers, and every wide kernel is
//! **bit-identical** to the scalar fallback.
//!
//! Why bit-identity is achievable at all:
//!
//! * Hamming distances are integer popcount sums — addition over the naturals
//!   is associative, so any lane order produces the same count.
//! * The sign-GEMM never multiplies: applying a ±1 weight is an IEEE sign-bit
//!   XOR (exact), and the SIMD layouts vectorize *across independent
//!   accumulation chains* (stage1: output columns; stage2: output rows), never
//!   *within* one chain. Each scalar f32 accumulator therefore sees exactly
//!   the same additions in exactly the same order as the scalar kernel.
//!
//! Dispatch is resolved once per process from [`detect`] plus the
//! [`SIMD_ENV`] (`CLO_HDNN_SIMD`) override, threaded exactly like
//! `CLO_HDNN_THREADS`:
//!
//! * unset / `auto` / empty — use the widest detected level;
//! * `off` / `scalar` — force the scalar reference kernels;
//! * `avx2`, `avx512`, `neon` — force a named level; if the CPU lacks it,
//!   warn on stderr and fall back to the detected level.
//!
//! The `unsafe` boundary is confined to this module: every `#[target_feature]`
//! kernel is only reachable through a dispatcher that re-checks availability,
//! so calling the safe entry points is sound on any CPU.

use std::sync::OnceLock;

/// Environment variable overriding kernel dispatch (`off|scalar|auto|avx2|avx512|neon`).
pub const SIMD_ENV: &str = "CLO_HDNN_SIMD";

/// An instruction-set level the hot-path kernels can dispatch to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable scalar reference kernels (always available, the bit-identity oracle).
    Scalar,
    /// x86_64 AVX2: 256-bit XOR + nibble-LUT popcount, 8-lane f32 sign-apply.
    Avx2,
    /// x86_64 AVX-512F + VPOPCNTDQ: 512-bit XOR + hardware 64-bit popcount.
    Avx512,
    /// aarch64 NEON: 128-bit XOR + `vcnt` byte popcount, 4-lane f32 sign-apply.
    Neon,
}

impl SimdLevel {
    /// Stable lowercase name, as reported in BENCH_*.json (`"kernel": "avx2"`).
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Avx512 => "avx512",
            SimdLevel::Neon => "neon",
        }
    }
}

/// Can `level`'s kernels run on a machine whose detected best level is
/// `detected`? (AVX-512 machines can run the AVX2 kernels; nothing runs a
/// foreign architecture's kernels.)
fn is_available(level: SimdLevel, detected: SimdLevel) -> bool {
    matches!(
        (level, detected),
        (SimdLevel::Scalar, _)
            | (SimdLevel::Avx2, SimdLevel::Avx2 | SimdLevel::Avx512)
            | (SimdLevel::Avx512, SimdLevel::Avx512)
            | (SimdLevel::Neon, SimdLevel::Neon)
    )
}

/// Detect the widest level this CPU supports. AVX-512 is only claimed when
/// both `avx512f` and `avx512vpopcntdq` are present (the Hamming kernel needs
/// the hardware popcount); aarch64 baselines NEON.
#[allow(unreachable_code)]
pub fn detect() -> SimdLevel {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx512f") && is_x86_feature_detected!("avx512vpopcntdq") {
            return SimdLevel::Avx512;
        }
        if is_x86_feature_detected!("avx2") {
            return SimdLevel::Avx2;
        }
        return SimdLevel::Scalar;
    }
    #[cfg(target_arch = "aarch64")]
    {
        return SimdLevel::Neon;
    }
    SimdLevel::Scalar
}

/// Resolve an override string against a detected level. Pure (no environment
/// reads) so the spelling table is unit-testable; warnings go to stderr, the
/// return value is always a level that [`is_available`] approves.
pub fn resolve(value: Option<&str>, detected: SimdLevel) -> SimdLevel {
    let spelled = match value {
        None => return detected,
        Some(v) => v.trim().to_ascii_lowercase(),
    };
    let forced = match spelled.as_str() {
        "" | "auto" => return detected,
        "off" | "scalar" | "none" => return SimdLevel::Scalar,
        "avx2" => SimdLevel::Avx2,
        "avx512" | "avx-512" => SimdLevel::Avx512,
        "neon" => SimdLevel::Neon,
        other => {
            eprintln!(
                "clo_hdnn: unrecognized {SIMD_ENV}='{other}' (want off|scalar|auto|avx2|avx512|neon); using detected '{}'",
                detected.name()
            );
            return detected;
        }
    };
    if is_available(forced, detected) {
        forced
    } else {
        eprintln!(
            "clo_hdnn: {SIMD_ENV}='{}' not supported on this CPU (detected '{}'); using detected level",
            forced.name(),
            detected.name()
        );
        detected
    }
}

/// The process-wide dispatched level: `detect()` filtered through the
/// [`SIMD_ENV`] override, resolved once and cached.
pub fn active() -> SimdLevel {
    static ACTIVE: OnceLock<SimdLevel> = OnceLock::new();
    *ACTIVE.get_or_init(|| resolve(std::env::var(SIMD_ENV).ok().as_deref(), detect()))
}

// ---------------------------------------------------------------------------
// Kernel 1: XOR + popcount over packed u64 words (Hamming distance).
// ---------------------------------------------------------------------------

/// Popcount of `a XOR b` over equal-length packed words. Integer sum, so the
/// result is identical at every level by associativity.
pub fn xor_popcount(level: SimdLevel, a: &[u64], b: &[u64]) -> u64 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    {
        if level == SimdLevel::Avx512 && avx512_ok() {
            return unsafe { xor_popcount_avx512(a, b) };
        }
        if level != SimdLevel::Scalar && is_x86_feature_detected!("avx2") {
            return unsafe { xor_popcount_avx2(a, b) };
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if level == SimdLevel::Neon {
            return unsafe { xor_popcount_neon(a, b) };
        }
    }
    let _ = level;
    xor_popcount_scalar(a, b)
}

/// Both AVX-512 features the Hamming kernel needs are present.
#[cfg(target_arch = "x86_64")]
fn avx512_ok() -> bool {
    is_x86_feature_detected!("avx512f") && is_x86_feature_detected!("avx512vpopcntdq")
}

fn xor_popcount_scalar(a: &[u64], b: &[u64]) -> u64 {
    a.iter().zip(b).map(|(&x, &y)| (x ^ y).count_ones() as u64).sum()
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn xor_popcount_avx2(a: &[u64], b: &[u64]) -> u64 {
    use std::arch::x86_64::*;
    let n = a.len();
    // Mula's nibble-LUT popcount: per-byte counts via two shuffles, then
    // horizontal byte sums into the four u64 lanes with SAD against zero.
    #[rustfmt::skip]
    let lut = _mm256_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
    );
    let low_mask = _mm256_set1_epi8(0x0f);
    let mut acc = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 4 <= n {
        let va = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
        let vb = _mm256_loadu_si256(b.as_ptr().add(i) as *const __m256i);
        let x = _mm256_xor_si256(va, vb);
        let lo = _mm256_and_si256(x, low_mask);
        let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(x), low_mask);
        let cnt = _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
        acc = _mm256_add_epi64(acc, _mm256_sad_epu8(cnt, _mm256_setzero_si256()));
        i += 4;
    }
    let mut lanes = [0u64; 4];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
    let mut total = lanes[0] + lanes[1] + lanes[2] + lanes[3];
    while i < n {
        total += (a[i] ^ b[i]).count_ones() as u64;
        i += 1;
    }
    total
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vpopcntdq")]
unsafe fn xor_popcount_avx512(a: &[u64], b: &[u64]) -> u64 {
    use std::arch::x86_64::*;
    let n = a.len();
    let mut acc = _mm512_setzero_si512();
    let mut i = 0usize;
    while i + 8 <= n {
        let va = _mm512_loadu_si512(a.as_ptr().add(i) as *const _);
        let vb = _mm512_loadu_si512(b.as_ptr().add(i) as *const _);
        acc = _mm512_add_epi64(acc, _mm512_popcnt_epi64(_mm512_xor_si512(va, vb)));
        i += 8;
    }
    let mut lanes = [0u64; 8];
    _mm512_storeu_si512(lanes.as_mut_ptr() as *mut _, acc);
    let mut total: u64 = lanes.iter().sum();
    while i < n {
        total += (a[i] ^ b[i]).count_ones() as u64;
        i += 1;
    }
    total
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn xor_popcount_neon(a: &[u64], b: &[u64]) -> u64 {
    use std::arch::aarch64::*;
    let n = a.len();
    let mut total: u64 = 0;
    let mut i = 0usize;
    while i + 2 <= n {
        let va = vld1q_u64(a.as_ptr().add(i));
        let vb = vld1q_u64(b.as_ptr().add(i));
        let cnt = vcntq_u8(vreinterpretq_u8_u64(veorq_u64(va, vb)));
        total += vaddlvq_u8(cnt) as u64;
        i += 2;
    }
    while i < n {
        total += (a[i] ^ b[i]).count_ones() as u64;
        i += 1;
    }
    total
}

// ---------------------------------------------------------------------------
// Kernel 2: sign-applied accumulate, dst[i] += ±src[i] (sign-GEMM stage1).
// ---------------------------------------------------------------------------

/// `dst[i] += sign_apply(src[i])` where the sign is `mask` (0 keeps the value,
/// `1 << 31` flips it). Lanes are independent accumulation chains, and sign
/// application is an exact IEEE sign-bit XOR, so every level is bit-identical.
pub fn add_signed(level: SimdLevel, dst: &mut [f32], src: &[f32], mask: u32) {
    debug_assert_eq!(dst.len(), src.len());
    #[cfg(target_arch = "x86_64")]
    {
        if level == SimdLevel::Avx512 && is_x86_feature_detected!("avx512f") {
            return unsafe { add_signed_avx512(dst, src, mask) };
        }
        if level != SimdLevel::Scalar && is_x86_feature_detected!("avx2") {
            return unsafe { add_signed_avx2(dst, src, mask) };
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if level == SimdLevel::Neon {
            return unsafe { add_signed_neon(dst, src, mask) };
        }
    }
    let _ = level;
    add_signed_scalar(dst, src, mask)
}

fn add_signed_scalar(dst: &mut [f32], src: &[f32], mask: u32) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += f32::from_bits(s.to_bits() ^ mask);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn add_signed_avx2(dst: &mut [f32], src: &[f32], mask: u32) {
    use std::arch::x86_64::*;
    let n = dst.len();
    let vm = _mm256_castsi256_ps(_mm256_set1_epi32(mask as i32));
    let mut i = 0usize;
    while i + 8 <= n {
        let vs = _mm256_loadu_ps(src.as_ptr().add(i));
        let vd = _mm256_loadu_ps(dst.as_ptr().add(i));
        _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_add_ps(vd, _mm256_xor_ps(vs, vm)));
        i += 8;
    }
    add_signed_scalar(&mut dst[i..], &src[i..], mask);
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn add_signed_avx512(dst: &mut [f32], src: &[f32], mask: u32) {
    use std::arch::x86_64::*;
    let n = dst.len();
    let vm = _mm512_castsi512_ps(_mm512_set1_epi32(mask as i32));
    let mut i = 0usize;
    while i + 16 <= n {
        let vs = _mm512_loadu_ps(src.as_ptr().add(i));
        let vd = _mm512_loadu_ps(dst.as_ptr().add(i));
        _mm512_storeu_ps(dst.as_mut_ptr().add(i), _mm512_add_ps(vd, _mm512_xor_ps(vs, vm)));
        i += 16;
    }
    add_signed_scalar(&mut dst[i..], &src[i..], mask);
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn add_signed_neon(dst: &mut [f32], src: &[f32], mask: u32) {
    use std::arch::aarch64::*;
    let n = dst.len();
    let vm = vdupq_n_u32(mask);
    let mut i = 0usize;
    while i + 4 <= n {
        let vs = vld1q_f32(src.as_ptr().add(i));
        let vd = vld1q_f32(dst.as_ptr().add(i));
        let signed = vreinterpretq_f32_u32(veorq_u32(vreinterpretq_u32_f32(vs), vm));
        vst1q_f32(dst.as_mut_ptr().add(i), vaddq_f32(vd, signed));
        i += 4;
    }
    add_signed_scalar(&mut dst[i..], &src[i..], mask);
}

// ---------------------------------------------------------------------------
// Kernel 3: eight sign-dot-products sharing one dense row (stage2 block).
// ---------------------------------------------------------------------------

/// For eight packed ±1 rows, accumulate `acc[k] += Σ_j ±trow[j]` with the sign
/// taken from bit `j` of `rows[k]` (bit set ⇔ +1). Each `acc[k]` is one
/// scalar accumulation chain over `j` ascending — the SIMD layouts vectorize
/// across `k`, so every lane replays the scalar chain exactly.
pub fn dot8_signed(level: SimdLevel, trow: &[f32], rows: &[&[u64]; 8], acc: &mut [f32; 8]) {
    #[cfg(target_arch = "x86_64")]
    {
        // 8 lanes is a natural AVX2 shape; the AVX-512 level reuses it
        // (256-bit ops avoid frequency downclocking on short stage2 rows).
        if level != SimdLevel::Scalar && is_x86_feature_detected!("avx2") {
            return unsafe { dot8_signed_avx2(trow, rows, acc) };
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if level == SimdLevel::Neon {
            return unsafe { dot8_signed_neon(trow, rows, acc) };
        }
    }
    let _ = level;
    dot8_signed_scalar(trow, rows, acc)
}

/// Sign mask for element `j` of a packed ±1 row: `0` when the bit is set
/// (+1), `1 << 31` when clear (-1). Mirrors `signmat::sign_mask`.
#[inline(always)]
fn row_sign_mask(row: &[u64], j: usize) -> u32 {
    ((((row[j / 64] >> (j % 64)) & 1) as u32) ^ 1) << 31
}

fn dot8_signed_scalar(trow: &[f32], rows: &[&[u64]; 8], acc: &mut [f32; 8]) {
    for (j, &tv) in trow.iter().enumerate() {
        let bits = tv.to_bits();
        for (k, row) in rows.iter().enumerate() {
            acc[k] += f32::from_bits(bits ^ row_sign_mask(row, j));
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot8_signed_avx2(trow: &[f32], rows: &[&[u64]; 8], acc: &mut [f32; 8]) {
    use std::arch::x86_64::*;
    let mut vacc = _mm256_loadu_ps(acc.as_ptr());
    let mut masks = [0u32; 8];
    for (j, &tv) in trow.iter().enumerate() {
        for (k, row) in rows.iter().enumerate() {
            masks[k] = row_sign_mask(row, j);
        }
        let vm = _mm256_loadu_si256(masks.as_ptr() as *const __m256i);
        let signed = _mm256_xor_ps(_mm256_castsi256_ps(vm), _mm256_set1_ps(tv));
        vacc = _mm256_add_ps(vacc, signed);
    }
    _mm256_storeu_ps(acc.as_mut_ptr(), vacc);
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dot8_signed_neon(trow: &[f32], rows: &[&[u64]; 8], acc: &mut [f32; 8]) {
    use std::arch::aarch64::*;
    let mut lo = vld1q_f32(acc.as_ptr());
    let mut hi = vld1q_f32(acc.as_ptr().add(4));
    let mut masks = [0u32; 8];
    for (j, &tv) in trow.iter().enumerate() {
        for (k, row) in rows.iter().enumerate() {
            masks[k] = row_sign_mask(row, j);
        }
        let vt = vdupq_n_f32(tv);
        let tb = vreinterpretq_u32_f32(vt);
        let slo = vreinterpretq_f32_u32(veorq_u32(tb, vld1q_u32(masks.as_ptr())));
        let shi = vreinterpretq_f32_u32(veorq_u32(tb, vld1q_u32(masks.as_ptr().add(4))));
        lo = vaddq_f32(lo, slo);
        hi = vaddq_f32(hi, shi);
    }
    vst1q_f32(acc.as_mut_ptr(), lo);
    vst1q_f32(acc.as_mut_ptr().add(4), hi);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn resolve_spellings() {
        let d = SimdLevel::Avx512;
        assert_eq!(resolve(None, d), d);
        assert_eq!(resolve(Some(""), d), d);
        assert_eq!(resolve(Some("auto"), d), d);
        assert_eq!(resolve(Some(" AUTO "), d), d);
        assert_eq!(resolve(Some("off"), d), SimdLevel::Scalar);
        assert_eq!(resolve(Some("scalar"), d), SimdLevel::Scalar);
        assert_eq!(resolve(Some("none"), d), SimdLevel::Scalar);
        assert_eq!(resolve(Some("avx2"), d), SimdLevel::Avx2);
        assert_eq!(resolve(Some("AVX512"), d), SimdLevel::Avx512);
        assert_eq!(resolve(Some("avx-512"), d), SimdLevel::Avx512);
        // a forced level the CPU lacks falls back to detected, with a warning
        assert_eq!(resolve(Some("neon"), d), d);
        assert_eq!(resolve(Some("avx512"), SimdLevel::Avx2), SimdLevel::Avx2);
        assert_eq!(resolve(Some("avx2"), SimdLevel::Neon), SimdLevel::Neon);
        // unknown spellings keep the detected level
        assert_eq!(resolve(Some("sse9"), d), d);
        // forcing scalar is always honored
        assert_eq!(resolve(Some("off"), SimdLevel::Scalar), SimdLevel::Scalar);
    }

    #[test]
    fn availability_lattice() {
        use SimdLevel::*;
        for d in [Scalar, Avx2, Avx512, Neon] {
            assert!(is_available(Scalar, d));
        }
        assert!(is_available(Avx2, Avx512));
        assert!(!is_available(Avx512, Avx2));
        assert!(!is_available(Neon, Avx512));
        assert!(!is_available(Avx2, Neon));
    }

    /// Every level the host actually supports must agree with scalar, bit for
    /// bit, across ragged lengths that exercise both vector body and tail.
    fn host_levels() -> Vec<SimdLevel> {
        vec![SimdLevel::Scalar, detect()]
    }

    #[test]
    fn xor_popcount_matches_scalar_on_host() {
        let mut rng = Rng::new(0x51AD);
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 33, 64, 129] {
            let a: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let b: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let want = xor_popcount_scalar(&a, &b);
            for lvl in host_levels() {
                assert_eq!(xor_popcount(lvl, &a, &b), want, "level {:?} n {}", lvl, n);
            }
        }
    }

    #[test]
    fn add_signed_matches_scalar_on_host() {
        let mut rng = Rng::new(0xADD5);
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 32, 100] {
            let src: Vec<f32> = (0..n).map(|_| rng.uniform() as f32 - 0.5).collect();
            let base: Vec<f32> = (0..n).map(|_| rng.uniform() as f32).collect();
            for mask in [0u32, 1 << 31] {
                let mut want = base.clone();
                add_signed_scalar(&mut want, &src, mask);
                for lvl in host_levels() {
                    let mut got = base.clone();
                    add_signed(lvl, &mut got, &src, mask);
                    assert_eq!(
                        got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        "level {:?} n {} mask {:#x}",
                        lvl,
                        n,
                        mask
                    );
                }
            }
        }
    }

    #[test]
    fn dot8_signed_matches_scalar_on_host() {
        let mut rng = Rng::new(0xD078);
        for cols in [1usize, 5, 31, 64, 65, 200] {
            let words = cols.div_ceil(64);
            let planes: Vec<Vec<u64>> =
                (0..8).map(|_| (0..words).map(|_| rng.next_u64()).collect()).collect();
            let rows: [&[u64]; 8] = std::array::from_fn(|k| planes[k].as_slice());
            let trow: Vec<f32> = (0..cols).map(|_| rng.uniform() as f32 - 0.5).collect();
            let base = [0.1f32, -0.2, 0.3, 0.0, 1.5, -2.5, 0.25, 4.0];
            let mut want = base;
            dot8_signed_scalar(&trow, &rows, &mut want);
            for lvl in host_levels() {
                let mut got = base;
                dot8_signed(lvl, &trow, &rows, &mut got);
                assert_eq!(
                    got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "level {:?} cols {}",
                    lvl,
                    cols
                );
            }
        }
    }

    #[test]
    fn active_is_available_on_host() {
        assert!(is_available(active(), detect()));
        assert!(!active().name().is_empty());
    }
}
