//! [`HdClassifier`]: the user-facing HD module — quantization, encoding,
//! progressive/full search, and gradient-free updates behind one API.

use crate::config::HdConfig;
use crate::hdc::chv::ChvStore;
use crate::hdc::progressive::{ProgressiveResult, ProgressiveSearch};
use crate::hdc::quantize::quantize_features;
use crate::hdc::HdBackend;
use crate::Result;

pub struct HdClassifier {
    backend: Box<dyn HdBackend>,
    pub store: ChvStore,
    pub policy: ProgressiveSearch,
    cfg: HdConfig,
}

impl HdClassifier {
    pub fn new(backend: Box<dyn HdBackend>, policy: ProgressiveSearch) -> HdClassifier {
        let cfg = backend.cfg().clone();
        HdClassifier {
            store: ChvStore::new(cfg.clone()),
            backend,
            policy,
            cfg,
        }
    }

    pub fn cfg(&self) -> &HdConfig {
        &self.cfg
    }

    /// Quantize raw features into the HD module's INT8 input format.
    pub fn quantize(&self, x: &[f32]) -> Vec<f32> {
        quantize_features(x, self.cfg.scale_x)
    }

    /// Encode a full QHV from raw features.
    pub fn encode(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        let xq = self.quantize(x);
        self.backend.encode_full(&xq, 1)
    }

    /// Progressive classification from raw features.
    pub fn classify(&mut self, x: &[f32]) -> Result<ProgressiveResult> {
        let xq = self.quantize(x);
        self.policy.classify(self.backend.as_mut(), &self.store, &xq)
    }

    /// Full (exhaustive) classification from raw features.
    pub fn classify_full(&mut self, x: &[f32]) -> Result<ProgressiveResult> {
        let xq = self.quantize(x);
        ProgressiveSearch::classify_full(self.backend.as_mut(), &self.store, &xq)
    }

    /// Single-pass learn: bundle the sample's QHV into its class CHV.
    pub fn learn(&mut self, x: &[f32], class: usize) -> Result<()> {
        let q = self.encode(x)?;
        self.store.update(class, &q, 1.0)
    }

    /// Batched single-pass learn: ONE backend encode call for all samples
    /// (the b8 dispatch amortization; on the native backend the rows also
    /// shard over its worker pool), then per-class bundling in sample
    /// order. Bit-identical to calling [`HdClassifier::learn`] per sample —
    /// batched encodes are pinned equal to per-sample encodes.
    pub fn learn_batch(&mut self, samples: &[(&[f32], usize)]) -> Result<()> {
        if samples.is_empty() {
            return Ok(());
        }
        let (feat, dim) = (self.cfg.features(), self.cfg.dim());
        let mut xq = Vec::with_capacity(samples.len() * feat);
        for (x, _) in samples {
            xq.extend(quantize_features(x, self.cfg.scale_x));
        }
        let qhvs = self.backend.encode_full(&xq, samples.len())?;
        for (n, (_, class)) in samples.iter().enumerate() {
            self.store.update(*class, &qhvs[n * dim..(n + 1) * dim], 1.0)?;
        }
        Ok(())
    }

    /// Retrain step (mistake-driven): full-classify; on error add to the
    /// true class and subtract from the mispredicted one. Returns whether
    /// the prediction was correct.
    pub fn retrain_step(&mut self, x: &[f32], class: usize) -> Result<bool> {
        let r = self.classify_full(x)?;
        if r.class == class {
            return Ok(true);
        }
        let q = self.encode(x)?;
        self.store.update(class, &q, 1.0)?;
        self.store.update(r.class, &q, -1.0)?;
        Ok(false)
    }

    /// Accuracy over (x, y) pairs using progressive search; also returns the
    /// mean fraction of segments used (the Fig.4 complexity metric).
    pub fn evaluate(
        &mut self,
        samples: impl Iterator<Item = (Vec<f32>, usize)>,
    ) -> Result<EvalReport> {
        let mut n = 0usize;
        let mut correct = 0usize;
        let mut seg_used = 0usize;
        let mut early = 0usize;
        for (x, y) in samples {
            let r = self.classify(&x)?;
            n += 1;
            correct += usize::from(r.class == y);
            seg_used += r.segments_used;
            early += usize::from(r.early_exit);
        }
        Ok(EvalReport {
            n,
            accuracy: correct as f64 / n.max(1) as f64,
            mean_segments: seg_used as f64 / n.max(1) as f64,
            early_exit_rate: early as f64 / n.max(1) as f64,
            total_segments: self.cfg.segments,
        })
    }

    pub fn backend_mut(&mut self) -> &mut dyn HdBackend {
        self.backend.as_mut()
    }
}

/// Evaluation summary (accuracy + progressive-search complexity).
#[derive(Clone, Debug)]
pub struct EvalReport {
    pub n: usize,
    pub accuracy: f64,
    pub mean_segments: f64,
    pub early_exit_rate: f64,
    pub total_segments: usize,
}

impl EvalReport {
    /// Fraction of encode+search complexity saved vs full search (Fig.4's
    /// "up to 61%").
    pub fn complexity_reduction(&self) -> f64 {
        1.0 - self.mean_segments / self.total_segments as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdc::encoder::SoftwareEncoder;
    use crate::util::Rng;

    fn classifier(tau: f32) -> HdClassifier {
        let cfg = HdConfig::synthetic("t", 8, 8, 32, 32, 8, 5);
        let enc = SoftwareEncoder::random(cfg, 21);
        HdClassifier::new(
            Box::new(enc),
            ProgressiveSearch { tau, min_segments: 1, ..Default::default() },
        )
    }

    fn protos(cl: &HdClassifier, n: usize) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(5);
        (0..n)
            .map(|_| (0..cl.cfg().features()).map(|_| rng.normal_f32() * 30.0).collect())
            .collect()
    }

    #[test]
    fn learn_then_classify_recovers_classes() {
        let mut cl = classifier(0.4);
        let ps = protos(&cl, 5);
        let mut rng = Rng::new(6);
        for (c, p) in ps.iter().enumerate() {
            for _ in 0..4 {
                let noisy: Vec<f32> = p.iter().map(|&v| v + rng.normal_f32() * 3.0).collect();
                cl.learn(&noisy, c).unwrap();
            }
        }
        for (c, p) in ps.iter().enumerate() {
            assert_eq!(cl.classify(p).unwrap().class, c);
        }
    }

    #[test]
    fn packed_mode_learn_then_classify_recovers_classes() {
        // the paper's precision split: bundle in INT8, search the binarized
        // AM through the XOR-tree path
        let mut cl = classifier(0.4);
        cl.policy.mode = crate::hdc::SearchMode::HammingPacked;
        let ps = protos(&cl, 5);
        let mut rng = Rng::new(8);
        for (c, p) in ps.iter().enumerate() {
            for _ in 0..4 {
                let noisy: Vec<f32> = p.iter().map(|&v| v + rng.normal_f32() * 3.0).collect();
                cl.learn(&noisy, c).unwrap();
            }
        }
        for (c, p) in ps.iter().enumerate() {
            assert_eq!(cl.classify(p).unwrap().class, c, "packed mode, class {c}");
        }
    }

    #[test]
    fn learn_batch_is_bit_identical_to_sequential_learn() {
        let mut seq = classifier(0.4);
        let mut bat = classifier(0.4);
        let ps = protos(&seq, 4);
        let mut rng = Rng::new(9);
        let mut samples: Vec<(Vec<f32>, usize)> = Vec::new();
        for (c, p) in ps.iter().enumerate() {
            for _ in 0..3 {
                let noisy: Vec<f32> = p.iter().map(|&v| v + rng.normal_f32() * 3.0).collect();
                samples.push((noisy, c));
            }
        }
        for (x, c) in &samples {
            seq.learn(x, *c).unwrap();
        }
        let refs: Vec<(&[f32], usize)> =
            samples.iter().map(|(x, c)| (x.as_slice(), *c)).collect();
        bat.learn_batch(&refs).unwrap();
        for c in 0..4 {
            assert_eq!(seq.store.class_hv(c), bat.store.class_hv(c), "class {c}");
            assert_eq!(seq.store.count(c), bat.store.count(c));
        }
        // empty batch is a no-op
        bat.learn_batch(&[]).unwrap();
        assert_eq!(seq.store.class_hv(0), bat.store.class_hv(0));
    }

    #[test]
    fn retrain_improves_or_keeps_training_accuracy() {
        let mut cl = classifier(0.4);
        let ps = protos(&cl, 5);
        let mut rng = Rng::new(7);
        let mut samples = Vec::new();
        for (c, p) in ps.iter().enumerate() {
            for _ in 0..6 {
                let noisy: Vec<f32> =
                    p.iter().map(|&v| v + rng.normal_f32() * 25.0).collect();
                samples.push((noisy, c));
            }
        }
        for (x, y) in &samples {
            cl.learn(x, *y).unwrap();
        }
        let acc_before = {
            let r = cl
                .evaluate(samples.iter().cloned())
                .unwrap();
            r.accuracy
        };
        for _ in 0..3 {
            for (x, y) in &samples {
                cl.retrain_step(x, *y).unwrap();
            }
        }
        let acc_after = cl.evaluate(samples.iter().cloned()).unwrap().accuracy;
        assert!(
            acc_after >= acc_before - 1e-9,
            "retraining regressed: {acc_before} -> {acc_after}"
        );
    }

    #[test]
    fn eval_report_complexity() {
        let r = EvalReport {
            n: 10,
            accuracy: 1.0,
            mean_segments: 4.0,
            early_exit_rate: 1.0,
            total_segments: 8,
        };
        assert!((r.complexity_reduction() - 0.5).abs() < 1e-12);
    }
}
