//! CHV associative-memory cache model (Fig.6).
//!
//! Class hypervectors are stored **per progressive-search segment** — the
//! layout the chip uses so that "only partial CHVs need to be stored in the
//! cache": segment s holds a (classes x seg_len) row-major block. Early
//! termination after k segments means segments k..S were never fetched;
//! [`ChvStore::bytes_resident`] quantifies the cache-capacity story against
//! the chip's 32 KB HDC SRAM.
//!
//! Bundling semantics: the store keeps a training-time accumulator per class
//! and serves a **count-normalized INT8 view** (clip(round(sum / count))) —
//! the INT8-feasible equivalent of mean bundling (OnlineHD-style; on the
//! chip this is the Training module's per-class shift/renormalization).
//! Naive saturating accumulation (the raw Fig.6 add/sub the `train_update`
//! HLO artifact implements — see [`raw_update`]) pins 80%+ of elements at
//! +-127 after a few dozen samples and destroys class information; the
//! normalized view is what search reads.
//!
//! Alongside the INT8 view the store maintains its **binarized image** — a
//! [`PackedChvStore`] refreshed on every write (bundle in INT8, binarize on
//! write), which is what [`SearchMode::HammingPacked`]
//! (`crate::hdc::SearchMode`) searches through the XOR+popcount path.

use crate::config::HdConfig;
use crate::hdc::packed::PackedChvStore;
use crate::Result;
use anyhow::bail;

/// The raw chip-level CHV update (Fig.6 step 3, == the `train_update` HLO
/// artifact): chvs += coef (outer) qhv, saturating at INT8.
pub fn raw_update(chvs: &mut [f32], qhv: &[f32], coef: &[f32]) {
    let d = qhv.len();
    for (c, &co) in coef.iter().enumerate() {
        if co == 0.0 {
            continue;
        }
        for (v, &q) in chvs[c * d..(c + 1) * d].iter_mut().zip(qhv) {
            *v = (*v + co * q).clamp(-127.0, 127.0);
        }
    }
}

#[derive(Clone, Debug)]
pub struct ChvStore {
    cfg: HdConfig,
    /// training accumulator: sums[s] = (classes x seg_len) raw sums
    sums: Vec<Vec<f32>>,
    /// the INT8 view search reads: clip(round(sum / count))
    view: Vec<Vec<f32>>,
    /// the binarized INT1 image of `view` (packed, refreshed on write)
    packed: PackedChvStore,
    /// per-class bundled-sample count (positive updates)
    counts: Vec<u64>,
}

impl ChvStore {
    pub fn new(cfg: HdConfig) -> ChvStore {
        let seg_block = cfg.classes * cfg.seg_len();
        ChvStore {
            sums: (0..cfg.segments).map(|_| vec![0.0; seg_block]).collect(),
            view: (0..cfg.segments).map(|_| vec![0.0; seg_block]).collect(),
            packed: PackedChvStore::new(&cfg),
            counts: vec![0; cfg.classes],
            cfg,
        }
    }

    pub fn cfg(&self) -> &HdConfig {
        &self.cfg
    }

    /// The (classes x seg_len) INT8-view block of segment `s`.
    pub fn segment(&self, s: usize) -> &[f32] {
        &self.view[s]
    }

    /// The (classes x seg_len) raw training-accumulator block of segment
    /// `s` — the state the durable knowledge store
    /// ([`crate::hdc::knowledge`]) persists so learning can continue after
    /// a restart.
    pub fn sums_segment(&self, s: usize) -> &[f32] {
        &self.sums[s]
    }

    /// Total positive (bundling) updates across all classes — the
    /// "learns" counter snapshot/auto-snapshot bookkeeping reads.
    pub fn total_learns(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Rebuild a store from persisted parts: per-segment raw accumulator
    /// blocks plus per-class counts. The INT8 view and the packed INT1
    /// mirror are *recomputed* (not trusted from disk), so both always
    /// equal what the same update stream would have produced in process.
    pub fn from_parts(
        cfg: HdConfig,
        sums: Vec<Vec<f32>>,
        counts: Vec<u64>,
    ) -> Result<ChvStore> {
        let seg_block = cfg.classes * cfg.seg_len();
        if sums.len() != cfg.segments {
            bail!("from_parts: {} segment blocks != segments {}", sums.len(), cfg.segments);
        }
        for (s, block) in sums.iter().enumerate() {
            if block.len() != seg_block {
                bail!(
                    "from_parts: segment {s} has {} values != classes*seg_len {}",
                    block.len(),
                    seg_block
                );
            }
        }
        if counts.len() != cfg.classes {
            bail!("from_parts: {} counts != classes {}", counts.len(), cfg.classes);
        }
        let mut store = ChvStore {
            view: (0..cfg.segments).map(|_| vec![0.0; seg_block]).collect(),
            packed: PackedChvStore::new(&cfg),
            sums,
            counts,
            cfg,
        };
        store.refresh_all()?;
        Ok(store)
    }

    /// Recompute the INT8 view (and its packed mirror) of every class row
    /// from the raw accumulators — the exact normalization `update`
    /// applies per write.
    fn refresh_all(&mut self) -> Result<()> {
        let sl = self.cfg.seg_len();
        for class in 0..self.cfg.classes {
            let norm = self.counts[class].max(1) as f32;
            for s in 0..self.cfg.segments {
                let range = class * sl..(class + 1) * sl;
                for (v, &acc) in self.view[s][range.clone()]
                    .iter_mut()
                    .zip(&self.sums[s][range.clone()])
                {
                    *v = (acc / norm).round_ties_even().clamp(-127.0, 127.0);
                }
                self.packed.write_row(class, s, &self.view[s][range])?;
            }
        }
        Ok(())
    }

    /// One class's row within segment `s` (INT8 view).
    pub fn class_segment(&self, class: usize, s: usize) -> &[f32] {
        let sl = self.cfg.seg_len();
        &self.view[s][class * sl..(class + 1) * sl]
    }

    /// Reassemble one class's full CHV (INT8 view).
    pub fn class_hv(&self, class: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.cfg.dim());
        for s in 0..self.cfg.segments {
            out.extend_from_slice(self.class_segment(class, s));
        }
        out
    }

    /// Add (sign=+1) or subtract (sign=-1) a full QHV into a class row and
    /// refresh its INT8 view.
    pub fn update(&mut self, class: usize, qhv: &[f32], sign: f32) -> Result<()> {
        if class >= self.cfg.classes {
            bail!("class {class} out of range");
        }
        if qhv.len() != self.cfg.dim() {
            bail!("qhv len {} != D {}", qhv.len(), self.cfg.dim());
        }
        if sign > 0.0 {
            self.counts[class] += 1;
        }
        let sl = self.cfg.seg_len();
        let norm = self.counts[class].max(1) as f32;
        for s in 0..self.cfg.segments {
            let qseg = &qhv[s * sl..(s + 1) * sl];
            let sums = &mut self.sums[s][class * sl..(class + 1) * sl];
            let view = &mut self.view[s][class * sl..(class + 1) * sl];
            for ((acc, v), &q) in sums.iter_mut().zip(view.iter_mut()).zip(qseg) {
                *acc += sign * q;
                *v = (*acc / norm).round_ties_even().clamp(-127.0, 127.0);
            }
            // binarize-on-write: the packed INT1 image always mirrors the view
            self.packed
                .write_row(class, s, &self.view[s][class * sl..(class + 1) * sl])?;
        }
        Ok(())
    }

    /// The binarized (INT1, bit-packed) image of the AM — the operand the
    /// XOR+popcount search path reads.
    pub fn packed(&self) -> &PackedChvStore {
        &self.packed
    }

    pub fn count(&self, class: usize) -> u64 {
        self.counts[class]
    }

    /// Has this class ever been bundled into? (The chip's AM only holds
    /// CHVs of classes seen so far; search skips empty slots.)
    pub fn is_trained(&self, class: usize) -> bool {
        self.counts[class] > 0
    }

    /// Classes with at least one bundled sample.
    pub fn trained_classes(&self) -> usize {
        self.counts.iter().filter(|&&c| c > 0).count()
    }

    /// Cache bytes touched when a search stops after `segments_used`
    /// segments (INT8 elements = 1 byte each).
    pub fn bytes_resident(&self, segments_used: usize) -> usize {
        segments_used.min(self.cfg.segments) * self.cfg.classes * self.cfg.seg_len()
    }

    /// Full-CHV cache footprint in bytes.
    pub fn bytes_total(&self) -> usize {
        self.bytes_resident(self.cfg.segments)
    }

    pub fn reset(&mut self) {
        for s in 0..self.cfg.segments {
            self.sums[s].fill(0.0);
            self.view[s].fill(0.0);
        }
        self.packed.reset();
        self.counts.fill(0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};

    fn tiny() -> HdConfig {
        HdConfig::synthetic("t", 8, 8, 32, 32, 8, 10)
    }

    #[test]
    fn update_then_reassemble() {
        let cfg = tiny();
        let mut store = ChvStore::new(cfg.clone());
        let qhv: Vec<f32> = (0..cfg.dim()).map(|i| (i % 7) as f32 - 3.0).collect();
        store.update(3, &qhv, 1.0).unwrap();
        assert_eq!(store.class_hv(3), qhv); // count 1 -> view == qhv
        assert_eq!(store.class_hv(2), vec![0.0; cfg.dim()]);
        assert_eq!(store.count(3), 1);
        assert_eq!(store.trained_classes(), 1);
    }

    #[test]
    fn add_then_subtract_roundtrips() {
        let cfg = tiny();
        let mut store = ChvStore::new(cfg.clone());
        let qhv: Vec<f32> = (0..cfg.dim()).map(|i| ((i % 11) as f32) - 5.0).collect();
        store.update(0, &qhv, 1.0).unwrap();
        store.update(0, &qhv, -1.0).unwrap();
        assert_eq!(store.class_hv(0), vec![0.0; cfg.dim()]);
    }

    #[test]
    fn view_is_count_normalized_mean() {
        let cfg = tiny();
        let mut store = ChvStore::new(cfg.clone());
        let q1 = vec![100.0; cfg.dim()];
        let q2 = vec![20.0; cfg.dim()];
        store.update(1, &q1, 1.0).unwrap();
        store.update(1, &q2, 1.0).unwrap();
        // mean of (100, 20) = 60 — no saturation, magnitude stays INT8-true
        assert!(store.class_hv(1).iter().all(|&v| v == 60.0));
    }

    #[test]
    fn bundling_many_samples_does_not_saturate() {
        // the failure mode that motivated the normalized view: 40 strong
        // QHVs bundled raw would pin everything at 127
        let cfg = tiny();
        let mut store = ChvStore::new(cfg.clone());
        for _ in 0..40 {
            store.update(0, &vec![90.0; cfg.dim()], 1.0).unwrap();
        }
        assert!(store.class_hv(0).iter().all(|&v| v == 90.0));
    }

    #[test]
    fn view_clips_to_int8_when_sums_exceed_range() {
        let cfg = tiny();
        let mut store = ChvStore::new(cfg.clone());
        store.update(1, &vec![127.0; cfg.dim()], 1.0).unwrap();
        store.update(1, &vec![-127.0; cfg.dim()], -1.0).unwrap(); // sums = 254, count 1
        assert!(store.class_hv(1).iter().all(|&v| v == 127.0));
    }

    #[test]
    fn raw_update_matches_hlo_semantics() {
        let mut chvs = vec![120.0, -120.0, 0.0, 50.0];
        raw_update(&mut chvs, &[10.0, -10.0], &[1.0, -1.0]);
        assert_eq!(chvs, vec![127.0, -127.0, -10.0, 60.0]);
    }

    #[test]
    fn cache_residency_model() {
        let cfg = tiny(); // 10 classes, seg_len 128, 8 segments
        let store = ChvStore::new(cfg);
        assert_eq!(store.bytes_resident(1), 10 * 128);
        assert_eq!(store.bytes_total(), 10 * 128 * 8);
        assert_eq!(store.bytes_resident(99), store.bytes_total());
    }

    #[test]
    fn paper_config_fits_hdc_sram() {
        // Chip summary: 32 KB HDC SRAM. ISOLET point: 26 classes x D=2048
        // INT8 = 52 KB full — progressive search with partial residency is
        // what makes it fit; half the segments -> 26 KB < 32 KB.
        let cfg = HdConfig::synthetic("isolet", 32, 20, 64, 32, 16, 26);
        let store = ChvStore::new(cfg);
        assert!(store.bytes_total() > 32 * 1024);
        assert!(store.bytes_resident(8) <= 32 * 1024);
    }

    #[test]
    fn prop_segment_layout_consistent_with_class_hv() {
        forall(20, 0xC44, |rng| {
            let cfg = tiny();
            let mut store = ChvStore::new(cfg.clone());
            let q = gen::int8_vec(rng, cfg.dim());
            let class = rng.below(cfg.classes);
            store.update(class, &q, 1.0).unwrap();
            let sl = cfg.seg_len();
            for s in 0..cfg.segments {
                assert_eq!(
                    store.class_segment(class, s),
                    &q[s * sl..(s + 1) * sl]
                );
            }
        });
    }

    #[test]
    fn prop_packed_image_tracks_view_through_updates_and_reset() {
        forall(20, 0xC45, |rng| {
            let cfg = tiny();
            let mut store = ChvStore::new(cfg.clone());
            for _ in 0..3 {
                let q = gen::int8_vec(rng, cfg.dim());
                let class = rng.below(cfg.classes);
                let sign = if rng.below(4) == 0 { -1.0 } else { 1.0 };
                store.update(class, &q, sign).unwrap();
            }
            for c in 0..cfg.classes {
                let bin: Vec<f32> = store
                    .class_hv(c)
                    .iter()
                    .map(|&v| if v >= 0.0 { 1.0 } else { -1.0 })
                    .collect();
                assert_eq!(store.packed().class_hv(c), bin, "class {c}");
            }
            store.reset();
            // all-zero view binarizes to all +1
            assert!(store.packed().class_hv(0).iter().all(|&v| v == 1.0));
        });
    }

    #[test]
    fn rejects_bad_input() {
        let cfg = tiny();
        let mut store = ChvStore::new(cfg.clone());
        assert!(store.update(99, &vec![0.0; cfg.dim()], 1.0).is_err());
        assert!(store.update(0, &[0.0; 3], 1.0).is_err());
    }
}
