//! Durable knowledge store — the on-disk form of the learned class
//! hypervectors.
//!
//! The paper's ODL story is that Clo-HDnn "updates **and stores** the
//! learned knowledge in the form of class hypervectors"; this module makes
//! that knowledge survive a process restart. The serialized state is the
//! *training-true* form of [`ChvStore`]: the raw f32 accumulators (so
//! learning continues exactly where it left off) plus the per-class bundle
//! counts. The INT8 search view and the bit-packed INT1 mirror are
//! **recomputed on load** and verified against a stored INT8 image, so a
//! restored classifier is bit-identical to the one that was snapshotted —
//! in both the scalar-L1 and packed-Hamming search modes.
//!
//! ## CLOK layout (little-endian; full spec in `docs/PROTOCOL.md`)
//!
//! ```text
//! offset 0   magic      b"CLOK"
//!        4   version    u32 (writes 2; reads 1 and 2)
//!        8   checksum   u64 FNV-1a over every byte after this field
//!       16   payload:
//!            name_len   u16, then name bytes (config identity)
//!            model_len  u16, then model bytes (registry identity; v2 only)
//!            f1 f2 d1 d2 segments classes   u32 each
//!            qbits      u8
//!            scale_x scale_q mean_absdiff   f32 each
//!            counts     classes × u64
//!            view       segments × classes × seg_len × i8   (verification image)
//!            sums       segments × classes × seg_len × f32  (training state)
//! ```
//!
//! v2 adds only the `model` field — the multi-model registry's identity
//! check, so a checkpoint learned as model A is never restored into model
//! B even when both share a config geometry. v1 files (no model field)
//! still load, reporting an empty model name that matches any model.
//!
//! ## Atomic write-rename
//!
//! [`save`] writes the whole image to a sibling `<file>.tmp`, fsyncs, then
//! `rename`s over the target and (on unix) fsyncs the directory entry — so
//! a crash mid-save can never corrupt the last good checkpoint, and a save
//! that returned success survives power loss. The loader only ever reads
//! the target path; a leftover partial `.tmp` from a crashed save is
//! detected and **removed** by [`load`]/[`load_named`], so directory scans
//! and `info --knowledge` can never mistake it for a checkpoint.

use crate::config::HdConfig;
use crate::hdc::chv::ChvStore;
use crate::Result;
use anyhow::{bail, Context};
use std::io::Write;
use std::path::{Path, PathBuf};

/// File magic of a knowledge checkpoint.
pub const MAGIC: &[u8; 4] = b"CLOK";
/// Current format version (what [`save`]/[`save_named`] write).
pub const VERSION: u32 = 2;
/// Oldest format version the loader accepts (v1 files carry no model
/// identity and load with an empty model name).
pub const VERSION_MIN: u32 = 1;

/// FNV-1a 64-bit — the integrity checksum over the payload bytes.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Do two configs quantize identically? Geometry alone is not enough to
/// serve a checkpoint: CHVs bundled under one `(qbits, scale_x, scale_q)`
/// triple are incommensurable with queries quantized/encoded under
/// another — restore would succeed and then silently misclassify.
pub fn calibration_matches(a: &HdConfig, b: &HdConfig) -> bool {
    a.qbits == b.qbits && a.scale_x == b.scale_x && a.scale_q == b.scale_q
}

/// Do two configs describe the same knowledge geometry? (Restore refuses a
/// checkpoint whose encoder/AM shape differs from the serving backend's.)
pub fn compatible(a: &HdConfig, b: &HdConfig) -> bool {
    a.f1 == b.f1
        && a.f2 == b.f2
        && a.d1 == b.d1
        && a.d2 == b.d2
        && a.segments == b.segments
        && a.classes == b.classes
}

/// Serialize a store to the current CLOK byte image with no model
/// identity (equivalent to [`to_bytes_named`] with an empty model).
pub fn to_bytes(store: &ChvStore) -> Vec<u8> {
    to_bytes_named(store, "")
}

/// Serialize a store to the current CLOK byte image, stamping the owning
/// model's registry name into the identity header (empty = unowned; loads
/// into any model).
pub fn to_bytes_named(store: &ChvStore, model: &str) -> Vec<u8> {
    let cfg = store.cfg();
    let seg_block = cfg.classes * cfg.seg_len();
    let mut payload = Vec::with_capacity(64 + cfg.classes * 8 + cfg.segments * seg_block * 5);
    let name = cfg.name.as_bytes();
    payload.extend_from_slice(&(name.len() as u16).to_le_bytes());
    payload.extend_from_slice(name);
    let model_b = model.as_bytes();
    payload.extend_from_slice(&(model_b.len() as u16).to_le_bytes());
    payload.extend_from_slice(model_b);
    for v in [cfg.f1, cfg.f2, cfg.d1, cfg.d2, cfg.segments, cfg.classes] {
        payload.extend_from_slice(&(v as u32).to_le_bytes());
    }
    payload.push(cfg.qbits);
    for v in [cfg.scale_x, cfg.scale_q, cfg.mean_absdiff] {
        payload.extend_from_slice(&v.to_le_bytes());
    }
    for c in 0..cfg.classes {
        payload.extend_from_slice(&store.count(c).to_le_bytes());
    }
    // the INT8 view (integral f32 in [-127, 127] by construction) — stored
    // so the loader can verify its recomputed normalization bit for bit
    for s in 0..cfg.segments {
        for &v in store.segment(s) {
            payload.push(v as i8 as u8);
        }
    }
    for s in 0..cfg.segments {
        for &v in store.sums_segment(s) {
            payload.extend_from_slice(&v.to_le_bytes());
        }
    }
    let mut out = Vec::with_capacity(16 + payload.len());
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Deserialize and verify a CLOK image, discarding the model identity
/// (see [`from_bytes_named`]).
pub fn from_bytes(bytes: &[u8]) -> Result<ChvStore> {
    Ok(from_bytes_named(bytes)?.0)
}

/// Deserialize and verify a CLOK image: checksum, shape, and the
/// recomputed-view-equals-stored-view bit-identity check. The packed INT1
/// mirror is rebuilt from the recomputed view (never trusted from disk).
/// Returns the store plus the model name stamped at save time (empty for
/// v1 files and unowned checkpoints).
pub fn from_bytes_named(bytes: &[u8]) -> Result<(ChvStore, String)> {
    if bytes.len() < 16 {
        bail!("knowledge file too short ({} bytes)", bytes.len());
    }
    if &bytes[0..4] != MAGIC {
        bail!("bad knowledge magic (not a CLOK file)");
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    if !(VERSION_MIN..=VERSION).contains(&version) {
        bail!("unsupported knowledge version {version} (expected {VERSION_MIN}..={VERSION})");
    }
    let checksum = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    let payload = &bytes[16..];
    let actual = fnv1a64(payload);
    if actual != checksum {
        bail!(
            "knowledge checksum mismatch: stored {checksum:#018x}, computed {actual:#018x} \
             (file corrupt or partially written)"
        );
    }
    let mut cur = crate::util::Cursor::new(payload);
    let name_len = cur.u16()? as usize;
    let name = String::from_utf8(cur.take(name_len)?.to_vec())
        .context("knowledge config name is not utf-8")?;
    // v2 identity header: the owning model's registry name
    let model = if version >= 2 {
        let model_len = cur.u16()? as usize;
        String::from_utf8(cur.take(model_len)?.to_vec())
            .context("knowledge model name is not utf-8")?
    } else {
        String::new()
    };
    let f1 = cur.u32()? as usize;
    let f2 = cur.u32()? as usize;
    let d1 = cur.u32()? as usize;
    let d2 = cur.u32()? as usize;
    let segments = cur.u32()? as usize;
    let classes = cur.u32()? as usize;
    let qbits = cur.u8()?;
    let scale_x = cur.f32()?;
    let scale_q = cur.f32()?;
    let mean_absdiff = cur.f32()?;
    let cfg = HdConfig {
        name,
        f1,
        f2,
        d1,
        d2,
        segments,
        classes,
        qbits,
        scale_x,
        scale_q,
        mean_absdiff,
        batches: vec![1],
        image: false,
    };
    cfg.validate()
        .context("knowledge header carries an out-of-envelope config")?;
    let seg_block = cfg.classes * cfg.seg_len();
    let mut counts = Vec::with_capacity(classes);
    for _ in 0..classes {
        counts.push(cur.u64()?);
    }
    let mut view_i8 = Vec::with_capacity(segments);
    for _ in 0..segments {
        view_i8.push(cur.take(seg_block)?.to_vec());
    }
    let mut sums = Vec::with_capacity(segments);
    for _ in 0..segments {
        let mut block = Vec::with_capacity(seg_block);
        for _ in 0..seg_block {
            block.push(cur.f32()?);
        }
        sums.push(block);
    }
    cur.finish()?;
    let store = ChvStore::from_parts(cfg, sums, counts)?;
    // bit-identity gate: the view recomputed from (sums, counts) must equal
    // the stored INT8 image element for element — catches normalization
    // drift between writer and reader versions, not just bit rot
    for (s, stored) in view_i8.iter().enumerate() {
        for (i, (&rebuilt, &disk)) in store.segment(s).iter().zip(stored).enumerate() {
            if rebuilt as i8 != disk as i8 {
                bail!(
                    "knowledge view mismatch at segment {s} element {i}: \
                     recomputed {} != stored {} (incompatible normalization)",
                    rebuilt as i8,
                    disk as i8
                );
            }
        }
    }
    Ok((store, model))
}

/// The sibling temp path `save` stages into before the atomic rename.
pub fn tmp_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

/// Atomically persist a store with no model identity (equivalent to
/// [`save_named`] with an empty model).
pub fn save(store: &ChvStore, path: impl AsRef<Path>) -> Result<()> {
    save_named(store, path, "")
}

/// Atomically persist a store stamped with its owning model's registry
/// name: write `<path>.tmp`, fsync, rename over `path`. The last good
/// checkpoint is never in a torn state.
pub fn save_named(store: &ChvStore, path: impl AsRef<Path>, model: &str) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("create snapshot dir {}", parent.display()))?;
        }
    }
    let bytes = to_bytes_named(store, model);
    let tmp = tmp_path(path);
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("create {}", tmp.display()))?;
        f.write_all(&bytes)?;
        f.sync_all().with_context(|| format!("fsync {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename {} -> {}", tmp.display(), path.display()))?;
    // the rename itself must be durable before success is reported: fsync
    // the directory entry, or a crash right after "snapshot ok" could roll
    // the file back to the previous checkpoint
    #[cfg(unix)]
    {
        let dir = match path.parent() {
            Some(p) if !p.as_os_str().is_empty() => p,
            _ => Path::new("."),
        };
        std::fs::File::open(dir)
            .and_then(|d| d.sync_all())
            .with_context(|| format!("fsync snapshot dir {}", dir.display()))?;
    }
    Ok(())
}

/// Load and verify a knowledge checkpoint. Only ever reads `path` itself —
/// a leftover partial `.tmp` from a crashed save is removed, never read.
pub fn load(path: impl AsRef<Path>) -> Result<ChvStore> {
    Ok(load_named(path)?.0)
}

/// [`load`], also returning the model name stamped at save time (empty for
/// v1 files and unowned checkpoints) for the registry's identity check.
pub fn load_named(path: impl AsRef<Path>) -> Result<(ChvStore, String)> {
    let path = path.as_ref();
    // a leftover `<path>.tmp` can only be the torn staging file of a save
    // that crashed before its rename — never a checkpoint. Remove it at
    // restore time so directory scans and `info --knowledge` can't confuse
    // it for one. (Saves and loads share the executor thread, so this
    // never races an in-flight save.)
    let tmp = tmp_path(path);
    if tmp.exists() {
        match std::fs::remove_file(&tmp) {
            Ok(()) => eprintln!("removed stale checkpoint staging file {}", tmp.display()),
            Err(e) => eprintln!(
                "could not remove stale checkpoint staging file {}: {e}",
                tmp.display()
            ),
        }
    }
    let bytes = std::fs::read(path)
        .with_context(|| format!("read knowledge file {}", path.display()))?;
    from_bytes_named(&bytes)
        .with_context(|| format!("parse knowledge file {}", path.display()))
}

/// Summary of a checkpoint on disk (the `clo_hdnn info --knowledge` view).
#[derive(Clone, Debug)]
pub struct KnowledgeInfo {
    /// the config the checkpoint was trained under
    pub config: HdConfig,
    /// registry model identity ("" for v1 files and unowned checkpoints)
    pub model: String,
    /// classes with at least one bundled sample
    pub trained_classes: usize,
    /// total bundled (positive) learns
    pub total_learns: u64,
    /// on-disk size
    pub file_bytes: usize,
}

/// Load a checkpoint and summarize it (also fully verifies it: checksum,
/// shapes, view bit-identity).
pub fn inspect(path: impl AsRef<Path>) -> Result<KnowledgeInfo> {
    let path = path.as_ref();
    let bytes = std::fs::read(path)
        .with_context(|| format!("read knowledge file {}", path.display()))?;
    let (store, model) = from_bytes_named(&bytes)
        .with_context(|| format!("parse knowledge file {}", path.display()))?;
    Ok(KnowledgeInfo {
        trained_classes: store.trained_classes(),
        total_learns: store.total_learns(),
        config: store.cfg().clone(),
        model,
        file_bytes: bytes.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};

    fn tiny() -> HdConfig {
        HdConfig::synthetic("t", 8, 8, 32, 32, 8, 10)
    }

    fn trained_store(rng: &mut crate::util::Rng, updates: usize) -> ChvStore {
        let cfg = tiny();
        let mut store = ChvStore::new(cfg.clone());
        for _ in 0..updates {
            let q = gen::int8_vec(rng, cfg.dim());
            let class = rng.below(cfg.classes);
            let sign = if rng.below(5) == 0 { -1.0 } else { 1.0 };
            store.update(class, &q, sign).unwrap();
        }
        store
    }

    fn tmp_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("clo_hdnn_knowledge_{name}"));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn prop_roundtrip_is_bit_identical() {
        forall(15, 0xD01, |rng| {
            let store = trained_store(rng, 1 + rng.below(12));
            let bytes = to_bytes(&store);
            let back = from_bytes(&bytes).unwrap();
            let cfg = store.cfg();
            assert_eq!(back.cfg().name, cfg.name);
            for c in 0..cfg.classes {
                assert_eq!(back.count(c), store.count(c), "count class {c}");
                assert_eq!(back.class_hv(c), store.class_hv(c), "view class {c}");
            }
            for s in 0..cfg.segments {
                assert_eq!(
                    back.sums_segment(s),
                    store.sums_segment(s),
                    "raw sums segment {s}"
                );
            }
            // the packed INT1 mirror is rebuilt on load, bit-identical
            assert_eq!(back.packed(), store.packed());
            assert_eq!(back.total_learns(), store.total_learns());
        });
    }

    #[test]
    fn learning_continues_identically_after_roundtrip() {
        // the warm-restart property at the store level: one more update on
        // the original and on the restored copy lands bit-identically
        let mut rng = crate::util::Rng::new(0xD02);
        let mut store = trained_store(&mut rng, 6);
        let mut back = from_bytes(&to_bytes(&store)).unwrap();
        let q = gen::int8_vec(&mut rng, store.cfg().dim());
        store.update(3, &q, 1.0).unwrap();
        back.update(3, &q, 1.0).unwrap();
        assert_eq!(store.class_hv(3), back.class_hv(3));
        assert_eq!(store.packed(), back.packed());
    }

    #[test]
    fn checksum_catches_any_flipped_byte() {
        let mut rng = crate::util::Rng::new(0xD03);
        let store = trained_store(&mut rng, 4);
        let bytes = to_bytes(&store);
        // flip a few sampled positions across header and payload
        for &pos in &[16usize, 40, bytes.len() / 2, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x40;
            assert!(from_bytes(&bad).is_err(), "flip at {pos} went undetected");
        }
    }

    #[test]
    fn rejects_bad_magic_version_truncation_and_trailing() {
        let mut rng = crate::util::Rng::new(0xD04);
        let store = trained_store(&mut rng, 3);
        let bytes = to_bytes(&store);

        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(from_bytes(&bad).unwrap_err().to_string().contains("magic"));

        let mut bad = bytes.clone();
        bad[4] = 99;
        assert!(from_bytes(&bad).unwrap_err().to_string().contains("version"));

        assert!(from_bytes(&bytes[..bytes.len() / 2]).is_err());
        assert!(from_bytes(&bytes[..10]).is_err());

        let mut bad = bytes.clone();
        bad.extend_from_slice(&[0, 0, 0, 0]);
        assert!(from_bytes(&bad).is_err(), "trailing bytes must be rejected");
    }

    #[test]
    fn save_load_roundtrip_on_disk() {
        let dir = tmp_dir("roundtrip");
        let path = dir.join("k.bin");
        let mut rng = crate::util::Rng::new(0xD05);
        let store = trained_store(&mut rng, 8);
        save(&store, &path).unwrap();
        assert!(!tmp_path(&path).exists(), "tmp must be renamed away");
        let back = load(&path).unwrap();
        assert_eq!(back.packed(), store.packed());
        let info = inspect(&path).unwrap();
        assert_eq!(info.trained_classes, store.trained_classes());
        assert_eq!(info.total_learns, store.total_learns());
        assert!(info.file_bytes > 0);
    }

    #[test]
    fn partial_tmp_file_never_shadows_last_good_checkpoint() {
        // crash-safety: a torn .tmp from a crashed save sits next to the
        // checkpoint; the loader removes it and reads only the good file
        let dir = tmp_dir("crash");
        let path = dir.join("k.bin");
        let mut rng = crate::util::Rng::new(0xD06);
        let store = trained_store(&mut rng, 5);
        save(&store, &path).unwrap();
        std::fs::write(tmp_path(&path), b"CLOK\x01\x00\x00\x00partial-garbage").unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.packed(), store.packed(), "good checkpoint survived");
        assert!(
            !tmp_path(&path).exists(),
            "restore must clean up the stale staging file"
        );
        // and the next save still works from the clean state
        save(&back, &path).unwrap();
        assert!(!tmp_path(&path).exists());
        assert!(load(&path).is_ok());
    }

    #[test]
    fn from_parts_rejects_bad_shapes() {
        let cfg = tiny();
        let seg_block = cfg.classes * cfg.seg_len();
        let good_sums: Vec<Vec<f32>> =
            (0..cfg.segments).map(|_| vec![0.0; seg_block]).collect();
        assert!(ChvStore::from_parts(cfg.clone(), good_sums[1..].to_vec(), vec![
            0;
            cfg.classes
        ])
        .is_err());
        let mut short = good_sums.clone();
        short[0].pop();
        assert!(ChvStore::from_parts(cfg.clone(), short, vec![0; cfg.classes]).is_err());
        assert!(
            ChvStore::from_parts(cfg.clone(), good_sums.clone(), vec![0; 3]).is_err()
        );
        assert!(ChvStore::from_parts(cfg, good_sums, vec![0; 10]).is_ok());
    }

    #[test]
    fn compatible_checks_geometry_only() {
        let a = tiny();
        let mut b = tiny();
        b.name = "other-name".into();
        b.scale_x = 0.25; // quantization knobs are not geometry
        assert!(compatible(&a, &b));
        b.classes = 5;
        assert!(!compatible(&a, &b));
    }

    #[test]
    fn calibration_matches_checks_quantization_knobs() {
        let a = tiny();
        let mut b = tiny();
        b.name = "other-name".into();
        b.mean_absdiff = 99.0; // early-exit tuning, not quantization
        assert!(calibration_matches(&a, &b));
        for mutate in [
            (|c: &mut HdConfig| c.scale_x = 0.25) as fn(&mut HdConfig),
            |c: &mut HdConfig| c.scale_q = 2.0,
            |c: &mut HdConfig| c.qbits = 4,
        ] {
            let mut c = tiny();
            mutate(&mut c);
            assert!(!calibration_matches(&a, &c));
        }
    }

    #[test]
    fn model_identity_roundtrips_and_defaults_empty() {
        let mut rng = crate::util::Rng::new(0xD07);
        let store = trained_store(&mut rng, 5);
        // unnamed save -> empty model
        let (back, model) = from_bytes_named(&to_bytes(&store)).unwrap();
        assert_eq!(model, "");
        assert_eq!(back.packed(), store.packed());
        // named save -> the name comes back, store bit-identical
        let (back, model) = from_bytes_named(&to_bytes_named(&store, "isolet-prod")).unwrap();
        assert_eq!(model, "isolet-prod");
        assert_eq!(back.packed(), store.packed());
        // and through the disk path + inspect
        let dir = tmp_dir("model_identity");
        let path = dir.join("k.clok");
        save_named(&store, &path, "isolet-prod").unwrap();
        let (_, model) = load_named(&path).unwrap();
        assert_eq!(model, "isolet-prod");
        assert_eq!(inspect(&path).unwrap().model, "isolet-prod");
    }

    /// Serialize the CLOK **v1** image (no model field) exactly as PR 4's
    /// writer did — the back-compat fixture generator.
    fn to_bytes_v1(store: &ChvStore) -> Vec<u8> {
        let cfg = store.cfg();
        let mut payload = Vec::new();
        let name = cfg.name.as_bytes();
        payload.extend_from_slice(&(name.len() as u16).to_le_bytes());
        payload.extend_from_slice(name);
        for v in [cfg.f1, cfg.f2, cfg.d1, cfg.d2, cfg.segments, cfg.classes] {
            payload.extend_from_slice(&(v as u32).to_le_bytes());
        }
        payload.push(cfg.qbits);
        for v in [cfg.scale_x, cfg.scale_q, cfg.mean_absdiff] {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        for c in 0..cfg.classes {
            payload.extend_from_slice(&store.count(c).to_le_bytes());
        }
        for s in 0..cfg.segments {
            for &v in store.segment(s) {
                payload.push(v as i8 as u8);
            }
        }
        for s in 0..cfg.segments {
            for &v in store.sums_segment(s) {
                payload.extend_from_slice(&v.to_le_bytes());
            }
        }
        let mut out = Vec::with_capacity(16 + payload.len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }

    #[test]
    fn v1_checkpoints_still_load_bit_identically() {
        // back-compat read: a pre-registry (v1) checkpoint loads, reports
        // an empty model, and reconstructs the exact same store
        let mut rng = crate::util::Rng::new(0xD08);
        let store = trained_store(&mut rng, 7);
        let v1 = to_bytes_v1(&store);
        assert_eq!(u32::from_le_bytes(v1[4..8].try_into().unwrap()), 1);
        let (back, model) = from_bytes_named(&v1).unwrap();
        assert_eq!(model, "");
        let cfg = store.cfg();
        for c in 0..cfg.classes {
            assert_eq!(back.count(c), store.count(c));
            assert_eq!(back.class_hv(c), store.class_hv(c));
        }
        assert_eq!(back.packed(), store.packed());
        // v1 truncation/trailing still rejected
        assert!(from_bytes(&v1[..v1.len() - 3]).is_err());
        let mut bad = v1;
        bad.extend_from_slice(&[0, 0]);
        assert!(from_bytes(&bad).is_err());
    }

    #[test]
    fn fnv_vector() {
        // standard FNV-1a test vectors
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
