//! Distance kernels for associative search (software side).
//!
//! L1 over INT8 CHVs is the default inference metric; negative dot doubles
//! as Hamming for +-1 hypervectors (the chip's XOR tree). Both are additive
//! over progressive-search segments, which is what makes partial-distance
//! accumulation exact.

use crate::Result;
use anyhow::bail;

/// L1 distances: qs (batch, len) vs chvs (classes, len) -> (batch, classes).
pub fn l1_batch(
    qs: &[f32],
    batch: usize,
    chvs: &[f32],
    classes: usize,
    len: usize,
) -> Result<Vec<f32>> {
    if batch == 0 {
        bail!("l1_batch: batch must be >= 1, got 0");
    }
    if qs.len() != batch * len {
        bail!("l1_batch: qs len {} != batch {batch} * len {len}", qs.len());
    }
    if chvs.len() != classes * len {
        bail!("l1_batch: chvs len {} != classes {classes} * len {len}", chvs.len());
    }
    let mut out = vec![0.0f32; batch * classes];
    for n in 0..batch {
        let q = &qs[n * len..(n + 1) * len];
        let row = &mut out[n * classes..(n + 1) * classes];
        for (c, o) in row.iter_mut().enumerate() {
            let chv = &chvs[c * len..(c + 1) * len];
            let mut acc = 0.0f32;
            for (&qv, &cv) in q.iter().zip(chv) {
                acc += (qv - cv).abs();
            }
            *o = acc;
        }
    }
    Ok(out)
}

/// Negative dot similarity (Hamming-equivalent for +-1 HVs).
pub fn neg_dot_batch(
    qs: &[f32],
    batch: usize,
    chvs: &[f32],
    classes: usize,
    len: usize,
) -> Result<Vec<f32>> {
    if batch == 0 {
        bail!("neg_dot_batch: batch must be >= 1, got 0");
    }
    if qs.len() != batch * len {
        bail!("neg_dot_batch: qs len {} != batch {batch} * len {len}", qs.len());
    }
    if chvs.len() != classes * len {
        bail!(
            "neg_dot_batch: chvs len {} != classes {classes} * len {len}",
            chvs.len()
        );
    }
    let mut out = vec![0.0f32; batch * classes];
    for n in 0..batch {
        let q = &qs[n * len..(n + 1) * len];
        for c in 0..classes {
            let chv = &chvs[c * len..(c + 1) * len];
            let dot: f32 = q.iter().zip(chv).map(|(&a, &b)| a * b).sum();
            out[n * classes + c] = -dot;
        }
    }
    Ok(out)
}

/// Hamming distance between +-1 hypervectors.
pub fn hamming_pm1(a: &[f32], b: &[f32]) -> usize {
    a.iter().zip(b).filter(|(x, y)| x != y).count()
}

/// Cosine distances (1 - cosine similarity): qs (batch, len) vs
/// chvs (classes, len) -> (batch, classes). A zero-norm operand yields the
/// maximum distance 1.0 (no direction to agree with). For binarized (+-1)
/// vectors this is exactly `2 * hamming / len` — the XOR-tree metric.
pub fn cosine_batch(
    qs: &[f32],
    batch: usize,
    chvs: &[f32],
    classes: usize,
    len: usize,
) -> Result<Vec<f32>> {
    if batch == 0 {
        bail!("cosine_batch: batch must be >= 1, got 0");
    }
    if qs.len() != batch * len {
        bail!("cosine_batch: qs len {} != batch {batch} * len {len}", qs.len());
    }
    if chvs.len() != classes * len {
        bail!(
            "cosine_batch: chvs len {} != classes {classes} * len {len}",
            chvs.len()
        );
    }
    let chv_norms: Vec<f32> = (0..classes)
        .map(|c| chvs[c * len..(c + 1) * len].iter().map(|v| v * v).sum::<f32>().sqrt())
        .collect();
    let mut out = vec![0.0f32; batch * classes];
    for n in 0..batch {
        let q = &qs[n * len..(n + 1) * len];
        let qn = q.iter().map(|v| v * v).sum::<f32>().sqrt();
        for c in 0..classes {
            let chv = &chvs[c * len..(c + 1) * len];
            let dot: f32 = q.iter().zip(chv).map(|(&a, &b)| a * b).sum();
            out[n * classes + c] = if qn == 0.0 || chv_norms[c] == 0.0 {
                1.0
            } else {
                1.0 - dot / (qn * chv_norms[c])
            };
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};

    #[test]
    fn l1_manual() {
        let qs = [1.0, 2.0];
        let chvs = [1.0, 2.0, -1.0, 4.0];
        let d = l1_batch(&qs, 1, &chvs, 2, 2).unwrap();
        assert_eq!(d, vec![0.0, 4.0]);
    }

    #[test]
    fn neg_dot_matches_hamming_for_pm1() {
        let mut rng = crate::util::Rng::new(1);
        let len = 64;
        let q: Vec<f32> = (0..len).map(|_| rng.sign()).collect();
        let c: Vec<f32> = (0..len).map(|_| rng.sign()).collect();
        let nd = neg_dot_batch(&q, 1, &c, 1, len).unwrap()[0];
        let ham = hamming_pm1(&q, &c) as f32;
        assert_eq!((len as f32 + nd) / 2.0, ham);
    }

    #[test]
    fn prop_l1_additive_over_segments() {
        forall(30, 0xD15, |rng| {
            let (segs, seg_len, classes) = (4usize, 16usize, 5usize);
            let len = segs * seg_len;
            let q = gen::int8_vec(rng, len);
            let chvs = gen::int8_vec(rng, classes * len);
            let full = l1_batch(&q, 1, &chvs, classes, len).unwrap();
            let mut acc = vec![0.0f32; classes];
            for s in 0..segs {
                let qseg = &q[s * seg_len..(s + 1) * seg_len];
                // gather the CHV columns of this segment
                let mut cseg = Vec::with_capacity(classes * seg_len);
                for c in 0..classes {
                    cseg.extend_from_slice(
                        &chvs[c * len + s * seg_len..c * len + (s + 1) * seg_len],
                    );
                }
                let d = l1_batch(qseg, 1, &cseg, classes, seg_len).unwrap();
                for (a, v) in acc.iter_mut().zip(d) {
                    *a += v;
                }
            }
            for (a, f) in acc.iter().zip(&full) {
                assert!((a - f).abs() < 1e-3, "{a} vs {f}");
            }
        });
    }

    #[test]
    fn prop_l1_metric_axioms() {
        forall(30, 0xA71, |rng| {
            let len = 32;
            let a = gen::int8_vec(rng, len);
            let b = gen::int8_vec(rng, len);
            let dab = l1_batch(&a, 1, &b, 1, len).unwrap()[0];
            let dba = l1_batch(&b, 1, &a, 1, len).unwrap()[0];
            let daa = l1_batch(&a, 1, &a, 1, len).unwrap()[0];
            assert_eq!(dab, dba); // symmetry
            assert_eq!(daa, 0.0); // identity
            assert!(dab >= 0.0);
        });
    }

    #[test]
    fn shape_errors() {
        // all four search kernels (L1, neg-dot, cosine, packed Hamming)
        // reject qs mismatch, chvs mismatch, and the empty batch — with
        // messages naming the offending dimension
        use crate::hdc::packed::hamming_search;
        assert!(l1_batch(&[0.0; 3], 1, &[0.0; 4], 2, 2).is_err());
        assert!(l1_batch(&[0.0; 2], 1, &[0.0; 3], 2, 2).is_err());
        assert!(l1_batch(&[], 0, &[0.0; 4], 2, 2).is_err());
        assert!(neg_dot_batch(&[0.0; 3], 1, &[0.0; 4], 2, 2).is_err());
        assert!(neg_dot_batch(&[0.0; 2], 1, &[0.0; 3], 2, 2).is_err());
        assert!(neg_dot_batch(&[], 0, &[0.0; 4], 2, 2).is_err());
        assert!(cosine_batch(&[0.0; 3], 1, &[0.0; 4], 2, 2).is_err());
        assert!(cosine_batch(&[0.0; 2], 1, &[0.0; 3], 2, 2).is_err());
        assert!(cosine_batch(&[], 0, &[0.0; 4], 2, 2).is_err());
        assert!(hamming_search(&[0; 3], 1, &[0; 4], 2, 128).is_err());
        assert!(hamming_search(&[0; 2], 1, &[0; 3], 2, 128).is_err());
        assert!(hamming_search(&[], 0, &[0; 4], 2, 128).is_err());

        let msg = |e: anyhow::Error| format!("{e:#}");
        let e = msg(neg_dot_batch(&[0.0; 3], 2, &[0.0; 4], 2, 2).unwrap_err());
        assert!(e.contains("batch 2") && e.contains("len 2"), "{e}");
        let e = msg(neg_dot_batch(&[0.0; 4], 2, &[0.0; 3], 2, 2).unwrap_err());
        assert!(e.contains("classes 2"), "{e}");
        let e = msg(cosine_batch(&[], 0, &[0.0; 4], 2, 2).unwrap_err());
        assert!(e.contains("batch"), "{e}");
    }

    #[test]
    fn prop_neg_dot_hamming_identity_and_packed_agree_any_length() {
        // (len + neg_dot) / 2 == hamming on ±1 vectors, for random lengths
        // including non-multiple-of-64 tails, and the bit-packed Hamming
        // (whose padding words must contribute zero) agrees exactly.
        use crate::hdc::packed::PackedHv;
        forall(40, 0xD17, |rng| {
            let len = 1 + rng.below(300);
            let q = gen::pm1_vec(rng, len);
            let c = gen::pm1_vec(rng, len);
            let nd = neg_dot_batch(&q, 1, &c, 1, len).unwrap()[0];
            let ham = hamming_pm1(&q, &c);
            assert_eq!((len as f32 + nd) / 2.0, ham as f32, "len {len}");
            let hp = PackedHv::from_pm1(&q)
                .unwrap()
                .hamming(&PackedHv::from_pm1(&c).unwrap())
                .unwrap();
            assert_eq!(hp, ham, "packed disagrees at len {len}");
        });
    }

    #[test]
    fn prop_cosine_agrees_with_hamming_on_binarized_vectors() {
        // On +-1 (INT1-quantized) hypervectors the cosine distance is an
        // affine function of Hamming: 1 - dot/len = 2 * hamming / len.
        forall(40, 0xC05, |rng| {
            let len = 64 + rng.below(128);
            let q = gen::pm1_vec(rng, len);
            let chvs = gen::pm1_vec(rng, 3 * len);
            let cos = cosine_batch(&q, 1, &chvs, 3, len).unwrap();
            for c in 0..3 {
                let ham = hamming_pm1(&q, &chvs[c * len..(c + 1) * len]) as f32;
                let want = 2.0 * ham / len as f32;
                assert!((cos[c] - want).abs() < 1e-4, "{} vs {want}", cos[c]);
            }
        });
    }

    #[test]
    fn prop_cosine_symmetry_identity_and_range() {
        forall(40, 0xC06, |rng| {
            let len = 32;
            let a = gen::quantized_vec(rng, len, 4);
            let b = gen::quantized_vec(rng, len, 4);
            let dab = cosine_batch(&a, 1, &b, 1, len).unwrap()[0];
            let dba = cosine_batch(&b, 1, &a, 1, len).unwrap()[0];
            assert!((dab - dba).abs() < 1e-5); // symmetry
            assert!((-1e-5..=2.0 + 1e-5).contains(&dab), "{dab}");
            let daa = cosine_batch(&a, 1, &a, 1, len).unwrap()[0];
            if a.iter().any(|&v| v != 0.0) {
                assert!(daa.abs() < 1e-5, "self-distance {daa}");
            } else {
                assert_eq!(daa, 1.0); // zero-norm convention
            }
        });
    }

    #[test]
    fn prop_neg_dot_symmetric_under_swap() {
        forall(40, 0xC07, |rng| {
            let len = 48;
            let a = gen::int8_vec(rng, len);
            let b = gen::int8_vec(rng, len);
            let dab = neg_dot_batch(&a, 1, &b, 1, len).unwrap()[0];
            let dba = neg_dot_batch(&b, 1, &a, 1, len).unwrap()[0];
            assert_eq!(dab, dba);
        });
    }
}
