//! Bit-packed INT1 associative memory — the chip's XOR-tree search path.
//!
//! The classifier reaches its TOPS/W point by comparing **binarized**
//! hypervectors with an XOR tree + popcount, not element-wise arithmetic.
//! This module is the software twin: ±1 hypervectors packed 64 elements per
//! `u64` word (bit set ⇔ element is +1, matching the INT1 quantizer's
//! `y >= 0 → +1` rule), Hamming distance via `xor` + `count_ones`, and a
//! [`PackedChvStore`] that shadows the INT8 [`ChvStore`](crate::hdc::ChvStore)
//! view with its binarized image (train in INT8, search in INT1 — the
//! paper's precision split).
//!
//! Metric convention: batch search distances are **`2 × Hamming`**, which is
//! exactly the L1 distance between the underlying ±1 vectors
//! (`|(+1) − (−1)| = 2`). That keeps packed and scalar search directly
//! comparable — unpacking a packed operand and running the scalar L1 kernel
//! yields bit-identical distances — and gives the progressive controller a
//! sound early-exit bound of **2 per remaining element** (vs 254 for INT8).
//!
//! Segments are packed **word-granularly**: every progressive-search segment
//! starts on a fresh word and pads its tail bits with zeros in both
//! operands, so padding XORs to zero and per-segment Hamming distances stay
//! exactly additive (the invariant progressive accumulation relies on).

use crate::config::HdConfig;
use crate::hdc::simd;
use crate::Result;
use anyhow::bail;

/// Elements per packed word.
pub const WORD_BITS: usize = 64;

/// Words needed to hold `bits` packed elements.
pub fn words_for(bits: usize) -> usize {
    bits.div_ceil(WORD_BITS)
}

/// Pack by sign (the INT1 quantizer's rule: `v >= 0 → +1`): bit set ⇔ +1.
/// Tail bits of the last word are zero.
pub fn pack_signs(values: &[f32]) -> Vec<u64> {
    let mut words = vec![0u64; words_for(values.len())];
    for (i, &v) in values.iter().enumerate() {
        if v >= 0.0 {
            words[i / WORD_BITS] |= 1u64 << (i % WORD_BITS);
        }
    }
    words
}

/// Pack a strict ±1 vector; errors on any other value (use [`pack_signs`]
/// to binarize arbitrary values).
pub fn pack_pm1(values: &[f32]) -> Result<Vec<u64>> {
    for (i, &v) in values.iter().enumerate() {
        if v != 1.0 && v != -1.0 {
            bail!("pack_pm1: element {i} is {v}, expected +-1");
        }
    }
    Ok(pack_signs(values))
}

/// Binarize and pack `n` row-major rows of `len` values each into the
/// contiguous (n × `words_for(len)`) layout [`hamming_search`] takes —
/// each row starts on a fresh word.
pub fn pack_rows(values: &[f32], n: usize, len: usize) -> Result<Vec<u64>> {
    if values.len() != n * len {
        bail!("pack_rows: {} values != rows {n} * len {len}", values.len());
    }
    let mut out = Vec::with_capacity(n * words_for(len));
    for r in 0..n {
        out.extend(pack_signs(&values[r * len..(r + 1) * len]));
    }
    Ok(out)
}

/// Unpack `len` elements back to ±1 f32.
pub fn unpack_pm1(words: &[u64], len: usize) -> Vec<f32> {
    assert!(
        words.len() >= words_for(len),
        "unpack_pm1: {} words cannot hold {len} bits",
        words.len()
    );
    (0..len)
        .map(|i| {
            if (words[i / WORD_BITS] >> (i % WORD_BITS)) & 1 == 1 {
                1.0
            } else {
                -1.0
            }
        })
        .collect()
}

/// Unpack `n` packed rows of `len` elements each (row stride =
/// `words_for(len)`) into a flat (n, len) ±1 matrix.
pub fn unpack_pm1_rows(rows: &[u64], n: usize, len: usize) -> Result<Vec<f32>> {
    let w = words_for(len);
    if rows.len() != n * w {
        bail!(
            "unpack_pm1_rows: {} words != rows {n} * words_per_row {w} (len {len})",
            rows.len()
        );
    }
    let mut out = Vec::with_capacity(n * len);
    for r in 0..n {
        out.extend(unpack_pm1(&rows[r * w..(r + 1) * w], len));
    }
    Ok(out)
}

/// The shared shape contract of the packed batch-search kernels: returns
/// the words-per-row on success so both entry points validate identically.
fn check_search_shapes(
    qs: &[u64],
    batch: usize,
    chvs: &[u64],
    classes: usize,
    len: usize,
) -> Result<usize> {
    if batch == 0 {
        bail!("packed search: batch must be >= 1, got 0");
    }
    let w = words_for(len);
    if qs.len() != batch * w {
        bail!(
            "packed search: qs has {} words != batch {batch} * words_per_row {w} (len {len})",
            qs.len()
        );
    }
    if chvs.len() != classes * w {
        bail!(
            "packed search: chvs has {} words != classes {classes} * words_per_row {w} (len {len})",
            chvs.len()
        );
    }
    Ok(w)
}

/// Hamming distance between two equal-length packed rows: XOR + popcount.
/// Equal-length padding cancels (0 ^ 0 = 0), so tail bits never contribute.
/// Dispatches to the process-wide SIMD level; popcount sums are integer, so
/// every level returns the identical count.
pub fn hamming_words(a: &[u64], b: &[u64]) -> usize {
    hamming_words_with(simd::active(), a, b)
}

/// [`hamming_words`] at an explicit SIMD level (differential tests force
/// scalar vs wide paths against each other through this seam).
pub fn hamming_words_with(level: simd::SimdLevel, a: &[u64], b: &[u64]) -> usize {
    debug_assert_eq!(a.len(), b.len());
    simd::xor_popcount(level, a, b) as usize
}

/// Packed associative search: qs (batch, words) vs chvs (classes, words) ->
/// (batch, classes), where words = `len.div_ceil(64)` and each distance is
/// `2 × Hamming` — the L1 distance between the ±1 vectors, so results are
/// bit-identical to [`l1_batch`](crate::hdc::distance::l1_batch) over the
/// unpacked operands.
pub fn hamming_search(
    qs: &[u64],
    batch: usize,
    chvs: &[u64],
    classes: usize,
    len: usize,
) -> Result<Vec<f32>> {
    hamming_search_with(simd::active(), qs, batch, chvs, classes, len)
}

/// [`hamming_search`] at an explicit SIMD level. The distance is an integer
/// popcount scaled by 2, so every level is bit-identical to scalar.
pub fn hamming_search_with(
    level: simd::SimdLevel,
    qs: &[u64],
    batch: usize,
    chvs: &[u64],
    classes: usize,
    len: usize,
) -> Result<Vec<f32>> {
    let w = check_search_shapes(qs, batch, chvs, classes, len)?;
    let mut out = vec![0.0f32; batch * classes];
    for n in 0..batch {
        let q = &qs[n * w..(n + 1) * w];
        let row = &mut out[n * classes..(n + 1) * classes];
        for (c, o) in row.iter_mut().enumerate() {
            let chv = &chvs[c * w..(c + 1) * w];
            let ham = simd::xor_popcount(level, q, chv);
            // 2 * Hamming == L1 over ±1; exact in f32 for D <= 2^22
            *o = 2.0 * ham as f32;
        }
    }
    Ok(out)
}

/// Pool-sharded packed search over **AM row-blocks**: the class rows are
/// split into contiguous blocks, each block runs [`hamming_search`] on a
/// scoped worker thread, and the per-block `(batch, block_classes)` results
/// are merged back into the `(batch, classes)` matrix. Distances are
/// bit-identical to the single-thread kernel (each distance is computed by
/// exactly the same XOR+popcount loop — sharding only partitions rows).
/// Serial pools and small AMs short-circuit to the inline kernel.
pub fn hamming_search_pool(
    pool: &crate::util::pool::WorkerPool,
    qs: &[u64],
    batch: usize,
    chvs: &[u64],
    classes: usize,
    len: usize,
) -> Result<Vec<f32>> {
    hamming_search_pool_with(simd::active(), pool, qs, batch, chvs, classes, len)
}

/// [`hamming_search_pool`] at an explicit SIMD level: every shard runs the
/// same level's kernel, so sharding and dispatch compose bit-identically.
pub fn hamming_search_pool_with(
    level: simd::SimdLevel,
    pool: &crate::util::pool::WorkerPool,
    qs: &[u64],
    batch: usize,
    chvs: &[u64],
    classes: usize,
    len: usize,
) -> Result<Vec<f32>> {
    // Same shape contract as hamming_search, checked up front so every
    // shard works on verified operands.
    let w = check_search_shapes(qs, batch, chvs, classes, len)?;
    // Below ~2 classes per worker the scope/merge overhead dominates.
    if pool.is_serial() || classes < 2 * pool.threads() {
        return hamming_search_with(level, qs, batch, chvs, classes, len);
    }
    let blocks = pool.run_blocks(classes, |c0, n_classes| {
        let sub = &chvs[c0 * w..(c0 + n_classes) * w];
        hamming_search_with(level, qs, batch, sub, n_classes, len)
            .expect("hamming_search_pool: block shapes verified up front")
    });
    let mut out = vec![0.0f32; batch * classes];
    for (c0, n_classes, block) in blocks {
        for n in 0..batch {
            out[n * classes + c0..n * classes + c0 + n_classes]
                .copy_from_slice(&block[n * n_classes..(n + 1) * n_classes]);
        }
    }
    Ok(out)
}

/// One bit-packed ±1 hypervector.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedHv {
    words: Vec<u64>,
    len: usize,
}

impl PackedHv {
    /// Pack a strict ±1 vector ([`pack_signs`] is the binarize-anything
    /// entry point).
    pub fn from_pm1(values: &[f32]) -> Result<PackedHv> {
        Ok(PackedHv { words: pack_pm1(values)?, len: values.len() })
    }

    /// Element count (bits).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The packed words (tail bits beyond `len` are always zero).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Back to ±1 f32.
    pub fn unpack(&self) -> Vec<f32> {
        unpack_pm1(&self.words, self.len)
    }

    /// Raw Hamming distance (count of differing elements) — the quantity
    /// `hamming_pm1` computes on the unpacked vectors.
    pub fn hamming(&self, other: &PackedHv) -> Result<usize> {
        if self.len != other.len {
            bail!("PackedHv::hamming: len {} != len {}", self.len, other.len);
        }
        Ok(hamming_words(&self.words, &other.words))
    }
}

/// The binarized associative memory: per progressive-search segment, a
/// (classes × seg_words) block of packed rows mirroring the INT8
/// [`ChvStore`](crate::hdc::ChvStore) view. Rows are **binarized on write**
/// — bundling stays INT8, only the searched image is INT1 — so every row
/// always equals `pack_signs` of the corresponding INT8 view row (including
/// the all-zero row of an untrained class, which binarizes to all +1).
///
/// `PartialEq` compares the full packed image word for word — the check the
/// durable knowledge store's warm-restart tests use to pin "mirror rebuilt
/// on load, bit-identical".
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedChvStore {
    classes: usize,
    segments: usize,
    seg_len: usize,
    seg_words: usize,
    /// per segment: (classes × seg_words) row-major packed block
    segs: Vec<Vec<u64>>,
}

impl PackedChvStore {
    pub fn new(cfg: &HdConfig) -> PackedChvStore {
        let seg_len = cfg.seg_len();
        let seg_words = words_for(seg_len);
        let mut store = PackedChvStore {
            classes: cfg.classes,
            segments: cfg.segments,
            seg_len,
            seg_words,
            segs: Vec::new(),
        };
        store.reset();
        store
    }

    /// Words per packed class row (one segment's worth).
    pub fn seg_words(&self) -> usize {
        self.seg_words
    }

    /// Elements per class row (one segment's worth).
    pub fn seg_len(&self) -> usize {
        self.seg_len
    }

    /// The (classes × seg_words) packed block of segment `s` — the operand
    /// `search_packed` takes.
    pub fn segment(&self, s: usize) -> &[u64] {
        &self.segs[s]
    }

    /// One class's packed row within segment `s`.
    pub fn class_segment(&self, class: usize, s: usize) -> &[u64] {
        &self.segs[s][class * self.seg_words..(class + 1) * self.seg_words]
    }

    /// Reassemble one class's full binarized CHV as ±1 f32.
    pub fn class_hv(&self, class: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.segments * self.seg_len);
        for s in 0..self.segments {
            out.extend(unpack_pm1(self.class_segment(class, s), self.seg_len));
        }
        out
    }

    /// Binarize-on-write: refresh one class row of segment `s` from its
    /// INT8 view values.
    pub fn write_row(&mut self, class: usize, s: usize, values: &[f32]) -> Result<()> {
        if class >= self.classes {
            bail!("write_row: class {class} out of range (< {})", self.classes);
        }
        if s >= self.segments {
            bail!("write_row: segment {s} out of range (< {})", self.segments);
        }
        if values.len() != self.seg_len {
            bail!(
                "write_row: row has {} values != seg_len {}",
                values.len(),
                self.seg_len
            );
        }
        let packed = pack_signs(values);
        self.segs[s][class * self.seg_words..(class + 1) * self.seg_words]
            .copy_from_slice(&packed);
        Ok(())
    }

    /// Packed cache bytes touched when a search stops after `segments_used`
    /// segments (8 bytes per word — the INT1 counterpart of
    /// [`ChvStore::bytes_resident`](crate::hdc::ChvStore::bytes_resident)).
    pub fn bytes_resident(&self, segments_used: usize) -> usize {
        segments_used.min(self.segments) * self.classes * self.seg_words * 8
    }

    /// Full packed-AM footprint in bytes.
    pub fn bytes_total(&self) -> usize {
        self.bytes_resident(self.segments)
    }

    /// Back to the all-zero-view image (every row = binarize(0…0) = all +1).
    pub fn reset(&mut self) {
        let zero_row = pack_signs(&vec![0.0f32; self.seg_len]);
        let mut block = Vec::with_capacity(self.classes * self.seg_words);
        for _ in 0..self.classes {
            block.extend_from_slice(&zero_row);
        }
        self.segs = (0..self.segments).map(|_| block.clone()).collect();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdc::distance::{hamming_pm1, l1_batch};
    use crate::util::prop::{forall, gen};

    #[test]
    fn pack_padding_bits_are_zero() {
        let v = vec![1.0f32; 70]; // all +1: 64 set bits + 6 in the tail word
        let w = pack_signs(&v);
        assert_eq!(w.len(), 2);
        assert_eq!(w[0], u64::MAX);
        assert_eq!(w[1], (1u64 << 6) - 1);
        // all -1: every bit (including padding) stays zero
        let w = pack_signs(&vec![-1.0f32; 70]);
        assert_eq!(w, vec![0, 0]);
    }

    #[test]
    fn pack_follows_int1_quantizer_rule() {
        // quantize(y, 1, _) maps y >= 0 to +1; pack_signs must agree bit
        // for bit, zero included.
        let vals = [-3.0, -0.0, 0.0, 0.5, 127.0, -127.0];
        let packed = pack_signs(&vals);
        let unpacked = unpack_pm1(&packed, vals.len());
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(unpacked[i], crate::hdc::quantize::quantize(v, 1, 1.0));
        }
    }

    #[test]
    fn pack_pm1_rejects_non_pm1() {
        assert!(pack_pm1(&[1.0, -1.0, 1.0]).is_ok());
        assert!(pack_pm1(&[1.0, 0.0]).is_err());
        assert!(pack_pm1(&[2.0]).is_err());
    }

    #[test]
    fn prop_pack_rows_matches_per_row_packing() {
        forall(30, 0xB16, |rng| {
            let (n, len) = (1 + rng.below(5), 1 + rng.below(150));
            let values = gen::pm1_vec(rng, n * len);
            let rows = pack_rows(&values, n, len).unwrap();
            let mut manual = Vec::new();
            for r in 0..n {
                manual.extend(pack_signs(&values[r * len..(r + 1) * len]));
            }
            assert_eq!(rows, manual);
            assert_eq!(rows.len(), n * words_for(len));
            assert!(pack_rows(&values, n + 1, len).is_err());
        });
    }

    #[test]
    fn prop_pack_unpack_roundtrip_any_length() {
        forall(40, 0xB17, |rng| {
            let len = 1 + rng.below(300); // exercises non-multiple-of-64 tails
            let v = gen::pm1_vec(rng, len);
            let hv = PackedHv::from_pm1(&v).unwrap();
            assert_eq!(hv.len(), len);
            assert_eq!(hv.unpack(), v);
            assert_eq!(hv.words().len(), words_for(len));
        });
    }

    #[test]
    fn prop_packed_hamming_equals_scalar_oracle() {
        forall(40, 0xB18, |rng| {
            let len = 1 + rng.below(300);
            let a = gen::pm1_vec(rng, len);
            let b = gen::pm1_vec(rng, len);
            let ha = PackedHv::from_pm1(&a).unwrap();
            let hb = PackedHv::from_pm1(&b).unwrap();
            assert_eq!(ha.hamming(&hb).unwrap(), hamming_pm1(&a, &b));
            assert_eq!(ha.hamming(&ha).unwrap(), 0);
        });
    }

    #[test]
    fn prop_hamming_search_matches_l1_on_pm1() {
        // The metric convention: packed distances are 2 * Hamming, which is
        // exactly the scalar L1 over the same ±1 vectors.
        forall(30, 0xB19, |rng| {
            let len = 1 + rng.below(200);
            let (batch, classes) = (1 + rng.below(3), 1 + rng.below(5));
            let qs = gen::pm1_vec(rng, batch * len);
            let chvs = gen::pm1_vec(rng, classes * len);
            let mut qp = Vec::new();
            for n in 0..batch {
                qp.extend(pack_signs(&qs[n * len..(n + 1) * len]));
            }
            let mut cp = Vec::new();
            for c in 0..classes {
                cp.extend(pack_signs(&chvs[c * len..(c + 1) * len]));
            }
            let packed = hamming_search(&qp, batch, &cp, classes, len).unwrap();
            let scalar = l1_batch(&qs, batch, &chvs, classes, len).unwrap();
            assert_eq!(packed, scalar);
        });
    }

    #[test]
    fn prop_hamming_search_additive_over_word_granular_segments() {
        // Mirrors prop_l1_additive_over_segments: packing each segment
        // independently (fresh word, zero tail) must keep partial distances
        // exactly additive — seg_len deliberately not a multiple of 64.
        forall(30, 0xB1A, |rng| {
            let (segs, seg_len, classes) = (4usize, 50usize, 5usize);
            let len = segs * seg_len;
            let q = gen::pm1_vec(rng, len);
            let chvs = gen::pm1_vec(rng, classes * len);
            let full = l1_batch(&q, 1, &chvs, classes, len).unwrap();
            let mut acc = vec![0.0f32; classes];
            for s in 0..segs {
                let qp = pack_signs(&q[s * seg_len..(s + 1) * seg_len]);
                let mut cp = Vec::new();
                for c in 0..classes {
                    cp.extend(pack_signs(
                        &chvs[c * len + s * seg_len..c * len + (s + 1) * seg_len],
                    ));
                }
                let d = hamming_search(&qp, 1, &cp, classes, seg_len).unwrap();
                for (a, v) in acc.iter_mut().zip(d) {
                    *a += v;
                }
            }
            assert_eq!(acc, full, "segment-wise packed distances must sum exactly");
        });
    }

    #[test]
    fn prop_pool_sharded_search_matches_single_thread() {
        // The pool parity property: sharding the AM into class row-blocks
        // must reproduce the single-thread distances bit for bit, for any
        // thread count, class count (incl. fewer classes than threads), and
        // non-word-aligned lengths.
        use crate::util::pool::WorkerPool;
        forall(20, 0xB1B, |rng| {
            let len = 1 + rng.below(200);
            let (batch, classes) = (1 + rng.below(3), 1 + rng.below(24));
            let qs = gen::pm1_vec(rng, batch * len);
            let chvs = gen::pm1_vec(rng, classes * len);
            let qp = pack_rows(&qs, batch, len).unwrap();
            let cp = pack_rows(&chvs, classes, len).unwrap();
            let want = hamming_search(&qp, batch, &cp, classes, len).unwrap();
            for threads in [1usize, 2, 4, 7] {
                let pool = WorkerPool::new(threads);
                let got = hamming_search_pool(&pool, &qp, batch, &cp, classes, len).unwrap();
                assert_eq!(got, want, "threads={threads} classes={classes}");
            }
        });
    }

    #[test]
    fn pool_sharded_search_shares_the_shape_contract() {
        use crate::util::pool::WorkerPool;
        let pool = WorkerPool::new(4);
        let q = vec![0u64; 2];
        let c = vec![0u64; 4];
        assert!(hamming_search_pool(&pool, &[], 0, &c, 2, 100).is_err());
        assert!(hamming_search_pool(&pool, &q, 2, &c, 2, 100).is_err());
        assert!(hamming_search_pool(&pool, &q, 1, &c, 3, 100).is_err());
        assert!(hamming_search_pool(&pool, &q, 1, &c, 2, 100).is_ok());
    }

    #[test]
    fn hamming_search_shape_errors() {
        let q = vec![0u64; 2];
        let c = vec![0u64; 4];
        // batch == 0
        assert!(hamming_search(&[], 0, &c, 2, 100).is_err());
        // qs word-count mismatch (100 bits need 2 words per row)
        assert!(hamming_search(&q, 2, &c, 2, 100).is_err());
        // chvs word-count mismatch
        assert!(hamming_search(&q, 1, &c, 3, 100).is_err());
        assert!(hamming_search(&q, 1, &c, 2, 100).is_ok());
        // errors name the offending dimension
        let err = format!("{:#}", hamming_search(&q, 2, &c, 2, 100).unwrap_err());
        assert!(err.contains("batch 2"), "{err}");
    }

    #[test]
    fn packed_hv_len_mismatch_errors() {
        let a = PackedHv::from_pm1(&[1.0, -1.0]).unwrap();
        let b = PackedHv::from_pm1(&[1.0, -1.0, 1.0]).unwrap();
        assert!(a.hamming(&b).is_err());
    }

    fn tiny() -> HdConfig {
        // seg_len = (32/8) * 32 = 128 elements = 2 words per row
        HdConfig::synthetic("t", 8, 8, 32, 32, 8, 10)
    }

    #[test]
    fn packed_store_binarizes_on_write() {
        let cfg = tiny();
        let mut ps = PackedChvStore::new(&cfg);
        assert_eq!(ps.seg_len(), cfg.seg_len());
        assert_eq!(ps.seg_words(), words_for(cfg.seg_len()));
        let row: Vec<f32> = (0..cfg.seg_len())
            .map(|i| if i % 3 == 0 { -(i as f32) - 1.0 } else { i as f32 })
            .collect();
        ps.write_row(3, 2, &row).unwrap();
        let got = unpack_pm1(ps.class_segment(3, 2), cfg.seg_len());
        let want: Vec<f32> = row
            .iter()
            .map(|&v| if v >= 0.0 { 1.0 } else { -1.0 })
            .collect();
        assert_eq!(got, want);
        // untouched rows keep the zero-view image: binarize(0) = +1
        assert!(unpack_pm1(ps.class_segment(0, 0), cfg.seg_len())
            .iter()
            .all(|&v| v == 1.0));
    }

    #[test]
    fn packed_store_reset_restores_zero_view_image() {
        let cfg = tiny();
        let mut ps = PackedChvStore::new(&cfg);
        ps.write_row(1, 1, &vec![-5.0; cfg.seg_len()]).unwrap();
        ps.reset();
        for s in 0..cfg.segments {
            for c in 0..cfg.classes {
                assert!(unpack_pm1(ps.class_segment(c, s), cfg.seg_len())
                    .iter()
                    .all(|&v| v == 1.0));
            }
        }
    }

    #[test]
    fn packed_store_rejects_bad_writes() {
        let cfg = tiny();
        let mut ps = PackedChvStore::new(&cfg);
        assert!(ps.write_row(99, 0, &vec![0.0; cfg.seg_len()]).is_err());
        assert!(ps.write_row(0, 99, &vec![0.0; cfg.seg_len()]).is_err());
        assert!(ps.write_row(0, 0, &[0.0; 3]).is_err());
    }

    #[test]
    fn packed_residency_is_8x_smaller_than_int8() {
        // the INT1 cache story: 1 bit/element vs 1 byte/element (seg_len is
        // a multiple of 64 here, so no padding slack)
        let cfg = tiny();
        let ps = PackedChvStore::new(&cfg);
        let int8_resident = 3 * cfg.classes * cfg.seg_len(); // bytes
        assert_eq!(ps.bytes_resident(3) * 8, int8_resident);
        assert_eq!(ps.bytes_total(), ps.bytes_resident(cfg.segments));
        assert_eq!(ps.bytes_resident(99), ps.bytes_total());
    }
}
