//! Bit-packed ±1 factor planes and the blocked sign-GEMM encode kernels —
//! the software twin of the chip's encoder datapath (Fig.5: 256 weight bits
//! fetched per cycle feeding 32 adder trees; a ±1 "multiply" is an
//! add/subtract, never a multiplier).
//!
//! Layout: a [`SignMat`] stores one factor matrix as row-major **sign
//! planes** — `words_for(cols)` `u64` words per row, bit set ⇔ entry is +1
//! (the same `v >= 0 → +1` rule as [`crate::hdc::packed::pack_signs`]), tail
//! bits zero. A (d1 × f1) and B (d2 × f2) therefore cost 1 bit per entry
//! instead of 4 bytes, and a whole row's signs arrive in one or two cache
//! lines.
//!
//! Kernels: [`stage1`] computes `T = A_rows @ X` with mask-selected
//! adds — per packed sign bit the operand's IEEE sign bit is XORed
//! (`x ^ sign_mask`), which is exact negation, so `t += (±x)` performs the
//! same add/subtract the scalar reference performs. [`stage2`] computes the
//! raw `Y = T @ B^T` accumulators the same way. Both kernels accumulate in
//! **exactly the scalar reference's order** (stage 1: `j1`-ascending per
//! output element; stage 2: `j2`-ascending per dot product), so the fast
//! path is bit-exact against [`SoftwareEncoder`](crate::hdc::SoftwareEncoder)'s
//! scalar kernel for arbitrary (including negative, non-integer) inputs —
//! the parity property the tests pin.
//!
//! Blocking: stage 1 walks X in [`COL_TILE`]-column tiles (1 KB of f32 — an
//! L1-resident strip of the stage-1 accumulator row), streaming all f1 rows
//! of the tile before moving right; stage 2 processes a small block of B
//! rows per pass (independent accumulator chains hide the f32 add latency
//! that bounds the single-chain scalar loop). No branches depend on the
//! (random) sign data anywhere — the scalar kernel's per-element
//! `if bv >= 0.0` mispredicts ~50% of the time on ±1 factors, which is the
//! other cost the sign-GEMM rewrite removes.
//!
//! Both kernels dispatch through [`crate::hdc::simd`] (stage 1 vectorizes
//! across the tile's output columns, stage 2 across eight B rows — always
//! across independent chains, never within one), and both are generic over
//! [`SignRows`], so they run identically off a stored [`SignMat`] or a
//! seed-derived [`SeededSignMat`] that regenerates rows on the fly.

use crate::hdc::packed::{pack_signs, unpack_pm1, words_for};
use crate::hdc::simd::{self, SimdLevel};
use crate::util::Rng;
use crate::Result;
use anyhow::bail;

/// Stage-1 column tile: 256 f32 = 1 KB of accumulator per strip.
pub const COL_TILE: usize = 256;

/// A ±1 matrix stored as bit-packed sign planes (bit set ⇔ +1), row-major,
/// each row starting on a fresh word with zero tail bits.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SignMat {
    rows: usize,
    cols: usize,
    words_per_row: usize,
    words: Vec<u64>,
}

impl SignMat {
    /// Pack by sign (`v >= 0 → +1`) — binarizes arbitrary values with the
    /// same rule the scalar encode kernel applies to its factors.
    pub fn from_signs(values: &[f32], rows: usize, cols: usize) -> SignMat {
        assert_eq!(
            values.len(),
            rows * cols,
            "SignMat::from_signs: {} values != {rows} x {cols}",
            values.len()
        );
        let words_per_row = words_for(cols);
        let mut words = Vec::with_capacity(rows * words_per_row);
        for r in 0..rows {
            words.extend(pack_signs(&values[r * cols..(r + 1) * cols]));
        }
        SignMat { rows, cols, words_per_row, words }
    }

    /// Pack a strict ±1 matrix; errors on any other value.
    pub fn from_pm1(values: &[f32], rows: usize, cols: usize) -> Result<SignMat> {
        if values.len() != rows * cols {
            bail!("SignMat::from_pm1: {} values != {rows} x {cols}", values.len());
        }
        for (i, &v) in values.iter().enumerate() {
            if v != 1.0 && v != -1.0 {
                bail!("SignMat::from_pm1: element {i} is {v}, expected +-1");
            }
        }
        Ok(SignMat::from_signs(values, rows, cols))
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Words per packed row (`words_for(cols)`).
    pub fn words_per_row(&self) -> usize {
        self.words_per_row
    }

    /// One row's packed sign words.
    pub fn row(&self, r: usize) -> &[u64] {
        &self.words[r * self.words_per_row..(r + 1) * self.words_per_row]
    }

    /// Entry sign as a bit: 1 ⇔ +1.
    pub fn bit(&self, r: usize, c: usize) -> u64 {
        (self.row(r)[c / 64] >> (c % 64)) & 1
    }

    /// Unpack back to a row-major ±1 matrix.
    pub fn to_pm1(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.rows * self.cols);
        for r in 0..self.rows {
            out.extend(unpack_pm1(self.row(r), self.cols));
        }
        out
    }

    /// Packed storage bytes (the 32x story vs f32 factors).
    pub fn bytes(&self) -> usize {
        self.words.len() * 8
    }
}

/// Row access over bit-packed ±1 sign planes — the seam that lets the
/// sign-GEMM kernels run off either a stored [`SignMat`] or a seed-derived
/// [`SeededSignMat`] regenerating rows on the fly.
pub trait SignRows {
    /// Row count.
    fn rows(&self) -> usize;
    /// Column count (elements per row).
    fn cols(&self) -> usize;
    /// Words per packed row (`words_for(cols)`).
    fn words_per_row(&self) -> usize;
    /// Row `r`'s packed sign words, written into `buf` (at least
    /// `words_per_row` long) when the implementation must materialize them.
    fn row_into<'a>(&'a self, r: usize, buf: &'a mut [u64]) -> &'a [u64];
}

impl SignRows for SignMat {
    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn words_per_row(&self) -> usize {
        self.words_per_row
    }

    fn row_into<'a>(&'a self, r: usize, _buf: &'a mut [u64]) -> &'a [u64] {
        self.row(r)
    }
}

/// splitmix64-style avalanche mix: an independent child seed for `stream`
/// derived from `seed`. Used for [`SeededSignMat`]'s per-row streams (and by
/// the encoder for its per-plane streams); a plain `seed + stream` would make
/// adjacent seeds share row streams.
pub fn derive_stream(seed: u64, stream: u64) -> u64 {
    let mut z = seed ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// A **rematerialized** ±1 sign plane: instead of storing `rows × cols` bits,
/// store the RNG seed and regenerate any row's packed words on demand
/// (Schmuck/Benini/Rahimi-style hypervector rematerialization). Registry
/// memory then scales with models × classes instead of models × D × F, and
/// arbitrarily large factor planes stay cache-resident.
///
/// The canonical generation rule — row `r` draws `cols` signs from a fresh
/// `Rng::new(derive_stream(seed, r + 1))` via [`Rng::sign`], packed with the
/// [`pack_signs`] convention (bit set ⇔ +1) — is also how [`materialize`]
/// builds the stored twin, so on-the-fly rows are bit-equal to the stored
/// plane *by construction*, not by test luck.
///
/// [`materialize`]: SeededSignMat::materialize
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SeededSignMat {
    seed: u64,
    rows: usize,
    cols: usize,
    words_per_row: usize,
}

impl SeededSignMat {
    /// A seed-derived `rows × cols` plane. O(1) memory; rows are generated
    /// on access.
    pub fn new(seed: u64, rows: usize, cols: usize) -> SeededSignMat {
        SeededSignMat { seed, rows, cols, words_per_row: words_for(cols) }
    }

    /// The plane's seed (per-row streams are derived from it).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Words per packed row (`words_for(cols)`).
    pub fn words_per_row(&self) -> usize {
        self.words_per_row
    }

    /// Regenerate row `r`'s packed sign words into `buf[..words_per_row]`
    /// (tail bits zero, same layout as [`SignMat::row`]).
    pub fn generate_row(&self, r: usize, buf: &mut [u64]) {
        assert!(r < self.rows, "SeededSignMat row {r} out of {}", self.rows);
        let w = self.words_per_row;
        assert!(buf.len() >= w, "SeededSignMat row buffer {} < {w} words", buf.len());
        let mut rng = Rng::new(derive_stream(self.seed, r as u64 + 1));
        for word in buf[..w].iter_mut() {
            *word = 0;
        }
        for c in 0..self.cols {
            if rng.sign() > 0.0 {
                buf[c / 64] |= 1 << (c % 64);
            }
        }
    }

    /// Row `r` as a ±1 vector (allocates; the reference/scalar path).
    pub fn row_pm1(&self, r: usize) -> Vec<f32> {
        let mut buf = vec![0u64; self.words_per_row];
        self.generate_row(r, &mut buf);
        unpack_pm1(&buf, self.cols)
    }

    /// Materialize the stored twin — the memory-for-compute trade in
    /// reverse. Uses the same per-row generator as [`generate_row`], so the
    /// result is bit-equal to the on-the-fly rows by construction.
    ///
    /// [`generate_row`]: SeededSignMat::generate_row
    pub fn materialize(&self) -> SignMat {
        let mut words = vec![0u64; self.rows * self.words_per_row];
        for r in 0..self.rows {
            let span = &mut words[r * self.words_per_row..(r + 1) * self.words_per_row];
            self.generate_row(r, span);
        }
        SignMat { rows: self.rows, cols: self.cols, words_per_row: self.words_per_row, words }
    }

    /// Unpack to a row-major ±1 matrix (materializes each row).
    pub fn to_pm1(&self) -> Vec<f32> {
        self.materialize().to_pm1()
    }

    /// Resident bytes: seed + geometry only, independent of `rows × cols`.
    pub fn bytes(&self) -> usize {
        std::mem::size_of::<Self>()
    }
}

impl SignRows for SeededSignMat {
    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn words_per_row(&self) -> usize {
        self.words_per_row
    }

    fn row_into<'a>(&'a self, r: usize, buf: &'a mut [u64]) -> &'a [u64] {
        let w = self.words_per_row;
        self.generate_row(r, &mut buf[..w]);
        &buf[..w]
    }
}

/// IEEE sign mask for sign bit `i` of a packed row: 0 for +1 (keep the
/// operand), `1 << 31` for −1 (flip the operand's sign — exact negation).
#[inline(always)]
fn sign_mask(row: &[u64], i: usize) -> u32 {
    ((((row[i / 64] >> (i % 64)) & 1) as u32) ^ 1) << 31
}

/// Stage 1: `T = A[row0..row0+rows] @ X` over one sample, X row-major
/// (f1 × f2), T row-major (rows × f2). Mask-selected adds over
/// [`COL_TILE`]-column tiles at the process-wide SIMD level; per output
/// element the `j1`-ascending accumulation order of the scalar reference is
/// preserved exactly.
pub fn stage1<P: SignRows + ?Sized>(
    a: &P,
    row0: usize,
    rows: usize,
    x: &[f32],
    f2: usize,
    t: &mut [f32],
) {
    stage1_with(simd::active(), a, row0, rows, x, f2, t)
}

/// [`stage1`] at an explicit SIMD level (the differential-test seam). The
/// tile's output columns are independent accumulation chains, so the
/// vectorized sign-apply ([`simd::add_signed`]) is bit-identical to scalar.
pub fn stage1_with<P: SignRows + ?Sized>(
    level: SimdLevel,
    a: &P,
    row0: usize,
    rows: usize,
    x: &[f32],
    f2: usize,
    t: &mut [f32],
) {
    let f1 = a.cols();
    debug_assert_eq!(x.len(), f1 * f2);
    debug_assert!(t.len() >= rows * f2);
    debug_assert!(row0 + rows <= a.rows());
    let mut rbuf = vec![0u64; a.words_per_row()];
    for r in 0..rows {
        let arow = a.row_into(row0 + r, &mut rbuf);
        let trow = &mut t[r * f2..(r + 1) * f2];
        trow.fill(0.0);
        let mut col = 0usize;
        while col < f2 {
            let tile = COL_TILE.min(f2 - col);
            let tchunk = &mut trow[col..col + tile];
            for j1 in 0..f1 {
                let mask = sign_mask(arow, j1);
                let xrow = &x[j1 * f2 + col..j1 * f2 + col + tile];
                simd::add_signed(level, tchunk, xrow, mask);
            }
            col += tile;
        }
    }
}

/// Stage 2 (raw accumulators): `out[r * d2 + i2] = Σ_j2 ±t[r][j2]` with
/// signs from B row `i2`, at the process-wide SIMD level. B rows are
/// processed in small blocks: the per-row accumulator chains are independent,
/// so the f32 add latency overlaps (the single-chain scalar loop is
/// latency-bound on `acc`), while each row's own `j2`-ascending accumulation
/// order — and therefore bit-exact agreement with the scalar reference — is
/// untouched. Quantization is the caller's separate pass (which is what lets
/// calibration reuse this kernel).
pub fn stage2<P: SignRows + ?Sized>(b: &P, t: &[f32], rows: usize, f2: usize, out: &mut [f32]) {
    stage2_with(simd::active(), b, t, rows, f2, out)
}

/// [`stage2`] at an explicit SIMD level (the differential-test seam). The
/// scalar level runs 4-row blocks of scalar chains; wide levels run 8-row
/// blocks through [`simd::dot8_signed`], one lane per B row — either way
/// every output element sees the same `j2`-ascending chain.
pub fn stage2_with<P: SignRows + ?Sized>(
    level: SimdLevel,
    b: &P,
    t: &[f32],
    rows: usize,
    f2: usize,
    out: &mut [f32],
) {
    let d2 = b.rows();
    debug_assert_eq!(b.cols(), f2);
    debug_assert!(t.len() >= rows * f2);
    debug_assert!(out.len() >= rows * d2);
    if level == SimdLevel::Scalar {
        stage2_scalar_level(b, t, rows, f2, d2, out);
    } else {
        stage2_simd_level(level, b, t, rows, f2, d2, out);
    }
}

/// Four B rows per pass, each a scalar accumulator chain.
fn stage2_scalar_level<P: SignRows + ?Sized>(
    b: &P,
    t: &[f32],
    rows: usize,
    f2: usize,
    d2: usize,
    out: &mut [f32],
) {
    let wpr = b.words_per_row();
    let mut scratch = vec![0u64; 4 * wpr];
    let mut i2 = 0usize;
    while i2 + 4 <= d2 {
        let (s0, rest) = scratch.split_at_mut(wpr);
        let (s1, rest) = rest.split_at_mut(wpr);
        let (s2, s3) = rest.split_at_mut(wpr);
        let b0 = b.row_into(i2, s0);
        let b1 = b.row_into(i2 + 1, s1);
        let b2 = b.row_into(i2 + 2, s2);
        let b3 = b.row_into(i2 + 3, s3);
        for r in 0..rows {
            let trow = &t[r * f2..(r + 1) * f2];
            let mut acc = [0.0f32; 4];
            for (j2, &tv) in trow.iter().enumerate() {
                let bits = tv.to_bits();
                acc[0] += f32::from_bits(bits ^ sign_mask(b0, j2));
                acc[1] += f32::from_bits(bits ^ sign_mask(b1, j2));
                acc[2] += f32::from_bits(bits ^ sign_mask(b2, j2));
                acc[3] += f32::from_bits(bits ^ sign_mask(b3, j2));
            }
            out[r * d2 + i2..r * d2 + i2 + 4].copy_from_slice(&acc);
        }
        i2 += 4;
    }
    // tail rows (d2 not a multiple of 4): single-chain, same order
    stage2_tail(b, t, rows, f2, d2, i2, &mut scratch[..wpr], out);
}

/// Eight B rows per pass, one SIMD lane each.
fn stage2_simd_level<P: SignRows + ?Sized>(
    level: SimdLevel,
    b: &P,
    t: &[f32],
    rows: usize,
    f2: usize,
    d2: usize,
    out: &mut [f32],
) {
    let wpr = b.words_per_row();
    let mut scratch = vec![0u64; 8 * wpr];
    let mut i2 = 0usize;
    while i2 + 8 <= d2 {
        let [c0, c1, c2, c3, c4, c5, c6, c7] = split8(&mut scratch, wpr);
        let rows8: [&[u64]; 8] = [
            b.row_into(i2, c0),
            b.row_into(i2 + 1, c1),
            b.row_into(i2 + 2, c2),
            b.row_into(i2 + 3, c3),
            b.row_into(i2 + 4, c4),
            b.row_into(i2 + 5, c5),
            b.row_into(i2 + 6, c6),
            b.row_into(i2 + 7, c7),
        ];
        for r in 0..rows {
            let trow = &t[r * f2..(r + 1) * f2];
            let mut acc = [0.0f32; 8];
            simd::dot8_signed(level, trow, &rows8, &mut acc);
            out[r * d2 + i2..r * d2 + i2 + 8].copy_from_slice(&acc);
        }
        i2 += 8;
    }
    // tail rows (d2 not a multiple of 8): single-chain, same order
    stage2_tail(b, t, rows, f2, d2, i2, &mut scratch[..wpr], out);
}

/// Shared single-chain tail for B rows `i2..d2`.
#[allow(clippy::too_many_arguments)]
fn stage2_tail<P: SignRows + ?Sized>(
    b: &P,
    t: &[f32],
    rows: usize,
    f2: usize,
    d2: usize,
    mut i2: usize,
    rbuf: &mut [u64],
    out: &mut [f32],
) {
    while i2 < d2 {
        let brow = b.row_into(i2, &mut *rbuf);
        for r in 0..rows {
            let trow = &t[r * f2..(r + 1) * f2];
            let mut acc = 0.0f32;
            for (j2, &tv) in trow.iter().enumerate() {
                acc += f32::from_bits(tv.to_bits() ^ sign_mask(brow, j2));
            }
            out[r * d2 + i2] = acc;
        }
        i2 += 1;
    }
}

/// Split a `8 * w`-word scratch buffer into eight disjoint `w`-word rows.
fn split8(buf: &mut [u64], w: usize) -> [&mut [u64]; 8] {
    let (a0, rest) = buf.split_at_mut(w);
    let (a1, rest) = rest.split_at_mut(w);
    let (a2, rest) = rest.split_at_mut(w);
    let (a3, rest) = rest.split_at_mut(w);
    let (a4, rest) = rest.split_at_mut(w);
    let (a5, rest) = rest.split_at_mut(w);
    let (a6, a7) = rest.split_at_mut(w);
    [a0, a1, a2, a3, a4, a5, a6, a7]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};

    #[test]
    fn signmat_roundtrip_and_layout() {
        let vals = [1.0f32, -1.0, -1.0, 1.0, 1.0, 1.0];
        let m = SignMat::from_pm1(&vals, 2, 3).unwrap();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        assert_eq!(m.words_per_row(), 1);
        assert_eq!(m.to_pm1(), vals);
        assert_eq!(m.bit(0, 0), 1);
        assert_eq!(m.bit(0, 1), 0);
        assert_eq!(m.bit(1, 2), 1);
        assert_eq!(m.bytes(), 16);
    }

    #[test]
    fn from_pm1_rejects_non_pm1_and_bad_shapes() {
        assert!(SignMat::from_pm1(&[1.0, 0.5], 1, 2).is_err());
        assert!(SignMat::from_pm1(&[1.0, -1.0], 2, 2).is_err());
        // from_signs binarizes instead
        let m = SignMat::from_signs(&[3.0, -0.25, 0.0], 1, 3);
        assert_eq!(m.to_pm1(), vec![1.0, -1.0, 1.0]);
    }

    #[test]
    fn prop_roundtrip_any_geometry() {
        forall(30, 0x51A, |rng| {
            let rows = 1 + rng.below(5);
            let cols = 1 + rng.below(150); // exercises multi-word rows + tails
            let vals = gen::pm1_vec(rng, rows * cols);
            let m = SignMat::from_pm1(&vals, rows, cols).unwrap();
            assert_eq!(m.to_pm1(), vals);
            assert_eq!(m.words_per_row(), cols.div_ceil(64));
            for r in 0..rows {
                for c in 0..cols {
                    let want = if vals[r * cols + c] > 0.0 { 1 } else { 0 };
                    assert_eq!(m.bit(r, c), want);
                }
            }
        });
    }

    /// Scalar references with the exact accumulation orders the kernels
    /// promise to preserve.
    fn stage1_scalar(
        a: &[f32],
        f1: usize,
        row0: usize,
        rows: usize,
        x: &[f32],
        f2: usize,
    ) -> Vec<f32> {
        let mut t = vec![0.0f32; rows * f2];
        for r in 0..rows {
            let arow = &a[(row0 + r) * f1..(row0 + r + 1) * f1];
            let trow = &mut t[r * f2..(r + 1) * f2];
            for (j1, &av) in arow.iter().enumerate() {
                for (tv, &xv) in trow.iter_mut().zip(&x[j1 * f2..(j1 + 1) * f2]) {
                    if av >= 0.0 {
                        *tv += xv;
                    } else {
                        *tv -= xv;
                    }
                }
            }
        }
        t
    }

    fn stage2_scalar(b: &[f32], d2: usize, t: &[f32], rows: usize, f2: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; rows * d2];
        for r in 0..rows {
            let trow = &t[r * f2..(r + 1) * f2];
            for i2 in 0..d2 {
                let brow = &b[i2 * f2..(i2 + 1) * f2];
                let mut acc = 0.0f32;
                for (&tv, &bv) in trow.iter().zip(brow) {
                    acc += if bv >= 0.0 { tv } else { -tv };
                }
                out[r * d2 + i2] = acc;
            }
        }
        out
    }

    #[test]
    fn prop_stages_bit_exact_vs_scalar_any_dims_and_signs() {
        // Dims deliberately not multiples of 64 (and crossing word
        // boundaries), inputs non-integer and negative: bit-exactness must
        // come from preserved accumulation order, not integer luck.
        forall(25, 0x51B, |rng| {
            let f1 = 1 + rng.below(100);
            let f2 = 1 + rng.below(300);
            let d1 = 1 + rng.below(8);
            let d2 = 1 + rng.below(100);
            let a = gen::pm1_vec(rng, d1 * f1);
            let b = gen::pm1_vec(rng, d2 * f2);
            let x = gen::normal_vec(rng, f1 * f2, 7.5);
            let am = SignMat::from_pm1(&a, d1, f1).unwrap();
            let bm = SignMat::from_pm1(&b, d2, f2).unwrap();
            let mut t = vec![0.0f32; d1 * f2];
            stage1(&am, 0, d1, &x, f2, &mut t);
            let t_ref = stage1_scalar(&a, f1, 0, d1, &x, f2);
            assert_eq!(t, t_ref, "stage1 f1={f1} f2={f2} d1={d1}");
            let mut y = vec![0.0f32; d1 * d2];
            stage2(&bm, &t, d1, f2, &mut y);
            let y_ref = stage2_scalar(&b, d2, &t_ref, d1, f2);
            assert_eq!(y, y_ref, "stage2 f2={f2} d2={d2}");
        });
    }

    #[test]
    fn stage1_respects_row_window() {
        let mut rng = crate::util::Rng::new(9);
        let (d1, f1, f2) = (6usize, 10usize, 70usize);
        let a = gen::pm1_vec(&mut rng, d1 * f1);
        let x = gen::normal_vec(&mut rng, f1 * f2, 3.0);
        let am = SignMat::from_pm1(&a, d1, f1).unwrap();
        let mut full = vec![0.0f32; d1 * f2];
        stage1(&am, 0, d1, &x, f2, &mut full);
        let mut window = vec![0.0f32; 2 * f2];
        stage1(&am, 3, 2, &x, f2, &mut window);
        assert_eq!(&window[..], &full[3 * f2..5 * f2]);
    }

    #[test]
    fn seeded_rows_equal_materialized_plane() {
        let sm = SeededSignMat::new(0xC0FFEE, 9, 130);
        let stored = sm.materialize();
        assert_eq!(SignRows::rows(&stored), 9);
        assert_eq!(SignRows::cols(&stored), 130);
        let mut buf = vec![0u64; sm.words_per_row()];
        for r in 0..9 {
            sm.generate_row(r, &mut buf);
            assert_eq!(&buf[..], stored.row(r), "row {r}");
            assert_eq!(sm.row_pm1(r), unpack_pm1(stored.row(r), 130));
        }
        assert_eq!(sm.to_pm1(), stored.to_pm1());
        // tail bits beyond cols stay zero (the word-granular invariant)
        assert_eq!(buf[sm.words_per_row() - 1] >> (130 % 64), 0);
        // O(1) resident cost vs the stored plane
        assert!(sm.bytes() < stored.bytes());
    }

    #[test]
    fn derive_stream_separates_adjacent_seeds_and_streams() {
        assert_ne!(derive_stream(1, 0), derive_stream(0, 1));
        assert_ne!(derive_stream(5, 2), derive_stream(5, 3));
        assert_ne!(derive_stream(5, 2), derive_stream(6, 2));
    }

    #[test]
    fn prop_stages_with_levels_bit_exact_stored_and_seeded() {
        // Scalar vs the host's widest level, over stored and rematerialized
        // planes, on dims that exercise vector bodies and ragged tails.
        let levels = [SimdLevel::Scalar, simd::detect()];
        forall(10, 0x51C, |rng| {
            let f1 = 1 + rng.below(70);
            let f2 = 1 + rng.below(200);
            let d1 = 1 + rng.below(6);
            let d2 = 1 + rng.below(70);
            let seeded_a = SeededSignMat::new(rng.next_u64(), d1, f1);
            let seeded_b = SeededSignMat::new(rng.next_u64(), d2, f2);
            let stored_a = seeded_a.materialize();
            let stored_b = seeded_b.materialize();
            let x = gen::normal_vec(rng, f1 * f2, 4.0);
            let mut t_ref = vec![0.0f32; d1 * f2];
            stage1_with(SimdLevel::Scalar, &stored_a, 0, d1, &x, f2, &mut t_ref);
            let mut y_ref = vec![0.0f32; d1 * d2];
            stage2_with(SimdLevel::Scalar, &stored_b, &t_ref, d1, f2, &mut y_ref);
            for &lvl in &levels {
                for seeded in [false, true] {
                    let mut t = vec![0.0f32; d1 * f2];
                    let mut y = vec![0.0f32; d1 * d2];
                    if seeded {
                        stage1_with(lvl, &seeded_a, 0, d1, &x, f2, &mut t);
                        stage2_with(lvl, &seeded_b, &t, d1, f2, &mut y);
                    } else {
                        stage1_with(lvl, &stored_a, 0, d1, &x, f2, &mut t);
                        stage2_with(lvl, &stored_b, &t, d1, f2, &mut y);
                    }
                    assert_eq!(t, t_ref, "stage1 lvl={lvl:?} seeded={seeded}");
                    assert_eq!(y, y_ref, "stage2 lvl={lvl:?} seeded={seeded}");
                }
            }
        });
    }
}
