//! Bit-packed ±1 factor planes and the blocked sign-GEMM encode kernels —
//! the software twin of the chip's encoder datapath (Fig.5: 256 weight bits
//! fetched per cycle feeding 32 adder trees; a ±1 "multiply" is an
//! add/subtract, never a multiplier).
//!
//! Layout: a [`SignMat`] stores one factor matrix as row-major **sign
//! planes** — `words_for(cols)` `u64` words per row, bit set ⇔ entry is +1
//! (the same `v >= 0 → +1` rule as [`crate::hdc::packed::pack_signs`]), tail
//! bits zero. A (d1 × f1) and B (d2 × f2) therefore cost 1 bit per entry
//! instead of 4 bytes, and a whole row's signs arrive in one or two cache
//! lines.
//!
//! Kernels: [`stage1`] computes `T = A_rows @ X` with mask-selected
//! adds — per packed sign bit the operand's IEEE sign bit is XORed
//! (`x ^ sign_mask`), which is exact negation, so `t += (±x)` performs the
//! same add/subtract the scalar reference performs. [`stage2`] computes the
//! raw `Y = T @ B^T` accumulators the same way. Both kernels accumulate in
//! **exactly the scalar reference's order** (stage 1: `j1`-ascending per
//! output element; stage 2: `j2`-ascending per dot product), so the fast
//! path is bit-exact against [`SoftwareEncoder`](crate::hdc::SoftwareEncoder)'s
//! scalar kernel for arbitrary (including negative, non-integer) inputs —
//! the parity property the tests pin.
//!
//! Blocking: stage 1 walks X in [`COL_TILE`]-column tiles (1 KB of f32 — an
//! L1-resident strip of the stage-1 accumulator row), streaming all f1 rows
//! of the tile before moving right; stage 2 processes four B rows per pass
//! (four independent accumulator chains hide the f32 add latency that
//! bounds the single-chain scalar loop). No branches depend on the (random)
//! sign data anywhere — the scalar kernel's per-element `if bv >= 0.0`
//! mispredicts ~50% of the time on ±1 factors, which is the other cost the
//! sign-GEMM rewrite removes.

use crate::hdc::packed::{pack_signs, unpack_pm1, words_for};
use crate::Result;
use anyhow::bail;

/// Stage-1 column tile: 256 f32 = 1 KB of accumulator per strip.
pub const COL_TILE: usize = 256;

/// A ±1 matrix stored as bit-packed sign planes (bit set ⇔ +1), row-major,
/// each row starting on a fresh word with zero tail bits.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SignMat {
    rows: usize,
    cols: usize,
    words_per_row: usize,
    words: Vec<u64>,
}

impl SignMat {
    /// Pack by sign (`v >= 0 → +1`) — binarizes arbitrary values with the
    /// same rule the scalar encode kernel applies to its factors.
    pub fn from_signs(values: &[f32], rows: usize, cols: usize) -> SignMat {
        assert_eq!(
            values.len(),
            rows * cols,
            "SignMat::from_signs: {} values != {rows} x {cols}",
            values.len()
        );
        let words_per_row = words_for(cols);
        let mut words = Vec::with_capacity(rows * words_per_row);
        for r in 0..rows {
            words.extend(pack_signs(&values[r * cols..(r + 1) * cols]));
        }
        SignMat { rows, cols, words_per_row, words }
    }

    /// Pack a strict ±1 matrix; errors on any other value.
    pub fn from_pm1(values: &[f32], rows: usize, cols: usize) -> Result<SignMat> {
        if values.len() != rows * cols {
            bail!("SignMat::from_pm1: {} values != {rows} x {cols}", values.len());
        }
        for (i, &v) in values.iter().enumerate() {
            if v != 1.0 && v != -1.0 {
                bail!("SignMat::from_pm1: element {i} is {v}, expected +-1");
            }
        }
        Ok(SignMat::from_signs(values, rows, cols))
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Words per packed row (`words_for(cols)`).
    pub fn words_per_row(&self) -> usize {
        self.words_per_row
    }

    /// One row's packed sign words.
    pub fn row(&self, r: usize) -> &[u64] {
        &self.words[r * self.words_per_row..(r + 1) * self.words_per_row]
    }

    /// Entry sign as a bit: 1 ⇔ +1.
    pub fn bit(&self, r: usize, c: usize) -> u64 {
        (self.row(r)[c / 64] >> (c % 64)) & 1
    }

    /// Unpack back to a row-major ±1 matrix.
    pub fn to_pm1(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.rows * self.cols);
        for r in 0..self.rows {
            out.extend(unpack_pm1(self.row(r), self.cols));
        }
        out
    }

    /// Packed storage bytes (the 32x story vs f32 factors).
    pub fn bytes(&self) -> usize {
        self.words.len() * 8
    }
}

/// IEEE sign mask for sign bit `i` of a packed row: 0 for +1 (keep the
/// operand), `1 << 31` for −1 (flip the operand's sign — exact negation).
#[inline(always)]
fn sign_mask(row: &[u64], i: usize) -> u32 {
    ((((row[i / 64] >> (i % 64)) & 1) as u32) ^ 1) << 31
}

/// Stage 1: `T = A[row0..row0+rows] @ X` over one sample, X row-major
/// (f1 × f2), T row-major (rows × f2). Mask-selected adds over
/// [`COL_TILE`]-column tiles; per output element the `j1`-ascending
/// accumulation order of the scalar reference is preserved exactly.
pub fn stage1(a: &SignMat, row0: usize, rows: usize, x: &[f32], f2: usize, t: &mut [f32]) {
    let f1 = a.cols();
    debug_assert_eq!(x.len(), f1 * f2);
    debug_assert!(t.len() >= rows * f2);
    debug_assert!(row0 + rows <= a.rows());
    for r in 0..rows {
        let arow = a.row(row0 + r);
        let trow = &mut t[r * f2..(r + 1) * f2];
        trow.fill(0.0);
        let mut col = 0usize;
        while col < f2 {
            let tile = COL_TILE.min(f2 - col);
            let tchunk = &mut trow[col..col + tile];
            for j1 in 0..f1 {
                let mask = sign_mask(arow, j1);
                let xrow = &x[j1 * f2 + col..j1 * f2 + col + tile];
                for (tv, &xv) in tchunk.iter_mut().zip(xrow) {
                    *tv += f32::from_bits(xv.to_bits() ^ mask);
                }
            }
            col += tile;
        }
    }
}

/// Stage 2 (raw accumulators): `out[r * d2 + i2] = Σ_j2 ±t[r][j2]` with
/// signs from B row `i2`. B rows are processed **four at a time**: the four
/// accumulator chains are independent, so the f32 add latency overlaps
/// (the single-chain scalar loop is latency-bound on `acc`), while each
/// row's own `j2`-ascending accumulation order — and therefore bit-exact
/// agreement with the scalar reference — is untouched. Quantization is the
/// caller's separate pass (which is what lets calibration reuse this
/// kernel).
pub fn stage2(b: &SignMat, t: &[f32], rows: usize, f2: usize, out: &mut [f32]) {
    let d2 = b.rows();
    debug_assert_eq!(b.cols(), f2);
    debug_assert!(t.len() >= rows * f2);
    debug_assert!(out.len() >= rows * d2);
    for r in 0..rows {
        let trow = &t[r * f2..(r + 1) * f2];
        let orow = &mut out[r * d2..(r + 1) * d2];
        let mut i2 = 0usize;
        while i2 + 4 <= d2 {
            let (b0, b1, b2, b3) =
                (b.row(i2), b.row(i2 + 1), b.row(i2 + 2), b.row(i2 + 3));
            let mut acc = [0.0f32; 4];
            for (j2, &tv) in trow.iter().enumerate() {
                let bits = tv.to_bits();
                acc[0] += f32::from_bits(bits ^ sign_mask(b0, j2));
                acc[1] += f32::from_bits(bits ^ sign_mask(b1, j2));
                acc[2] += f32::from_bits(bits ^ sign_mask(b2, j2));
                acc[3] += f32::from_bits(bits ^ sign_mask(b3, j2));
            }
            orow[i2..i2 + 4].copy_from_slice(&acc);
            i2 += 4;
        }
        // tail rows (d2 not a multiple of 4): single-chain, same order
        while i2 < d2 {
            let brow = b.row(i2);
            let mut acc = 0.0f32;
            for (j2, &tv) in trow.iter().enumerate() {
                acc += f32::from_bits(tv.to_bits() ^ sign_mask(brow, j2));
            }
            orow[i2] = acc;
            i2 += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};

    #[test]
    fn signmat_roundtrip_and_layout() {
        let vals = [1.0f32, -1.0, -1.0, 1.0, 1.0, 1.0];
        let m = SignMat::from_pm1(&vals, 2, 3).unwrap();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        assert_eq!(m.words_per_row(), 1);
        assert_eq!(m.to_pm1(), vals);
        assert_eq!(m.bit(0, 0), 1);
        assert_eq!(m.bit(0, 1), 0);
        assert_eq!(m.bit(1, 2), 1);
        assert_eq!(m.bytes(), 16);
    }

    #[test]
    fn from_pm1_rejects_non_pm1_and_bad_shapes() {
        assert!(SignMat::from_pm1(&[1.0, 0.5], 1, 2).is_err());
        assert!(SignMat::from_pm1(&[1.0, -1.0], 2, 2).is_err());
        // from_signs binarizes instead
        let m = SignMat::from_signs(&[3.0, -0.25, 0.0], 1, 3);
        assert_eq!(m.to_pm1(), vec![1.0, -1.0, 1.0]);
    }

    #[test]
    fn prop_roundtrip_any_geometry() {
        forall(30, 0x51A, |rng| {
            let rows = 1 + rng.below(5);
            let cols = 1 + rng.below(150); // exercises multi-word rows + tails
            let vals = gen::pm1_vec(rng, rows * cols);
            let m = SignMat::from_pm1(&vals, rows, cols).unwrap();
            assert_eq!(m.to_pm1(), vals);
            assert_eq!(m.words_per_row(), cols.div_ceil(64));
            for r in 0..rows {
                for c in 0..cols {
                    let want = if vals[r * cols + c] > 0.0 { 1 } else { 0 };
                    assert_eq!(m.bit(r, c), want);
                }
            }
        });
    }

    /// Scalar references with the exact accumulation orders the kernels
    /// promise to preserve.
    fn stage1_scalar(
        a: &[f32],
        f1: usize,
        row0: usize,
        rows: usize,
        x: &[f32],
        f2: usize,
    ) -> Vec<f32> {
        let mut t = vec![0.0f32; rows * f2];
        for r in 0..rows {
            let arow = &a[(row0 + r) * f1..(row0 + r + 1) * f1];
            let trow = &mut t[r * f2..(r + 1) * f2];
            for (j1, &av) in arow.iter().enumerate() {
                for (tv, &xv) in trow.iter_mut().zip(&x[j1 * f2..(j1 + 1) * f2]) {
                    if av >= 0.0 {
                        *tv += xv;
                    } else {
                        *tv -= xv;
                    }
                }
            }
        }
        t
    }

    fn stage2_scalar(b: &[f32], d2: usize, t: &[f32], rows: usize, f2: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; rows * d2];
        for r in 0..rows {
            let trow = &t[r * f2..(r + 1) * f2];
            for i2 in 0..d2 {
                let brow = &b[i2 * f2..(i2 + 1) * f2];
                let mut acc = 0.0f32;
                for (&tv, &bv) in trow.iter().zip(brow) {
                    acc += if bv >= 0.0 { tv } else { -tv };
                }
                out[r * d2 + i2] = acc;
            }
        }
        out
    }

    #[test]
    fn prop_stages_bit_exact_vs_scalar_any_dims_and_signs() {
        // Dims deliberately not multiples of 64 (and crossing word
        // boundaries), inputs non-integer and negative: bit-exactness must
        // come from preserved accumulation order, not integer luck.
        forall(25, 0x51B, |rng| {
            let f1 = 1 + rng.below(100);
            let f2 = 1 + rng.below(300);
            let d1 = 1 + rng.below(8);
            let d2 = 1 + rng.below(100);
            let a = gen::pm1_vec(rng, d1 * f1);
            let b = gen::pm1_vec(rng, d2 * f2);
            let x = gen::normal_vec(rng, f1 * f2, 7.5);
            let am = SignMat::from_pm1(&a, d1, f1).unwrap();
            let bm = SignMat::from_pm1(&b, d2, f2).unwrap();
            let mut t = vec![0.0f32; d1 * f2];
            stage1(&am, 0, d1, &x, f2, &mut t);
            let t_ref = stage1_scalar(&a, f1, 0, d1, &x, f2);
            assert_eq!(t, t_ref, "stage1 f1={f1} f2={f2} d1={d1}");
            let mut y = vec![0.0f32; d1 * d2];
            stage2(&bm, &t, d1, f2, &mut y);
            let y_ref = stage2_scalar(&b, d2, &t_ref, d1, f2);
            assert_eq!(y, y_ref, "stage2 f2={f2} d2={d2}");
        });
    }

    #[test]
    fn stage1_respects_row_window() {
        let mut rng = crate::util::Rng::new(9);
        let (d1, f1, f2) = (6usize, 10usize, 70usize);
        let a = gen::pm1_vec(&mut rng, d1 * f1);
        let x = gen::normal_vec(&mut rng, f1 * f2, 3.0);
        let am = SignMat::from_pm1(&a, d1, f1).unwrap();
        let mut full = vec![0.0f32; d1 * f2];
        stage1(&am, 0, d1, &x, f2, &mut full);
        let mut window = vec![0.0f32; 2 * f2];
        stage1(&am, 3, 2, &x, f2, &mut window);
        assert_eq!(&window[..], &full[3 * f2..5 * f2]);
    }
}
