//! Software Kronecker HD encoder (Fig.5) — the pure-Rust reference for the
//! AOT Pallas kernel, the fallback backend, and the op/memory cost model the
//! Fig.5 comparison bench is built on.
//!
//! Encoding: QHV = quantize(vec(A_seg @ X @ B^T)) with X = reshape(x, f1, f2)
//! and +-1 factors A (d1 x f1), B (d2 x f2). Because A and B are +-1, every
//! "multiply" in stage 1/2 is an add/subtract — the chip's adder trees; we
//! count ops accordingly in [`kron_cost`].
//!
//! Two interchangeable kernels serve the same math ([`EncodeKernel`]):
//! * `Scalar` — the original branchy triple loop, kept as the reference;
//! * `SignGemm` (default) — the blocked sign-GEMM over bit-packed
//!   [`SignMat`] sign planes ([`crate::hdc::signmat`]): mask-selected adds,
//!   no data-dependent branches, bit-exact to `Scalar` because both preserve
//!   the same per-element accumulation order.
//!
//! Both kernels share one raw-accumulator core (`encode_rows_raw`), which is
//! what [`SoftwareEncoder::calibrate`] drives too — calibration always
//! exercises whichever kernel serves traffic instead of re-implementing the
//! loops. Factor planes come in two representations behind one seam:
//! stored ([`SoftwareEncoder::new`]/[`SoftwareEncoder::random`]) or
//! seed-derived **rematerialized** ([`SoftwareEncoder::random_remat`]),
//! which keeps only the plane seeds resident and regenerates rows inside
//! the kernels — bit-identical to the stored twin by construction. [`SoftwareEncoder::encode_batch`] is the batched engine: it
//! amortizes the per-sample reshape across rows, optionally shards rows over
//! a [`WorkerPool`], and emits word-granular bit-packed QHV segments next to
//! the INT8 values so the progressive-search packed path consumes encoder
//! output with zero repacking.

use crate::config::HdConfig;
use crate::hdc::packed;
use crate::hdc::quantize;
use crate::hdc::signmat::{self, derive_stream, SeededSignMat, SignMat};
use crate::hdc::HdBackend;
use crate::util::pool::WorkerPool;
use crate::util::Rng;
use crate::Result;
use anyhow::bail;

/// Which encode kernel serves traffic (both are bit-exact to each other).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EncodeKernel {
    /// The original branchy scalar loops (reference / parity baseline).
    Scalar,
    /// Blocked sign-GEMM over bit-packed sign planes (the fast default).
    #[default]
    SignGemm,
}

impl EncodeKernel {
    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> Result<EncodeKernel> {
        match s {
            "scalar" => Ok(EncodeKernel::Scalar),
            "signgemm" | "sign-gemm" | "gemm" => Ok(EncodeKernel::SignGemm),
            other => bail!("unknown encode kernel '{other}' (scalar|signgemm)"),
        }
    }
}

/// How the ±1 factor planes are held (private: kernels and accessors are the
/// only readers, so the representations can never desync).
#[derive(Clone, Debug)]
enum FactorPlanes {
    /// Dense f32 factors plus their bit-packed sign planes, built once at
    /// construction from the same values.
    Stored { a: Vec<f32>, b: Vec<f32>, a_signs: SignMat, b_signs: SignMat },
    /// Seed-derived **rematerialized** planes: only the seeds + geometry are
    /// resident; rows regenerate on the fly inside the sign-GEMM kernels.
    Seeded { a_signs: SeededSignMat, b_signs: SeededSignMat },
}

/// Stream ids for the seed-derived factor planes. Fixed constants so a
/// rematerializing encoder and its materialized twin
/// ([`SoftwareEncoder::random_remat_materialized`]) agree by construction.
const A_PLANE_STREAM: u64 = 1;
const B_PLANE_STREAM: u64 = 2;

/// Pure-Rust Kronecker encoder + L1 search backend.
#[derive(Clone, Debug)]
pub struct SoftwareEncoder {
    cfg: HdConfig,
    planes: FactorPlanes,
    /// scratch for stage-1 output (seg_rows x f2 max = d1 x f2)
    scratch: Vec<f32>,
    kernel: EncodeKernel,
}

impl SoftwareEncoder {
    pub fn new(cfg: HdConfig, a: Vec<f32>, b: Vec<f32>) -> Result<SoftwareEncoder> {
        if a.len() != cfg.d1 * cfg.f1 {
            bail!("A has {} elements, expected {}", a.len(), cfg.d1 * cfg.f1);
        }
        if b.len() != cfg.d2 * cfg.f2 {
            bail!("B has {} elements, expected {}", b.len(), cfg.d2 * cfg.f2);
        }
        let scratch = vec![0.0; cfg.d1 * cfg.f2];
        // from_signs (not from_pm1): the sign planes binarize with the same
        // `v >= 0` rule the scalar kernel applies, so both kernels agree
        // even on degenerate non-±1 factors.
        let a_signs = SignMat::from_signs(&a, cfg.d1, cfg.f1);
        let b_signs = SignMat::from_signs(&b, cfg.d2, cfg.f2);
        let planes = FactorPlanes::Stored { a, b, a_signs, b_signs };
        let kernel = EncodeKernel::default();
        Ok(SoftwareEncoder { cfg, planes, scratch, kernel })
    }

    /// Random +-1 factors (matches the build-time generator's distribution;
    /// exact factor values come from artifacts/hd_factors_<cfg>.bin in
    /// production).
    pub fn random(cfg: HdConfig, seed: u64) -> SoftwareEncoder {
        let mut rng = Rng::new(seed);
        let a = (0..cfg.d1 * cfg.f1).map(|_| rng.sign()).collect();
        let b = (0..cfg.d2 * cfg.f2).map(|_| rng.sign()).collect();
        SoftwareEncoder::new(cfg, a, b).unwrap()
    }

    /// Random seed-derived factors held as **rematerialized** planes: only
    /// the two plane seeds stay resident ([`SoftwareEncoder::factor_bytes`]
    /// is O(1) instead of O(D·F)), and the sign-GEMM kernels regenerate rows
    /// on the fly. Encodes are bit-identical to the stored twin built by
    /// [`SoftwareEncoder::random_remat_materialized`] from the same seed.
    ///
    /// Note the factor *values* differ from [`SoftwareEncoder::random`] at
    /// the same seed: remat planes draw per-row streams (so any row is
    /// reachable in O(cols)), while `random` draws one sequential stream.
    pub fn random_remat(cfg: HdConfig, seed: u64) -> SoftwareEncoder {
        let a_signs = SeededSignMat::new(derive_stream(seed, A_PLANE_STREAM), cfg.d1, cfg.f1);
        let b_signs = SeededSignMat::new(derive_stream(seed, B_PLANE_STREAM), cfg.d2, cfg.f2);
        let scratch = vec![0.0; cfg.d1 * cfg.f2];
        let planes = FactorPlanes::Seeded { a_signs, b_signs };
        SoftwareEncoder { cfg, planes, scratch, kernel: EncodeKernel::default() }
    }

    /// The stored twin of [`SoftwareEncoder::random_remat`]: same seed, same
    /// factor values, but fully materialized planes. Exists so bit-equality
    /// of the two representations is pinned by construction (the tests
    /// encode through both and compare).
    pub fn random_remat_materialized(cfg: HdConfig, seed: u64) -> SoftwareEncoder {
        let a = SeededSignMat::new(derive_stream(seed, A_PLANE_STREAM), cfg.d1, cfg.f1).to_pm1();
        let b = SeededSignMat::new(derive_stream(seed, B_PLANE_STREAM), cfg.d2, cfg.f2).to_pm1();
        SoftwareEncoder::new(cfg, a, b).expect("remat factor shapes are correct by construction")
    }

    /// Whether the factor planes are rematerialized (seed-derived).
    pub fn is_remat(&self) -> bool {
        matches!(self.planes, FactorPlanes::Seeded { .. })
    }

    /// Resident factor memory in bytes: dense f32 factors + packed sign
    /// planes when stored; a few words of seed + geometry when
    /// rematerialized (the models × classes registry-memory story).
    pub fn factor_bytes(&self) -> usize {
        match &self.planes {
            FactorPlanes::Stored { a, b, a_signs, b_signs } => {
                (a.len() + b.len()) * std::mem::size_of::<f32>() + a_signs.bytes() + b_signs.bytes()
            }
            FactorPlanes::Seeded { a_signs, b_signs } => a_signs.bytes() + b_signs.bytes(),
        }
    }

    /// The A factor, (d1, f1) row-major ±1. Stored planes return the
    /// constructor's dense factor; rematerialized planes regenerate it
    /// (an O(d1·f1) materialization per call).
    pub fn a(&self) -> Vec<f32> {
        match &self.planes {
            FactorPlanes::Stored { a, .. } => a.clone(),
            FactorPlanes::Seeded { a_signs, .. } => a_signs.to_pm1(),
        }
    }

    /// The B factor, (d2, f2) row-major ±1 (see [`SoftwareEncoder::a`]).
    pub fn b(&self) -> Vec<f32> {
        match &self.planes {
            FactorPlanes::Stored { b, .. } => b.clone(),
            FactorPlanes::Seeded { b_signs, .. } => b_signs.to_pm1(),
        }
    }

    /// The kernel currently serving encode traffic.
    pub fn kernel(&self) -> EncodeKernel {
        self.kernel
    }

    /// Switch the encode kernel (bench/ablation hook; results are
    /// bit-identical either way).
    pub fn set_kernel(&mut self, kernel: EncodeKernel) {
        self.kernel = kernel;
    }

    /// Set `scale_q` so the raw accumulator range maps onto INT8 without
    /// saturation — the Rust twin of aot.py's build-time calibration (the
    /// AOT artifacts bake the python-calibrated value; synthetic/bench
    /// configs must call this before training or QHVs clip to +-127 and
    /// bundling degenerates). Runs the *serving* encode kernel's raw pass,
    /// so calibration can never drift from the traffic path.
    pub fn calibrate(&mut self, xs: &[f32], batch: usize) {
        let (feat, d1, d2) = (self.cfg.features(), self.cfg.d1, self.cfg.d2);
        let mut scratch = std::mem::take(&mut self.scratch);
        let mut raw = vec![0.0f32; d1 * d2];
        let mut max_abs = 0.0f32;
        for n in 0..batch {
            self.encode_rows_raw(&xs[n * feat..(n + 1) * feat], 0, d1, &mut scratch, &mut raw);
            for &v in &raw {
                max_abs = max_abs.max(v.abs());
            }
        }
        self.scratch = scratch;
        if max_abs > 0.0 {
            self.cfg.scale_q = max_abs / 127.0;
        }
    }

    /// Raw (unquantized) accumulators of rows [row0, row0+rows) of A against
    /// one feature vector: `out[r * d2 + i2] = Σ ±x` — the shared core both
    /// kernels implement and calibration reuses. `scratch` holds the stage-1
    /// strip (>= rows * f2).
    fn encode_rows_raw(
        &self,
        x: &[f32],
        row0: usize,
        rows: usize,
        scratch: &mut [f32],
        out: &mut [f32],
    ) {
        let (f1, f2, d2) = (self.cfg.f1, self.cfg.f2, self.cfg.d2);
        debug_assert_eq!(x.len(), f1 * f2);
        debug_assert!(out.len() >= rows * d2);
        match (&self.planes, self.kernel) {
            (FactorPlanes::Stored { a_signs, b_signs, .. }, EncodeKernel::SignGemm) => {
                signmat::stage1(a_signs, row0, rows, x, f2, scratch);
                signmat::stage2(b_signs, scratch, rows, f2, out);
            }
            (FactorPlanes::Seeded { a_signs, b_signs }, EncodeKernel::SignGemm) => {
                // the rematerialized hot path: identical kernels, rows
                // regenerated from the seed inside stage1/stage2
                signmat::stage1(a_signs, row0, rows, x, f2, scratch);
                signmat::stage2(b_signs, scratch, rows, f2, out);
            }
            (FactorPlanes::Stored { a, b, .. }, EncodeKernel::Scalar) => {
                for r in 0..rows {
                    let arow = &a[(row0 + r) * f1..(row0 + r + 1) * f1];
                    scalar_stage1_row(arow, x, f2, &mut scratch[r * f2..(r + 1) * f2]);
                }
                for r in 0..rows {
                    let trow = &scratch[r * f2..(r + 1) * f2];
                    for i2 in 0..d2 {
                        out[r * d2 + i2] = scalar_stage2_row(&b[i2 * f2..(i2 + 1) * f2], trow);
                    }
                }
            }
            (FactorPlanes::Seeded { a_signs, b_signs }, EncodeKernel::Scalar) => {
                // reference path for remat planes: regenerate each ±1 row
                // and run the same branchy loops (bit unpacking yields exact
                // ±1, so scalar and sign-GEMM stay bit-identical here too)
                for r in 0..rows {
                    let arow = a_signs.row_pm1(row0 + r);
                    scalar_stage1_row(&arow, x, f2, &mut scratch[r * f2..(r + 1) * f2]);
                }
                for i2 in 0..d2 {
                    let brow = b_signs.row_pm1(i2);
                    for r in 0..rows {
                        let trow = &scratch[r * f2..(r + 1) * f2];
                        out[r * d2 + i2] = scalar_stage2_row(&brow, trow);
                    }
                }
            }
        }
    }

    /// Encode rows [row0, row0+rows) of A against one feature vector,
    /// writing `rows * d2` QHV values into `out`.
    fn encode_rows(&mut self, x: &[f32], row0: usize, rows: usize, out: &mut [f32]) {
        let mut scratch = std::mem::take(&mut self.scratch);
        self.encode_rows_raw(x, row0, rows, &mut scratch, out);
        self.scratch = scratch;
        quantize::quantize_slice(out, self.cfg.qbits, self.cfg.scale_q);
    }

    /// Batched QHV encode: xs (batch, F) -> (batch, D), optionally sharding
    /// samples over `pool`. Bit-identical to per-sample `encode_full`.
    pub fn encode_qhvs(
        &self,
        xs: &[f32],
        batch: usize,
        pool: Option<&WorkerPool>,
    ) -> Result<Vec<f32>> {
        let (feat, dim, d1) = (self.cfg.features(), self.cfg.dim(), self.cfg.d1);
        if batch == 0 {
            bail!("encode_qhvs: batch must be >= 1, got 0");
        }
        if xs.len() != batch * feat {
            bail!("xs len {} != batch {batch} * F {feat}", xs.len());
        }
        let (qbits, scale, f2) = (self.cfg.qbits, self.cfg.scale_q, self.cfg.f2);
        let mut qhvs = vec![0.0f32; batch * dim];
        let encode_block = |first_row: usize, block: &mut [f32]| {
            let mut scratch = vec![0.0f32; d1 * f2];
            for (i, orow) in block.chunks_mut(dim).enumerate() {
                let n = first_row + i;
                self.encode_rows_raw(&xs[n * feat..(n + 1) * feat], 0, d1, &mut scratch, orow);
                quantize::quantize_slice(orow, qbits, scale);
            }
        };
        match pool {
            Some(p) if !p.is_serial() => p.run_rows(&mut qhvs, dim, encode_block),
            _ => encode_block(0, &mut qhvs),
        }
        Ok(qhvs)
    }

    /// The batched encode engine: INT8 QHVs plus their word-granular
    /// bit-packed segment image in one pass, sharded over `pool` when given.
    /// The packed rows use exactly the [`packed`] segment layout (each
    /// segment starts a fresh word, zero tails), so progressive search and
    /// `hamming_search` consume them with zero repacking.
    pub fn encode_batch(
        &self,
        xs: &[f32],
        batch: usize,
        pool: Option<&WorkerPool>,
    ) -> Result<EncodedBatch> {
        let qhvs = self.encode_qhvs(xs, batch, pool)?;
        let dim = self.cfg.dim();
        let (segments, seg_len) = (self.cfg.segments, self.cfg.seg_len());
        let seg_words = packed::words_for(seg_len);
        let row_words = segments * seg_words;
        let mut packed_rows = vec![0u64; batch * row_words];
        let pack_block = |first_row: usize, block: &mut [u64]| {
            for (i, prow) in block.chunks_mut(row_words).enumerate() {
                let q = &qhvs[(first_row + i) * dim..(first_row + i + 1) * dim];
                for s in 0..segments {
                    let words = packed::pack_signs(&q[s * seg_len..(s + 1) * seg_len]);
                    prow[s * seg_words..(s + 1) * seg_words].copy_from_slice(&words);
                }
            }
        };
        match pool {
            Some(p) if !p.is_serial() => p.run_rows(&mut packed_rows, row_words, pack_block),
            _ => pack_block(0, &mut packed_rows),
        }
        Ok(EncodedBatch {
            batch,
            dim,
            segments,
            seg_len,
            seg_words,
            qhvs,
            packed: packed_rows,
        })
    }
}

/// Reference scalar stage 1 for one A row: `trow = ±x` accumulated
/// `j1`-ascending with the branchy `aval >= 0.0` sign select — the
/// accumulation order every fast kernel must preserve.
fn scalar_stage1_row(arow: &[f32], x: &[f32], f2: usize, trow: &mut [f32]) {
    trow.fill(0.0);
    for (j1, &aval) in arow.iter().enumerate() {
        let xrow = &x[j1 * f2..(j1 + 1) * f2];
        if aval >= 0.0 {
            for (t, &xv) in trow.iter_mut().zip(xrow) {
                *t += xv;
            }
        } else {
            for (t, &xv) in trow.iter_mut().zip(xrow) {
                *t -= xv;
            }
        }
    }
}

/// Reference scalar stage 2 for one B row: a single `j2`-ascending chain.
fn scalar_stage2_row(brow: &[f32], trow: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (&t, &bv) in trow.iter().zip(brow) {
        acc += if bv >= 0.0 { t } else { -t };
    }
    acc
}

/// One batched encode's output: INT8 QHVs plus the bit-packed segment image
/// in the word-granular layout the packed search kernels take.
#[derive(Clone, Debug)]
pub struct EncodedBatch {
    pub batch: usize,
    pub dim: usize,
    pub segments: usize,
    pub seg_len: usize,
    /// words per packed segment (`words_for(seg_len)`)
    pub seg_words: usize,
    /// (batch, D) INT8 QHV values
    pub qhvs: Vec<f32>,
    /// (batch, segments * seg_words) packed rows; sample n's segment s sits
    /// at `n * segments * seg_words + s * seg_words`
    pub packed: Vec<u64>,
}

impl EncodedBatch {
    /// Sample n's INT8 QHV.
    pub fn qhv(&self, n: usize) -> &[f32] {
        &self.qhvs[n * self.dim..(n + 1) * self.dim]
    }

    /// Packed words per sample row.
    pub fn row_words(&self) -> usize {
        self.segments * self.seg_words
    }

    /// Sample n's bit-packed segment s — a ready `search_packed` operand.
    pub fn packed_segment(&self, n: usize, s: usize) -> &[u64] {
        let base = n * self.row_words() + s * self.seg_words;
        &self.packed[base..base + self.seg_words]
    }
}

impl HdBackend for SoftwareEncoder {
    fn cfg(&self) -> &HdConfig {
        &self.cfg
    }

    fn encode_segment(&mut self, xs: &[f32], batch: usize, seg: usize) -> Result<Vec<f32>> {
        let (feat, rows, seg_len) = (self.cfg.features(), self.cfg.seg_rows(), self.cfg.seg_len());
        if seg >= self.cfg.segments {
            bail!("segment {seg} out of range (<{})", self.cfg.segments);
        }
        if xs.len() != batch * feat {
            bail!("xs len {} != batch {batch} * F {feat}", xs.len());
        }
        let mut out = vec![0.0; batch * seg_len];
        for n in 0..batch {
            self.encode_rows(
                &xs[n * feat..(n + 1) * feat].to_vec(),
                seg * rows,
                rows,
                &mut out[n * seg_len..(n + 1) * seg_len],
            );
        }
        Ok(out)
    }

    fn encode_segment_packed(&mut self, xs: &[f32], batch: usize, seg: usize) -> Result<Vec<u64>> {
        // The zero-repack path: quantize and pack by sign in one pass over
        // the raw accumulators — identical bits to pack_rows(encode_segment)
        // (the trait's default), which the parity tests pin.
        let (feat, rows, seg_len) = (self.cfg.features(), self.cfg.seg_rows(), self.cfg.seg_len());
        if seg >= self.cfg.segments {
            bail!("segment {seg} out of range (<{})", self.cfg.segments);
        }
        if xs.len() != batch * feat {
            bail!("xs len {} != batch {batch} * F {feat}", xs.len());
        }
        let seg_words = packed::words_for(seg_len);
        let (qbits, scale) = (self.cfg.qbits, self.cfg.scale_q);
        let mut raw = vec![0.0f32; seg_len];
        let mut out = vec![0u64; batch * seg_words];
        let mut scratch = std::mem::take(&mut self.scratch);
        for n in 0..batch {
            let x = &xs[n * feat..(n + 1) * feat];
            self.encode_rows_raw(x, seg * rows, rows, &mut scratch, &mut raw);
            let words = &mut out[n * seg_words..(n + 1) * seg_words];
            for (i, &acc) in raw.iter().enumerate() {
                if quantize::quantize(acc, qbits, scale) >= 0.0 {
                    words[i / 64] |= 1u64 << (i % 64);
                }
            }
        }
        self.scratch = scratch;
        Ok(out)
    }

    fn encode_full(&mut self, xs: &[f32], batch: usize) -> Result<Vec<f32>> {
        let (feat, dim, d1) = (self.cfg.features(), self.cfg.dim(), self.cfg.d1);
        if xs.len() != batch * feat {
            bail!("xs len {} != batch {batch} * F {feat}", xs.len());
        }
        let mut out = vec![0.0; batch * dim];
        for n in 0..batch {
            self.encode_rows(
                &xs[n * feat..(n + 1) * feat].to_vec(),
                0,
                d1,
                &mut out[n * dim..(n + 1) * dim],
            );
        }
        Ok(out)
    }

    fn search(
        &mut self,
        qs: &[f32],
        batch: usize,
        chvs: &[f32],
        classes: usize,
        len: usize,
    ) -> Result<Vec<f32>> {
        crate::hdc::distance::l1_batch(qs, batch, chvs, classes, len)
    }
}

/// Cost model of one full-QHV encode per encoder family (Fig.5 table).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EncoderCost {
    /// add-equivalent arithmetic ops
    pub ops: u64,
    /// encoder parameter storage (bits)
    pub mem_bits: u64,
}

/// Kronecker encoder: stage1 d1*f1*f2 adds + stage2 d1*d2*f2 adds; memory is
/// the two binary factors only — and that is a *physical* bit count, not an
/// accounting convention: [`SignMat`] stores A and B as 1-bit sign planes
/// (64 entries per `u64` word), which is exactly what the sign-GEMM kernels
/// execute from. Every "op" is an add/subtract realized as a mask-selected
/// add (`x ^ sign_bit`), mirroring the chip's 256-weight-bits-per-cycle
/// adder trees.
pub fn kron_cost(cfg: &HdConfig) -> EncoderCost {
    let (d1, d2, f1, f2) = (cfg.d1 as u64, cfg.d2 as u64, cfg.f1 as u64, cfg.f2 as u64);
    EncoderCost {
        ops: d1 * f1 * f2 + d1 * d2 * f2,
        mem_bits: d1 * f1 + d2 * f2,
    }
}

/// Conventional random projection [11]: dense +-1 D x F matrix.
pub fn rp_cost(cfg: &HdConfig) -> EncoderCost {
    let (d, f) = (cfg.dim() as u64, cfg.features() as u64);
    EncoderCost { ops: d * f, mem_bits: d * f }
}

/// Cyclic RP [4]: one +-1 row of length F per block, rotated D/F times —
/// same op count as RP, F*ceil(D/F)-ish storage (one seed row per block).
pub fn crp_cost(cfg: &HdConfig) -> EncoderCost {
    let (d, f) = (cfg.dim() as u64, cfg.features() as u64);
    EncoderCost { ops: d * f, mem_bits: f * d.div_ceil(f) }
}

/// ID-LEVEL encoder [12]: F item HVs of length D (binary) + L level HVs;
/// encoding XORs/adds F hypervectors of length D.
pub fn id_level_cost(cfg: &HdConfig, levels: u64) -> EncoderCost {
    let (d, f) = (cfg.dim() as u64, cfg.features() as u64);
    EncoderCost { ops: d * f, mem_bits: d * (f + levels) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};

    fn tiny() -> HdConfig {
        HdConfig::synthetic("t", 8, 8, 32, 32, 8, 10)
    }

    /// Direct dense (A kron B) @ x oracle.
    fn dense_oracle(cfg: &HdConfig, a: &[f32], b: &[f32], x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; cfg.dim()];
        for i1 in 0..cfg.d1 {
            for i2 in 0..cfg.d2 {
                let mut acc = 0.0;
                for j1 in 0..cfg.f1 {
                    for j2 in 0..cfg.f2 {
                        acc += a[i1 * cfg.f1 + j1] * b[i2 * cfg.f2 + j2] * x[j1 * cfg.f2 + j2];
                    }
                }
                out[i1 * cfg.d2 + i2] =
                    quantize::quantize(acc, cfg.qbits, cfg.scale_q);
            }
        }
        out
    }

    #[test]
    fn matches_dense_kronecker_oracle() {
        let cfg = tiny();
        let mut enc = SoftwareEncoder::random(cfg.clone(), 1);
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..cfg.features()).map(|_| rng.range(-100, 101) as f32).collect();
        let want = dense_oracle(&cfg, &enc.a(), &enc.b(), &x);
        for kernel in [EncodeKernel::Scalar, EncodeKernel::SignGemm] {
            enc.set_kernel(kernel);
            let got = enc.encode_full(&x, 1).unwrap();
            assert_eq!(got, want, "{kernel:?}");
        }
    }

    #[test]
    fn segments_concatenate_to_full() {
        let cfg = tiny();
        let mut enc = SoftwareEncoder::random(cfg.clone(), 3);
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..cfg.features()).map(|_| rng.range(-50, 51) as f32).collect();
        let full = enc.encode_full(&x, 1).unwrap();
        let mut cat = Vec::new();
        for s in 0..cfg.segments {
            cat.extend(enc.encode_segment(&x, 1, s).unwrap());
        }
        assert_eq!(full, cat);
    }

    #[test]
    fn batch_equals_loop() {
        let cfg = tiny();
        let mut enc = SoftwareEncoder::random(cfg.clone(), 5);
        let mut rng = Rng::new(6);
        let xs: Vec<f32> = (0..3 * cfg.features()).map(|_| rng.range(-50, 51) as f32).collect();
        let batched = enc.encode_full(&xs, 3).unwrap();
        for n in 0..3 {
            let one = enc
                .encode_full(&xs[n * cfg.features()..(n + 1) * cfg.features()], 1)
                .unwrap();
            assert_eq!(&batched[n * cfg.dim()..(n + 1) * cfg.dim()], &one[..]);
        }
    }

    #[test]
    fn prop_signgemm_bit_exact_vs_scalar_kernel() {
        // The tentpole parity property: arbitrary geometries (f1/f2/d2 not
        // multiples of 64), negative non-integer inputs, segment windows.
        forall(20, 0xE0D, |rng| {
            let f1 = 1 + rng.below(70);
            let f2 = 1 + rng.below(90);
            let d1 = 2 * (1 + rng.below(4)); // even so segments=2 divides d1
            let d2 = 1 + rng.below(130);
            let cfg = HdConfig::synthetic("p", f1, f2, d1, d2, 2, 3);
            let mut enc = SoftwareEncoder::random(cfg.clone(), rng.next_u64());
            let x = gen::normal_vec(rng, cfg.features(), 9.0);
            enc.set_kernel(EncodeKernel::Scalar);
            let want_full = enc.encode_full(&x, 1).unwrap();
            let want_seg = enc.encode_segment(&x, 1, 1).unwrap();
            enc.set_kernel(EncodeKernel::SignGemm);
            assert_eq!(enc.encode_full(&x, 1).unwrap(), want_full, "f1={f1} f2={f2} d2={d2}");
            assert_eq!(enc.encode_segment(&x, 1, 1).unwrap(), want_seg);
        });
    }

    #[test]
    fn prop_calibrate_agrees_across_kernels() {
        // calibrate runs the serving kernel's raw pass; both kernels must
        // land on the identical scale_q.
        forall(10, 0xE0E, |rng| {
            let cfg = tiny();
            let seed = rng.next_u64();
            let xs = gen::normal_vec(rng, 2 * cfg.features(), 25.0);
            let mut scalar = SoftwareEncoder::random(cfg.clone(), seed);
            scalar.set_kernel(EncodeKernel::Scalar);
            scalar.calibrate(&xs, 2);
            let mut gemm = SoftwareEncoder::random(cfg.clone(), seed);
            gemm.set_kernel(EncodeKernel::SignGemm);
            gemm.calibrate(&xs, 2);
            assert_eq!(scalar.cfg().scale_q, gemm.cfg().scale_q);
        });
    }

    #[test]
    fn prop_encode_batch_matches_encode_full_and_segment_packing() {
        forall(10, 0xE0F, |rng| {
            let cfg = tiny();
            let mut enc = SoftwareEncoder::random(cfg.clone(), rng.next_u64());
            let batch = 1 + rng.below(5);
            let xs = gen::int8_vec(rng, batch * cfg.features());
            let eb = enc.encode_batch(&xs, batch, None).unwrap();
            let full = enc.encode_full(&xs, batch).unwrap();
            assert_eq!(eb.qhvs, full);
            assert_eq!(eb.row_words(), cfg.segments * packed::words_for(cfg.seg_len()));
            for n in 0..batch {
                assert_eq!(eb.qhv(n), &full[n * cfg.dim()..(n + 1) * cfg.dim()]);
                for s in 0..cfg.segments {
                    let want = packed::pack_signs(
                        &full[n * cfg.dim() + s * cfg.seg_len()
                            ..n * cfg.dim() + (s + 1) * cfg.seg_len()],
                    );
                    assert_eq!(eb.packed_segment(n, s), &want[..], "sample {n} seg {s}");
                }
            }
        });
    }

    #[test]
    fn prop_encode_batch_pooled_is_bit_identical() {
        let pool = WorkerPool::new(4);
        forall(8, 0xE10, |rng| {
            let cfg = tiny();
            let enc = SoftwareEncoder::random(cfg.clone(), rng.next_u64());
            let batch = 1 + rng.below(9);
            let xs = gen::int8_vec(rng, batch * cfg.features());
            let serial = enc.encode_batch(&xs, batch, None).unwrap();
            let pooled = enc.encode_batch(&xs, batch, Some(&pool)).unwrap();
            assert_eq!(serial.qhvs, pooled.qhvs);
            assert_eq!(serial.packed, pooled.packed);
        });
    }

    #[test]
    fn encode_segment_packed_matches_pack_of_encode_segment() {
        let cfg = tiny();
        let mut enc = SoftwareEncoder::random(cfg.clone(), 12);
        let mut rng = Rng::new(13);
        let batch = 3;
        let xs: Vec<f32> =
            (0..batch * cfg.features()).map(|_| rng.range(-80, 81) as f32).collect();
        for s in 0..cfg.segments {
            let q = enc.encode_segment(&xs, batch, s).unwrap();
            let want = packed::pack_rows(&q, batch, cfg.seg_len()).unwrap();
            let got = enc.encode_segment_packed(&xs, batch, s).unwrap();
            assert_eq!(got, want, "segment {s}");
        }
        assert!(enc.encode_segment_packed(&xs, batch, 99).is_err());
        assert!(enc.encode_segment_packed(&xs[..3], 1, 0).is_err());
    }

    #[test]
    fn prop_output_is_quantized(){
        forall(20, 0xE0C, |rng| {
            let cfg = tiny();
            let mut enc = SoftwareEncoder::random(cfg.clone(), rng.next_u64());
            let x = gen::int8_vec(rng, cfg.features());
            let q = enc.encode_full(&x, 1).unwrap();
            for v in q {
                assert!(v.abs() <= 127.0 && v.fract() == 0.0);
            }
        });
    }

    #[test]
    fn rejects_bad_shapes() {
        let cfg = tiny();
        let mut enc = SoftwareEncoder::random(cfg.clone(), 1);
        assert!(enc.encode_full(&[0.0; 3], 1).is_err());
        assert!(enc.encode_segment(&vec![0.0; cfg.features()], 1, 99).is_err());
        assert!(enc.encode_qhvs(&[], 0, None).is_err());
        assert!(enc.encode_batch(&[0.0; 3], 1, None).is_err());
        assert!(SoftwareEncoder::new(cfg.clone(), vec![1.0; 3], vec![1.0; 3]).is_err());
        assert!(EncodeKernel::parse("turbo").is_err());
        assert_eq!(EncodeKernel::parse("scalar").unwrap(), EncodeKernel::Scalar);
        assert_eq!(EncodeKernel::parse("signgemm").unwrap(), EncodeKernel::SignGemm);
    }

    #[test]
    fn cost_model_ratios_match_paper_scale() {
        // Paper Fig.5: 43x speedup, 1376x memory vs lengthy encoders at the
        // large operating point (F=640 padded from 617, D=8192).
        let cfg = HdConfig::synthetic("big", 32, 20, 256, 32, 16, 26);
        assert_eq!(cfg.dim(), 8192);
        let k = kron_cost(&cfg);
        let rp = rp_cost(&cfg);
        let speedup = rp.ops as f64 / k.ops as f64;
        let memsave = rp.mem_bits as f64 / k.mem_bits as f64;
        assert!(speedup > 15.0, "speedup {speedup}");
        assert!(memsave > 500.0, "memsave {memsave}");
    }

    #[test]
    fn sign_planes_store_the_cost_models_bit_count() {
        // kron_cost's mem_bits is literally what SignMat keeps resident
        // (up to the per-row word-padding slack).
        let cfg = tiny();
        let enc = SoftwareEncoder::random(cfg.clone(), 2);
        let k = kron_cost(&cfg);
        let FactorPlanes::Stored { a_signs, b_signs, .. } = &enc.planes else {
            panic!("SoftwareEncoder::new builds stored planes");
        };
        let packed_bits = (a_signs.bytes() + b_signs.bytes()) as u64 * 8;
        assert!(packed_bits >= k.mem_bits);
        // padding slack is bounded by 63 bits per row
        assert!(packed_bits <= k.mem_bits + 63 * (cfg.d1 + cfg.d2) as u64);
    }

    #[test]
    fn remat_encoder_bit_equals_materialized_twin() {
        // The tentpole remat property: a seed-only encoder and its fully
        // materialized twin produce identical factors, QHVs, and packed
        // segments — under both kernels.
        let cfg = tiny();
        let mut remat = SoftwareEncoder::random_remat(cfg.clone(), 0xBEEF);
        let mut stored = SoftwareEncoder::random_remat_materialized(cfg.clone(), 0xBEEF);
        assert!(remat.is_remat());
        assert!(!stored.is_remat());
        assert_eq!(remat.a(), stored.a());
        assert_eq!(remat.b(), stored.b());
        // the memory story: seeds + geometry vs dense f32 + sign planes
        assert!(remat.factor_bytes() < stored.factor_bytes() / 10);
        let mut rng = Rng::new(21);
        let xs: Vec<f32> = (0..2 * cfg.features()).map(|_| rng.range(-80, 81) as f32).collect();
        remat.calibrate(&xs, 2);
        stored.calibrate(&xs, 2);
        assert_eq!(remat.cfg().scale_q, stored.cfg().scale_q);
        for kernel in [EncodeKernel::Scalar, EncodeKernel::SignGemm] {
            remat.set_kernel(kernel);
            stored.set_kernel(kernel);
            assert_eq!(
                remat.encode_full(&xs, 2).unwrap(),
                stored.encode_full(&xs, 2).unwrap(),
                "{kernel:?}"
            );
            for s in 0..cfg.segments {
                assert_eq!(
                    remat.encode_segment_packed(&xs, 2, s).unwrap(),
                    stored.encode_segment_packed(&xs, 2, s).unwrap(),
                    "{kernel:?} segment {s}"
                );
            }
        }
        // different seeds give different planes (streams are separated)
        let other = SoftwareEncoder::random_remat(cfg, 0xBEF0);
        assert_ne!(other.a(), remat.a());
    }
}
