//! Software Kronecker HD encoder (Fig.5) — the pure-Rust reference for the
//! AOT Pallas kernel, the fallback backend, and the op/memory cost model the
//! Fig.5 comparison bench is built on.
//!
//! Encoding: QHV = quantize(vec(A_seg @ X @ B^T)) with X = reshape(x, f1, f2)
//! and +-1 factors A (d1 x f1), B (d2 x f2). Because A and B are +-1, every
//! "multiply" in stage 1/2 is an add/subtract — the chip's adder trees; we
//! count ops accordingly in [`kron_cost`].

use crate::config::HdConfig;
use crate::hdc::quantize;
use crate::hdc::HdBackend;
use crate::util::Rng;
use crate::Result;
use anyhow::bail;

/// Pure-Rust Kronecker encoder + L1 search backend.
#[derive(Clone, Debug)]
pub struct SoftwareEncoder {
    cfg: HdConfig,
    /// A: (d1, f1) row-major +-1
    pub a: Vec<f32>,
    /// B: (d2, f2) row-major +-1
    pub b: Vec<f32>,
    /// scratch for stage-1 output (seg_rows x f2 max = d1 x f2)
    scratch: Vec<f32>,
}

impl SoftwareEncoder {
    pub fn new(cfg: HdConfig, a: Vec<f32>, b: Vec<f32>) -> Result<SoftwareEncoder> {
        if a.len() != cfg.d1 * cfg.f1 {
            bail!("A has {} elements, expected {}", a.len(), cfg.d1 * cfg.f1);
        }
        if b.len() != cfg.d2 * cfg.f2 {
            bail!("B has {} elements, expected {}", b.len(), cfg.d2 * cfg.f2);
        }
        let scratch = vec![0.0; cfg.d1 * cfg.f2];
        Ok(SoftwareEncoder { cfg, a, b, scratch })
    }

    /// Random +-1 factors (matches the build-time generator's distribution;
    /// exact factor values come from artifacts/hd_factors_<cfg>.bin in
    /// production).
    pub fn random(cfg: HdConfig, seed: u64) -> SoftwareEncoder {
        let mut rng = Rng::new(seed);
        let a = (0..cfg.d1 * cfg.f1).map(|_| rng.sign()).collect();
        let b = (0..cfg.d2 * cfg.f2).map(|_| rng.sign()).collect();
        SoftwareEncoder::new(cfg, a, b).unwrap()
    }

    /// Set `scale_q` so the raw accumulator range maps onto INT8 without
    /// saturation — the Rust twin of aot.py's build-time calibration (the
    /// AOT artifacts bake the python-calibrated value; synthetic/bench
    /// configs must call this before training or QHVs clip to +-127 and
    /// bundling degenerates).
    pub fn calibrate(&mut self, xs: &[f32], batch: usize) {
        let (f1, f2, d1, d2) = (self.cfg.f1, self.cfg.f2, self.cfg.d1, self.cfg.d2);
        let mut max_abs = 0.0f32;
        let mut t = vec![0.0f32; f2];
        for n in 0..batch {
            let x = &xs[n * f1 * f2..(n + 1) * f1 * f2];
            for i1 in 0..d1 {
                let arow = &self.a[i1 * f1..(i1 + 1) * f1];
                t.fill(0.0);
                for (j1, &av) in arow.iter().enumerate() {
                    for (tv, &xv) in t.iter_mut().zip(&x[j1 * f2..(j1 + 1) * f2]) {
                        *tv += av * xv;
                    }
                }
                for i2 in 0..d2 {
                    let brow = &self.b[i2 * f2..(i2 + 1) * f2];
                    let acc: f32 = t.iter().zip(brow).map(|(&tv, &bv)| tv * bv).sum();
                    max_abs = max_abs.max(acc.abs());
                }
            }
        }
        if max_abs > 0.0 {
            self.cfg.scale_q = max_abs / 127.0;
        }
    }

    /// Encode rows [row0, row0+rows) of A against one feature vector,
    /// writing `rows * d2` QHV values into `out`.
    fn encode_rows(&mut self, x: &[f32], row0: usize, rows: usize, out: &mut [f32]) {
        let (f1, f2, d2) = (self.cfg.f1, self.cfg.f2, self.cfg.d2);
        debug_assert_eq!(x.len(), f1 * f2);
        debug_assert_eq!(out.len(), rows * d2);
        // Stage 1: T = A_rows @ X  (rows x f2); A is +-1 -> adds only.
        for r in 0..rows {
            let arow = &self.a[(row0 + r) * f1..(row0 + r + 1) * f1];
            let trow = &mut self.scratch[r * f2..(r + 1) * f2];
            trow.fill(0.0);
            for (j1, &aval) in arow.iter().enumerate() {
                let xrow = &x[j1 * f2..(j1 + 1) * f2];
                if aval >= 0.0 {
                    for (t, &xv) in trow.iter_mut().zip(xrow) {
                        *t += xv;
                    }
                } else {
                    for (t, &xv) in trow.iter_mut().zip(xrow) {
                        *t -= xv;
                    }
                }
            }
        }
        // Stage 2: Y = T @ B^T (rows x d2), quantize.
        let (bits, scale) = (self.cfg.qbits, self.cfg.scale_q);
        for r in 0..rows {
            let trow = &self.scratch[r * f2..(r + 1) * f2];
            for i2 in 0..d2 {
                let brow = &self.b[i2 * f2..(i2 + 1) * f2];
                let mut acc = 0.0f32;
                for (&t, &bv) in trow.iter().zip(brow) {
                    acc += if bv >= 0.0 { t } else { -t };
                }
                out[r * d2 + i2] = quantize::quantize(acc, bits, scale);
            }
        }
    }
}

impl HdBackend for SoftwareEncoder {
    fn cfg(&self) -> &HdConfig {
        &self.cfg
    }

    fn encode_segment(&mut self, xs: &[f32], batch: usize, seg: usize) -> Result<Vec<f32>> {
        let (feat, rows, seg_len) = (self.cfg.features(), self.cfg.seg_rows(), self.cfg.seg_len());
        if seg >= self.cfg.segments {
            bail!("segment {seg} out of range (<{})", self.cfg.segments);
        }
        if xs.len() != batch * feat {
            bail!("xs len {} != batch {batch} * F {feat}", xs.len());
        }
        let mut out = vec![0.0; batch * seg_len];
        for n in 0..batch {
            self.encode_rows(
                &xs[n * feat..(n + 1) * feat].to_vec(),
                seg * rows,
                rows,
                &mut out[n * seg_len..(n + 1) * seg_len],
            );
        }
        Ok(out)
    }

    fn encode_full(&mut self, xs: &[f32], batch: usize) -> Result<Vec<f32>> {
        let (feat, dim, d1) = (self.cfg.features(), self.cfg.dim(), self.cfg.d1);
        if xs.len() != batch * feat {
            bail!("xs len {} != batch {batch} * F {feat}", xs.len());
        }
        let mut out = vec![0.0; batch * dim];
        for n in 0..batch {
            self.encode_rows(
                &xs[n * feat..(n + 1) * feat].to_vec(),
                0,
                d1,
                &mut out[n * dim..(n + 1) * dim],
            );
        }
        Ok(out)
    }

    fn search(
        &mut self,
        qs: &[f32],
        batch: usize,
        chvs: &[f32],
        classes: usize,
        len: usize,
    ) -> Result<Vec<f32>> {
        crate::hdc::distance::l1_batch(qs, batch, chvs, classes, len)
    }
}

/// Cost model of one full-QHV encode per encoder family (Fig.5 table).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EncoderCost {
    /// add-equivalent arithmetic ops
    pub ops: u64,
    /// encoder parameter storage (bits)
    pub mem_bits: u64,
}

/// Kronecker encoder: stage1 d1*f1*f2 adds + stage2 d1*d2*f2 adds; memory is
/// the two binary factors only.
pub fn kron_cost(cfg: &HdConfig) -> EncoderCost {
    let (d1, d2, f1, f2) = (cfg.d1 as u64, cfg.d2 as u64, cfg.f1 as u64, cfg.f2 as u64);
    EncoderCost {
        ops: d1 * f1 * f2 + d1 * d2 * f2,
        mem_bits: d1 * f1 + d2 * f2,
    }
}

/// Conventional random projection [11]: dense +-1 D x F matrix.
pub fn rp_cost(cfg: &HdConfig) -> EncoderCost {
    let (d, f) = (cfg.dim() as u64, cfg.features() as u64);
    EncoderCost { ops: d * f, mem_bits: d * f }
}

/// Cyclic RP [4]: one +-1 row of length F per block, rotated D/F times —
/// same op count as RP, F*ceil(D/F)-ish storage (one seed row per block).
pub fn crp_cost(cfg: &HdConfig) -> EncoderCost {
    let (d, f) = (cfg.dim() as u64, cfg.features() as u64);
    EncoderCost { ops: d * f, mem_bits: f * d.div_ceil(f) }
}

/// ID-LEVEL encoder [12]: F item HVs of length D (binary) + L level HVs;
/// encoding XORs/adds F hypervectors of length D.
pub fn id_level_cost(cfg: &HdConfig, levels: u64) -> EncoderCost {
    let (d, f) = (cfg.dim() as u64, cfg.features() as u64);
    EncoderCost { ops: d * f, mem_bits: d * (f + levels) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};

    fn tiny() -> HdConfig {
        HdConfig::synthetic("t", 8, 8, 32, 32, 8, 10)
    }

    /// Direct dense (A kron B) @ x oracle.
    fn dense_oracle(cfg: &HdConfig, a: &[f32], b: &[f32], x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; cfg.dim()];
        for i1 in 0..cfg.d1 {
            for i2 in 0..cfg.d2 {
                let mut acc = 0.0;
                for j1 in 0..cfg.f1 {
                    for j2 in 0..cfg.f2 {
                        acc += a[i1 * cfg.f1 + j1] * b[i2 * cfg.f2 + j2] * x[j1 * cfg.f2 + j2];
                    }
                }
                out[i1 * cfg.d2 + i2] =
                    quantize::quantize(acc, cfg.qbits, cfg.scale_q);
            }
        }
        out
    }

    #[test]
    fn matches_dense_kronecker_oracle() {
        let cfg = tiny();
        let mut enc = SoftwareEncoder::random(cfg.clone(), 1);
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..cfg.features()).map(|_| rng.range(-100, 101) as f32).collect();
        let got = enc.encode_full(&x, 1).unwrap();
        let want = dense_oracle(&cfg, &enc.a.clone(), &enc.b.clone(), &x);
        assert_eq!(got, want);
    }

    #[test]
    fn segments_concatenate_to_full() {
        let cfg = tiny();
        let mut enc = SoftwareEncoder::random(cfg.clone(), 3);
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..cfg.features()).map(|_| rng.range(-50, 51) as f32).collect();
        let full = enc.encode_full(&x, 1).unwrap();
        let mut cat = Vec::new();
        for s in 0..cfg.segments {
            cat.extend(enc.encode_segment(&x, 1, s).unwrap());
        }
        assert_eq!(full, cat);
    }

    #[test]
    fn batch_equals_loop() {
        let cfg = tiny();
        let mut enc = SoftwareEncoder::random(cfg.clone(), 5);
        let mut rng = Rng::new(6);
        let xs: Vec<f32> = (0..3 * cfg.features()).map(|_| rng.range(-50, 51) as f32).collect();
        let batched = enc.encode_full(&xs, 3).unwrap();
        for n in 0..3 {
            let one = enc
                .encode_full(&xs[n * cfg.features()..(n + 1) * cfg.features()], 1)
                .unwrap();
            assert_eq!(&batched[n * cfg.dim()..(n + 1) * cfg.dim()], &one[..]);
        }
    }

    #[test]
    fn prop_output_is_quantized(){
        forall(20, 0xE0C, |rng| {
            let cfg = tiny();
            let mut enc = SoftwareEncoder::random(cfg.clone(), rng.next_u64());
            let x = gen::int8_vec(rng, cfg.features());
            let q = enc.encode_full(&x, 1).unwrap();
            for v in q {
                assert!(v.abs() <= 127.0 && v.fract() == 0.0);
            }
        });
    }

    #[test]
    fn rejects_bad_shapes() {
        let cfg = tiny();
        let mut enc = SoftwareEncoder::random(cfg.clone(), 1);
        assert!(enc.encode_full(&[0.0; 3], 1).is_err());
        assert!(enc.encode_segment(&vec![0.0; cfg.features()], 1, 99).is_err());
        assert!(SoftwareEncoder::new(cfg.clone(), vec![1.0; 3], vec![1.0; 3]).is_err());
    }

    #[test]
    fn cost_model_ratios_match_paper_scale() {
        // Paper Fig.5: 43x speedup, 1376x memory vs lengthy encoders at the
        // large operating point (F=640 padded from 617, D=8192).
        let cfg = HdConfig::synthetic("big", 32, 20, 256, 32, 16, 26);
        assert_eq!(cfg.dim(), 8192);
        let k = kron_cost(&cfg);
        let rp = rp_cost(&cfg);
        let speedup = rp.ops as f64 / k.ops as f64;
        let memsave = rp.mem_bits as f64 / k.mem_bits as f64;
        assert!(speedup > 15.0, "speedup {speedup}");
        assert!(memsave > 500.0, "memsave {memsave}");
    }
}
