//! Per-module cycle/energy accounting used by the chip simulator and the
//! Fig.10c/d breakdown bench.

use crate::energy::Domain;

/// One module's contribution to an inference.
#[derive(Clone, Debug)]
pub struct ModuleCost {
    pub name: String,
    pub domain: Domain,
    pub cycles: u64,
    /// arithmetic ops (FLOPs for WCFE, INT ops for HDC)
    pub ops: u64,
    /// SRAM bytes touched
    pub sram_bytes: u64,
    pub energy_j: f64,
}

/// Ordered collection of module costs for one simulated operation.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub modules: Vec<ModuleCost>,
}

impl Trace {
    pub fn push(&mut self, m: ModuleCost) {
        self.modules.push(m);
    }

    pub fn total_cycles(&self, domain: Option<Domain>) -> u64 {
        self.modules
            .iter()
            .filter(|m| domain.map(|d| m.domain == d).unwrap_or(true))
            .map(|m| m.cycles)
            .sum()
    }

    pub fn total_energy_j(&self, domain: Option<Domain>) -> f64 {
        self.modules
            .iter()
            .filter(|m| domain.map(|d| m.domain == d).unwrap_or(true))
            .map(|m| m.energy_j)
            .sum()
    }

    pub fn total_ops(&self, domain: Option<Domain>) -> u64 {
        self.modules
            .iter()
            .filter(|m| domain.map(|d| m.domain == d).unwrap_or(true))
            .map(|m| m.ops)
            .sum()
    }

    /// (latency%, energy%) share of one domain — the Fig.10c/d numbers.
    pub fn domain_share(&self, domain: Domain) -> (f64, f64) {
        let lat = self.total_cycles(Some(domain)) as f64
            / self.total_cycles(None).max(1) as f64;
        let e = self.total_energy_j(Some(domain)) / self.total_energy_j(None).max(1e-30);
        (lat, e)
    }

    /// Merge another trace into this one (multi-inference accumulation).
    pub fn merge(&mut self, other: &Trace) {
        for m in &other.modules {
            if let Some(existing) = self
                .modules
                .iter_mut()
                .find(|e| e.name == m.name && e.domain == m.domain)
            {
                existing.cycles += m.cycles;
                existing.ops += m.ops;
                existing.sram_bytes += m.sram_bytes;
                existing.energy_j += m.energy_j;
            } else {
                self.modules.push(m.clone());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(name: &str, domain: Domain, cycles: u64, energy: f64) -> ModuleCost {
        ModuleCost {
            name: name.into(),
            domain,
            cycles,
            ops: cycles,
            sram_bytes: 0,
            energy_j: energy,
        }
    }

    #[test]
    fn totals_and_shares() {
        let mut t = Trace::default();
        t.push(m("wcfe", Domain::Wcfe, 90, 9e-6));
        t.push(m("enc", Domain::Hdc, 5, 0.5e-6));
        t.push(m("srch", Domain::Hdc, 5, 0.5e-6));
        assert_eq!(t.total_cycles(None), 100);
        let (lat, e) = t.domain_share(Domain::Wcfe);
        assert!((lat - 0.9).abs() < 1e-12);
        assert!((e - 0.9).abs() < 1e-9);
    }

    #[test]
    fn merge_accumulates_by_name() {
        let mut a = Trace::default();
        a.push(m("enc", Domain::Hdc, 5, 1e-6));
        let mut b = Trace::default();
        b.push(m("enc", Domain::Hdc, 7, 2e-6));
        b.push(m("srch", Domain::Hdc, 3, 1e-6));
        a.merge(&b);
        assert_eq!(a.modules.len(), 2);
        assert_eq!(a.total_cycles(None), 15);
    }
}
