//! Whole-chip analytic simulator (Fig.10): module-level cycle counts from
//! the datapath geometries (Fig.5/6/7) + the calibrated energy model.
//!
//! Datapath throughputs (from the paper's micro-architecture):
//! * Kronecker encoder: 256 weight-bits/cycle into 32 8:1 adder trees ->
//!   256 add-equivalent ops/cycle.
//! * HD search: 64-bit CHV slice per cycle -> 8 INT8 element-compares/cycle.
//! * HDC train update: reuses the 32 adder trees -> 32 INT8 adds/cycle.
//! * WCFE: 4x16 PE array, 1 BF16 MAC each -> 64 MACs/cycle (pattern-reuse
//!   cycles from [`crate::wcfe::pe_array`]).

use crate::config::{ChipConfig, HdConfig, OperatingPoint};
use crate::energy::{Domain, EnergyModel};
use crate::fifo::CdcFifo;
use crate::sim::trace::{ModuleCost, Trace};
use crate::wcfe::pe_array::{LayerGeometry, PeArray};
use crate::wcfe::schedule::ReuseSchedule;
use crate::wcfe::{Codebook, WcfeModel};

/// Dual-mode select (Fig.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// simple datasets: features go straight to the HD module
    Bypass,
    /// complex datasets: WCFE -> CDC FIFO -> HD module
    Normal,
}

/// One simulated inference: trace + derived wall-clock/energy at a DVFS point.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub trace: Trace,
    pub op: OperatingPoint,
    pub latency_s: f64,
    pub energy_j: f64,
    /// (latency share, energy share) of the WCFE domain (Fig.10c/d)
    pub wcfe_latency_share: f64,
    pub wcfe_energy_share: f64,
}

pub struct Chip {
    pub cfg: ChipConfig,
    pub energy: EnergyModel,
}

impl Default for Chip {
    fn default() -> Self {
        Chip { cfg: ChipConfig::default(), energy: EnergyModel::default() }
    }
}

impl Chip {
    /// Encoder ops for one progressive-search segment (adds; +-1 weights).
    pub fn encode_segment_ops(&self, hd: &HdConfig) -> u64 {
        let rows = hd.seg_rows() as u64;
        rows * (hd.f1 * hd.f2) as u64 + rows * (hd.d2 * hd.f2) as u64
    }

    pub fn encode_segment_cycles(&self, hd: &HdConfig) -> u64 {
        self.encode_segment_ops(hd)
            .div_ceil(self.cfg.enc_weight_bits_per_cycle as u64)
    }

    /// Search ops for one segment over all classes (INT8 |q-c| compares).
    pub fn search_segment_ops(&self, hd: &HdConfig) -> u64 {
        (hd.classes * hd.seg_len()) as u64
    }

    pub fn search_segment_cycles(&self, hd: &HdConfig) -> u64 {
        let elems_per_cycle = (self.cfg.search_bits_per_cycle / 8) as u64;
        self.search_segment_ops(hd).div_ceil(elems_per_cycle)
    }

    /// Train-update cost over the full CHV row.
    pub fn train_update_ops(&self, hd: &HdConfig) -> u64 {
        hd.dim() as u64
    }

    pub fn train_update_cycles(&self, hd: &HdConfig) -> u64 {
        self.train_update_ops(hd).div_ceil(self.cfg.enc_adder_trees as u64)
    }

    /// WCFE forward cost with pattern reuse (clustered) per image.
    pub fn wcfe_cost(&self, model: &WcfeModel, cb: &Codebook) -> (u64, u64) {
        let pe = PeArray::new(self.cfg.clone());
        let mut cycles = 0u64;
        let mut ops = 0u64;
        for (layer_cb, (h, w)) in cb.layers.iter().zip(model.layer_geometries()) {
            let sched = ReuseSchedule::build(layer_cb);
            let cost = pe.clustered_cost(&sched, LayerGeometry { out_h: h, out_w: w });
            cycles += cost.cycles;
            ops += cost.adds + cost.mults;
        }
        // FC layer runs dense on the PE array
        let fc_macs = (model.convs.last().map(|l| l.c_out).unwrap_or(0) * model.fc_out) as u64;
        cycles += fc_macs.div_ceil(self.cfg.pe_count() as u64);
        ops += 2 * fc_macs;
        (cycles, ops)
    }

    /// Simulate one inference at voltage `v`. `segments_used` reflects the
    /// progressive search's actual termination point (from a live run or a
    /// policy sweep); `wcfe` supplies the front-end when mode == Normal.
    pub fn simulate_inference(
        &self,
        hd: &HdConfig,
        mode: Mode,
        segments_used: usize,
        wcfe: Option<(&WcfeModel, &Codebook)>,
        v: f64,
    ) -> SimReport {
        let op = self.cfg.point_at_voltage(v);
        let mut trace = Trace::default();

        if mode == Mode::Normal {
            let (model, cb) = wcfe.expect("normal mode requires WCFE model");
            let (cycles, ops) = self.wcfe_cost(model, cb);
            // weight-index + activation traffic: one byte per weight index
            // fetch per output position is dominated by activations; model
            // activations only (h*w*c per layer boundary).
            let act_bytes: u64 = model
                .layer_geometries()
                .iter()
                .zip(&model.convs)
                .map(|((h, w), l)| (h * w * l.c_out) as u64)
                .sum();
            trace.push(ModuleCost {
                name: "wcfe".into(),
                domain: Domain::Wcfe,
                cycles,
                ops,
                sram_bytes: act_bytes,
                energy_j: self.energy.energy_j(Domain::Wcfe, ops, v)
                    + self.energy.sram_energy_j(act_bytes, v),
            });
            // feature handoff through the global CDC FIFO
            let fifo = CdcFifo::new(1024);
            let words = hd.features();
            let cycles = fifo.transfer_cycles(words, op.freq_mhz, op.freq_mhz);
            trace.push(ModuleCost {
                name: "cdc_fifo".into(),
                domain: Domain::Hdc,
                cycles,
                ops: 0,
                sram_bytes: words as u64 * 4,
                energy_j: self.energy.sram_energy_j(words as u64 * 4, v),
            });
        }

        let segs = segments_used.min(hd.segments).max(1) as u64;
        let enc_ops = self.encode_segment_ops(hd) * segs;
        let enc_cycles = self.encode_segment_cycles(hd) * segs;
        trace.push(ModuleCost {
            name: "hd_encoder".into(),
            domain: Domain::Hdc,
            cycles: enc_cycles,
            ops: enc_ops,
            sram_bytes: (hd.d1 * hd.f1 + hd.d2 * hd.f2) as u64 / 8,
            energy_j: self.energy.energy_j(Domain::Hdc, enc_ops, v),
        });

        let srch_ops = self.search_segment_ops(hd) * segs;
        let srch_cycles = self.search_segment_cycles(hd) * segs;
        let chv_bytes = (hd.classes * hd.seg_len()) as u64 * segs;
        trace.push(ModuleCost {
            name: "hd_search".into(),
            domain: Domain::Hdc,
            cycles: srch_cycles,
            ops: srch_ops,
            sram_bytes: chv_bytes,
            energy_j: self.energy.energy_j(Domain::Hdc, srch_ops, v)
                + self.energy.sram_energy_j(chv_bytes, v),
        });

        self.finish(trace, op)
    }

    /// Simulate one training update (single-pass bundle) at voltage `v`.
    pub fn simulate_train(&self, hd: &HdConfig, v: f64) -> SimReport {
        let op = self.cfg.point_at_voltage(v);
        let mut trace = Trace::default();
        let enc_ops = self.encode_segment_ops(hd) * hd.segments as u64;
        trace.push(ModuleCost {
            name: "hd_encoder".into(),
            domain: Domain::Hdc,
            cycles: self.encode_segment_cycles(hd) * hd.segments as u64,
            ops: enc_ops,
            sram_bytes: 0,
            energy_j: self.energy.energy_j(Domain::Hdc, enc_ops, v),
        });
        let upd_ops = self.train_update_ops(hd);
        trace.push(ModuleCost {
            name: "hd_train".into(),
            domain: Domain::Hdc,
            cycles: self.train_update_cycles(hd),
            ops: upd_ops,
            sram_bytes: hd.dim() as u64 * 2,
            energy_j: self.energy.energy_j(Domain::Hdc, upd_ops, v)
                + self.energy.sram_energy_j(hd.dim() as u64 * 2, v),
        });
        self.finish(trace, op)
    }

    fn finish(&self, trace: Trace, op: OperatingPoint) -> SimReport {
        let cycles = trace.total_cycles(None);
        let energy = trace.total_energy_j(None);
        let (lat_share, e_share) = trace.domain_share(Domain::Wcfe);
        SimReport {
            latency_s: cycles as f64 / (op.freq_mhz * 1e6),
            energy_j: energy,
            wcfe_latency_share: lat_share,
            wcfe_energy_share: e_share,
            trace,
            op,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::wcfe::codebook::LayerCodebook;
    use crate::wcfe::conv::ConvLayer;

    fn hd() -> HdConfig {
        HdConfig::synthetic("cifar", 32, 16, 128, 32, 16, 100)
    }

    fn wcfe_fixture() -> (WcfeModel, Codebook) {
        let mut rng = Rng::new(1);
        let channels = [32usize, 64, 128];
        let mut convs = Vec::new();
        let mut layers = Vec::new();
        let mut c_in = 3usize;
        for &c_out in &channels {
            let w: Vec<f32> = (0..9 * c_in * c_out).map(|_| rng.normal_f32() * 0.1).collect();
            layers.push(LayerCodebook::from_weights("l", &w, 9 * c_in, c_out, 16));
            convs.push(ConvLayer { w, c_in, c_out });
            c_in = c_out;
        }
        let fc_out = 512;
        let model = WcfeModel {
            convs,
            fc: vec![0.0; 128 * fc_out],
            fc_out,
            image_hw: 32,
            image_c: 3,
        };
        let cb = Codebook { layers, dense_tail_bits: (128 * fc_out * 16) as u64 };
        (model, cb)
    }

    #[test]
    fn datapath_cycle_formulas() {
        let chip = Chip::default();
        let hd = hd();
        // encoder: rows=8 per segment -> 8*512 + 8*512 = 8192 adds / 256 = 32
        assert_eq!(chip.encode_segment_ops(&hd), 8192);
        assert_eq!(chip.encode_segment_cycles(&hd), 32);
        // search: 100 classes * 256 elems / 8 per cycle
        assert_eq!(chip.search_segment_cycles(&hd), 100 * 256 / 8);
    }

    #[test]
    fn normal_mode_breakdown_matches_fig10_shape() {
        // Fig.10c/d: WCFE dominates — 94.2% energy, 87.7% latency on
        // CIFAR-100. The simulator must land in that regime (>80% both).
        let chip = Chip::default();
        let (model, cb) = wcfe_fixture();
        let r = chip.simulate_inference(&hd(), Mode::Normal, 16, Some((&model, &cb)), 0.9);
        assert!(
            r.wcfe_energy_share > 0.85 && r.wcfe_energy_share < 0.99,
            "energy share {}",
            r.wcfe_energy_share
        );
        assert!(
            r.wcfe_latency_share > 0.70,
            "latency share {}",
            r.wcfe_latency_share
        );
    }

    #[test]
    fn bypass_mode_has_no_wcfe_cost() {
        let chip = Chip::default();
        let r = chip.simulate_inference(&hd(), Mode::Bypass, 16, None, 0.9);
        assert_eq!(r.wcfe_energy_share, 0.0);
        assert!(r.trace.modules.iter().all(|m| m.name != "wcfe"));
    }

    #[test]
    fn progressive_termination_scales_hdc_cost() {
        let chip = Chip::default();
        let full = chip.simulate_inference(&hd(), Mode::Bypass, 16, None, 0.9);
        let early = chip.simulate_inference(&hd(), Mode::Bypass, 6, None, 0.9);
        let ratio = early.energy_j / full.energy_j;
        assert!((ratio - 6.0 / 16.0).abs() < 0.05, "ratio {ratio}");
        assert!(early.latency_s < full.latency_s);
    }

    #[test]
    fn lower_voltage_cheaper_but_slower() {
        let chip = Chip::default();
        let lo = chip.simulate_inference(&hd(), Mode::Bypass, 16, None, 0.7);
        let hi = chip.simulate_inference(&hd(), Mode::Bypass, 16, None, 1.2);
        assert!(lo.energy_j < hi.energy_j);
        assert!(lo.latency_s > hi.latency_s);
    }

    #[test]
    fn train_sim_nonzero() {
        let chip = Chip::default();
        let r = chip.simulate_train(&hd(), 0.9);
        assert!(r.energy_j > 0.0 && r.latency_s > 0.0);
        assert_eq!(r.trace.modules.len(), 2);
    }
}
