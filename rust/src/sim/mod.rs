//! Whole-chip simulator: composes the WCFE PE-array model, the encoder and
//! search datapath models, the CDC FIFO and the energy model into
//! per-inference latency/energy reports (Fig.10) and an ISA [`crate::isa::Device`].

pub mod chip;
pub mod device;
pub mod trace;

pub use chip::{Chip, Mode, SimReport};
pub use device::SimDevice;
pub use trace::{ModuleCost, Trace};
