//! [`SimDevice`]: a *functional* ISA device — the interpreter's arithmetic
//! instructions perform real HDC compute (via any [`HdBackend`]) while
//! cycle costs come from the chip's datapath model. This is what makes the
//! Fig.8 programming model executable end-to-end: an assembled program
//! classifies actual samples.

use crate::hdc::chv::ChvStore;
use crate::hdc::quantize::quantize_features;
use crate::hdc::{best_two, HdBackend};
use crate::isa::interpreter::{Device, MachineState};
use crate::isa::intrinsics::q88_to_tau;
use crate::sim::chip::Chip;
use crate::Result;
use anyhow::{anyhow, bail};

pub struct SimDevice {
    pub chip: Chip,
    backend: Box<dyn HdBackend>,
    pub store: ChvStore,
    /// input feature queue; `Ldf` pops the next sample
    pub inputs: Vec<Vec<f32>>,
    next_input: usize,
    /// current raw + quantized feature buffer
    feature: Vec<f32>,
    qfeature: Vec<f32>,
    /// per-segment QHV cache (for Upd after Enc of all segments)
    qhv_segments: Vec<Option<Vec<f32>>>,
    /// accumulated distances
    acc: Vec<f32>,
    /// result register: last argmin
    pub predicted: Option<usize>,
    pub stored_results: Vec<usize>,
    /// at least one search ran since the last Ldf (Sto records a result
    /// only for inference flows; training's Sto is a CHV write-back)
    searched: bool,
    /// FIFO occupancy model
    fifo_words: usize,
}

impl SimDevice {
    pub fn new(backend: Box<dyn HdBackend>, chip: Chip) -> SimDevice {
        let cfg = backend.cfg().clone();
        SimDevice {
            chip,
            store: ChvStore::new(cfg.clone()),
            inputs: Vec::new(),
            next_input: 0,
            feature: Vec::new(),
            qfeature: Vec::new(),
            qhv_segments: vec![None; cfg.segments],
            acc: vec![0.0; cfg.classes],
            predicted: None,
            stored_results: Vec::new(),
            searched: false,
            backend,
            fifo_words: 0,
        }
    }

    pub fn queue_input(&mut self, x: Vec<f32>) {
        self.inputs.push(x);
    }

    fn reset_inference_state(&mut self) {
        self.acc.fill(0.0);
        for s in &mut self.qhv_segments {
            *s = None;
        }
        self.predicted = None;
        self.searched = false;
    }

    /// Assemble the full QHV from cached segments (requires all Enc'd).
    fn full_qhv(&self) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        for (s, seg) in self.qhv_segments.iter().enumerate() {
            out.extend_from_slice(
                seg.as_ref()
                    .ok_or_else(|| anyhow!("segment {s} not encoded before upd"))?,
            );
        }
        Ok(out)
    }
}

impl Device for SimDevice {
    fn load_weights(&mut self, _tile: u16) -> Result<u64> {
        // weight-buffer fill: 1KB buffer at 256 b/cycle
        Ok((self.chip.cfg.enc_weight_buffer_kb * 1024 * 8
            / self.chip.cfg.enc_weight_bits_per_cycle) as u64)
    }

    fn load_features(&mut self, _slot: u16) -> Result<u64> {
        if self.next_input >= self.inputs.len() {
            bail!("input queue exhausted");
        }
        self.feature = self.inputs[self.next_input].clone();
        self.next_input += 1;
        self.reset_inference_state();
        // feature load: 4 bytes/cycle SRAM port
        Ok(self.feature.len() as u64 / 4)
    }

    fn store(&mut self, _slot: u16) -> Result<u64> {
        if self.searched {
            let (best, _, _) = best_two(&self.acc);
            self.predicted = Some(best);
            self.stored_results.push(best);
        }
        // otherwise: training flow — Upd already wrote the CHV block back
        Ok(1)
    }

    fn fifo_push(&mut self, words: u16) -> Result<u64> {
        self.fifo_words += words as usize;
        Ok(words as u64 + 2)
    }

    fn fifo_pop(&mut self, words: u16) -> Result<u64> {
        if self.fifo_words < words as usize {
            bail!("fifo underflow");
        }
        self.fifo_words -= words as usize;
        Ok(words as u64 + 2)
    }

    fn encode_segment(&mut self, seg: u16) -> Result<u64> {
        let seg = seg as usize;
        if self.qfeature.is_empty() {
            bail!("qnt must run before enc");
        }
        let q = self.backend.encode_segment(&self.qfeature, 1, seg)?;
        self.qhv_segments[seg] = Some(q);
        Ok(self.chip.encode_segment_cycles(self.backend.cfg()))
    }

    fn search_segment(&mut self, seg: u16) -> Result<u64> {
        let cfg = self.backend.cfg().clone();
        let seg = seg as usize;
        let q = self.qhv_segments[seg]
            .as_ref()
            .ok_or_else(|| anyhow!("srch before enc of segment {seg}"))?
            .clone();
        let d = self.backend.search(
            &q,
            1,
            self.store.segment(seg),
            cfg.classes,
            cfg.seg_len(),
        )?;
        for (a, v) in self.acc.iter_mut().zip(&d) {
            *a += v;
        }
        self.searched = true;
        Ok(self.chip.search_segment_cycles(&cfg))
    }

    fn train_update(&mut self, class: u16) -> Result<u64> {
        let q = self.full_qhv()?;
        self.store.update(class as usize, &q, 1.0)?;
        Ok(self.chip.train_update_cycles(self.backend.cfg()))
    }

    fn conv_layer(&mut self, _layer: u16) -> Result<u64> {
        // feature extraction is modeled at chip level (the functional WCFE
        // path runs through the AOT artifact in the coordinator); the ISA
        // device charges representative cycles per layer.
        Ok(10_000)
    }

    fn compare_margin(&mut self, tau_q8_8: u16, state: &MachineState) -> Result<(bool, u64)> {
        let cfg = self.backend.cfg();
        let segs_done = self
            .qhv_segments
            .iter()
            .filter(|s| s.is_some())
            .count();
        let (_, b1, b2) = best_two(&self.acc);
        let remaining = ((cfg.segments - segs_done) * cfg.seg_len()) as f32;
        let tau = q88_to_tau(tau_q8_8);
        let exceeded = segs_done >= state.min_seg.max(1) as usize
            && (b2 - b1) > tau * cfg.mean_absdiff * remaining;
        Ok((exceeded, 1))
    }

    fn quantize(&mut self, _bits: u16) -> Result<u64> {
        if self.feature.is_empty() {
            bail!("ldf must run before qnt");
        }
        self.qfeature = quantize_features(&self.feature, self.backend.cfg().scale_x);
        Ok((self.feature.len() / 16).max(1) as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HdConfig;
    use crate::hdc::encoder::SoftwareEncoder;
    use crate::isa::intrinsics::{program_inference, program_train};
    use crate::isa::Interpreter;
    use crate::util::Rng;

    fn device() -> SimDevice {
        let cfg = HdConfig::synthetic("t", 8, 8, 32, 32, 8, 4);
        SimDevice::new(Box::new(SoftwareEncoder::random(cfg, 51)), Chip::default())
    }

    fn protos(n: usize, feat: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..feat).map(|_| rng.normal_f32() * 40.0).collect())
            .collect()
    }

    #[test]
    fn assembled_training_then_inference_classifies() {
        let mut dev = device();
        let cfg = dev.backend.cfg().clone();
        let ps = protos(4, cfg.features(), 52);
        let itp = Interpreter::default();

        // train each class via the ISA training program
        for (c, p) in ps.iter().enumerate() {
            dev.queue_input(p.clone());
            let prog = program_train(&cfg, c);
            itp.run(&prog, &mut dev).unwrap();
        }
        assert_eq!(dev.store.trained_classes(), 4);

        // classify each prototype via the progressive inference program
        let prog = program_inference(&cfg, 0, false, 0.3, 1);
        for (c, p) in ps.iter().enumerate() {
            dev.queue_input(p.clone());
            itp.run(&prog, &mut dev).unwrap();
            assert_eq!(dev.predicted, Some(c), "class {c} misclassified");
        }
        // only the 4 inference Sto's record results (training Sto is a
        // CHV write-back)
        assert_eq!(dev.stored_results.len(), 4);
    }

    #[test]
    fn early_exit_reduces_cycles() {
        let mut dev = device();
        let cfg = dev.backend.cfg().clone();
        let ps = protos(4, cfg.features(), 53);
        let itp = Interpreter::default();
        for (c, p) in ps.iter().enumerate() {
            dev.queue_input(p.clone());
            itp.run(&program_train(&cfg, c), &mut dev).unwrap();
        }
        // confident input, loose threshold -> early exit -> fewer cycles
        dev.queue_input(ps[0].clone());
        let loose = itp
            .run(&program_inference(&cfg, 0, false, 0.05, 1), &mut dev)
            .unwrap();
        dev.queue_input(ps[0].clone());
        let full = itp
            .run(&program_inference(&cfg, 0, false, f32::INFINITY, 1), &mut dev)
            .unwrap();
        assert!(loose.cycles < full.cycles, "{} !< {}", loose.cycles, full.cycles);
    }

    #[test]
    fn guards_against_misordered_programs() {
        let mut dev = device();
        // enc before qnt
        assert!(dev.encode_segment(0).is_err());
        // srch before enc
        assert!(dev.search_segment(0).is_err());
        // ldf with empty queue
        assert!(dev.load_features(0).is_err());
        // fifo pop underflow
        assert!(dev.fifo_pop(4).is_err());
    }
}
