//! Cluster-factored WCFE forward (Fig.7b executed, not just modeled) — the
//! pattern-reuse trick that gives the silicon its 4.66 TFLOPS/W, behind the
//! same forward API as the naive [`WcfeModel`].
//!
//! The naive conv multiplies every input scalar by `c_out` distinct weights.
//! With clustered weights those `c_out` values are draws from a K-entry
//! codebook, so the kernel computes the K products `x * centroid[k]` **once
//! per input scalar** and then gathers them by codebook index across the
//! output channels — `c_out` multiplies collapse to `K` multiplies plus
//! `c_out` indexed adds (the [`ReuseSchedule`](crate::wcfe::ReuseSchedule)
//! counts exactly this). Because each gathered value is bitwise the same
//! product the naive loop computes (`x * centroid[idx] == x * w`) and the
//! accumulation order is unchanged, [`conv3x3_clustered`] is **bit-exact**
//! against [`conv3x3_same`](crate::wcfe::conv::conv3x3_same) over the
//! codebook-reconstructed weights — the
//! parity property the tests pin, not an approximate claim.

use crate::util::pool::WorkerPool;
use crate::wcfe::codebook::{Codebook, LayerCodebook};
use crate::wcfe::conv::WcfeModel;
use crate::Result;
use anyhow::bail;

/// SAME-padded 3x3 convolution over (h, h, c_in) NHWC data with
/// cluster-factored weights: per input scalar, K centroid products computed
/// once and index-gathered across output channels. Bit-exact vs
/// [`conv3x3_same`](crate::wcfe::conv::conv3x3_same) over `cb.reconstruct()` (same products, same order,
/// same zero-input skip).
pub fn conv3x3_clustered(x: &[f32], h: usize, c_in: usize, cb: &LayerCodebook) -> Vec<f32> {
    let c_out = cb.c_out;
    assert_eq!(cb.k_in, 9 * c_in, "codebook k_in {} != 9 * c_in {}", cb.k_in, 9 * c_in);
    assert_eq!(x.len(), h * h * c_in);
    assert_eq!(cb.idx.len(), cb.k_in * c_out);
    let k = cb.centroids.len();
    let mut prod = vec![0.0f32; k];
    let mut out = vec![0.0f32; h * h * c_out];
    for py in 0..h {
        for px in 0..h {
            let obase = (py * h + px) * c_out;
            for (tap, (dy, dx)) in (0..3)
                .flat_map(|dy| (0..3).map(move |dx| (dy, dx)))
                .enumerate()
            {
                let iy = py as isize + dy as isize - 1;
                let ix = px as isize + dx as isize - 1;
                if iy < 0 || ix < 0 || iy >= h as isize || ix >= h as isize {
                    continue;
                }
                let ibase = (iy as usize * h + ix as usize) * c_in;
                for ci in 0..c_in {
                    let xv = x[ibase + ci];
                    if xv == 0.0 {
                        continue;
                    }
                    // K multiplies, reused across all c_out channels
                    for (p, &c) in prod.iter_mut().zip(&cb.centroids) {
                        *p = xv * c;
                    }
                    let row = tap * c_in + ci;
                    let irow = &cb.idx[row * c_out..(row + 1) * c_out];
                    let orow = &mut out[obase..obase + c_out];
                    for (o, &ki) in orow.iter_mut().zip(irow) {
                        *o += prod[ki as usize];
                    }
                }
            }
        }
    }
    out
}

/// A WCFE whose conv layers run the cluster-factored kernel — same
/// `forward(img)` surface and bit-identical features to the wrapped
/// [`WcfeModel`] (whose dense weights are the codebook reconstruction).
#[derive(Clone, Debug)]
pub struct ClusteredWcfe {
    pub model: WcfeModel,
    /// one codebook per conv layer, in layer order
    pub layers: Vec<LayerCodebook>,
}

impl ClusteredWcfe {
    /// Cluster a model's conv weights at `clusters` centroids (per-layer
    /// 1-D k-means) and replace its dense weights with their codebook
    /// reconstruction, so the naive and clustered forwards compute over the
    /// same effective weights and stay bit-comparable.
    pub fn cluster(mut model: WcfeModel, clusters: usize) -> ClusteredWcfe {
        let mut layers = Vec::with_capacity(model.convs.len());
        for (i, conv) in model.convs.iter_mut().enumerate() {
            let cb = LayerCodebook::from_weights(
                &format!("conv{}", i + 1),
                &conv.w,
                9 * conv.c_in,
                conv.c_out,
                clusters,
            );
            conv.w = cb.reconstruct();
            layers.push(cb);
        }
        ClusteredWcfe { model, layers }
    }

    /// Pair a model with a build-time codebook artifact; the model's dense
    /// weights are replaced by the codebook reconstruction (shape-checked
    /// per layer).
    pub fn from_codebook(mut model: WcfeModel, cb: &Codebook) -> Result<ClusteredWcfe> {
        if cb.layers.len() != model.convs.len() {
            bail!(
                "codebook has {} layers, model has {} conv layers",
                cb.layers.len(),
                model.convs.len()
            );
        }
        for (l, conv) in cb.layers.iter().zip(model.convs.iter_mut()) {
            if l.k_in != 9 * conv.c_in || l.c_out != conv.c_out {
                bail!(
                    "codebook layer {} is {}x{}, conv expects {}x{}",
                    l.name,
                    l.k_in,
                    l.c_out,
                    9 * conv.c_in,
                    conv.c_out
                );
            }
            conv.w = l.reconstruct();
        }
        Ok(ClusteredWcfe { model, layers: cb.layers.clone() })
    }

    /// Forward one image through the cluster-factored conv stack — same
    /// contract as [`WcfeModel::forward`].
    pub fn forward(&self, img: &[f32]) -> Result<Vec<f32>> {
        self.model
            .forward_with(img, |layer, x, h, c_in| {
                conv3x3_clustered(x, h, c_in, &self.layers[layer])
            })
    }

    /// Forward a batch of images, sharded across the worker pool (one
    /// scoped thread per contiguous block — the serve path's FE batching).
    /// Per-image results are bit-identical to [`ClusteredWcfe::forward`];
    /// a bad image fails alone without touching its neighbors.
    pub fn forward_batch(&self, imgs: &[&[f32]], pool: &WorkerPool) -> Vec<Result<Vec<f32>>> {
        pool.run_blocks(imgs.len(), |start, len| {
            imgs[start..start + len]
                .iter()
                .map(|img| self.forward(img))
                .collect::<Vec<_>>()
        })
        .into_iter()
        .flat_map(|(_, _, rs)| rs)
        .collect()
    }

    /// Absolute op count of one cluster-factored forward (K centroid
    /// multiplies per input scalar + `c_out` gathered adds, per conv layer,
    /// plus the dense FC MACs) — what the energy accounting charges a
    /// normal-mode query for feature extraction.
    pub fn clustered_ops(&self) -> u64 {
        let mut ops = 0u64;
        let mut h = self.model.image_hw as u64;
        for (conv, cb) in self.model.convs.iter().zip(&self.layers) {
            let inputs = h * h * 9 * conv.c_in as u64;
            ops += inputs * cb.centroids.len() as u64 + inputs * conv.c_out as u64;
            h /= 2;
        }
        ops + 2 * self.fc_macs()
    }

    /// What the same forward costs with dense (un-clustered) conv kernels
    /// — the baseline a bypassed query avoids entirely; the clustered /
    /// dense gap is the Fig.7 pattern-reuse saving.
    pub fn dense_ops(&self) -> u64 {
        let mut ops = 0u64;
        let mut h = self.model.image_hw as u64;
        for conv in &self.model.convs {
            let inputs = h * h * 9 * conv.c_in as u64;
            ops += 2 * inputs * conv.c_out as u64;
            h /= 2;
        }
        ops + 2 * self.fc_macs()
    }

    fn fc_macs(&self) -> u64 {
        (self.model.convs.last().map(|l| l.c_out).unwrap_or(0) * self.model.fc_out) as u64
    }

    /// Dense-vs-clustered multiply reduction of one forward pass over the
    /// conv stack (the Fig.7 2.1x CONV-compute story): the naive kernel
    /// multiplies each input scalar `c_out` times, the factored kernel only
    /// `K` times (the gathered adds exist in both).
    pub fn mult_reduction(&self) -> f64 {
        let mut dense = 0u64;
        let mut clustered = 0u64;
        let mut h = self.model.image_hw as u64;
        for (conv, cb) in self.model.convs.iter().zip(&self.layers) {
            let inputs = h * h * 9 * conv.c_in as u64;
            dense += inputs * conv.c_out as u64;
            clustered += inputs * cb.centroids.len() as u64;
            h /= 2;
        }
        dense as f64 / clustered.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::Rng;
    use crate::wcfe::conv::{conv3x3_same, ConvLayer};

    fn toy_model(rng: &mut Rng, channels: &[usize], image_hw: usize, image_c: usize) -> WcfeModel {
        let mut convs = Vec::new();
        let mut c_in = image_c;
        for &c_out in channels {
            convs.push(ConvLayer {
                w: (0..9 * c_in * c_out).map(|_| rng.normal_f32() * 0.2).collect(),
                c_in,
                c_out,
            });
            c_in = c_out;
        }
        let fc_out = 16;
        WcfeModel {
            convs,
            fc: (0..c_in * fc_out).map(|_| rng.normal_f32() * 0.2).collect(),
            fc_out,
            image_hw,
            image_c,
        }
    }

    #[test]
    fn clustered_conv_bit_exact_vs_naive_on_reconstructed_weights() {
        let mut rng = Rng::new(1);
        let (h, c_in, c_out) = (6usize, 3usize, 8usize);
        let w: Vec<f32> = (0..9 * c_in * c_out).map(|_| rng.normal_f32()).collect();
        let cb = LayerCodebook::from_weights("l", &w, 9 * c_in, c_out, 4);
        let wr = cb.reconstruct();
        let x: Vec<f32> = (0..h * h * c_in).map(|_| rng.normal_f32()).collect();
        let naive = conv3x3_same(&x, h, c_in, &wr, c_out);
        let clustered = conv3x3_clustered(&x, h, c_in, &cb);
        assert_eq!(naive, clustered, "must agree bit for bit");
    }

    #[test]
    fn prop_clustered_forward_bit_exact_vs_naive() {
        // the tentpole parity: whole-model forward, arbitrary images
        // (incl. exact zeros exercising the skip path), several cluster
        // counts — naive forward over reconstructed weights == clustered
        forall(8, 0xC1F, |rng| {
            let model = toy_model(rng, &[4, 6], 8, 3);
            let clusters = 2 + rng.below(7);
            let cw = ClusteredWcfe::cluster(model, clusters);
            let img: Vec<f32> = (0..8 * 8 * 3)
                .map(|_| if rng.below(8) == 0 { 0.0 } else { rng.uniform() as f32 })
                .collect();
            let naive = cw.model.forward(&img).unwrap();
            let fast = cw.forward(&img).unwrap();
            assert_eq!(naive, fast, "clusters={clusters}");
        });
    }

    #[test]
    fn from_codebook_checks_shapes_and_reconstructs() {
        let mut rng = Rng::new(3);
        let model = toy_model(&mut rng, &[4], 4, 3);
        let good = Codebook {
            layers: vec![LayerCodebook::from_weights(
                "conv1",
                &model.convs[0].w,
                9 * 3,
                4,
                4,
            )],
            dense_tail_bits: 0,
        };
        let cw = ClusteredWcfe::from_codebook(model.clone(), &good).unwrap();
        assert_eq!(cw.model.convs[0].w, good.layers[0].reconstruct());
        let img = vec![0.5f32; 4 * 4 * 3];
        assert_eq!(cw.model.forward(&img).unwrap(), cw.forward(&img).unwrap());

        let bad = Codebook { layers: vec![], dense_tail_bits: 0 };
        assert!(ClusteredWcfe::from_codebook(model.clone(), &bad).is_err());
        let small_w = vec![0.1f32; 18 * 4];
        let wrong_shape = Codebook {
            layers: vec![LayerCodebook::from_weights("conv1", &small_w, 18, 4, 4)],
            dense_tail_bits: 0,
        };
        assert!(ClusteredWcfe::from_codebook(model, &wrong_shape).is_err());
    }

    #[test]
    fn forward_batch_matches_per_image_forward() {
        let mut rng = Rng::new(9);
        let model = toy_model(&mut rng, &[4, 6], 8, 1);
        let cw = ClusteredWcfe::cluster(model, 4);
        let imgs: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..8 * 8).map(|_| rng.uniform() as f32).collect())
            .collect();
        let mut refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
        let bad = vec![0.0f32; 3];
        refs.push(&bad);
        for threads in [1usize, 3] {
            let pool = WorkerPool::new(threads);
            let out = cw.forward_batch(&refs, &pool);
            assert_eq!(out.len(), 6);
            for (img, r) in imgs.iter().zip(&out) {
                assert_eq!(r.as_ref().unwrap(), &cw.forward(img).unwrap());
            }
            assert!(out[5].is_err(), "short image fails alone");
        }
    }

    #[test]
    fn ops_accounting_orders_sanely() {
        let mut rng = Rng::new(5);
        let model = toy_model(&mut rng, &[8, 16], 16, 3);
        let cw = ClusteredWcfe::cluster(model, 4);
        let (dense, clustered) = (cw.dense_ops(), cw.clustered_ops());
        assert!(clustered > 0 && dense > clustered, "dense {dense} clustered {clustered}");
        // add counts match in both kernels; the multiply gap alone drives
        // the ratio, so it is bounded by mult_reduction
        let ratio = dense as f64 / clustered as f64;
        assert!(ratio < cw.mult_reduction() + 1e-9, "{ratio}");
    }

    #[test]
    fn mult_reduction_tracks_codebook_size() {
        let mut rng = Rng::new(4);
        let model = toy_model(&mut rng, &[32, 64], 16, 3);
        let cw = ClusteredWcfe::cluster(model, 16);
        let r = cw.mult_reduction();
        // per layer the reduction is c_out / K (32/16 = 2x, 64/16 = 4x);
        // the whole-stack number lands between the two
        assert!(r > 2.0 && r < 4.0, "reduction {r}");
    }
}
