//! Post-training 1-D k-means weight clustering (Fig.7a) — the Rust twin of
//! `python/compile/pretrain.py::kmeans_1d` (quantile init + Lloyd), used to
//! re-cluster at other codebook sizes for the ablation benches.

/// Lloyd's algorithm over scalar weight values; returns (centroids, index
/// per value). Deterministic: quantile initialization, fixed iteration cap.
pub fn kmeans_1d(values: &[f32], k: usize, iters: usize) -> (Vec<f32>, Vec<u32>) {
    assert!(k >= 1 && !values.is_empty());
    let mut sorted: Vec<f32> = values.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut cent: Vec<f64> = (0..k)
        .map(|j| {
            let q = (j as f64 + 0.5) / k as f64;
            let pos = q * (sorted.len() - 1) as f64;
            let lo = pos.floor() as usize;
            let hi = pos.ceil() as usize;
            let w = pos - lo as f64;
            sorted[lo] as f64 * (1.0 - w) + sorted[hi] as f64 * w
        })
        .collect();

    let assign = |cent: &[f64], v: f32| -> usize {
        let mut best = 0usize;
        let mut bd = f64::INFINITY;
        for (j, &c) in cent.iter().enumerate() {
            let d = (v as f64 - c).abs();
            if d < bd {
                bd = d;
                best = j;
            }
        }
        best
    };

    for _ in 0..iters {
        let mut sum = vec![0.0f64; k];
        let mut cnt = vec![0usize; k];
        for &v in values {
            let j = assign(&cent, v);
            sum[j] += v as f64;
            cnt[j] += 1;
        }
        for j in 0..k {
            if cnt[j] > 0 {
                cent[j] = sum[j] / cnt[j] as f64;
            }
        }
    }
    let idx: Vec<u32> = values.iter().map(|&v| assign(&cent, v) as u32).collect();
    (cent.iter().map(|&c| c as f32).collect(), idx)
}

/// Mean |w - centroid[idx]| / mean |w| — the clustering fidelity metric.
pub fn relative_l1_error(values: &[f32], cent: &[f32], idx: &[u32]) -> f64 {
    let num: f64 = values
        .iter()
        .zip(idx)
        .map(|(&v, &i)| (v - cent[i as usize]).abs() as f64)
        .sum();
    let den: f64 = values.iter().map(|&v| v.abs() as f64).sum();
    num / den.max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::Rng;

    #[test]
    fn recovers_separated_clusters() {
        let mut rng = Rng::new(1);
        let mut v = Vec::new();
        for &c in &[-3.0f32, 0.0, 4.0] {
            for _ in 0..50 {
                v.push(c + rng.normal_f32() * 0.01);
            }
        }
        let (cent, idx) = kmeans_1d(&v, 3, 30);
        let mut sorted = cent.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((sorted[0] + 3.0).abs() < 0.05);
        assert!(sorted[1].abs() < 0.05);
        assert!((sorted[2] - 4.0).abs() < 0.05);
        assert_eq!(idx.len(), v.len());
    }

    #[test]
    fn prop_assignment_is_nearest_and_error_bounded() {
        forall(20, 0x5EED, |rng| {
            let n = 50 + rng.below(200);
            let k = 2 + rng.below(15);
            let v: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let (cent, idx) = kmeans_1d(&v, k, 20);
            assert_eq!(cent.len(), k);
            for (x, &i) in v.iter().zip(&idx) {
                let d = (x - cent[i as usize]).abs();
                for &c in &cent {
                    assert!(d <= (x - c).abs() + 1e-5);
                }
            }
            // k clusters never worse than 1 cluster
            let (c1, i1) = kmeans_1d(&v, 1, 20);
            assert!(
                relative_l1_error(&v, &cent, &idx)
                    <= relative_l1_error(&v, &c1, &i1) + 1e-9
            );
        });
    }

    #[test]
    fn sixteen_clusters_give_small_error_on_gaussian_weights() {
        // matches the build-time observation (~9-10% rel L1 at 16 centroids)
        let mut rng = Rng::new(2);
        let v: Vec<f32> = (0..10_000).map(|_| rng.normal_f32() * 0.1).collect();
        let (cent, idx) = kmeans_1d(&v, 16, 30);
        let err = relative_l1_error(&v, &cent, &idx);
        assert!(err < 0.12, "err {err}");
    }
}
