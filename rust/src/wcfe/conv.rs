//! Software reference of the WCFE forward pass (conv3x3-relu-maxpool x3,
//! GAP, FC) — the same graph `python/compile/model.py::wcfe_forward` lowers,
//! in plain f32. Production inference uses the AOT `wcfe_fwd` artifact; this
//! twin exists for parity tests, ablations at other codebook sizes, and the
//! PE-array cost model's layer geometry.

use crate::data::TensorFile;
use crate::Result;
use anyhow::bail;

/// One conv layer's weights as a (k_in = 9*c_in) x c_out matrix.
#[derive(Clone, Debug)]
pub struct ConvLayer {
    pub w: Vec<f32>,
    pub c_in: usize,
    pub c_out: usize,
}

/// The WCFE model: conv stack + FC, loaded from wcfe_weights.bin.
#[derive(Clone, Debug)]
pub struct WcfeModel {
    pub convs: Vec<ConvLayer>,
    /// (c_last, fc_out)
    pub fc: Vec<f32>,
    pub fc_out: usize,
    pub image_hw: usize,
    pub image_c: usize,
}

impl WcfeModel {
    /// Load from the named-tensor artifact; layer channel plan must match.
    pub fn load(tf: &TensorFile, channels: &[usize], fc_out: usize,
                image_hw: usize, image_c: usize) -> Result<WcfeModel> {
        let mut convs = Vec::new();
        let mut c_in = image_c;
        for (i, &c_out) in channels.iter().enumerate() {
            let name = format!("conv{}", i + 1);
            let w = tf.f32_shaped(&name, &[9 * c_in, c_out])?;
            convs.push(ConvLayer { w: w.to_vec(), c_in, c_out });
            c_in = c_out;
        }
        let fc = tf.f32_shaped("fc", &[c_in, fc_out])?;
        Ok(WcfeModel {
            convs,
            fc: fc.to_vec(),
            fc_out,
            image_hw,
            image_c,
        })
    }

    /// A deterministic seeded WCFE — the scenario matrix's hermetic
    /// front-end: He-scaled normal weights drawn from `seed`, same layer
    /// plan as [`WcfeModel::load`]. Two calls with equal arguments build
    /// bit-identical models, so primaries, replicas and test references
    /// extract identical features without any artifact directory.
    pub fn seeded(
        image_hw: usize,
        image_c: usize,
        channels: &[usize],
        fc_out: usize,
        seed: u64,
    ) -> WcfeModel {
        let mut rng = crate::util::Rng::new(seed);
        let mut convs = Vec::with_capacity(channels.len());
        let mut c_in = image_c;
        for &c_out in channels {
            let scale = (2.0 / (9 * c_in) as f32).sqrt();
            convs.push(ConvLayer {
                w: (0..9 * c_in * c_out).map(|_| rng.normal_f32() * scale).collect(),
                c_in,
                c_out,
            });
            c_in = c_out;
        }
        let fc_scale = (2.0 / c_in as f32).sqrt();
        WcfeModel {
            convs,
            fc: (0..c_in * fc_out).map(|_| rng.normal_f32() * fc_scale).collect(),
            fc_out,
            image_hw,
            image_c,
        }
    }

    /// Forward one image (h*w*c row-major, values in [0,1]) to features.
    pub fn forward(&self, img: &[f32]) -> Result<Vec<f32>> {
        self.forward_with(img, |layer, x, h, c_in| {
            conv3x3_same(x, h, c_in, &self.convs[layer].w, self.convs[layer].c_out)
        })
    }

    /// The forward pass with a pluggable conv kernel: `conv(layer, x, h,
    /// c_in)` must return the layer's (h, h, c_out) pre-activation plane.
    /// Everything around it (input normalization, relu, maxpool, GAP, FC)
    /// is shared, which is what keeps the naive and cluster-factored paths
    /// bit-comparable ([`crate::wcfe::clustered`]).
    pub fn forward_with<F>(&self, img: &[f32], mut conv: F) -> Result<Vec<f32>>
    where
        F: FnMut(usize, &[f32], usize, usize) -> Vec<f32>,
    {
        let hw = self.image_hw;
        if img.len() != hw * hw * self.image_c {
            bail!("image len {} != {}", img.len(), hw * hw * self.image_c);
        }
        // input normalization matches model.py: x*2 - 1
        let mut x: Vec<f32> = img.iter().map(|&v| v * 2.0 - 1.0).collect();
        let mut h = hw;
        let mut c = self.image_c;
        for (li, layer) in self.convs.iter().enumerate() {
            x = conv(li, &x, h, c);
            debug_assert_eq!(x.len(), h * h * layer.c_out);
            for v in &mut x {
                *v = v.max(0.0); // relu
            }
            x = maxpool2(&x, h, layer.c_out);
            h /= 2;
            c = layer.c_out;
        }
        // global average pool -> (c,)
        let mut gap = vec![0.0f32; c];
        let positions = (h * h) as f32;
        for p in 0..h * h {
            for ch in 0..c {
                gap[ch] += x[p * c + ch];
            }
        }
        for v in &mut gap {
            *v /= positions;
        }
        // fc: (c) @ (c, fc_out)
        let mut out = vec![0.0f32; self.fc_out];
        for (i, &g) in gap.iter().enumerate() {
            let row = &self.fc[i * self.fc_out..(i + 1) * self.fc_out];
            for (o, &w) in out.iter_mut().zip(row) {
                *o += g * w;
            }
        }
        Ok(out)
    }

    /// Layer output geometries (for the PE-array cost model): (h, w) of each
    /// conv layer's output plane (before pooling).
    pub fn layer_geometries(&self) -> Vec<(usize, usize)> {
        let mut h = self.image_hw;
        let mut out = Vec::new();
        for _ in &self.convs {
            out.push((h, h));
            h /= 2;
        }
        out
    }

    /// Total dense MACs of one forward pass (conv + fc).
    pub fn dense_macs(&self) -> u64 {
        let mut h = self.image_hw as u64;
        let mut total = 0u64;
        for l in &self.convs {
            total += h * h * (9 * l.c_in * l.c_out) as u64;
            h /= 2;
        }
        total + (self.convs.last().map(|l| l.c_out).unwrap_or(0) * self.fc_out) as u64
    }
}

/// SAME-padded 3x3 convolution over (h, h, c_in) row-major NHWC data.
/// w is (9*c_in, c_out) with patch order matching model.py's im2col
/// (dy-major, then dx, then channel).
pub fn conv3x3_same(x: &[f32], h: usize, c_in: usize, w: &[f32], c_out: usize) -> Vec<f32> {
    assert_eq!(x.len(), h * h * c_in);
    assert_eq!(w.len(), 9 * c_in * c_out);
    let mut out = vec![0.0f32; h * h * c_out];
    for py in 0..h {
        for px in 0..h {
            let obase = (py * h + px) * c_out;
            for (tap, (dy, dx)) in (0..3)
                .flat_map(|dy| (0..3).map(move |dx| (dy, dx)))
                .enumerate()
            {
                let iy = py as isize + dy as isize - 1;
                let ix = px as isize + dx as isize - 1;
                if iy < 0 || ix < 0 || iy >= h as isize || ix >= h as isize {
                    continue;
                }
                let ibase = (iy as usize * h + ix as usize) * c_in;
                for ci in 0..c_in {
                    let xv = x[ibase + ci];
                    if xv == 0.0 {
                        continue;
                    }
                    let wrow = &w[(tap * c_in + ci) * c_out..(tap * c_in + ci + 1) * c_out];
                    let orow = &mut out[obase..obase + c_out];
                    for (o, &wv) in orow.iter_mut().zip(wrow) {
                        *o += xv * wv;
                    }
                }
            }
        }
    }
    out
}

/// 2x2 max pooling over (h, h, c) NHWC.
pub fn maxpool2(x: &[f32], h: usize, c: usize) -> Vec<f32> {
    let oh = h / 2;
    let mut out = vec![f32::NEG_INFINITY; oh * oh * c];
    for py in 0..oh {
        for px in 0..oh {
            for dy in 0..2 {
                for dx in 0..2 {
                    let ibase = ((2 * py + dy) * h + 2 * px + dx) * c;
                    let obase = (py * oh + px) * c;
                    for ch in 0..c {
                        let v = x[ibase + ch];
                        if v > out[obase + ch] {
                            out[obase + ch] = v;
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn conv_identity_kernel_center_tap() {
        // kernel with 1.0 at the center tap copies the input channel
        let h = 4;
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..h * h).map(|_| rng.normal_f32()).collect();
        let mut w = vec![0.0f32; 9];
        w[4] = 1.0; // tap (dy=1, dx=1), c_in=c_out=1
        let y = conv3x3_same(&x, h, 1, &w, 1);
        assert_eq!(y, x);
    }

    #[test]
    fn conv_counts_border_taps_correctly() {
        // all-ones kernel over all-ones image: corner=4, edge=6, interior=9
        let h = 4;
        let x = vec![1.0f32; h * h];
        let w = vec![1.0f32; 9];
        let y = conv3x3_same(&x, h, 1, &w, 1);
        assert_eq!(y[0], 4.0);
        assert_eq!(y[1], 6.0);
        assert_eq!(y[h + 1], 9.0);
    }

    #[test]
    fn maxpool_picks_max() {
        let x = vec![
            1.0, 5.0, 2.0, 0.0, //
            3.0, 4.0, 1.0, 7.0, //
            0.0, 0.0, 9.0, 1.0, //
            2.0, 1.0, 0.0, 3.0,
        ];
        let y = maxpool2(&x, 4, 1);
        assert_eq!(y, vec![5.0, 7.0, 2.0, 9.0]);
    }

    #[test]
    fn seeded_models_are_deterministic() {
        let a = WcfeModel::seeded(8, 1, &[4, 8], 16, 42);
        let b = WcfeModel::seeded(8, 1, &[4, 8], 16, 42);
        assert_eq!(a.fc, b.fc);
        for (la, lb) in a.convs.iter().zip(&b.convs) {
            assert_eq!(la.w, lb.w);
        }
        let c = WcfeModel::seeded(8, 1, &[4, 8], 16, 43);
        assert_ne!(a.fc, c.fc, "different seeds must differ");
        let img: Vec<f32> = (0..8 * 8).map(|i| (i % 7) as f32 / 7.0).collect();
        let fa = a.forward(&img).unwrap();
        assert_eq!(fa.len(), 16);
        assert!(fa.iter().all(|v| v.is_finite()));
        assert_eq!(fa, b.forward(&img).unwrap());
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let mut rng = Rng::new(2);
        let channels = [4usize, 8];
        let mut convs = Vec::new();
        let mut c_in = 3;
        for &c_out in &channels {
            convs.push(ConvLayer {
                w: (0..9 * c_in * c_out).map(|_| rng.normal_f32() * 0.1).collect(),
                c_in,
                c_out,
            });
            c_in = c_out;
        }
        let model = WcfeModel {
            convs,
            fc: (0..8 * 16).map(|_| rng.normal_f32() * 0.1).collect(),
            fc_out: 16,
            image_hw: 8,
            image_c: 3,
        };
        let img: Vec<f32> = (0..8 * 8 * 3).map(|_| rng.uniform() as f32).collect();
        let f = model.forward(&img).unwrap();
        assert_eq!(f.len(), 16);
        assert!(f.iter().all(|v| v.is_finite()));
        assert_eq!(model.layer_geometries(), vec![(8, 8), (4, 4)]);
        assert!(model.dense_macs() > 0);
    }
}
