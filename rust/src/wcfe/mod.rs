//! WCFE — Weight-Clustering Feature Extractor (Fig.7).
//!
//! Numerics of the conv stack run through the AOT `wcfe_fwd` artifact (or
//! the [`conv`] software reference); what lives here natively is the paper's
//! *architectural* content:
//! * post-training weight clustering + codebook ([`clustering`], [`codebook`]),
//! * the pattern-reuse schedule (accumulate inputs sharing a weight index,
//!   multiply once — [`schedule`]) and the cluster-factored conv forward
//!   that *executes* it bit-exactly against the naive reference
//!   ([`clustered`]),
//! * the 4x16 PE-array cycle/op model behind the 1.9x parameter and 2.1x
//!   CONV-compute reduction claims ([`pe_array`]).

pub mod clustered;
pub mod clustering;
pub mod codebook;
pub mod conv;
pub mod pe_array;
pub mod schedule;

pub use clustered::{conv3x3_clustered, ClusteredWcfe};
pub use clustering::kmeans_1d;
pub use codebook::{Codebook, LayerCodebook};
pub use conv::WcfeModel;
pub use pe_array::{PeArray, PeCost};
pub use schedule::ReuseSchedule;
