//! 4x16 PE-array cycle/op/energy model (Fig.7c).
//!
//! Each PE has 1 BF16 MAC and 4 register files; the RFs let a PE accumulate
//! the next output's cluster bins while the multiplier drains the previous
//! one, so per-output latency is max(adds, mults) instead of adds + mults.
//! The model yields the Fig.7 compute-reduction factor (~2.1x on the paper's
//! network) and feeds the chip-level latency/energy breakdowns (Fig.10c/d).

use crate::config::ChipConfig;
use crate::wcfe::schedule::ReuseSchedule;

/// Arithmetic-op and cycle cost of one conv layer over all output positions.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PeCost {
    pub mults: u64,
    pub adds: u64,
    pub cycles: u64,
    /// MAC-slot utilization of the array during this layer
    pub utilization: f64,
}

/// Geometry of one conv layer's output plane.
#[derive(Clone, Copy, Debug)]
pub struct LayerGeometry {
    pub out_h: usize,
    pub out_w: usize,
}

impl LayerGeometry {
    pub fn positions(&self) -> u64 {
        (self.out_h * self.out_w) as u64
    }
}

pub struct PeArray {
    pub chip: ChipConfig,
}

impl PeArray {
    pub fn new(chip: ChipConfig) -> PeArray {
        PeArray { chip }
    }

    pub fn pes(&self) -> u64 {
        self.chip.pe_count() as u64
    }

    /// Dense execution: one MAC per weight per output position, spread over
    /// the array.
    pub fn dense_cost(&self, sched: &ReuseSchedule, geo: LayerGeometry) -> PeCost {
        let per_pos = sched.dense_mults() as u64; // MACs
        let total = per_pos * geo.positions();
        let cycles = total.div_ceil(self.pes());
        PeCost {
            mults: total,
            adds: total, // each MAC = mult + add
            cycles,
            utilization: 1.0,
        }
    }

    /// Clustered execution with pattern reuse: K adds + M mults per output
    /// position; the 4 RFs overlap accumulate/multiply phases so the
    /// per-position latency contribution is max(K, M) MAC-slots, provided
    /// the RF depth covers the phase imbalance (it does for ncl <= K).
    pub fn clustered_cost(&self, sched: &ReuseSchedule, geo: LayerGeometry) -> PeCost {
        let adds_pp = sched.adds() as u64;
        let mults_pp = sched.clustered_mults() as u64;
        let slots_pp = adds_pp.max(mults_pp);
        let total_slots = slots_pp * geo.positions();
        let cycles = total_slots.div_ceil(self.pes());
        PeCost {
            mults: mults_pp * geo.positions(),
            adds: adds_pp * geo.positions(),
            cycles,
            utilization: (adds_pp + mults_pp) as f64 / (2 * slots_pp) as f64,
        }
    }

    /// Fig.7's CONV-compute reduction: dense MAC-slots / clustered slots.
    /// Energy-weighted ops with the calibrated BF16 mult:add cost ratio
    /// (crate::energy::EnergyModel::mult_add_ratio = 1.2) — the paper's
    /// "computation" metric follows datapath energy.
    pub fn compute_reduction(&self, sched: &ReuseSchedule, geo: LayerGeometry) -> f64 {
        const MULT_ADD_RATIO: f64 = 1.2;
        let d = self.dense_cost(sched, geo);
        let c = self.clustered_cost(sched, geo);
        let dense_e = MULT_ADD_RATIO * d.mults as f64 + d.adds as f64;
        let clus_e = MULT_ADD_RATIO * c.mults as f64 + c.adds as f64;
        dense_e / clus_e
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::wcfe::codebook::LayerCodebook;

    fn sched(k_in: usize, c_out: usize, ncl: usize, seed: u64) -> ReuseSchedule {
        let mut rng = Rng::new(seed);
        let w: Vec<f32> = (0..k_in * c_out).map(|_| rng.normal_f32()).collect();
        ReuseSchedule::build(&LayerCodebook::from_weights("l", &w, k_in, c_out, ncl))
    }

    fn arr() -> PeArray {
        PeArray::new(ChipConfig::default())
    }

    #[test]
    fn dense_cycles_ideal_spread() {
        let s = sched(27, 32, 16, 1);
        let geo = LayerGeometry { out_h: 32, out_w: 32 };
        let c = arr().dense_cost(&s, geo);
        assert_eq!(c.mults, 27 * 32 * 1024);
        assert_eq!(c.cycles, (27 * 32 * 1024u64).div_ceil(64));
    }

    #[test]
    fn clustered_fewer_mults_same_adds() {
        let s = sched(288, 64, 16, 2);
        let geo = LayerGeometry { out_h: 16, out_w: 16 };
        let d = arr().dense_cost(&s, geo);
        let c = arr().clustered_cost(&s, geo);
        assert!(c.mults < d.mults / 10);
        assert_eq!(c.adds, d.adds);
        assert!(c.cycles <= d.cycles);
    }

    #[test]
    fn compute_reduction_near_paper_for_big_layers() {
        // paper: 2.1x CONV-computation reduction; our conv2/conv3-shaped
        // layers land in the 1.8-2.2 band with the 2:1 mult:add energy model
        let s = sched(576, 128, 16, 3);
        let geo = LayerGeometry { out_h: 8, out_w: 8 };
        let r = arr().compute_reduction(&s, geo);
        assert!(r > 1.9 && r < 2.3, "reduction {r}");
    }

    #[test]
    fn tiny_layer_gains_little() {
        // conv1 (K=27) has little sharing to exploit — reduction < 1.6
        let s = sched(27, 32, 16, 4);
        let geo = LayerGeometry { out_h: 32, out_w: 32 };
        let r = arr().compute_reduction(&s, geo);
        assert!(r < 1.7, "reduction {r}");
    }

    #[test]
    fn utilization_bounded() {
        let s = sched(288, 64, 16, 5);
        let geo = LayerGeometry { out_h: 4, out_w: 4 };
        let c = arr().clustered_cost(&s, geo);
        assert!(c.utilization > 0.0 && c.utilization <= 1.0);
    }
}
