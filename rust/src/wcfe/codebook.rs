//! Weight codebook (Fig.7a): per-layer centroid table + per-weight indices,
//! and the storage model behind the paper's 1.9x parameter reduction.

use crate::data::TensorFile;
use crate::wcfe::clustering::kmeans_1d;
use crate::Result;
use anyhow::bail;

/// One conv layer's clustered weights: idx is (k_in x c_out) row-major.
#[derive(Clone, Debug)]
pub struct LayerCodebook {
    pub name: String,
    pub centroids: Vec<f32>,
    pub idx: Vec<u32>,
    pub k_in: usize,
    pub c_out: usize,
}

impl LayerCodebook {
    pub fn from_weights(name: &str, w: &[f32], k_in: usize, c_out: usize,
                        clusters: usize) -> LayerCodebook {
        assert_eq!(w.len(), k_in * c_out);
        let (centroids, idx) = kmeans_1d(w, clusters, 30);
        LayerCodebook { name: name.into(), centroids, idx, k_in, c_out }
    }

    /// Reconstruct the dense weight matrix from the codebook.
    pub fn reconstruct(&self) -> Vec<f32> {
        self.idx.iter().map(|&i| self.centroids[i as usize]).collect()
    }

    /// Index width in bits (ceil log2 of codebook size).
    pub fn index_bits(&self) -> u32 {
        (usize::BITS - (self.centroids.len() - 1).leading_zeros()).max(1)
    }

    /// Storage bits: dense BF16 vs clustered (index table + centroid table).
    pub fn dense_bits(&self) -> u64 {
        self.idx.len() as u64 * 16
    }

    pub fn clustered_bits(&self) -> u64 {
        self.idx.len() as u64 * self.index_bits() as u64
            + self.centroids.len() as u64 * 16
    }
}

/// The whole WCFE's codebooks (conv layers clustered; FC stays dense BF16,
/// mirroring the paper which clusters the CONV filters).
#[derive(Clone, Debug)]
pub struct Codebook {
    pub layers: Vec<LayerCodebook>,
    /// dense (unclustered) parameter bits outside the codebooks (FC)
    pub dense_tail_bits: u64,
}

impl Codebook {
    /// Load the build-time codebook artifact (wcfe_codebook.bin).
    pub fn load(tf: &TensorFile, layer_names: &[&str], fc_params: u64) -> Result<Codebook> {
        let mut layers = Vec::new();
        for name in layer_names {
            let cent = tf.f32(&format!("{name}_centroids"))?;
            let idx_t = tf.get(&format!("{name}_idx"))?;
            let dims = idx_t.dims().to_vec();
            if dims.len() != 2 {
                bail!("{name}_idx must be 2-D, got {dims:?}");
            }
            let idx: Vec<u32> = idx_t.as_i32()?.iter().map(|&v| v as u32).collect();
            layers.push(LayerCodebook {
                name: name.to_string(),
                centroids: cent.to_vec(),
                idx,
                k_in: dims[0],
                c_out: dims[1],
            });
        }
        Ok(Codebook { layers, dense_tail_bits: fc_params * 16 })
    }

    /// Total model parameter bits, dense BF16 baseline.
    pub fn total_dense_bits(&self) -> u64 {
        self.layers.iter().map(|l| l.dense_bits()).sum::<u64>() + self.dense_tail_bits
    }

    /// Total model parameter bits with clustering.
    pub fn total_clustered_bits(&self) -> u64 {
        self.layers.iter().map(|l| l.clustered_bits()).sum::<u64>() + self.dense_tail_bits
    }

    /// The Fig.7 parameter-reduction factor (paper: 1.9x).
    pub fn param_reduction(&self) -> f64 {
        self.total_dense_bits() as f64 / self.total_clustered_bits() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn toy_layer(k_in: usize, c_out: usize, clusters: usize, seed: u64) -> LayerCodebook {
        let mut rng = Rng::new(seed);
        let w: Vec<f32> = (0..k_in * c_out).map(|_| rng.normal_f32() * 0.1).collect();
        LayerCodebook::from_weights("l", &w, k_in, c_out, clusters)
    }

    #[test]
    fn reconstruct_uses_centroid_values() {
        let l = toy_layer(9, 4, 4, 1);
        let w = l.reconstruct();
        assert_eq!(w.len(), 36);
        for (v, &i) in w.iter().zip(&l.idx) {
            assert_eq!(*v, l.centroids[i as usize]);
        }
    }

    #[test]
    fn index_bits() {
        assert_eq!(toy_layer(9, 4, 16, 2).index_bits(), 4);
        assert_eq!(toy_layer(9, 4, 2, 3).index_bits(), 1);
        assert_eq!(toy_layer(9, 4, 5, 4).index_bits(), 3);
    }

    #[test]
    fn param_reduction_matches_paper_shape() {
        // Our cifar WCFE: conv 27x32, 288x64, 576x128 clustered @16 (4-bit
        // idx), FC 128*512 dense bf16 -> overall ~1.8-2x, the paper's 1.9x.
        let layers = vec![
            toy_layer(27, 32, 16, 5),
            toy_layer(288, 64, 16, 6),
            toy_layer(576, 128, 16, 7),
        ];
        let cb = Codebook { layers, dense_tail_bits: 128 * 512 * 16 };
        let r = cb.param_reduction();
        assert!(r > 1.6 && r < 2.4, "param reduction {r}");
    }

    #[test]
    fn conv_only_reduction_is_near_4x() {
        let cb = Codebook {
            layers: vec![toy_layer(288, 64, 16, 8)],
            dense_tail_bits: 0,
        };
        let r = cb.param_reduction();
        assert!(r > 3.5 && r < 4.1, "{r}");
    }
}
