//! Pattern-reuse schedule (Fig.7b): for each output channel, group the
//! weight positions by codebook index so inputs sharing a weight are
//! ACCUMULATED first and MULTIPLIED once. This is the data structure the PE
//! array walks; its shape determines the add/multiply counts in
//! [`crate::wcfe::pe_array`].

use crate::wcfe::codebook::LayerCodebook;

/// For one output channel: `groups[c]` = the input-patch positions whose
/// weight maps to centroid `c`.
#[derive(Clone, Debug)]
pub struct ChannelSchedule {
    pub groups: Vec<Vec<u32>>,
}

impl ChannelSchedule {
    /// Non-empty groups = number of multiplies this channel needs.
    pub fn multiplies(&self) -> usize {
        self.groups.iter().filter(|g| !g.is_empty()).count()
    }

    /// Total accumulation adds (= k_in, every input added into some bin).
    pub fn adds(&self) -> usize {
        self.groups.iter().map(|g| g.len()).sum()
    }
}

/// The whole layer's reuse schedule.
#[derive(Clone, Debug)]
pub struct ReuseSchedule {
    pub channels: Vec<ChannelSchedule>,
    pub k_in: usize,
}

impl ReuseSchedule {
    pub fn build(cb: &LayerCodebook) -> ReuseSchedule {
        let ncl = cb.centroids.len();
        let mut channels = Vec::with_capacity(cb.c_out);
        for co in 0..cb.c_out {
            let mut groups = vec![Vec::new(); ncl];
            for k in 0..cb.k_in {
                let idx = cb.idx[k * cb.c_out + co] as usize;
                groups[idx].push(k as u32);
            }
            channels.push(ChannelSchedule { groups });
        }
        ReuseSchedule { channels, k_in: cb.k_in }
    }

    /// Dense multiply count per output position (one MAC per weight).
    pub fn dense_mults(&self) -> usize {
        self.channels.len() * self.k_in
    }

    /// Clustered multiply count per output position.
    pub fn clustered_mults(&self) -> usize {
        self.channels.iter().map(|c| c.multiplies()).sum()
    }

    /// Accumulation adds per output position (same dense vs clustered).
    pub fn adds(&self) -> usize {
        self.channels.iter().map(|c| c.adds()).sum()
    }

    /// Execute the schedule on one input patch (reference semantics used by
    /// tests to prove reuse == dense math).
    pub fn apply(&self, cb: &LayerCodebook, patch: &[f32]) -> Vec<f32> {
        assert_eq!(patch.len(), self.k_in);
        self.channels
            .iter()
            .map(|ch| {
                let mut acc = 0.0f32;
                for (c, group) in ch.groups.iter().enumerate() {
                    if group.is_empty() {
                        continue;
                    }
                    // accumulate inputs sharing weight c ...
                    let s: f32 = group.iter().map(|&k| patch[k as usize]).sum();
                    // ... multiply once
                    acc += s * cb.centroids[c];
                }
                acc
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};
    use crate::util::Rng;
    use crate::wcfe::codebook::LayerCodebook;

    fn toy(k_in: usize, c_out: usize, clusters: usize, seed: u64) -> LayerCodebook {
        let mut rng = Rng::new(seed);
        let w: Vec<f32> = (0..k_in * c_out).map(|_| rng.normal_f32()).collect();
        LayerCodebook::from_weights("l", &w, k_in, c_out, clusters)
    }

    #[test]
    fn schedule_covers_every_weight_once() {
        let cb = toy(27, 8, 4, 1);
        let s = ReuseSchedule::build(&cb);
        for ch in &s.channels {
            let mut seen: Vec<u32> = ch.groups.iter().flatten().copied().collect();
            seen.sort_unstable();
            assert_eq!(seen, (0..27).collect::<Vec<_>>());
        }
        assert_eq!(s.adds(), 27 * 8);
    }

    #[test]
    fn reuse_math_equals_dense_matmul() {
        let cb = toy(18, 6, 4, 2);
        let s = ReuseSchedule::build(&cb);
        let w = cb.reconstruct();
        let mut rng = Rng::new(3);
        let patch: Vec<f32> = (0..18).map(|_| rng.normal_f32()).collect();
        let got = s.apply(&cb, &patch);
        for co in 0..6 {
            let want: f32 = (0..18).map(|k| patch[k] * w[k * 6 + co]).sum();
            assert!((got[co] - want).abs() < 1e-4, "{} vs {}", got[co], want);
        }
    }

    #[test]
    fn clustered_mults_bounded_by_codebook_size() {
        let cb = toy(288, 16, 16, 4);
        let s = ReuseSchedule::build(&cb);
        assert!(s.clustered_mults() <= 16 * 16);
        assert!(s.clustered_mults() < s.dense_mults());
    }

    #[test]
    fn prop_mult_reduction_grows_with_fan_in() {
        forall(10, 0xF16, |rng| {
            let k_in = gen::choice(rng, &[64usize, 256, 512]);
            let cb = toy(k_in, 4, 16, rng.next_u64());
            let s = ReuseSchedule::build(&cb);
            let reduction = s.dense_mults() as f64 / s.clustered_mults() as f64;
            assert!(reduction >= k_in as f64 / 16.0 * 0.9);
        });
    }
}
