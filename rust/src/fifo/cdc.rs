//! Dual-clock (CDC) FIFO model with backpressure.
//!
//! Functionally a bounded queue; for timing it models a producer domain at
//! `w_freq` and consumer domain at `r_freq` (the WCFE and HD modules run on
//! independent clocks in the 50-250 MHz envelope) with gray-code-sync
//! latency of 2 consumer cycles per pointer crossing.

use anyhow::{bail, Result};
use std::collections::VecDeque;

#[derive(Clone, Debug, Default)]
pub struct FifoStats {
    pub pushed: u64,
    pub popped: u64,
    /// push attempts rejected because the FIFO was full (backpressure)
    pub stalls_full: u64,
    /// pop attempts rejected because the FIFO was empty
    pub stalls_empty: u64,
    pub max_occupancy: usize,
}

/// Bounded CDC FIFO carrying f32 words (feature values crossing domains).
#[derive(Clone, Debug)]
pub struct CdcFifo {
    q: VecDeque<f32>,
    pub capacity: usize,
    pub stats: FifoStats,
}

impl CdcFifo {
    pub fn new(capacity: usize) -> CdcFifo {
        assert!(capacity > 0);
        CdcFifo { q: VecDeque::with_capacity(capacity), capacity, stats: FifoStats::default() }
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.q.len() >= self.capacity
    }

    /// Push one word; Err = backpressure (caller must retry — nothing is
    /// dropped silently).
    pub fn push(&mut self, v: f32) -> Result<()> {
        if self.is_full() {
            self.stats.stalls_full += 1;
            bail!("fifo full (capacity {})", self.capacity);
        }
        self.q.push_back(v);
        self.stats.pushed += 1;
        self.stats.max_occupancy = self.stats.max_occupancy.max(self.q.len());
        Ok(())
    }

    pub fn pop(&mut self) -> Result<f32> {
        match self.q.pop_front() {
            Some(v) => {
                self.stats.popped += 1;
                Ok(v)
            }
            None => {
                self.stats.stalls_empty += 1;
                bail!("fifo empty")
            }
        }
    }

    /// Push a whole slice, returning how many words were accepted.
    pub fn push_slice(&mut self, vs: &[f32]) -> usize {
        let mut n = 0;
        for &v in vs {
            if self.push(v).is_err() {
                break;
            }
            n += 1;
        }
        n
    }

    /// Pop up to `n` words.
    pub fn pop_n(&mut self, n: usize) -> Vec<f32> {
        let take = n.min(self.q.len());
        (0..take).map(|_| self.pop().unwrap()).collect()
    }

    /// Cycle cost (in CONSUMER cycles) of transferring `words` across the
    /// domain crossing: limited by the slower of the two domains, plus the
    /// 2-cycle gray-code pointer synchronization.
    pub fn transfer_cycles(&self, words: usize, w_freq_mhz: f64, r_freq_mhz: f64) -> u64 {
        if words == 0 {
            return 0;
        }
        // producer fills at w_freq, consumer drains at r_freq; the transfer
        // rate in consumer cycles/word is max(1, r/w).
        let ratio = (r_freq_mhz / w_freq_mhz).max(1.0);
        (words as f64 * ratio).ceil() as u64 + 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn fifo_order_preserved() {
        let mut f = CdcFifo::new(4);
        for v in [1.0, 2.0, 3.0] {
            f.push(v).unwrap();
        }
        assert_eq!(f.pop().unwrap(), 1.0);
        assert_eq!(f.pop().unwrap(), 2.0);
        assert_eq!(f.pop().unwrap(), 3.0);
        assert!(f.pop().is_err());
        assert_eq!(f.stats.stalls_empty, 1);
    }

    #[test]
    fn backpressure_on_full() {
        let mut f = CdcFifo::new(2);
        assert_eq!(f.push_slice(&[1.0, 2.0, 3.0]), 2);
        assert!(f.is_full());
        assert_eq!(f.stats.stalls_full, 1);
        f.pop().unwrap();
        assert!(f.push(3.0).is_ok());
    }

    #[test]
    fn prop_no_loss_no_duplication() {
        forall(30, 0xF1F0, |rng| {
            let cap = 1 + rng.below(64);
            let mut f = CdcFifo::new(cap);
            let mut reference = std::collections::VecDeque::new();
            for _ in 0..200 {
                if rng.bool(0.55) {
                    let v = rng.next_u64() as u32 as f32;
                    if f.push(v).is_ok() {
                        reference.push_back(v);
                    }
                } else if let Ok(v) = f.pop() {
                    assert_eq!(Some(v), reference.pop_front());
                }
                assert_eq!(f.len(), reference.len());
                assert!(f.len() <= cap);
            }
            assert_eq!(f.stats.pushed - f.stats.popped, f.len() as u64);
        });
    }

    #[test]
    fn transfer_cycles_scales_with_domain_ratio() {
        let f = CdcFifo::new(1024);
        // same speed domains: 1 cycle/word + 2 sync
        assert_eq!(f.transfer_cycles(100, 250.0, 250.0), 102);
        // slow producer (50 MHz) into fast consumer (250 MHz): consumer
        // waits 5 cycles/word
        assert_eq!(f.transfer_cycles(100, 50.0, 250.0), 502);
        // fast producer into slow consumer: consumer-bound, 1 cycle/word
        assert_eq!(f.transfer_cycles(100, 250.0, 50.0), 102);
        assert_eq!(f.transfer_cycles(0, 50.0, 250.0), 0);
    }
}
