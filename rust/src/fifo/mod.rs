//! Global CDC FIFO (Fig.3/Fig.4): the dual-clock handoff between the WCFE
//! and HD clock domains that makes the dual-mode data flows composable.

pub mod cdc;

pub use cdc::{CdcFifo, FifoStats};
