//! Clo-HDnn CLI — the leader entrypoint.
//!
//! Subcommands:
//!   info                         inspect artifacts (or list built-in configs)
//!   infer   --config <name>      progressive inference over the test set
//!   cl-run  --config <name>      continual-learning experiment (Fig.9 row)
//!   sim     --config <name>      chip latency/energy report (Fig.10)
//!   serve   --config <name>      Poisson-traffic serving demo
//!   bench   --config <name>      packed-vs-scalar perf harness -> BENCH_classifier.json
//!   asm     <file>               assemble + disassemble an ISA program
//!
//! Every data-path command runs hermetically on the pure-Rust
//! [`NativeBackend`] by default: with no `artifacts/` directory present, a
//! built-in synthetic config (tiny|isolet|ucihar) and a deterministic blob
//! dataset are used. `--backend pjrt` selects the AOT/PJRT path (requires
//! building with `--features pjrt` and a populated artifact directory).
//!
//! Global flags: --artifacts <dir> (default ./artifacts or $CLO_ARTIFACTS),
//! --backend native|pjrt, --threads, --tau, --min-seg, --samples, --tasks,
//! --voltage.

use clo_hdnn::cl::learners::HdLearner;
use clo_hdnn::cl::ClHarness;
use clo_hdnn::config::HdConfig;
use clo_hdnn::coordinator::{BackendSpec, Coordinator, CoordinatorOptions, Payload};
use clo_hdnn::data::{synthetic, Dataset, TaskStream};
use clo_hdnn::hdc::quantize::quantize_features;
use clo_hdnn::hdc::{HdClassifier, ProgressiveSearch, SearchMode, Trainer};
#[cfg(feature = "pjrt")]
use clo_hdnn::runtime::{Engine, PjrtBackend};
use clo_hdnn::runtime::{Manifest, NativeBackend};
use clo_hdnn::sim::{Chip, Mode};
use clo_hdnn::util::stats::fmt_secs;
use clo_hdnn::util::{Args, Rng};
use clo_hdnn::Result;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "info" => cmd_info(&args),
        "infer" => cmd_infer(&args),
        "cl-run" => cmd_cl_run(&args),
        "sim" => cmd_sim(&args),
        "serve" => cmd_serve(&args),
        "bench" => cmd_bench(&args),
        "asm" => cmd_asm(&args),
        _ => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "clo-hdnn <info|infer|cl-run|sim|serve|bench|asm> [flags]
  --artifacts <dir>   artifact directory (default ./artifacts)
  --backend <name>    native (default, pure Rust) or pjrt (needs --features pjrt)
  --config <name>     HD config: tiny|isolet|ucihar (built-in) or any manifest config
  --search <mode>     associative-search kernel: l1 (INT8, default) or packed
                      (bit-packed INT1 Hamming via XOR+popcount)
  --threads <n>       per-call worker threads for the native backend
                      (default 0 = auto: CLO_HDNN_THREADS if set, else all cores)
  --encode <kernel>   encode kernel on infer|cl-run|bench: signgemm (fast
                      default) or scalar (branchy reference; both bit-exact)
  --tau <f>           progressive-search confidence (default 0.5)
  --min-seg <n>       minimum segments before early exit (default 1)
  --samples <n>       evaluation sample cap
  --tasks <n>         CL tasks (default 5)
  --voltage <v>       DVFS point for sim (default 0.9)

bench flags: --config tiny|isolet|ucihar|all, --quick (small sweep),
  --out <file> (default BENCH_classifier.json), --iters/--warmup,
  --taus a,b,c (progressive sweep points),
  --encoder-out <file> (default BENCH_encoder.json: scalar vs sign-GEMM vs
  sign-GEMM+pool encode throughput over growing row counts)

With no artifacts present, commands fall back to built-in synthetic configs
and deterministic blob datasets — no Python toolchain required.";

#[cfg(feature = "pjrt")]
const BACKENDS: &str = "native|pjrt";
#[cfg(not(feature = "pjrt"))]
const BACKENDS: &str = "native; rebuild with --features pjrt to enable pjrt";

fn artifacts_dir(args: &Args) -> std::path::PathBuf {
    args.get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Manifest::default_dir)
}

fn search_mode(args: &Args) -> Result<SearchMode> {
    SearchMode::parse(&args.str_or("search", "l1"))
}

fn policy(args: &Args) -> Result<ProgressiveSearch> {
    Ok(ProgressiveSearch {
        tau: args.f64_or("tau", 0.5) as f32,
        min_segments: args.usize_or("min-seg", 1),
        mode: search_mode(args)?,
    })
}

fn load_datasets(m: &Manifest, cfg: &str) -> Result<(Dataset, Dataset)> {
    Ok((
        Dataset::load(m.dataset_path(&format!("ds_{cfg}_train"))?)?,
        Dataset::load(m.dataset_path(&format!("ds_{cfg}_test"))?)?,
    ))
}

/// Config + (train, test) datasets from the artifact directory when present,
/// otherwise from the built-in synthetic workloads.
fn load_workload(
    args: &Args,
    cfg_name: &str,
) -> Result<(HdConfig, Dataset, Dataset, Option<Manifest>)> {
    let dir = artifacts_dir(args);
    if dir.join("manifest.json").exists() {
        let m = Manifest::load(&dir)?;
        let cfg = m.config(cfg_name)?.clone();
        let (train, test) = load_datasets(&m, cfg_name)?;
        Ok((cfg, train, test, Some(m)))
    } else {
        let cfg = synthetic::config(cfg_name)?;
        let per_class = args.usize_or("per-class", 40);
        let (train, test) = synthetic::blobs(&cfg, per_class, 10, 17);
        Ok((cfg, train, test, None))
    }
}

/// The `--threads` budget for in-call backend parallelism. `0` (the
/// default) means auto: `CLO_HDNN_THREADS` when set, else all cores.
fn threads_arg(args: &Args) -> usize {
    args.usize_or("threads", 0)
}

/// The `--encode` kernel selection (default: the sign-GEMM fast path).
fn encode_kernel_arg(args: &Args) -> Result<clo_hdnn::hdc::EncodeKernel> {
    clo_hdnn::hdc::EncodeKernel::parse(&args.str_or("encode", "signgemm"))
}

/// Build the NativeBackend: production factors when the artifact directory
/// carries them, otherwise seeded factors recalibrated on training samples.
/// `--threads` sizes the backend's per-call worker pool, `--encode` picks
/// the (bit-exact) encode kernel.
fn native_backend(
    cfg: &HdConfig,
    manifest: Option<&Manifest>,
    train: &Dataset,
    args: &Args,
) -> Result<NativeBackend> {
    let threads = threads_arg(args);
    let kernel = encode_kernel_arg(args)?;
    if let Some(m) = manifest {
        if m.dir.join(format!("hd_factors_{}.bin", cfg.name)).exists() {
            let mut backend = NativeBackend::from_manifest(m, &cfg.name, 8)?;
            backend.set_threads(threads);
            backend.set_encode_kernel(kernel);
            return Ok(backend);
        }
    }
    let mut backend = NativeBackend::seeded(cfg.clone(), 7, 8)?;
    backend.set_threads(threads);
    backend.set_encode_kernel(kernel);
    // Seeded factors come with the config's default scale_q; recalibrate on
    // a few (feature-quantized) training samples so QHVs span INT8 without
    // saturating.
    let n = train.n.min(16);
    if n > 0 && train.dim == cfg.features() {
        let mut xs = Vec::with_capacity(n * cfg.features());
        for i in 0..n {
            xs.extend(quantize_features(train.sample(i), cfg.scale_x));
        }
        backend.calibrate(&xs, n);
    }
    Ok(backend)
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    if !dir.join("manifest.json").exists() {
        println!(
            "no artifacts at {} — built-in synthetic configs (NativeBackend):",
            dir.display()
        );
        for name in synthetic::names() {
            let c = synthetic::config(name)?;
            println!(
                "  {name:10} F={:<5} D={:<5} classes={:<4} segments={} (bypass mode)",
                c.features(),
                c.dim(),
                c.classes,
                c.segments
            );
        }
        return Ok(());
    }
    let m = Manifest::load(dir)?;
    m.check_files()?;
    println!("artifact dir: {}", m.dir.display());
    println!("configs:");
    for (name, c) in &m.configs {
        println!(
            "  {name:10} F={:<5} D={:<5} classes={:<4} segments={} qbits={} {}",
            c.features(),
            c.dim(),
            c.classes,
            c.segments,
            c.qbits,
            if c.image { "(normal mode)" } else { "(bypass mode)" }
        );
    }
    println!("executables: {}", m.executables.len());
    for e in m.executables.values() {
        println!("  {:34} {:14} batch={}", e.name, e.kind, e.batch);
    }
    println!("datasets: {}", m.datasets.len());
    if let Some(w) = &m.wcfe {
        println!(
            "wcfe: channels={:?} fc_out={} clusters={} pretrain_acc={:.3} clustered_acc={:.3}",
            w.channels, w.fc_out, w.clusters, w.pretrain_acc, w.clustered_acc
        );
    }
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<()> {
    match args.str_or("backend", "native").as_str() {
        "native" => cmd_infer_native(args),
        #[cfg(feature = "pjrt")]
        "pjrt" => cmd_infer_pjrt(args),
        other => anyhow::bail!("unknown --backend '{other}' ({BACKENDS})"),
    }
}

fn report_eval(report: &clo_hdnn::hdc::classifier::EvalReport, dt: f64) {
    println!(
        "accuracy {:.4} over {} samples | mean segments {:.2}/{} (complexity -{:.1}%) | early-exit {:.1}% | {:.1} inf/s",
        report.accuracy,
        report.n,
        report.mean_segments,
        report.total_segments,
        report.complexity_reduction() * 100.0,
        report.early_exit_rate * 100.0,
        report.n as f64 / dt
    );
}

fn cmd_infer_native(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "tiny");
    let (cfg, train, test, manifest) = load_workload(args, &cfg_name)?;
    let pol = policy(args)?;
    println!(
        "backend: native (pure Rust, {}) | search {:?}",
        if manifest.is_some() { "artifact data" } else { "synthetic data" },
        pol.mode
    );
    let backend = native_backend(&cfg, manifest.as_ref(), &train, args)?;
    let mut cl = HdClassifier::new(Box::new(backend), pol);
    let cap = args.usize_or("samples", 400);

    let t0 = std::time::Instant::now();
    let trainer = Trainer { retrain_epochs: args.usize_or("retrain", 1) };
    let idx: Vec<usize> = (0..train.n.min(cap * 4)).collect();
    trainer.train_indices(&mut cl, &train, &idx)?;
    println!("trained on {} samples in {}", idx.len(), fmt_secs(t0.elapsed().as_secs_f64()));

    let t1 = std::time::Instant::now();
    let n = test.n.min(cap);
    let report = cl.evaluate((0..n).map(|i| (test.sample(i).to_vec(), test.label(i))))?;
    report_eval(&report, t1.elapsed().as_secs_f64());
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_infer_pjrt(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "tiny");
    let dir = artifacts_dir(args);
    let mut engine = Engine::load(&dir)?;
    println!("PJRT platform: {}", engine.platform());
    let backend = PjrtBackend::new(&mut engine, &cfg_name, 1)?;
    let mut cl = HdClassifier::new(Box::new(backend), policy(args)?);
    let m = &engine.manifest;
    let (train, test) = load_datasets(m, &cfg_name)?;
    let cap = args.usize_or("samples", 400);

    let t0 = std::time::Instant::now();
    let trainer = Trainer { retrain_epochs: args.usize_or("retrain", 1) };
    let idx: Vec<usize> = (0..train.n.min(cap * 4)).collect();
    trainer.train_indices(&mut cl, &train, &idx)?;
    println!("trained on {} samples in {}", idx.len(), fmt_secs(t0.elapsed().as_secs_f64()));

    let t1 = std::time::Instant::now();
    let n = test.n.min(cap);
    let report = cl.evaluate((0..n).map(|i| (test.sample(i).to_vec(), test.label(i))))?;
    report_eval(&report, t1.elapsed().as_secs_f64());
    Ok(())
}

fn report_cl_run(run: &clo_hdnn::cl::ClRun) {
    println!("learner: {}", run.learner);
    println!(
        "accuracy curve: {:?}",
        run.matrix
            .curve()
            .iter()
            .map(|a| (a * 1000.0).round() / 1000.0)
            .collect::<Vec<_>>()
    );
    println!(
        "final avg accuracy {:.4} | mean forgetting {:.4} | mean segments {:?}",
        run.final_accuracy, run.mean_forgetting, run.mean_segments
    );
}

fn cmd_cl_run(args: &Args) -> Result<()> {
    match args.str_or("backend", "native").as_str() {
        "native" => cmd_cl_run_native(args),
        #[cfg(feature = "pjrt")]
        "pjrt" => cmd_cl_run_pjrt(args),
        other => anyhow::bail!("unknown --backend '{other}' ({BACKENDS})"),
    }
}

fn cmd_cl_run_native(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "tiny");
    let (cfg, train, test, manifest) = load_workload(args, &cfg_name)?;
    let n_tasks = args.usize_or("tasks", 5).min(cfg.classes);
    let stream = TaskStream::class_incremental(&train, n_tasks, 1);
    let mut harness = ClHarness::new(&train, &test, &stream);
    harness.eval_cap = args.usize_or("samples", 200);

    let backend = native_backend(&cfg, manifest.as_ref(), &train, args)?;
    let mut hd = HdLearner::new(
        HdClassifier::new(Box::new(backend), policy(args)?),
        Trainer { retrain_epochs: args.usize_or("retrain", 1) },
    );
    let run = harness.run(&mut hd)?;
    report_cl_run(&run);
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_cl_run_pjrt(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "tiny");
    let dir = artifacts_dir(args);
    let mut engine = Engine::load(&dir)?;
    let cfg = engine.manifest.config(&cfg_name)?.clone();
    let (train, test) = load_datasets(&engine.manifest, &cfg_name)?;
    let n_tasks = args.usize_or("tasks", 5).min(cfg.classes);
    let stream = TaskStream::class_incremental(&train, n_tasks, 1);
    let mut harness = ClHarness::new(&train, &test, &stream);
    harness.eval_cap = args.usize_or("samples", 200);

    let backend = PjrtBackend::new(&mut engine, &cfg_name, 1)?;
    let mut hd = HdLearner::new(
        HdClassifier::new(Box::new(backend), policy(args)?),
        Trainer { retrain_epochs: args.usize_or("retrain", 1) },
    );
    let run = harness.run(&mut hd)?;
    report_cl_run(&run);
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let has_artifacts = dir.join("manifest.json").exists();
    let cfg_name = args.str_or("config", if has_artifacts { "cifar100" } else { "tiny" });
    let v = args.f64_or("voltage", 0.9);
    let (cfg, manifest) = if has_artifacts {
        let m = Manifest::load(&dir)?;
        (m.config(&cfg_name)?.clone(), Some(m))
    } else {
        (synthetic::config(&cfg_name)?, None)
    };
    let chip = Chip::default();
    let report = if cfg.image {
        let m = manifest
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("image config {cfg_name} needs AOT artifacts"))?;
        let wm = m.wcfe.as_ref().ok_or_else(|| anyhow::anyhow!("no wcfe in manifest"))?;
        let tf = clo_hdnn::data::TensorFile::load(m.dir.join(&wm.weights))?;
        let model = clo_hdnn::wcfe::WcfeModel::load(
            &tf, &wm.channels, wm.fc_out, wm.image_hw, wm.image_c)?;
        let cb_tf = clo_hdnn::data::TensorFile::load(m.dir.join(&wm.codebook))?;
        let cb = clo_hdnn::wcfe::Codebook::load(
            &cb_tf,
            &["conv1", "conv2", "conv3"],
            (wm.channels.last().unwrap() * wm.fc_out) as u64,
        )?;
        chip.simulate_inference(&cfg, Mode::Normal, cfg.segments, Some((&model, &cb)), v)
    } else {
        chip.simulate_inference(&cfg, Mode::Bypass, cfg.segments, None, v)
    };
    println!(
        "config {cfg_name} @ {:.2} V / {:.0} MHz:",
        report.op.voltage, report.op.freq_mhz
    );
    for mc in &report.trace.modules {
        println!(
            "  {:10} {:>10} cycles {:>12} ops {:>9.3} uJ",
            mc.name,
            mc.cycles,
            mc.ops,
            mc.energy_j * 1e6
        );
    }
    println!(
        "latency {} | energy {:.3} uJ | WCFE share: {:.1}% latency, {:.1}% energy",
        fmt_secs(report.latency_s),
        report.energy_j * 1e6,
        report.wcfe_latency_share * 100.0,
        report.wcfe_energy_share * 100.0
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "tiny");
    let dir = artifacts_dir(args);
    let (cfg, train, test, manifest) = load_workload(args, &cfg_name)?;
    // Artifact factors only when they actually exist — otherwise fall back
    // to seeded factors, matching native_backend()'s behavior for infer.
    let has_factors =
        manifest.is_some() && dir.join(format!("hd_factors_{cfg_name}.bin")).exists();
    let backend = match args.str_or("backend", "native").as_str() {
        "native" if has_factors => {
            BackendSpec::NativeArtifacts { artifacts: dir, config: cfg_name.clone() }
        }
        "native" => BackendSpec::Native { cfg: cfg.clone(), seed: 7 },
        #[cfg(feature = "pjrt")]
        "pjrt" => BackendSpec::Pjrt { artifacts: dir, config: cfg_name.clone() },
        other => anyhow::bail!("unknown --backend '{other}' ({BACKENDS})"),
    };
    let mode = search_mode(args)?;
    println!("serving config {cfg_name} on {backend:?} | search {mode:?}");
    let opts = CoordinatorOptions {
        backend,
        tau: args.f64_or("tau", 0.5) as f32,
        min_segments: args.usize_or("min-seg", 1),
        search_mode: mode,
        mode_policy: Default::default(),
        queue_depth: 256,
        threads: threads_arg(args),
    };
    let coord = Coordinator::start(opts)?;
    // online learning phase
    let learn_n = args.usize_or("learn", 400).min(train.n);
    for i in 0..learn_n {
        coord.call(Payload::Learn(train.sample(i).to_vec(), train.label(i)))?;
    }
    // serving phase with Poisson arrivals
    let n = args.usize_or("samples", 200).min(test.n);
    let rate = args.f64_or("rate", 200.0);
    let mut rng = Rng::new(9);
    let mut metrics = clo_hdnn::coordinator::ServeMetrics::default();
    let mut correct = 0usize;
    let t0 = std::time::Instant::now();
    for i in 0..n {
        std::thread::sleep(std::time::Duration::from_secs_f64(rng.exponential(rate)));
        let r = coord.call(Payload::Features(test.sample(i).to_vec()))?;
        if r.error.is_some() {
            metrics.record_error();
            continue;
        }
        metrics.record(r.latency_s, r.segments_used, r.early_exit, r.used_wcfe);
        correct += usize::from(r.class == Some(test.label(i)));
    }
    metrics.wall_s = t0.elapsed().as_secs_f64();
    println!(
        "served {} requests | acc {:.4} | p50 {} p95 {} | {:.1} req/s | segments {:.2}/{} (-{:.1}% complexity)",
        metrics.total,
        correct as f64 / n as f64,
        fmt_secs(metrics.latency_percentile(50.0)),
        fmt_secs(metrics.latency_percentile(95.0)),
        metrics.throughput_rps(),
        metrics.mean_segments(),
        cfg.segments,
        metrics.complexity_reduction(cfg.segments) * 100.0
    );
    Ok(())
}

/// `clo_hdnn bench`: the packed-vs-scalar classifier perf harness. Runs
/// encode / full-search / progressive sweeps on the synthetic configs
/// through the NativeBackend, prints the stage tables, and writes a
/// machine-readable `BENCH_classifier.json` (samples/s, ns/query, packed
/// speedup, complexity saving per tau) so the repo carries a perf
/// trajectory. `--quick` shrinks the sweep for CI smoke runs.
fn cmd_bench(args: &Args) -> Result<()> {
    use clo_hdnn::util::json::Json;
    use std::collections::BTreeMap;

    let quick = args.flag("quick");
    let cfg_arg = args.str_or("config", "isolet");
    let names: Vec<String> = if cfg_arg == "all" {
        synthetic::names().iter().map(|s| s.to_string()).collect()
    } else {
        vec![cfg_arg]
    };
    let out_path = args.str_or("out", "BENCH_classifier.json");
    let (warmup, iters) = if quick { (1, 5) } else { (3, 25) };
    let bench = clo_hdnn::util::stats::Bench::new(
        args.usize_or("warmup", warmup),
        args.usize_or("iters", iters),
    );
    let taus: Vec<f32> = args
        .str_or("taus", if quick { "0.5" } else { "0.1,0.5,1.0,2.0" })
        .split(',')
        .map(|t| t.trim().parse::<f32>().map_err(|_| anyhow::anyhow!("bad tau '{t}'")))
        .collect::<Result<_>>()?;

    let mut reports: BTreeMap<String, Json> = BTreeMap::new();
    for name in &names {
        reports.insert(name.clone(), bench_config(name, &bench, &taus, quick, args)?);
    }
    let doc = Json::obj(vec![
        ("version", Json::Num(1.0)),
        ("quick", Json::Bool(quick)),
        ("warmup", Json::Num(bench.warmup as f64)),
        ("iters", Json::Num(bench.iters as f64)),
        ("configs", Json::Obj(reports)),
    ]);
    std::fs::write(&out_path, doc.dump())?;
    println!("\nwrote {out_path}");

    // the encoder engine harness: scalar vs sign-GEMM vs sign-GEMM+pool
    // over growing row counts -> BENCH_encoder.json
    let enc_out = args.str_or("encoder-out", "BENCH_encoder.json");
    let mut enc_reports: BTreeMap<String, Json> = BTreeMap::new();
    for name in &names {
        enc_reports.insert(name.clone(), bench_encoder(name, &bench, quick, args)?);
    }
    let enc_doc = Json::obj(vec![
        ("version", Json::Num(1.0)),
        ("quick", Json::Bool(quick)),
        ("warmup", Json::Num(bench.warmup as f64)),
        ("iters", Json::Num(bench.iters as f64)),
        ("configs", Json::Obj(enc_reports)),
    ]);
    std::fs::write(&enc_out, enc_doc.dump())?;
    println!("wrote {enc_out}");
    Ok(())
}

/// One config's encoder-engine rows: per row count, median ns/encode for
/// the scalar kernel, the sign-GEMM kernel, and the pooled batch engine
/// (whose number includes the packed-segment emission).
fn bench_encoder(
    name: &str,
    bench: &clo_hdnn::util::stats::Bench,
    quick: bool,
    args: &Args,
) -> Result<clo_hdnn::util::json::Json> {
    use clo_hdnn::hdc::{EncodeKernel, HdBackend, SoftwareEncoder};
    use clo_hdnn::util::json::Json;
    use clo_hdnn::util::pool::WorkerPool;
    use clo_hdnn::util::stats::Table;
    use std::hint::black_box;

    let cfg = synthetic::config(name)?;
    let feat = cfg.features();
    let (train, _test) = synthetic::blobs(&cfg, 8, 2, 17);
    let mut enc = SoftwareEncoder::random(cfg.clone(), 7);
    let calib_n = train.n.min(8);
    let mut calib = Vec::with_capacity(calib_n * feat);
    for i in 0..calib_n {
        calib.extend(quantize_features(train.sample(i), cfg.scale_x));
    }
    enc.calibrate(&calib, calib_n);

    let pool = WorkerPool::new(threads_arg(args));
    let row_counts: &[usize] = if quick { &[1, 8] } else { &[1, 8, 32] };
    let max_rows = *row_counts.last().unwrap();
    let mut input = Vec::with_capacity(max_rows * feat);
    let mut i = 0usize;
    while input.len() < max_rows * feat {
        input.extend(quantize_features(train.sample(i % train.n), cfg.scale_x));
        i += 1;
    }

    println!(
        "\n== bench-encoder {name}: F={feat} D={} ({} worker threads) ==",
        cfg.dim(),
        pool.threads()
    );
    let mut table = Table::new(&[
        "rows",
        "scalar ns/enc",
        "sign-GEMM ns/enc",
        "pool ns/enc",
        "sign-GEMM speedup",
        "pool speedup",
    ]);
    let mut rows_json = Vec::new();
    let mut speedup_b1 = 0.0f64;
    for &rows in row_counts {
        let xs = &input[..rows * feat];
        enc.set_kernel(EncodeKernel::Scalar);
        let s_scalar = bench.run(|| black_box(enc.encode_full(black_box(xs), rows).unwrap()));
        enc.set_kernel(EncodeKernel::SignGemm);
        let s_gemm = bench.run(|| black_box(enc.encode_full(black_box(xs), rows).unwrap()));
        let s_pool =
            bench.run(|| black_box(enc.encode_batch(black_box(xs), rows, Some(&pool)).unwrap()));
        let per = |median: f64| median * 1e9 / rows as f64;
        let gemm_speedup = per(s_scalar.median) / per(s_gemm.median);
        let pool_speedup = per(s_scalar.median) / per(s_pool.median);
        if rows == 1 {
            speedup_b1 = gemm_speedup;
        }
        table.row(&[
            format!("{rows}"),
            format!("{:.0}", per(s_scalar.median)),
            format!("{:.0}", per(s_gemm.median)),
            format!("{:.0}", per(s_pool.median)),
            format!("{gemm_speedup:.2}x"),
            format!("{pool_speedup:.2}x"),
        ]);
        rows_json.push(Json::obj(vec![
            ("rows", Json::Num(rows as f64)),
            ("scalar_ns_per_encode", Json::Num(per(s_scalar.median))),
            ("signgemm_ns_per_encode", Json::Num(per(s_gemm.median))),
            ("signgemm_pool_ns_per_encode", Json::Num(per(s_pool.median))),
            ("scalar_samples_per_s", Json::Num(rows as f64 / s_scalar.median)),
            ("signgemm_samples_per_s", Json::Num(rows as f64 / s_gemm.median)),
            (
                "signgemm_pool_samples_per_s",
                Json::Num(rows as f64 / s_pool.median),
            ),
            ("signgemm_speedup", Json::Num(gemm_speedup)),
            ("signgemm_pool_speedup", Json::Num(pool_speedup)),
        ]));
    }
    table.print();
    println!("single-row sign-GEMM speedup: {speedup_b1:.2}x");

    Ok(Json::obj(vec![
        ("features", Json::Num(feat as f64)),
        ("dim", Json::Num(cfg.dim() as f64)),
        ("segments", Json::Num(cfg.segments as f64)),
        ("pool_threads", Json::Num(pool.threads() as f64)),
        ("signgemm_speedup_b1", Json::Num(speedup_b1)),
        ("rows", Json::Arr(rows_json)),
    ]))
}

/// One config's worth of bench rows (and the human-readable tables).
fn bench_config(
    name: &str,
    bench: &clo_hdnn::util::stats::Bench,
    taus: &[f32],
    quick: bool,
    args: &Args,
) -> Result<clo_hdnn::util::json::Json> {
    use clo_hdnn::hdc::{distance, packed};
    use clo_hdnn::util::json::Json;
    use clo_hdnn::util::stats::Table;
    use std::hint::black_box;

    let cfg = synthetic::config(name)?;
    let per_class = args.usize_or("per-class", if quick { 6 } else { 20 });
    let (train, test) = synthetic::blobs(&cfg, per_class, 4, 17);
    let backend = native_backend(&cfg, None, &train, args)?;
    let mut cl = HdClassifier::new(Box::new(backend), ProgressiveSearch::default());
    Trainer { retrain_epochs: 0 }.train_all(&mut cl, &train)?;

    let n_q = args.usize_or("queries", if quick { 8 } else { 32 }).min(test.n).max(1);
    let queries: Vec<Vec<f32>> = (0..n_q).map(|i| test.sample(i).to_vec()).collect();
    let (d, classes) = (cfg.dim(), cfg.classes);

    // pre-encoded operands for the kernel-level full-D search comparison
    let mut qhvs: Vec<Vec<f32>> = Vec::with_capacity(n_q);
    for q in &queries {
        qhvs.push(cl.encode(q)?);
    }
    let qhvs_packed: Vec<Vec<u64>> = qhvs.iter().map(|q| packed::pack_signs(q)).collect();
    let mut chvs_full = Vec::with_capacity(classes * d);
    for c in 0..classes {
        chvs_full.extend(cl.store.class_hv(c));
    }
    let chvs_packed = packed::pack_rows(&chvs_full, classes, d)?;

    println!(
        "\n== bench {name}: F={} D={} classes={} segments={} ({} queries) ==",
        cfg.features(),
        d,
        classes,
        cfg.segments,
        n_q
    );
    let ns_per_q = |median: f64| median * 1e9 / n_q as f64;

    let s_enc = bench.run(|| cl.encode(black_box(&queries[0])).unwrap());
    let encode = Json::obj(vec![
        ("ns_per_query", Json::Num(s_enc.median * 1e9)),
        ("samples_per_s", Json::Num(1.0 / s_enc.median)),
    ]);

    let s_scalar = bench.run(|| {
        for q in &qhvs {
            black_box(distance::l1_batch(q, 1, &chvs_full, classes, d).unwrap());
        }
    });
    let s_packed = bench.run(|| {
        for q in &qhvs_packed {
            black_box(packed::hamming_search(q, 1, &chvs_packed, classes, d).unwrap());
        }
    });
    let speedup = ns_per_q(s_scalar.median) / ns_per_q(s_packed.median);

    let mut t = Table::new(&["stage", "ns/query", "queries/s", "notes"]);
    t.row(&[
        "encode full (native b1)".into(),
        format!("{:.0}", s_enc.median * 1e9),
        format!("{:.0}", 1.0 / s_enc.median),
        format!("{} segments", cfg.segments),
    ]);
    t.row(&[
        "search full-D (scalar L1)".into(),
        format!("{:.0}", ns_per_q(s_scalar.median)),
        format!("{:.0}", n_q as f64 / s_scalar.median),
        format!("{classes} CHVs x {d} f32"),
    ]);
    t.row(&[
        "search full-D (packed INT1)".into(),
        format!("{:.0}", ns_per_q(s_packed.median)),
        format!("{:.0}", n_q as f64 / s_packed.median),
        format!("XOR+popcount, {} words, {speedup:.1}x", packed::words_for(d)),
    ]);
    t.print();

    let search = Json::obj(vec![
        (
            "scalar",
            Json::obj(vec![
                ("ns_per_query", Json::Num(ns_per_q(s_scalar.median))),
                ("queries_per_s", Json::Num(n_q as f64 / s_scalar.median)),
            ]),
        ),
        (
            "packed",
            Json::obj(vec![
                ("ns_per_query", Json::Num(ns_per_q(s_packed.median))),
                ("queries_per_s", Json::Num(n_q as f64 / s_packed.median)),
            ]),
        ),
        ("speedup", Json::Num(speedup)),
    ]);

    // progressive sweep: end-to-end classify per tau, both kernels
    let mut t2 = Table::new(&["tau", "mode", "ns/query", "segs", "saving", "acc"]);
    let mut prog_rows = Vec::new();
    for &tau in taus {
        for mode in [SearchMode::L1Int8, SearchMode::HammingPacked] {
            cl.policy = ProgressiveSearch { tau, min_segments: 1, mode };
            let s = bench.run(|| {
                for q in &queries {
                    black_box(cl.classify(black_box(q)).unwrap());
                }
            });
            let report = cl.evaluate(
                queries.iter().enumerate().map(|(i, q)| (q.clone(), test.label(i))),
            )?;
            let mode_name = match mode {
                SearchMode::L1Int8 => "l1int8",
                SearchMode::HammingPacked => "hamming_packed",
            };
            t2.row(&[
                format!("{tau}"),
                mode_name.into(),
                format!("{:.0}", ns_per_q(s.median)),
                format!("{:.2}/{}", report.mean_segments, cfg.segments),
                format!("{:.1}%", report.complexity_reduction() * 100.0),
                format!("{:.3}", report.accuracy),
            ]);
            prog_rows.push(Json::obj(vec![
                ("tau", Json::Num(tau as f64)),
                ("mode", Json::Str(mode_name.into())),
                ("ns_per_query", Json::Num(ns_per_q(s.median))),
                ("samples_per_s", Json::Num(n_q as f64 / s.median)),
                ("mean_segments", Json::Num(report.mean_segments)),
                ("complexity_saving", Json::Num(report.complexity_reduction())),
                ("early_exit_rate", Json::Num(report.early_exit_rate)),
                ("accuracy", Json::Num(report.accuracy)),
            ]));
        }
    }
    t2.print();

    Ok(Json::obj(vec![
        ("features", Json::Num(cfg.features() as f64)),
        ("dim", Json::Num(d as f64)),
        ("classes", Json::Num(classes as f64)),
        ("segments", Json::Num(cfg.segments as f64)),
        ("queries", Json::Num(n_q as f64)),
        ("encode", encode),
        ("search", search),
        ("progressive", Json::Arr(prog_rows)),
    ]))
}

fn cmd_asm(args: &Args) -> Result<()> {
    let path = args
        .positional()
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("asm needs a file path"))?;
    let src = std::fs::read_to_string(path)?;
    let prog = clo_hdnn::isa::assemble(&src)?;
    println!("{} instructions, bytecode words:", prog.len());
    for (i, w) in prog.bytecode().iter().enumerate() {
        println!("  [{i:3}] {w:#07x}");
    }
    println!("\ndisassembly:\n{}", prog.disassemble());
    Ok(())
}
