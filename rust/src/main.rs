//! Clo-HDnn CLI — the leader entrypoint.
//!
//! Subcommands:
//!   info                         inspect the artifact manifest
//!   infer   --config <name>      progressive inference over the test set
//!   cl-run  --config <name>      continual-learning experiment (Fig.9 row)
//!   sim     --config <name>      chip latency/energy report (Fig.10)
//!   serve   --config <name>      Poisson-traffic serving demo
//!   asm     <file>               assemble + disassemble an ISA program
//!
//! Global flags: --artifacts <dir> (default ./artifacts or $CLO_ARTIFACTS),
//! --tau, --min-seg, --samples, --tasks, --voltage.

use clo_hdnn::cl::learners::HdLearner;
use clo_hdnn::cl::ClHarness;
use clo_hdnn::config::HdConfig;
use clo_hdnn::coordinator::{BackendSpec, Coordinator, CoordinatorOptions, Payload};
use clo_hdnn::data::{Dataset, TaskStream};
use clo_hdnn::hdc::{HdClassifier, ProgressiveSearch, Trainer};
use clo_hdnn::runtime::{Engine, Manifest, PjrtBackend};
use clo_hdnn::sim::{Chip, Mode};
use clo_hdnn::util::stats::fmt_secs;
use clo_hdnn::util::{Args, Rng};
use clo_hdnn::Result;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "info" => cmd_info(&args),
        "infer" => cmd_infer(&args),
        "cl-run" => cmd_cl_run(&args),
        "sim" => cmd_sim(&args),
        "serve" => cmd_serve(&args),
        "asm" => cmd_asm(&args),
        _ => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "clo-hdnn <info|infer|cl-run|sim|serve|asm> [flags]
  --artifacts <dir>   artifact directory (default ./artifacts)
  --config <name>     HD config: tiny|isolet|ucihar|cifar100
  --tau <f>           progressive-search confidence (default 0.5)
  --min-seg <n>       minimum segments before early exit (default 1)
  --samples <n>       evaluation sample cap
  --tasks <n>         CL tasks (default 5)
  --voltage <v>       DVFS point for sim (default 0.9)";

fn artifacts_dir(args: &Args) -> std::path::PathBuf {
    args.get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Manifest::default_dir)
}

fn load_datasets(m: &Manifest, cfg: &str) -> Result<(Dataset, Dataset)> {
    Ok((
        Dataset::load(m.dataset_path(&format!("ds_{cfg}_train"))?)?,
        Dataset::load(m.dataset_path(&format!("ds_{cfg}_test"))?)?,
    ))
}

fn cmd_info(args: &Args) -> Result<()> {
    let m = Manifest::load(artifacts_dir(args))?;
    m.check_files()?;
    println!("artifact dir: {}", m.dir.display());
    println!("configs:");
    for (name, c) in &m.configs {
        println!(
            "  {name:10} F={:<5} D={:<5} classes={:<4} segments={} qbits={} {}",
            c.features(),
            c.dim(),
            c.classes,
            c.segments,
            c.qbits,
            if c.image { "(normal mode)" } else { "(bypass mode)" }
        );
    }
    println!("executables: {}", m.executables.len());
    for e in m.executables.values() {
        println!("  {:34} {:14} batch={}", e.name, e.kind, e.batch);
    }
    println!("datasets: {}", m.datasets.len());
    if let Some(w) = &m.wcfe {
        println!(
            "wcfe: channels={:?} fc_out={} clusters={} pretrain_acc={:.3} clustered_acc={:.3}",
            w.channels, w.fc_out, w.clusters, w.pretrain_acc, w.clustered_acc
        );
    }
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "tiny");
    let tau = args.f64_or("tau", 0.5) as f32;
    let dir = artifacts_dir(args);
    let mut engine = Engine::load(&dir)?;
    println!("PJRT platform: {}", engine.platform());
    let backend = PjrtBackend::new(&mut engine, &cfg_name, 1)?;
    let mut cl = HdClassifier::new(
        Box::new(backend),
        ProgressiveSearch { tau, min_segments: args.usize_or("min-seg", 1) },
    );
    let m = &engine.manifest;
    let (train, test) = load_datasets(m, &cfg_name)?;
    let cap = args.usize_or("samples", 400);

    let t0 = std::time::Instant::now();
    let trainer = Trainer { retrain_epochs: args.usize_or("retrain", 1) };
    let idx: Vec<usize> = (0..train.n.min(cap * 4)).collect();
    trainer.train_indices(&mut cl, &train, &idx)?;
    println!("trained on {} samples in {}", idx.len(), fmt_secs(t0.elapsed().as_secs_f64()));

    let t1 = std::time::Instant::now();
    let n = test.n.min(cap);
    let report = cl.evaluate((0..n).map(|i| (test.sample(i).to_vec(), test.label(i))))?;
    let dt = t1.elapsed().as_secs_f64();
    println!(
        "accuracy {:.4} over {} samples | mean segments {:.2}/{} (complexity -{:.1}%) | early-exit {:.1}% | {:.1} inf/s",
        report.accuracy,
        report.n,
        report.mean_segments,
        report.total_segments,
        report.complexity_reduction() * 100.0,
        report.early_exit_rate * 100.0,
        report.n as f64 / dt
    );
    Ok(())
}

fn cmd_cl_run(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "tiny");
    let dir = artifacts_dir(args);
    let mut engine = Engine::load(&dir)?;
    let cfg = engine.manifest.config(&cfg_name)?.clone();
    let (train, test) = load_datasets(&engine.manifest, &cfg_name)?;
    let n_tasks = args.usize_or("tasks", 5).min(cfg.classes);
    let stream = TaskStream::class_incremental(&train, n_tasks, 1);
    let mut harness = ClHarness::new(&train, &test, &stream);
    harness.eval_cap = args.usize_or("samples", 200);

    let backend = PjrtBackend::new(&mut engine, &cfg_name, 1)?;
    let mut hd = HdLearner::new(
        HdClassifier::new(
            Box::new(backend),
            ProgressiveSearch {
                tau: args.f64_or("tau", 0.5) as f32,
                min_segments: args.usize_or("min-seg", 1),
            },
        ),
        Trainer { retrain_epochs: args.usize_or("retrain", 1) },
    );
    let run = harness.run(&mut hd)?;
    println!("learner: {}", run.learner);
    println!("accuracy curve: {:?}", run
        .matrix
        .curve()
        .iter()
        .map(|a| (a * 1000.0).round() / 1000.0)
        .collect::<Vec<_>>());
    println!(
        "final avg accuracy {:.4} | mean forgetting {:.4} | mean segments {:?}",
        run.final_accuracy, run.mean_forgetting, run.mean_segments
    );
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "cifar100");
    let v = args.f64_or("voltage", 0.9);
    let m = Manifest::load(artifacts_dir(args))?;
    let cfg = m.config(&cfg_name)?.clone();
    let chip = Chip::default();
    let report = if cfg.image {
        let wm = m.wcfe.as_ref().ok_or_else(|| anyhow::anyhow!("no wcfe in manifest"))?;
        let tf = clo_hdnn::data::TensorFile::load(m.dir.join(&wm.weights))?;
        let model = clo_hdnn::wcfe::WcfeModel::load(
            &tf, &wm.channels, wm.fc_out, wm.image_hw, wm.image_c)?;
        let cb_tf = clo_hdnn::data::TensorFile::load(m.dir.join(&wm.codebook))?;
        let cb = clo_hdnn::wcfe::Codebook::load(
            &cb_tf,
            &["conv1", "conv2", "conv3"],
            (wm.channels.last().unwrap() * wm.fc_out) as u64,
        )?;
        chip.simulate_inference(&cfg, Mode::Normal, cfg.segments, Some((&model, &cb)), v)
    } else {
        chip.simulate_inference(&cfg, Mode::Bypass, cfg.segments, None, v)
    };
    println!(
        "config {cfg_name} @ {:.2} V / {:.0} MHz:",
        report.op.voltage, report.op.freq_mhz
    );
    for mc in &report.trace.modules {
        println!(
            "  {:10} {:>10} cycles {:>12} ops {:>9.3} uJ",
            mc.name,
            mc.cycles,
            mc.ops,
            mc.energy_j * 1e6
        );
    }
    println!(
        "latency {} | energy {:.3} uJ | WCFE share: {:.1}% latency, {:.1}% energy",
        fmt_secs(report.latency_s),
        report.energy_j * 1e6,
        report.wcfe_latency_share * 100.0,
        report.wcfe_energy_share * 100.0
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "tiny");
    let dir = artifacts_dir(args);
    let m = Manifest::load(&dir)?;
    let cfg = m.config(&cfg_name)?.clone();
    let (train, test) = load_datasets(&m, &cfg_name)?;
    let opts = CoordinatorOptions {
        backend: BackendSpec::Pjrt { artifacts: dir, config: cfg_name.clone() },
        tau: args.f64_or("tau", 0.5) as f32,
        min_segments: args.usize_or("min-seg", 1),
        mode_policy: Default::default(),
        queue_depth: 256,
    };
    let coord = Coordinator::start(opts)?;
    // online learning phase
    let learn_n = args.usize_or("learn", 400).min(train.n);
    for i in 0..learn_n {
        coord.call(Payload::Learn(train.sample(i).to_vec(), train.label(i)))?;
    }
    // serving phase with Poisson arrivals
    let n = args.usize_or("samples", 200).min(test.n);
    let rate = args.f64_or("rate", 200.0);
    let mut rng = Rng::new(9);
    let mut metrics = clo_hdnn::coordinator::ServeMetrics::default();
    let mut correct = 0usize;
    let t0 = std::time::Instant::now();
    for i in 0..n {
        std::thread::sleep(std::time::Duration::from_secs_f64(rng.exponential(rate)));
        let r = coord.call(Payload::Features(test.sample(i).to_vec()))?;
        if r.error.is_some() {
            metrics.record_error();
            continue;
        }
        metrics.record(r.latency_s, r.segments_used, r.early_exit, r.used_wcfe);
        correct += usize::from(r.class == Some(test.label(i)));
    }
    metrics.wall_s = t0.elapsed().as_secs_f64();
    println!(
        "served {} requests | acc {:.4} | p50 {} p95 {} | {:.1} req/s | segments {:.2}/{} (-{:.1}% complexity)",
        metrics.total,
        correct as f64 / n as f64,
        fmt_secs(metrics.latency_percentile(50.0)),
        fmt_secs(metrics.latency_percentile(95.0)),
        metrics.throughput_rps(),
        metrics.mean_segments(),
        cfg.segments,
        metrics.complexity_reduction(cfg.segments) * 100.0
    );
    Ok(())
}

fn cmd_asm(args: &Args) -> Result<()> {
    let path = args
        .positional()
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("asm needs a file path"))?;
    let src = std::fs::read_to_string(path)?;
    let prog = clo_hdnn::isa::assemble(&src)?;
    println!("{} instructions, bytecode words:", prog.len());
    for (i, w) in prog.bytecode().iter().enumerate() {
        println!("  [{i:3}] {w:#07x}");
    }
    println!("\ndisassembly:\n{}", prog.disassemble());
    Ok(())
}
