//! Clo-HDnn CLI — the leader entrypoint.
//!
//! Subcommands:
//!   info                         inspect artifacts (or list built-in configs)
//!   infer   --config <name>      progressive inference over the test set
//!   cl-run  --config <name>      continual-learning experiment (Fig.9 row)
//!   sim     --config <name>      chip latency/energy report (Fig.10)
//!   serve   --config <name>      Poisson-traffic serving demo, or with
//!                                --listen <addr> a TCP server speaking the
//!                                length-prefixed wire protocol
//!   loadgen --connect <addr>     concurrent-client load generator against a
//!                                live server -> BENCH_serve.json (or
//!                                --fleet a,b,c through the health-checked
//!                                failover client)
//!   admin   --connect <addr>     runtime fleet administration: follower
//!                                promotion and model add/remove
//!   bench   --config <name>      packed-vs-scalar perf harness -> BENCH_classifier.json
//!   asm     <file>               assemble + disassemble an ISA program
//!
//! Every data-path command runs hermetically on the pure-Rust
//! [`NativeBackend`] by default: with no `artifacts/` directory present, a
//! built-in synthetic config (tiny|isolet|ucihar) and a deterministic blob
//! dataset are used. `--backend pjrt` selects the AOT/PJRT path (requires
//! building with `--features pjrt` and a populated artifact directory).
//!
//! Global flags: --artifacts <dir> (default ./artifacts or $CLO_ARTIFACTS),
//! --backend native|pjrt, --threads, --tau, --min-seg, --samples, --tasks,
//! --voltage.

use clo_hdnn::cl::learners::HdLearner;
use clo_hdnn::cl::ClHarness;
use clo_hdnn::config::HdConfig;
use clo_hdnn::coordinator::{
    BackendSpec, Coordinator, CoordinatorOptions, ModePolicy, Payload, WcfeSpec,
};
use clo_hdnn::data::{scenario, synthetic, Dataset, TaskStream};
use clo_hdnn::hdc::quantize::quantize_features;
use clo_hdnn::hdc::{HdClassifier, ProgressiveSearch, SearchMode, Trainer};
#[cfg(feature = "pjrt")]
use clo_hdnn::runtime::{Engine, PjrtBackend};
use clo_hdnn::runtime::{Manifest, NativeBackend};
use clo_hdnn::sim::{Chip, Mode};
use clo_hdnn::util::stats::fmt_secs;
use clo_hdnn::util::{Args, Rng};
use clo_hdnn::Result;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Boolean flags the CLI understands: registered so the parser never
/// swallows a following positional/value token as their "value".
const BOOL_FLAGS: &[&str] = &[
    "quick",
    "no-restore",
    "allow-remote-snapshot-paths",
    "snapshot-default",
    "remat",
];

fn run() -> Result<()> {
    let args = Args::from_env_with_bools(BOOL_FLAGS);
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "info" => cmd_info(&args),
        "infer" => cmd_infer(&args),
        "cl-run" => cmd_cl_run(&args),
        "sim" => cmd_sim(&args),
        "serve" => cmd_serve(&args),
        "loadgen" => cmd_loadgen(&args),
        "admin" => cmd_admin(&args),
        "bench" => cmd_bench(&args),
        "asm" => cmd_asm(&args),
        _ => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "clo-hdnn <info|infer|cl-run|sim|serve|loadgen|admin|bench|asm> [flags]
  --artifacts <dir>   artifact directory (default ./artifacts)
  --backend <name>    native (default, pure Rust) or pjrt (needs --features pjrt)
  --config <name>     HD config: tiny|isolet|ucihar (built-in), a dual-mode
                      scenario cell (mnist|isolet|ucihar × -easy|-hard, e.g.
                      mnist-easy), or any manifest config
  --search <mode>     associative-search kernel: l1 (INT8, default) or packed
                      (bit-packed INT1 Hamming via XOR+popcount)
  --threads <n>       per-call worker threads for the native backend
                      (default 0 = auto: CLO_HDNN_THREADS if set, else all cores)
  --encode <kernel>   encode kernel on infer|cl-run|bench: signgemm (fast
                      default) or scalar (branchy reference; both bit-exact)
  --remat             regenerate seeded factor planes from their seed on the
                      fly instead of storing them (O(1) resident factor
                      memory per model; bit-identical results; ignored when
                      artifact factors exist)
  --tau <f>           progressive-search confidence (default 0.5)
  --min-seg <n>       minimum segments before early exit (default 1)
  --samples <n>       evaluation sample cap
  --tasks <n>         CL tasks (default 5)
  --voltage <v>       DVFS point for sim (default 0.9)

dual-mode flags (serve + listen): --policy auto|bypass|normal|
  confidence:<margin> (routing policy; auto = images run the WCFE, features
  bypass; confidence = bypass first, re-run through the WCFE when the top-2
  distance margin falls below <margin> — see README \"Dual-mode operation\"),
  --wcfe off|artifacts|scenario:<name> (where the serving WCFE front-end
  comes from; serving a scenario config equips that cell's seeded front-end
  automatically)

serve flags: --listen <host:port> switches from the Poisson demo to the TCP
  wire-protocol server; --models <a,b,c> hosts several models side by side
  (one executor each; model names double as config names unless the
  manifest's models section maps them), --model <name> = single model
  (alias of --config); --snapshot <file> (default knowledge checkpoint,
  auto-restored on startup when it exists — suppress with --no-restore;
  with --models the file is per-model-ized: k.clok -> k_<model>.clok),
  --snapshot-every <n> (auto-snapshot cadence in learns; default 0 = off),
  --restore <file> (explicit warm-start checkpoint; single-model only),
  --learn <n> (pre-learn n synthetic samples into the default model;
  default 0 in listen mode), --duration <secs> (serve for a bounded time
  with a graceful shutdown flush; default 0 = forever — a killed process
  keeps at most --snapshot-every learns unsaved per model),
  --allow-remote-snapshot-paths (honor client-supplied Snapshot paths; off
  by default — the socket is unauthenticated), --idle-timeout <secs> (close
  connections that send nothing for this long; default 60),
  --max-conns <n> (simultaneous-connection cap, peers beyond it are shed
  with an error frame; default 10240), --wal <file> (durable learn log:
  every Learn is appended + fsynced before it is acknowledged, a crashed
  server replays the suffix on restart — per-model-ized like --snapshot;
  a successful snapshot folds + rotates the log), --wal-fsync-every <n>
  (fsync cadence in learns; default 1 = every learn durable before its
  ack), --replicate-from <host:port> (follower mode: each hosted model
  bootstraps from the same-named model on that primary, tails its learn
  log, and serves reads locally — when the primary dies the follower keeps
  serving its last-converged state and reconnects with backoff),
  --promote-on down:<millis> (follower failure detector: when a tailed
  primary has been continuously unreachable for this long, promote the
  local model — it bumps its epoch (generation counter), seals the
  inherited learn log, and starts accepting learns as the new primary;
  a stale old primary that returns is fenced by the epoch)

loadgen flags: --connect <host:port> (required), --clients <n> (default 4),
  --connections <n> (concurrent connections, spread across the client
  threads; default = --clients), --requests <n> per client (default 200),
  --learn-frac <f> (default 0.25), --model <name> / --models <a,b>
  (wire-v2 model targeting; mixes the request stream across models and
  reports per-model latency percentiles; model names must be synthetic
  config names), --pipeline <k> (keep k requests in flight per connection
  over wire v2; default 1), --timeout <secs> (per-reply deadline, counted
  per connection and per model; default 30, 0 = wait forever),
  --search default|l1|packed, --out <file> (default BENCH_serve.json),
  --snapshot-default (ask the server to checkpoint every driven model to
  its configured default at the end), --snapshot-out <file> (checkpoint to
  an explicit server-side path; single-model; needs
  --allow-remote-snapshot-paths on the server),
  --payload features|image|mix (request body shape: features = bypass-space
  Infer/Learn, image = raw-pixel InferImage/LearnImage through the server's
  WCFE, mix = alternate both; image|mix need scenario configs and write the
  dual-mode report), --dualmode-out <file> (default BENCH_dualmode.json),
  --per-class <n> (synthetic workload size, must match the server's),
  --replicas <a,b> (read fan-out: infers round-robin across the primary
  and these follower servers, learns stay pinned to the primary; the
  JSON's targets section attributes traffic per server — a target that
  dies mid-run is failed over: its owed replies count as its errors and
  reads re-route to the remaining live targets),
  --fleet <a,b,c> (drive the servers through the health-checked fleet
  client instead of pinned connections: learns follow the current primary
  by (epoch, learn_seq) — re-discovered automatically after a follower
  promotion — reads spread round-robin over live endpoints within
  --staleness learns of the freshest, and every request retries across
  the fleet with capped backoff; single-threaded and seeded, so the
  request schedule is deterministic), --staleness <n> (fleet
  read-staleness bound in learns; default unbounded), --retries <n>
  (fleet per-request attempt budget; default 3), --probe-interval-ms <n>
  (fleet health-probe cadence; default 100),
  --scale-connections <a,b,c> (after the main run, hold a..c concurrent
  connections open and drive --scale-requests (default 2) infer rounds on
  every one -> the JSON's connection-scaling section)

admin flags: --connect <host:port> (required) plus one action: promote
  (promote the --model (default model when omitted) to a new epoch —
  follower takeover; the model seals its inherited learn log and accepts
  learns as the new primary generation), model-add <name> (boot a new
  model at runtime, cloning the executor configuration of --from <model>
  (default model when omitted); knowledge starts empty and per-model
  snapshot/WAL paths are derived), model-remove <name> (tear a model down
  at runtime; its knowledge flushes to disk before the acknowledgement;
  the default model is refused)

info flags: --knowledge <file> verifies + summarizes a knowledge
  checkpoint; --model <name> shows one serving model's registry entry;
  --connect <host:port> polls a live server and prints one stats line per
  model (learns, classes, snapshots, the replication learn_seq)

bench flags: --config tiny|isolet|ucihar|all, --quick (small sweep),
  --out <file> (default BENCH_classifier.json), --iters/--warmup,
  --taus a,b,c (progressive sweep points),
  --encoder-out <file> (default BENCH_encoder.json: scalar vs sign-GEMM vs
  sign-GEMM+pool encode throughput over growing row counts),
  --margin <f> (confidence-escalation margin for the dual-mode scenario
  matrix; default 2000), --dualmode-out <file> (default BENCH_dualmode.json:
  per-scenario bypass fraction, escalations, energy/query, FE ops avoided)

Env: CLO_HDNN_THREADS caps worker threads (same as --threads);
  CLO_HDNN_SIMD=off|avx2|avx512|neon overrides the runtime-dispatched SIMD
  kernel level (default auto-detect; every level is bit-identical to scalar)

With no artifacts present, commands fall back to built-in synthetic configs
and deterministic blob datasets — no Python toolchain required.";

#[cfg(feature = "pjrt")]
const BACKENDS: &str = "native|pjrt";
#[cfg(not(feature = "pjrt"))]
const BACKENDS: &str = "native; rebuild with --features pjrt to enable pjrt";

fn artifacts_dir(args: &Args) -> std::path::PathBuf {
    args.get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Manifest::default_dir)
}

fn search_mode(args: &Args) -> Result<SearchMode> {
    SearchMode::parse(&args.str_or("search", "l1"))
}

fn policy(args: &Args) -> Result<ProgressiveSearch> {
    Ok(ProgressiveSearch {
        tau: args.f64_or("tau", 0.5)? as f32,
        min_segments: args.usize_or("min-seg", 1)?,
        mode: search_mode(args)?,
    })
}

fn load_datasets(m: &Manifest, cfg: &str) -> Result<(Dataset, Dataset)> {
    Ok((
        Dataset::load(m.dataset_path(&format!("ds_{cfg}_train"))?)?,
        Dataset::load(m.dataset_path(&format!("ds_{cfg}_test"))?)?,
    ))
}

/// Config + (train, test) datasets from the artifact directory when present,
/// otherwise from the built-in synthetic workloads.
fn load_workload(
    args: &Args,
    cfg_name: &str,
) -> Result<(HdConfig, Dataset, Dataset, Option<Manifest>)> {
    let dir = artifacts_dir(args);
    if dir.join("manifest.json").exists() {
        let m = Manifest::load(&dir)?;
        let cfg = m.config(cfg_name)?.clone();
        let (train, test) = load_datasets(&m, cfg_name)?;
        Ok((cfg, train, test, Some(m)))
    } else {
        let (cfg, sc) = builtin_config(cfg_name)?;
        let per_class = args.usize_or("per-class", 40)?;
        // a scenario cell's pixels double as its bypass feature vector, so
        // the image datasets drive the feature-space paths (infer, cl-run,
        // the serve demo) unchanged
        let (train, test) = match &sc {
            Some(sc) => sc.images(per_class, 10),
            None => synthetic::blobs(&cfg, per_class, 10, 17),
        };
        Ok((cfg, train, test, None))
    }
}

/// Resolve a built-in config name: a synthetic feature-space config
/// (tiny|isolet|ucihar) or a dual-mode scenario cell (mnist-easy, ...,
/// ucihar-hard), returned with its scenario when it is one.
fn builtin_config(name: &str) -> Result<(HdConfig, Option<scenario::Scenario>)> {
    if let Ok(cfg) = synthetic::config(name) {
        return Ok((cfg, None));
    }
    match scenario::get(name) {
        Ok(sc) => Ok((sc.cfg.clone(), Some(sc))),
        Err(_) => anyhow::bail!(
            "no built-in config or scenario '{name}' (configs {}; scenarios {}); \
             image-mode configs such as cifar100 need AOT artifacts",
            synthetic::names().join("|"),
            scenario::names().join("|")
        ),
    }
}

/// The `--policy` dual-mode routing policy; `fallback` carries a
/// manifest-supplied per-model spelling when one exists.
fn mode_policy_arg(args: &Args, fallback: Option<&str>) -> Result<ModePolicy> {
    match args.get("policy").or(fallback) {
        Some(s) => ModePolicy::parse(s),
        None => Ok(ModePolicy::default()),
    }
}

/// A scenario cell's seeded-WCFE build spec.
fn scenario_wcfe(sc: &scenario::Scenario) -> WcfeSpec {
    WcfeSpec::Seeded {
        image_hw: sc.image_hw,
        image_c: sc.image_c,
        channels: sc.channels.clone(),
        clusters: sc.clusters,
        seed: sc.seed,
    }
}

/// The `--wcfe` front-end source. Default: the served scenario's seeded
/// front-end when the config is a scenario cell, else the artifact path.
fn wcfe_arg(args: &Args, cfg: &HdConfig, sc: Option<&scenario::Scenario>) -> Result<WcfeSpec> {
    let spec = match args.get("wcfe") {
        Some(s) => s,
        None => {
            return Ok(match sc {
                Some(sc) => scenario_wcfe(sc),
                None => WcfeSpec::Artifacts,
            })
        }
    };
    Ok(match spec {
        "off" | "disabled" => WcfeSpec::Disabled,
        "artifacts" => WcfeSpec::Artifacts,
        other => match other.strip_prefix("scenario:") {
            Some(name) => {
                let s = scenario::get(name)?;
                anyhow::ensure!(
                    s.cfg.features() == cfg.features(),
                    "scenario '{name}' extracts {} features but the served config \
                     has {} — the front-end would feed the wrong geometry",
                    s.cfg.features(),
                    cfg.features()
                );
                scenario_wcfe(&s)
            }
            None => anyhow::bail!("bad --wcfe '{other}' (off|artifacts|scenario:<name>)"),
        },
    })
}

/// The `--threads` budget for in-call backend parallelism. `0` (the
/// default) means auto: `CLO_HDNN_THREADS` when set, else all cores.
fn threads_arg(args: &Args) -> Result<usize> {
    args.usize_or("threads", 0)
}

/// The `--encode` kernel selection (default: the sign-GEMM fast path).
fn encode_kernel_arg(args: &Args) -> Result<clo_hdnn::hdc::EncodeKernel> {
    clo_hdnn::hdc::EncodeKernel::parse(&args.str_or("encode", "signgemm"))
}

/// Build the NativeBackend: production factors when the artifact directory
/// carries them, otherwise seeded factors recalibrated on training samples.
/// `--threads` sizes the backend's per-call worker pool, `--encode` picks
/// the (bit-exact) encode kernel.
fn native_backend(
    cfg: &HdConfig,
    manifest: Option<&Manifest>,
    train: &Dataset,
    args: &Args,
) -> Result<NativeBackend> {
    let threads = threads_arg(args)?;
    let kernel = encode_kernel_arg(args)?;
    if let Some(m) = manifest {
        if m.dir.join(format!("hd_factors_{}.bin", cfg.name)).exists() {
            let mut backend = NativeBackend::from_manifest(m, &cfg.name, 8)?;
            backend.set_threads(threads);
            backend.set_encode_kernel(kernel);
            return Ok(backend);
        }
    }
    let mut backend = if args.flag("remat") {
        NativeBackend::seeded_remat(cfg.clone(), 7, 8)?
    } else {
        NativeBackend::seeded(cfg.clone(), 7, 8)?
    };
    backend.set_threads(threads);
    backend.set_encode_kernel(kernel);
    // Seeded factors come with the config's default scale_q; recalibrate on
    // a few (feature-quantized) training samples so QHVs span INT8 without
    // saturating.
    let n = train.n.min(16);
    if n > 0 && train.dim == cfg.features() {
        let mut xs = Vec::with_capacity(n * cfg.features());
        for i in 0..n {
            xs.extend(quantize_features(train.sample(i), cfg.scale_x));
        }
        backend.calibrate(&xs, n);
    }
    Ok(backend)
}

fn cmd_info(args: &Args) -> Result<()> {
    // live-server polling: one stats line per hosted model. learn_seq is
    // what a replication operator watches — compare a follower's against
    // the primary's to measure staleness.
    if let Some(addr) = args.get("connect") {
        return cmd_info_connect(args, addr);
    }
    // knowledge-checkpoint inspection: verify (magic, checksum, shapes,
    // view bit-identity) and summarize, exiting nonzero on corruption
    if let Some(path) = args.get("knowledge") {
        let info = clo_hdnn::hdc::knowledge::inspect(path)?;
        let c = &info.config;
        println!("knowledge checkpoint {path} ({} bytes): OK", info.file_bytes);
        println!(
            "  config {:10} F={:<5} D={:<5} classes={:<4} segments={}",
            c.name,
            c.features(),
            c.dim(),
            c.classes,
            c.segments
        );
        println!(
            "  trained classes {}/{} | total learns {}",
            info.trained_classes, c.classes, info.total_learns
        );
        println!(
            "  model identity: {}",
            if info.model.is_empty() { "(none — loads into any model)" } else { info.model.as_str() }
        );
        return Ok(());
    }
    // one serving model's registry entry (manifest models section, or a
    // built-in synthetic config when serving hermetically)
    if let Some(model) = args.get("model") {
        return cmd_info_model(args, model);
    }
    let dir = artifacts_dir(args);
    if !dir.join("manifest.json").exists() {
        println!(
            "no artifacts at {} — built-in synthetic configs (NativeBackend):",
            dir.display()
        );
        for name in synthetic::names() {
            let c = synthetic::config(name)?;
            println!(
                "  {name:10} F={:<5} D={:<5} classes={:<4} segments={} (bypass mode)",
                c.features(),
                c.dim(),
                c.classes,
                c.segments
            );
        }
        return Ok(());
    }
    let m = Manifest::load(dir)?;
    m.check_files()?;
    println!("artifact dir: {}", m.dir.display());
    println!("configs:");
    for (name, c) in &m.configs {
        println!(
            "  {name:10} F={:<5} D={:<5} classes={:<4} segments={} qbits={} {}",
            c.features(),
            c.dim(),
            c.classes,
            c.segments,
            c.qbits,
            if c.image { "(normal mode)" } else { "(bypass mode)" }
        );
    }
    println!("executables: {}", m.executables.len());
    for e in m.executables.values() {
        println!("  {:34} {:14} batch={}", e.name, e.kind, e.batch);
    }
    println!("datasets: {}", m.datasets.len());
    if !m.models.is_empty() {
        println!("serving models: {}", m.models.len());
        for e in &m.models {
            println!(
                "  {:12} config={:10} search={:8} threads={} knowledge={}",
                e.name,
                e.config,
                e.search.as_deref().unwrap_or("default"),
                e.threads,
                e.knowledge_file.as_deref().unwrap_or("-")
            );
        }
    }
    if let Some(k) = &m.knowledge {
        println!(
            "knowledge: {} (config {}, auto-snapshot every {} learns){}",
            k.file,
            k.config,
            k.every_learns,
            if m.dir.join(&k.file).exists() { "" } else { " [not yet written]" }
        );
    }
    if let Some(w) = &m.wcfe {
        println!(
            "wcfe: channels={:?} fc_out={} clusters={} pretrain_acc={:.3} clustered_acc={:.3}",
            w.channels, w.fc_out, w.clusters, w.pretrain_acc, w.clustered_acc
        );
    }
    Ok(())
}

/// `clo_hdnn info --model <name>`: one serving model's registry view.
/// `clo_hdnn info --connect <addr>`: poll a live server and print one
/// stats line per hosted model (or only `--model`'s) — knowledge counters
/// plus the monotonic `learn_seq` that replication staleness checks key
/// off. Exits nonzero when the server is unreachable, so scripts can use
/// it both as a health probe and a catch-up poll.
fn cmd_info_connect(args: &Args, addr: &str) -> Result<()> {
    use clo_hdnn::serve::Client;
    let mut c = Client::connect_with_retry(addr, 5, std::time::Duration::from_millis(20))?;
    c.set_timeout(Some(std::time::Duration::from_secs(10)))?;
    let (version, default_model, mut models) = c.hello()?;
    if let Some(one) = args.get("model") {
        models = vec![one.to_string()];
    } else if models.is_empty() {
        models = vec![String::new()];
    }
    for m in &models {
        if !m.is_empty() && version < clo_hdnn::serve::wire::WIRE_V2 {
            anyhow::bail!(
                "server at {addr} only speaks wire v{version}: cannot target model '{m}'"
            );
        }
        c.set_model(m)?;
        let st = c.stats()?;
        let label = if m.is_empty() { default_model.as_str() } else { m.as_str() };
        let policy = ModePolicy::from_code(st.policy, st.policy_margin);
        println!(
            "model {label}: learns {} | classes {} | snapshots {} | learn_seq {} | \
             epoch {} | served {} | wire_errors {} | policy {} | bypass {} | \
             normal {} | escalations {}",
            st.learns,
            st.trained_classes,
            st.snapshots,
            st.learn_seq,
            st.epoch,
            st.served,
            st.wire_errors,
            policy.spelling(),
            st.bypass,
            st.normal,
            st.escalations
        );
    }
    Ok(())
}

fn cmd_info_model(args: &Args, model: &str) -> Result<()> {
    let dir = artifacts_dir(args);
    if !dir.join("manifest.json").exists() {
        let c = synthetic::config(model)?;
        println!(
            "model {model} (built-in synthetic, no registry entry): \
             F={} D={} classes={} segments={}",
            c.features(),
            c.dim(),
            c.classes,
            c.segments
        );
        return Ok(());
    }
    let m = Manifest::load(&dir)?;
    if let Some(entry) = m.model(model) {
        let c = m.config(&entry.config)?;
        println!(
            "model {model}: config {} F={} D={} classes={} segments={}",
            entry.config,
            c.features(),
            c.dim(),
            c.classes,
            c.segments
        );
        println!(
            "  search {} | threads {} | tau {}",
            entry.search.as_deref().unwrap_or("default"),
            entry.threads,
            entry.tau.map(|t| t.to_string()).unwrap_or_else(|| "default".into())
        );
        match m.model_knowledge_path(model) {
            Some(p) => println!(
                "  knowledge {} (auto-snapshot every {} learns){}",
                p.display(),
                entry.every_learns,
                if p.exists() { "" } else { " [not yet written]" }
            ),
            None => println!("  knowledge: none configured"),
        }
    } else if let Ok(c) = m.config(model) {
        println!(
            "model {model}: no registry entry; config exists (F={} D={} classes={} \
             segments={}) and can be served as a model of the same name",
            c.features(),
            c.dim(),
            c.classes,
            c.segments
        );
    } else {
        anyhow::bail!("no model or config '{model}' in the manifest");
    }
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<()> {
    match args.str_or("backend", "native").as_str() {
        "native" => cmd_infer_native(args),
        #[cfg(feature = "pjrt")]
        "pjrt" => cmd_infer_pjrt(args),
        other => anyhow::bail!("unknown --backend '{other}' ({BACKENDS})"),
    }
}

fn report_eval(report: &clo_hdnn::hdc::classifier::EvalReport, dt: f64) {
    println!(
        "accuracy {:.4} over {} samples | mean segments {:.2}/{} (complexity -{:.1}%) | early-exit {:.1}% | {:.1} inf/s",
        report.accuracy,
        report.n,
        report.mean_segments,
        report.total_segments,
        report.complexity_reduction() * 100.0,
        report.early_exit_rate * 100.0,
        report.n as f64 / dt
    );
}

fn cmd_infer_native(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "tiny");
    let (cfg, train, test, manifest) = load_workload(args, &cfg_name)?;
    let pol = policy(args)?;
    println!(
        "backend: native (pure Rust, {}) | search {:?}",
        if manifest.is_some() { "artifact data" } else { "synthetic data" },
        pol.mode
    );
    let backend = native_backend(&cfg, manifest.as_ref(), &train, args)?;
    let mut cl = HdClassifier::new(Box::new(backend), pol);
    let cap = args.usize_or("samples", 400)?;

    let t0 = std::time::Instant::now();
    let trainer = Trainer { retrain_epochs: args.usize_or("retrain", 1)? };
    let idx: Vec<usize> = (0..train.n.min(cap * 4)).collect();
    trainer.train_indices(&mut cl, &train, &idx)?;
    println!("trained on {} samples in {}", idx.len(), fmt_secs(t0.elapsed().as_secs_f64()));

    let t1 = std::time::Instant::now();
    let n = test.n.min(cap);
    let report = cl.evaluate((0..n).map(|i| (test.sample(i).to_vec(), test.label(i))))?;
    report_eval(&report, t1.elapsed().as_secs_f64());
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_infer_pjrt(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "tiny");
    let dir = artifacts_dir(args);
    let mut engine = Engine::load(&dir)?;
    println!("PJRT platform: {}", engine.platform());
    let backend = PjrtBackend::new(&mut engine, &cfg_name, 1)?;
    let mut cl = HdClassifier::new(Box::new(backend), policy(args)?);
    let m = &engine.manifest;
    let (train, test) = load_datasets(m, &cfg_name)?;
    let cap = args.usize_or("samples", 400)?;

    let t0 = std::time::Instant::now();
    let trainer = Trainer { retrain_epochs: args.usize_or("retrain", 1)? };
    let idx: Vec<usize> = (0..train.n.min(cap * 4)).collect();
    trainer.train_indices(&mut cl, &train, &idx)?;
    println!("trained on {} samples in {}", idx.len(), fmt_secs(t0.elapsed().as_secs_f64()));

    let t1 = std::time::Instant::now();
    let n = test.n.min(cap);
    let report = cl.evaluate((0..n).map(|i| (test.sample(i).to_vec(), test.label(i))))?;
    report_eval(&report, t1.elapsed().as_secs_f64());
    Ok(())
}

fn report_cl_run(run: &clo_hdnn::cl::ClRun) {
    println!("learner: {}", run.learner);
    println!(
        "accuracy curve: {:?}",
        run.matrix
            .curve()
            .iter()
            .map(|a| (a * 1000.0).round() / 1000.0)
            .collect::<Vec<_>>()
    );
    println!(
        "final avg accuracy {:.4} | mean forgetting {:.4} | mean segments {:?}",
        run.final_accuracy, run.mean_forgetting, run.mean_segments
    );
}

fn cmd_cl_run(args: &Args) -> Result<()> {
    match args.str_or("backend", "native").as_str() {
        "native" => cmd_cl_run_native(args),
        #[cfg(feature = "pjrt")]
        "pjrt" => cmd_cl_run_pjrt(args),
        other => anyhow::bail!("unknown --backend '{other}' ({BACKENDS})"),
    }
}

fn cmd_cl_run_native(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "tiny");
    let (cfg, train, test, manifest) = load_workload(args, &cfg_name)?;
    let n_tasks = args.usize_or("tasks", 5)?.min(cfg.classes);
    let stream = TaskStream::class_incremental(&train, n_tasks, 1);
    let mut harness = ClHarness::new(&train, &test, &stream);
    harness.eval_cap = args.usize_or("samples", 200)?;

    let backend = native_backend(&cfg, manifest.as_ref(), &train, args)?;
    let mut hd = HdLearner::new(
        HdClassifier::new(Box::new(backend), policy(args)?),
        Trainer { retrain_epochs: args.usize_or("retrain", 1)? },
    );
    let run = harness.run(&mut hd)?;
    report_cl_run(&run);
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_cl_run_pjrt(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "tiny");
    let dir = artifacts_dir(args);
    let mut engine = Engine::load(&dir)?;
    let cfg = engine.manifest.config(&cfg_name)?.clone();
    let (train, test) = load_datasets(&engine.manifest, &cfg_name)?;
    let n_tasks = args.usize_or("tasks", 5)?.min(cfg.classes);
    let stream = TaskStream::class_incremental(&train, n_tasks, 1);
    let mut harness = ClHarness::new(&train, &test, &stream);
    harness.eval_cap = args.usize_or("samples", 200)?;

    let backend = PjrtBackend::new(&mut engine, &cfg_name, 1)?;
    let mut hd = HdLearner::new(
        HdClassifier::new(Box::new(backend), policy(args)?),
        Trainer { retrain_epochs: args.usize_or("retrain", 1)? },
    );
    let run = harness.run(&mut hd)?;
    report_cl_run(&run);
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let has_artifacts = dir.join("manifest.json").exists();
    let cfg_name = args.str_or("config", if has_artifacts { "cifar100" } else { "tiny" });
    let v = args.f64_or("voltage", 0.9)?;
    let (cfg, manifest) = if has_artifacts {
        let m = Manifest::load(&dir)?;
        (m.config(&cfg_name)?.clone(), Some(m))
    } else {
        (synthetic::config(&cfg_name)?, None)
    };
    let chip = Chip::default();
    let report = if cfg.image {
        let m = manifest
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("image config {cfg_name} needs AOT artifacts"))?;
        let wm = m.wcfe.as_ref().ok_or_else(|| anyhow::anyhow!("no wcfe in manifest"))?;
        let tf = clo_hdnn::data::TensorFile::load(m.dir.join(&wm.weights))?;
        let model = clo_hdnn::wcfe::WcfeModel::load(
            &tf, &wm.channels, wm.fc_out, wm.image_hw, wm.image_c)?;
        let cb_tf = clo_hdnn::data::TensorFile::load(m.dir.join(&wm.codebook))?;
        let cb = clo_hdnn::wcfe::Codebook::load(
            &cb_tf,
            &["conv1", "conv2", "conv3"],
            (wm.channels.last().unwrap() * wm.fc_out) as u64,
        )?;
        chip.simulate_inference(&cfg, Mode::Normal, cfg.segments, Some((&model, &cb)), v)
    } else {
        chip.simulate_inference(&cfg, Mode::Bypass, cfg.segments, None, v)
    };
    println!(
        "config {cfg_name} @ {:.2} V / {:.0} MHz:",
        report.op.voltage, report.op.freq_mhz
    );
    for mc in &report.trace.modules {
        println!(
            "  {:10} {:>10} cycles {:>12} ops {:>9.3} uJ",
            mc.name,
            mc.cycles,
            mc.ops,
            mc.energy_j * 1e6
        );
    }
    println!(
        "latency {} | energy {:.3} uJ | WCFE share: {:.1}% latency, {:.1}% energy",
        fmt_secs(report.latency_s),
        report.energy_j * 1e6,
        report.wcfe_latency_share * 100.0,
        report.wcfe_energy_share * 100.0
    );
    Ok(())
}

/// The knowledge wiring for serving: explicit flags win; the manifest's
/// `knowledge` section supplies defaults only when `manifest_defaults` is
/// set (the long-lived `--listen` server — the throwaway Poisson demo
/// must never silently restore/overwrite a production checkpoint); an
/// existing default checkpoint warm-restarts automatically unless
/// `--no-restore`.
fn knowledge_opts(
    args: &Args,
    manifest: Option<&Manifest>,
    cfg_name: &str,
    manifest_defaults: bool,
) -> Result<(Option<std::path::PathBuf>, usize, Option<std::path::PathBuf>)> {
    let manifest = manifest.filter(|_| manifest_defaults);
    let manifest_k = manifest.and_then(|m| m.knowledge_path(cfg_name));
    let snapshot_path = args
        .get("snapshot")
        .map(std::path::PathBuf::from)
        .or(manifest_k);
    let manifest_every = manifest
        .and_then(|m| m.knowledge.as_ref())
        .filter(|k| k.config == cfg_name)
        .map(|k| k.every_learns)
        .unwrap_or(0);
    let snapshot_every = args.usize_or("snapshot-every", manifest_every)?;
    let restore_path = match args.get("restore") {
        Some(p) => Some(std::path::PathBuf::from(p)),
        None if args.flag("no-restore") => None,
        None => snapshot_path.clone().filter(|p| p.exists()),
    };
    Ok((snapshot_path, snapshot_every, restore_path))
}

/// Build the serving [`CoordinatorOptions`] (shared by the Poisson demo
/// and the TCP listen mode; only the latter takes the manifest's
/// knowledge defaults).
fn serve_coordinator_opts(
    args: &Args,
    cfg: &HdConfig,
    cfg_name: &str,
    manifest: Option<&Manifest>,
    manifest_knowledge_defaults: bool,
) -> Result<CoordinatorOptions> {
    let dir = artifacts_dir(args);
    // Artifact factors only when they actually exist — otherwise fall back
    // to seeded factors, matching native_backend()'s behavior for infer.
    let has_factors =
        manifest.is_some() && dir.join(format!("hd_factors_{cfg_name}.bin")).exists();
    let backend = match args.str_or("backend", "native").as_str() {
        "native" if has_factors => {
            BackendSpec::NativeArtifacts { artifacts: dir, config: cfg_name.to_string() }
        }
        "native" if args.flag("remat") => {
            BackendSpec::NativeRemat { cfg: cfg.clone(), seed: 7 }
        }
        "native" => BackendSpec::Native { cfg: cfg.clone(), seed: 7 },
        #[cfg(feature = "pjrt")]
        "pjrt" => BackendSpec::Pjrt { artifacts: dir, config: cfg_name.to_string() },
        other => anyhow::bail!("unknown --backend '{other}' ({BACKENDS})"),
    };
    let (snapshot_path, snapshot_every, restore_path) =
        knowledge_opts(args, manifest, cfg_name, manifest_knowledge_defaults)?;
    let sc = scenario::get(cfg_name).ok();
    Ok(CoordinatorOptions {
        backend,
        model: String::new(),
        tau: args.f64_or("tau", 0.5)? as f32,
        min_segments: args.usize_or("min-seg", 1)?,
        search_mode: search_mode(args)?,
        mode_policy: mode_policy_arg(args, None)?,
        wcfe: wcfe_arg(args, cfg, sc.as_ref())?,
        queue_depth: 256,
        threads: threads_arg(args)?,
        snapshot_path,
        snapshot_every,
        restore_path,
        // the Poisson demo is ephemeral by design; durability is a listen-
        // mode concern (--wal)
        wal_path: None,
        wal_fsync_every: 1,
    })
}

/// Parse a `--models a,b,c` comma list (trimmed, empties dropped) — shared
/// by serve and loadgen so the accepted syntax cannot drift between them.
fn parse_model_list(list: &str) -> Vec<String> {
    list.split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect()
}

/// Per-model-ize a shared `--snapshot` path when hosting several models:
/// `k.clok` + model `isolet` -> `k_isolet.clok` (single-model serving
/// keeps the path untouched).
fn per_model_path(base: &std::path::Path, model: &str, multi: bool) -> std::path::PathBuf {
    if !multi {
        return base.to_path_buf();
    }
    let stem = base.file_stem().and_then(|s| s.to_str()).unwrap_or("knowledge");
    let file = match base.extension().and_then(|e| e.to_str()) {
        Some(ext) if !ext.is_empty() => format!("{stem}_{model}.{ext}"),
        _ => format!("{stem}_{model}"),
    };
    base.with_file_name(file)
}

/// Build one registry [`ModelSpec`](clo_hdnn::serve::ModelSpec) for the
/// listen server. Precedence per knob: explicit CLI flag > the manifest's
/// `models` entry > (single-model only) the legacy `knowledge` section >
/// library default.
fn listen_model_spec(
    args: &Args,
    name: &str,
    manifest: Option<&Manifest>,
    multi: bool,
) -> Result<clo_hdnn::serve::ModelSpec> {
    let dir = artifacts_dir(args);
    let meta = manifest.and_then(|m| m.model(name)).cloned();
    let cfg_name = meta
        .as_ref()
        .map(|m| m.config.clone())
        .unwrap_or_else(|| name.to_string());
    let (cfg, sc) = match manifest {
        Some(m) => (m.config(&cfg_name)?.clone(), None),
        None => builtin_config(&cfg_name)?,
    };
    let has_factors =
        manifest.is_some() && dir.join(format!("hd_factors_{cfg_name}.bin")).exists();
    let backend = match args.str_or("backend", "native").as_str() {
        "native" if has_factors => BackendSpec::NativeArtifacts {
            artifacts: dir.clone(),
            config: cfg_name.clone(),
        },
        "native" if args.flag("remat") => {
            BackendSpec::NativeRemat { cfg: cfg.clone(), seed: 7 }
        }
        "native" => BackendSpec::Native { cfg: cfg.clone(), seed: 7 },
        #[cfg(feature = "pjrt")]
        "pjrt" => BackendSpec::Pjrt { artifacts: dir.clone(), config: cfg_name.clone() },
        other => anyhow::bail!("unknown --backend '{other}' ({BACKENDS})"),
    };
    let search_mode = match args.get("search") {
        Some(s) => SearchMode::parse(s)?,
        None => match meta.as_ref().and_then(|m| m.search.as_deref()) {
            Some(s) => SearchMode::parse(s)?,
            None => SearchMode::default(),
        },
    };
    let tau = match args.get("tau") {
        Some(_) => args.f64_or("tau", 0.5)? as f32,
        None => meta.as_ref().and_then(|m| m.tau).unwrap_or(0.5) as f32,
    };
    let threads = match args.get("threads") {
        Some(_) => threads_arg(args)?,
        None => meta.as_ref().map(|m| m.threads).unwrap_or(0),
    };
    // knowledge wiring: the model's manifest entry first; the legacy
    // single-model `knowledge` section only when serving a single model
    let model_k = manifest.and_then(|m| m.model_knowledge_path(name));
    let legacy_k = if multi {
        None
    } else {
        manifest.and_then(|m| m.knowledge_path(&cfg_name))
    };
    let snapshot_path = args
        .get("snapshot")
        .map(|p| per_model_path(std::path::Path::new(p), name, multi))
        .or(model_k)
        .or(legacy_k);
    let meta_every = meta.as_ref().map(|m| m.every_learns).unwrap_or(0);
    let legacy_every = if multi || meta_every > 0 {
        0
    } else {
        manifest
            .and_then(|m| m.knowledge.as_ref())
            .filter(|k| k.config == cfg_name)
            .map(|k| k.every_learns)
            .unwrap_or(0)
    };
    let snapshot_every =
        args.usize_or("snapshot-every", meta_every.max(legacy_every))?;
    let restore_path = match args.get("restore") {
        Some(_) if multi => anyhow::bail!(
            "--restore targets a single model; with --models, per-model \
             --snapshot checkpoints auto-restore instead"
        ),
        Some(p) => Some(std::path::PathBuf::from(p)),
        None if args.flag("no-restore") => None,
        None => snapshot_path.clone().filter(|p| p.exists()),
    };
    // durable learn log: per-model-ized exactly like --snapshot, so every
    // model gets its own segment file (w.clow -> w_<model>.clow)
    let wal_path = args
        .get("wal")
        .map(|p| per_model_path(std::path::Path::new(p), name, multi));
    // dual-mode routing: explicit --policy > the model's manifest entry >
    // auto (the same precedence as search/tau)
    let mode_policy =
        mode_policy_arg(args, meta.as_ref().and_then(|m| m.policy.as_deref()))?;
    let opts = CoordinatorOptions {
        backend,
        model: name.to_string(),
        tau,
        min_segments: args.usize_or("min-seg", 1)?,
        search_mode,
        mode_policy,
        wcfe: wcfe_arg(args, &cfg, sc.as_ref())?,
        queue_depth: 256,
        threads,
        snapshot_path,
        snapshot_every,
        restore_path,
        wal_path,
        wal_fsync_every: args.usize_or("wal-fsync-every", 1)?,
    };
    Ok(clo_hdnn::serve::ModelSpec::new(name, opts))
}

fn cmd_serve(args: &Args) -> Result<()> {
    if args.get("listen").is_some() {
        return cmd_serve_listen(args);
    }
    let cfg_name = args.str_or("config", "tiny");
    let (cfg, train, test, manifest) = load_workload(args, &cfg_name)?;
    let opts = serve_coordinator_opts(args, &cfg, &cfg_name, manifest.as_ref(), false)?;
    let mode = opts.search_mode;
    let policy = opts.mode_policy;
    // only the hermetic path yields scenario (image) datasets; artifact
    // datasets are feature-space even if a config name were to collide
    let is_scenario = manifest.is_none() && scenario::get(&cfg_name).is_ok();
    println!(
        "serving config {cfg_name} on {:?} | search {mode:?} | policy {}",
        opts.backend,
        policy.spelling()
    );
    let coord = Coordinator::start(opts)?;
    // online learning phase
    let learn_n = args.usize_or("learn", 400)?.min(train.n);
    for i in 0..learn_n {
        coord.call(Payload::Learn(train.sample(i).to_vec(), train.label(i)))?;
    }
    // serving phase with Poisson arrivals; scenario cells send their raw
    // pixels as images so the routing policy decides the mode per request
    let n = args.usize_or("samples", 200)?.min(test.n);
    let rate = args.f64_or("rate", 200.0)?;
    let mut rng = Rng::new(9);
    let mut metrics = clo_hdnn::coordinator::ServeMetrics::default();
    let mut correct = 0usize;
    let t0 = std::time::Instant::now();
    for i in 0..n {
        std::thread::sleep(std::time::Duration::from_secs_f64(rng.exponential(rate)));
        let sample = test.sample(i).to_vec();
        let r = coord.call(if is_scenario {
            Payload::Image(sample)
        } else {
            Payload::Features(sample)
        })?;
        if r.error.is_some() {
            metrics.record_error();
            continue;
        }
        metrics.record_infer(
            r.latency_s,
            r.segments_used,
            r.early_exit,
            r.used_wcfe,
            r.escalated,
            r.energy_j,
        );
        correct += usize::from(r.class == Some(test.label(i)));
    }
    metrics.wall_s = t0.elapsed().as_secs_f64();
    println!(
        "served {} requests | acc {:.4} | p50 {} p95 {} | {:.1} req/s | segments {:.2}/{} (-{:.1}% complexity)",
        metrics.total,
        correct as f64 / n as f64,
        fmt_secs(metrics.latency_percentile(50.0)),
        fmt_secs(metrics.latency_percentile(95.0)),
        metrics.throughput_rps(),
        metrics.mean_segments(),
        cfg.segments,
        metrics.complexity_reduction(cfg.segments) * 100.0
    );
    println!(
        "dual-mode: policy {} | bypass {:.0}% ({} of {}) | escalations {} | {:.3e} J/query",
        policy.spelling(),
        metrics.bypass_fraction() * 100.0,
        metrics.bypass_runs(),
        metrics.segments_used.len(),
        metrics.escalations,
        metrics.energy_per_query_j()
    );
    Ok(())
}

/// `clo_hdnn serve --listen <addr>`: the TCP wire-protocol server — a
/// multi-model registry behind one socket. `--models a,b,c` (or the
/// manifest's `models` section) hosts several models side by side, each
/// with its own executor, search mode, and durable knowledge checkpoint;
/// a single `--model`/`--config` keeps the original one-model behavior.
/// Learned knowledge survives restarts: an existing `--snapshot` file (or
/// the manifest's knowledge wiring) is restored on startup per model,
/// learns auto-checkpoint every `--snapshot-every` bundles, and shutdown
/// flushes whatever is unsaved.
fn cmd_serve_listen(args: &Args) -> Result<()> {
    use clo_hdnn::serve::{
        DEFAULT_IDLE_TIMEOUT_SECS, DEFAULT_MAX_CONNS, Registry, ServeOptions, Server,
    };

    let listen = args.str_or("listen", "127.0.0.1:7311");
    let dir = artifacts_dir(args);
    let manifest = if dir.join("manifest.json").exists() {
        Some(Manifest::load(&dir)?)
    } else {
        None
    };
    // model list: --models a,b | --model a (alias --config a) | every
    // manifest models entry | the tiny default
    let names: Vec<String> = match args.get("models") {
        Some(list) => parse_model_list(list),
        None => match args.get("model").or_else(|| args.get("config")) {
            Some(one) => vec![one.to_string()],
            None => {
                let from_manifest: Vec<String> = manifest
                    .as_ref()
                    .map(|m| m.models.iter().map(|e| e.name.clone()).collect())
                    .unwrap_or_default();
                if from_manifest.is_empty() {
                    vec!["tiny".to_string()]
                } else {
                    from_manifest
                }
            }
        },
    };
    if names.is_empty() {
        anyhow::bail!("serve --listen needs at least one model (--models a,b)");
    }
    let multi = names.len() > 1;
    let mut specs = Vec::with_capacity(names.len());
    for name in &names {
        specs.push(listen_model_spec(args, name, manifest.as_ref(), multi)?);
    }
    for spec in &specs {
        println!(
            "model {:12} on {:?} | search {:?} | policy {} | snapshot {:?} (every {} learns) | restore {:?} | wal {:?}",
            spec.name,
            spec.opts.backend,
            spec.opts.search_mode,
            spec.opts.mode_policy.spelling(),
            spec.opts.snapshot_path,
            spec.opts.snapshot_every,
            spec.opts.restore_path,
            spec.opts.wal_path
        );
    }
    let registry = Registry::start(specs)?;
    // follower mode: each hosted model tails the same-named model on the
    // primary (grab the coordinator handles before the server takes the
    // registry)
    let replica_coords: Vec<(String, std::sync::Arc<Coordinator>)> =
        match args.get("replicate-from") {
            Some(_) => names
                .iter()
                .map(|n| registry.get(n).map(|c| (n.clone(), c.clone())))
                .collect::<Result<_>>()?,
            None => Vec::new(),
        };
    // optional pre-learn phase into the default model (default 0:
    // knowledge comes from the checkpoints and from Learn traffic)
    let learn_arg = args.usize_or("learn", 0)?;
    if learn_arg > 0 {
        let default = registry.default_name().to_string();
        let default_cfg = manifest
            .as_ref()
            .and_then(|m| m.model(&default))
            .map(|e| e.config.clone())
            .unwrap_or_else(|| default.clone());
        let (_, train, _test, _) = load_workload(args, &default_cfg)?;
        let coord = registry.get("")?;
        let learn_n = learn_arg.min(train.n);
        for i in 0..learn_n {
            let r = coord.call(Payload::Learn(train.sample(i).to_vec(), train.label(i)))?;
            if let Some(e) = r.error {
                anyhow::bail!("pre-learn failed: {e}");
            }
        }
        println!("pre-learned {learn_n} samples into model {default}");
    }
    let idle_secs = args.f64_or("idle-timeout", DEFAULT_IDLE_TIMEOUT_SECS as f64)?;
    let max_conns = args.usize_or("max-conns", DEFAULT_MAX_CONNS)?.max(1);
    let serve_opts = ServeOptions {
        allow_snapshot_paths: args.flag("allow-remote-snapshot-paths"),
        idle_timeout: std::time::Duration::from_secs_f64(idle_secs.max(0.001)),
        max_conns,
        ..ServeOptions::default()
    };
    let server = Server::start(&listen, registry, serve_opts)?;
    println!(
        "listening on {} | {} model(s): {} | wire v1+v2 (pipelined) | \
         idle-timeout {idle_secs}s | max {max_conns} conns",
        server.local_addr(),
        names.len(),
        names.join(", ")
    );
    let mut replicas: Vec<clo_hdnn::serve::Replica> = Vec::new();
    if let Some(primary) = args.get("replicate-from") {
        for (name, coord) in replica_coords {
            let mut ropts = clo_hdnn::serve::ReplicaOptions::new(primary);
            ropts.model = name;
            replicas.push(clo_hdnn::serve::Replica::start(coord, ropts)?);
        }
        println!(
            "following {} model(s) on primary {primary} (serving local reads; \
             learns arrive via the primary's log)",
            replicas.len()
        );
    }
    let promote_on = args.get("promote-on").map(parse_promote_on).transpose()?;
    if promote_on.is_some() && replicas.is_empty() {
        anyhow::bail!("--promote-on needs --replicate-from (there is no follower to promote)");
    }
    let duration = args.f64_or("duration", 0.0)?;
    let deadline = (duration > 0.0)
        .then(|| std::time::Instant::now() + std::time::Duration::from_secs_f64(duration));
    // failure-detector state: when each follower's tailer lost its primary
    // (None while connected)
    let mut down_since: Vec<Option<std::time::Instant>> = vec![None; replicas.len()];
    loop {
        if let Some(d) = deadline {
            if std::time::Instant::now() >= d {
                break;
            }
        }
        let tick = match (promote_on, deadline) {
            // nothing to watch, serve until killed
            (None, None) => std::time::Duration::from_secs(3600),
            (None, Some(_)) => std::time::Duration::from_millis(250),
            // the failure detector's resolution
            (Some(_), _) => std::time::Duration::from_millis(25),
        };
        std::thread::sleep(tick);
        if let Some(threshold) = promote_on {
            // promote() consumes the replica, so a promoted follower leaves
            // both vectors; its model keeps serving as the new primary
            let mut i = 0;
            while i < replicas.len() {
                if replicas[i].status().connected {
                    down_since[i] = None;
                    i += 1;
                    continue;
                }
                let since = *down_since[i].get_or_insert_with(std::time::Instant::now);
                if since.elapsed() < threshold {
                    i += 1;
                    continue;
                }
                let r = replicas.remove(i);
                down_since.remove(i);
                match r.promote() {
                    Ok((epoch, base)) => println!(
                        "promote-on: primary down past {threshold:?}; promoted to \
                         epoch {epoch} (log sealed at learn {base}) — accepting learns"
                    ),
                    Err(e) => eprintln!("promote-on: promotion failed: {e:#}"),
                }
            }
        }
    }
    // quiesce replication first so no learn lands between the server's
    // shutdown snapshot flush and process exit
    for r in replicas {
        r.stop();
    }
    let (served, wire_errors, learns) = server.counters();
    println!(
        "shutting down after {duration}s: served {served} frames | {learns} learns | {wire_errors} wire errors"
    );
    server.stop(); // joins connections, flushes the shutdown snapshots
    Ok(())
}

/// Parse `--promote-on down:<millis>`: the listen server's promotion
/// failure detector — a followed model is promoted once its tailer has
/// been continuously disconnected from its primary for this long.
fn parse_promote_on(spec: &str) -> Result<std::time::Duration> {
    let ms = spec
        .strip_prefix("down:")
        .and_then(|ms| ms.parse::<u64>().ok())
        .ok_or_else(|| anyhow::anyhow!("bad --promote-on '{spec}' (down:<millis>)"))?;
    Ok(std::time::Duration::from_millis(ms.max(1)))
}

/// `clo_hdnn admin`: runtime fleet administration over the wire. Actions:
/// `promote` bumps the targeted model's epoch (follower takeover — the
/// model seals its inherited learn log and serves learns as the new
/// primary generation), `model-add <name>` boots a new model on the
/// server cloning `--from`'s executor configuration, and `model-remove
/// <name>` tears one down (knowledge flushes before the acknowledgement).
fn cmd_admin(args: &Args) -> Result<()> {
    use clo_hdnn::serve::Client;
    let addr = args
        .get("connect")
        .ok_or_else(|| anyhow::anyhow!("admin needs --connect <host:port>"))?;
    let action = args.positional().get(1).map(|s| s.as_str()).ok_or_else(|| {
        anyhow::anyhow!("admin needs an action: promote | model-add <name> | model-remove <name>")
    })?;
    let mut c = Client::connect_v2(addr)?;
    c.set_timeout(Some(std::time::Duration::from_secs(30)))?;
    match action {
        "promote" => {
            let model = args.str_or("model", "");
            c.set_model(&model)?;
            let (epoch, base_seq) = c.promote()?;
            println!(
                "promoted model {} on {addr}: epoch {epoch}, log sealed at learn {base_seq}",
                if model.is_empty() { "(default)" } else { model.as_str() }
            );
        }
        "model-add" => {
            let name = args
                .positional()
                .get(2)
                .ok_or_else(|| anyhow::anyhow!("model-add needs a model name"))?;
            let source = args.str_or("from", "");
            let models = c.model_add(name, &source)?;
            println!("added model {name} on {addr}; now hosting: {}", models.join(", "));
        }
        "model-remove" => {
            let name = args
                .positional()
                .get(2)
                .ok_or_else(|| anyhow::anyhow!("model-remove needs a model name"))?;
            let models = c.model_remove(name)?;
            println!("removed model {name} on {addr}; now hosting: {}", models.join(", "));
        }
        other => anyhow::bail!("unknown admin action '{other}' (promote|model-add|model-remove)"),
    }
    Ok(())
}

/// One loadgen target: a wire model name ("" = server default) plus its
/// deterministic synthetic workload. Scenario cells additionally carry
/// their image geometry so the driver can send image-shaped bodies and
/// reconstruct the cell's WCFE cost model for the dual-mode report.
struct LoadgenWork {
    wire_model: String,
    label: String,
    train: Dataset,
    test: Dataset,
    scenario: Option<scenario::Scenario>,
}

/// Which request shape `loadgen` puts on the wire. Image bodies need a
/// scenario workload (they carry raw pixels the server's WCFE geometry
/// must match); `Mix` alternates per request so one run exercises both
/// the bypass feature path and the image routing path.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum PayloadKind {
    Features,
    Image,
    Mix,
}

impl PayloadKind {
    fn parse(s: &str) -> Result<PayloadKind> {
        Ok(match s {
            "features" => PayloadKind::Features,
            "image" => PayloadKind::Image,
            "mix" => PayloadKind::Mix,
            other => anyhow::bail!("bad --payload '{other}' (features|image|mix)"),
        })
    }

    /// Does a thread's `i`-th request go out image-shaped? Deterministic
    /// in `i` so the mix is reproducible across runs.
    fn image_for(self, i: usize) -> bool {
        match self {
            PayloadKind::Features => false,
            PayloadKind::Image => true,
            PayloadKind::Mix => i % 2 == 0,
        }
    }
}

/// A request in flight on a pipelined loadgen connection.
struct LoadgenPending {
    model: usize,
    /// expected label for infers; `None` marks a learn
    expect: Option<usize>,
    t0: std::time::Instant,
}

/// Per-connection loadgen accounting. A single process-wide error counter
/// cannot attribute scaling failures, so errors and timeouts are counted
/// on the connection that saw them (the JSON's `per_connection` section).
struct ConnReport {
    /// global connection index (thread-strided across client threads)
    conn: usize,
    /// which server this connection talks to: 0 = the primary (--connect),
    /// 1.. = the matching --replicas entry
    target: usize,
    requests: u64,
    errors: u64,
    timeouts: u64,
}

/// One live loadgen connection: its client, its in-flight window, and its
/// own report.
struct LoadgenConn {
    client: clo_hdnn::serve::Client,
    pending: std::collections::HashMap<u64, LoadgenPending>,
    report: ConnReport,
    /// false once the target died (transport failure with no reconnect);
    /// dead connections stop receiving traffic and the stream fails over
    /// to the remaining live targets
    alive: bool,
}

/// What came of one `loadgen_drain_one` call.
enum DrainOutcome {
    /// a reply landed and was folded into the accumulators
    Delivered,
    /// the receive deadline expired: in-flight requests were counted as
    /// timeouts; the caller may reconnect to the same target
    TimedOut,
    /// the transport failed outright (peer gone, e.g. a chaos kill -9):
    /// in-flight requests were counted as errors; the caller must mark
    /// the connection dead and fail the stream over
    Died,
}

/// Connect (negotiating wire v2 when asked) via the client's bounded
/// retry/backoff-with-jitter loop — a server draining a large accept burst
/// can leave the listen backlog momentarily full, and a hundred loadgen
/// threads retrying in lockstep would keep it full — then arm the
/// per-reply deadline.
fn loadgen_connect(
    addr: &str,
    v2: bool,
    timeout: Option<std::time::Duration>,
) -> Result<clo_hdnn::serve::Client> {
    use clo_hdnn::serve::Client;
    let mut c = Client::connect_with_retry(addr, 10, std::time::Duration::from_millis(10))?;
    if v2 {
        let (version, _, _) = c.hello()?;
        if version < clo_hdnn::serve::wire::WIRE_V2 {
            anyhow::bail!("server at {addr} only speaks wire v{version}");
        }
    }
    c.set_timeout(timeout)?;
    Ok(c)
}

/// Collect one reply off a pipelined connection and fold it into the
/// per-model accumulators `(metrics, correct, infers)` plus the
/// connection's own report. A receive-deadline expiry counts every
/// in-flight request as a timeout (attributed to its model) and lets the
/// caller reconnect; a hard transport failure counts them as errors and
/// tells the caller to mark the target dead — a killed server must fail
/// the stream over, not abort the whole client thread. Only protocol
/// violations (unmatched id, mismatched reply type) still abort.
fn loadgen_drain_one(
    conn: &mut LoadgenConn,
    per: &mut [(clo_hdnn::coordinator::ServeMetrics, usize, usize)],
) -> Result<DrainOutcome> {
    use clo_hdnn::serve::{RecvTimeout, WireResponse};
    let resp = match conn.client.recv() {
        Ok(r) => r,
        Err(e) if e.downcast_ref::<RecvTimeout>().is_some() => {
            for (_, p) in conn.pending.drain() {
                per[p.model].0.record_timeout();
                conn.report.timeouts += 1;
            }
            return Ok(DrainOutcome::TimedOut);
        }
        Err(_) => {
            for (_, p) in conn.pending.drain() {
                per[p.model].0.record_error();
                conn.report.errors += 1;
            }
            return Ok(DrainOutcome::Died);
        }
    };
    let p = conn
        .pending
        .remove(&resp.id())
        .ok_or_else(|| anyhow::anyhow!("reply id {} matches no in-flight request", resp.id()))?;
    let dt = p.t0.elapsed().as_secs_f64();
    let (m, correct, infers) = &mut per[p.model];
    match (&resp, p.expect) {
        (WireResponse::Error { .. }, _) => {
            m.record_error();
            conn.report.errors += 1;
        }
        (
            WireResponse::Infer { class, segments, early, wcfe, escalated, energy_j, .. },
            Some(label),
        ) => {
            m.record_infer(dt, *segments as usize, *early, *wcfe, *escalated, *energy_j);
            *infers += 1;
            *correct += usize::from(*class as usize == label);
        }
        (WireResponse::Learn { .. }, None) => m.record_learn(dt),
        (other, _) => anyhow::bail!("reply type does not match its request: {other:?}"),
    }
    Ok(DrainOutcome::Delivered)
}

/// Pick the connection slot for a request that may only go to the first
/// `upto` connections (learns stay in the primary range; infers may use
/// them all), skipping dead targets: start at the round-robin slot `i %
/// upto` and walk forward until a live one turns up. `None` means every
/// eligible target is dead.
fn pick_live_slot(live: &[bool], upto: usize, i: usize) -> Option<usize> {
    let upto = upto.min(live.len());
    if upto == 0 {
        return None;
    }
    let start = i % upto;
    (0..upto).map(|k| (start + k) % upto).find(|&s| live[s])
}

/// One point of the connection-scaling curve: hold `n` concurrent
/// connections open (spread over `threads` client threads) and drive
/// `rounds` lockstep infer round-trips on every one — pipeline 1,
/// infer-only, pure transport concurrency. Returns the point's JSON row.
#[allow(clippy::too_many_arguments)]
fn loadgen_scale_point(
    addr: &str,
    v2: bool,
    work: &LoadgenWork,
    n: usize,
    rounds: usize,
    threads: usize,
    mode: Option<SearchMode>,
    timeout: Option<std::time::Duration>,
) -> Result<clo_hdnn::util::json::Json> {
    use clo_hdnn::coordinator::ServeMetrics;
    use clo_hdnn::serve::{RecvTimeout, ReqBody, WireResponse};
    use clo_hdnn::util::json::Json;

    let t0 = std::time::Instant::now();
    let results: Vec<Result<ServeMetrics>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                s.spawn(move || -> Result<ServeMetrics> {
                    // global connection ids owned by this thread: t, t+threads, ...
                    let mut conns = Vec::new();
                    for g in (0..n).filter(|g| g % threads == t) {
                        conns.push((g, loadgen_connect(addr, v2, timeout)?));
                    }
                    let mut m = ServeMetrics::default();
                    for r in 0..rounds {
                        // send one infer on every connection, then collect
                        // every reply — all n stay concurrently in flight
                        let mut sends = Vec::with_capacity(conns.len());
                        for (slot, (g, c)) in conns.iter_mut().enumerate() {
                            let idx = (*g + r * n) % work.test.n;
                            let body = ReqBody::Infer {
                                mode: clo_hdnn::serve::Client::mode_byte(mode),
                                features: work.test.sample(idx).to_vec(),
                            };
                            let q0 = std::time::Instant::now();
                            let id = c.send_for(&work.wire_model, body)?;
                            sends.push((slot, id, q0));
                        }
                        for (slot, id, q0) in sends {
                            let c = &mut conns[slot].1;
                            match c.recv() {
                                Ok(resp) if resp.id() == id => match resp {
                                    WireResponse::Error { .. } => m.record_error(),
                                    _ => m.record(q0.elapsed().as_secs_f64(), 0, false, false),
                                },
                                Ok(_) => m.record_error(),
                                Err(e) if e.downcast_ref::<RecvTimeout>().is_some() => {
                                    m.record_timeout()
                                }
                                Err(e) => return Err(e),
                            }
                        }
                    }
                    Ok(m)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("scaling thread panicked"))
            .collect()
    });
    let mut m = ServeMetrics::default();
    for r in results {
        m.merge(&r?);
    }
    m.wall_s = t0.elapsed().as_secs_f64();
    let lat = m.latency_summary();
    println!(
        "scale {n} conns: {} requests | errors {} | timeouts {} | p50 {} | p99 {} | {:.0} req/s",
        m.total,
        m.errors,
        m.timeouts,
        fmt_secs(lat.p50_s),
        fmt_secs(lat.p99_s),
        m.throughput_rps()
    );
    Ok(Json::obj(vec![
        ("connections", Json::Num(n as f64)),
        ("requests", Json::Num(m.total as f64)),
        ("errors", Json::Num(m.errors as f64)),
        ("timeouts", Json::Num(m.timeouts as f64)),
        ("wall_s", Json::Num(m.wall_s)),
        ("throughput_rps", Json::Num(m.throughput_rps())),
        (
            "latency",
            Json::obj(vec![
                ("p50_s", Json::Num(lat.p50_s)),
                ("p99_s", Json::Num(lat.p99_s)),
            ]),
        ),
    ]))
}

/// `clo_hdnn loadgen`: drive a live TCP server with N concurrent client
/// threads mixing Infer and Learn traffic over deterministic synthetic
/// workloads, then report throughput + latency percentiles (per model when
/// Accuracy for report tables: `n/a` when the run produced no inferences
/// (e.g. an all-learn mix) instead of formatting the NaN that 0/0 yields.
fn accuracy_cell(correct: usize, infers: usize) -> String {
    if infers == 0 {
        "n/a".to_string()
    } else {
        format!("{:.4}", correct as f64 / infers as f64)
    }
}

/// Accuracy for `BENCH_serve.json`: explicit `null` when no inferences
/// ran, so downstream tooling sees a typed absent value rather than a NaN
/// the JSON writer has to degrade silently.
fn accuracy_json(correct: usize, infers: usize) -> clo_hdnn::util::json::Json {
    use clo_hdnn::util::json::Json;
    if infers == 0 {
        Json::Null
    } else {
        Json::Num(correct as f64 / infers as f64)
    }
}

/// One scenario cell of `BENCH_dualmode.json` — the shape is shared by
/// `bench` and `loadgen` so `scripts/bench_gate.py` gates either source.
/// The FE complexity-savings ledger rebuilds the cell's seeded WCFE
/// locally (deterministic, so client and server agree on the cost model):
/// a bypassed query avoids the dense FE entirely, a normal-mode query
/// still avoids the dense-vs-clustered op gap.
fn dualmode_cell(
    sc: &scenario::Scenario,
    m: &clo_hdnn::coordinator::ServeMetrics,
    correct: usize,
    infers: usize,
    policy: &str,
) -> clo_hdnn::util::json::Json {
    use clo_hdnn::util::json::Json;
    let fe = clo_hdnn::wcfe::ClusteredWcfe::cluster(
        clo_hdnn::wcfe::WcfeModel::seeded(
            sc.image_hw,
            sc.image_c,
            &sc.channels,
            sc.cfg.features(),
            sc.seed,
        ),
        sc.clusters,
    );
    let (dense, clustered) = (fe.dense_ops(), fe.clustered_ops());
    let avoided =
        m.bypass_runs() * dense + m.wcfe_runs * dense.saturating_sub(clustered);
    let s = m.latency_summary();
    Json::obj(vec![
        ("family", Json::Str(sc.family.to_string())),
        ("hard", Json::Bool(sc.hard)),
        ("policy", Json::Str(policy.to_string())),
        ("infers", Json::Num(m.segments_used.len() as f64)),
        ("learns", Json::Num(m.learns as f64)),
        ("errors", Json::Num(m.errors as f64)),
        ("bypass", Json::Num(m.bypass_runs() as f64)),
        ("normal", Json::Num(m.wcfe_runs as f64)),
        ("escalations", Json::Num(m.escalations as f64)),
        ("bypass_fraction", Json::Num(m.bypass_fraction())),
        ("accuracy", accuracy_json(correct, infers)),
        ("energy_total_j", Json::Num(m.energy_j)),
        ("energy_per_query_j", Json::Num(m.energy_per_query_j())),
        (
            "fe_ops",
            Json::obj(vec![
                ("dense_per_query", Json::Num(dense as f64)),
                ("clustered_per_query", Json::Num(clustered as f64)),
                ("avoided_total", Json::Num(avoided as f64)),
            ]),
        ),
        (
            "latency",
            Json::obj(vec![
                ("p50_s", Json::Num(s.p50_s)),
                ("p99_s", Json::Num(s.p99_s)),
            ]),
        ),
    ])
}

/// driving several) and write `BENCH_serve.json` (version 4, with
/// per-connection and per-target error/timeout attribution). `--models
/// a,b` targets a model mix over wire v2, `--pipeline k` keeps k requests
/// in flight per connection, `--connections n` spreads the streams over n
/// sockets, `--replicas a,b` fans Infer traffic out over follower servers
/// (learns stay pinned to the primary), and `--scale-connections a,b,c`
/// appends a connection-scaling curve against the reactor. With
/// `--learn-frac 0` the per-model request streams are fully deterministic,
/// so accuracy comparisons across a server restart are exact — the
/// warm-restart CI gate relies on that (the sample schedule is per client
/// *thread*, so connection count doesn't perturb it).
fn cmd_loadgen(args: &Args) -> Result<()> {
    use clo_hdnn::coordinator::ServeMetrics;
    use clo_hdnn::serve::{Client, ReqBody};
    use clo_hdnn::util::json::Json;
    use clo_hdnn::util::stats::Table;
    use std::collections::{BTreeMap, HashMap};

    // --fleet switches loadgen into the health-checked failover client: a
    // different driving loop (single Fleet, probe-routed), reported in the
    // same BENCH_serve.json shape
    if let Some(list) = args.get("fleet") {
        return cmd_loadgen_fleet(args, &parse_model_list(list));
    }
    let addr = args
        .get("connect")
        .ok_or_else(|| anyhow::anyhow!("loadgen needs --connect <host:port>"))?
        .to_string();
    // read fan-out: follower servers that serve Infer traffic alongside the
    // primary. Learns always go to the primary — follower knowledge must
    // arrive through the primary's learn log, or the stores would diverge.
    let replica_addrs: Vec<String> =
        args.get("replicas").map(parse_model_list).unwrap_or_default();
    let model_names: Vec<String> = match args.get("models") {
        Some(list) => parse_model_list(list),
        None => args.get("model").map(|m| vec![m.to_string()]).unwrap_or_default(),
    };
    let pipeline = args.usize_or("pipeline", 1)?.clamp(1, 64);
    let payload = PayloadKind::parse(&args.str_or("payload", "features"))?;
    // model targeting and pipelining both need wire v2; a plain run stays
    // on v1 so the launch protocol keeps getting exercised end to end
    let v2 = !model_names.is_empty() || pipeline > 1;
    let per_class = args.usize_or("per-class", 40)?;
    let build_work = |name: &str, wire_model: String| -> Result<LoadgenWork> {
        let (cfg, sc) = builtin_config(name).map_err(|e| {
            anyhow::anyhow!(
                "loadgen workloads are hermetic, so --models entries must be \
                 synthetic config or scenario names: {e}"
            )
        })?;
        let (train, test) = match &sc {
            Some(sc) => sc.images(per_class, 10),
            None => synthetic::blobs(&cfg, per_class, 10, 17),
        };
        Ok(LoadgenWork { wire_model, label: name.to_string(), train, test, scenario: sc })
    };
    let works: Vec<LoadgenWork> = if model_names.is_empty() {
        let cfg_name = args.str_or("config", "tiny");
        vec![build_work(&cfg_name, String::new())?]
    } else {
        model_names.iter().map(|name| build_work(name, name.clone())).collect::<Result<_>>()?
    };
    if payload != PayloadKind::Features {
        if let Some(w) = works.iter().find(|w| w.scenario.is_none()) {
            anyhow::bail!(
                "--payload {payload:?} sends image bodies, so every driven workload \
                 must be a scenario cell — '{}' is not (have {})",
                w.label,
                scenario::names().join("|")
            );
        }
    }
    let clients = args.usize_or("clients", 4)?.max(1);
    // total concurrent connections, spread across the client threads
    // (thread t owns connections t, t+clients, ...); the default of one
    // per thread reproduces the historical thread-per-connection shape
    let connections = args.usize_or("connections", clients)?.max(clients);
    let requests = args.usize_or("requests", 200)?;
    let learn_frac = args.f64_or("learn-frac", 0.25)?.clamp(0.0, 1.0);
    let timeout_s = args.f64_or("timeout", 30.0)?;
    let timeout = (timeout_s > 0.0).then(|| std::time::Duration::from_secs_f64(timeout_s));
    let mode = match args.str_or("search", "default").as_str() {
        "default" => None,
        other => Some(SearchMode::parse(other)?),
    };

    println!(
        "loadgen -> {addr}: {clients} clients x {requests} requests over {connections} \
         connection(s), learn-frac {learn_frac}, pipeline {pipeline}, models [{}], \
         search {:?}, replicas [{}]",
        works.iter().map(|w| w.label.as_str()).collect::<Vec<_>>().join(","),
        mode,
        replica_addrs.join(",")
    );
    type PerModel = Vec<(ServeMetrics, usize, usize)>;
    let t0 = std::time::Instant::now();
    let results: Vec<Result<(PerModel, Vec<ConnReport>)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|t| {
                let (addr, works, replica_addrs) = (&addr, &works, &replica_addrs);
                s.spawn(move || -> Result<(PerModel, Vec<ConnReport>)> {
                    let mut conns: Vec<LoadgenConn> = Vec::new();
                    for g in (0..connections).filter(|g| g % clients == t) {
                        conns.push(LoadgenConn {
                            client: loadgen_connect(addr, v2, timeout)?,
                            pending: HashMap::new(),
                            report: ConnReport {
                                conn: g,
                                target: 0,
                                requests: 0,
                                errors: 0,
                                timeouts: 0,
                            },
                            alive: true,
                        });
                    }
                    // primary connections first; then one connection per
                    // follower (per thread), with globally unique ids past
                    // the primary range
                    let primary_count = conns.len().max(1);
                    for (ri, raddr) in replica_addrs.iter().enumerate() {
                        conns.push(LoadgenConn {
                            client: loadgen_connect(raddr, v2, timeout)?,
                            pending: HashMap::new(),
                            report: ConnReport {
                                conn: connections + ri * clients + t,
                                target: ri + 1,
                                requests: 0,
                                errors: 0,
                                timeouts: 0,
                            },
                            alive: true,
                        });
                    }
                    let mut rng = Rng::new(0xC0FF_EE00 + t as u64);
                    let mut per: PerModel =
                        works.iter().map(|_| (ServeMetrics::default(), 0, 0)).collect();
                    // per-model deterministic sample schedule: client t
                    // covers a strided slice of each model's dataset (the
                    // schedule is per *thread*, so adding connections never
                    // changes which samples are sent — only which socket
                    // carries them)
                    let mut sent = vec![0usize; works.len()];
                    for i in 0..requests {
                        let mi = (t + i) % works.len();
                        let w = &works[mi];
                        let k = sent[mi];
                        sent[mi] += 1;
                        // scenario geometry guarantees pixels == features,
                        // so either body shape is valid — image bodies take
                        // the routed (policy-decided) path, feature bodies
                        // the bypass path
                        let as_image = payload.image_for(i);
                        let (body, expect) = if rng.uniform() < learn_frac {
                            let j = (t + k * clients) % w.train.n;
                            let class = w.train.label(j) as u32;
                            let sample = w.train.sample(j).to_vec();
                            let body = if as_image {
                                ReqBody::LearnImage { class, pixels: sample }
                            } else {
                                ReqBody::Learn { class, features: sample }
                            };
                            (body, None)
                        } else {
                            let idx = (t + k * clients) % w.test.n;
                            let sample = w.test.sample(idx).to_vec();
                            let body = if as_image {
                                ReqBody::InferImage {
                                    mode: Client::mode_byte(mode),
                                    pixels: sample,
                                }
                            } else {
                                ReqBody::Infer {
                                    mode: Client::mode_byte(mode),
                                    features: sample,
                                }
                            };
                            (body, Some(w.test.label(idx)))
                        };
                        // learns stay pinned to the primary's connections;
                        // infers round-robin across every target (a lagging
                        // follower answers from its last-converged state —
                        // stale, never wrong-model). Dead targets are
                        // skipped: the stream fails over to whichever
                        // eligible connections are still alive.
                        let upto = if expect.is_none() && !replica_addrs.is_empty() {
                            primary_count
                        } else {
                            conns.len()
                        };
                        let live: Vec<bool> = conns.iter().map(|c| c.alive).collect();
                        let Some(slot) = pick_live_slot(&live, upto, i) else {
                            anyhow::bail!(
                                "every eligible loadgen target connection is dead"
                            );
                        };
                        let conn = &mut conns[slot];
                        let q0 = std::time::Instant::now();
                        match conn.client.send_for(&w.wire_model, body) {
                            Ok(id) => {
                                conn.report.requests += 1;
                                conn.pending
                                    .insert(id, LoadgenPending { model: mi, expect, t0: q0 });
                            }
                            Err(_) => {
                                // the socket died between replies (e.g. a
                                // chaos kill -9 mid-stream): attribute the
                                // failed send plus everything in flight,
                                // mark the target dead, move on
                                conn.alive = false;
                                conn.report.errors += 1;
                                per[mi].0.record_error();
                                for (_, p) in conn.pending.drain() {
                                    per[p.model].0.record_error();
                                    conn.report.errors += 1;
                                }
                                continue;
                            }
                        }
                        // the pipeline window is per connection
                        while conn.pending.len() >= pipeline {
                            match loadgen_drain_one(conn, &mut per)? {
                                DrainOutcome::Delivered => {}
                                DrainOutcome::TimedOut => {
                                    let taddr = if conn.report.target == 0 {
                                        addr.as_str()
                                    } else {
                                        replica_addrs[conn.report.target - 1].as_str()
                                    };
                                    match loadgen_connect(taddr, v2, timeout) {
                                        Ok(c) => conn.client = c,
                                        Err(_) => {
                                            conn.alive = false;
                                            conn.report.errors += 1;
                                            break;
                                        }
                                    }
                                }
                                DrainOutcome::Died => {
                                    conn.alive = false;
                                    break;
                                }
                            }
                        }
                    }
                    for conn in &mut conns {
                        while !conn.pending.is_empty() {
                            match loadgen_drain_one(conn, &mut per)? {
                                DrainOutcome::Delivered => {}
                                DrainOutcome::TimedOut => {
                                    let taddr = if conn.report.target == 0 {
                                        addr.as_str()
                                    } else {
                                        replica_addrs[conn.report.target - 1].as_str()
                                    };
                                    match loadgen_connect(taddr, v2, timeout) {
                                        Ok(c) => conn.client = c,
                                        Err(_) => {
                                            conn.alive = false;
                                            conn.report.errors += 1;
                                            break;
                                        }
                                    }
                                }
                                DrainOutcome::Died => {
                                    conn.alive = false;
                                    break;
                                }
                            }
                        }
                    }
                    Ok((per, conns.into_iter().map(|c| c.report).collect()))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("loadgen client thread panicked"))
            .collect()
    });
    let wall_s = t0.elapsed().as_secs_f64();

    let mut by_model: PerModel = works.iter().map(|_| (ServeMetrics::default(), 0, 0)).collect();
    let mut conn_reports: Vec<ConnReport> = Vec::with_capacity(connections);
    for r in results {
        let (per, reports) = r?;
        for (i, (m, c, n)) in per.into_iter().enumerate() {
            by_model[i].0.merge(&m);
            by_model[i].1 += c;
            by_model[i].2 += n;
        }
        conn_reports.extend(reports);
    }
    conn_reports.sort_by_key(|r| r.conn);
    let mut metrics = ServeMetrics::default();
    let (mut correct, mut infers) = (0usize, 0usize);
    for (m, c, n) in &mut by_model {
        m.wall_s = wall_s;
        metrics.merge(m);
        correct += *c;
        infers += *n;
    }
    metrics.wall_s = wall_s;

    let lat = metrics.latency_summary();
    let mut table = Table::new(&["metric", "value"]);
    table.row(&["requests".into(), format!("{}", metrics.total)]);
    table.row(&["learns".into(), format!("{}", metrics.learns)]);
    table.row(&["errors".into(), format!("{}", metrics.errors)]);
    table.row(&["timeouts".into(), format!("{}", metrics.timeouts)]);
    table.row(&["accuracy".into(), accuracy_cell(correct, infers)]);
    table.row(&["throughput".into(), format!("{:.1} req/s", metrics.throughput_rps())]);
    table.row(&["p50".into(), fmt_secs(lat.p50_s)]);
    table.row(&["p95".into(), fmt_secs(lat.p95_s)]);
    table.row(&["p99".into(), fmt_secs(lat.p99_s)]);
    table.print();
    if works.len() > 1 {
        let mut mt = Table::new(&["model", "requests", "learns", "errors", "acc", "p50", "p95", "p99"]);
        for (w, (m, c, n)) in works.iter().zip(&by_model) {
            let s = m.latency_summary();
            mt.row(&[
                w.label.clone(),
                format!("{}", m.total),
                format!("{}", m.learns),
                format!("{}", m.errors),
                accuracy_cell(*c, *n),
                fmt_secs(s.p50_s),
                fmt_secs(s.p95_s),
                fmt_secs(s.p99_s),
            ]);
        }
        mt.print();
    }
    // name offending connections (an operator's first isolation question:
    // "which connection is misbehaving?"); quiet when the run is clean
    if conn_reports.iter().any(|r| r.errors + r.timeouts > 0) {
        let mut ct = Table::new(&["conn", "target", "requests", "errors", "timeouts"]);
        for r in conn_reports.iter().filter(|r| r.errors + r.timeouts > 0) {
            ct.row(&[
                format!("{}", r.conn),
                format!("{}", r.target),
                format!("{}", r.requests),
                format!("{}", r.errors),
                format!("{}", r.timeouts),
            ]);
        }
        ct.print();
    }

    // per-target attribution (primary first, then each --replicas entry):
    // which server carried the traffic, and which one produced the errors
    let mut per_target = vec![(0u64, 0u64, 0u64); 1 + replica_addrs.len()];
    for r in &conn_reports {
        let t = &mut per_target[r.target];
        t.0 += r.requests;
        t.1 += r.errors;
        t.2 += r.timeouts;
    }
    if !replica_addrs.is_empty() {
        let mut tt = Table::new(&["target", "requests", "errors", "timeouts"]);
        for (ti, (req, err, to)) in per_target.iter().enumerate() {
            let label = if ti == 0 {
                format!("{addr} (primary)")
            } else {
                replica_addrs[ti - 1].clone()
            };
            tt.row(&[label, format!("{req}"), format!("{err}"), format!("{to}")]);
        }
        tt.print();
    }

    // optional connection-scaling sweep: how does the server hold up as
    // concurrent connections grow? (infer-only, driven on the first model)
    let mut scaling: Vec<Json> = Vec::new();
    if let Some(list) = args.get("scale-connections") {
        let rounds = args.usize_or("scale-requests", 2)?.max(1);
        for tok in list.split(',').filter(|s| !s.trim().is_empty()) {
            let n: usize = tok
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad --scale-connections entry '{tok}'"))?;
            let threads = clients.min(n.max(1));
            scaling.push(loadgen_scale_point(
                &addr,
                v2,
                &works[0],
                n.max(1),
                rounds,
                threads,
                mode,
                timeout,
            )?);
        }
    }

    // end-of-run server-side actions: optional snapshots + per-model stats
    let mut control = if v2 { Client::connect_v2(&addr)? } else { Client::connect(&addr)? };
    let mut snapshot_paths: Vec<String> = Vec::new();
    if args.flag("snapshot-default") {
        // empty wire path = the server's configured default checkpoint,
        // one per driven model
        for w in &works {
            control.set_model(&w.wire_model)?;
            let written = control.snapshot(None)?;
            println!("server checkpointed model [{}] to {written}", w.label);
            snapshot_paths.push(written);
        }
    } else if let Some(path) = args.get("snapshot-out") {
        if works.len() > 1 {
            anyhow::bail!("--snapshot-out targets one model; use --snapshot-default");
        }
        control.set_model(&works[0].wire_model)?;
        let written = control.snapshot(Some(path))?;
        println!("server checkpointed knowledge to {written}");
        snapshot_paths.push(written);
    }
    let mut models_json: BTreeMap<String, Json> = BTreeMap::new();
    let mut model_stats: Vec<clo_hdnn::serve::WireStats> = Vec::with_capacity(works.len());
    // knowledge counters summed across driven models (the process-wide
    // served/wire_errors counters are identical in every reply)
    let (mut total_learns, mut total_classes, mut total_snapshots) = (0u64, 0u64, 0u64);
    for (w, (m, c, n)) in works.iter().zip(&by_model) {
        control.set_model(&w.wire_model)?;
        let st = control.stats()?;
        total_learns += st.learns;
        total_classes += st.trained_classes as u64;
        total_snapshots += st.snapshots;
        let s = m.latency_summary();
        models_json.insert(
            w.label.clone(),
            Json::obj(vec![
                ("requests", Json::Num(m.total as f64)),
                ("learns", Json::Num(m.learns as f64)),
                ("infers", Json::Num(*n as f64)),
                ("errors", Json::Num(m.errors as f64)),
                ("accuracy", accuracy_json(*c, *n)),
                (
                    "latency",
                    Json::obj(vec![
                        ("mean_s", Json::Num(s.mean_s)),
                        ("p50_s", Json::Num(s.p50_s)),
                        ("p95_s", Json::Num(s.p95_s)),
                        ("p99_s", Json::Num(s.p99_s)),
                    ]),
                ),
                (
                    "server",
                    Json::obj(vec![
                        ("learns", Json::Num(st.learns as f64)),
                        ("trained_classes", Json::Num(st.trained_classes as f64)),
                        ("snapshots", Json::Num(st.snapshots as f64)),
                        (
                            "policy",
                            Json::Str(
                                ModePolicy::from_code(st.policy, st.policy_margin).spelling(),
                            ),
                        ),
                        ("bypass", Json::Num(st.bypass as f64)),
                        ("normal", Json::Num(st.normal as f64)),
                        ("escalations", Json::Num(st.escalations as f64)),
                    ]),
                ),
            ]),
        );
        model_stats.push(st);
    }
    let server_stats = *model_stats.last().expect("at least one model is always driven");
    println!(
        "server: served {} | learns {} (across {} driven model(s)) | wire errors {}",
        server_stats.served,
        total_learns,
        works.len(),
        server_stats.wire_errors
    );

    let doc = Json::obj(vec![
        ("version", Json::Num(4.0)),
        (
            "config",
            Json::Str(works.iter().map(|w| w.label.clone()).collect::<Vec<_>>().join(",")),
        ),
        ("clients", Json::Num(clients as f64)),
        ("connections", Json::Num(connections as f64)),
        ("requests_per_client", Json::Num(requests as f64)),
        ("learn_frac", Json::Num(learn_frac)),
        ("pipeline", Json::Num(pipeline as f64)),
        ("wire_version", Json::Num(if v2 { 2.0 } else { 1.0 })),
        ("requests", Json::Num(metrics.total as f64)),
        ("learns", Json::Num(metrics.learns as f64)),
        ("infers", Json::Num(infers as f64)),
        ("errors", Json::Num(metrics.errors as f64)),
        ("timeouts", Json::Num(metrics.timeouts as f64)),
        ("accuracy", accuracy_json(correct, infers)),
        ("wall_s", Json::Num(wall_s)),
        ("throughput_rps", Json::Num(metrics.throughput_rps())),
        (
            "latency",
            Json::obj(vec![
                ("mean_s", Json::Num(lat.mean_s)),
                ("p50_s", Json::Num(lat.p50_s)),
                ("p95_s", Json::Num(lat.p95_s)),
                ("p99_s", Json::Num(lat.p99_s)),
            ]),
        ),
        (
            "per_connection",
            Json::Arr(
                conn_reports
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("conn", Json::Num(r.conn as f64)),
                            ("target", Json::Num(r.target as f64)),
                            ("requests", Json::Num(r.requests as f64)),
                            ("errors", Json::Num(r.errors as f64)),
                            ("timeouts", Json::Num(r.timeouts as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "targets",
            Json::Arr(
                per_target
                    .iter()
                    .enumerate()
                    .map(|(ti, (req, err, to))| {
                        Json::obj(vec![
                            (
                                "addr",
                                Json::Str(if ti == 0 {
                                    addr.clone()
                                } else {
                                    replica_addrs[ti - 1].clone()
                                }),
                            ),
                            (
                                "role",
                                Json::Str(
                                    if ti == 0 { "primary" } else { "replica" }.to_string(),
                                ),
                            ),
                            ("requests", Json::Num(*req as f64)),
                            ("errors", Json::Num(*err as f64)),
                            ("timeouts", Json::Num(*to as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("scaling", Json::Arr(scaling)),
        ("models", Json::Obj(models_json)),
        (
            "server",
            // served/wire_errors are process-wide; the knowledge counters
            // are summed over the driven models (per-model values live
            // under "models")
            Json::obj(vec![
                ("served", Json::Num(server_stats.served as f64)),
                ("wire_errors", Json::Num(server_stats.wire_errors as f64)),
                ("learns", Json::Num(total_learns as f64)),
                ("trained_classes", Json::Num(total_classes as f64)),
                ("snapshots", Json::Num(total_snapshots as f64)),
            ]),
        ),
        (
            "snapshot_out",
            if snapshot_paths.is_empty() {
                Json::Null
            } else {
                Json::Arr(snapshot_paths.into_iter().map(Json::Str).collect())
            },
        ),
    ]);
    let out_path = args.str_or("out", "BENCH_serve.json");
    std::fs::write(&out_path, doc.dump())?;
    println!("wrote {out_path}");

    // dual-mode report: written whenever the run drove scenario workloads
    // (even under --payload features — the routing policy picks the mode,
    // the payload shape only picks the wire encoding), so one loadgen run
    // yields both the serving report and the energy/complexity ledger
    let dual: Vec<usize> =
        (0..works.len()).filter(|&i| works[i].scenario.is_some()).collect();
    if !dual.is_empty() {
        let mut cells: BTreeMap<String, Json> = BTreeMap::new();
        let mut dt = Table::new(&[
            "scenario", "infers", "bypass", "normal", "escalations", "energy/query",
        ]);
        let mut policy = String::new();
        for &i in &dual {
            let w = &works[i];
            let sc = w.scenario.as_ref().expect("filtered on scenario");
            let (m, c, n) = &by_model[i];
            let st = &model_stats[i];
            policy = ModePolicy::from_code(st.policy, st.policy_margin).spelling();
            dt.row(&[
                w.label.clone(),
                format!("{}", m.segments_used.len()),
                format!("{}", m.bypass_runs()),
                format!("{}", m.wcfe_runs),
                format!("{}", m.escalations),
                format!("{:.3e} J", m.energy_per_query_j()),
            ]);
            cells.insert(w.label.clone(), dualmode_cell(sc, m, *c, *n, &policy));
        }
        dt.print();
        let dm = Json::obj(vec![
            ("version", Json::Num(1.0)),
            ("source", Json::Str("loadgen".into())),
            ("policy", Json::Str(policy)),
            ("scenarios", Json::Obj(cells)),
        ]);
        let dm_path = args.str_or("dualmode-out", "BENCH_dualmode.json");
        std::fs::write(&dm_path, dm.dump())?;
        println!("wrote {dm_path}");
    }
    Ok(())
}

/// `clo_hdnn loadgen --fleet a,b,c`: drive a replicated fleet through the
/// health-checked failover [`Fleet`](clo_hdnn::serve::Fleet) client
/// instead of raw per-target connections. Learns follow the probed
/// primary (re-discovered by epoch after a promotion), staleness-bounded
/// reads spread over the live followers, and every request carries the
/// fleet's retry budget — so a primary kill mid-run costs at most the
/// budgeted retries, not the stream. Single-threaded by design: the
/// probe/route sequence is then deterministic, which is what the
/// failover-drill CI gate replays. Reports `BENCH_serve.json` (version 4,
/// `"mode": "fleet"`) with a per-target table built from the fleet's own
/// probe views.
fn cmd_loadgen_fleet(args: &Args, addrs: &[String]) -> Result<()> {
    use clo_hdnn::coordinator::ServeMetrics;
    use clo_hdnn::serve::{Fleet, FleetOptions};
    use clo_hdnn::util::json::Json;
    use clo_hdnn::util::stats::Table;

    if addrs.is_empty() {
        anyhow::bail!("--fleet needs at least one host:port entry");
    }
    // one workload: the fleet replicates one model, so a model mix would
    // fight the staleness bound's single learn_seq axis
    let cfg_name = args
        .get("model")
        .or_else(|| args.get("config"))
        .unwrap_or("tiny")
        .to_string();
    let per_class = args.usize_or("per-class", 40)?;
    let (cfg, sc) = builtin_config(&cfg_name).map_err(|e| {
        anyhow::anyhow!(
            "loadgen workloads are hermetic, so --model must be a synthetic \
             config or scenario name: {e}"
        )
    })?;
    let (train, test) = match &sc {
        Some(sc) => sc.images(per_class, 10),
        None => synthetic::blobs(&cfg, per_class, 10, 17),
    };
    let staleness = match args.get("staleness") {
        Some(s) => s
            .parse::<u64>()
            .map_err(|_| anyhow::anyhow!("bad --staleness '{s}' (a learn count)"))?,
        None => u64::MAX,
    };
    let requests = args.usize_or("requests", 200)?;
    let learn_frac = args.f64_or("learn-frac", 0.25)?.clamp(0.0, 1.0);
    let timeout_s = args.f64_or("timeout", 5.0)?;
    let fopts = FleetOptions {
        model: args.get("model").unwrap_or("").to_string(),
        probe_interval: std::time::Duration::from_millis(
            args.usize_or("probe-interval-ms", 100)? as u64,
        ),
        staleness,
        retry_budget: args.usize_or("retries", 3)?.max(1),
        timeout: std::time::Duration::from_secs_f64(timeout_s.max(0.01)),
        ..FleetOptions::default()
    };
    let mut fleet = Fleet::connect(addrs, fopts)?;
    println!(
        "loadgen --fleet [{}]: {requests} requests, learn-frac {learn_frac}, \
         staleness {}, primary {}",
        addrs.join(","),
        if staleness == u64::MAX { "unbounded".to_string() } else { staleness.to_string() },
        fleet.primary().unwrap_or("<none>")
    );

    let t0 = std::time::Instant::now();
    let mut rng = Rng::new(0xF1EE_7000);
    let mut m = ServeMetrics::default();
    let (mut correct, mut infers) = (0usize, 0usize);
    let mut learns_acked = 0u64;
    let mut sent_learn = 0usize;
    let mut sent_infer = 0usize;
    for _ in 0..requests {
        if rng.uniform() < learn_frac {
            let j = sent_learn % train.n;
            sent_learn += 1;
            let q0 = std::time::Instant::now();
            match fleet.learn(train.sample(j), train.label(j)) {
                Ok(()) => {
                    learns_acked += 1;
                    m.record_learn(q0.elapsed().as_secs_f64());
                }
                Err(_) => m.record_error(),
            }
        } else {
            let idx = sent_infer % test.n;
            sent_infer += 1;
            let q0 = std::time::Instant::now();
            match fleet.infer(test.sample(idx)) {
                Ok(r) => {
                    m.record_infer(
                        q0.elapsed().as_secs_f64(),
                        r.segments_used,
                        r.early_exit,
                        r.used_wcfe,
                        r.escalated,
                        r.energy_j,
                    );
                    infers += 1;
                    correct += usize::from(r.class == test.label(idx));
                }
                Err(_) => m.record_error(),
            }
        }
    }
    m.wall_s = t0.elapsed().as_secs_f64();
    let final_stats = fleet.primary_stats().ok();
    let reports = fleet.target_reports();

    let lat = m.latency_summary();
    let mut table = Table::new(&["metric", "value"]);
    table.row(&["requests".into(), format!("{}", m.total)]);
    table.row(&["learns_acked".into(), format!("{learns_acked}")]);
    table.row(&["errors".into(), format!("{}", m.errors)]);
    table.row(&["timeouts".into(), format!("{}", m.timeouts)]);
    table.row(&["accuracy".into(), accuracy_cell(correct, infers)]);
    table.row(&["throughput".into(), format!("{:.1} req/s", m.throughput_rps())]);
    table.row(&["p50".into(), fmt_secs(lat.p50_s)]);
    table.row(&["p99".into(), fmt_secs(lat.p99_s)]);
    table.print();
    let mut tt = Table::new(&["target", "alive", "epoch", "learn_seq", "served", "errors"]);
    for r in &reports {
        tt.row(&[
            r.addr.clone(),
            format!("{}", r.alive),
            format!("{}", r.epoch),
            format!("{}", r.learn_seq),
            format!("{}", r.served),
            format!("{}", r.errors),
        ]);
    }
    tt.print();
    if let Some(st) = &final_stats {
        println!(
            "fleet primary {}: epoch {} | learn_seq {} | {} learns",
            fleet.primary().unwrap_or("<none>"),
            st.epoch,
            st.learn_seq,
            st.learns
        );
    }

    let doc = Json::obj(vec![
        ("version", Json::Num(4.0)),
        ("mode", Json::Str("fleet".into())),
        ("config", Json::Str(cfg_name)),
        ("requests", Json::Num(m.total as f64)),
        ("learns", Json::Num(m.learns as f64)),
        ("learns_acked", Json::Num(learns_acked as f64)),
        ("infers", Json::Num(infers as f64)),
        ("errors", Json::Num(m.errors as f64)),
        ("timeouts", Json::Num(m.timeouts as f64)),
        ("accuracy", accuracy_json(correct, infers)),
        ("learn_frac", Json::Num(learn_frac)),
        ("wall_s", Json::Num(m.wall_s)),
        ("throughput_rps", Json::Num(m.throughput_rps())),
        (
            "latency",
            Json::obj(vec![
                ("mean_s", Json::Num(lat.mean_s)),
                ("p50_s", Json::Num(lat.p50_s)),
                ("p95_s", Json::Num(lat.p95_s)),
                ("p99_s", Json::Num(lat.p99_s)),
            ]),
        ),
        (
            "final_epoch",
            final_stats.as_ref().map(|s| Json::Num(s.epoch as f64)).unwrap_or(Json::Null),
        ),
        (
            "final_learn_seq",
            final_stats
                .as_ref()
                .map(|s| Json::Num(s.learn_seq as f64))
                .unwrap_or(Json::Null),
        ),
        (
            "primary",
            fleet.primary().map(|p| Json::Str(p.to_string())).unwrap_or(Json::Null),
        ),
        (
            "targets",
            Json::Arr(
                reports
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("addr", Json::Str(r.addr.clone())),
                            ("alive", Json::Bool(r.alive)),
                            ("epoch", Json::Num(r.epoch as f64)),
                            ("learn_seq", Json::Num(r.learn_seq as f64)),
                            ("served", Json::Num(r.served as f64)),
                            ("errors", Json::Num(r.errors as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let out_path = args.str_or("out", "BENCH_serve.json");
    std::fs::write(&out_path, doc.dump())?;
    println!("wrote {out_path}");
    Ok(())
}

/// `clo_hdnn bench`: the packed-vs-scalar classifier perf harness. Runs
/// encode / full-search / progressive sweeps on the synthetic configs
/// through the NativeBackend, prints the stage tables, and writes a
/// machine-readable `BENCH_classifier.json` (samples/s, ns/query, packed
/// speedup, complexity saving per tau) so the repo carries a perf
/// trajectory. `--quick` shrinks the sweep for CI smoke runs.
fn cmd_bench(args: &Args) -> Result<()> {
    use clo_hdnn::util::json::Json;
    use std::collections::BTreeMap;

    let quick = args.flag("quick");
    let cfg_arg = args.str_or("config", "isolet");
    let names: Vec<String> = if cfg_arg == "all" {
        synthetic::names().iter().map(|s| s.to_string()).collect()
    } else {
        vec![cfg_arg]
    };
    let out_path = args.str_or("out", "BENCH_classifier.json");
    let (warmup, iters) = if quick { (1, 5) } else { (3, 25) };
    let bench = clo_hdnn::util::stats::Bench::new(
        args.usize_or("warmup", warmup)?,
        args.usize_or("iters", iters)?,
    );
    let taus: Vec<f32> = args
        .str_or("taus", if quick { "0.5" } else { "0.1,0.5,1.0,2.0" })
        .split(',')
        .map(|t| t.trim().parse::<f32>().map_err(|_| anyhow::anyhow!("bad tau '{t}'")))
        .collect::<Result<_>>()?;

    // which SIMD level the dispatcher actually selected for this run — the
    // bench gate compares like against like by keying baselines on it
    let kernel = clo_hdnn::hdc::simd::active().name();

    let mut reports: BTreeMap<String, Json> = BTreeMap::new();
    for name in &names {
        reports.insert(name.clone(), bench_config(name, &bench, &taus, quick, args)?);
    }
    let doc = Json::obj(vec![
        ("version", Json::Num(1.0)),
        ("quick", Json::Bool(quick)),
        ("kernel", Json::Str(kernel.to_string())),
        ("warmup", Json::Num(bench.warmup as f64)),
        ("iters", Json::Num(bench.iters as f64)),
        ("configs", Json::Obj(reports)),
    ]);
    std::fs::write(&out_path, doc.dump())?;
    println!("\nwrote {out_path}");

    // the encoder engine harness: scalar vs sign-GEMM vs sign-GEMM+pool
    // over growing row counts -> BENCH_encoder.json
    let enc_out = args.str_or("encoder-out", "BENCH_encoder.json");
    let mut enc_reports: BTreeMap<String, Json> = BTreeMap::new();
    for name in &names {
        enc_reports.insert(name.clone(), bench_encoder(name, &bench, quick, args)?);
    }
    let enc_doc = Json::obj(vec![
        ("version", Json::Num(1.0)),
        ("quick", Json::Bool(quick)),
        ("kernel", Json::Str(kernel.to_string())),
        ("warmup", Json::Num(bench.warmup as f64)),
        ("iters", Json::Num(bench.iters as f64)),
        ("configs", Json::Obj(enc_reports)),
    ]);
    std::fs::write(&enc_out, enc_doc.dump())?;
    println!("wrote {enc_out}");

    // the dual-mode scenario matrix -> BENCH_dualmode.json: every cell
    // served end to end through a local coordinator under the Confidence
    // policy, with energy + FE-complexity-savings accounting
    bench_dualmode(args, quick)?;
    Ok(())
}

/// `bench`'s dual-mode phase: drive every scenario-matrix cell through a
/// local coordinator under the Confidence policy (`--margin`, default
/// 2000 — raw top-2 distance units, see README's tuning recipe) and write
/// `BENCH_dualmode.json` in the same cell shape `loadgen` emits. The
/// store is taught in pixel space (`Payload::Learn` bypasses routing), so
/// bypass answers are grounded and escalated re-runs hit the same store
/// deterministically; the easy/hard axis then shows up as the bypass
/// fraction and the per-query energy spread.
fn bench_dualmode(args: &Args, quick: bool) -> Result<()> {
    use clo_hdnn::coordinator::ServeMetrics;
    use clo_hdnn::util::json::Json;
    use clo_hdnn::util::stats::Table;
    use std::collections::BTreeMap;

    let margin = args.f64_or("margin", 2000.0)? as f32;
    let policy = ModePolicy::Confidence { margin };
    let (learn_pc, test_pc) = if quick { (6, 4) } else { (12, 10) };
    println!("\n== bench-dualmode: scenario matrix under {} ==", policy.spelling());
    let mut cells: BTreeMap<String, Json> = BTreeMap::new();
    let mut table = Table::new(&[
        "scenario",
        "infers",
        "bypass",
        "escalations",
        "acc",
        "energy/query",
        "ns/query",
    ]);
    for sc in scenario::matrix() {
        let mut opts = CoordinatorOptions::software(sc.cfg.clone());
        opts.mode_policy = policy;
        opts.wcfe = scenario_wcfe(&sc);
        opts.threads = threads_arg(args)?;
        let coord = Coordinator::start(opts)?;
        let (train, test) = sc.images(learn_pc, test_pc);
        for i in 0..train.n {
            let r = coord.call(Payload::Learn(train.sample(i).to_vec(), train.label(i)))?;
            if let Some(e) = r.error {
                anyhow::bail!("dual-mode bench learn failed on {}: {e}", sc.name);
            }
        }
        let mut m = ServeMetrics::default();
        let mut correct = 0usize;
        let t0 = std::time::Instant::now();
        for i in 0..test.n {
            let r = coord.call(Payload::Image(test.sample(i).to_vec()))?;
            if let Some(e) = r.error {
                anyhow::bail!("dual-mode bench infer failed on {}: {e}", sc.name);
            }
            m.record_infer(
                r.latency_s,
                r.segments_used,
                r.early_exit,
                r.used_wcfe,
                r.escalated,
                r.energy_j,
            );
            correct += usize::from(r.class == Some(test.label(i)));
        }
        m.wall_s = t0.elapsed().as_secs_f64();
        table.row(&[
            sc.name.clone(),
            format!("{}", test.n),
            format!("{:.0}%", 100.0 * m.bypass_fraction()),
            format!("{}", m.escalations),
            accuracy_cell(correct, test.n),
            format!("{:.3e} J", m.energy_per_query_j()),
            format!("{:.0}", m.mean_latency() * 1e9),
        ]);
        cells.insert(
            sc.name.clone(),
            dualmode_cell(&sc, &m, correct, test.n, &policy.spelling()),
        );
    }
    table.print();
    let doc = Json::obj(vec![
        ("version", Json::Num(1.0)),
        ("source", Json::Str("bench".into())),
        ("policy", Json::Str(policy.spelling())),
        ("scenarios", Json::Obj(cells)),
    ]);
    let path = args.str_or("dualmode-out", "BENCH_dualmode.json");
    std::fs::write(&path, doc.dump())?;
    println!("wrote {path}");
    Ok(())
}

/// One config's encoder-engine rows: per row count, median ns/encode for
/// the scalar kernel, the sign-GEMM kernel, and the pooled batch engine
/// (whose number includes the packed-segment emission).
fn bench_encoder(
    name: &str,
    bench: &clo_hdnn::util::stats::Bench,
    quick: bool,
    args: &Args,
) -> Result<clo_hdnn::util::json::Json> {
    use clo_hdnn::hdc::{EncodeKernel, HdBackend, SoftwareEncoder};
    use clo_hdnn::util::json::Json;
    use clo_hdnn::util::pool::WorkerPool;
    use clo_hdnn::util::stats::Table;
    use std::hint::black_box;

    let cfg = synthetic::config(name)?;
    let feat = cfg.features();
    let (train, _test) = synthetic::blobs(&cfg, 8, 2, 17);
    let mut enc = SoftwareEncoder::random(cfg.clone(), 7);
    let calib_n = train.n.min(8);
    let mut calib = Vec::with_capacity(calib_n * feat);
    for i in 0..calib_n {
        calib.extend(quantize_features(train.sample(i), cfg.scale_x));
    }
    enc.calibrate(&calib, calib_n);

    let pool = WorkerPool::new(threads_arg(args)?);
    let row_counts: &[usize] = if quick { &[1, 8] } else { &[1, 8, 32] };
    let max_rows = *row_counts.last().unwrap();
    let mut input = Vec::with_capacity(max_rows * feat);
    let mut i = 0usize;
    while input.len() < max_rows * feat {
        input.extend(quantize_features(train.sample(i % train.n), cfg.scale_x));
        i += 1;
    }

    println!(
        "\n== bench-encoder {name}: F={feat} D={} ({} worker threads) ==",
        cfg.dim(),
        pool.threads()
    );
    let mut table = Table::new(&[
        "rows",
        "scalar ns/enc",
        "sign-GEMM ns/enc",
        "pool ns/enc",
        "sign-GEMM speedup",
        "pool speedup",
    ]);
    let mut rows_json = Vec::new();
    let mut speedup_b1 = 0.0f64;
    for &rows in row_counts {
        let xs = &input[..rows * feat];
        enc.set_kernel(EncodeKernel::Scalar);
        let s_scalar = bench.run(|| black_box(enc.encode_full(black_box(xs), rows).unwrap()));
        enc.set_kernel(EncodeKernel::SignGemm);
        let s_gemm = bench.run(|| black_box(enc.encode_full(black_box(xs), rows).unwrap()));
        let s_pool =
            bench.run(|| black_box(enc.encode_batch(black_box(xs), rows, Some(&pool)).unwrap()));
        let per = |median: f64| median * 1e9 / rows as f64;
        let gemm_speedup = per(s_scalar.median) / per(s_gemm.median);
        let pool_speedup = per(s_scalar.median) / per(s_pool.median);
        if rows == 1 {
            speedup_b1 = gemm_speedup;
        }
        table.row(&[
            format!("{rows}"),
            format!("{:.0}", per(s_scalar.median)),
            format!("{:.0}", per(s_gemm.median)),
            format!("{:.0}", per(s_pool.median)),
            format!("{gemm_speedup:.2}x"),
            format!("{pool_speedup:.2}x"),
        ]);
        rows_json.push(Json::obj(vec![
            ("rows", Json::Num(rows as f64)),
            ("scalar_ns_per_encode", Json::Num(per(s_scalar.median))),
            ("signgemm_ns_per_encode", Json::Num(per(s_gemm.median))),
            ("signgemm_pool_ns_per_encode", Json::Num(per(s_pool.median))),
            ("scalar_samples_per_s", Json::Num(rows as f64 / s_scalar.median)),
            ("signgemm_samples_per_s", Json::Num(rows as f64 / s_gemm.median)),
            (
                "signgemm_pool_samples_per_s",
                Json::Num(rows as f64 / s_pool.median),
            ),
            ("signgemm_speedup", Json::Num(gemm_speedup)),
            ("signgemm_pool_speedup", Json::Num(pool_speedup)),
        ]));
    }
    table.print();
    println!("single-row sign-GEMM speedup: {speedup_b1:.2}x");

    Ok(Json::obj(vec![
        ("features", Json::Num(feat as f64)),
        ("dim", Json::Num(cfg.dim() as f64)),
        ("segments", Json::Num(cfg.segments as f64)),
        ("pool_threads", Json::Num(pool.threads() as f64)),
        ("signgemm_speedup_b1", Json::Num(speedup_b1)),
        ("rows", Json::Arr(rows_json)),
    ]))
}

/// One config's worth of bench rows (and the human-readable tables).
fn bench_config(
    name: &str,
    bench: &clo_hdnn::util::stats::Bench,
    taus: &[f32],
    quick: bool,
    args: &Args,
) -> Result<clo_hdnn::util::json::Json> {
    use clo_hdnn::hdc::{distance, packed};
    use clo_hdnn::util::json::Json;
    use clo_hdnn::util::stats::Table;
    use std::hint::black_box;

    let cfg = synthetic::config(name)?;
    let per_class = args.usize_or("per-class", if quick { 6 } else { 20 })?;
    let (train, test) = synthetic::blobs(&cfg, per_class, 4, 17);
    let backend = native_backend(&cfg, None, &train, args)?;
    let mut cl = HdClassifier::new(Box::new(backend), ProgressiveSearch::default());
    Trainer { retrain_epochs: 0 }.train_all(&mut cl, &train)?;

    let n_q = args.usize_or("queries", if quick { 8 } else { 32 })?.min(test.n).max(1);
    let queries: Vec<Vec<f32>> = (0..n_q).map(|i| test.sample(i).to_vec()).collect();
    let (d, classes) = (cfg.dim(), cfg.classes);

    // pre-encoded operands for the kernel-level full-D search comparison
    let mut qhvs: Vec<Vec<f32>> = Vec::with_capacity(n_q);
    for q in &queries {
        qhvs.push(cl.encode(q)?);
    }
    let qhvs_packed: Vec<Vec<u64>> = qhvs.iter().map(|q| packed::pack_signs(q)).collect();
    let mut chvs_full = Vec::with_capacity(classes * d);
    for c in 0..classes {
        chvs_full.extend(cl.store.class_hv(c));
    }
    let chvs_packed = packed::pack_rows(&chvs_full, classes, d)?;

    println!(
        "\n== bench {name}: F={} D={} classes={} segments={} ({} queries) ==",
        cfg.features(),
        d,
        classes,
        cfg.segments,
        n_q
    );
    let ns_per_q = |median: f64| median * 1e9 / n_q as f64;

    let s_enc = bench.run(|| cl.encode(black_box(&queries[0])).unwrap());
    let encode = Json::obj(vec![
        ("ns_per_query", Json::Num(s_enc.median * 1e9)),
        ("samples_per_s", Json::Num(1.0 / s_enc.median)),
    ]);

    let s_scalar = bench.run(|| {
        for q in &qhvs {
            black_box(distance::l1_batch(q, 1, &chvs_full, classes, d).unwrap());
        }
    });
    let s_packed = bench.run(|| {
        for q in &qhvs_packed {
            black_box(packed::hamming_search(q, 1, &chvs_packed, classes, d).unwrap());
        }
    });
    let speedup = ns_per_q(s_scalar.median) / ns_per_q(s_packed.median);

    let mut t = Table::new(&["stage", "ns/query", "queries/s", "notes"]);
    t.row(&[
        "encode full (native b1)".into(),
        format!("{:.0}", s_enc.median * 1e9),
        format!("{:.0}", 1.0 / s_enc.median),
        format!("{} segments", cfg.segments),
    ]);
    t.row(&[
        "search full-D (scalar L1)".into(),
        format!("{:.0}", ns_per_q(s_scalar.median)),
        format!("{:.0}", n_q as f64 / s_scalar.median),
        format!("{classes} CHVs x {d} f32"),
    ]);
    t.row(&[
        "search full-D (packed INT1)".into(),
        format!("{:.0}", ns_per_q(s_packed.median)),
        format!("{:.0}", n_q as f64 / s_packed.median),
        format!("XOR+popcount, {} words, {speedup:.1}x", packed::words_for(d)),
    ]);
    t.print();

    let search = Json::obj(vec![
        (
            "scalar",
            Json::obj(vec![
                ("ns_per_query", Json::Num(ns_per_q(s_scalar.median))),
                ("queries_per_s", Json::Num(n_q as f64 / s_scalar.median)),
            ]),
        ),
        (
            "packed",
            Json::obj(vec![
                ("ns_per_query", Json::Num(ns_per_q(s_packed.median))),
                ("queries_per_s", Json::Num(n_q as f64 / s_packed.median)),
            ]),
        ),
        ("speedup", Json::Num(speedup)),
    ]);

    // progressive sweep: end-to-end classify per tau, both kernels
    let mut t2 = Table::new(&["tau", "mode", "ns/query", "segs", "saving", "acc"]);
    let mut prog_rows = Vec::new();
    for &tau in taus {
        for mode in [SearchMode::L1Int8, SearchMode::HammingPacked] {
            cl.policy = ProgressiveSearch { tau, min_segments: 1, mode };
            let s = bench.run(|| {
                for q in &queries {
                    black_box(cl.classify(black_box(q)).unwrap());
                }
            });
            let report = cl.evaluate(
                queries.iter().enumerate().map(|(i, q)| (q.clone(), test.label(i))),
            )?;
            let mode_name = match mode {
                SearchMode::L1Int8 => "l1int8",
                SearchMode::HammingPacked => "hamming_packed",
            };
            t2.row(&[
                format!("{tau}"),
                mode_name.into(),
                format!("{:.0}", ns_per_q(s.median)),
                format!("{:.2}/{}", report.mean_segments, cfg.segments),
                format!("{:.1}%", report.complexity_reduction() * 100.0),
                format!("{:.3}", report.accuracy),
            ]);
            prog_rows.push(Json::obj(vec![
                ("tau", Json::Num(tau as f64)),
                ("mode", Json::Str(mode_name.into())),
                ("ns_per_query", Json::Num(ns_per_q(s.median))),
                ("samples_per_s", Json::Num(n_q as f64 / s.median)),
                ("mean_segments", Json::Num(report.mean_segments)),
                ("complexity_saving", Json::Num(report.complexity_reduction())),
                ("early_exit_rate", Json::Num(report.early_exit_rate)),
                ("accuracy", Json::Num(report.accuracy)),
            ]));
        }
    }
    t2.print();

    Ok(Json::obj(vec![
        ("features", Json::Num(cfg.features() as f64)),
        ("dim", Json::Num(d as f64)),
        ("classes", Json::Num(classes as f64)),
        ("segments", Json::Num(cfg.segments as f64)),
        ("queries", Json::Num(n_q as f64)),
        ("encode", encode),
        ("search", search),
        ("progressive", Json::Arr(prog_rows)),
    ]))
}

fn cmd_asm(args: &Args) -> Result<()> {
    let path = args
        .positional()
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("asm needs a file path"))?;
    let src = std::fs::read_to_string(path)?;
    let prog = clo_hdnn::isa::assemble(&src)?;
    println!("{} instructions, bytecode words:", prog.len());
    for (i, w) in prog.bytecode().iter().enumerate() {
        println!("  [{i:3}] {w:#07x}");
    }
    println!("\ndisassembly:\n{}", prog.disassemble());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_live_slot_skips_dead_targets_and_respects_the_learn_range() {
        let live = [true, true, false, true];
        // round-robin over all four, walking past the dead slot
        assert_eq!(pick_live_slot(&live, 4, 0), Some(0));
        assert_eq!(pick_live_slot(&live, 4, 2), Some(3));
        assert_eq!(pick_live_slot(&live, 4, 3), Some(3));
        // a learn confined to the primary range never reaches slot 3
        assert_eq!(pick_live_slot(&live, 2, 1), Some(1));
        // every slot in range dead -> no target, even with a live one
        // outside the range
        assert_eq!(pick_live_slot(&[false, false, true], 2, 0), None);
        assert_eq!(pick_live_slot(&[false, false], 2, 1), None);
        assert_eq!(pick_live_slot(&[], 4, 0), None);
        assert_eq!(pick_live_slot(&[true], 0, 0), None);
    }

    #[test]
    fn promote_on_parses_down_detector_specs() {
        assert_eq!(
            parse_promote_on("down:250").unwrap(),
            std::time::Duration::from_millis(250)
        );
        // zero clamps to the minimum the monitor loop can act on
        assert_eq!(
            parse_promote_on("down:0").unwrap(),
            std::time::Duration::from_millis(1)
        );
        assert!(parse_promote_on("down:").is_err());
        assert!(parse_promote_on("up:5").is_err());
        assert!(parse_promote_on("250").is_err());
    }
}
