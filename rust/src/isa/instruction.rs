//! The unified 20-bit instruction word: 4-bit opcode | 16-bit operand.

use crate::isa::opcode::Opcode;
use anyhow::{anyhow, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Instr {
    pub op: Opcode,
    pub operand: u16,
}

impl Instr {
    pub fn new(op: Opcode, operand: u16) -> Instr {
        Instr { op, operand }
    }

    /// Encode into the low 20 bits of a u32 (the chip's bytecode word).
    pub fn encode(&self) -> u32 {
        ((self.op as u32) << 16) | self.operand as u32
    }

    pub fn decode(word: u32) -> Result<Instr> {
        if word >> 20 != 0 {
            return Err(anyhow!("instruction word {word:#x} exceeds 20 bits"));
        }
        let op = Opcode::from_bits((word >> 16) as u8)
            .ok_or_else(|| anyhow!("bad opcode in {word:#x}"))?;
        Ok(Instr { op, operand: (word & 0xFFFF) as u16 })
    }

    /// `cfg` packs (reg << 12) | value into the operand.
    pub fn cfg(reg: crate::isa::opcode::CfgReg, value: u16) -> Instr {
        assert!(value < (1 << 12), "cfg value must fit 12 bits");
        Instr::new(Opcode::Cfg, ((reg as u16) << 12) | value)
    }

    pub fn asm(&self) -> String {
        match self.op {
            Opcode::Cfg => {
                if let Some(reg) =
                    crate::isa::opcode::CfgReg::from_bits((self.operand >> 12) as u8)
                {
                    return format!("cfg {} {}", reg.name(), self.operand & 0xFFF);
                }
                format!("cfg? {}", self.operand)
            }
            Opcode::Nop | Opcode::Halt => self.op.mnemonic().to_string(),
            _ => format!("{} {}", self.op.mnemonic(), self.operand),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::opcode::CfgReg;
    use crate::util::prop::forall;

    #[test]
    fn encode_layout() {
        let i = Instr::new(Opcode::Enc, 0x0123);
        assert_eq!(i.encode(), 0x8_0123);
        let j = Instr::new(Opcode::Halt, 0);
        assert_eq!(j.encode(), 0x1_0000);
    }

    #[test]
    fn prop_encode_decode_roundtrip() {
        forall(200, 0x15A, |rng| {
            let op = Opcode::all()[rng.below(16)];
            let operand = (rng.next_u64() & 0xFFFF) as u16;
            let i = Instr::new(op, operand);
            let back = Instr::decode(i.encode()).unwrap();
            assert_eq!(back, i);
            assert!(i.encode() < (1 << 20), "20-bit overflow");
        });
    }

    #[test]
    fn decode_rejects_wide_words() {
        assert!(Instr::decode(1 << 20).is_err());
    }

    #[test]
    fn cfg_packing() {
        let i = Instr::cfg(CfgReg::Mode, 1);
        assert_eq!(i.operand >> 12, 0x3);
        assert_eq!(i.operand & 0xFFF, 1);
    }

    #[test]
    #[should_panic]
    fn cfg_value_overflow_panics() {
        let _ = Instr::cfg(CfgReg::Classes, 4096);
    }
}
