//! Customized ISA + programming model (Fig.8).
//!
//! Unified 20-bit instruction format: 4-bit opcode + 16-bit operand, two
//! instruction classes (memory / arithmetic) covering the WCFE, the HD
//! module and the global FIFO. The chip is programmed through C/C++
//! intrinsics compiled to this bytecode; here [`intrinsics`] is the Rust
//! twin of that header, [`assembler`] the textual route, and
//! [`interpreter`] the execution model driving a [`interpreter::Device`].

pub mod assembler;
pub mod instruction;
pub mod interpreter;
pub mod intrinsics;
pub mod opcode;
pub mod program;

pub use assembler::assemble;
pub use instruction::Instr;
pub use interpreter::{Device, Interpreter, MachineState};
pub use opcode::Opcode;
pub use program::Program;
