//! Program container: ordered instructions + label map, bytecode emission
//! and disassembly.

use crate::isa::instruction::Instr;
use crate::Result;
use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Program {
    pub instrs: Vec<Instr>,
    pub labels: BTreeMap<String, usize>,
}

impl Program {
    pub fn new(instrs: Vec<Instr>) -> Program {
        Program { instrs, labels: BTreeMap::new() }
    }

    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Emit the 20-bit bytecode words (one u32 per instruction, as the
    /// inline-assembly operator would).
    pub fn bytecode(&self) -> Vec<u32> {
        self.instrs.iter().map(Instr::encode).collect()
    }

    /// Rebuild a program from bytecode words.
    pub fn from_bytecode(words: &[u32]) -> Result<Program> {
        let instrs = words
            .iter()
            .map(|&w| Instr::decode(w))
            .collect::<Result<Vec<_>>>()?;
        Ok(Program::new(instrs))
    }

    /// Textual disassembly with label annotations.
    pub fn disassemble(&self) -> String {
        let rev: BTreeMap<usize, &String> =
            self.labels.iter().map(|(k, v)| (*v, k)).collect();
        let mut out = String::new();
        for (pc, i) in self.instrs.iter().enumerate() {
            if let Some(l) = rev.get(&pc) {
                out.push_str(&format!("{l}:\n"));
            }
            out.push_str(&format!("  {:<20} ; pc={pc} word={:#07x}\n", i.asm(), i.encode()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::opcode::Opcode;

    #[test]
    fn bytecode_roundtrip() {
        let p = Program::new(vec![
            Instr::new(Opcode::Ldf, 0),
            Instr::new(Opcode::Enc, 3),
            Instr::new(Opcode::Halt, 0),
        ]);
        let bc = p.bytecode();
        assert_eq!(bc.len(), 3);
        let back = Program::from_bytecode(&bc).unwrap();
        assert_eq!(back.instrs, p.instrs);
    }

    #[test]
    fn disassembly_contains_mnemonics_and_labels() {
        let mut p = Program::new(vec![
            Instr::new(Opcode::Enc, 0),
            Instr::new(Opcode::Bnz, 0),
        ]);
        p.labels.insert("loop".into(), 0);
        let d = p.disassemble();
        assert!(d.contains("loop:"));
        assert!(d.contains("enc 0"));
        assert!(d.contains("bnz 0"));
    }

    #[test]
    fn from_bytecode_rejects_garbage() {
        assert!(Program::from_bytecode(&[u32::MAX]).is_err());
    }
}
