//! Execution model for the 20-bit ISA.
//!
//! The interpreter owns architectural state (pc, config registers, the exit
//! flag, cycle/instruction counters); functional semantics of the
//! arithmetic/memory instructions are delegated to a [`Device`] — the chip
//! simulator in production ([`crate::sim`]), or a mock in tests. This split
//! mirrors the chip: the sequencer is tiny, the datapath does the work.

use crate::isa::instruction::Instr;
use crate::isa::opcode::{CfgReg, Opcode};
use crate::isa::program::Program;
use crate::Result;
use anyhow::bail;

/// Architectural state visible to programs.
#[derive(Clone, Debug, Default)]
pub struct MachineState {
    pub pc: usize,
    pub halted: bool,
    /// confidence-compare result: true = margin exceeded, search may exit
    pub exit_flag: bool,
    pub classes: u16,
    pub min_seg: u16,
    pub qbits: u16,
    /// 0 = bypass mode, 1 = normal (WCFE) mode
    pub mode: u16,
    pub train_mode: u16,
    pub instructions_retired: u64,
}

/// Datapath hooks the interpreter calls into.
pub trait Device {
    /// memory-class ops; return value is the cycle cost of the operation.
    fn load_weights(&mut self, tile: u16) -> Result<u64>;
    fn load_features(&mut self, slot: u16) -> Result<u64>;
    fn store(&mut self, slot: u16) -> Result<u64>;
    fn fifo_push(&mut self, words: u16) -> Result<u64>;
    fn fifo_pop(&mut self, words: u16) -> Result<u64>;
    /// arithmetic-class ops
    fn encode_segment(&mut self, seg: u16) -> Result<u64>;
    fn search_segment(&mut self, seg: u16) -> Result<u64>;
    fn train_update(&mut self, class: u16) -> Result<u64>;
    fn conv_layer(&mut self, layer: u16) -> Result<u64>;
    /// margin test; returns (margin_exceeded, cycles)
    fn compare_margin(&mut self, tau_q8_8: u16, state: &MachineState) -> Result<(bool, u64)>;
    fn quantize(&mut self, bits: u16) -> Result<u64>;
}

/// Interpreter outcome.
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    pub cycles: u64,
    pub instructions: u64,
    pub state: MachineState,
}

pub struct Interpreter {
    /// hard cap against runaway programs (branch loops)
    pub max_instructions: u64,
}

impl Default for Interpreter {
    fn default() -> Self {
        Interpreter { max_instructions: 1_000_000 }
    }
}

impl Interpreter {
    pub fn run(&self, program: &Program, device: &mut dyn Device) -> Result<RunReport> {
        let mut st = MachineState::default();
        let mut cycles = 0u64;
        while !st.halted {
            if st.pc >= program.instrs.len() {
                bail!("pc {} fell off the program (missing halt?)", st.pc);
            }
            if st.instructions_retired >= self.max_instructions {
                bail!("instruction budget exceeded (runaway loop?)");
            }
            let instr = program.instrs[st.pc];
            let mut next_pc = st.pc + 1;
            let cost = self.step(instr, &mut st, &mut next_pc, device)?;
            cycles += cost.max(1); // every instruction costs >= 1 cycle
            st.instructions_retired += 1;
            st.pc = next_pc;
        }
        Ok(RunReport { cycles, instructions: st.instructions_retired, state: st })
    }

    fn step(
        &self,
        instr: Instr,
        st: &mut MachineState,
        next_pc: &mut usize,
        device: &mut dyn Device,
    ) -> Result<u64> {
        use Opcode::*;
        Ok(match instr.op {
            Nop => 0,
            Halt => {
                st.halted = true;
                0
            }
            Cfg => {
                let reg = CfgReg::from_bits((instr.operand >> 12) as u8)
                    .ok_or_else(|| anyhow::anyhow!("bad cfg register"))?;
                let val = instr.operand & 0xFFF;
                match reg {
                    CfgReg::Classes => st.classes = val,
                    CfgReg::MinSeg => st.min_seg = val,
                    CfgReg::QBits => st.qbits = val,
                    CfgReg::Mode => st.mode = val,
                    CfgReg::TrainMode => st.train_mode = val,
                }
                0
            }
            Ldw => device.load_weights(instr.operand)?,
            Ldf => device.load_features(instr.operand)?,
            Sto => device.store(instr.operand)?,
            Push => device.fifo_push(instr.operand)?,
            Pop => device.fifo_pop(instr.operand)?,
            Enc => device.encode_segment(instr.operand)?,
            Srch => device.search_segment(instr.operand)?,
            Upd => device.train_update(instr.operand)?,
            Conv => device.conv_layer(instr.operand)?,
            Cmp => {
                let (exceeded, c) = device.compare_margin(instr.operand, st)?;
                st.exit_flag = exceeded;
                c
            }
            Qnt => device.quantize(instr.operand)?,
            Bnz => {
                if !st.exit_flag {
                    *next_pc = instr.operand as usize;
                }
                0
            }
            Jmp => {
                *next_pc = instr.operand as usize;
                0
            }
        })
    }
}

/// A scripted mock device for interpreter tests: fixed cycle costs, margin
/// exceeds after `exit_after` compares; records the call sequence.
#[derive(Debug, Default)]
pub struct MockDevice {
    pub calls: Vec<String>,
    pub exit_after: usize,
    pub compares: usize,
}

impl Device for MockDevice {
    fn load_weights(&mut self, t: u16) -> Result<u64> {
        self.calls.push(format!("ldw {t}"));
        Ok(4)
    }
    fn load_features(&mut self, s: u16) -> Result<u64> {
        self.calls.push(format!("ldf {s}"));
        Ok(8)
    }
    fn store(&mut self, s: u16) -> Result<u64> {
        self.calls.push(format!("sto {s}"));
        Ok(2)
    }
    fn fifo_push(&mut self, w: u16) -> Result<u64> {
        self.calls.push(format!("push {w}"));
        Ok(w as u64)
    }
    fn fifo_pop(&mut self, w: u16) -> Result<u64> {
        self.calls.push(format!("pop {w}"));
        Ok(w as u64)
    }
    fn encode_segment(&mut self, s: u16) -> Result<u64> {
        self.calls.push(format!("enc {s}"));
        Ok(16)
    }
    fn search_segment(&mut self, s: u16) -> Result<u64> {
        self.calls.push(format!("srch {s}"));
        Ok(12)
    }
    fn train_update(&mut self, c: u16) -> Result<u64> {
        self.calls.push(format!("upd {c}"));
        Ok(6)
    }
    fn conv_layer(&mut self, l: u16) -> Result<u64> {
        self.calls.push(format!("conv {l}"));
        Ok(100)
    }
    fn compare_margin(&mut self, _tau: u16, _st: &MachineState) -> Result<(bool, u64)> {
        self.compares += 1;
        self.calls.push(format!("cmp#{}", self.compares));
        Ok((self.compares >= self.exit_after, 1))
    }
    fn quantize(&mut self, b: u16) -> Result<u64> {
        self.calls.push(format!("qnt {b}"));
        Ok(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::assembler::assemble;

    #[test]
    fn straight_line_program() {
        let p = assemble("ldf 0\nenc 0\nsrch 0\nsto 1\nhalt").unwrap();
        let mut dev = MockDevice { exit_after: 1, ..Default::default() };
        let r = Interpreter::default().run(&p, &mut dev).unwrap();
        assert_eq!(dev.calls, vec!["ldf 0", "enc 0", "srch 0", "sto 1"]);
        assert_eq!(r.instructions, 5);
        assert_eq!(r.cycles, 8 + 16 + 12 + 2 + 1); // halt costs 1 (min)
    }

    #[test]
    fn progressive_loop_exits_via_flag() {
        // the Fig.4 control flow: encode/search segments until cmp sets the
        // exit flag, then fall through to sto/halt.
        let src = r#"
            ldf 0
            loop:
              enc 0
              srch 0
              cmp 128
              bnz loop
            sto 0
            halt
        "#;
        let p = assemble(src).unwrap();
        let mut dev = MockDevice { exit_after: 3, ..Default::default() };
        let r = Interpreter::default().run(&p, &mut dev).unwrap();
        let encs = dev.calls.iter().filter(|c| c.starts_with("enc")).count();
        assert_eq!(encs, 3, "loop should run exactly 3 iterations");
        assert!(r.state.exit_flag);
        assert!(r.state.halted);
    }

    #[test]
    fn cfg_registers_set_state() {
        let p = assemble("cfg classes 26\ncfg mode 1\ncfg qbits 8\nhalt").unwrap();
        let mut dev = MockDevice { exit_after: 1, ..Default::default() };
        let r = Interpreter::default().run(&p, &mut dev).unwrap();
        assert_eq!(r.state.classes, 26);
        assert_eq!(r.state.mode, 1);
        assert_eq!(r.state.qbits, 8);
    }

    #[test]
    fn runaway_loop_is_caught() {
        let p = assemble("loop:\njmp loop").unwrap();
        let mut dev = MockDevice::default();
        let itp = Interpreter { max_instructions: 1000 };
        assert!(itp.run(&p, &mut dev).is_err());
    }

    #[test]
    fn missing_halt_is_error() {
        let p = assemble("nop").unwrap();
        let mut dev = MockDevice::default();
        assert!(Interpreter::default().run(&p, &mut dev).is_err());
    }
}
