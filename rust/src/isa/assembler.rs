//! Two-pass textual assembler for the 20-bit ISA.
//!
//! Syntax (one instruction per line):
//! ```text
//! ; comment
//! start:            ; label
//!   cfg classes 26  ; cfg takes a register name + 12-bit value
//!   ldf 0
//! loop:
//!   enc 3
//!   srch 3
//!   cmp 128
//!   bnz loop        ; branch targets may be labels or absolute pcs
//!   halt
//! ```

use crate::isa::instruction::Instr;
use crate::isa::opcode::{CfgReg, Opcode};
use crate::isa::program::Program;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

fn cfg_reg(name: &str) -> Option<CfgReg> {
    Some(match name {
        "classes" => CfgReg::Classes,
        "minseg" => CfgReg::MinSeg,
        "qbits" => CfgReg::QBits,
        "mode" => CfgReg::Mode,
        "trainmode" => CfgReg::TrainMode,
        _ => return None,
    })
}

pub fn assemble(src: &str) -> Result<Program> {
    // pass 1: collect labels
    let mut labels: BTreeMap<String, usize> = BTreeMap::new();
    let mut pc = 0usize;
    for (lineno, raw) in src.lines().enumerate() {
        let line = strip(raw);
        if line.is_empty() {
            continue;
        }
        if let Some(label) = line.strip_suffix(':') {
            let label = label.trim();
            if label.is_empty() || labels.insert(label.to_string(), pc).is_some() {
                bail!("line {}: bad or duplicate label '{label}'", lineno + 1);
            }
        } else {
            pc += 1;
        }
    }
    // pass 2: encode
    let mut instrs = Vec::with_capacity(pc);
    for (lineno, raw) in src.lines().enumerate() {
        let line = strip(raw);
        if line.is_empty() || line.ends_with(':') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let mnemonic = parts.next().unwrap();
        let op = Opcode::from_mnemonic(mnemonic)
            .ok_or_else(|| anyhow!("line {}: unknown mnemonic '{mnemonic}'", lineno + 1))?;
        let instr = match op {
            Opcode::Cfg => {
                let reg = parts
                    .next()
                    .and_then(cfg_reg)
                    .ok_or_else(|| anyhow!("line {}: cfg needs a register name", lineno + 1))?;
                let val: u16 = parts
                    .next()
                    .ok_or_else(|| anyhow!("line {}: cfg needs a value", lineno + 1))?
                    .parse()
                    .map_err(|_| anyhow!("line {}: bad cfg value", lineno + 1))?;
                if val >= 1 << 12 {
                    bail!("line {}: cfg value must fit 12 bits", lineno + 1);
                }
                Instr::cfg(reg, val)
            }
            Opcode::Bnz | Opcode::Jmp => {
                let target = parts
                    .next()
                    .ok_or_else(|| anyhow!("line {}: branch needs a target", lineno + 1))?;
                let dest = if let Some(&pc) = labels.get(target) {
                    pc as u16
                } else {
                    target
                        .parse()
                        .map_err(|_| anyhow!("line {}: unknown target '{target}'", lineno + 1))?
                };
                Instr::new(op, dest)
            }
            Opcode::Nop | Opcode::Halt => Instr::new(op, 0),
            _ => {
                let operand: u16 = parts
                    .next()
                    .unwrap_or("0")
                    .parse()
                    .map_err(|_| anyhow!("line {}: bad operand", lineno + 1))?;
                Instr::new(op, operand)
            }
        };
        if let Some(extra) = parts.next() {
            bail!("line {}: trailing token '{extra}'", lineno + 1);
        }
        instrs.push(instr);
    }
    Ok(Program { instrs, labels })
}

fn strip(line: &str) -> &str {
    let line = line.split(';').next().unwrap_or("");
    line.trim()
}

#[cfg(test)]
mod tests {
    use super::*;

    const DEMO: &str = r#"
        ; progressive inference demo
        cfg classes 26
        cfg minseg 1
          ldf 0
        loop:
          enc 0
          srch 0
          cmp 128
          bnz loop
          sto 0
          halt
    "#;

    #[test]
    fn assembles_demo() {
        let p = assemble(DEMO).unwrap();
        assert_eq!(p.len(), 9);
        assert_eq!(p.labels["loop"], 3);
        assert_eq!(p.instrs[5].op, Opcode::Cmp);
        // bnz points back at the loop label
        assert_eq!(p.instrs[6], Instr::new(Opcode::Bnz, 3));
    }

    #[test]
    fn assemble_disassemble_reassemble_fixpoint() {
        let p = assemble(DEMO).unwrap();
        let text = p.disassemble();
        let p2 = assemble(&text).unwrap();
        assert_eq!(p.instrs, p2.instrs);
    }

    #[test]
    fn rejects_unknown_mnemonic_and_labels() {
        assert!(assemble("frobnicate 1").is_err());
        assert!(assemble("bnz nowhere").is_err());
        assert!(assemble("a:\na:\nnop").is_err());
        assert!(assemble("cfg bogus 1").is_err());
        assert!(assemble("enc 1 2").is_err());
    }

    #[test]
    fn numeric_branch_targets() {
        let p = assemble("nop\njmp 0").unwrap();
        assert_eq!(p.instrs[1], Instr::new(Opcode::Jmp, 0));
    }
}
