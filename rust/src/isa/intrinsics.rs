//! Programming-model intrinsics (Fig.8): the Rust twin of the chip's C/C++
//! intrinsic header. High-level CL application code calls these builders;
//! each emits the exact instruction sequence the inline-assembly operator
//! would, so `Program::bytecode()` is the deployable image.

use crate::config::HdConfig;
use crate::isa::instruction::Instr;
use crate::isa::opcode::{CfgReg, Opcode};
use crate::isa::program::Program;

/// Builder that accumulates instructions + labels.
#[derive(Default)]
pub struct ProgramBuilder {
    instrs: Vec<Instr>,
    labels: std::collections::BTreeMap<String, usize>,
}

impl ProgramBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn label(&mut self, name: &str) -> &mut Self {
        self.labels.insert(name.to_string(), self.instrs.len());
        self
    }

    pub fn emit(&mut self, op: Opcode, operand: u16) -> &mut Self {
        self.instrs.push(Instr::new(op, operand));
        self
    }

    pub fn cfg(&mut self, reg: CfgReg, val: u16) -> &mut Self {
        self.instrs.push(Instr::cfg(reg, val));
        self
    }

    pub fn branch_if_not_exit(&mut self, label: &str) -> &mut Self {
        let target = self.labels[label] as u16;
        self.emit(Opcode::Bnz, target)
    }

    pub fn build(&mut self) -> Program {
        Program {
            instrs: std::mem::take(&mut self.instrs),
            labels: std::mem::take(&mut self.labels),
        }
    }
}

/// Encode tau (confidence knob) into the Cmp operand's q8.8 fixed point.
pub fn tau_to_q88(tau: f32) -> u16 {
    (tau * 256.0).round().clamp(0.0, 65535.0) as u16
}

pub fn q88_to_tau(q: u16) -> f32 {
    q as f32 / 256.0
}

/// `clo_infer_progressive()` intrinsic: dual-mode progressive inference.
/// In normal mode the conv layers run first and their features cross the
/// CDC FIFO into the HD domain; bypass skips straight to load-features.
pub fn program_inference(cfg: &HdConfig, n_conv_layers: usize, normal_mode: bool,
                         tau: f32, min_seg: usize) -> Program {
    let mut b = ProgramBuilder::new();
    b.cfg(CfgReg::Classes, cfg.classes as u16)
        .cfg(CfgReg::QBits, cfg.qbits as u16)
        .cfg(CfgReg::MinSeg, min_seg as u16)
        .cfg(CfgReg::Mode, u16::from(normal_mode));
    if normal_mode {
        for l in 0..n_conv_layers {
            b.emit(Opcode::Conv, l as u16);
        }
        // WCFE -> HD handoff through the global CDC FIFO
        b.emit(Opcode::Push, cfg.features() as u16);
        b.emit(Opcode::Pop, cfg.features() as u16);
    }
    b.emit(Opcode::Ldf, 0);
    b.emit(Opcode::Qnt, cfg.qbits as u16);
    // Unrolled progressive-search loop (the chip sequencer's macro
    // expansion): after each segment's cmp, `bnz <next segment>` continues
    // when the confidence flag is CLEAR; when SET, the guarded `jmp done`
    // terminates encoding + search early (Fig.4).
    let mut done_fixups = Vec::new();
    for seg in 0..cfg.segments {
        b.emit(Opcode::Enc, seg as u16);
        b.emit(Opcode::Srch, seg as u16);
        if seg + 1 >= min_seg && seg + 1 < cfg.segments {
            b.emit(Opcode::Cmp, tau_to_q88(tau));
            let next_seg_pc = (b.instrs.len() + 2) as u16;
            b.emit(Opcode::Bnz, next_seg_pc);
            done_fixups.push(b.instrs.len());
            b.emit(Opcode::Jmp, 0); // patched to `done` below
        }
    }
    b.label("done");
    b.emit(Opcode::Sto, 0);
    b.emit(Opcode::Halt, 0);
    let mut p = b.build();
    let done = p.labels["done"] as u16;
    for pc in done_fixups {
        p.instrs[pc] = Instr::new(Opcode::Jmp, done);
    }
    p
}

/// `clo_train_single_pass()` intrinsic: encode all segments, bundle into the
/// class CHV.
pub fn program_train(cfg: &HdConfig, class: usize) -> Program {
    let mut b = ProgramBuilder::new();
    b.cfg(CfgReg::Classes, cfg.classes as u16)
        .cfg(CfgReg::QBits, 8)
        .cfg(CfgReg::TrainMode, 0);
    b.emit(Opcode::Ldf, 0);
    b.emit(Opcode::Qnt, 8);
    for seg in 0..cfg.segments {
        b.emit(Opcode::Enc, seg as u16);
    }
    b.emit(Opcode::Upd, class as u16);
    b.emit(Opcode::Sto, class as u16);
    b.emit(Opcode::Halt, 0);
    b.build()
}

/// `clo_load_model()` intrinsic: stream encoder factor tiles into the
/// 8-bank weight buffer.
pub fn program_load_weights(n_tiles: usize) -> Program {
    let mut b = ProgramBuilder::new();
    for t in 0..n_tiles {
        b.emit(Opcode::Ldw, t as u16);
    }
    b.emit(Opcode::Halt, 0);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::interpreter::{Interpreter, MockDevice};

    fn cfg() -> HdConfig {
        HdConfig::synthetic("t", 8, 8, 32, 32, 8, 10)
    }

    #[test]
    fn tau_q88_roundtrip() {
        for tau in [0.0f32, 0.5, 1.0, 2.25] {
            assert!((q88_to_tau(tau_to_q88(tau)) - tau).abs() < 1.0 / 256.0);
        }
    }

    #[test]
    fn bypass_program_has_no_conv() {
        let p = program_inference(&cfg(), 3, false, 0.5, 1);
        assert!(!p.instrs.iter().any(|i| i.op == Opcode::Conv));
        assert!(!p.instrs.iter().any(|i| i.op == Opcode::Push));
        assert_eq!(p.instrs.iter().filter(|i| i.op == Opcode::Enc).count(), 8);
    }

    #[test]
    fn normal_program_runs_conv_then_fifo() {
        let p = program_inference(&cfg(), 3, true, 0.5, 1);
        let ops: Vec<Opcode> = p.instrs.iter().map(|i| i.op).collect();
        let conv_pos = ops.iter().position(|&o| o == Opcode::Conv).unwrap();
        let push_pos = ops.iter().position(|&o| o == Opcode::Push).unwrap();
        let enc_pos = ops.iter().position(|&o| o == Opcode::Enc).unwrap();
        assert!(conv_pos < push_pos && push_pos < enc_pos);
    }

    #[test]
    fn progressive_program_early_exits_on_device_flag() {
        let p = program_inference(&cfg(), 0, false, 0.5, 1);
        let mut dev = MockDevice { exit_after: 2, ..Default::default() };
        let r = Interpreter::default().run(&p, &mut dev).unwrap();
        let encs = dev.calls.iter().filter(|c| c.starts_with("enc")).count();
        assert_eq!(encs, 2, "should stop after the 2nd segment's cmp");
        assert!(r.state.halted);
    }

    #[test]
    fn progressive_program_runs_all_segments_if_never_confident() {
        let p = program_inference(&cfg(), 0, false, 0.5, 1);
        let mut dev = MockDevice { exit_after: usize::MAX, ..Default::default() };
        let _ = Interpreter::default().run(&p, &mut dev).unwrap();
        let encs = dev.calls.iter().filter(|c| c.starts_with("enc")).count();
        assert_eq!(encs, 8);
    }

    #[test]
    fn train_program_shape() {
        let p = program_train(&cfg(), 3);
        assert_eq!(p.instrs.iter().filter(|i| i.op == Opcode::Enc).count(), 8);
        assert!(p.instrs.iter().any(|i| i.op == Opcode::Upd && i.operand == 3));
    }
}
