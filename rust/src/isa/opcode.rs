//! The 4-bit opcode space (Fig.8): memory-class and arithmetic-class
//! instructions for the WCFE, HD module and global FIFO, plus minimal
//! control flow for programmability.

/// All 16 opcodes. Encodings are frozen (they appear in golden tests).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Opcode {
    /// no operation
    Nop = 0x0,
    /// stop execution
    Halt = 0x1,
    /// set config register [operand: (reg << 12) | value]
    Cfg = 0x2,
    // ---- memory class ----
    /// load weight tile into the encoder weight buffer [operand: tile id]
    Ldw = 0x3,
    /// load feature vector from input buffer [operand: slot]
    Ldf = 0x4,
    /// store result/CHV block back to cache [operand: slot]
    Sto = 0x5,
    /// push through the global CDC FIFO [operand: word count]
    Push = 0x6,
    /// pop from the global CDC FIFO [operand: word count]
    Pop = 0x7,
    // ---- arithmetic class ----
    /// Kronecker-encode one QHV segment [operand: segment index]
    Enc = 0x8,
    /// associative search over one segment [operand: segment index]
    Srch = 0x9,
    /// CHV train update (+QHV / -QHV per coef) [operand: class]
    Upd = 0xA,
    /// run one WCFE conv layer [operand: layer index]
    Conv = 0xB,
    /// margin/confidence compare; sets the exit flag [operand: tau q8.8]
    Cmp = 0xC,
    /// quantize the feature/QHV buffer [operand: bits]
    Qnt = 0xD,
    // ---- control ----
    /// branch to absolute pc if exit flag CLEAR [operand: target]
    Bnz = 0xE,
    /// unconditional jump [operand: target]
    Jmp = 0xF,
}

impl Opcode {
    pub fn from_bits(bits: u8) -> Option<Opcode> {
        use Opcode::*;
        Some(match bits {
            0x0 => Nop,
            0x1 => Halt,
            0x2 => Cfg,
            0x3 => Ldw,
            0x4 => Ldf,
            0x5 => Sto,
            0x6 => Push,
            0x7 => Pop,
            0x8 => Enc,
            0x9 => Srch,
            0xA => Upd,
            0xB => Conv,
            0xC => Cmp,
            0xD => Qnt,
            0xE => Bnz,
            0xF => Jmp,
            _ => return None,
        })
    }

    pub fn mnemonic(&self) -> &'static str {
        use Opcode::*;
        match self {
            Nop => "nop",
            Halt => "halt",
            Cfg => "cfg",
            Ldw => "ldw",
            Ldf => "ldf",
            Sto => "sto",
            Push => "push",
            Pop => "pop",
            Enc => "enc",
            Srch => "srch",
            Upd => "upd",
            Conv => "conv",
            Cmp => "cmp",
            Qnt => "qnt",
            Bnz => "bnz",
            Jmp => "jmp",
        }
    }

    pub fn from_mnemonic(s: &str) -> Option<Opcode> {
        use Opcode::*;
        Some(match s {
            "nop" => Nop,
            "halt" => Halt,
            "cfg" => Cfg,
            "ldw" => Ldw,
            "ldf" => Ldf,
            "sto" => Sto,
            "push" => Push,
            "pop" => Pop,
            "enc" => Enc,
            "srch" => Srch,
            "upd" => Upd,
            "conv" => Conv,
            "cmp" => Cmp,
            "qnt" => Qnt,
            "bnz" => Bnz,
            "jmp" => Jmp,
            _ => return None,
        })
    }

    /// Instruction class (Fig.8 groups): memory vs arithmetic vs control.
    pub fn class(&self) -> InstrClass {
        use Opcode::*;
        match self {
            Ldw | Ldf | Sto | Push | Pop => InstrClass::Memory,
            Enc | Srch | Upd | Conv | Cmp | Qnt => InstrClass::Arithmetic,
            Nop | Halt | Cfg | Bnz | Jmp => InstrClass::Control,
        }
    }

    pub fn all() -> [Opcode; 16] {
        use Opcode::*;
        [
            Nop, Halt, Cfg, Ldw, Ldf, Sto, Push, Pop, Enc, Srch, Upd, Conv,
            Cmp, Qnt, Bnz, Jmp,
        ]
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InstrClass {
    Memory,
    Arithmetic,
    Control,
}

/// Config register ids (operand high nibble of `Cfg`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum CfgReg {
    /// active class count
    Classes = 0x0,
    /// progressive-search minimum segments
    MinSeg = 0x1,
    /// quantization bits
    QBits = 0x2,
    /// dual-mode select: 0 = bypass, 1 = normal (through WCFE)
    Mode = 0x3,
    /// train coefficient select: 0 = add-only, 1 = add/sub
    TrainMode = 0x4,
}

impl CfgReg {
    pub fn name(&self) -> &'static str {
        use CfgReg::*;
        match self {
            Classes => "classes",
            MinSeg => "minseg",
            QBits => "qbits",
            Mode => "mode",
            TrainMode => "trainmode",
        }
    }

    pub fn from_bits(bits: u8) -> Option<CfgReg> {
        use CfgReg::*;
        Some(match bits {
            0x0 => Classes,
            0x1 => MinSeg,
            0x2 => QBits,
            0x3 => Mode,
            0x4 => TrainMode,
            _ => return None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opcode_bits_roundtrip() {
        for op in Opcode::all() {
            assert_eq!(Opcode::from_bits(op as u8), Some(op));
        }
        assert_eq!(Opcode::from_bits(16), None);
    }

    #[test]
    fn mnemonics_roundtrip() {
        for op in Opcode::all() {
            assert_eq!(Opcode::from_mnemonic(op.mnemonic()), Some(op));
        }
        assert_eq!(Opcode::from_mnemonic("bogus"), None);
    }

    #[test]
    fn classes_partition() {
        use InstrClass::*;
        let mut mem = 0;
        let mut arith = 0;
        let mut ctl = 0;
        for op in Opcode::all() {
            match op.class() {
                Memory => mem += 1,
                Arithmetic => arith += 1,
                Control => ctl += 1,
            }
        }
        assert_eq!((mem, arith, ctl), (5, 6, 5));
    }
}
