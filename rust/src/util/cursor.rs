//! Shared little-endian byte cursor for the repo's binary formats — the
//! CLOK knowledge checkpoints (`crate::hdc::knowledge`) and the serve wire
//! protocol (`crate::serve::wire`). One bounds-checked reader keeps their
//! truncation/trailing-byte behavior identical.

use crate::Result;
use anyhow::bail;

/// A forward-only reader over a byte payload; every getter is
/// bounds-checked and little-endian.
pub struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(b: &'a [u8]) -> Cursor<'a> {
        Cursor { b, i: 0 }
    }

    /// Bytes consumed so far.
    pub fn offset(&self) -> usize {
        self.i
    }

    /// Consume exactly `n` bytes.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // self.i <= b.len() always, so the subtraction cannot underflow —
        // and this form cannot overflow for any attacker/on-disk n
        if n > self.b.len() - self.i {
            bail!(
                "payload truncated: need {n} bytes at offset {}, have {}",
                self.i,
                self.b.len() - self.i
            );
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// `n` consecutive f32 values (the wire protocol's feature blocks).
    pub fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let total = n
            .checked_mul(4)
            .ok_or_else(|| anyhow::anyhow!("element count {n} overflows the payload"))?;
        let bytes = self.take(total)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect())
    }

    /// A `u16`-length-prefixed utf-8 string.
    pub fn str16(&mut self) -> Result<String> {
        let n = self.u16()? as usize;
        Ok(String::from_utf8(self.take(n)?.to_vec())?)
    }

    /// Assert every payload byte was consumed (rejects trailing garbage).
    pub fn finish(&self) -> Result<()> {
        if self.i != self.b.len() {
            bail!("payload has {} trailing bytes", self.b.len() - self.i);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reads_every_width_in_order() {
        let mut b = Vec::new();
        b.push(7u8);
        b.extend_from_slice(&513u16.to_le_bytes());
        b.extend_from_slice(&70000u32.to_le_bytes());
        b.extend_from_slice(&u64::MAX.to_le_bytes());
        b.extend_from_slice(&(-2.5f32).to_le_bytes());
        b.extend_from_slice(&1.25e-7f64.to_le_bytes());
        b.extend_from_slice(&2u16.to_le_bytes());
        b.extend_from_slice(b"hi");
        let mut c = Cursor::new(&b);
        assert_eq!(c.u8().unwrap(), 7);
        assert_eq!(c.u16().unwrap(), 513);
        assert_eq!(c.u32().unwrap(), 70000);
        assert_eq!(c.u64().unwrap(), u64::MAX);
        assert_eq!(c.f32().unwrap(), -2.5);
        assert_eq!(c.f64().unwrap(), 1.25e-7);
        assert_eq!(c.str16().unwrap(), "hi");
        assert!(c.finish().is_ok());
        assert_eq!(c.offset(), b.len());
    }

    #[test]
    fn truncation_trailing_and_overflow_are_rejected() {
        let mut c = Cursor::new(&[1, 2, 3]);
        assert!(c.u32().is_err(), "3 bytes cannot yield a u32");
        let mut c = Cursor::new(&[1, 2, 3, 4]);
        c.u16().unwrap();
        assert!(c.finish().unwrap_err().to_string().contains("trailing"));
        // an absurd element count must fail before any allocation
        let mut c = Cursor::new(&[0u8; 8]);
        assert!(c.f32s(usize::MAX).is_err());
        assert!(c.f32s(usize::MAX / 2).is_err());
    }

    #[test]
    fn f32s_and_str16_roundtrip() {
        let vals = [1.0f32, -0.5, 3.25];
        let mut b = Vec::new();
        for v in vals {
            b.extend_from_slice(&v.to_le_bytes());
        }
        let mut c = Cursor::new(&b);
        assert_eq!(c.f32s(3).unwrap(), vals);
        assert!(c.finish().is_ok());
        // non-utf8 string payloads error instead of panicking
        let mut b = Vec::new();
        b.extend_from_slice(&2u16.to_le_bytes());
        b.extend_from_slice(&[0xFF, 0xFE]);
        assert!(Cursor::new(&b).str16().is_err());
    }
}
