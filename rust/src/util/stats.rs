//! Timing + statistics for the hand-rolled benchmark harness.
//!
//! `cargo bench` runs `[[bench]] harness = false` binaries built on
//! [`Bench`]: warmup, repeated timed runs, robust summary (median / p95 /
//! mean / stddev), and table-formatted reporting so each bench reproduces
//! one of the paper's figures/tables as printed rows.

use std::time::{Duration, Instant};

/// Summary statistics over a set of per-iteration durations (seconds).
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub p95: f64,
    pub min: f64,
    pub max: f64,
    pub stddev: f64,
}

impl Summary {
    /// Panicking variant of [`Summary::try_from_secs`] for callers that
    /// guarantee at least one sample (the bench runner always does).
    pub fn from_secs(xs: Vec<f64>) -> Summary {
        Summary::try_from_secs(xs).expect("Summary::from_secs on empty sample set")
    }

    /// Summarize per-iteration durations; `None` when there are no samples.
    ///
    /// Empty inputs are a real condition (e.g. a loadgen run whose request
    /// mix produced zero operations of some kind) — callers that would
    /// otherwise serialize NaN into a report must branch on the `None` and
    /// make the empty case explicit instead.
    pub fn try_from_secs(mut xs: Vec<f64>) -> Option<Summary> {
        if xs.is_empty() {
            return None;
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Some(Summary {
            n,
            mean,
            median: percentile_sorted(&xs, 50.0),
            p95: percentile_sorted(&xs, 95.0),
            min: xs[0],
            max: xs[n - 1],
            stddev: var.sqrt(),
        })
    }
}

/// Percentile over a pre-sorted slice (linear interpolation). Panics on an
/// empty slice; use [`try_percentile_sorted`] when emptiness is reachable.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    try_percentile_sorted(sorted, p).expect("percentile_sorted on empty slice")
}

/// Percentile over a pre-sorted slice; `None` on an empty slice.
pub fn try_percentile_sorted(sorted: &[f64], p: f64) -> Option<f64> {
    if sorted.is_empty() {
        return None;
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    Some(if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    })
}

/// Micro-benchmark runner.
pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 3, iters: 20 }
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bench { warmup, iters }
    }

    /// Time `f` (excluding warmup runs); returns per-iteration seconds.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Summary {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        Summary::from_secs(samples)
    }
}

/// Human-readable duration.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Throughput formatting (ops/s).
pub fn fmt_rate(ops_per_sec: f64) -> String {
    if ops_per_sec >= 1e9 {
        format!("{:.2} Gop/s", ops_per_sec / 1e9)
    } else if ops_per_sec >= 1e6 {
        format!("{:.2} Mop/s", ops_per_sec / 1e6)
    } else if ops_per_sec >= 1e3 {
        format!("{:.2} Kop/s", ops_per_sec / 1e3)
    } else {
        format!("{ops_per_sec:.2} op/s")
    }
}

/// Fixed-width table printer used by every figure-bench.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let cols: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            println!("| {} |", cols.join(" | "));
        };
        line(&self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("|-{}-|", sep.join("-|-"));
        for row in &self.rows {
            line(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::from_secs(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.mean - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = vec![0.0, 10.0];
        assert!((percentile_sorted(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 10.0);
    }

    #[test]
    fn empty_inputs_are_explicit_not_nan() {
        // The panicking entry points stay panicking (their contract), while
        // the try_ variants return None so report writers can never leak a
        // NaN row into a JSON document.
        assert!(Summary::try_from_secs(vec![]).is_none());
        assert!(try_percentile_sorted(&[], 50.0).is_none());
        let s = Summary::try_from_secs(vec![2.0]).unwrap();
        assert_eq!(s.n, 1);
        assert_eq!(s.median, 2.0);
        assert_eq!(try_percentile_sorted(&[1.0, 3.0], 50.0), Some(2.0));
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn from_secs_empty_panics_with_message() {
        let _ = Summary::from_secs(vec![]);
    }

    #[test]
    fn bench_runs_expected_iters() {
        let mut count = 0usize;
        let b = Bench::new(2, 5);
        let s = b.run(|| count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn fmt_helpers() {
        assert!(fmt_secs(2.0).ends_with(" s"));
        assert!(fmt_secs(2e-3).ends_with("ms"));
        assert!(fmt_secs(2e-6).ends_with("us"));
        assert!(fmt_rate(5e6).contains("Mop/s"));
    }
}
