//! xoshiro256++ PRNG + Gaussian sampling (Box–Muller).
//!
//! Deterministic, seedable, and fast enough for workload generation; used by
//! every synthetic generator, the property-test framework, and the
//! Kronecker-factor initialization fallback.

/// xoshiro256++ by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    cached_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Derive an independent stream (for per-task / per-thread generators).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [0, n) (Lemire-ish rejection-free for our needs).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// +-1 with equal probability.
    pub fn sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        let (u1, u2) = (self.uniform().max(1e-300), self.uniform());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Exponential with the given rate (Poisson-process inter-arrival gaps).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.uniform().max(1e-300).ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_unit_interval_and_unbiased() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(1);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
