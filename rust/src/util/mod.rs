//! Small self-contained utilities: seeded PRNG, timing/statistics for the
//! hand-rolled bench harness, a mini property-testing framework, a
//! dependency-free CLI argument parser, and a zero-dependency scoped worker
//! pool.
//!
//! (The offline vendor set has no rand/criterion/proptest/clap, so these are
//! first-class citizens of the repo rather than stop-gaps.)

pub mod args;
pub mod cursor;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;

pub use args::Args;
pub use cursor::Cursor;
pub use pool::WorkerPool;
pub use rng::Rng;
pub use stats::{Bench, Summary};
