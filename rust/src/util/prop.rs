//! Mini property-testing framework (the vendor set has no proptest).
//!
//! `forall(cases, seed, |rng| ...)` runs a property against `cases`
//! independently seeded [`Rng`] streams; on failure it reports the failing
//! stream's seed so the case can be replayed deterministically with
//! `replay(seed, ...)`. Generators are just closures over `Rng` — shapes,
//! vectors, quantized values, etc. live next to their modules.

use super::rng::Rng;

/// Run `prop` against `cases` independent random streams. Panics with the
/// failing seed embedded in the message.
pub fn forall<F: FnMut(&mut Rng)>(cases: usize, seed: u64, mut prop: F) {
    for case in 0..cases {
        let case_seed = seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property failed on case {case} (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case.
pub fn replay<F: FnMut(&mut Rng)>(case_seed: u64, mut prop: F) {
    let mut rng = Rng::new(case_seed);
    prop(&mut rng);
}

/// Common generators.
pub mod gen {
    use super::Rng;

    /// Vector of INT8-valued f32 in [-127, 127].
    pub fn int8_vec(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.range(-127, 128) as f32).collect()
    }

    /// +-1 matrix (flattened row-major).
    pub fn sign_matrix(rng: &mut Rng, rows: usize, cols: usize) -> Vec<f32> {
        (0..rows * cols).map(|_| rng.sign()).collect()
    }

    /// Gaussian f32 vector.
    pub fn normal_vec(rng: &mut Rng, len: usize, sigma: f32) -> Vec<f32> {
        (0..len).map(|_| rng.normal_f32() * sigma).collect()
    }

    /// Pick one of the given values.
    pub fn choice<T: Copy>(rng: &mut Rng, options: &[T]) -> T {
        options[rng.below(options.len())]
    }

    /// +-1 hypervector (the INT1 / XOR-tree domain).
    pub fn pm1_vec(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.sign()).collect()
    }

    /// Vector of INT`bits`-valued f32 on the symmetric signed grid
    /// [-(2^(bits-1)-1), 2^(bits-1)-1].
    pub fn quantized_vec(rng: &mut Rng, len: usize, bits: u8) -> Vec<f32> {
        let m = ((1i64 << (bits - 1)) - 1).max(1);
        (0..len).map(|_| rng.range(-m, m + 1) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivially_true_property() {
        forall(50, 1, |rng| {
            let x = rng.uniform();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn forall_reports_seed_on_failure() {
        forall(50, 2, |rng| {
            assert!(rng.uniform() < 0.5, "coin landed high");
        });
    }

    #[test]
    fn generators_shapes() {
        let mut rng = Rng::new(3);
        assert_eq!(gen::int8_vec(&mut rng, 10).len(), 10);
        assert_eq!(gen::sign_matrix(&mut rng, 3, 4).len(), 12);
        let c = gen::choice(&mut rng, &[1, 2, 3]);
        assert!((1..=3).contains(&c));
    }

    #[test]
    fn int8_vec_in_range() {
        let mut rng = Rng::new(4);
        for v in gen::int8_vec(&mut rng, 1000) {
            assert!((-127.0..=127.0).contains(&v));
            assert_eq!(v.fract(), 0.0);
        }
    }

    #[test]
    fn new_generators_stay_on_their_grids() {
        let mut rng = Rng::new(5);
        for v in gen::pm1_vec(&mut rng, 500) {
            assert!(v == 1.0 || v == -1.0);
        }
        for v in gen::quantized_vec(&mut rng, 500, 4) {
            assert!((-7.0..=7.0).contains(&v) && v.fract() == 0.0);
        }
        for v in gen::quantized_vec(&mut rng, 100, 1) {
            assert!((-1.0..=1.0).contains(&v) && v.fract() == 0.0);
        }
    }
}
