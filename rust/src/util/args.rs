//! Dependency-free CLI argument parser (no clap in the offline vendor set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments; typed getters with defaults.
//!
//! Boolean flags need registration: a bare `--flag` followed by a
//! non-`--` token is ambiguous (is the token the flag's value or a
//! positional?), and the parser used to guess "value" — so
//! `clo_hdnn infer --packed model_dir` stored `packed="model_dir"`, lost
//! the positional, *and* made `flag("packed")` return false. Callers now
//! pass their boolean set to [`Args::parse_with_bools`] (registered
//! booleans never consume the next token), and [`Args::flag`] treats any
//! present key as true unless its value is explicitly falsy, so even an
//! unregistered boolean that swallowed a token still reads as set.

use crate::Result;
use anyhow::bail;
use std::collections::HashMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    flags: HashMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse with no registered boolean flags (greedy `--key value`).
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        Args::parse_with_bools(argv, &[])
    }

    /// Parse with a known-boolean set: a registered `--flag` never consumes
    /// the following token (it can still be given an explicit value via
    /// `--flag=false`).
    pub fn parse_with_bools(argv: impl IntoIterator<Item = String>, bools: &[&str]) -> Args {
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if !bools.contains(&stripped)
                    && iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    flags.insert(stripped.to_string(), v);
                } else {
                    flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                positional.push(arg);
            }
        }
        Args { flags, positional }
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// [`Args::from_env`] with the caller's boolean-flag set registered.
    pub fn from_env_with_bools(bools: &[&str]) -> Args {
        Args::parse_with_bools(std::env::args().skip(1), bools)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Integer flag with default. A malformed value is a proper error
    /// naming the flag and the offending token — never a panic, so a bad
    /// `--threads x` can't take down a served process with a backtrace.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(n) => Ok(n),
                Err(_) => bail!("--{key} expects an integer, got '{v}'"),
            },
        }
    }

    /// Float flag with default; malformed values error like [`Args::usize_or`].
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(n) => Ok(n),
                Err(_) => bail!("--{key} expects a number, got '{v}'"),
            },
        }
    }

    /// True when the key is present and not explicitly falsy. Presence wins:
    /// a boolean that (unregistered) swallowed the next token still counts
    /// as set.
    pub fn flag(&self, key: &str) -> bool {
        match self.get(key) {
            Some("false") | Some("0") | Some("no") | None => false,
            Some(_) => true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    fn parse_bools(v: &[&str], bools: &[&str]) -> Args {
        Args::parse_with_bools(v.iter().map(|s| s.to_string()), bools)
    }

    #[test]
    fn key_value_styles() {
        let a = parse(&["--mode", "serve", "--batch=8", "--fast"]);
        assert_eq!(a.str_or("mode", ""), "serve");
        assert_eq!(a.usize_or("batch", 0).unwrap(), 8);
        assert!(a.flag("fast"));
        assert!(!a.flag("slow"));
    }

    #[test]
    fn positional_collected() {
        let a = parse(&["run", "--x", "1", "path/to/file"]);
        assert_eq!(a.positional(), &["run".to_string(), "path/to/file".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.usize_or("n", 42).unwrap(), 42);
        assert_eq!(a.f64_or("tau", 1.5).unwrap(), 1.5);
        assert_eq!(a.str_or("name", "tiny"), "tiny");
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--a", "--b", "2"]);
        assert!(a.flag("a"));
        assert_eq!(a.usize_or("b", 0).unwrap(), 2);
    }

    #[test]
    fn registered_bool_does_not_swallow_positionals() {
        // the regression: `infer --packed model_dir` must keep the
        // positional AND report the flag as set
        let a = parse_bools(&["infer", "--packed", "model_dir"], &["packed"]);
        assert_eq!(
            a.positional(),
            &["infer".to_string(), "model_dir".to_string()]
        );
        assert!(a.flag("packed"));
        assert_eq!(a.get("packed"), Some("true"));
    }

    #[test]
    fn registered_bool_accepts_explicit_value() {
        let a = parse_bools(&["--quick=false", "--deep=yes"], &["quick", "deep"]);
        assert!(!a.flag("quick"));
        assert!(a.flag("deep"));
    }

    #[test]
    fn unregistered_bool_that_swallowed_a_token_still_reads_set() {
        // defense in depth: even without registration, presence wins
        let a = parse(&["--packed", "model_dir"]);
        assert!(a.flag("packed"));
        assert!(!a.flag("packed-off"));
    }

    #[test]
    fn falsy_spellings_read_unset() {
        let a = parse(&["--a=false", "--b=0", "--c=no", "--d=1"]);
        assert!(!a.flag("a"));
        assert!(!a.flag("b"));
        assert!(!a.flag("c"));
        assert!(a.flag("d"));
    }

    #[test]
    fn malformed_numbers_error_with_flag_and_value() {
        let a = parse(&["--threads", "x", "--tau", "fast"]);
        let e = a.usize_or("threads", 1).unwrap_err().to_string();
        assert!(e.contains("--threads") && e.contains("'x'"), "{e}");
        let e = a.f64_or("tau", 0.5).unwrap_err().to_string();
        assert!(e.contains("--tau") && e.contains("'fast'"), "{e}");
        // well-formed values still parse
        let a = parse(&["--threads", "4"]);
        assert_eq!(a.usize_or("threads", 1).unwrap(), 4);
    }
}
