//! Dependency-free CLI argument parser (no clap in the offline vendor set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments; typed getters with defaults.

use std::collections::HashMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    flags: HashMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    flags.insert(stripped.to_string(), v);
                } else {
                    flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                positional.push(arg);
            }
        }
        Args { flags, positional }
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number")))
            .unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn key_value_styles() {
        let a = parse(&["--mode", "serve", "--batch=8", "--fast"]);
        assert_eq!(a.str_or("mode", ""), "serve");
        assert_eq!(a.usize_or("batch", 0), 8);
        assert!(a.flag("fast"));
        assert!(!a.flag("slow"));
    }

    #[test]
    fn positional_collected() {
        let a = parse(&["run", "--x", "1", "path/to/file"]);
        assert_eq!(a.positional(), &["run".to_string(), "path/to/file".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.usize_or("n", 42), 42);
        assert_eq!(a.f64_or("tau", 1.5), 1.5);
        assert_eq!(a.str_or("name", "tiny"), "tiny");
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--a", "--b", "2"]);
        assert!(a.flag("a"));
        assert_eq!(a.usize_or("b", 0), 2);
    }
}
