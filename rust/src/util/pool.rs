//! Zero-dependency scoped worker pool (no rayon — the workspace builds
//! offline): `std::thread::scope` for borrow-friendly fork/join plus an
//! `mpsc` channel to merge per-block results.
//!
//! The pool is deliberately stateless — a thread *budget*, not a set of
//! long-lived threads. Scoped threads are spawned per call and joined before
//! the call returns, so shards can borrow the caller's slices directly (no
//! `'static` bound, no `Arc`), and a `threads == 1` pool degrades to a plain
//! inline call with zero overhead. Threading model: the coordinator's
//! executor thread *owns* the backend (backends are not `Send`); the pool is
//! owned *by* the backend and only fans out within one backend call, so no
//! shared mutable state ever crosses a request boundary.
//!
//! Two primitives cover the repo's data-parallel shapes:
//! * [`WorkerPool::run_rows`] — shard a row-major output buffer into
//!   contiguous row blocks, one scoped thread per block (batched encode);
//! * [`WorkerPool::run_blocks`] — block-map an index range and collect each
//!   block's result over a channel (associative-memory search over class
//!   row-blocks, merged by the caller).

use std::sync::mpsc;

/// Environment variable overriding every **auto** (`0`) thread budget —
/// the hook the CI matrix uses to run the whole suite serial and 4-wide.
pub const THREADS_ENV: &str = "CLO_HDNN_THREADS";

/// A thread budget for scoped fork/join parallelism.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkerPool {
    threads: usize,
}

impl Default for WorkerPool {
    /// The serial pool (1 thread) — every `run_*` call runs inline.
    fn default() -> Self {
        WorkerPool::new(1)
    }
}

/// Resolve a thread-count spelling. A non-zero count is taken literally
/// (explicit `--threads N` beats everything). `0` means **auto**:
/// `CLO_HDNN_THREADS` when set (itself `0`/unset ⇒ all available cores) —
/// so the env var reaches every pool sized with the auto default, CLI and
/// coordinator paths included.
fn resolve(threads: usize) -> usize {
    if threads != 0 {
        return threads;
    }
    let env = std::env::var(THREADS_ENV).ok();
    match env.and_then(|v| v.trim().parse::<usize>().ok()) {
        Some(n) if n != 0 => n,
        _ => WorkerPool::available(),
    }
}

/// Parse an explicit `CLO_HDNN_THREADS`-style value (pure, testable).
/// Unset or whitespace-only values fall back to `default`; `0` resolves
/// like [`WorkerPool::new`]'s auto spelling. A non-empty value that is not
/// a thread count (junk, negative, overflow) warns once on stderr and
/// resolves to all cores — deterministically, instead of silently adopting
/// whatever `default` the call site happened to pass.
pub fn parse_threads(value: Option<&str>, default: usize) -> usize {
    match value.map(str::trim) {
        None | Some("") => resolve(default),
        Some(v) => match v.parse::<usize>() {
            Ok(n) => resolve(n),
            Err(_) => {
                eprintln!("warning: {THREADS_ENV}='{v}' is not a thread count; using all cores");
                WorkerPool::available()
            }
        },
    }
}

impl WorkerPool {
    /// A pool with the given thread budget; `0` means all available cores.
    pub fn new(threads: usize) -> WorkerPool {
        WorkerPool { threads: resolve(threads).max(1) }
    }

    /// Core count reported by the OS (>= 1).
    pub fn available() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    /// Pool sized by `CLO_HDNN_THREADS` when set (0 = all cores), otherwise
    /// `default` threads — the hook the CI matrix uses to run the whole test
    /// suite single- and multi-threaded.
    pub fn from_env_or(default: usize) -> WorkerPool {
        let env = std::env::var(THREADS_ENV).ok();
        WorkerPool::new(parse_threads(env.as_deref(), default))
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// True when every `run_*` call executes inline on the caller thread.
    pub fn is_serial(&self) -> bool {
        self.threads == 1
    }

    /// Shard `data` (row-major, `row_len` items per row) into contiguous
    /// row blocks and run `f(first_row, block)` on each block, one scoped
    /// thread per block. Blocks are disjoint `&mut` slices, so `f` writes
    /// its rows without any synchronization. Returns after every block
    /// finished (scoped join).
    pub fn run_rows<T, F>(&self, data: &mut [T], row_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(row_len > 0, "run_rows: row_len must be >= 1");
        assert_eq!(data.len() % row_len, 0, "run_rows: data is not whole rows");
        let rows = data.len() / row_len;
        if rows == 0 {
            return;
        }
        let shards = self.threads.min(rows);
        if shards <= 1 {
            f(0, data);
            return;
        }
        let rows_per = rows.div_ceil(shards);
        std::thread::scope(|s| {
            for (i, block) in data.chunks_mut(rows_per * row_len).enumerate() {
                let f = &f;
                s.spawn(move || f(i * rows_per, block));
            }
        });
    }

    /// Split `0..n` into contiguous blocks, evaluate `f(start, len)` on each
    /// block in parallel, and return `(start, len, result)` triples sorted
    /// by `start`. Results travel back over an `mpsc` channel; the caller
    /// merges them (the associative-search sharding shape, where per-block
    /// outputs interleave in the final `(batch, classes)` matrix).
    pub fn run_blocks<R, F>(&self, n: usize, f: F) -> Vec<(usize, usize, R)>
    where
        R: Send,
        F: Fn(usize, usize) -> R + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        let shards = self.threads.min(n);
        if shards <= 1 {
            return vec![(0, n, f(0, n))];
        }
        let per = n.div_ceil(shards);
        let (tx, rx) = mpsc::channel::<(usize, usize, R)>();
        std::thread::scope(|s| {
            let mut start = 0;
            while start < n {
                let len = per.min(n - start);
                let tx = tx.clone();
                let f = &f;
                s.spawn(move || {
                    let r = f(start, len);
                    let _ = tx.send((start, len, r));
                });
                start += len;
            }
        });
        drop(tx);
        let mut out: Vec<(usize, usize, R)> = rx.into_iter().collect();
        out.sort_by_key(|&(start, _, _)| start);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn new_clamps_and_resolves_zero() {
        assert_eq!(WorkerPool::new(3).threads(), 3);
        assert!(WorkerPool::new(0).threads() >= 1);
        assert!(WorkerPool::default().is_serial());
        assert!(WorkerPool::available() >= 1);
    }

    #[test]
    fn parse_threads_spellings() {
        assert_eq!(parse_threads(Some("4"), 1), 4);
        assert_eq!(parse_threads(Some(" 2 "), 1), 2);
        assert_eq!(parse_threads(None, 3), 3);
        // whitespace-only behaves exactly like unset: take the default
        assert_eq!(parse_threads(Some(""), 3), 3);
        assert_eq!(parse_threads(Some("   "), 3), 3);
        // junk, negatives and overflow warn and resolve to all cores — the
        // same value no matter which default the call site passed
        let cores = WorkerPool::available();
        assert_eq!(parse_threads(Some("nope"), 3), cores);
        assert_eq!(parse_threads(Some("-2"), 1), cores);
        assert_eq!(parse_threads(Some("99999999999999999999999999"), 3), cores);
        // "0" and a default of 0 both mean all cores
        assert!(parse_threads(Some("0"), 1) >= 1);
        assert!(parse_threads(None, 0) >= 1);
    }

    #[test]
    fn run_rows_touches_every_row_exactly_once() {
        for threads in [1usize, 2, 4, 7] {
            let pool = WorkerPool::new(threads);
            let row_len = 3;
            let mut data = vec![0u32; 10 * row_len];
            pool.run_rows(&mut data, row_len, |first_row, block| {
                for (i, row) in block.chunks_mut(row_len).enumerate() {
                    for v in row.iter_mut() {
                        *v += (first_row + i) as u32 + 1;
                    }
                }
            });
            let want: Vec<u32> = (0..10u32).flat_map(|r| vec![r + 1; row_len]).collect();
            assert_eq!(data, want, "threads={threads}");
        }
    }

    #[test]
    fn run_rows_empty_and_fewer_rows_than_threads() {
        let pool = WorkerPool::new(8);
        let mut empty: Vec<f32> = Vec::new();
        pool.run_rows(&mut empty, 4, |_, _| panic!("no rows, no calls"));
        let mut one = vec![0.0f32; 5];
        pool.run_rows(&mut one, 5, |first, block| {
            assert_eq!(first, 0);
            block.fill(1.0);
        });
        assert!(one.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn run_rows_actually_runs_parallel_shards() {
        let pool = WorkerPool::new(4);
        let calls = AtomicUsize::new(0);
        let mut data = vec![0u8; 16];
        pool.run_rows(&mut data, 1, |_, _| {
            calls.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(calls.load(Ordering::SeqCst), 4, "one call per shard");
    }

    #[test]
    fn run_blocks_covers_range_in_order() {
        for threads in [1usize, 3, 5] {
            let pool = WorkerPool::new(threads);
            let blocks = pool.run_blocks(11, |start, len| {
                (start..start + len).map(|i| i * i).collect::<Vec<_>>()
            });
            let mut covered = Vec::new();
            let mut next = 0usize;
            for (start, len, squares) in blocks {
                assert_eq!(start, next, "blocks sorted and contiguous");
                assert_eq!(squares.len(), len);
                covered.extend(squares);
                next = start + len;
            }
            assert_eq!(next, 11);
            let want: Vec<usize> = (0..11).map(|i| i * i).collect();
            assert_eq!(covered, want, "threads={threads}");
        }
    }

    #[test]
    fn fan_out_clamps_to_work_size() {
        // more threads than rows: one shard per row, never an empty shard
        let pool = WorkerPool::new(8);
        let calls = AtomicUsize::new(0);
        let mut data = vec![0u8; 3];
        pool.run_rows(&mut data, 1, |_, block| {
            assert_eq!(block.len(), 1);
            calls.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(calls.load(Ordering::SeqCst), 3, "shards clamp to row count");
        // and run_blocks clamps to the range length the same way
        let blocks = pool.run_blocks(2, |start, len| (start, len));
        assert_eq!(blocks.len(), 2);
        assert!(blocks.iter().all(|&(_, len, _)| len == 1));
    }

    #[test]
    fn run_blocks_empty_range() {
        let pool = WorkerPool::new(4);
        let blocks = pool.run_blocks(0, |_, _| 1u8);
        assert!(blocks.is_empty());
    }
}
