//! Minimal JSON parser + serializer (no serde_json in the offline vendor
//! set). The parser covers `artifacts/manifest.json` — the full JSON
//! grammar minus exotic number forms; [`Json::dump`] is the writing side,
//! used by the `clo_hdnn bench` harness to emit `BENCH_*.json` reports.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: `obj.path(&["a", "b"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// Build an object from `(key, value)` pairs (keys sorted by BTreeMap).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Serialize to compact JSON text. Round-trips through [`Json::parse`];
    /// non-finite numbers (which JSON cannot represent) serialize as `null`.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    out.push_str(&format!("{n}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // consume one UTF-8 code point
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_structure() {
        let text = r#"{
            "version": 1,
            "configs": {"tiny": {"f1": 8, "scale_q": 3.25, "batches": [1, 8]}},
            "executables": [{"name": "enc", "inputs": [{"shape": [1, 64], "dtype": "float32"}]}],
            "flag": true, "none": null
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.path(&["configs", "tiny", "f1"]).unwrap().as_usize(), Some(8));
        assert_eq!(
            j.path(&["configs", "tiny", "scale_q"]).unwrap().as_f64(),
            Some(3.25)
        );
        let exes = j.get("executables").unwrap().as_arr().unwrap();
        assert_eq!(exes[0].get("name").unwrap().as_str(), Some("enc"));
        assert_eq!(j.get("flag"), Some(&Json::Bool(true)));
        assert_eq!(j.get("none"), Some(&Json::Null));
    }

    #[test]
    fn parses_nested_arrays_and_negatives() {
        let j = Json::parse("[[1, -2.5e2], [], [3]]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_arr().unwrap()[1].as_f64(), Some(-250.0));
        assert!(a[1].as_arr().unwrap().is_empty());
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo ✓"));
    }

    #[test]
    fn dump_roundtrips_through_parse() {
        let j = Json::obj(vec![
            ("version", Json::Num(1.0)),
            ("name", Json::Str("bench \"tiny\"\n".into())),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
            (
                "rows",
                Json::Arr(vec![Json::Num(-2.5), Json::Num(1e-4), Json::Num(1234.0)]),
            ),
            ("nested", Json::obj(vec![("speedup", Json::Num(4.75))])),
        ]);
        let text = j.dump();
        assert_eq!(Json::parse(&text).unwrap(), j);
        // integer-valued floats print without a fractional part
        assert!(text.contains("\"version\":1"), "{text}");
        assert!(text.contains("\"speedup\":4.75"), "{text}");
    }

    #[test]
    fn dump_non_finite_numbers_as_null() {
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
        assert_eq!(Json::Arr(vec![Json::Num(f64::NEG_INFINITY)]).dump(), "[null]");
    }

    #[test]
    fn dump_escapes_control_chars() {
        let j = Json::Str("a\u{1}b".into());
        let text = j.dump();
        assert_eq!(text, "\"a\\u0001b\"");
        assert_eq!(Json::parse(&text).unwrap(), j);
    }
}
