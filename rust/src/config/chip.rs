//! Physical chip envelope (Fig.11 summary table): 40 nm CMOS, 14.4 mm²,
//! 0.7-1.2 V, 50-250 MHz, 168 KB WCFE SRAM + 32 KB HDC SRAM.
//!
//! The DVFS mapping between supply voltage and clock frequency follows the
//! measured range linearly (the paper reports the two endpoints); energy
//! scaling lives in `crate::energy`.

/// Static chip parameters (constants from the paper's summary table).
#[derive(Clone, Debug)]
pub struct ChipConfig {
    pub technology_nm: u32,
    pub die_area_mm2: f64,
    pub sram_wcfe_kb: u32,
    pub sram_hdc_kb: u32,
    pub vmin: f64,
    pub vmax: f64,
    pub fmin_mhz: f64,
    pub fmax_mhz: f64,
    pub max_classes: usize,
    /// WCFE PE array geometry (Fig.7c): 4 x 16 PEs, 4 register files + 1 MAC each.
    pub pe_rows: usize,
    pub pe_cols: usize,
    pub rf_per_pe: usize,
    /// HD search fetch width: 64-bit CHV slice per cycle (Fig.6).
    pub search_bits_per_cycle: usize,
    /// Encoder datapath (Fig.5): 8-bank 1KB weight buffer, 256 b weights per
    /// cycle, 32 adder trees of 8:1.
    pub enc_weight_buffer_kb: usize,
    pub enc_weight_banks: usize,
    pub enc_weight_bits_per_cycle: usize,
    pub enc_adder_trees: usize,
    pub enc_adder_fan_in: usize,
}

impl Default for ChipConfig {
    fn default() -> Self {
        ChipConfig {
            technology_nm: 40,
            die_area_mm2: 14.4,
            sram_wcfe_kb: 168,
            sram_hdc_kb: 32,
            vmin: 0.7,
            vmax: 1.2,
            fmin_mhz: 50.0,
            fmax_mhz: 250.0,
            max_classes: 128,
            pe_rows: 4,
            pe_cols: 16,
            rf_per_pe: 4,
            search_bits_per_cycle: 64,
            enc_weight_buffer_kb: 1,
            enc_weight_banks: 8,
            enc_weight_bits_per_cycle: 256,
            enc_adder_trees: 32,
            enc_adder_fan_in: 8,
        }
    }
}

/// One DVFS operating point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OperatingPoint {
    pub voltage: f64,
    pub freq_mhz: f64,
}

impl ChipConfig {
    /// Linear V->f mapping across the measured envelope.
    pub fn point_at_voltage(&self, v: f64) -> OperatingPoint {
        let v = v.clamp(self.vmin, self.vmax);
        let t = (v - self.vmin) / (self.vmax - self.vmin);
        OperatingPoint {
            voltage: v,
            freq_mhz: self.fmin_mhz + t * (self.fmax_mhz - self.fmin_mhz),
        }
    }

    /// Sweep the DVFS envelope in `n` steps (used by the Fig.10 bench).
    pub fn dvfs_sweep(&self, n: usize) -> Vec<OperatingPoint> {
        assert!(n >= 2);
        (0..n)
            .map(|i| {
                let v = self.vmin + (self.vmax - self.vmin) * i as f64 / (n - 1) as f64;
                self.point_at_voltage(v)
            })
            .collect()
    }

    /// Total PE count of the WCFE array.
    pub fn pe_count(&self) -> usize {
        self.pe_rows * self.pe_cols
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoints_match_paper() {
        let c = ChipConfig::default();
        let lo = c.point_at_voltage(0.7);
        let hi = c.point_at_voltage(1.2);
        assert_eq!(lo.freq_mhz, 50.0);
        assert_eq!(hi.freq_mhz, 250.0);
        assert_eq!(c.pe_count(), 64);
    }

    #[test]
    fn clamps_out_of_range() {
        let c = ChipConfig::default();
        assert_eq!(c.point_at_voltage(0.2).voltage, 0.7);
        assert_eq!(c.point_at_voltage(2.0).voltage, 1.2);
    }

    #[test]
    fn sweep_monotone() {
        let c = ChipConfig::default();
        let pts = c.dvfs_sweep(6);
        assert_eq!(pts.len(), 6);
        for w in pts.windows(2) {
            assert!(w[1].voltage > w[0].voltage);
            assert!(w[1].freq_mhz > w[0].freq_mhz);
        }
    }
}
