//! Runtime configuration: HD operating points (parsed from the artifact
//! manifest, mirroring `python/compile/config.py`) and the chip's physical
//! operating envelope (Fig.11 summary table).

pub mod chip;

pub use chip::{ChipConfig, OperatingPoint};

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};

/// One HD operating point: the Kronecker factorization geometry, progressive
/// search segmentation, and quantization scales calibrated at build time.
#[derive(Clone, Debug, PartialEq)]
pub struct HdConfig {
    pub name: String,
    pub f1: usize,
    pub f2: usize,
    pub d1: usize,
    pub d2: usize,
    pub segments: usize,
    pub classes: usize,
    pub qbits: u8,
    /// feature quantization step (f32 feature -> INT8 value)
    pub scale_x: f32,
    /// QHV quantization step (accumulator -> INT`qbits` value)
    pub scale_q: f32,
    /// expected per-element |q_i - q_j| between independent QHVs (feeds the
    /// progressive-search confidence threshold)
    pub mean_absdiff: f32,
    /// batch sizes with emitted executables
    pub batches: Vec<usize>,
    /// normal-mode (image -> WCFE) config?
    pub image: bool,
}

impl HdConfig {
    /// Feature dimension F = f1 * f2 (chip supports 8-1024).
    pub fn features(&self) -> usize {
        self.f1 * self.f2
    }

    /// HDC dimension D = d1 * d2 (chip supports 1024-8192).
    pub fn dim(&self) -> usize {
        self.d1 * self.d2
    }

    /// Rows of A per progressive-search segment.
    pub fn seg_rows(&self) -> usize {
        self.d1 / self.segments
    }

    /// QHV elements per progressive-search segment.
    pub fn seg_len(&self) -> usize {
        self.seg_rows() * self.d2
    }

    pub fn from_manifest(name: &str, meta: &Json) -> Result<HdConfig> {
        let u = |k: &str| -> Result<usize> {
            meta.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("config {name}: missing field {k}"))
        };
        let f = |k: &str| -> Result<f64> {
            meta.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("config {name}: missing field {k}"))
        };
        let cfg = HdConfig {
            name: name.to_string(),
            f1: u("f1")?,
            f2: u("f2")?,
            d1: u("d1")?,
            d2: u("d2")?,
            segments: u("segments")?,
            classes: u("classes")?,
            qbits: u("qbits")? as u8,
            scale_x: f("scale_x")? as f32,
            scale_q: f("scale_q")? as f32,
            mean_absdiff: f("mean_absdiff")? as f32,
            batches: meta
                .get("batches")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_else(|| vec![1]),
            image: matches!(meta.get("image"), Some(Json::Bool(true))),
        };
        cfg.validate().context(format!("config {name}"))?;
        Ok(cfg)
    }

    /// Chip envelope checks (Fig.11 summary): F in 8..=1024, D in 1024..=8192,
    /// <=128 classes, segments divide d1.
    pub fn validate(&self) -> Result<()> {
        let f = self.features();
        let d = self.dim();
        if !(8..=1024).contains(&f) {
            return Err(anyhow!("feature dim {f} outside chip range 8-1024"));
        }
        if !(1024..=8192).contains(&d) {
            return Err(anyhow!("HDC dim {d} outside chip range 1024-8192"));
        }
        if self.classes == 0 || self.classes > 128 {
            return Err(anyhow!("classes {} outside chip range 1-128", self.classes));
        }
        if self.segments == 0 || self.d1 % self.segments != 0 {
            return Err(anyhow!(
                "segments {} must divide d1 {}",
                self.segments,
                self.d1
            ));
        }
        if !(1..=8).contains(&self.qbits) {
            return Err(anyhow!("qbits {} outside INT1-8", self.qbits));
        }
        Ok(())
    }

    /// A test/bench config without manifest round-trip.
    pub fn synthetic(name: &str, f1: usize, f2: usize, d1: usize, d2: usize,
                     segments: usize, classes: usize) -> HdConfig {
        HdConfig {
            name: name.into(),
            f1,
            f2,
            d1,
            d2,
            segments,
            classes,
            qbits: 8,
            scale_x: 1.0,
            scale_q: 8.0,
            mean_absdiff: 40.0,
            batches: vec![1],
            image: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_dims() {
        let c = HdConfig::synthetic("t", 8, 8, 32, 32, 8, 10);
        assert_eq!(c.features(), 64);
        assert_eq!(c.dim(), 1024);
        assert_eq!(c.seg_rows(), 4);
        assert_eq!(c.seg_len(), 128);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn validate_rejects_out_of_envelope() {
        let mut c = HdConfig::synthetic("t", 8, 8, 32, 32, 8, 10);
        c.classes = 200;
        assert!(c.validate().is_err());
        let mut c2 = HdConfig::synthetic("t", 8, 8, 32, 32, 7, 10);
        c2.segments = 7; // does not divide 32
        assert!(c2.validate().is_err());
        let c3 = HdConfig::synthetic("t", 2, 2, 32, 32, 8, 10); // F = 4 < 8
        assert!(c3.validate().is_err());
    }

    #[test]
    fn from_manifest_roundtrip() {
        let meta = Json::parse(
            r#"{"f1": 8, "f2": 8, "d1": 32, "d2": 32, "segments": 8,
                "classes": 10, "qbits": 8, "scale_x": 0.5, "scale_q": 3.0,
                "mean_absdiff": 40.5, "batches": [1, 8], "image": false}"#,
        )
        .unwrap();
        let c = HdConfig::from_manifest("tiny", &meta).unwrap();
        assert_eq!(c.batches, vec![1, 8]);
        assert_eq!(c.scale_q, 3.0);
        assert!(!c.image);
    }
}
