//! Length-prefixed binary wire protocol for the TCP serving layer.
//!
//! Zero-dependency framing: every message is `[len: u32 LE][payload]`,
//! where `len` counts the payload bytes only and is capped at
//! [`MAX_FRAME`] (an oversized length cannot desynchronize the stream into
//! unbounded allocation). Payloads are little-endian throughout.
//!
//! ## Request payloads
//!
//! ```text
//! id: u64, op: u8, then per op:
//!   OP_INFER     mode u8 (0 default | 1 l1 | 2 packed), n u32, n × f32
//!   OP_LEARN     class u32, n u32, n × f32
//!   OP_SNAPSHOT  path_len u16, path utf-8 (empty = server default)
//!   OP_STATS     (empty)
//! ```
//!
//! ## Response payloads
//!
//! ```text
//! id: u64, kind: u8, then per kind:
//!   OP_INFER     class u32, segments u32, early u8
//!   OP_LEARN     class u32
//!   OP_SNAPSHOT  path_len u16, path utf-8
//!   OP_STATS     served u64, wire_errors u64, learns u64,
//!                trained_classes u32, snapshots u64
//!   KIND_ERROR   msg_len u16, msg utf-8
//! ```
//!
//! Error recovery contract: a payload that *frames* correctly but decodes
//! badly (garbage opcode, truncated body, trailing bytes) gets a
//! [`WireResponse::Error`] reply and the connection survives — framing
//! keeps the stream in sync. Only a broken frame header or an oversized
//! length tears the connection down (after a best-effort error reply).

use crate::Result;
use anyhow::bail;
use std::io::{Read, Write};

/// Hard cap on a frame payload (16 MiB — far above any request the HD
/// configs can produce, small enough to bound a malicious allocation).
pub const MAX_FRAME: usize = 16 * 1024 * 1024;

pub const OP_INFER: u8 = 1;
pub const OP_LEARN: u8 = 2;
pub const OP_SNAPSHOT: u8 = 3;
pub const OP_STATS: u8 = 4;
/// Response-only kind tag for error replies.
pub const KIND_ERROR: u8 = 0xEE;

/// Per-request search-mode selector on [`WireRequest::Infer`].
pub const MODE_DEFAULT: u8 = 0;
pub const MODE_L1: u8 = 1;
pub const MODE_PACKED: u8 = 2;

/// One frame-read outcome.
#[derive(Debug)]
pub enum Frame {
    /// a complete payload
    Payload(Vec<u8>),
    /// clean EOF at a frame boundary (peer closed)
    Eof,
    /// read timeout with zero bytes consumed (still at a frame boundary;
    /// safe to retry)
    Idle,
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// Fill `buf` completely. Read timeouts *inside* a frame keep waiting (the
/// peer has committed to the frame; the bound of ~150 retries ≈ 30 s at
/// the server's 200 ms read timeout stops a stalled peer from pinning a
/// thread forever); EOF mid-buffer is a hard error — bytes were consumed,
/// the stream is no longer at a frame boundary.
fn read_full(r: &mut impl Read, buf: &mut [u8], what: &str) -> Result<()> {
    let mut got = 0usize;
    let mut idle = 0u32;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => bail!("connection closed mid-{what} ({got}/{} bytes)", buf.len()),
            Ok(n) => {
                got += n;
                idle = 0;
            }
            Err(e) if is_timeout(&e) => {
                idle += 1;
                if idle > 150 {
                    bail!("peer stalled mid-{what} ({got}/{} bytes)", buf.len());
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Read one frame. `Idle` is only returned when the read timed out with
/// zero bytes consumed; `Eof` only on a clean close at a frame boundary.
pub fn read_frame(r: &mut impl Read, max: usize) -> Result<Frame> {
    let mut hdr = [0u8; 4];
    // distinguish idle-timeout from clean EOF: peek at the first byte
    let mut first = [0u8; 1];
    loop {
        match r.read(&mut first) {
            Ok(0) => return Ok(Frame::Eof),
            Ok(1) => break,
            Ok(_) => unreachable!("read > buf"),
            Err(e) if is_timeout(&e) => return Ok(Frame::Idle),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    hdr[0] = first[0];
    read_full(r, &mut hdr[1..], "frame header")?;
    let len = u32::from_le_bytes(hdr) as usize;
    if len > max {
        bail!("frame length {len} exceeds the {max}-byte cap");
    }
    let mut buf = vec![0u8; len];
    read_full(r, &mut buf, "frame body")?;
    Ok(Frame::Payload(buf))
}

/// Write one `[len][payload]` frame and flush it.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        bail!("refusing to send a {}-byte frame (cap {MAX_FRAME})", payload.len());
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Best-effort request id of a framed-but-garbled payload (for addressing
/// the error reply); 0 when even the id is missing.
pub fn peek_id(payload: &[u8]) -> u64 {
    if payload.len() >= 8 {
        u64::from_le_bytes(payload[0..8].try_into().unwrap())
    } else {
        0
    }
}

fn put_str16(out: &mut Vec<u8>, s: &str) {
    let b = s.as_bytes();
    let n = b.len().min(u16::MAX as usize);
    out.extend_from_slice(&(n as u16).to_le_bytes());
    out.extend_from_slice(&b[..n]);
}

/// A decoded client request.
#[derive(Clone, Debug, PartialEq)]
pub enum WireRequest {
    Infer { id: u64, mode: u8, features: Vec<f32> },
    Learn { id: u64, class: u32, features: Vec<f32> },
    Snapshot { id: u64, path: String },
    Stats { id: u64 },
}

impl WireRequest {
    pub fn id(&self) -> u64 {
        match self {
            WireRequest::Infer { id, .. }
            | WireRequest::Learn { id, .. }
            | WireRequest::Snapshot { id, .. }
            | WireRequest::Stats { id } => *id,
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            WireRequest::Infer { id, mode, features } => {
                out.extend_from_slice(&id.to_le_bytes());
                out.push(OP_INFER);
                out.push(*mode);
                out.extend_from_slice(&(features.len() as u32).to_le_bytes());
                for v in features {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            WireRequest::Learn { id, class, features } => {
                out.extend_from_slice(&id.to_le_bytes());
                out.push(OP_LEARN);
                out.extend_from_slice(&class.to_le_bytes());
                out.extend_from_slice(&(features.len() as u32).to_le_bytes());
                for v in features {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            WireRequest::Snapshot { id, path } => {
                out.extend_from_slice(&id.to_le_bytes());
                out.push(OP_SNAPSHOT);
                put_str16(&mut out, path);
            }
            WireRequest::Stats { id } => {
                out.extend_from_slice(&id.to_le_bytes());
                out.push(OP_STATS);
            }
        }
        out
    }

    pub fn decode(payload: &[u8]) -> Result<WireRequest> {
        let mut c = crate::util::Cursor::new(payload);
        let id = c.u64()?;
        let op = c.u8()?;
        let req = match op {
            OP_INFER => {
                let mode = c.u8()?;
                if mode > MODE_PACKED {
                    bail!("unknown infer mode {mode} (0=default 1=l1 2=packed)");
                }
                let n = c.u32()? as usize;
                WireRequest::Infer { id, mode, features: c.f32s(n)? }
            }
            OP_LEARN => {
                let class = c.u32()?;
                let n = c.u32()? as usize;
                WireRequest::Learn { id, class, features: c.f32s(n)? }
            }
            OP_SNAPSHOT => WireRequest::Snapshot { id, path: c.str16()? },
            OP_STATS => WireRequest::Stats { id },
            other => bail!("unknown opcode {other:#04x}"),
        };
        c.finish()?;
        Ok(req)
    }
}

/// Server-side counters a Stats reply carries.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireStats {
    /// frames served (all opcodes, error replies included)
    pub served: u64,
    /// frames that decoded badly (the error-reply count)
    pub wire_errors: u64,
    /// total bundled learns in the live knowledge store
    pub learns: u64,
    /// classes with at least one bundled sample
    pub trained_classes: u32,
    /// snapshots written this process
    pub snapshots: u64,
}

/// A decoded server reply.
#[derive(Clone, Debug, PartialEq)]
pub enum WireResponse {
    Infer { id: u64, class: u32, segments: u32, early: bool },
    Learn { id: u64, class: u32 },
    Snapshot { id: u64, path: String },
    Stats { id: u64, stats: WireStats },
    Error { id: u64, msg: String },
}

impl WireResponse {
    pub fn id(&self) -> u64 {
        match self {
            WireResponse::Infer { id, .. }
            | WireResponse::Learn { id, .. }
            | WireResponse::Snapshot { id, .. }
            | WireResponse::Stats { id, .. }
            | WireResponse::Error { id, .. } => *id,
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            WireResponse::Infer { id, class, segments, early } => {
                out.extend_from_slice(&id.to_le_bytes());
                out.push(OP_INFER);
                out.extend_from_slice(&class.to_le_bytes());
                out.extend_from_slice(&segments.to_le_bytes());
                out.push(u8::from(*early));
            }
            WireResponse::Learn { id, class } => {
                out.extend_from_slice(&id.to_le_bytes());
                out.push(OP_LEARN);
                out.extend_from_slice(&class.to_le_bytes());
            }
            WireResponse::Snapshot { id, path } => {
                out.extend_from_slice(&id.to_le_bytes());
                out.push(OP_SNAPSHOT);
                put_str16(&mut out, path);
            }
            WireResponse::Stats { id, stats } => {
                out.extend_from_slice(&id.to_le_bytes());
                out.push(OP_STATS);
                out.extend_from_slice(&stats.served.to_le_bytes());
                out.extend_from_slice(&stats.wire_errors.to_le_bytes());
                out.extend_from_slice(&stats.learns.to_le_bytes());
                out.extend_from_slice(&stats.trained_classes.to_le_bytes());
                out.extend_from_slice(&stats.snapshots.to_le_bytes());
            }
            WireResponse::Error { id, msg } => {
                out.extend_from_slice(&id.to_le_bytes());
                out.push(KIND_ERROR);
                put_str16(&mut out, msg);
            }
        }
        out
    }

    pub fn decode(payload: &[u8]) -> Result<WireResponse> {
        let mut c = crate::util::Cursor::new(payload);
        let id = c.u64()?;
        let kind = c.u8()?;
        let resp = match kind {
            OP_INFER => WireResponse::Infer {
                id,
                class: c.u32()?,
                segments: c.u32()?,
                early: c.u8()? != 0,
            },
            OP_LEARN => WireResponse::Learn { id, class: c.u32()? },
            OP_SNAPSHOT => WireResponse::Snapshot { id, path: c.str16()? },
            OP_STATS => WireResponse::Stats {
                id,
                stats: WireStats {
                    served: c.u64()?,
                    wire_errors: c.u64()?,
                    learns: c.u64()?,
                    trained_classes: c.u32()?,
                    snapshots: c.u64()?,
                },
            },
            KIND_ERROR => WireResponse::Error { id, msg: c.str16()? },
            other => bail!("unknown response kind {other:#04x}"),
        };
        c.finish()?;
        Ok(resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(r: WireRequest) {
        let bytes = r.encode();
        assert_eq!(WireRequest::decode(&bytes).unwrap(), r);
    }

    fn roundtrip_resp(r: WireResponse) {
        let bytes = r.encode();
        assert_eq!(WireResponse::decode(&bytes).unwrap(), r);
    }

    #[test]
    fn request_roundtrips() {
        roundtrip_req(WireRequest::Infer {
            id: 7,
            mode: MODE_PACKED,
            features: vec![1.5, -2.25, 0.0],
        });
        roundtrip_req(WireRequest::Infer { id: 8, mode: MODE_DEFAULT, features: vec![] });
        roundtrip_req(WireRequest::Learn { id: 9, class: 3, features: vec![42.0; 64] });
        roundtrip_req(WireRequest::Snapshot { id: 10, path: "k.clok".into() });
        roundtrip_req(WireRequest::Snapshot { id: 11, path: String::new() });
        roundtrip_req(WireRequest::Stats { id: 12 });
    }

    #[test]
    fn response_roundtrips() {
        roundtrip_resp(WireResponse::Infer { id: 1, class: 4, segments: 3, early: true });
        roundtrip_resp(WireResponse::Learn { id: 2, class: 0 });
        roundtrip_resp(WireResponse::Snapshot { id: 3, path: "a/b.clok".into() });
        roundtrip_resp(WireResponse::Stats {
            id: 4,
            stats: WireStats {
                served: 100,
                wire_errors: 2,
                learns: 40,
                trained_classes: 9,
                snapshots: 1,
            },
        });
        roundtrip_resp(WireResponse::Error { id: 5, msg: "class 99 out of range".into() });
    }

    #[test]
    fn decode_rejects_garbage_opcode_truncation_and_trailing() {
        let good = WireRequest::Infer { id: 1, mode: 0, features: vec![1.0] }.encode();
        // garbage opcode
        let mut bad = good.clone();
        bad[8] = 0x77;
        assert!(WireRequest::decode(&bad).unwrap_err().to_string().contains("opcode"));
        // truncated feature block
        assert!(WireRequest::decode(&good[..good.len() - 2]).is_err());
        // short header
        assert!(WireRequest::decode(&good[..5]).is_err());
        // trailing bytes
        let mut bad = good.clone();
        bad.push(0);
        assert!(WireRequest::decode(&bad).unwrap_err().to_string().contains("trailing"));
        // bad infer mode
        let mut bad = good;
        bad[9] = 9;
        assert!(WireRequest::decode(&bad).unwrap_err().to_string().contains("mode"));
    }

    #[test]
    fn decode_rejects_absurd_feature_count() {
        // n claims 2^31 floats but the payload carries none
        let mut b = Vec::new();
        b.extend_from_slice(&1u64.to_le_bytes());
        b.push(OP_INFER);
        b.push(MODE_DEFAULT);
        b.extend_from_slice(&(u32::MAX).to_le_bytes());
        assert!(WireRequest::decode(&b).is_err());
    }

    #[test]
    fn frame_io_roundtrip_and_caps() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = std::io::Cursor::new(buf);
        match read_frame(&mut r, MAX_FRAME).unwrap() {
            Frame::Payload(p) => assert_eq!(p, b"hello"),
            other => panic!("{other:?}"),
        }
        match read_frame(&mut r, MAX_FRAME).unwrap() {
            Frame::Payload(p) => assert!(p.is_empty()),
            other => panic!("{other:?}"),
        }
        assert!(matches!(read_frame(&mut r, MAX_FRAME).unwrap(), Frame::Eof));
    }

    #[test]
    fn oversized_length_is_rejected_before_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        buf.extend_from_slice(b"whatever");
        let mut r = std::io::Cursor::new(buf);
        let e = read_frame(&mut r, MAX_FRAME).unwrap_err().to_string();
        assert!(e.contains("exceeds"), "{e}");
        // caller-tightened cap too
        let mut buf = Vec::new();
        write_frame(&mut buf, &[0u8; 100]).unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert!(read_frame(&mut r, 10).is_err());
    }

    #[test]
    fn truncated_header_and_body_error() {
        let mut r = std::io::Cursor::new(vec![5u8, 0]);
        assert!(read_frame(&mut r, MAX_FRAME).is_err(), "2-byte header");
        let mut buf = Vec::new();
        buf.extend_from_slice(&10u32.to_le_bytes());
        buf.extend_from_slice(b"only4");
        let mut r = std::io::Cursor::new(buf);
        assert!(read_frame(&mut r, MAX_FRAME).is_err(), "truncated body");
    }

    #[test]
    fn peek_id_best_effort() {
        let req = WireRequest::Stats { id: 0xDEAD_BEEF };
        assert_eq!(peek_id(&req.encode()), 0xDEAD_BEEF);
        assert_eq!(peek_id(&[1, 2, 3]), 0);
    }

    #[test]
    fn write_frame_emits_len_prefix() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &[0xAB; 8]).unwrap();
        assert_eq!(&buf[..4], &8u32.to_le_bytes());
        assert_eq!(buf.len(), 12);
        assert!(MAX_FRAME >= 1 << 20);
    }
}
