//! Length-prefixed binary wire protocol for the TCP serving layer.
//!
//! Zero-dependency framing: every message is `[len: u32 LE][payload]`,
//! where `len` counts the payload bytes only and is capped at
//! [`MAX_FRAME`] (an oversized length cannot desynchronize the stream into
//! unbounded allocation). Payloads are little-endian throughout.
//!
//! Two request encodings exist — see `docs/PROTOCOL.md` for the full
//! byte-level specification (its constants are pinned against this module
//! by `tests/protocol_doc.rs`):
//!
//! * **v1** (the launch protocol): `id: u64, op: u8, body` — one implicit
//!   model (the server default), one request in flight at a time by
//!   convention.
//! * **v2** (negotiated): `id: u64, op: u8, model: str16, body` — every
//!   request names its target model (`""` = server default) and a
//!   connection may keep up to [`MAX_INFLIGHT`] client-id'd frames in
//!   flight; replies are matched by id and may complete out of order.
//!
//! Version negotiation: a client sends [`ReqBody::Hello`] — always encoded
//! in the v1 shape, so it parses before any negotiation has happened — and
//! the server replies [`WireResponse::Hello`] with the negotiated version,
//! its default model, and the model list. A v1 client simply never says
//! hello and is served exactly as before. Response frames use one shape in
//! both versions.
//!
//! ## Request payloads (after the version-dependent header)
//!
//! ```text
//! OP_INFER      mode u8 (0 default | 1 l1 | 2 packed), n u32, n × f32
//! OP_LEARN      class u32, n u32, n × f32
//! OP_SNAPSHOT   path_len u16, path utf-8 (empty = server default)
//! OP_STATS      (empty)
//! OP_HELLO      version u32 (the highest version the client speaks)
//! OP_CONN_STATS (empty — answered by the reactor, never an executor)
//! OP_WAL_TAIL   after u64 (highest learn sequence the caller has applied)
//! OP_SNAPSHOT_FETCH (empty)
//! OP_INFER_IMAGE mode u8 (as OP_INFER), n u32, n × f32 raw pixels — the
//!                server routes per its mode policy (WCFE or bypass)
//! OP_LEARN_IMAGE class u32, n u32, n × f32 raw pixels
//! OP_PROMOTE    (empty — promotes the target model to a new epoch)
//! OP_MODEL_ADD  name str16, source str16 (template model to clone the
//!               geometry from; "" = the server default)
//! OP_MODEL_REMOVE name str16
//! ```
//!
//! ## Response payloads
//!
//! ```text
//! id: u64, kind: u8, then per kind:
//!   OP_INFER     class u32, segments u32, early u8, flags u8
//!                (bit0 = WCFE ran, bit1 = confidence-escalated),
//!                energy_j f64 (image infers reply with this kind too)
//!   OP_LEARN     class u32
//!   OP_SNAPSHOT  path_len u16, path utf-8
//!   OP_STATS     served u64, wire_errors u64, learns u64,
//!                trained_classes u32, snapshots u64, learn_seq u64,
//!                bypass u64, normal u64, escalations u64, policy u8
//!                (0 auto | 1 force-bypass | 2 force-normal | 3 confidence),
//!                policy_margin f32, epoch u64
//!   OP_HELLO     version u32, default_model str16,
//!                count u16, count × model str16
//!   OP_CONN_STATS conn_id u64, age_ms u64, frames u64, replies u64,
//!                errors u64, inflight u32, pending u32, peak_window u32,
//!                queued_write_bytes u64
//!   OP_WAL_TAIL  base_seq u64, last_seq u64, epoch u64, count u32,
//!                count × (rec_len u32, rec: seq u64, class u32,
//!                         n u32, n × f32)
//!   OP_SNAPSHOT_FETCH last_seq u64, img_len u32, image (CLOK bytes)
//!   OP_PROMOTE   epoch u64 (the new generation), base_seq u64 (the
//!                sealed learn sequence the new segment opened at)
//!   OP_MODEL_ADD / OP_MODEL_REMOVE (one shape, kind echoes the opcode)
//!                count u16, count × model str16 (the post-mutation list)
//!   KIND_ERROR   msg_len u16, msg utf-8
//! ```
//!
//! Error recovery contract: a payload that *frames* correctly but decodes
//! badly (garbage opcode, truncated body, trailing bytes) gets a
//! [`WireResponse::Error`] reply — echoing the request id whenever the
//! payload carried one — and the connection survives: framing keeps the
//! stream in sync, so under pipelining the other in-flight requests (and
//! every other model on the server) are unaffected. Only a broken frame
//! header or an oversized length tears the connection down (after a
//! best-effort error reply).

use crate::hdc::wal::WalRecord;
use crate::Result;
use anyhow::bail;
use std::io::{Read, Write};

/// Hard cap on a frame payload (16 MiB — far above any request the HD
/// configs can produce, small enough to bound a malicious allocation).
pub const MAX_FRAME: usize = 16 * 1024 * 1024;

/// Wire protocol v1: single implicit model, no model field in requests.
pub const WIRE_V1: u32 = 1;
/// Wire protocol v2: model-addressed, pipelined requests (negotiated via
/// a hello frame).
pub const WIRE_V2: u32 = 2;

/// Server-side cap on in-flight (pipelined) frames per connection. A v2
/// client may keep up to this many requests outstanding; further frames
/// are simply not read until replies drain (TCP backpressure).
pub const MAX_INFLIGHT: usize = 64;

/// Classification request/reply opcode.
pub const OP_INFER: u8 = 1;
/// Learning (bundle one labeled sample) request/reply opcode.
pub const OP_LEARN: u8 = 2;
/// Knowledge-checkpoint request/reply opcode.
pub const OP_SNAPSHOT: u8 = 3;
/// Counter-snapshot request/reply opcode.
pub const OP_STATS: u8 = 4;
/// Version-negotiation request/reply opcode (always v1-shaped on the
/// request side).
pub const OP_HELLO: u8 = 5;
/// Per-connection counter-snapshot request/reply opcode. Scoped to the
/// connection that sends it (the model field, if present, is ignored) and
/// answered by the serving reactor directly — it never crosses an
/// executor, so it stays answerable even when the executors are saturated.
pub const OP_CONN_STATS: u8 = 6;
/// Learn-log tail request/reply opcode: the records with sequence number
/// greater than the caller's `after` (replication tailing; requires the
/// target model to run with a WAL).
pub const OP_WAL_TAIL: u8 = 7;
/// In-memory knowledge-image request/reply opcode: the target model's live
/// store serialized as CLOK bytes (replication bootstrap).
pub const OP_SNAPSHOT_FETCH: u8 = 8;
/// Image-classification request opcode: the body carries raw pixels
/// (h*w*c row-major, values in [0,1]) instead of features; the server's
/// dual-mode router decides whether the WCFE runs. Replies use the
/// [`OP_INFER`] kind.
pub const OP_INFER_IMAGE: u8 = 9;
/// Image-learning request opcode: a labeled raw image; the server extracts
/// features per its mode policy before bundling. Replies use the
/// [`OP_LEARN`] kind.
pub const OP_LEARN_IMAGE: u8 = 10;
/// Follower-promotion admin opcode: the target model bumps its epoch
/// (generation counter), seals its inherited WAL position by rotating to
/// a fresh segment at `base_seq = learn_seq`, and starts a new primary
/// lineage. The reply carries the new epoch and the sealed base.
pub const OP_PROMOTE: u8 = 11;
/// Dynamic-registry admin opcode: spin up a named model at runtime,
/// cloning its geometry from a source template model. The reply carries
/// the post-mutation model list.
pub const OP_MODEL_ADD: u8 = 12;
/// Dynamic-registry admin opcode: tear down a named model at runtime
/// (knowledge flush + WAL close on the way out). The default model cannot
/// be removed. The reply carries the post-mutation model list.
pub const OP_MODEL_REMOVE: u8 = 13;
/// Response-only kind tag for error replies.
pub const KIND_ERROR: u8 = 0xEE;

/// [`WireResponse::Infer`] flags bit: the WCFE front-end ran (normal mode).
pub const FLAG_WCFE: u8 = 1;
/// [`WireResponse::Infer`] flags bit: a Confidence policy re-ran the
/// request through the WCFE after a thin bypass margin (implies
/// [`FLAG_WCFE`]).
pub const FLAG_ESCALATED: u8 = 2;

/// Per-request search-mode selector on [`ReqBody::Infer`]: the server's
/// configured default kernel.
pub const MODE_DEFAULT: u8 = 0;
/// Per-request search-mode selector: scalar INT8 L1.
pub const MODE_L1: u8 = 1;
/// Per-request search-mode selector: bit-packed INT1 Hamming.
pub const MODE_PACKED: u8 = 2;

/// One frame-read outcome.
#[derive(Debug)]
pub enum Frame {
    /// a complete payload
    Payload(Vec<u8>),
    /// clean EOF at a frame boundary (peer closed)
    Eof,
    /// read timeout with zero bytes consumed (still at a frame boundary;
    /// safe to retry)
    Idle,
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// Fill `buf` completely. Read timeouts *inside* a frame keep waiting (the
/// peer has committed to the frame; the bound of ~150 retries ≈ 30 s at
/// the server's 200 ms read timeout stops a stalled peer from pinning a
/// thread forever); EOF mid-buffer is a hard error — bytes were consumed,
/// the stream is no longer at a frame boundary.
fn read_full(r: &mut impl Read, buf: &mut [u8], what: &str) -> Result<()> {
    let mut got = 0usize;
    let mut idle = 0u32;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => bail!("connection closed mid-{what} ({got}/{} bytes)", buf.len()),
            Ok(n) => {
                got += n;
                idle = 0;
            }
            Err(e) if is_timeout(&e) => {
                idle += 1;
                if idle > 150 {
                    bail!("peer stalled mid-{what} ({got}/{} bytes)", buf.len());
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Read one frame. `Idle` is only returned when the read timed out with
/// zero bytes consumed; `Eof` only on a clean close at a frame boundary.
pub fn read_frame(r: &mut impl Read, max: usize) -> Result<Frame> {
    let mut hdr = [0u8; 4];
    // distinguish idle-timeout from clean EOF: peek at the first byte
    let mut first = [0u8; 1];
    loop {
        match r.read(&mut first) {
            Ok(0) => return Ok(Frame::Eof),
            Ok(1) => break,
            Ok(_) => unreachable!("read > buf"),
            Err(e) if is_timeout(&e) => return Ok(Frame::Idle),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    hdr[0] = first[0];
    read_full(r, &mut hdr[1..], "frame header")?;
    let len = u32::from_le_bytes(hdr) as usize;
    if len > max {
        bail!("frame length {len} exceeds the {max}-byte cap");
    }
    let mut buf = vec![0u8; len];
    read_full(r, &mut buf, "frame body")?;
    Ok(Frame::Payload(buf))
}

/// Write one `[len][payload]` frame and flush it.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        bail!("refusing to send a {}-byte frame (cap {MAX_FRAME})", payload.len());
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Best-effort request id of a framed-but-garbled payload (for addressing
/// the error reply); 0 when even the id is missing.
pub fn peek_id(payload: &[u8]) -> u64 {
    if payload.len() >= 8 {
        u64::from_le_bytes(payload[0..8].try_into().unwrap())
    } else {
        0
    }
}

/// Incremental frame reassembly for a non-blocking connection: bytes
/// arrive in arbitrary chunks (a read may split a frame anywhere, even
/// mid-length-prefix), [`FrameAssembler::extend`] buffers them, and
/// [`FrameAssembler::next_payload`] yields each complete payload exactly
/// as [`read_frame`] would have on a blocking stream.
///
/// The only hard failure is an oversized length prefix — it is rejected
/// as soon as the four header bytes are present, before any payload
/// allocation, and the assembler is then poisoned (the stream can no
/// longer be trusted to be at a frame boundary).
#[derive(Debug)]
pub struct FrameAssembler {
    buf: Vec<u8>,
    /// bytes of `buf` already consumed by completed frames (compacted
    /// lazily so each arriving chunk is not memmoved)
    pos: usize,
    max: usize,
    poisoned: bool,
}

impl FrameAssembler {
    /// An empty assembler enforcing the given payload cap (normally
    /// [`MAX_FRAME`]).
    pub fn new(max: usize) -> FrameAssembler {
        FrameAssembler { buf: Vec::new(), pos: 0, max, poisoned: false }
    }

    /// Buffer one arriving chunk (any size, including empty).
    pub fn extend(&mut self, chunk: &[u8]) {
        self.buf.extend_from_slice(chunk);
    }

    /// Bytes buffered but not yet consumed by a completed frame.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Whether the buffered bytes start a frame that has not completed —
    /// i.e. the peer went away mid-frame if EOF arrives now.
    pub fn mid_frame(&self) -> bool {
        self.buffered() > 0
    }

    /// Pop the next complete payload: `Ok(None)` when more bytes are
    /// needed (an incomplete header or body), `Err` when the length
    /// prefix exceeds the cap (connection-fatal, see type docs).
    pub fn next_payload(&mut self) -> Result<Option<Vec<u8>>> {
        if self.poisoned {
            bail!("frame stream poisoned by an earlier oversized length");
        }
        if self.buffered() < 4 {
            self.compact();
            return Ok(None);
        }
        let hdr: [u8; 4] = self.buf[self.pos..self.pos + 4].try_into().unwrap();
        let len = u32::from_le_bytes(hdr) as usize;
        if len > self.max {
            self.poisoned = true;
            bail!("frame length {len} exceeds the {}-byte cap", self.max);
        }
        if self.buffered() < 4 + len {
            self.compact();
            return Ok(None);
        }
        let payload = self.buf[self.pos + 4..self.pos + 4 + len].to_vec();
        self.pos += 4 + len;
        self.compact();
        Ok(Some(payload))
    }

    /// Drop consumed bytes once they dominate the buffer (keeps the
    /// amortized cost of `extend` linear without memmoving every frame).
    fn compact(&mut self) {
        if self.pos >= 4096 && self.pos * 2 >= self.buf.len() {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
    }
}

fn put_str16(out: &mut Vec<u8>, s: &str) {
    let b = s.as_bytes();
    let n = b.len().min(u16::MAX as usize);
    out.extend_from_slice(&(n as u16).to_le_bytes());
    out.extend_from_slice(&b[..n]);
}

/// The operation-specific body of a request frame (everything after the
/// id/op/model header).
#[derive(Clone, Debug, PartialEq)]
pub enum ReqBody {
    /// classify a feature vector (optionally forcing a search kernel via
    /// [`MODE_L1`]/[`MODE_PACKED`])
    Infer {
        /// search-kernel selector ([`MODE_DEFAULT`]/[`MODE_L1`]/[`MODE_PACKED`])
        mode: u8,
        /// the feature vector (length must match the target model's config)
        features: Vec<f32>,
    },
    /// bundle one labeled sample into the target model's knowledge store
    Learn {
        /// the sample's class label
        class: u32,
        /// the feature vector
        features: Vec<f32>,
    },
    /// checkpoint the target model's knowledge (empty path = the server's
    /// configured default for that model)
    Snapshot {
        /// server-side checkpoint path ("" = configured default)
        path: String,
    },
    /// report serving + knowledge counters for the target model
    Stats,
    /// report the sending connection's own reactor-side counters (the
    /// model field is carried-but-ignored on v2; the reply never touches
    /// an executor)
    ConnStats,
    /// fetch the target model's learn-log records newer than `after`
    /// (replication tailing; errors when the model keeps no WAL, or when
    /// `after` predates the log's fold point — re-bootstrap with
    /// [`ReqBody::SnapshotFetch`] in that case)
    WalTail {
        /// the highest learn sequence the caller has already applied
        after: u64,
    },
    /// fetch the target model's live knowledge store as CLOK bytes
    /// (replication bootstrap; works with or without a WAL)
    SnapshotFetch,
    /// classify a raw image (the server's dual-mode router decides whether
    /// the WCFE front-end runs); the reply is an ordinary
    /// [`WireResponse::Infer`] whose flags report what the router did
    InferImage {
        /// search-kernel selector ([`MODE_DEFAULT`]/[`MODE_L1`]/[`MODE_PACKED`])
        mode: u8,
        /// raw pixels, h*w*c row-major in [0,1] (length must match the
        /// target model's WCFE image geometry — or its feature count,
        /// under a bypass route)
        pixels: Vec<f32>,
    },
    /// bundle one labeled raw image (features are extracted server-side
    /// when the mode policy routes image learns through the WCFE)
    LearnImage {
        /// the sample's class label
        class: u32,
        /// raw pixels, h*w*c row-major in [0,1]
        pixels: Vec<f32>,
    },
    /// negotiate the wire version (always encoded in the v1 shape)
    Hello {
        /// highest protocol version the client speaks
        version: u32,
    },
    /// promote the target model to a new epoch (follower promotion: seal
    /// the inherited WAL position, open a fresh segment, start accepting
    /// learns as the new primary generation)
    Promote,
    /// spin up a named model at runtime, cloning its serving geometry
    /// from a source template model
    ModelAdd {
        /// the new model's name (must not collide with a hosted model)
        name: String,
        /// the template model whose configuration is cloned (`""` = the
        /// server default model)
        source: String,
    },
    /// tear down a named model at runtime (the default model is refused)
    ModelRemove {
        /// the model to remove
        name: String,
    },
}

/// A decoded client request: client-assigned id, target model (`""` =
/// server default; only encodable on wire v2), and the operation body.
#[derive(Clone, Debug, PartialEq)]
pub struct WireRequest {
    /// client-assigned request id, echoed on the matching reply (including
    /// error replies). Pipelined clients must keep in-flight ids unique.
    pub id: u64,
    /// target model name; empty = the server's default model
    pub model: String,
    /// the operation
    pub body: ReqBody,
}

impl WireRequest {
    /// A request for the server's default model.
    pub fn new(id: u64, body: ReqBody) -> WireRequest {
        WireRequest { id, model: String::new(), body }
    }

    /// A request targeting a named model (requires wire v2 on encode).
    pub fn for_model(id: u64, model: impl Into<String>, body: ReqBody) -> WireRequest {
        WireRequest { id, model: model.into(), body }
    }

    /// The opcode byte this request encodes with.
    pub fn op(&self) -> u8 {
        match self.body {
            ReqBody::Infer { .. } => OP_INFER,
            ReqBody::Learn { .. } => OP_LEARN,
            ReqBody::Snapshot { .. } => OP_SNAPSHOT,
            ReqBody::Stats => OP_STATS,
            ReqBody::ConnStats => OP_CONN_STATS,
            ReqBody::WalTail { .. } => OP_WAL_TAIL,
            ReqBody::SnapshotFetch => OP_SNAPSHOT_FETCH,
            ReqBody::InferImage { .. } => OP_INFER_IMAGE,
            ReqBody::LearnImage { .. } => OP_LEARN_IMAGE,
            ReqBody::Hello { .. } => OP_HELLO,
            ReqBody::Promote => OP_PROMOTE,
            ReqBody::ModelAdd { .. } => OP_MODEL_ADD,
            ReqBody::ModelRemove { .. } => OP_MODEL_REMOVE,
        }
    }

    /// Encode at the given wire version. Model-targeted requests refuse
    /// the v1 encoding (silently dropping the model would route the
    /// request to the wrong knowledge store).
    pub fn encode(&self, version: u32) -> Result<Vec<u8>> {
        if version != WIRE_V1 && version != WIRE_V2 {
            bail!("unknown wire version {version} (have {WIRE_V1} and {WIRE_V2})");
        }
        let hello = matches!(self.body, ReqBody::Hello { .. });
        if !self.model.is_empty() && (version == WIRE_V1 || hello) {
            bail!(
                "model-targeted requests need wire v2 (negotiate with a hello \
                 frame first; hello itself is model-free)"
            );
        }
        let mut out = Vec::new();
        out.extend_from_slice(&self.id.to_le_bytes());
        out.push(self.op());
        if version == WIRE_V2 && !hello {
            put_str16(&mut out, &self.model);
        }
        match &self.body {
            ReqBody::Infer { mode, features } | ReqBody::InferImage { mode, pixels: features } => {
                out.push(*mode);
                out.extend_from_slice(&(features.len() as u32).to_le_bytes());
                for v in features {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            ReqBody::Learn { class, features }
            | ReqBody::LearnImage { class, pixels: features } => {
                out.extend_from_slice(&class.to_le_bytes());
                out.extend_from_slice(&(features.len() as u32).to_le_bytes());
                for v in features {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            ReqBody::Snapshot { path } => put_str16(&mut out, path),
            ReqBody::Stats | ReqBody::ConnStats | ReqBody::SnapshotFetch | ReqBody::Promote => {}
            ReqBody::WalTail { after } => out.extend_from_slice(&after.to_le_bytes()),
            ReqBody::Hello { version } => out.extend_from_slice(&version.to_le_bytes()),
            ReqBody::ModelAdd { name, source } => {
                put_str16(&mut out, name);
                put_str16(&mut out, source);
            }
            ReqBody::ModelRemove { name } => put_str16(&mut out, name),
        }
        Ok(out)
    }

    /// Decode a request payload under the connection's negotiated version.
    /// Hello frames are always v1-shaped (they are what negotiates v2), so
    /// the model field is skipped for them in either version.
    pub fn decode(payload: &[u8], version: u32) -> Result<WireRequest> {
        if version != WIRE_V1 && version != WIRE_V2 {
            bail!("unknown wire version {version} (have {WIRE_V1} and {WIRE_V2})");
        }
        let mut c = crate::util::Cursor::new(payload);
        let id = c.u64()?;
        let op = c.u8()?;
        let model = if version == WIRE_V2 && op != OP_HELLO {
            c.str16()?
        } else {
            String::new()
        };
        let body = match op {
            OP_INFER => {
                let mode = c.u8()?;
                if mode > MODE_PACKED {
                    bail!("unknown infer mode {mode} (0=default 1=l1 2=packed)");
                }
                let n = c.u32()? as usize;
                ReqBody::Infer { mode, features: c.f32s(n)? }
            }
            OP_LEARN => {
                let class = c.u32()?;
                let n = c.u32()? as usize;
                ReqBody::Learn { class, features: c.f32s(n)? }
            }
            OP_SNAPSHOT => ReqBody::Snapshot { path: c.str16()? },
            OP_STATS => ReqBody::Stats,
            OP_CONN_STATS => ReqBody::ConnStats,
            OP_WAL_TAIL => ReqBody::WalTail { after: c.u64()? },
            OP_SNAPSHOT_FETCH => ReqBody::SnapshotFetch,
            OP_INFER_IMAGE => {
                let mode = c.u8()?;
                if mode > MODE_PACKED {
                    bail!("unknown infer mode {mode} (0=default 1=l1 2=packed)");
                }
                let n = c.u32()? as usize;
                ReqBody::InferImage { mode, pixels: c.f32s(n)? }
            }
            OP_LEARN_IMAGE => {
                let class = c.u32()?;
                let n = c.u32()? as usize;
                ReqBody::LearnImage { class, pixels: c.f32s(n)? }
            }
            OP_HELLO => ReqBody::Hello { version: c.u32()? },
            OP_PROMOTE => ReqBody::Promote,
            OP_MODEL_ADD => ReqBody::ModelAdd { name: c.str16()?, source: c.str16()? },
            OP_MODEL_REMOVE => ReqBody::ModelRemove { name: c.str16()? },
            other => bail!("unknown opcode {other:#04x}"),
        };
        c.finish()?;
        Ok(WireRequest { id, model, body })
    }
}

/// Server-side counters a Stats reply carries. `served`/`wire_errors` are
/// process-wide; the knowledge counters belong to the model the Stats
/// request targeted.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WireStats {
    /// frames served process-wide (all opcodes, error replies included)
    pub served: u64,
    /// frames that decoded badly process-wide (the error-reply count)
    pub wire_errors: u64,
    /// total bundled learns in the target model's live knowledge store
    pub learns: u64,
    /// target-model classes with at least one bundled sample
    pub trained_classes: u32,
    /// snapshots the target model wrote this process
    pub snapshots: u64,
    /// the target model's monotonic learn sequence: its WAL's last
    /// acknowledged sequence when it logs learns, else its live learn
    /// count. A follower compares this against its own applied sequence to
    /// detect stale reads.
    pub learn_seq: u64,
    /// target-model classifications answered without the WCFE
    pub bypass: u64,
    /// target-model classifications answered through the WCFE
    pub normal: u64,
    /// target-model bypass-first classifications re-run through the WCFE
    /// by a Confidence policy
    pub escalations: u64,
    /// the target model's active mode policy (0 auto, 1 force-bypass,
    /// 2 force-normal, 3 confidence)
    pub policy: u8,
    /// the Confidence policy's escalation margin (0 for other policies)
    pub policy_margin: f32,
    /// the target model's promotion generation: 0 on an original primary's
    /// lineage, +1 per promotion. A fleet client treats the endpoint with
    /// the highest (epoch, learn_seq) as the current primary; a stale old
    /// primary reappearing with a lower epoch is fenced.
    pub epoch: u64,
}

/// Reactor-side counters for one connection, as carried by an
/// [`OP_CONN_STATS`] reply. Everything here is scoped to the connection
/// that asked — a misbehaving client can be diagnosed without trusting its
/// own accounting.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireConnStats {
    /// the reactor's token for this connection (monotonic per server)
    pub conn_id: u64,
    /// milliseconds since the connection was accepted
    pub age_ms: u64,
    /// request frames decoded on this connection (this one included)
    pub frames: u64,
    /// reply frames queued to this connection (this one excluded)
    pub replies: u64,
    /// error replies among those (decode failures, refusals, sheds)
    pub errors: u64,
    /// requests currently inside an executor
    pub inflight: u32,
    /// requests parsed but not yet dispatched (executor queue was full)
    pub pending: u32,
    /// high-water mark of inflight + pending (the pipeline window actually
    /// used; never exceeds [`MAX_INFLIGHT`])
    pub peak_window: u32,
    /// reply bytes buffered but not yet accepted by the peer's socket
    pub queued_write_bytes: u64,
}

/// A decoded server reply (one shape in both wire versions; replies are
/// matched to requests by id and may arrive out of order on a pipelined
/// connection).
#[derive(Clone, Debug, PartialEq)]
pub enum WireResponse {
    /// classification result (feature and image infers alike)
    Infer {
        /// echoed request id
        id: u64,
        /// predicted class
        class: u32,
        /// progressive-search segments evaluated
        segments: u32,
        /// whether the search exited before the last segment
        early: bool,
        /// whether the WCFE front-end ran ([`FLAG_WCFE`] on the wire)
        wcfe: bool,
        /// whether a Confidence policy re-ran the request through the
        /// WCFE after a thin bypass margin ([`FLAG_ESCALATED`])
        escalated: bool,
        /// modeled energy for this query in joules (0 when the server
        /// keeps no energy accounting)
        energy_j: f64,
    },
    /// learn acknowledgement
    Learn {
        /// echoed request id
        id: u64,
        /// the class that was bundled
        class: u32,
    },
    /// checkpoint acknowledgement
    Snapshot {
        /// echoed request id
        id: u64,
        /// the server-side path written
        path: String,
    },
    /// counter snapshot
    Stats {
        /// echoed request id
        id: u64,
        /// the counters
        stats: WireStats,
    },
    /// per-connection counter snapshot (reactor-answered)
    ConnStats {
        /// echoed request id
        id: u64,
        /// the sending connection's counters
        stats: WireConnStats,
    },
    /// learn-log suffix (replication tailing)
    WalTail {
        /// echoed request id
        id: u64,
        /// the log segment's fold point: records at or before this
        /// sequence live only in the snapshot the segment was rotated
        /// against
        base_seq: u64,
        /// the log's newest acknowledged sequence (the suffix may stop
        /// short of it when the reply was byte-budget-capped — keep
        /// tailing until `records` catches up)
        last_seq: u64,
        /// the serving model's promotion generation. A follower refuses
        /// records from a source whose epoch is below its own (a stale
        /// old primary must not be replayed over a promoted lineage).
        epoch: u64,
        /// the records with sequence greater than the request's `after`,
        /// oldest first
        records: Vec<WalRecord>,
    },
    /// serialized live knowledge store (replication bootstrap)
    SnapshotImage {
        /// echoed request id
        id: u64,
        /// the learn sequence the image captures (apply tail records
        /// newer than this)
        last_seq: u64,
        /// the CLOK checkpoint bytes
        image: Vec<u8>,
    },
    /// version-negotiation acknowledgement
    Hello {
        /// echoed request id
        id: u64,
        /// negotiated version (min of client's and server's newest)
        version: u32,
        /// the model Infer/Learn/... frames with an empty model hit
        default_model: String,
        /// every model this server hosts, in registration order
        models: Vec<String>,
    },
    /// promotion acknowledgement: the target model now serves a new
    /// generation
    Promote {
        /// echoed request id
        id: u64,
        /// the new epoch (old epoch + 1)
        epoch: u64,
        /// the learn sequence the promotion sealed — the fresh WAL
        /// segment's fold point
        base_seq: u64,
    },
    /// model add/remove acknowledgement (one shape for both opcodes; the
    /// wire kind byte echoes the opcode that mutated the registry)
    ModelAdmin {
        /// echoed request id
        id: u64,
        /// which mutation this acknowledges ([`OP_MODEL_ADD`] or
        /// [`OP_MODEL_REMOVE`]); doubles as the wire kind byte
        op: u8,
        /// every model the server hosts after the mutation, in
        /// registration order
        models: Vec<String>,
    },
    /// request failure; `id` echoes the failed request (0 when the frame
    /// was too garbled to carry one)
    Error {
        /// echoed request id (best effort — 0 if unrecoverable)
        id: u64,
        /// server-side error detail
        msg: String,
    },
}

impl WireResponse {
    /// The echoed request id.
    pub fn id(&self) -> u64 {
        match self {
            WireResponse::Infer { id, .. }
            | WireResponse::Learn { id, .. }
            | WireResponse::Snapshot { id, .. }
            | WireResponse::Stats { id, .. }
            | WireResponse::ConnStats { id, .. }
            | WireResponse::WalTail { id, .. }
            | WireResponse::SnapshotImage { id, .. }
            | WireResponse::Hello { id, .. }
            | WireResponse::Promote { id, .. }
            | WireResponse::ModelAdmin { id, .. }
            | WireResponse::Error { id, .. } => *id,
        }
    }

    /// Encode the reply payload (version-independent shape).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            WireResponse::Infer { id, class, segments, early, wcfe, escalated, energy_j } => {
                out.extend_from_slice(&id.to_le_bytes());
                out.push(OP_INFER);
                out.extend_from_slice(&class.to_le_bytes());
                out.extend_from_slice(&segments.to_le_bytes());
                out.push(u8::from(*early));
                let flags =
                    u8::from(*wcfe) * FLAG_WCFE | u8::from(*escalated) * FLAG_ESCALATED;
                out.push(flags);
                out.extend_from_slice(&energy_j.to_le_bytes());
            }
            WireResponse::Learn { id, class } => {
                out.extend_from_slice(&id.to_le_bytes());
                out.push(OP_LEARN);
                out.extend_from_slice(&class.to_le_bytes());
            }
            WireResponse::Snapshot { id, path } => {
                out.extend_from_slice(&id.to_le_bytes());
                out.push(OP_SNAPSHOT);
                put_str16(&mut out, path);
            }
            WireResponse::Stats { id, stats } => {
                out.extend_from_slice(&id.to_le_bytes());
                out.push(OP_STATS);
                out.extend_from_slice(&stats.served.to_le_bytes());
                out.extend_from_slice(&stats.wire_errors.to_le_bytes());
                out.extend_from_slice(&stats.learns.to_le_bytes());
                out.extend_from_slice(&stats.trained_classes.to_le_bytes());
                out.extend_from_slice(&stats.snapshots.to_le_bytes());
                out.extend_from_slice(&stats.learn_seq.to_le_bytes());
                out.extend_from_slice(&stats.bypass.to_le_bytes());
                out.extend_from_slice(&stats.normal.to_le_bytes());
                out.extend_from_slice(&stats.escalations.to_le_bytes());
                out.push(stats.policy);
                out.extend_from_slice(&stats.policy_margin.to_le_bytes());
                out.extend_from_slice(&stats.epoch.to_le_bytes());
            }
            WireResponse::ConnStats { id, stats } => {
                out.extend_from_slice(&id.to_le_bytes());
                out.push(OP_CONN_STATS);
                out.extend_from_slice(&stats.conn_id.to_le_bytes());
                out.extend_from_slice(&stats.age_ms.to_le_bytes());
                out.extend_from_slice(&stats.frames.to_le_bytes());
                out.extend_from_slice(&stats.replies.to_le_bytes());
                out.extend_from_slice(&stats.errors.to_le_bytes());
                out.extend_from_slice(&stats.inflight.to_le_bytes());
                out.extend_from_slice(&stats.pending.to_le_bytes());
                out.extend_from_slice(&stats.peak_window.to_le_bytes());
                out.extend_from_slice(&stats.queued_write_bytes.to_le_bytes());
            }
            WireResponse::WalTail { id, base_seq, last_seq, epoch, records } => {
                out.extend_from_slice(&id.to_le_bytes());
                out.push(OP_WAL_TAIL);
                out.extend_from_slice(&base_seq.to_le_bytes());
                out.extend_from_slice(&last_seq.to_le_bytes());
                out.extend_from_slice(&epoch.to_le_bytes());
                let n = records.len().min(u32::MAX as usize);
                out.extend_from_slice(&(n as u32).to_le_bytes());
                for rec in &records[..n] {
                    let p = rec.payload();
                    out.extend_from_slice(&(p.len() as u32).to_le_bytes());
                    out.extend_from_slice(&p);
                }
            }
            WireResponse::SnapshotImage { id, last_seq, image } => {
                out.extend_from_slice(&id.to_le_bytes());
                out.push(OP_SNAPSHOT_FETCH);
                out.extend_from_slice(&last_seq.to_le_bytes());
                out.extend_from_slice(&(image.len() as u32).to_le_bytes());
                out.extend_from_slice(image);
            }
            WireResponse::Hello { id, version, default_model, models } => {
                out.extend_from_slice(&id.to_le_bytes());
                out.push(OP_HELLO);
                out.extend_from_slice(&version.to_le_bytes());
                put_str16(&mut out, default_model);
                let n = models.len().min(u16::MAX as usize);
                out.extend_from_slice(&(n as u16).to_le_bytes());
                for m in &models[..n] {
                    put_str16(&mut out, m);
                }
            }
            WireResponse::Promote { id, epoch, base_seq } => {
                out.extend_from_slice(&id.to_le_bytes());
                out.push(OP_PROMOTE);
                out.extend_from_slice(&epoch.to_le_bytes());
                out.extend_from_slice(&base_seq.to_le_bytes());
            }
            WireResponse::ModelAdmin { id, op, models } => {
                out.extend_from_slice(&id.to_le_bytes());
                out.push(*op);
                let n = models.len().min(u16::MAX as usize);
                out.extend_from_slice(&(n as u16).to_le_bytes());
                for m in &models[..n] {
                    put_str16(&mut out, m);
                }
            }
            WireResponse::Error { id, msg } => {
                out.extend_from_slice(&id.to_le_bytes());
                out.push(KIND_ERROR);
                put_str16(&mut out, msg);
            }
        }
        out
    }

    /// Decode a reply payload.
    pub fn decode(payload: &[u8]) -> Result<WireResponse> {
        let mut c = crate::util::Cursor::new(payload);
        let id = c.u64()?;
        let kind = c.u8()?;
        let resp = match kind {
            OP_INFER => {
                let (class, segments, early) = (c.u32()?, c.u32()?, c.u8()? != 0);
                let flags = c.u8()?;
                if flags & !(FLAG_WCFE | FLAG_ESCALATED) != 0 {
                    bail!("unknown infer flags {flags:#04x}");
                }
                WireResponse::Infer {
                    id,
                    class,
                    segments,
                    early,
                    wcfe: flags & FLAG_WCFE != 0,
                    escalated: flags & FLAG_ESCALATED != 0,
                    energy_j: c.f64()?,
                }
            }
            OP_LEARN => WireResponse::Learn { id, class: c.u32()? },
            OP_SNAPSHOT => WireResponse::Snapshot { id, path: c.str16()? },
            OP_STATS => WireResponse::Stats {
                id,
                stats: WireStats {
                    served: c.u64()?,
                    wire_errors: c.u64()?,
                    learns: c.u64()?,
                    trained_classes: c.u32()?,
                    snapshots: c.u64()?,
                    learn_seq: c.u64()?,
                    bypass: c.u64()?,
                    normal: c.u64()?,
                    escalations: c.u64()?,
                    policy: c.u8()?,
                    policy_margin: c.f32()?,
                    epoch: c.u64()?,
                },
            },
            OP_CONN_STATS => WireResponse::ConnStats {
                id,
                stats: WireConnStats {
                    conn_id: c.u64()?,
                    age_ms: c.u64()?,
                    frames: c.u64()?,
                    replies: c.u64()?,
                    errors: c.u64()?,
                    inflight: c.u32()?,
                    pending: c.u32()?,
                    peak_window: c.u32()?,
                    queued_write_bytes: c.u64()?,
                },
            },
            OP_WAL_TAIL => {
                let base_seq = c.u64()?;
                let last_seq = c.u64()?;
                let epoch = c.u64()?;
                let n = c.u32()? as usize;
                let mut records = Vec::with_capacity(n.min(4096));
                for _ in 0..n {
                    let len = c.u32()? as usize;
                    records.push(WalRecord::from_payload(c.take(len)?)?);
                }
                WireResponse::WalTail { id, base_seq, last_seq, epoch, records }
            }
            OP_SNAPSHOT_FETCH => {
                let last_seq = c.u64()?;
                let len = c.u32()? as usize;
                WireResponse::SnapshotImage { id, last_seq, image: c.take(len)?.to_vec() }
            }
            OP_HELLO => {
                let version = c.u32()?;
                let default_model = c.str16()?;
                let n = c.u16()? as usize;
                let mut models = Vec::with_capacity(n.min(1024));
                for _ in 0..n {
                    models.push(c.str16()?);
                }
                WireResponse::Hello { id, version, default_model, models }
            }
            OP_PROMOTE => WireResponse::Promote { id, epoch: c.u64()?, base_seq: c.u64()? },
            OP_MODEL_ADD | OP_MODEL_REMOVE => {
                let n = c.u16()? as usize;
                let mut models = Vec::with_capacity(n.min(1024));
                for _ in 0..n {
                    models.push(c.str16()?);
                }
                WireResponse::ModelAdmin { id, op: kind, models }
            }
            KIND_ERROR => WireResponse::Error { id, msg: c.str16()? },
            other => bail!("unknown response kind {other:#04x}"),
        };
        c.finish()?;
        Ok(resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(r: WireRequest, version: u32) {
        let bytes = r.encode(version).unwrap();
        assert_eq!(WireRequest::decode(&bytes, version).unwrap(), r);
    }

    fn roundtrip_resp(r: WireResponse) {
        let bytes = r.encode();
        assert_eq!(WireResponse::decode(&bytes).unwrap(), r);
    }

    #[test]
    fn v1_request_roundtrips() {
        roundtrip_req(
            WireRequest::new(
                7,
                ReqBody::Infer { mode: MODE_PACKED, features: vec![1.5, -2.25, 0.0] },
            ),
            WIRE_V1,
        );
        roundtrip_req(
            WireRequest::new(8, ReqBody::Infer { mode: MODE_DEFAULT, features: vec![] }),
            WIRE_V1,
        );
        roundtrip_req(
            WireRequest::new(9, ReqBody::Learn { class: 3, features: vec![42.0; 64] }),
            WIRE_V1,
        );
        roundtrip_req(WireRequest::new(10, ReqBody::Snapshot { path: "k.clok".into() }), WIRE_V1);
        roundtrip_req(WireRequest::new(11, ReqBody::Snapshot { path: String::new() }), WIRE_V1);
        roundtrip_req(WireRequest::new(12, ReqBody::Stats), WIRE_V1);
        roundtrip_req(WireRequest::new(13, ReqBody::Hello { version: WIRE_V2 }), WIRE_V1);
        roundtrip_req(WireRequest::new(14, ReqBody::ConnStats), WIRE_V1);
        roundtrip_req(WireRequest::new(15, ReqBody::WalTail { after: 0 }), WIRE_V1);
        roundtrip_req(WireRequest::new(16, ReqBody::WalTail { after: u64::MAX }), WIRE_V1);
        roundtrip_req(WireRequest::new(17, ReqBody::SnapshotFetch), WIRE_V1);
        roundtrip_req(
            WireRequest::new(
                18,
                ReqBody::InferImage { mode: MODE_PACKED, pixels: vec![0.5; 256] },
            ),
            WIRE_V1,
        );
        roundtrip_req(
            WireRequest::new(19, ReqBody::LearnImage { class: 2, pixels: vec![0.25; 64] }),
            WIRE_V1,
        );
        roundtrip_req(WireRequest::new(20, ReqBody::Promote), WIRE_V1);
        roundtrip_req(
            WireRequest::new(
                21,
                ReqBody::ModelAdd { name: "shadow".into(), source: String::new() },
            ),
            WIRE_V1,
        );
        roundtrip_req(
            WireRequest::new(22, ReqBody::ModelRemove { name: "shadow".into() }),
            WIRE_V1,
        );
    }

    #[test]
    fn v2_request_roundtrips_with_models() {
        for model in ["", "tiny", "isolet-prod"] {
            roundtrip_req(
                WireRequest::for_model(
                    21,
                    model,
                    ReqBody::Infer { mode: MODE_L1, features: vec![0.5, 1.0] },
                ),
                WIRE_V2,
            );
            roundtrip_req(
                WireRequest::for_model(
                    22,
                    model,
                    ReqBody::Learn { class: 1, features: vec![9.0; 8] },
                ),
                WIRE_V2,
            );
            roundtrip_req(
                WireRequest::for_model(23, model, ReqBody::Snapshot { path: "x".into() }),
                WIRE_V2,
            );
            roundtrip_req(WireRequest::for_model(24, model, ReqBody::Stats), WIRE_V2);
            roundtrip_req(WireRequest::for_model(26, model, ReqBody::ConnStats), WIRE_V2);
            roundtrip_req(
                WireRequest::for_model(27, model, ReqBody::WalTail { after: 42 }),
                WIRE_V2,
            );
            roundtrip_req(WireRequest::for_model(28, model, ReqBody::SnapshotFetch), WIRE_V2);
            roundtrip_req(
                WireRequest::for_model(
                    29,
                    model,
                    ReqBody::InferImage { mode: MODE_DEFAULT, pixels: vec![1.0, 0.0] },
                ),
                WIRE_V2,
            );
            roundtrip_req(
                WireRequest::for_model(
                    30,
                    model,
                    ReqBody::LearnImage { class: 0, pixels: vec![] },
                ),
                WIRE_V2,
            );
            roundtrip_req(WireRequest::for_model(31, model, ReqBody::Promote), WIRE_V2);
            roundtrip_req(
                WireRequest::for_model(
                    32,
                    model,
                    ReqBody::ModelAdd { name: "b".into(), source: "a".into() },
                ),
                WIRE_V2,
            );
            roundtrip_req(
                WireRequest::for_model(33, model, ReqBody::ModelRemove { name: "b".into() }),
                WIRE_V2,
            );
        }
        // hello is v1-shaped even on a v2 connection
        roundtrip_req(WireRequest::new(25, ReqBody::Hello { version: 7 }), WIRE_V2);
    }

    #[test]
    fn v1_encode_refuses_model_targeting() {
        let req = WireRequest::for_model(1, "tiny", ReqBody::Stats);
        let e = req.encode(WIRE_V1).unwrap_err().to_string();
        assert!(e.contains("wire v2"), "{e}");
        // hello never carries a model in either version
        let req = WireRequest::for_model(2, "tiny", ReqBody::Hello { version: WIRE_V2 });
        assert!(req.encode(WIRE_V2).is_err());
        // unknown versions refused outright
        assert!(WireRequest::new(3, ReqBody::Stats).encode(9).is_err());
        assert!(WireRequest::decode(&[0u8; 16], 9).is_err());
    }

    #[test]
    fn response_roundtrips() {
        roundtrip_resp(WireResponse::Infer {
            id: 1,
            class: 4,
            segments: 3,
            early: true,
            wcfe: false,
            escalated: false,
            energy_j: 0.0,
        });
        roundtrip_resp(WireResponse::Infer {
            id: 14,
            class: 0,
            segments: 16,
            early: false,
            wcfe: true,
            escalated: true,
            energy_j: 3.75e-6,
        });
        roundtrip_resp(WireResponse::Learn { id: 2, class: 0 });
        roundtrip_resp(WireResponse::Snapshot { id: 3, path: "a/b.clok".into() });
        roundtrip_resp(WireResponse::Stats {
            id: 4,
            stats: WireStats {
                served: 100,
                wire_errors: 2,
                learns: 40,
                trained_classes: 9,
                snapshots: 1,
                learn_seq: 40,
                bypass: 70,
                normal: 30,
                escalations: 12,
                policy: 3,
                policy_margin: 48.5,
                epoch: 2,
            },
        });
        roundtrip_resp(WireResponse::Hello {
            id: 6,
            version: WIRE_V2,
            default_model: "tiny".into(),
            models: vec!["tiny".into(), "isolet".into()],
        });
        roundtrip_resp(WireResponse::Hello {
            id: 7,
            version: WIRE_V1,
            default_model: String::new(),
            models: vec![],
        });
        roundtrip_resp(WireResponse::Error { id: 5, msg: "class 99 out of range".into() });
        roundtrip_resp(WireResponse::WalTail {
            id: 9,
            base_seq: 4,
            last_seq: 7,
            epoch: 1,
            records: vec![
                WalRecord { seq: 5, class: 0, features: vec![0.25, -1.0] },
                WalRecord { seq: 6, class: 3, features: vec![] },
                WalRecord { seq: 7, class: 1, features: vec![9.5; 16] },
            ],
        });
        roundtrip_resp(WireResponse::WalTail {
            id: 10,
            base_seq: 0,
            last_seq: 0,
            epoch: 0,
            records: vec![],
        });
        roundtrip_resp(WireResponse::Promote { id: 13, epoch: 3, base_seq: 1_000_000 });
        roundtrip_resp(WireResponse::ModelAdmin {
            id: 14,
            op: OP_MODEL_ADD,
            models: vec!["tiny".into(), "shadow".into()],
        });
        roundtrip_resp(WireResponse::ModelAdmin {
            id: 15,
            op: OP_MODEL_REMOVE,
            models: vec!["tiny".into()],
        });
        roundtrip_resp(WireResponse::SnapshotImage {
            id: 11,
            last_seq: 12,
            image: vec![0xC1, 0x00, 0xFF, 0x7E],
        });
        roundtrip_resp(WireResponse::SnapshotImage { id: 12, last_seq: 0, image: vec![] });
        roundtrip_resp(WireResponse::ConnStats {
            id: 8,
            stats: WireConnStats {
                conn_id: 41,
                age_ms: 12_345,
                frames: 100,
                replies: 99,
                errors: 1,
                inflight: 7,
                pending: 3,
                peak_window: 64,
                queued_write_bytes: 4096,
            },
        });
    }

    #[test]
    fn decode_rejects_garbage_opcode_truncation_and_trailing() {
        let good = WireRequest::new(1, ReqBody::Infer { mode: 0, features: vec![1.0] })
            .encode(WIRE_V1)
            .unwrap();
        // garbage opcode
        let mut bad = good.clone();
        bad[8] = 0x77;
        assert!(WireRequest::decode(&bad, WIRE_V1)
            .unwrap_err()
            .to_string()
            .contains("opcode"));
        // truncated feature block
        assert!(WireRequest::decode(&good[..good.len() - 2], WIRE_V1).is_err());
        // short header
        assert!(WireRequest::decode(&good[..5], WIRE_V1).is_err());
        // trailing bytes
        let mut bad = good.clone();
        bad.push(0);
        assert!(WireRequest::decode(&bad, WIRE_V1)
            .unwrap_err()
            .to_string()
            .contains("trailing"));
        // bad infer mode
        let mut bad = good;
        bad[9] = 9;
        assert!(WireRequest::decode(&bad, WIRE_V1)
            .unwrap_err()
            .to_string()
            .contains("mode"));
    }

    #[test]
    fn v2_decode_rejects_truncated_model_field() {
        let good = WireRequest::for_model(1, "tiny", ReqBody::Stats).encode(WIRE_V2).unwrap();
        // cut inside the model string
        assert!(WireRequest::decode(&good[..good.len() - 2], WIRE_V2).is_err());
        // a v1-encoded stats frame is NOT a valid v2 frame (missing model)
        let v1 = WireRequest::new(1, ReqBody::Stats).encode(WIRE_V1).unwrap();
        assert!(WireRequest::decode(&v1, WIRE_V2).is_err());
    }

    #[test]
    fn decode_rejects_absurd_feature_count() {
        // n claims 2^31 floats but the payload carries none
        let mut b = Vec::new();
        b.extend_from_slice(&1u64.to_le_bytes());
        b.push(OP_INFER);
        b.push(MODE_DEFAULT);
        b.extend_from_slice(&(u32::MAX).to_le_bytes());
        assert!(WireRequest::decode(&b, WIRE_V1).is_err());
    }

    #[test]
    fn frame_io_roundtrip_and_caps() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = std::io::Cursor::new(buf);
        match read_frame(&mut r, MAX_FRAME).unwrap() {
            Frame::Payload(p) => assert_eq!(p, b"hello"),
            other => panic!("{other:?}"),
        }
        match read_frame(&mut r, MAX_FRAME).unwrap() {
            Frame::Payload(p) => assert!(p.is_empty()),
            other => panic!("{other:?}"),
        }
        assert!(matches!(read_frame(&mut r, MAX_FRAME).unwrap(), Frame::Eof));
    }

    #[test]
    fn oversized_length_is_rejected_before_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        buf.extend_from_slice(b"whatever");
        let mut r = std::io::Cursor::new(buf);
        let e = read_frame(&mut r, MAX_FRAME).unwrap_err().to_string();
        assert!(e.contains("exceeds"), "{e}");
        // caller-tightened cap too
        let mut buf = Vec::new();
        write_frame(&mut buf, &[0u8; 100]).unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert!(read_frame(&mut r, 10).is_err());
    }

    #[test]
    fn truncated_header_and_body_error() {
        let mut r = std::io::Cursor::new(vec![5u8, 0]);
        assert!(read_frame(&mut r, MAX_FRAME).is_err(), "2-byte header");
        let mut buf = Vec::new();
        buf.extend_from_slice(&10u32.to_le_bytes());
        buf.extend_from_slice(b"only4");
        let mut r = std::io::Cursor::new(buf);
        assert!(read_frame(&mut r, MAX_FRAME).is_err(), "truncated body");
    }

    #[test]
    fn peek_id_best_effort() {
        let req = WireRequest::new(0xDEAD_BEEF, ReqBody::Stats);
        assert_eq!(peek_id(&req.encode(WIRE_V1).unwrap()), 0xDEAD_BEEF);
        assert_eq!(peek_id(&req.encode(WIRE_V2).unwrap()), 0xDEAD_BEEF);
        assert_eq!(peek_id(&[1, 2, 3]), 0);
    }

    #[test]
    fn write_frame_emits_len_prefix() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &[0xAB; 8]).unwrap();
        assert_eq!(&buf[..4], &8u32.to_le_bytes());
        assert_eq!(buf.len(), 12);
        assert!(MAX_FRAME >= 1 << 20);
    }

    #[test]
    fn assembler_yields_whole_frames_from_any_split() {
        let mut stream = Vec::new();
        write_frame(&mut stream, b"alpha").unwrap();
        write_frame(&mut stream, b"").unwrap();
        write_frame(&mut stream, &[7u8; 300]).unwrap();
        // one byte at a time — every header and length prefix is torn
        let mut asm = FrameAssembler::new(MAX_FRAME);
        let mut got = Vec::new();
        for b in &stream {
            asm.extend(std::slice::from_ref(b));
            while let Some(p) = asm.next_payload().unwrap() {
                got.push(p);
            }
        }
        assert_eq!(got, vec![b"alpha".to_vec(), Vec::new(), vec![7u8; 300]]);
        assert!(!asm.mid_frame(), "stream ended at a frame boundary");
        // all at once
        let mut asm = FrameAssembler::new(MAX_FRAME);
        asm.extend(&stream);
        let mut got2 = Vec::new();
        while let Some(p) = asm.next_payload().unwrap() {
            got2.push(p);
        }
        assert_eq!(got2, got);
    }

    #[test]
    fn assembler_tracks_mid_frame_and_rejects_oversize() {
        let mut asm = FrameAssembler::new(MAX_FRAME);
        assert!(!asm.mid_frame());
        asm.extend(&[3, 0]); // half a length prefix
        assert!(asm.mid_frame());
        assert!(asm.next_payload().unwrap().is_none());
        asm.extend(&[0, 0, b'a']); // header complete, body 1/3
        assert!(asm.next_payload().unwrap().is_none());
        asm.extend(b"bc");
        assert_eq!(asm.next_payload().unwrap().unwrap(), b"abc");
        assert!(!asm.mid_frame());
        // oversized length rejected at the header, then poisoned
        let mut asm = FrameAssembler::new(10);
        asm.extend(&100u32.to_le_bytes());
        assert!(asm.next_payload().is_err());
        assert!(asm.next_payload().is_err(), "stays poisoned");
    }

    #[test]
    fn assembler_compacts_without_losing_frames() {
        // enough traffic to cross the compaction threshold several times
        let mut asm = FrameAssembler::new(MAX_FRAME);
        let mut expect = Vec::new();
        let mut stream = Vec::new();
        for i in 0..200u32 {
            let payload = vec![(i % 251) as u8; 40 + (i as usize % 17)];
            write_frame(&mut stream, &payload).unwrap();
            expect.push(payload);
        }
        let mut got = Vec::new();
        for chunk in stream.chunks(33) {
            asm.extend(chunk);
            while let Some(p) = asm.next_payload().unwrap() {
                got.push(p);
            }
        }
        assert_eq!(got, expect);
        assert_eq!(asm.buffered(), 0);
    }

    /// Satellite: arbitrary valid v1/v2 frame sequences, split at random
    /// chunk boundaries (including mid-header and mid-length-prefix),
    /// reassemble bit-identically to whole-frame decoding.
    #[test]
    fn prop_chunked_reassembly_matches_whole_frame_decode() {
        use crate::util::prop::forall;
        forall(60, 0xC0FF_EE00, |rng| {
            let version = if rng.bool(0.5) { WIRE_V1 } else { WIRE_V2 };
            let nframes = 1 + rng.below(8);
            let mut reqs = Vec::new();
            for i in 0..nframes {
                let model = if version == WIRE_V2 && rng.bool(0.5) {
                    ["", "tiny", "isolet", "m3"][rng.below(4)].to_string()
                } else {
                    String::new()
                };
                let body = match rng.below(11) {
                    0 => ReqBody::Infer {
                        mode: rng.below(3) as u8,
                        features: (0..rng.below(40)).map(|_| rng.sign() * 3.0).collect(),
                    },
                    1 => ReqBody::Learn {
                        class: rng.below(32) as u32,
                        features: (0..rng.below(40)).map(|_| rng.sign()).collect(),
                    },
                    2 => ReqBody::Snapshot { path: "snap/k.clok"[..rng.below(12)].to_string() },
                    3 => ReqBody::Stats,
                    4 => ReqBody::ConnStats,
                    5 => ReqBody::WalTail { after: rng.below(1 << 20) as u64 },
                    6 => ReqBody::SnapshotFetch,
                    7 => ReqBody::Promote,
                    8 => ReqBody::ModelAdd {
                        name: "added-m"[..1 + rng.below(7)].to_string(),
                        source: ["", "tiny"][rng.below(2)].to_string(),
                    },
                    9 => ReqBody::ModelRemove { name: "victim"[..1 + rng.below(6)].to_string() },
                    _ => ReqBody::Hello { version: WIRE_V2 },
                };
                let hello = matches!(body, ReqBody::Hello { .. });
                let model = if hello { String::new() } else { model };
                reqs.push(WireRequest { id: i as u64 + 1, model, body });
            }
            // whole-frame reference: encode + frame each request
            let mut stream = Vec::new();
            let mut reference = Vec::new();
            for r in &reqs {
                let payload = r.encode(version).unwrap();
                reference.push(WireRequest::decode(&payload, version).unwrap());
                write_frame(&mut stream, &payload).unwrap();
            }
            // chunked reassembly at random split points
            let mut asm = FrameAssembler::new(MAX_FRAME);
            let mut decoded = Vec::new();
            let mut off = 0;
            while off < stream.len() {
                let n = 1 + rng.below(11).min(stream.len() - off - 1);
                asm.extend(&stream[off..off + n]);
                off += n;
                while let Some(p) = asm.next_payload().unwrap() {
                    decoded.push(WireRequest::decode(&p, version).unwrap());
                }
            }
            assert_eq!(decoded, reference);
            assert_eq!(decoded, reqs);
            assert!(!asm.mid_frame());
        });
    }

    #[test]
    fn header_byte_layout_is_pinned() {
        // the offsets docs/PROTOCOL.md documents: id at 0 (8 bytes), op at
        // 8, and — v2 only — the model str16 at 9
        let v1 = WireRequest::new(0x0102_0304_0506_0708, ReqBody::Stats)
            .encode(WIRE_V1)
            .unwrap();
        assert_eq!(v1[8], OP_STATS);
        assert_eq!(v1.len(), 9);
        let v2 = WireRequest::for_model(1, "ab", ReqBody::Stats).encode(WIRE_V2).unwrap();
        assert_eq!(v2[8], OP_STATS);
        assert_eq!(&v2[9..11], &2u16.to_le_bytes());
        assert_eq!(&v2[11..13], b"ab");
        assert_eq!(v2.len(), 13);
        // responses: id at 0, kind at 8
        let resp = WireResponse::Learn { id: 3, class: 1 }.encode();
        assert_eq!(resp[8], OP_LEARN);
    }

    #[test]
    fn dual_mode_byte_layout_is_pinned() {
        // image-infer request (v1): id u64, op, mode u8 at 9, n u32 at 10,
        // then n raw little-endian f32 pixels
        let req = WireRequest::new(7, ReqBody::InferImage { mode: MODE_L1, pixels: vec![0.5] })
            .encode(WIRE_V1)
            .unwrap();
        assert_eq!(req[8], OP_INFER_IMAGE);
        assert_eq!(req[9], MODE_L1);
        assert_eq!(&req[10..14], &1u32.to_le_bytes());
        assert_eq!(&req[14..18], &0.5f32.to_le_bytes());
        assert_eq!(req.len(), 18);
        // image-learn request (v1): id u64, op, class u32 at 9, n u32 at 13
        let req = WireRequest::new(8, ReqBody::LearnImage { class: 3, pixels: vec![1.0] })
            .encode(WIRE_V1)
            .unwrap();
        assert_eq!(req[8], OP_LEARN_IMAGE);
        assert_eq!(&req[9..13], &3u32.to_le_bytes());
        assert_eq!(&req[13..17], &1u32.to_le_bytes());
        assert_eq!(req.len(), 21);
        // infer reply: class at 9, segments at 13, early at 17, flags at 18,
        // energy_j f64 at 19..27
        let resp = WireResponse::Infer {
            id: 9,
            class: 6,
            segments: 5,
            early: true,
            wcfe: true,
            escalated: true,
            energy_j: 2.5e-6,
        }
        .encode();
        assert_eq!(resp[8], OP_INFER);
        assert_eq!(&resp[9..13], &6u32.to_le_bytes());
        assert_eq!(&resp[13..17], &5u32.to_le_bytes());
        assert_eq!(resp[17], 1);
        assert_eq!(resp[18], FLAG_WCFE | FLAG_ESCALATED);
        assert_eq!(&resp[19..27], &2.5e-6f64.to_le_bytes());
        assert_eq!(resp.len(), 27);
        // stats reply: dual-mode counters follow learn_seq — bypass at 53,
        // normal at 61, escalations at 69, policy at 77, margin f32 at 78,
        // epoch u64 at 82
        let resp = WireResponse::Stats {
            id: 10,
            stats: WireStats {
                served: 1,
                wire_errors: 0,
                learns: 2,
                trained_classes: 3,
                snapshots: 4,
                learn_seq: 5,
                bypass: 6,
                normal: 7,
                escalations: 8,
                policy: 3,
                policy_margin: 12.5,
                epoch: 9,
            },
        }
        .encode();
        assert_eq!(resp[8], OP_STATS);
        assert_eq!(&resp[53..61], &6u64.to_le_bytes());
        assert_eq!(&resp[61..69], &7u64.to_le_bytes());
        assert_eq!(&resp[69..77], &8u64.to_le_bytes());
        assert_eq!(resp[77], 3);
        assert_eq!(&resp[78..82], &12.5f32.to_le_bytes());
        assert_eq!(&resp[82..90], &9u64.to_le_bytes());
        assert_eq!(resp.len(), 90);
        // an infer reply with unknown flag bits must be rejected
        let mut bad = WireResponse::Infer {
            id: 11,
            class: 0,
            segments: 1,
            early: false,
            wcfe: false,
            escalated: false,
            energy_j: 0.0,
        }
        .encode();
        bad[18] = 0x80;
        assert!(WireResponse::decode(&bad).is_err());
    }

    #[test]
    fn wal_tail_byte_layout_is_pinned() {
        // request: id u64, op, after u64 (v1 shape)
        let req = WireRequest::new(2, ReqBody::WalTail { after: 0x0102 }).encode(WIRE_V1).unwrap();
        assert_eq!(req[8], OP_WAL_TAIL);
        assert_eq!(&req[9..17], &0x0102u64.to_le_bytes());
        assert_eq!(req.len(), 17);
        // response: base_seq at 9, last_seq at 17, epoch at 25, count at
        // 33, then length-prefixed record payloads (seq u64, class u32,
        // n u32, n×f32)
        let resp = WireResponse::WalTail {
            id: 3,
            base_seq: 10,
            last_seq: 11,
            epoch: 4,
            records: vec![WalRecord { seq: 11, class: 2, features: vec![1.0] }],
        }
        .encode();
        assert_eq!(resp[8], OP_WAL_TAIL);
        assert_eq!(&resp[9..17], &10u64.to_le_bytes());
        assert_eq!(&resp[17..25], &11u64.to_le_bytes());
        assert_eq!(&resp[25..33], &4u64.to_le_bytes());
        assert_eq!(&resp[33..37], &1u32.to_le_bytes());
        assert_eq!(&resp[37..41], &20u32.to_le_bytes(), "record payload length");
        assert_eq!(&resp[41..49], &11u64.to_le_bytes(), "record seq");
        assert_eq!(&resp[49..53], &2u32.to_le_bytes(), "record class");
        assert_eq!(&resp[53..57], &1u32.to_le_bytes(), "record n");
        assert_eq!(&resp[57..61], &1.0f32.to_le_bytes());
        assert_eq!(resp.len(), 61);
        // snapshot-fetch response: last_seq at 9, img_len at 17
        let resp = WireResponse::SnapshotImage { id: 4, last_seq: 6, image: vec![0xAA; 3] }
            .encode();
        assert_eq!(resp[8], OP_SNAPSHOT_FETCH);
        assert_eq!(&resp[9..17], &6u64.to_le_bytes());
        assert_eq!(&resp[17..21], &3u32.to_le_bytes());
        assert_eq!(&resp[21..], &[0xAA; 3]);
    }

    #[test]
    fn promotion_and_model_admin_byte_layout_is_pinned() {
        // promote request (v1): header only — no body
        let req = WireRequest::new(5, ReqBody::Promote).encode(WIRE_V1).unwrap();
        assert_eq!(req[8], OP_PROMOTE);
        assert_eq!(req.len(), 9);
        // promote reply: epoch u64 at 9, base_seq u64 at 17
        let resp = WireResponse::Promote { id: 5, epoch: 2, base_seq: 40 }.encode();
        assert_eq!(resp[8], OP_PROMOTE);
        assert_eq!(&resp[9..17], &2u64.to_le_bytes());
        assert_eq!(&resp[17..25], &40u64.to_le_bytes());
        assert_eq!(resp.len(), 25);
        // model-add request (v1): name str16 at 9, source str16 after it
        let req = WireRequest::new(
            6,
            ReqBody::ModelAdd { name: "ab".into(), source: "c".into() },
        )
        .encode(WIRE_V1)
        .unwrap();
        assert_eq!(req[8], OP_MODEL_ADD);
        assert_eq!(&req[9..11], &2u16.to_le_bytes());
        assert_eq!(&req[11..13], b"ab");
        assert_eq!(&req[13..15], &1u16.to_le_bytes());
        assert_eq!(&req[15..16], b"c");
        assert_eq!(req.len(), 16);
        // model-remove request (v1): name str16 at 9
        let req =
            WireRequest::new(7, ReqBody::ModelRemove { name: "ab".into() }).encode(WIRE_V1).unwrap();
        assert_eq!(req[8], OP_MODEL_REMOVE);
        assert_eq!(&req[9..11], &2u16.to_le_bytes());
        assert_eq!(&req[11..13], b"ab");
        assert_eq!(req.len(), 13);
        // model-admin reply (both opcodes): count u16 at 9, then str16s;
        // the kind byte echoes the mutating opcode
        let resp = WireResponse::ModelAdmin {
            id: 8,
            op: OP_MODEL_REMOVE,
            models: vec!["ab".into()],
        }
        .encode();
        assert_eq!(resp[8], OP_MODEL_REMOVE);
        assert_eq!(&resp[9..11], &1u16.to_le_bytes());
        assert_eq!(&resp[11..13], &2u16.to_le_bytes());
        assert_eq!(&resp[13..15], b"ab");
        assert_eq!(resp.len(), 15);
    }

    #[test]
    fn wal_tail_decode_rejects_truncated_records() {
        let good = WireResponse::WalTail {
            id: 1,
            base_seq: 0,
            last_seq: 2,
            epoch: 0,
            records: vec![
                WalRecord { seq: 1, class: 0, features: vec![1.0, 2.0] },
                WalRecord { seq: 2, class: 1, features: vec![3.0] },
            ],
        }
        .encode();
        assert!(WireResponse::decode(&good).is_ok());
        // cut inside the final record's feature block
        assert!(WireResponse::decode(&good[..good.len() - 2]).is_err());
        // trailing bytes after the last record
        let mut bad = good.clone();
        bad.push(0);
        assert!(WireResponse::decode(&bad).is_err());
        // a record length that claims more bytes than the frame holds
        let mut bad = good;
        let count_at = 33;
        bad[count_at + 4..count_at + 8].copy_from_slice(&1_000_000u32.to_le_bytes());
        assert!(WireResponse::decode(&bad).is_err());
    }
}
