//! Follower-side replication: keep a local model converged with a primary
//! server's knowledge by tailing its durable learn log over the wire.
//!
//! A [`Replica`] owns one background tailer thread per followed model. The
//! thread connects to the primary (bounded retry with exponential backoff
//! and jitter, [`Client::connect_with_retry`]), then polls
//! `OP_WAL_TAIL` with the highest learn sequence it has applied locally.
//! Three things can come back:
//!
//! * **records** — applied in order to the local
//!   [`Coordinator`](crate::coordinator::Coordinator) as ordinary Learn
//!   requests. Sequence continuity is checked record by record; the HDC
//!   store is deterministic, so a follower that applies the same `(class,
//!   features)` stream through the same backend converges to a
//!   bit-identical knowledge store.
//! * **a compaction refusal** — the follower's position predates the
//!   primary log's fold point (the primary snapshotted and rotated). The
//!   follower re-bootstraps: `OP_SNAPSHOT_FETCH` pulls the primary's live
//!   store as CLOK bytes, a local RestoreImage installs it (the CLOK
//!   model-identity header is the safety check), and tailing resumes from
//!   the image's sequence.
//! * **a transport failure** — the primary is gone. The follower keeps
//!   serving its last-converged state (graceful degradation: Infer traffic
//!   never sees the outage) and reconnects with capped
//!   exponential-backoff-with-jitter until the primary returns.
//!
//! Staleness is observable, never hidden: [`Replica::status`] exposes the
//! applied sequence, and the local model's own Stats reply carries it as
//! `learn_seq` — compare against the primary's to detect a stale read.
//!
//! ## Promotion and fencing
//!
//! When the primary dies for good, [`Replica::promote`] ends the follower
//! role: tailing quiesces (thread joined), then the local model executes a
//! `Promote` — its epoch becomes `max(local, highest source epoch
//! observed) + 1` and its WAL (if any) is sealed at `base_seq =
//! applied_seq` under the new epoch. From then on the model is the primary
//! of a new generation. The epoch travels in every stats and wal-tail
//! reply, and the tailer enforces it in both directions: a tail source
//! reporting an epoch *below* the local model's is a stale old primary —
//! its records are refused, [`ReplicaStatus::fenced`] increments, and the
//! connection retreats to backoff (divergence refusal, not convergence).
//! Conversely, if the *local* model's epoch rises above what it was when
//! tailing began (an `OP_PROMOTE` arrived over the wire while this tailer
//! ran), the tailer quiesces itself: a primary must not apply another
//! primary's log.
//!
//! [`ModelSync`] is the registry-level companion: it polls the primary's
//! hello model list and converges a local [`Registry`] — adding missing
//! models (each with its own tailer, so knowledge converges too) and
//! removing models the primary dropped.

use crate::coordinator::{Coordinator, Payload};
use crate::serve::client::{Client, ServerError};
use crate::serve::wire;
use crate::Result;
use anyhow::{bail, Context};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Follower knobs.
#[derive(Clone, Debug)]
pub struct ReplicaOptions {
    /// the primary server's address (`host:port`)
    pub primary: String,
    /// the model to follow on the primary (`""` = its default model)
    pub model: String,
    /// idle poll cadence once caught up (how stale a follower can be is
    /// roughly this plus one round trip)
    pub poll_interval: Duration,
    /// first reconnect delay after losing the primary; doubles per failure
    pub reconnect_base: Duration,
    /// reconnect delay cap
    pub reconnect_max: Duration,
}

impl ReplicaOptions {
    /// Follow the primary's default model with the default cadences.
    pub fn new(primary: impl Into<String>) -> ReplicaOptions {
        ReplicaOptions {
            primary: primary.into(),
            model: String::new(),
            poll_interval: Duration::from_millis(25),
            reconnect_base: Duration::from_millis(50),
            reconnect_max: Duration::from_secs(5),
        }
    }
}

/// A point-in-time view of a follower's progress.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReplicaStatus {
    /// the highest primary learn sequence applied locally
    pub applied_seq: u64,
    /// connection attempts that failed or connections that were lost
    pub reconnects: u64,
    /// snapshot-image bootstraps performed (initial sync + compaction gaps)
    pub bootstraps: u64,
    /// tail sources refused for carrying an epoch below the local model's
    /// (each refusal is one fenced contact with a stale old primary)
    pub fenced: u64,
    /// whether the tailer currently holds a live connection to the primary
    pub connected: bool,
}

#[derive(Default)]
struct Shared {
    applied_seq: AtomicU64,
    reconnects: AtomicU64,
    bootstraps: AtomicU64,
    fenced: AtomicU64,
    /// highest epoch any tail reply has reported (the promotion floor)
    source_epoch: AtomicU64,
    /// the local model's epoch when tailing began — a rise above this
    /// means the local model was promoted over the wire and the tailer
    /// must quiesce itself
    epoch0: AtomicU64,
    connected: AtomicBool,
    stop: AtomicBool,
}

/// A running follower: one tailer thread keeping `local` converged with a
/// primary. Dropping (or [`Replica::stop`]) signals the thread and joins
/// it; the local coordinator lives on, still serving the last state.
pub struct Replica {
    shared: Arc<Shared>,
    local: Arc<Coordinator>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Replica {
    /// Start following. `local` is the coordinator the tailer applies
    /// learns to — it must run the same config as the primary's model (the
    /// bootstrap image's identity/geometry checks enforce it). The tailer
    /// starts from the local store's own learn sequence, so a follower
    /// restarted with its own WAL or snapshot resumes where it left off
    /// instead of re-bootstrapping.
    pub fn start(local: Arc<Coordinator>, opts: ReplicaOptions) -> Result<Replica> {
        let r = local.call(Payload::Stats).context("replica: local stats")?;
        if let Some(e) = r.error {
            bail!("replica: local stats: {e}");
        }
        let shared = Arc::new(Shared::default());
        shared
            .applied_seq
            .store(r.stats.map(|s| s.learn_seq).unwrap_or(0), Ordering::SeqCst);
        shared
            .epoch0
            .store(r.stats.map(|s| s.epoch).unwrap_or(0), Ordering::SeqCst);
        let sh = shared.clone();
        let coord = local.clone();
        let thread = std::thread::Builder::new()
            .name("clo-hdnn-replica".into())
            .spawn(move || tail_loop(coord, opts, sh))?;
        Ok(Replica { shared, local, thread: Some(thread) })
    }

    /// The follower's current progress counters.
    pub fn status(&self) -> ReplicaStatus {
        ReplicaStatus {
            applied_seq: self.shared.applied_seq.load(Ordering::SeqCst),
            reconnects: self.shared.reconnects.load(Ordering::SeqCst),
            bootstraps: self.shared.bootstraps.load(Ordering::SeqCst),
            fenced: self.shared.fenced.load(Ordering::SeqCst),
            connected: self.shared.connected.load(Ordering::SeqCst),
        }
    }

    /// End the follower role and take over as primary: quiesce tailing
    /// (the thread is joined — no record can land after this), then
    /// promote the local model to `max(local epoch, highest epoch the
    /// dead primary reported) + 1`, sealing the inherited WAL position at
    /// `base_seq = applied_seq`. Consumes the replica — a promoted model
    /// must never tail again under its old identity. Returns `(epoch,
    /// sealed_base_seq)`.
    pub fn promote(mut self) -> Result<(u64, u64)> {
        self.shutdown();
        let floor = self.shared.source_epoch.load(Ordering::SeqCst);
        let r = self
            .local
            .call(Payload::Promote { min_epoch: floor })
            .context("replica: promote local model")?;
        if let Some(e) = r.error {
            bail!("replica: promote local model: {e}");
        }
        let stats = r.stats.context("promote reply carries stats")?;
        Ok((stats.epoch, stats.learn_seq))
    }

    /// Stop tailing and join the thread. The local model keeps serving.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Replica {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Sleep up to `total`, waking early when stop is signalled (keeps
/// [`Replica::stop`] prompt even mid-backoff).
fn sleep_interruptible(shared: &Shared, total: Duration) {
    let slice = Duration::from_millis(20);
    let mut left = total;
    while !shared.stop.load(Ordering::SeqCst) && left > Duration::ZERO {
        let d = left.min(slice);
        std::thread::sleep(d);
        left = left.saturating_sub(d);
    }
}

/// One connection attempt: bounded retry, negotiate, target the model,
/// bound reads so a half-dead primary cannot wedge the tailer.
fn connect(opts: &ReplicaOptions) -> Result<Client> {
    let mut client = Client::connect_with_retry(&opts.primary, 3, Duration::from_millis(50))?;
    client.set_timeout(Some(Duration::from_secs(5)))?;
    let (version, _, _) = client.hello()?;
    if !opts.model.is_empty() {
        if version < wire::WIRE_V2 {
            bail!(
                "primary at {} only speaks wire v{version}: cannot follow \
                 named model '{}'",
                opts.primary,
                opts.model
            );
        }
        client.set_model(&opts.model)?;
    }
    Ok(client)
}

fn tail_loop(local: Arc<Coordinator>, opts: ReplicaOptions, shared: Arc<Shared>) {
    let seed = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.subsec_nanos() as u64 ^ d.as_secs())
        .unwrap_or(0x5DEE_CE66);
    let mut rng = crate::util::Rng::new(seed ^ 0x7EA1);
    let base = opts.reconnect_base.max(Duration::from_millis(1));
    let mut backoff = base;
    while !shared.stop.load(Ordering::SeqCst) {
        let outcome = connect(&opts).and_then(|mut client| {
            shared.connected.store(true, Ordering::SeqCst);
            backoff = base;
            serve_connection(&local, &opts, &shared, &mut client)
        });
        shared.connected.store(false, Ordering::SeqCst);
        let e = match outcome {
            Ok(()) => break, // stop was signalled inside the tail loop
            Err(e) => e,
        };
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        shared.reconnects.fetch_add(1, Ordering::SeqCst);
        eprintln!(
            "replica: primary {} unavailable ({e:#}); serving the \
             last-converged state and retrying",
            opts.primary
        );
        // capped exponential backoff, full jitter in (backoff/2, backoff]
        let nanos = backoff.as_nanos() as u64;
        let jittered = nanos / 2 + rng.next_u64() % (nanos / 2 + 1);
        sleep_interruptible(&shared, Duration::from_nanos(jittered));
        backoff = (backoff * 2).min(opts.reconnect_max);
    }
    shared.connected.store(false, Ordering::SeqCst);
}

/// Tail one live connection until stop (Ok) or any failure (Err → the
/// caller reconnects with backoff).
fn serve_connection(
    local: &Coordinator,
    opts: &ReplicaOptions,
    shared: &Shared,
    client: &mut Client,
) -> Result<()> {
    while !shared.stop.load(Ordering::SeqCst) {
        // self-quiesce: if the local model's epoch rose above what it was
        // when tailing began, an OP_PROMOTE arrived over the wire — this
        // model is a primary now, and a primary must not apply another
        // primary's log
        let r = local.call(Payload::Stats).context("replica: local stats")?;
        if let Some(e) = r.error {
            bail!("replica: local stats: {e}");
        }
        let my_epoch = r.stats.map(|s| s.epoch).unwrap_or(0);
        if my_epoch > shared.epoch0.load(Ordering::SeqCst) {
            eprintln!(
                "replica: local model was promoted to epoch {my_epoch}; \
                 quiescing the tailer"
            );
            shared.stop.store(true, Ordering::SeqCst);
            return Ok(());
        }
        let after = shared.applied_seq.load(Ordering::SeqCst);
        let tail = match client.wal_tail(after) {
            Ok(t) => t,
            Err(e) => match e.downcast_ref::<ServerError>() {
                // the primary compacted past our position: re-sync from
                // its live image, then resume tailing
                Some(se) if se.msg.contains("snapshot-fetch") => {
                    bootstrap(local, shared, client)?;
                    continue;
                }
                // any other refusal (e.g. the primary keeps no WAL) is a
                // configuration problem — surface it and retreat to the
                // reconnect backoff instead of hammering
                Some(se) => bail!("primary refused wal-tail: {}", se.msg),
                None => return Err(e), // transport failure
            },
        };
        // divergence refusal: a tail source below the local epoch is a
        // stale old primary that lost a promotion race — applying its
        // records would fork the lineage. Refuse and retreat to backoff.
        if tail.epoch < my_epoch {
            shared.fenced.fetch_add(1, Ordering::SeqCst);
            bail!(
                "fenced stale primary: its epoch {} is below the local \
                 model's {my_epoch}; refusing its records",
                tail.epoch
            );
        }
        shared.source_epoch.fetch_max(tail.epoch, Ordering::SeqCst);
        let mut progressed = false;
        for rec in &tail.records {
            let have = shared.applied_seq.load(Ordering::SeqCst);
            if rec.seq <= have {
                continue; // duplicate from a re-poll; learns are idempotent to skip
            }
            if rec.seq != have + 1 {
                // a hole the protocol should never produce — resync rather
                // than silently diverge
                eprintln!(
                    "replica: learn-log gap (have {have}, next record is \
                     {}); re-bootstrapping from the primary's image",
                    rec.seq
                );
                bootstrap(local, shared, client)?;
                progressed = true;
                break;
            }
            let r = local
                .call(Payload::Learn(rec.features.clone(), rec.class as usize))
                .with_context(|| format!("replica: apply learn {}", rec.seq))?;
            if let Some(err) = r.error {
                bail!("replica: apply learn {}: {err}", rec.seq);
            }
            shared.applied_seq.store(rec.seq, Ordering::SeqCst);
            progressed = true;
        }
        if !progressed && tail.last_seq <= shared.applied_seq.load(Ordering::SeqCst) {
            // caught up: idle-poll (a budget-capped reply with last_seq
            // ahead of us re-polls immediately instead)
            sleep_interruptible(shared, opts.poll_interval);
        }
    }
    Ok(())
}

/// Pull the primary's live store and install it locally; tailing resumes
/// from the sequence the image captures.
fn bootstrap(local: &Coordinator, shared: &Shared, client: &mut Client) -> Result<()> {
    let (last_seq, image) = client.snapshot_fetch().context("replica: snapshot-fetch")?;
    let r = local
        .call(Payload::RestoreImage(image))
        .context("replica: install bootstrap image")?;
    if let Some(err) = r.error {
        bail!("replica: install bootstrap image: {err}");
    }
    shared.applied_seq.store(last_seq, Ordering::SeqCst);
    shared.bootstraps.fetch_add(1, Ordering::SeqCst);
    eprintln!("replica: bootstrapped from the primary's image at learn {last_seq}");
    Ok(())
}

/// Registry-convergence knobs for [`ModelSync`].
#[derive(Clone, Debug)]
pub struct ModelSyncOptions {
    /// the primary server's address (`host:port`)
    pub primary: String,
    /// how often the primary's model list is polled
    pub poll_interval: Duration,
    /// per-model tailer knobs for the replicas ModelSync spawns (the
    /// `primary` and `model` fields are overwritten per model)
    pub replica: ReplicaOptions,
}

impl ModelSyncOptions {
    /// Poll the primary's model list every 250ms with default tailer
    /// cadences.
    pub fn new(primary: impl Into<String>) -> ModelSyncOptions {
        let primary = primary.into();
        ModelSyncOptions {
            replica: ReplicaOptions::new(primary.clone()),
            primary,
            poll_interval: Duration::from_millis(250),
        }
    }
}

#[derive(Default)]
struct SyncShared {
    stop: AtomicBool,
    polls: AtomicU64,
    added: AtomicU64,
    removed: AtomicU64,
}

/// Registry-level replication: converge a local [`Registry`]'s model *set*
/// with a primary's, so runtime `OP_MODEL_ADD`/`OP_MODEL_REMOVE` mutations
/// propagate to followers.
///
/// One thread polls the primary's hello model list. A model the primary
/// hosts that the local registry lacks is added ([`Registry::add`] clones
/// the local default's configuration under the new name) and given its own
/// [`Replica`] tailer, so its knowledge converges too. A non-default local
/// model absent from the primary is torn down (tailer first, then
/// [`Registry::remove`]). The local *default* model is never touched in
/// either direction — it has its own tailer (or is itself the primary of
/// record) and [`Registry::remove`] refuses it anyway.
pub struct ModelSync {
    shared: Arc<SyncShared>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl ModelSync {
    /// Start converging `registry`'s model set with the primary's.
    pub fn start(registry: Arc<crate::serve::Registry>, opts: ModelSyncOptions) -> ModelSync {
        let shared = Arc::new(SyncShared::default());
        let sh = shared.clone();
        let thread = std::thread::Builder::new()
            .name("clo-hdnn-modelsync".into())
            .spawn(move || sync_loop(registry, opts, sh))
            .expect("spawn modelsync thread");
        ModelSync { shared, thread: Some(thread) }
    }

    /// `(polls, models_added, models_removed)` so far.
    pub fn counters(&self) -> (u64, u64, u64) {
        (
            self.shared.polls.load(Ordering::SeqCst),
            self.shared.added.load(Ordering::SeqCst),
            self.shared.removed.load(Ordering::SeqCst),
        )
    }

    /// Stop polling and join the thread (per-model tailers stop too).
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ModelSync {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn sync_loop(
    registry: Arc<crate::serve::Registry>,
    opts: ModelSyncOptions,
    shared: Arc<SyncShared>,
) {
    let mut tailers: std::collections::HashMap<String, Replica> = std::collections::HashMap::new();
    while !shared.stop.load(Ordering::SeqCst) {
        if let Err(e) = sync_once(&registry, &opts, &shared, &mut tailers) {
            eprintln!("modelsync: primary {} unreachable ({e:#}); retrying", opts.primary);
        }
        shared.polls.fetch_add(1, Ordering::SeqCst);
        sleep_sync(&shared, opts.poll_interval);
    }
    // explicit teardown order: tailers before their coordinators go away
    // with the registry the caller still holds
    for (_, r) in tailers.drain() {
        r.stop();
    }
}

/// One poll: fetch the primary's model list and apply the set difference.
fn sync_once(
    registry: &Arc<crate::serve::Registry>,
    opts: &ModelSyncOptions,
    shared: &SyncShared,
    tailers: &mut std::collections::HashMap<String, Replica>,
) -> Result<()> {
    let mut client = Client::connect(&opts.primary)?;
    client.set_timeout(Some(Duration::from_secs(5)))?;
    let (version, _, remote) = client.hello()?;
    if version < wire::WIRE_V2 {
        bail!("primary at {} only speaks wire v{version}: no model list to sync", opts.primary);
    }
    drop(client);
    let default = registry.default_name().to_string();
    let local = registry.names();
    // additions: every primary model the local registry lacks
    for name in remote.iter().filter(|n| **n != default && !local.contains(n)) {
        // clone the local default's configuration — geometry must match the
        // primary's anyway for the tailer's bootstrap image to install
        match registry.add(name, "") {
            Ok(_) => {
                shared.added.fetch_add(1, Ordering::SeqCst);
                eprintln!("modelsync: added model '{name}' from the primary's list");
            }
            Err(e) => {
                eprintln!("modelsync: cannot add model '{name}': {e:#}");
                continue;
            }
        }
        match registry.get(name) {
            Ok(coord) => {
                let mut ropts = opts.replica.clone();
                ropts.primary = opts.primary.clone();
                ropts.model = name.clone();
                match Replica::start(coord, ropts) {
                    Ok(r) => {
                        tailers.insert(name.clone(), r);
                    }
                    Err(e) => eprintln!("modelsync: cannot tail model '{name}': {e:#}"),
                }
            }
            Err(e) => eprintln!("modelsync: added model '{name}' vanished: {e:#}"),
        }
    }
    // removals: every non-default local model the primary no longer hosts
    for name in local.iter().filter(|n| **n != default && !remote.contains(n)) {
        if let Some(r) = tailers.remove(name) {
            r.stop();
        }
        match registry.remove(name) {
            Ok(_) => {
                shared.removed.fetch_add(1, Ordering::SeqCst);
                eprintln!("modelsync: removed model '{name}' (dropped by the primary)");
            }
            Err(e) => eprintln!("modelsync: cannot remove model '{name}': {e:#}"),
        }
    }
    Ok(())
}

/// Sleep up to `total`, waking early on stop (keeps [`ModelSync::stop`]
/// prompt).
fn sleep_sync(shared: &SyncShared, total: Duration) {
    let slice = Duration::from_millis(20);
    let mut left = total;
    while !shared.stop.load(Ordering::SeqCst) && left > Duration::ZERO {
        let d = left.min(slice);
        std::thread::sleep(d);
        left = left.saturating_sub(d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HdConfig;
    use crate::coordinator::CoordinatorOptions;
    use crate::serve::{ModelSpec, Registry, ServeOptions, Server};
    use crate::util::Rng;
    use std::time::Instant;

    fn protos(cfg: &HdConfig) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(91);
        (0..cfg.classes)
            .map(|_| (0..cfg.features()).map(|_| rng.normal_f32() * 40.0).collect())
            .collect()
    }

    fn wait_until(mut f: impl FnMut() -> bool, ms: u64) -> bool {
        let deadline = Instant::now() + Duration::from_millis(ms);
        while Instant::now() < deadline {
            if f() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        f()
    }

    fn test_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("clo_hdnn_replica_{name}"));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn follower_tails_learns_and_keeps_serving_when_the_primary_dies() {
        let dir = test_dir("tail");
        let wal = dir.join("p.clog");
        let _ = std::fs::remove_file(&wal);
        let cfg = HdConfig::synthetic("t", 8, 8, 32, 32, 8, 4);
        let mut popts = CoordinatorOptions::software(cfg.clone());
        popts.wal_path = Some(wal);
        let registry = Registry::start(vec![ModelSpec::new("m", popts)]).unwrap();
        let server = Server::start("127.0.0.1:0", registry, ServeOptions::default()).unwrap();
        let addr = server.local_addr().to_string();

        // learns that land before the follower exists
        let mut c = Client::connect(&addr).unwrap();
        let ps = protos(&cfg);
        for (cls, p) in ps.iter().enumerate() {
            c.learn(p, cls).unwrap();
        }

        let follower = Arc::new(
            Coordinator::start(CoordinatorOptions::software(cfg.clone())).unwrap(),
        );
        let mut ropts = ReplicaOptions::new(addr.clone());
        ropts.poll_interval = Duration::from_millis(5);
        let replica = Replica::start(follower.clone(), ropts).unwrap();
        assert!(
            wait_until(|| replica.status().applied_seq == ps.len() as u64, 5000),
            "follower never caught up: {:?}",
            replica.status()
        );

        // learns that stream in while the follower is live
        for (cls, p) in ps.iter().enumerate() {
            c.learn(p, cls).unwrap();
        }
        assert!(
            wait_until(|| replica.status().applied_seq == 2 * ps.len() as u64, 5000),
            "follower fell behind: {:?}",
            replica.status()
        );
        assert!(replica.status().connected);

        // the follower's local stats report its applied sequence
        let s = follower.call(Payload::Stats).unwrap().stats.unwrap();
        assert_eq!(s.learns, 2 * ps.len() as u64);
        assert_eq!(s.learn_seq, 2 * ps.len() as u64);

        // the follower serves the primary's knowledge...
        for (cls, p) in ps.iter().enumerate() {
            let r = follower.call(Payload::Features(p.clone())).unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
            assert_eq!(r.class, Some(cls));
        }

        // ...and keeps serving it after the primary dies
        drop(c);
        server.stop();
        assert!(wait_until(|| !replica.status().connected, 5000));
        for (cls, p) in ps.iter().enumerate() {
            let r = follower.call(Payload::Features(p.clone())).unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
            assert_eq!(r.class, Some(cls), "degraded serving must stay converged");
        }
        assert_eq!(replica.status().applied_seq, 2 * ps.len() as u64);
        replica.stop();
    }

    #[test]
    fn follower_bootstraps_from_the_image_when_the_log_was_compacted() {
        let dir = test_dir("boot");
        let wal = dir.join("p.clog");
        let snap = dir.join("p.clok");
        let _ = std::fs::remove_file(&wal);
        let _ = std::fs::remove_file(&snap);
        let cfg = HdConfig::synthetic("t", 8, 8, 32, 32, 8, 4);
        let mut popts = CoordinatorOptions::software(cfg.clone());
        popts.wal_path = Some(wal);
        popts.snapshot_path = Some(snap);
        let registry = Registry::start(vec![ModelSpec::new("m", popts)]).unwrap();
        let server = Server::start("127.0.0.1:0", registry, ServeOptions::default()).unwrap();
        let addr = server.local_addr().to_string();

        let mut c = Client::connect(&addr).unwrap();
        let ps = protos(&cfg);
        for (cls, p) in ps.iter().enumerate() {
            c.learn(p, cls).unwrap();
        }
        // snapshotting to the configured default rotates the log: a tail
        // from sequence 0 now has to bootstrap
        c.snapshot(None).unwrap();

        let follower = Arc::new(
            Coordinator::start(CoordinatorOptions::software(cfg.clone())).unwrap(),
        );
        let mut ropts = ReplicaOptions::new(addr.clone());
        ropts.poll_interval = Duration::from_millis(5);
        let replica = Replica::start(follower.clone(), ropts).unwrap();
        assert!(
            wait_until(|| replica.status().applied_seq == ps.len() as u64, 5000),
            "follower never bootstrapped: {:?}",
            replica.status()
        );
        assert!(replica.status().bootstraps >= 1, "{:?}", replica.status());

        // post-bootstrap learns still tail through
        for (cls, p) in ps.iter().enumerate() {
            c.learn(p, cls).unwrap();
        }
        assert!(
            wait_until(|| replica.status().applied_seq == 2 * ps.len() as u64, 5000),
            "follower fell behind after bootstrap: {:?}",
            replica.status()
        );
        for (cls, p) in ps.iter().enumerate() {
            let r = follower.call(Payload::Features(p.clone())).unwrap();
            assert_eq!(r.class, Some(cls));
        }
        replica.stop();
        server.stop();
    }

    #[test]
    fn follower_reports_disconnected_and_reconnects_when_the_primary_returns() {
        let dir = test_dir("reconnect");
        let wal = dir.join("p.clog");
        let _ = std::fs::remove_file(&wal);
        let cfg = HdConfig::synthetic("t", 8, 8, 32, 32, 8, 4);
        let ps = protos(&cfg);

        // start the follower first: no primary yet, so it degrades
        let follower = Arc::new(
            Coordinator::start(CoordinatorOptions::software(cfg.clone())).unwrap(),
        );
        // an ephemeral port we then bind for real below is racy; instead
        // bind-and-drop to reserve a likely-free port number
        let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap().to_string();
        drop(probe);

        let mut ropts = ReplicaOptions::new(addr.clone());
        ropts.poll_interval = Duration::from_millis(5);
        ropts.reconnect_base = Duration::from_millis(20);
        ropts.reconnect_max = Duration::from_millis(100);
        let replica = Replica::start(follower.clone(), ropts).unwrap();
        assert!(
            wait_until(|| replica.status().reconnects >= 1, 5000),
            "no reconnect attempts recorded: {:?}",
            replica.status()
        );
        assert!(!replica.status().connected);

        // the primary comes up on that address with learns to offer
        let mut popts = CoordinatorOptions::software(cfg.clone());
        popts.wal_path = Some(wal);
        let registry = Registry::start(vec![ModelSpec::new("m", popts)]).unwrap();
        let server = match Server::start(&addr, registry, ServeOptions::default()) {
            Ok(s) => s,
            // the reserved port was taken in the interim: extremely rare,
            // and the degradation half of the test already passed
            Err(_) => {
                replica.stop();
                return;
            }
        };
        let mut c = Client::connect(&addr).unwrap();
        for (cls, p) in ps.iter().enumerate() {
            c.learn(p, cls).unwrap();
        }
        assert!(
            wait_until(|| replica.status().applied_seq == ps.len() as u64, 10_000),
            "follower never converged after the primary returned: {:?}",
            replica.status()
        );
        assert!(replica.status().connected);
        replica.stop();
        server.stop();
    }
}
