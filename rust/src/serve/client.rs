//! Blocking TCP client for the [`wire`](crate::serve::wire) protocol —
//! what `clo_hdnn loadgen` drives and the integration tests talk through.
//!
//! A fresh [`Client`] speaks wire v1 (single implicit model, one request
//! in flight per call). Calling [`Client::hello`] (or connecting with
//! [`Client::connect_v2`]) negotiates wire v2, which unlocks model
//! targeting ([`Client::set_model`]) and pipelining: the low-level
//! [`Client::send_for`] / [`Client::recv`] pair lets a caller keep many
//! client-id'd requests in flight on one connection and collect replies in
//! whatever order the server's model executors complete them.
//!
//! ```no_run
//! use clo_hdnn::serve::{Client, ReqBody};
//!
//! # fn main() -> clo_hdnn::Result<()> {
//! // blocking, one model
//! let mut c = Client::connect("127.0.0.1:7311")?;
//! c.learn(&[0.0; 64], 3)?;
//! let reply = c.infer(&[0.0; 64])?;
//! println!("class {} in {} segments", reply.class, reply.segments_used);
//!
//! // pipelined, two models on one connection
//! let mut c = Client::connect_v2("127.0.0.1:7311")?;
//! let a = c.send_for("tiny", ReqBody::Infer { mode: 0, features: vec![0.0; 64] })?;
//! let b = c.send_for("isolet", ReqBody::Infer { mode: 0, features: vec![0.0; 640] })?;
//! for _ in 0..2 {
//!     let resp = c.recv()?; // match resp.id() against a and b
//!     assert!(resp.id() == a || resp.id() == b);
//! }
//! # Ok(())
//! # }
//! ```

use crate::hdc::wal::WalRecord;
use crate::hdc::SearchMode;
use crate::serve::wire::{self, ReqBody, WireConnStats, WireRequest, WireResponse, WireStats};
use crate::Result;
use anyhow::{bail, Context};
use std::io::BufReader;
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// A server-reported request failure: the echoed request id plus the
/// server-side detail string. Carried inside the `anyhow` error chain so
/// callers can `downcast_ref::<ServerError>()` to tell a server-side
/// refusal apart from transport failure.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServerError {
    /// the id of the request that failed (0 when the server could not
    /// recover one from the frame)
    pub id: u64,
    /// server-side error detail
    pub msg: String,
}

impl std::fmt::Display for ServerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "server error (request {}): {}", self.id, self.msg)
    }
}

impl std::error::Error for ServerError {}

/// A receive deadline expired with no reply frame (only produced after
/// [`Client::set_timeout`]). Carried inside the `anyhow` chain so callers
/// — loadgen's per-connection timeout accounting, most importantly — can
/// `downcast_ref::<RecvTimeout>()` to tell a timeout apart from transport
/// failure or a [`ServerError`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RecvTimeout {
    /// the configured deadline that expired
    pub after: Duration,
}

impl std::fmt::Display for RecvTimeout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "no reply within {:?}", self.after)
    }
}

impl std::error::Error for RecvTimeout {}

/// One learn-log tail reply over the wire (see [`Client::wal_tail`]).
#[derive(Clone, Debug, PartialEq)]
pub struct WalTailReply {
    /// the primary log segment's fold point: learns at or before this
    /// sequence live only in the snapshot the segment was rotated against
    pub base_seq: u64,
    /// the primary log's newest acknowledged sequence (the reply may stop
    /// short of it when byte-budget-capped — keep tailing until caught up)
    pub last_seq: u64,
    /// the serving model's promotion generation — a follower refuses a
    /// tail source whose epoch is below its own (stale-primary fencing)
    pub epoch: u64,
    /// the records newer than the request's `after`, oldest first
    pub records: Vec<WalRecord>,
}

/// One classification reply over the wire.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct InferReply {
    /// predicted class
    pub class: usize,
    /// progressive-search segments evaluated
    pub segments_used: usize,
    /// whether the search exited before the last segment
    pub early_exit: bool,
    /// whether the WCFE front-end ran for this query (normal mode)
    pub used_wcfe: bool,
    /// whether a confidence-policy bypass pass escalated into the WCFE
    pub escalated: bool,
    /// server-modeled energy of this query in joules (0 when the server
    /// has no energy accounting for the model)
    pub energy_j: f64,
}

/// A synchronous connection. The high-level calls (`infer`/`learn`/…)
/// keep one request in flight and match the reply by id; the low-level
/// `send_for`/`recv` pair exposes wire-v2 pipelining.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
    version: u32,
    model: String,
    timeout: Option<Duration>,
}

impl Client {
    /// Connect speaking wire v1 (served by the default model; call
    /// [`Client::hello`] to upgrade).
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            next_id: 1,
            version: wire::WIRE_V1,
            model: String::new(),
            timeout: None,
        })
    }

    /// Like [`Client::connect`], but retry a refused or unreachable server
    /// for up to `attempts` tries with exponential backoff and full jitter
    /// starting from `base_delay` (capped at 2 s per sleep) — the polite
    /// way to wait out a server that is still binding its port, or a
    /// replication primary that is restarting. The total worst-case wait
    /// is bounded; the last connect error is returned when every attempt
    /// fails.
    pub fn connect_with_retry(
        addr: &str,
        attempts: usize,
        base_delay: Duration,
    ) -> Result<Client> {
        const MAX_DELAY: Duration = Duration::from_secs(2);
        let attempts = attempts.max(1);
        let seed = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos() as u64 ^ d.as_secs())
            .unwrap_or(0x9E37_79B9);
        let mut rng = crate::util::Rng::new(seed ^ addr.len() as u64);
        let mut delay = base_delay.max(Duration::from_millis(1)).min(MAX_DELAY);
        let mut last = None;
        for attempt in 0..attempts {
            match Client::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => last = Some(e),
            }
            if attempt + 1 < attempts {
                // full jitter in (delay/2, delay]: concurrent retriers
                // (loadgen threads, follower tailers) spread out instead
                // of stampeding the listen backlog in lockstep
                let nanos = delay.as_nanos() as u64;
                let jittered = nanos / 2 + rng.next_u64() % (nanos / 2 + 1);
                std::thread::sleep(Duration::from_nanos(jittered));
                delay = (delay * 2).min(MAX_DELAY);
            }
        }
        Err(last
            .expect("attempts >= 1, so at least one connect ran")
            .context(format!("connect {addr}: still failing after {attempts} attempts")))
    }

    /// Bound every subsequent [`Client::recv`] (and the high-level calls
    /// built on it): when no reply frame arrives within `timeout`, recv
    /// fails with a typed [`RecvTimeout`] instead of waiting forever.
    /// `None` restores unbounded blocking reads.
    pub fn set_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.reader.get_ref().set_read_timeout(timeout)?;
        self.timeout = timeout;
        Ok(())
    }

    /// Connect and negotiate wire v2, failing if the server won't speak it.
    pub fn connect_v2(addr: &str) -> Result<Client> {
        let mut client = Client::connect(addr)?;
        let (version, _, _) = client.hello()?;
        if version < wire::WIRE_V2 {
            bail!("server at {addr} only speaks wire v{version}");
        }
        Ok(client)
    }

    /// Negotiate the wire version. Returns `(negotiated_version,
    /// default_model, models)`; all subsequent requests on this connection
    /// use the negotiated encoding.
    pub fn hello(&mut self) -> Result<(u32, String, Vec<String>)> {
        let id = self.id();
        let req = WireRequest::new(id, ReqBody::Hello { version: wire::WIRE_V2 });
        // hello is always v1-shaped: it is what negotiates v2
        wire::write_frame(&mut self.writer, &req.encode(wire::WIRE_V1)?)?;
        match self.recv_matching(id)? {
            WireResponse::Hello { version, default_model, models, .. } => {
                self.version = version;
                Ok((version, default_model, models))
            }
            other => bail!("unexpected reply to hello: {other:?}"),
        }
    }

    /// The connection's negotiated wire version.
    pub fn version(&self) -> u32 {
        self.version
    }

    /// Target a named model for subsequent requests (`""` = the server's
    /// default). Non-empty names need a negotiated wire v2 connection.
    pub fn set_model(&mut self, model: &str) -> Result<()> {
        if !model.is_empty() && self.version < wire::WIRE_V2 {
            bail!("model targeting needs wire v2: call hello() first");
        }
        self.model = model.to_string();
        Ok(())
    }

    /// The currently targeted model (`""` = server default).
    pub fn model(&self) -> &str {
        &self.model
    }

    /// The wire mode byte for an optional per-request search-kernel
    /// override.
    pub fn mode_byte(mode: Option<SearchMode>) -> u8 {
        match mode {
            None => wire::MODE_DEFAULT,
            Some(SearchMode::L1Int8) => wire::MODE_L1,
            Some(SearchMode::HammingPacked) => wire::MODE_PACKED,
        }
    }

    fn id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Low-level pipelined send targeting the client's current model;
    /// returns the assigned request id. Does not wait for the reply —
    /// collect it (and any other in-flight replies) with [`Client::recv`].
    pub fn send(&mut self, body: ReqBody) -> Result<u64> {
        let model = std::mem::take(&mut self.model);
        let result = self.send_for(&model, body);
        self.model = model;
        result
    }

    /// Low-level pipelined send targeting an explicit model (`""` = server
    /// default); returns the assigned request id.
    pub fn send_for(&mut self, model: &str, body: ReqBody) -> Result<u64> {
        if !model.is_empty() && self.version < wire::WIRE_V2 {
            bail!("model targeting needs wire v2: call hello() first");
        }
        let id = self.id();
        let req = if model.is_empty() {
            WireRequest::new(id, body)
        } else {
            WireRequest::for_model(id, model, body)
        };
        wire::write_frame(&mut self.writer, &req.encode(self.version)?)?;
        Ok(id)
    }

    /// Low-level pipelined receive: the next reply frame, whatever request
    /// it answers (replies may arrive out of order across models — match
    /// [`WireResponse::id`] against your in-flight ids). Server-side error
    /// replies are returned as [`WireResponse::Error`] *values* so a
    /// pipelined caller can attribute each failure to its request.
    pub fn recv(&mut self) -> Result<WireResponse> {
        loop {
            match wire::read_frame(&mut self.reader, wire::MAX_FRAME)? {
                wire::Frame::Idle => match self.timeout {
                    // the configured deadline passed at a frame boundary
                    Some(after) => return Err(RecvTimeout { after }.into()),
                    None => continue, // no read timeout set; defensive
                },
                wire::Frame::Eof => bail!("server closed the connection"),
                wire::Frame::Payload(p) => return WireResponse::decode(&p),
            }
        }
    }

    /// One-in-flight receive: the reply must answer `id`, and server-side
    /// errors become a typed [`ServerError`].
    fn recv_matching(&mut self, id: u64) -> Result<WireResponse> {
        let resp = self.recv()?;
        if resp.id() != id {
            bail!(
                "response id {} != request id {id} (pipelined replies must be \
                 collected with recv())",
                resp.id()
            );
        }
        match resp {
            WireResponse::Error { id, msg } => Err(ServerError { id, msg }.into()),
            other => Ok(other),
        }
    }

    fn call(&mut self, body: ReqBody) -> Result<WireResponse> {
        let id = self.send(body)?;
        self.recv_matching(id)
    }

    /// Classify with the server's default search mode (`mode: None`) or an
    /// explicit per-request kernel.
    pub fn infer_mode(
        &mut self,
        features: &[f32],
        mode: Option<SearchMode>,
    ) -> Result<InferReply> {
        let body = ReqBody::Infer {
            mode: Client::mode_byte(mode),
            features: features.to_vec(),
        };
        self.infer_call(body)
    }

    /// Classify with the server's default search mode.
    pub fn infer(&mut self, features: &[f32]) -> Result<InferReply> {
        self.infer_mode(features, None)
    }

    /// Classify a raw image: the server's mode policy decides whether the
    /// pixels run through the model's WCFE front-end (normal mode) or feed
    /// the HDC encoder directly (bypass), and the reply's `used_wcfe` /
    /// `escalated` flags report which path actually served it.
    pub fn infer_image_mode(
        &mut self,
        pixels: &[f32],
        mode: Option<SearchMode>,
    ) -> Result<InferReply> {
        let body = ReqBody::InferImage {
            mode: Client::mode_byte(mode),
            pixels: pixels.to_vec(),
        };
        self.infer_call(body)
    }

    /// Classify a raw image with the server's default search mode.
    pub fn infer_image(&mut self, pixels: &[f32]) -> Result<InferReply> {
        self.infer_image_mode(pixels, None)
    }

    fn infer_call(&mut self, body: ReqBody) -> Result<InferReply> {
        match self.call(body)? {
            WireResponse::Infer { class, segments, early, wcfe, escalated, energy_j, .. } => {
                Ok(InferReply {
                    class: class as usize,
                    segments_used: segments as usize,
                    early_exit: early,
                    used_wcfe: wcfe,
                    escalated,
                    energy_j,
                })
            }
            other => bail!("unexpected reply to infer: {other:?}"),
        }
    }

    /// Bundle a labeled sample into the targeted model's knowledge store.
    pub fn learn(&mut self, features: &[f32], class: usize) -> Result<()> {
        let body = ReqBody::Learn { class: class as u32, features: features.to_vec() };
        match self.call(body)? {
            WireResponse::Learn { .. } => Ok(()),
            other => bail!("unexpected reply to learn: {other:?}"),
        }
    }

    /// Bundle a labeled raw image: the server routes it through the
    /// model's WCFE front-end when its mode policy says images train in
    /// feature space.
    pub fn learn_image(&mut self, pixels: &[f32], class: usize) -> Result<()> {
        let body = ReqBody::LearnImage { class: class as u32, pixels: pixels.to_vec() };
        match self.call(body)? {
            WireResponse::Learn { .. } => Ok(()),
            other => bail!("unexpected reply to learn: {other:?}"),
        }
    }

    /// Ask the server to checkpoint the targeted model's knowledge;
    /// `None` uses the server's configured default path for that model.
    /// Returns the path written.
    pub fn snapshot(&mut self, path: Option<&str>) -> Result<String> {
        let body = ReqBody::Snapshot { path: path.unwrap_or("").to_string() };
        match self.call(body)? {
            WireResponse::Snapshot { path, .. } => Ok(path),
            other => bail!("unexpected reply to snapshot: {other:?}"),
        }
    }

    /// Server + targeted-model counters.
    pub fn stats(&mut self) -> Result<WireStats> {
        match self.call(ReqBody::Stats)? {
            WireResponse::Stats { stats, .. } => Ok(stats),
            other => bail!("unexpected reply to stats: {other:?}"),
        }
    }

    /// This connection's own reactor-side counters (answered by the
    /// server's event loop without crossing an executor — useful exactly
    /// when the executors are saturated).
    pub fn conn_stats(&mut self) -> Result<WireConnStats> {
        match self.call(ReqBody::ConnStats)? {
            WireResponse::ConnStats { stats, .. } => Ok(stats),
            other => bail!("unexpected reply to conn-stats: {other:?}"),
        }
    }

    /// Fetch the targeted model's learn-log records newer than `after`
    /// (replication tailing). Fails with a typed [`ServerError`] when the
    /// model keeps no WAL, or when `after` predates the log's fold point —
    /// re-bootstrap with [`Client::snapshot_fetch`] in the latter case.
    pub fn wal_tail(&mut self, after: u64) -> Result<WalTailReply> {
        match self.call(ReqBody::WalTail { after })? {
            WireResponse::WalTail { base_seq, last_seq, epoch, records, .. } => {
                Ok(WalTailReply { base_seq, last_seq, epoch, records })
            }
            other => bail!("unexpected reply to wal-tail: {other:?}"),
        }
    }

    /// Promote the targeted model to a new epoch (follower promotion: the
    /// model seals its inherited WAL position under `epoch = old + 1` and
    /// serves learns as the new primary generation). Returns `(epoch,
    /// sealed_base_seq)`.
    pub fn promote(&mut self) -> Result<(u64, u64)> {
        match self.call(ReqBody::Promote)? {
            WireResponse::Promote { epoch, base_seq, .. } => Ok((epoch, base_seq)),
            other => bail!("unexpected reply to promote: {other:?}"),
        }
    }

    /// Spin up a new model named `name` on the server at runtime, cloning
    /// the executor configuration of `source` (`""` = the server's default
    /// model). Returns the post-mutation model list.
    pub fn model_add(&mut self, name: &str, source: &str) -> Result<Vec<String>> {
        let body = ReqBody::ModelAdd { name: name.to_string(), source: source.to_string() };
        match self.call(body)? {
            WireResponse::ModelAdmin { models, .. } => Ok(models),
            other => bail!("unexpected reply to model-add: {other:?}"),
        }
    }

    /// Tear down the named model on the server at runtime (the server's
    /// default model is refused). Returns the post-mutation model list.
    pub fn model_remove(&mut self, name: &str) -> Result<Vec<String>> {
        match self.call(ReqBody::ModelRemove { name: name.to_string() })? {
            WireResponse::ModelAdmin { models, .. } => Ok(models),
            other => bail!("unexpected reply to model-remove: {other:?}"),
        }
    }

    /// Fetch the targeted model's live knowledge store as CLOK checkpoint
    /// bytes plus the learn sequence the image captures (replication
    /// bootstrap; feed the bytes to a local restore, then tail from the
    /// returned sequence).
    pub fn snapshot_fetch(&mut self) -> Result<(u64, Vec<u8>)> {
        match self.call(ReqBody::SnapshotFetch)? {
            WireResponse::SnapshotImage { last_seq, image, .. } => Ok((last_seq, image)),
            other => bail!("unexpected reply to snapshot-fetch: {other:?}"),
        }
    }
}

/// [`Fleet`] knobs.
#[derive(Clone, Debug)]
pub struct FleetOptions {
    /// model every fleet request targets (`""` = each server's default)
    pub model: String,
    /// re-probe cadence: requests arriving later than this after the last
    /// probe refresh every endpoint's health/epoch/learn_seq view first
    pub probe_interval: Duration,
    /// staleness bound for reads: an endpoint is read-eligible only when
    /// its probed `learn_seq` is within this many learns of the most
    /// advanced live endpoint (`u64::MAX` = read anywhere alive)
    pub staleness: u64,
    /// attempts per request across the fleet before the last error is
    /// surfaced (each failed attempt marks its endpoint dead, re-probes,
    /// and backs off)
    pub retry_budget: usize,
    /// first inter-attempt backoff (doubles per retry, deterministic)
    pub backoff_base: Duration,
    /// backoff cap
    pub backoff_max: Duration,
    /// per-connection receive deadline (a hung endpoint fails fast and
    /// the request retries elsewhere)
    pub timeout: Duration,
}

impl Default for FleetOptions {
    fn default() -> Self {
        FleetOptions {
            model: String::new(),
            probe_interval: Duration::from_millis(250),
            staleness: u64::MAX,
            retry_budget: 3,
            backoff_base: Duration::from_millis(10),
            backoff_max: Duration::from_millis(500),
            timeout: Duration::from_secs(2),
        }
    }
}

/// One endpoint's last-probed view, as reported by
/// [`Fleet::target_reports`] (what `loadgen --fleet` attributes per-target
/// results with).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FleetTargetReport {
    /// the endpoint address as given to [`Fleet::connect`]
    pub addr: String,
    /// whether the last contact (probe or request) succeeded
    pub alive: bool,
    /// the endpoint's promotion generation at the last good probe
    pub epoch: u64,
    /// the endpoint's learn sequence at the last good probe
    pub learn_seq: u64,
    /// requests this endpoint answered successfully
    pub served: u64,
    /// requests (and probes) attributed to this endpoint as failures
    pub errors: u64,
}

/// One fleet member: a lazily-(re)connected client plus its probed view.
struct Endpoint {
    addr: String,
    client: Option<Client>,
    alive: bool,
    epoch: u64,
    learn_seq: u64,
    served: u64,
    errors: u64,
}

impl Endpoint {
    /// The connected client, dialing (without retry — the fleet's retry
    /// budget is the retry loop) when there is none.
    fn client(&mut self, opts: &FleetOptions) -> Result<&mut Client> {
        if self.client.is_none() {
            let mut c = Client::connect(&self.addr)?;
            c.set_timeout(Some(opts.timeout))?;
            if !opts.model.is_empty() {
                c.hello()?;
                c.set_model(&opts.model)?;
            }
            self.client = Some(c);
        }
        Ok(self.client.as_mut().expect("just connected"))
    }

    /// Mark a failed contact: drop the connection so the next attempt
    /// redials, and attribute the error here.
    fn mark_dead(&mut self) {
        self.client = None;
        self.alive = false;
        self.errors += 1;
    }
}

/// A health-checked multi-endpoint client: wraps N servers replicating one
/// model, probes each with `OP_STATS` on a fixed cadence, routes learns to
/// the current primary — the live endpoint with the highest `(epoch,
/// learn_seq)`, re-discovered automatically after a follower promotion —
/// and spreads staleness-bounded reads round-robin over the live endpoints
/// whose probed `learn_seq` is close enough to the freshest one. Every
/// request carries a retry budget with capped deterministic backoff; each
/// failed attempt is attributed to its endpoint and the next attempt
/// re-routes. Probing is synchronous (driven from the request path when
/// the probe interval has elapsed), so a single-threaded caller — the
/// chaos tests, most importantly — sees a deterministic sequence of probes
/// and routes.
pub struct Fleet {
    endpoints: Vec<Endpoint>,
    opts: FleetOptions,
    last_probe: Option<Instant>,
    rr: usize,
}

/// The primary's slot among `(alive, epoch, learn_seq)` endpoint views:
/// the live endpoint with the highest `(epoch, learn_seq)`, lowest slot on
/// ties (deterministic routing).
fn pick_primary(views: &[(bool, u64, u64)]) -> Option<usize> {
    views
        .iter()
        .enumerate()
        .filter(|(_, v)| v.0)
        .max_by(|(ia, a), (ib, b)| (a.1, a.2, std::cmp::Reverse(*ia)).cmp(&(b.1, b.2, std::cmp::Reverse(*ib))))
        .map(|(i, _)| i)
}

/// The read-eligible slots among `(alive, epoch, learn_seq)` views: live
/// endpoints whose `learn_seq` is within `staleness` of the most advanced
/// live endpoint's.
fn eligible_reads(views: &[(bool, u64, u64)], staleness: u64) -> Vec<usize> {
    let freshest = views.iter().filter(|v| v.0).map(|v| v.2).max().unwrap_or(0);
    views
        .iter()
        .enumerate()
        .filter(|(_, v)| v.0 && freshest.saturating_sub(v.2) <= staleness)
        .map(|(i, _)| i)
        .collect()
}

impl Fleet {
    /// Wrap the given endpoints and run one initial probe sweep. Fails
    /// only on an empty list — a fleet whose members are all down connects
    /// fine and reports every request as exhausting its retry budget,
    /// which is what a failover harness wants to observe.
    pub fn connect(addrs: &[String], opts: FleetOptions) -> Result<Fleet> {
        if addrs.is_empty() {
            bail!("a fleet needs at least one endpoint");
        }
        let endpoints = addrs
            .iter()
            .map(|a| Endpoint {
                addr: a.clone(),
                client: None,
                alive: false,
                epoch: 0,
                learn_seq: 0,
                served: 0,
                errors: 0,
            })
            .collect();
        let mut fleet = Fleet { endpoints, opts, last_probe: None, rr: 0 };
        fleet.probe();
        Ok(fleet)
    }

    /// Probe every endpoint now: one `OP_STATS` round-trip each, updating
    /// `alive`/`epoch`/`learn_seq` (dead endpoints get a reconnect
    /// attempt — this is also the path that re-discovers a restarted
    /// server).
    pub fn probe(&mut self) {
        for ep in &mut self.endpoints {
            let stats = ep.client(&self.opts).and_then(|c| c.stats());
            match stats {
                Ok(s) => {
                    ep.alive = true;
                    ep.epoch = s.epoch;
                    ep.learn_seq = s.learn_seq;
                }
                Err(_) => ep.mark_dead(),
            }
        }
        self.last_probe = Some(Instant::now());
    }

    fn maybe_probe(&mut self) {
        let due = match self.last_probe {
            None => true,
            Some(t) => t.elapsed() >= self.opts.probe_interval,
        };
        if due {
            self.probe();
        }
    }

    /// The current primary's address, if any endpoint is live.
    pub fn primary(&self) -> Option<&str> {
        pick_primary(&self.views()).map(|i| self.endpoints[i].addr.as_str())
    }

    fn views(&self) -> Vec<(bool, u64, u64)> {
        self.endpoints.iter().map(|e| (e.alive, e.epoch, e.learn_seq)).collect()
    }

    /// Per-endpoint health/attribution snapshot (loadgen's `targets`
    /// array).
    pub fn target_reports(&self) -> Vec<FleetTargetReport> {
        self.endpoints
            .iter()
            .map(|e| FleetTargetReport {
                addr: e.addr.clone(),
                alive: e.alive,
                epoch: e.epoch,
                learn_seq: e.learn_seq,
                served: e.served,
                errors: e.errors,
            })
            .collect()
    }

    /// Bundle a labeled sample on the current primary, failing over (and
    /// re-discovering the primary by epoch) within the retry budget.
    pub fn learn(&mut self, features: &[f32], class: usize) -> Result<()> {
        self.with_retries(|fleet| pick_primary(&fleet.views()).into_iter().collect(), |c| {
            c.learn(features, class)
        })
    }

    /// Classify on any live, staleness-eligible endpoint (round-robin),
    /// failing over within the retry budget.
    pub fn infer(&mut self, features: &[f32]) -> Result<InferReply> {
        let staleness = self.opts.staleness;
        self.with_retries(
            move |fleet| eligible_reads(&fleet.views(), staleness),
            |c| c.infer(features),
        )
    }

    /// Stats from the current primary (what the failover drill gates
    /// learn-seq continuity on), with fleet retry semantics.
    pub fn primary_stats(&mut self) -> Result<WireStats> {
        self.with_retries(|fleet| pick_primary(&fleet.views()).into_iter().collect(), |c| c.stats())
    }

    /// The retry engine: pick candidate slots, try the round-robin next
    /// one, attribute failures, re-probe, back off deterministically, and
    /// repeat within the budget.
    fn with_retries<T>(
        &mut self,
        candidates: impl Fn(&Fleet) -> Vec<usize>,
        mut attempt: impl FnMut(&mut Client) -> Result<T>,
    ) -> Result<T> {
        let budget = self.opts.retry_budget.max(1);
        let mut backoff = self.opts.backoff_base.max(Duration::from_millis(1));
        let mut last: Option<anyhow::Error> = None;
        for tries in 0..budget {
            self.maybe_probe();
            let slots = candidates(self);
            if slots.is_empty() {
                last = Some(anyhow::anyhow!("no live fleet endpoint is eligible"));
            } else {
                let slot = slots[self.rr % slots.len()];
                self.rr = self.rr.wrapping_add(1);
                let ep = &mut self.endpoints[slot];
                match ep.client(&self.opts).and_then(&mut attempt) {
                    Ok(v) => {
                        ep.served += 1;
                        return Ok(v);
                    }
                    Err(e) => {
                        // a server-side refusal (e.g. unknown class) is the
                        // caller's error, not the endpoint's death
                        if e.downcast_ref::<ServerError>().is_none() {
                            ep.mark_dead();
                        } else {
                            ep.errors += 1;
                            return Err(e);
                        }
                        last = Some(e);
                    }
                }
            }
            if tries + 1 < budget {
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(self.opts.backoff_max);
                // failures invalidate the probed view — refresh before the
                // next routing decision instead of waiting out the cadence
                self.last_probe = None;
            }
        }
        Err(last
            .expect("budget >= 1, so at least one attempt ran")
            .context(format!("fleet request failed after {budget} attempts")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primary_is_highest_epoch_then_learn_seq_then_lowest_slot() {
        // epoch dominates learn_seq: the promoted follower at slot 2 wins
        // even though the stale old primary at slot 0 has more learns
        let views = [(true, 0, 900), (false, 0, 0), (true, 1, 40)];
        assert_eq!(pick_primary(&views), Some(2));
        // equal epochs: learn_seq decides
        let views = [(true, 1, 10), (true, 1, 40)];
        assert_eq!(pick_primary(&views), Some(1));
        // full tie: lowest slot, deterministically
        let views = [(true, 1, 40), (true, 1, 40)];
        assert_eq!(pick_primary(&views), Some(0));
        // dead endpoints never win; an all-dead fleet has no primary
        assert_eq!(pick_primary(&[(false, 9, 9)]), None);
        assert_eq!(pick_primary(&[]), None);
    }

    #[test]
    fn read_eligibility_is_staleness_bounded() {
        let views = [(true, 0, 100), (true, 0, 95), (true, 0, 80), (false, 0, 100)];
        // tight bound: only the freshest live endpoints qualify
        assert_eq!(eligible_reads(&views, 5), vec![0, 1]);
        assert_eq!(eligible_reads(&views, 0), vec![0]);
        // unbounded: every live endpoint qualifies (never the dead one)
        assert_eq!(eligible_reads(&views, u64::MAX), vec![0, 1, 2]);
        assert!(eligible_reads(&[(false, 0, 1)], u64::MAX).is_empty());
    }
}
