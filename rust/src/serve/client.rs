//! Blocking TCP client for the [`wire`](crate::serve::wire) protocol —
//! what `clo_hdnn loadgen` drives and the integration tests talk through.

use crate::hdc::SearchMode;
use crate::serve::wire::{self, WireRequest, WireResponse, WireStats};
use crate::Result;
use anyhow::{bail, Context};
use std::io::BufReader;
use std::net::TcpStream;

/// One classification reply over the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct InferReply {
    pub class: usize,
    pub segments_used: usize,
    pub early_exit: bool,
}

/// A synchronous connection: one in-flight request at a time, matched by id.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            next_id: 1,
        })
    }

    fn call(&mut self, req: WireRequest) -> Result<WireResponse> {
        let id = req.id();
        wire::write_frame(&mut self.writer, &req.encode())?;
        loop {
            match wire::read_frame(&mut self.reader, wire::MAX_FRAME)? {
                wire::Frame::Idle => continue, // no read timeout set; defensive
                wire::Frame::Eof => bail!("server closed the connection"),
                wire::Frame::Payload(p) => {
                    let resp = WireResponse::decode(&p)?;
                    if resp.id() != id {
                        bail!("response id {} != request id {id}", resp.id());
                    }
                    if let WireResponse::Error { msg, .. } = &resp {
                        bail!("server error: {msg}");
                    }
                    return Ok(resp);
                }
            }
        }
    }

    fn id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Classify with the server's default search mode (`mode: None`) or an
    /// explicit per-request kernel.
    pub fn infer_mode(
        &mut self,
        features: &[f32],
        mode: Option<SearchMode>,
    ) -> Result<InferReply> {
        let id = self.id();
        let mode = match mode {
            None => wire::MODE_DEFAULT,
            Some(SearchMode::L1Int8) => wire::MODE_L1,
            Some(SearchMode::HammingPacked) => wire::MODE_PACKED,
        };
        match self.call(WireRequest::Infer { id, mode, features: features.to_vec() })? {
            WireResponse::Infer { class, segments, early, .. } => Ok(InferReply {
                class: class as usize,
                segments_used: segments as usize,
                early_exit: early,
            }),
            other => bail!("unexpected reply to infer: {other:?}"),
        }
    }

    pub fn infer(&mut self, features: &[f32]) -> Result<InferReply> {
        self.infer_mode(features, None)
    }

    /// Bundle a labeled sample into the server's knowledge store.
    pub fn learn(&mut self, features: &[f32], class: usize) -> Result<()> {
        let id = self.id();
        match self.call(WireRequest::Learn {
            id,
            class: class as u32,
            features: features.to_vec(),
        })? {
            WireResponse::Learn { .. } => Ok(()),
            other => bail!("unexpected reply to learn: {other:?}"),
        }
    }

    /// Ask the server to checkpoint its knowledge store; `None` uses the
    /// server's configured default path. Returns the path written.
    pub fn snapshot(&mut self, path: Option<&str>) -> Result<String> {
        let id = self.id();
        match self.call(WireRequest::Snapshot {
            id,
            path: path.unwrap_or("").to_string(),
        })? {
            WireResponse::Snapshot { path, .. } => Ok(path),
            other => bail!("unexpected reply to snapshot: {other:?}"),
        }
    }

    pub fn stats(&mut self) -> Result<WireStats> {
        let id = self.id();
        match self.call(WireRequest::Stats { id })? {
            WireResponse::Stats { stats, .. } => Ok(stats),
            other => bail!("unexpected reply to stats: {other:?}"),
        }
    }
}
